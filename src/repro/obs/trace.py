"""Span tracing: one request's journey, reconstructable as a tree.

A :class:`Span` is a named interval with a parent link and an optional
``rid`` (request id) correlation key. The router opens a root ``request``
span per rid; lifecycle transitions, dispatch attempts, prefill chunks and
decode steps open children under it — so a retried, fault-injected request
across two replicas reads as one tree:

    request rid=r3
    ├─ queued
    ├─ admitted            replica=0
    ├─ dispatch attempt=0  replica=0   (fault: raise)
    ├─ retry_backoff
    ├─ dispatch attempt=1  replica=1
    │  ├─ prefill_chunk …
    │  └─ decode …
    └─ done

Bounded by construction: completed spans land in a ``deque(maxlen=capacity)``
ring buffer (a long-running server cannot leak through its own telemetry —
the failure mode of the old append-only ``BatchServer.events`` list this
replaces). Spans still open when the ring wraps are kept until ended.

Time comes from the injected clock (defaults to the process clock in
:mod:`repro.obs`), so FakeClock-driven fault tests produce deterministic
timestamps. Export: :meth:`Tracer.to_jsonl` (one span per line) and
:meth:`Tracer.to_chrome_trace` (Chrome ``trace_event`` JSON — open in
https://ui.perfetto.dev, spans group per-rid as tracks).
"""
from __future__ import annotations

import json
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional


@dataclass
class Span:
    name: str
    sid: int
    parent: Optional[int] = None
    rid: Optional[str] = None
    t0: float = 0.0
    t1: Optional[float] = None        # None while still open
    attrs: Dict[str, Any] = field(default_factory=dict)

    @property
    def duration(self) -> float:
        return (self.t1 - self.t0) if self.t1 is not None else 0.0

    def to_dict(self) -> dict:
        d = {"name": self.name, "sid": self.sid, "t0": self.t0,
             "t1": self.t1}
        if self.parent is not None:
            d["parent"] = self.parent
        if self.rid is not None:
            d["rid"] = self.rid
        if self.attrs:
            d["attrs"] = self.attrs
        return d


class _SpanHandle:
    """Context-manager handle returned by :meth:`Tracer.span`."""

    __slots__ = ("tracer", "span")

    def __init__(self, tracer: "Tracer", span: Span):
        self.tracer = tracer
        self.span = span

    @property
    def sid(self) -> int:
        return self.span.sid

    def set(self, **attrs) -> "_SpanHandle":
        self.span.attrs.update(attrs)
        return self

    def end(self, **attrs) -> Span:
        if attrs:
            self.span.attrs.update(attrs)
        self.tracer.end(self.span)
        return self.span

    def __enter__(self) -> "_SpanHandle":
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is not None and "error" not in self.span.attrs:
            self.span.attrs["error"] = exc_type.__name__
        self.tracer.end(self.span)
        return False


class Tracer:
    """Ring-buffer span recorder. ``capacity`` bounds *completed* spans;
    open spans are tracked separately until ended."""

    def __init__(self, clock: Optional[Callable[[], float]] = None,
                 capacity: int = 4096):
        if clock is None:
            from repro.obs import default_clock
            clock = default_clock
        self.clock = clock
        self.capacity = capacity
        self.spans: deque[Span] = deque(maxlen=capacity)
        self._open: Dict[int, Span] = {}
        self._next_sid = 1
        self.dropped = 0                 # spans evicted by the ring

    # -- recording ----------------------------------------------------------
    def start(self, name: str, *, parent: Optional[int] = None,
              rid: Optional[str] = None, **attrs) -> Span:
        s = Span(name=name, sid=self._next_sid, parent=parent, rid=rid,
                 t0=self.clock(), attrs=dict(attrs))
        self._next_sid += 1
        self._open[s.sid] = s
        return s

    def end(self, span: Span, **attrs) -> Span:
        if attrs:
            span.attrs.update(attrs)
        if span.t1 is None:
            span.t1 = self.clock()
        self._open.pop(span.sid, None)
        if len(self.spans) == self.capacity:
            self.dropped += 1
        self.spans.append(span)
        return span

    def span(self, name: str, *, parent: Optional[int] = None,
             rid: Optional[str] = None, **attrs) -> _SpanHandle:
        return _SpanHandle(self, self.start(name, parent=parent, rid=rid,
                                            **attrs))

    def event(self, name: str, *, parent: Optional[int] = None,
              rid: Optional[str] = None, **attrs) -> Span:
        """Zero-duration span (a point annotation on the timeline)."""
        s = self.start(name, parent=parent, rid=rid, **attrs)
        s.t1 = s.t0
        return self.end(s)

    # -- queries ------------------------------------------------------------
    def completed(self, rid: Optional[str] = None) -> List[Span]:
        if rid is None:
            return list(self.spans)
        return [s for s in self.spans if s.rid == rid]

    def rids(self) -> List[str]:
        seen: Dict[str, None] = {}
        for s in self.spans:
            if s.rid is not None:
                seen.setdefault(s.rid, None)
        return list(seen)

    def span_tree(self, rid: str) -> Optional[dict]:
        """Reconstruct one request's spans as a nested dict tree.

        Root = the span named ``request`` for that rid (falls back to the
        earliest parentless span). Children sorted by start time; spans
        whose parent fell out of the ring attach to the root so the tree
        stays complete-at-the-top even under eviction. Returns None if the
        rid has no spans. Shape: ``{"name", "t0", "t1", "attrs",
        "children": [...]}``.
        """
        spans = self.completed(rid)
        if not spans:
            return None
        by_sid = {s.sid: s for s in spans}
        roots = [s for s in spans if s.name == "request"] or \
                [s for s in spans if s.parent is None or
                 s.parent not in by_sid]
        root = min(roots, key=lambda s: (s.t0, s.sid))
        children: Dict[int, List[Span]] = {}
        for s in spans:
            if s.sid == root.sid:
                continue
            p = s.parent if (s.parent in by_sid and s.parent != s.sid) \
                else root.sid
            children.setdefault(p, []).append(s)

        def build(s: Span) -> dict:
            kids = sorted(children.get(s.sid, []),
                          key=lambda c: (c.t0, c.sid))
            return {"name": s.name, "t0": s.t0, "t1": s.t1,
                    "attrs": s.attrs, "children": [build(k) for k in kids]}

        return build(root)

    # -- export -------------------------------------------------------------
    def to_jsonl(self) -> str:
        return "\n".join(json.dumps(s.to_dict(), sort_keys=True)
                         for s in self.spans) + ("\n" if self.spans else "")

    def to_chrome_trace(self, process_name: str = "repro.serve") -> dict:
        """Chrome ``trace_event`` format (Perfetto-viewable). Complete
        events (``ph: "X"``), µs timestamps; tid groups spans per rid so
        each request renders as its own track."""
        tids: Dict[str, int] = {}

        def tid_for(rid: Optional[str]) -> int:
            key = rid if rid is not None else "<untagged>"
            if key not in tids:
                tids[key] = len(tids) + 1
            return tids[key]

        events: List[dict] = []
        for s in self.spans:
            t1 = s.t1 if s.t1 is not None else s.t0
            args = dict(s.attrs)
            if s.rid is not None:
                args["rid"] = s.rid
            if s.parent is not None:
                args["parent"] = s.parent
            events.append({
                "name": s.name, "ph": "X", "pid": 1, "tid": tid_for(s.rid),
                "ts": round(s.t0 * 1e6, 3),
                "dur": round((t1 - s.t0) * 1e6, 3),
                "args": args,
            })
        meta = [{"name": "process_name", "ph": "M", "pid": 1, "tid": 0,
                 "args": {"name": process_name}}]
        meta += [{"name": "thread_name", "ph": "M", "pid": 1, "tid": t,
                  "args": {"name": f"rid {k}" if k != "<untagged>" else k}}
                 for k, t in sorted(tids.items(), key=lambda kv: kv[1])]
        return {"traceEvents": meta + events, "displayTimeUnit": "ms"}

    def write(self, path: str) -> None:
        """Write the trace to ``path``: ``.jsonl`` → JSON-lines, anything
        else → Chrome trace_event JSON."""
        if path.endswith(".jsonl"):
            body = self.to_jsonl()
        else:
            body = json.dumps(self.to_chrome_trace())
        with open(path, "w") as f:
            f.write(body)


def load_jsonl(path: str) -> List[Span]:
    """Inverse of :meth:`Tracer.to_jsonl` (used by the CI obs-smoke check)."""
    spans = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            spans.append(Span(name=d["name"], sid=d["sid"],
                              parent=d.get("parent"), rid=d.get("rid"),
                              t0=d["t0"], t1=d.get("t1"),
                              attrs=d.get("attrs", {})))
    return spans


def tree_from_spans(spans: List[Span], rid: str) -> Optional[dict]:
    """Span-tree reconstruction over a loaded span list (same semantics as
    :meth:`Tracer.span_tree`)."""
    t = Tracer(clock=time.monotonic, capacity=max(len(spans), 1))
    for s in spans:
        if s.rid == rid:
            t.spans.append(s)
    return t.span_tree(rid)
