"""falcon-mamba-7b [ssm]: 64L d_model=4096 (attn-free) vocab=65024,
ssm_state=16 — Mamba1 architecture. [arXiv:2410.05355; unverified]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1, d_ff=0,
    vocab=65024,
    ssm=SSMConfig(version=1, d_state=16, d_conv=4, expand=2, dt_rank=256,
                  chunk=256),
    tie_embeddings=True,
    supports_long_context=True,
)
