"""SLO burn-rate alerting (repro.obs.slo) and the router's SLO-driven
degradation controller:

* the one-line objective DSL (``parse``) and its validation;
* multi-window burn evaluation — PAGE needs fast AND slow burn with sample
  support, a single spike cannot flap the ladder, de-escalation waits out
  ``clear_s`` (asymmetric hysteresis);
* the controller ladder on a live router — burn-driven shed to int8 with
  the ``shed_queue_depth`` floor DISABLED (proving the SLO signal acts on
  its own), admission tightening to ``max_queue // tighten_factor`` visible
  as :class:`RejectedError`, and probe-back with hysteresis;
* the ISSUE-10 acceptance chaos loop: a flaky replica (raise/hang plan from
  serve.faults) under a deterministic FakeClock drives breach -> PAGE ->
  tighten+shed -> burn clears -> probe -> recover -> healthy, asserted
  end-to-end from the obs snapshot, the controller/alert event records, and
  the trace.
"""
import dataclasses

import jax
import numpy as np
import pytest

import repro.obs as obs
from repro import configs
from repro.models.model import build_model
from repro.obs import AlertState, Objective, Registry, SloMonitor, Tracer
from repro.serve import lifecycle as lc
from repro.serve.batcher import BatchServer, Request
from repro.serve.faults import FakeClock, FaultPlan
from repro.serve.router import (CTL_HEALTHY, CTL_TIGHTENED, ReplicaRouter,
                                RouterConfig)

MAX_LEN = 48
MAX_NEW = 4

_STATE = {}


def _setup():
    if not _STATE:
        cfg = configs.smoke_config(configs.get_config("minicpm-2b"))
        cfg = dataclasses.replace(cfg, attention_impl="naive")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        _STATE["m"] = (cfg, model, params)
    return _STATE["m"]


def _prompts(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, size=(int(l),))
            for l in rng.integers(3, 10, n)]


# -- objective DSL ------------------------------------------------------------

def test_objective_parse_dsl():
    o = Objective.parse("ttft_ms p99 < 200")
    assert (o.name, o.kind, o.quantile, o.threshold) == \
        ("ttft_ms", "latency", 0.99, 200.0)
    o = Objective.parse("itl_ms p50 < 1.5", fast_window_s=1.0,
                        slow_window_s=6.0)
    assert o.quantile == 0.5 and o.fast_window_s == 1.0
    e = Objective.parse("error_rate < 0.1")
    assert e.kind == "error_rate" and e.threshold == 0.1
    assert e.effective_clear_s == pytest.approx(e.slow_window_s / 3)
    for bad in ("ttft_ms 200", "p99 <", "error_rate p99 < 0.5", "x < -1",
                "ttft_ms p99 < 0"):
        with pytest.raises(ValueError):
            Objective.parse(bad)
    with pytest.raises(ValueError):
        Objective("x", 1.0, fast_window_s=30.0, slow_window_s=5.0)
    with pytest.raises(ValueError):
        Objective("x", 1.0, kind="throughput")


def test_monitor_rejects_duplicates_and_routes_by_kind():
    clock = FakeClock()
    r = Registry()
    with pytest.raises(ValueError):
        SloMonitor([Objective("a", 1.0), Objective("a", 2.0)],
                   registry=r, clock=clock)
    mon = SloMonitor([Objective("lat_ms", 100.0),
                      Objective("error_rate", 0.5, kind="error_rate")],
                     registry=r, clock=clock)
    # mismatched-kind and unknown-name feeds are silent no-ops (the router
    # feeds every objective name unconditionally)
    mon.observe_latency("error_rate", 5.0)
    mon.observe_event("lat_ms", True)
    mon.observe_latency("nope", 5.0)
    assert mon.evaluate(clock()) == AlertState.OK


# -- burn evaluation / hysteresis --------------------------------------------

def _latency_monitor(clock, **kw):
    kw.setdefault("fast_window_s", 2.0)
    kw.setdefault("slow_window_s", 8.0)
    kw.setdefault("min_count", 3)
    obj = Objective("lat_ms", 100.0, **kw)
    reg = Registry()
    return SloMonitor([obj], registry=reg, tracer=Tracer(clock=clock),
                      clock=clock), reg, obj


def test_page_requires_fast_and_slow_burn_with_sample_support():
    clock = FakeClock()
    mon, reg, obj = _latency_monitor(clock)
    # sustained breach: bad observations across both windows
    for _ in range(6):
        clock.advance(0.25)
        mon.observe_latency("lat_ms", 500.0)
    assert mon.evaluate() == AlertState.PAGE
    assert mon.states()["lat_ms"] is AlertState.PAGE
    snap = reg.snapshot()
    st = {s["labels"]["slo"]: s["value"]
          for s in snap["slo_state"]["series"]}
    assert st["lat_ms"] == 2
    burns = {s["labels"]["window"]: s["value"]
             for s in snap["slo_burn_rate"]["series"]}
    assert burns["fast"] == pytest.approx(5.0)   # 500 / 100
    assert burns["slow"] == pytest.approx(5.0)
    trans = snap["slo_transitions_total"]["series"]
    assert {(s["labels"]["to"], s["value"]) for s in trans} == {("PAGE", 1)}
    ev = [s for s in mon.tracer.spans if s.name == "slo_alert"]
    assert len(ev) == 1 and ev[0].attrs["to"] == "PAGE" \
        and ev[0].attrs["frm"] == "OK"


def test_single_spike_cannot_flap_min_count_floor():
    clock = FakeClock()
    mon, _, _ = _latency_monitor(clock)       # min_count=3
    clock.advance(0.25)
    mon.observe_latency("lat_ms", 10_000.0)   # one monster spike
    clock.advance(0.25)
    mon.observe_latency("lat_ms", 10_000.0)   # still under the floor
    assert mon.evaluate() == AlertState.OK
    assert mon.states()["lat_ms"] is AlertState.OK


def test_deescalation_waits_out_clear_s():
    clock = FakeClock()
    mon, _, obj = _latency_monitor(clock, clear_s=3.0)
    for _ in range(4):
        clock.advance(0.25)
        mon.observe_latency("lat_ms", 500.0)
    assert mon.evaluate() == AlertState.PAGE
    # the breach scrolls out of both windows...
    clock.advance(9.0)
    # ...but PAGE holds until the burn has been clear for clear_s
    assert mon.evaluate() == AlertState.PAGE      # starts the clear timer
    clock.advance(1.0)
    assert mon.evaluate() == AlertState.PAGE      # 1.0 < clear_s
    clock.advance(2.5)
    assert mon.evaluate() == AlertState.OK        # 3.5 >= clear_s
    t = mon.trackers["lat_ms"]
    # a re-breach during the clear countdown resets it (timer, not latch)
    for _ in range(4):
        clock.advance(0.25)
        mon.observe_latency("lat_ms", 500.0)
    assert mon.evaluate() == AlertState.PAGE
    clock.advance(9.0)
    mon.evaluate()
    assert t._below_since is not None
    for _ in range(4):
        clock.advance(0.25)
        mon.observe_latency("lat_ms", 500.0)
    assert mon.evaluate() == AlertState.PAGE and t._below_since is None


def test_error_rate_objective_burns_on_bad_fraction():
    clock = FakeClock()
    reg = Registry()
    mon = SloMonitor([Objective("error_rate", 0.25, kind="error_rate",
                                fast_window_s=2.0, slow_window_s=8.0,
                                min_count=4)],
                     registry=reg, clock=clock)
    for i in range(8):
        clock.advance(0.25)
        mon.observe_event("error_rate", ok=(i % 2 == 0))   # 50% bad
    assert mon.evaluate() == AlertState.PAGE               # 0.5/0.25 = 2x burn
    bf, bs = mon.trackers["error_rate"].last_burns
    # slow window covers all 8 events exactly; the fast window clips the
    # first (good) event, so its bad fraction is slightly higher
    assert bs == pytest.approx(2.0) and bf >= 2.0


# -- router controller --------------------------------------------------------

def _fleet(reg, clock, *, objectives, max_queue=64, tighten_factor=4,
           probe_s=0.5, fault_plan=None, max_retries=4):
    cfg_m, model, params = _setup()
    servers = [BatchServer(model, batch_slots=2, max_len=MAX_LEN,
                           registry=reg),
               BatchServer(model, batch_slots=2, max_len=MAX_LEN,
                           quantized=True, registry=reg)]
    rt = ReplicaRouter(
        servers, params, fault_plan=fault_plan, clock=clock, registry=reg,
        cfg=RouterConfig(step_timeout_s=5.0, quarantine_s=0.2,
                         max_retries=max_retries, max_queue=max_queue,
                         tighten_factor=tighten_factor, probe_s=probe_s,
                         shed_queue_depth=999,   # floor DISABLED: any shed
                         objectives=objectives))  # below is burn-driven
    return cfg_m, rt


def test_burn_driven_shed_independent_of_queue_depth_floor():
    """With ``shed_queue_depth`` at 999 the old queue-depth knob can never
    fire, and the float replica has free slots throughout — so a shed to
    the int8 replica can ONLY come from the SLO controller's burn signal.

    Phase 1 (two requests, fits the float replica) completes on the float
    tier with zero sheds; its TTFT breaches the absurd 1 ms objective, the
    controller pages; phase 2's requests are then shed to int8 even though
    the float replica is idle."""
    clock = FakeClock()
    reg = Registry()
    obj = Objective("ttft_ms", 1.0, fast_window_s=2.0, slow_window_s=8.0,
                    min_count=1)              # any real TTFT breaches 1 ms
    cfg, rt = _fleet(reg, clock, objectives=[obj])
    prompts = _prompts(cfg, 4)
    for i, p in enumerate(prompts[:2]):
        rt.submit(Request(rid=i, prompt=p, max_new_tokens=MAX_NEW, eos_id=-1))
    recs = rt.drive(max_ticks=4000)
    assert all(r.tier == "float" for r in recs.values())
    assert rt.stats["shed_to_quantized"] == 0
    # a couple of idle ticks: the controller tick runs before dispatch, so
    # it needs one step to see the final completions' TTFT observations
    for _ in range(3):
        rt.step()
    assert rt.ctl_state == CTL_TIGHTENED
    first_ctl = next(i for i, e in enumerate(rt.events)
                     if e[0] == "controller")

    for i, p in enumerate(prompts[2:], start=2):
        rt.submit(Request(rid=i, prompt=p, max_new_tokens=MAX_NEW, eos_id=-1))
    recs = rt.drive(max_ticks=4000)
    assert all(r.state is lc.Lifecycle.DONE for r in recs.values())
    assert rt.stats["shed_to_quantized"] >= 1
    sheds = [e for e in rt.events if e[0] == "shed"]
    assert sheds and all(rt.replicas[e[2]].tier == "int8" for e in sheds)
    first_shed = next(i for i, e in enumerate(rt.events) if e[0] == "shed")
    assert first_ctl < first_shed             # controller moved BEFORE any shed
    assert all(recs[i].tier == "int8" for i in (2, 3))


def test_admission_tightens_to_max_queue_over_factor():
    clock = FakeClock()
    reg = Registry()
    obj = Objective("ttft_ms", 1.0, fast_window_s=2.0, slow_window_s=8.0,
                    min_count=1)
    cfg, rt = _fleet(reg, clock, objectives=[obj], max_queue=8,
                     tighten_factor=4)
    prompts = _prompts(cfg, 16)
    for i, p in enumerate(prompts[:4]):
        rt.submit(Request(rid=i, prompt=p, max_new_tokens=MAX_NEW, eos_id=-1))
    rt.drive(max_ticks=4000)                  # TTFT > 1ms: controller pages
    for _ in range(3):                        # let the controller tick see
        rt.step()                             # the last completions
    assert rt.ctl_state == CTL_TIGHTENED
    assert rt.admission_limit() == 2          # 8 // 4
    assert reg.get("router_admission_limit").value == 2
    submitted, rejected = 0, None
    for i, p in enumerate(prompts[4:], start=4):
        try:
            rt.submit(Request(rid=i, prompt=p, max_new_tokens=MAX_NEW,
                              eos_id=-1))
            submitted += 1
        except lc.RejectedError as e:
            rejected = e
            break
    assert submitted == 2 and rejected is not None
    assert "tightened" in str(rejected)
    assert rt.stats["rejected"] == 1


def test_chaos_loop_breach_alert_shed_tighten_recover():
    """ISSUE-10 acceptance: deterministic FakeClock chaos run. The flaky
    replica's hang faults jump the shared clock 10 fake-seconds, so retried
    requests complete with router TTFT far over threshold -> the SLO pages
    -> the controller tightens and sheds to int8 -> the faults stop, the
    burn scrolls out of both windows, clear_s + probe_s elapse -> recover.
    Every leg is asserted from the metrics snapshot, the event records, and
    the trace."""
    clock = FakeClock()
    reg = Registry()
    obj = Objective.parse("ttft_ms p99 < 2000", fast_window_s=2.0,
                          slow_window_s=8.0, min_count=2)
    cfg, rt = _fleet(reg, clock, objectives=[obj],
                     fault_plan=FaultPlan.flaky_replica(
                         0, start=2, period=4, rounds=4, seed=0))
    for i, p in enumerate(_prompts(cfg, 8)):
        rt.submit(Request(rid=i, prompt=p, max_new_tokens=MAX_NEW, eos_id=-1))
    recs = rt.drive(max_ticks=20_000)
    assert all(r.state is lc.Lifecycle.DONE for r in recs.values())
    assert rt.stats["retries"] >= 1, "fault plan never fired"

    # the breach happened and was acted on while the run was live
    ctr = reg.get("router_controller_total")
    assert ctr.labels(action="tighten").value >= 1
    assert rt.stats["shed_to_quantized"] >= 1
    trans = reg.get("slo_transitions_total")
    assert trans.labels(slo="ttft_ms", to="PAGE").value >= 1

    # drain: faults are exhausted; keep ticking so the burn scrolls out of
    # the slow window, clear_s elapses, and the probe window passes
    for _ in range(1600):
        rt.step()
    assert rt.ctl_state == CTL_HEALTHY
    assert rt.slo.states()["ttft_ms"] is AlertState.OK
    assert ctr.labels(action="probe").value >= 1
    assert ctr.labels(action="recover").value >= 1
    assert trans.labels(slo="ttft_ms", to="OK").value >= 1

    # snapshot view (what obs_check gates in CI)
    snap = reg.snapshot()
    assert [s["value"] for s in snap["slo_state"]["series"]] == [0]
    assert snap["router_controller_state"]["series"][0]["value"] == 0
    assert snap["router_admission_limit"]["series"][0]["value"] == \
        rt.cfg.max_queue
    rep_states = {s["labels"]["replica"]: s["value"]
                  for s in snap["router_replica_state"]["series"]}
    # replica 1 (never faulted) must be healthy; replica 0 may legitimately
    # end PROBING if its last quarantine expired after the traffic drained
    assert rep_states["1"] == 0 and rep_states["0"] in (0, 1)
    ttft_rows = snap["router_ttft_ms_window"]["series"]
    assert {r["labels"]["tier"] for r in ttft_rows} == {"float", "int8"}

    # ladder ordering from the controller event record: tighten strictly
    # before probe strictly before recover
    actions = [e[1] for e in rt.events if e[0] == "controller"]
    assert actions.index("tighten") < actions.index("probe") \
        < actions.index("recover")

    # trace: the alert and every controller move are point events with
    # attrs, and the PAGE alert lands BEFORE the tighten move (the same
    # controller tick evaluates, then acts)
    spans = list(rt.tracer.spans)
    alerts = [s for s in spans if s.name == "slo_alert"]
    moves = [s for s in spans if s.name == "controller"]
    assert any(s.attrs["to"] == "PAGE" for s in alerts)
    assert any(s.attrs["to"] == "OK" for s in alerts)
    assert moves and moves[-1].attrs["action"] == "recover"
    i_page = spans.index(next(s for s in alerts if s.attrs["to"] == "PAGE"))
    i_tight = spans.index(next(m for m in moves
                               if m.attrs["action"] == "tighten"))
    assert i_page < i_tight
    page = spans[i_page]
    assert page.attrs["burn_fast"] >= 1.0 and page.attrs["burn_slow"] >= 1.0
