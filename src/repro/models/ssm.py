"""State-space blocks: Mamba1 selective scan (falcon-mamba) and Mamba2 SSD
(zamba2), both with O(chunk) memory (no (B,S,d_inner,N) materialisation —
essential for the 32k prefill cells, see DESIGN.md §4).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

Array = jax.Array


def _causal_conv(x: Array, w: Array, state: Optional[Array] = None,
                 ) -> Tuple[Array, Array]:
    """Depthwise causal conv1d. x: (B,S,C), w: (W,C). Returns (y, new_state)
    where state is the trailing (W-1) inputs for streaming decode."""
    width = w.shape[0]
    if state is None:
        x_pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    else:
        x_pad = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    new_state = x_pad[:, -(width - 1):, :] if width > 1 else x_pad[:, :0, :]
    y = sum(x_pad[:, i:i + x.shape[1], :] * w[i][None, None, :]
            for i in range(width))
    return y, new_state


# --- Mamba1 (selective scan) -------------------------------------------------

def mamba1_init(key, cfg: ModelConfig, dtype) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    dt_rank = s.dt_rank or -(-d // 16)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    return {
        "in_proj": L.dense_init(k1, d, 2 * di, dtype),
        "conv_w": (jax.random.normal(k2, (s.d_conv, di), jnp.float32) * 0.1).astype(dtype),
        "x_proj": L.dense_init(k3, di, dt_rank + 2 * s.d_state, dtype),
        "dt_proj": L.dense_init(k4, dt_rank, di, dtype, bias=True),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, s.d_state + 1, dtype=jnp.float32), (di, s.d_state))).astype(dtype),
        "D": jnp.ones((di,), dtype),
        "out_proj": L.dense_init(k5, di, d, dtype),
    }


def _mamba1_scan(xz: Array, dt: Array, B: Array, C: Array, A: Array, D: Array,
                 h0: Array, chunk: int) -> Tuple[Array, Array]:
    """Selective scan, chunked over sequence to bound the (B,c,di,N) transient.

    xz: (Bt,S,di) conv+silu output; dt: (Bt,S,di); B,C: (Bt,S,N); A: (di,N).
    h0: (Bt,di,N) initial state. Returns (y (Bt,S,di), h_final).
    """
    bt, s, di = xz.shape
    n = A.shape[-1]
    n_chunks = max(1, s // chunk)
    assert s % n_chunks == 0
    xz_c = xz.reshape(bt, n_chunks, -1, di)
    dt_c = dt.reshape(bt, n_chunks, -1, di)
    b_c = B.reshape(bt, n_chunks, -1, n)
    c_c = C.reshape(bt, n_chunks, -1, n)

    def chunk_step(h, inp):
        xzk, dtk, bk, ck = inp                      # (Bt,c,di) / (Bt,c,N)
        da = jnp.exp(dtk[..., None] * A)            # (Bt,c,di,N) discretized A
        dbx = dtk[..., None] * bk[:, :, None, :] * xzk[..., None]

        def step(hh, t_inp):
            da_t, dbx_t = t_inp                     # (Bt,di,N)
            hh = da_t * hh + dbx_t
            return hh, hh

        h, hs = jax.lax.scan(step, h,
                             (jnp.moveaxis(da, 1, 0), jnp.moveaxis(dbx, 1, 0)))
        y = jnp.einsum("cbdn,bcn->bcd", hs, ck)     # (Bt,c,di)
        return h, y

    h, ys = jax.lax.scan(
        chunk_step, h0,
        (jnp.moveaxis(xz_c, 1, 0), jnp.moveaxis(dt_c, 1, 0),
         jnp.moveaxis(b_c, 1, 0), jnp.moveaxis(c_c, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1).reshape(bt, s, di)
    return y + xz * D, h


def mamba1_apply(p: dict, x: Array, *, cfg: ModelConfig,
                 cache: Optional[dict] = None, prefill: bool = False,
                 ) -> Tuple[Array, Optional[dict]]:
    """cache = {"conv": (B, W-1, di), "ssm": (B, di, N)} for streaming decode.

    prefill=True (forward-only) routes the recurrence through the fused
    Pallas selective-scan kernel (state in VMEM — §Perf falcon-mamba); train
    keeps the differentiable chunked scan."""
    s_cfg = cfg.ssm
    b, s, d = x.shape
    di = s_cfg.expand * d
    dt_rank = s_cfg.dt_rank or -(-d // 16)
    xz = L.dense(x, p["in_proj"])
    xs, z = jnp.split(xz, 2, axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    xs, new_conv = _causal_conv(xs, p["conv_w"], conv_state)
    xs = jax.nn.silu(xs)
    proj = L.dense(xs, p["x_proj"])
    dt, bmat, cmat = jnp.split(proj, [dt_rank, dt_rank + s_cfg.d_state], axis=-1)
    dt = jax.nn.softplus(L.dense(dt, p["dt_proj"]))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    h0 = (cache["ssm"].astype(jnp.float32) if cache is not None
          else jnp.zeros((b, di, s_cfg.d_state), jnp.float32))
    if s > 1:
        # fused Pallas path: fwd-only (prefill) keeps h for the cache; train
        # uses the custom-VJP kernel pair (§Perf falcon-mamba iters 1-2)
        y, h = _selective_scan_fused(xs, dt, bmat, cmat, A, h0, s_cfg.chunk,
                                     trainable=not prefill)
        y = y.astype(jnp.float32) + xs.astype(jnp.float32) * p["D"].astype(jnp.float32)
        if h is None:
            h = h0
    else:
        y, h = _mamba1_scan(xs.astype(jnp.float32), dt.astype(jnp.float32),
                            bmat.astype(jnp.float32), cmat.astype(jnp.float32),
                            A, p["D"].astype(jnp.float32), h0, s_cfg.chunk)
    out = L.dense((y.astype(x.dtype) * jax.nn.silu(z)), p["out_proj"])
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype), "ssm": h.astype(cache["ssm"].dtype)}
    return out, new_cache


def _selective_scan_fused(xs, dt, bmat, cmat, A, h0, chunk, *,
                          trainable: bool = False):
    """Fused Pallas selective scan, shard_mapped (B->data, d_inner->model).

    trainable=True uses the custom-VJP kernel pair (exact grads, chunk-
    checkpointed bwd recompute) and returns (y, None); otherwise returns
    (y, h_final) for the streaming-cache contract."""
    from repro.dist import context as dctx
    from repro.kernels import selective_scan as ssk
    import numpy as np
    mesh = dctx.get_mesh()
    b, s, di = xs.shape
    ck, bd = min(chunk, 128), min(512, di)
    if trainable:
        call = lambda x_, dt_, b_, c_, a_, h_: ssk.selective_scan_trainable(
            x_, dt_, b_, c_, a_, h_, ck, bd)
    else:
        call = lambda x_, dt_, b_, c_, a_, h_: ssk.selective_scan(
            x_, dt_, b_, c_, a_, h_, chunk=ck, bd=bd, interpret=None)[:2]
    if mesh is None:
        out = call(xs, dt, bmat, cmat, A, h0)
        return (out, None) if trainable else out
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    axis_size = dict(zip(mesh.axis_names, mesh.devices.shape))
    baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bshard = baxes if b % int(np.prod([axis_size[a] for a in baxes] or [1])) == 0 else None
    dshard = "model" if ("model" in axis_size and di % axis_size["model"] == 0) else None
    sx = P(bshard, None, dshard)
    sn = P(bshard, None, None)
    in_specs = (sx, sx, sn, sn, P(dshard, None), P(bshard, dshard, None))
    out = shard_map(call, mesh=mesh, in_specs=in_specs,
                    out_specs=sx if trainable else (sx, P(bshard, dshard, None)),
                    check_rep=False)(xs, dt, bmat, cmat, A, h0)
    return (out, None) if trainable else out


# --- Mamba2 (SSD, scalar-per-head decay) -------------------------------------

def mamba2_init(key, cfg: ModelConfig, dtype) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    n_heads = di // s.head_dim
    bc_dim = 2 * s.n_groups * s.d_state
    k1, k2, k3, k4, k5, k6, k7 = jax.random.split(key, 7)
    return {
        # PERF (§Perf zamba2 iter-2): separate projections instead of one fused
        # in_proj — the fused (2*di + 2*G*N + H)-wide output had split
        # boundaries misaligned with the model-axis shards, inducing an
        # all-gather per chunk step (7k all-gathers / 1.7TB wire per train
        # step). Separate x / BC / dt / z outputs shard cleanly, and the
        # depthwise conv splits per-channel into conv_x + conv_bc (identical
        # math, aligned shards).
        "z_proj": L.dense_init(k1, d, di, dtype),
        "x_proj_in": L.dense_init(k5, d, di, dtype),
        "bc_proj": L.dense_init(k7, d, bc_dim, dtype),
        "dtp": L.dense_init(k6, d, n_heads, dtype),
        "conv_x": (jax.random.normal(k2, (s.d_conv, di), jnp.float32) * 0.1).astype(dtype),
        "conv_bc": (jax.random.normal(k3, (s.d_conv, bc_dim), jnp.float32) * 0.1).astype(dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(dtype),
        "D": jnp.ones((n_heads,), dtype),
        "dt_bias": jnp.zeros((n_heads,), dtype),
        "norm": L.rmsnorm_init(di, dtype),
        "out_proj": L.dense_init(k4, di, d, dtype),
    }


def _segsum(log_a: Array) -> Array:
    """(..., C) -> (..., C, C) lower-triangular cumulative log-decay sums."""
    c = log_a.shape[-1]
    cums = jnp.cumsum(log_a, axis=-1)
    diff = cums[..., :, None] - cums[..., None, :]
    mask = jnp.tril(jnp.ones((c, c), bool))
    return jnp.where(mask, diff, -jnp.inf)


def _ssd_chunked(xh: Array, dt: Array, log_a: Array, B: Array, C: Array,
                 h0: Array, chunk: int) -> Tuple[Array, Array]:
    """Mamba2 SSD. xh: (Bt,S,H,P); dt,log_a contributions: (Bt,S,H);
    B,C: (Bt,S,G,N); h0: (Bt,H,P,N). Sequential scan over chunks, the
    intra-chunk term is the attention-like einsum of the SSD paper."""
    bt, s, h, p_ = xh.shape
    g, n = B.shape[2], B.shape[3]
    heads_per_g = h // g
    n_chunks = max(1, s // chunk)
    assert s % n_chunks == 0
    c = s // n_chunks

    def rs(t):
        return t.reshape(bt, n_chunks, c, *t.shape[2:])

    xh_c, dt_c, la_c = rs(xh), rs(dt), rs(log_a)
    b_c, c_c = rs(B), rs(C)

    # PERF (§Perf zamba2 iter-4): intra-chunk tensors in the model compute
    # dtype (bf16 in production), state carry in f32 — halves chunk bytes.
    cdt = xh.dtype

    def chunk_step(hstate, inp):
        xk, dtk, lak, bk, ck = inp
        # PERF (EXPERIMENTS.md §Perf zamba2 iter-1): fold every scalar factor
        # (dt, segment decays) into x/C BEFORE the contractions so all einsums
        # are clean 2-operand dots. Multi-operand einsums with per-(b,c,h)
        # scalar operands made jax materialize (B,c,H,P,N) 5-D intermediates
        # in the BACKWARD pass (~430TB/step for the zamba2 train cell).
        seg = _segsum(jnp.moveaxis(lak, 1, 2))          # (Bt,H,c,c) f32
        decay = jnp.exp(seg)
        bk_h = jnp.repeat(bk, heads_per_g, axis=2)      # (Bt,c,H,N)
        ck_h = jnp.repeat(ck, heads_per_g, axis=2)
        xdt = (xk * dtk[..., None].astype(cdt))         # (Bt,c,H,P) dt folded
        scores = jnp.einsum("bqhn,bkhn->bhqk", ck_h, bk_h,
                            preferred_element_type=jnp.float32)
        scores = (scores * decay).astype(cdt)
        intra = jnp.einsum("bhqk,bkhp->bqhp", scores, xdt,
                           preferred_element_type=jnp.float32)
        # inter-chunk: carry-in state contribution + state update
        cum = jnp.cumsum(lak, axis=1)                   # (Bt,c,H) f32
        c_scaled = ck_h * jnp.exp(cum)[..., None].astype(cdt)
        inter = jnp.einsum("bqhn,bhpn->bqhp", c_scaled.astype(jnp.float32),
                           hstate)
        total_decay = jnp.exp(cum[:, -1])               # (Bt,H)
        x_tail = xdt * jnp.exp(cum[:, -1][:, None] - cum)[..., None].astype(cdt)
        new_state = (hstate * total_decay[..., None, None]
                     + jnp.einsum("bkhp,bkhn->bhpn", x_tail, bk_h,
                                  preferred_element_type=jnp.float32))
        return new_state, intra + inter

    h_fin, ys = jax.lax.scan(
        chunk_step, h0,
        tuple(jnp.moveaxis(t, 1, 0) for t in (xh_c, dt_c, la_c, b_c, c_c)))
    y = jnp.moveaxis(ys, 0, 1).reshape(bt, s, h, p_)
    return y, h_fin


def mamba2_apply(p: dict, x: Array, *, cfg: ModelConfig,
                 cache: Optional[dict] = None,
                 ) -> Tuple[Array, Optional[dict]]:
    """cache = {"conv": (B,W-1,conv_dim), "ssm": (B,H,P,N)}."""
    s_cfg = cfg.ssm
    b, s, d = x.shape
    di = s_cfg.expand * d
    hdim = s_cfg.head_dim
    n_heads = di // hdim
    g, n = s_cfg.n_groups, s_cfg.d_state
    z = L.dense(x, p["z_proj"])
    xin = L.dense(x, p["x_proj_in"])
    bc = L.dense(x, p["bc_proj"])
    dt = L.dense(x, p["dtp"])
    xs, new_conv_x = _causal_conv(xin, p["conv_x"],
                                  cache["conv"] if cache is not None else None)
    bc, new_conv_bc = _causal_conv(bc, p["conv_bc"],
                                   cache["conv_bc"] if cache is not None else None)
    xs = jax.nn.silu(xs)
    bc = jax.nn.silu(bc)
    bmat, cmat = jnp.split(bc, [g * n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    log_a = -jnp.exp(p["A_log"].astype(jnp.float32)) * dt        # (B,S,H)
    xh = xs.reshape(b, s, n_heads, hdim)           # model dtype (bf16 prod)
    bmat = bmat.reshape(b, s, g, n)
    cmat = cmat.reshape(b, s, g, n)
    h0 = (cache["ssm"].astype(jnp.float32) if cache is not None
          else jnp.zeros((b, n_heads, hdim, n), jnp.float32))
    y, h = _ssd_chunked(xh, dt, log_a, bmat, cmat, h0, s_cfg.chunk)
    y = y + (xh * p["D"][None, None, :, None].astype(xh.dtype)).astype(y.dtype)
    y = y.reshape(b, s, di).astype(x.dtype) * jax.nn.silu(z)
    out = L.dense(L.rmsnorm(y, p["norm"], cfg.norm_eps), p["out_proj"])
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv_x.astype(cache["conv"].dtype),
                     "conv_bc": new_conv_bc.astype(cache["conv_bc"].dtype),
                     "ssm": h.astype(cache["ssm"].dtype)}
    return out, new_cache
