"""Vision launcher: CNN classify smoke + fused-conv schedule tuning.

    # classify smoke: tiny AlexNet through the fused implicit-im2col kernels
    PYTHONPATH=src python -m repro.launch.vision --model alexnet --smoke \
        --gemm-impl pallas --gemm-block auto

    # quantized int8 path (offline-prepared weights, Eq. 15/20 epilogue)
    PYTHONPATH=src python -m repro.launch.vision --model alexnet --smoke \
        --quantized --gemm-block auto

    # pre-populate the repro.tune conv schedules from the model's conv set
    PYTHONPATH=src python -m repro.launch.vision --model alexnet --smoke \
        --tune --budget 3 --iters 1

The smoke asserts logits are finite and the forward is deterministic, and —
with ``--quantized`` — that the int8 logits stay within a loose relative
error of the float logits (the quantization contract, not a bit check; the
bit-exactness checks live in tests/test_conv_fused.py). ``--tune`` follows
the ``launch.tune`` warm-cache contract: ``--expect-cached`` exits non-zero
if anything had to be measured, so CI can assert cold-then-warm.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gemm import GemmConfig, use_gemm
from repro.vision import models as vm


def _smoke_defaults(args) -> None:
    if args.smoke:
        args.image_size = args.image_size or (67 if args.model == "alexnet"
                                              else 32)
        args.width_div = args.width_div or 8
        args.classes = args.classes or 10
    args.image_size = args.image_size or 0
    args.width_div = args.width_div or 1
    args.classes = args.classes or 1000


def _tune(args, model, image_size: int) -> int:
    from repro import tune
    from repro.tune import measure

    algos = [a for a in args.algos.split(",") if a]
    dtypes = [jnp.dtype(d) for d in args.dtypes.split(",") if d]
    cache = tune.get_cache()
    jobs = []
    seen = set()
    for conv, h, w in vm.conv_geometries(model, image_size):
        for algo in algos:
            for dt in dtypes:
                cin_g = conv.cin // conv.groups
                k = conv.kh * conv.kw * cin_g
                oh, ow = vm._spatial(conv, h, w)
                key = tune.conv_key(algo, dt, oh * ow, conv.cout // conv.groups,
                                    k, cin_g * conv.kw)
                if key not in seen:
                    seen.add(key)
                    jobs.append((conv, h, w, algo, dt))
    t0 = time.perf_counter()
    measured = cached = 0
    for conv, h, w, algo, dt in jobs:
        pre = measure.counters["timed_candidates"]
        entry = tune.tune_conv(
            args.batch, h, w, conv.cin, conv.cout, conv.kh, conv.kw, dt,
            stride=conv.stride, pad=conv.pad, groups=conv.groups, algo=algo,
            budget=args.budget, iters=args.iters, cache=cache, persist=False)
        fresh = measure.counters["timed_candidates"] > pre
        measured += fresh
        cached += not fresh
        b = entry["blocks"]
        status = "tuned " if fresh else "cached"
        print(f"[{status}] conv {algo:8s} {jnp.dtype(dt).name:7s} "
              f"{conv.name:12s} {h}x{w}x{conv.cin}->k{conv.kh}x{conv.kw} "
              f"g{conv.groups} -> bm={b['bm']} bn={b['bn']} bk={b['bk']} "
              f"({entry['us']}us, {entry['candidates']} candidates)")
    if measured:
        cache.save()
    print(f"{args.model}: {measured} conv buckets tuned / {cached} reused "
          f"({time.perf_counter() - t0:.1f}s) -> {cache.path}")
    if args.expect_cached and measured:
        print("--expect-cached: FAIL — warm cache still measured",
              file=sys.stderr)
        return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="CNN classify smoke / fused-conv schedule tuning")
    ap.add_argument("--model", required=True, choices=sorted(vm.BUILDERS))
    ap.add_argument("--smoke", action="store_true",
                    help="tiny image + width_div=8 + 10 classes")
    ap.add_argument("--image-size", type=int, default=0)
    ap.add_argument("--width-div", type=int, default=0)
    ap.add_argument("--classes", type=int, default=0)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--algo", choices=["baseline", "fip", "ffip"],
                    default="ffip")
    ap.add_argument("--gemm-impl", choices=["xla", "pallas"], default="pallas")
    ap.add_argument("--gemm-block", default=None,
                    help="'auto' (repro.tune conv schedules) or 'bm,bn,bk'")
    ap.add_argument("--quantized", action="store_true",
                    help="int8 path (offline weight quantization)")
    ap.add_argument("--tune", action="store_true",
                    help="pre-populate conv schedules instead of classifying")
    ap.add_argument("--algos", default="baseline,fip,ffip",
                    help="--tune: algos to tune")
    ap.add_argument("--dtypes", default="float32,int8",
                    help="--tune: dtypes to tune")
    ap.add_argument("--budget", type=int, default=0)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--expect-cached", action="store_true",
                    help="--tune: fail if anything had to be measured")
    ap.add_argument("--prepared", default=None, metavar="DIR",
                    help="run from a repro.prepare vision artifact "
                         "(python -m repro.launch.prepare --vision ...) "
                         "instead of quantizing in-process")
    args = ap.parse_args(argv)
    _smoke_defaults(args)

    default_size = 227 if args.model == "alexnet" else 224
    image_size = args.image_size or default_size
    model = vm.build(args.model, num_classes=args.classes,
                     image_size=image_size, width_div=args.width_div)
    if args.tune:
        return _tune(args, model, image_size)

    gemm_block = args.gemm_block
    if gemm_block and gemm_block != "auto":
        gemm_block = tuple(int(x) for x in gemm_block.split(","))
    if gemm_block and args.gemm_impl != "pallas":
        raise SystemExit("--gemm-block requires --gemm-impl pallas")

    key = jax.random.PRNGKey(0)
    params = vm.init_params(model, key)
    prepared = None
    if args.prepared:
        from repro import prepare
        prepared = prepare.load(args.prepared)
        if prepared.kind != "vision":
            raise SystemExit(f"--prepared: {args.prepared} is a "
                             f"{prepared.kind!r} artifact, not vision")
        if args.quantized and not prepared.quantized:
            raise SystemExit("--quantized with a float-only artifact — "
                             "re-run launch.prepare with --quantized")
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (args.batch, image_size, image_size, 3))
    n_convs = len(vm.conv_layers(model))
    print(f"{args.model}: image {image_size}x{image_size}, width/{args.width_div}, "
          f"{n_convs} convs, algo={args.algo} impl={args.gemm_impl} "
          f"block={args.gemm_block or 'default'} quantized={args.quantized}")

    t0 = time.perf_counter()
    float_logits = vm.apply(model, params, x)     # xla/baseline reference
    print(f"float reference forward: {time.perf_counter() - t0:.2f}s")
    assert bool(jnp.isfinite(float_logits).all()), "float logits not finite"

    cfg = GemmConfig(algo=args.algo, impl=args.gemm_impl,
                     quantized=args.quantized, block=gemm_block)
    if prepared is not None:
        run_params = prepared.params
    elif args.quantized:
        run_params = vm.attach_quantized(model, params)
    else:
        run_params = params
    with use_gemm(cfg):
        t0 = time.perf_counter()
        logits = vm.apply(model, run_params, x)
        dt1 = time.perf_counter() - t0
        logits2 = vm.apply(model, run_params, x)
    assert bool(jnp.isfinite(logits).all()), "logits not finite"
    assert (np.asarray(logits) == np.asarray(logits2)).all(), \
        "forward not deterministic"
    rel = float(jnp.linalg.norm(logits - float_logits)
                / (jnp.linalg.norm(float_logits) + 1e-9))
    top1 = jnp.argmax(logits, axis=-1)
    print(f"configured forward: {dt1:.2f}s  top1={np.asarray(top1)}  "
          f"rel_err_vs_float={rel:.4f}")
    # the fused float path is allclose-tight; the int8 path has a loose
    # quantization budget (bit-exactness is tested against the reference
    # oracle in tests/test_conv_fused.py, not against float)
    limit = 0.35 if args.quantized else 1e-3
    if rel > limit:
        print(f"FAIL: rel err {rel:.4f} > {limit}", file=sys.stderr)
        return 1
    if prepared is not None and prepared.recomputed:
        print(f"FAIL: prepared artifact recomputed offline work: "
              f"{prepared.recompute_report()}", file=sys.stderr)
        return 1
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
