"""whisper-small [audio]: 12L d_model=768 12H (GQA kv=12) d_ff=3072 vocab=51865.
Enc-dec; conv mel frontend is a STUB (precomputed frame embeddings), per brief.
[arXiv:2212.04356; unverified]"""
from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="enc-dec",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab=51865, norm="layernorm", act="gelu", qkv_bias=True,
    rope_theta=10000.0, tie_embeddings=True,
    encoder=EncoderConfig(n_layers=12, n_frames=1500),
    frontend="audio", is_encoder_decoder=True,
    supports_long_context=False,
)
