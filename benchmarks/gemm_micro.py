"""GEMM micro-benchmarks: the three arithmetic paths, timed on this host.

CAVEAT printed with results: this container is CPU-only; interpret-mode Pallas
timings measure the emulation harness, not TPU silicon. The load-bearing
numbers are the arithmetic-complexity counters (measured multiplies via jaxpr
instrumentation), which are platform-independent — those are the paper's Eq.5/6.

``python benchmarks/gemm_micro.py`` additionally runs the repro.tune
autotuner over each pallas kernel/dtype and writes
``benchmarks/BENCH_gemm.json`` (the BENCH_serve.json convention):
default-block vs tuned-block timings per kernel/dtype with the static
defaults preserved under a ``baseline_default`` key (and any previous file's
results under ``baseline_prev``), so the tuning win — and the machine it was
measured on — stays visible in one artifact.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time
from typing import List

import jax
import jax.numpy as jnp

from repro.core import analytical as an
from repro.core import fip
from repro.kernels import ops

OUT = pathlib.Path(__file__).resolve().parent / "BENCH_gemm.json"


def _time(fn, *args, iters: int = 3) -> float:
    # warmup: ONE call (jax.block_until_ready handles tuples/pytrees). The
    # old isinstance-probe evaluated fn(*args) twice, doubling compile+run
    # warmup cost for every timed entry.
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> List[str]:
    rows = ["gemm_micro.name,us_per_call,derived"]
    key = jax.random.PRNGKey(0)
    for m, k, n in [(256, 256, 256), (512, 1024, 512)]:
        ka, kb = jax.random.split(key)
        a = jax.random.normal(ka, (m, k), jnp.float32)
        b = jax.random.normal(kb, (k, n), jnp.float32)
        t_xla = _time(jax.jit(lambda a, b: a @ b), a, b)
        t_ref_fip = _time(jax.jit(lambda a, b: fip.fip_matmul(a, b, k_chunk=32)), a, b)
        rows.append(f"gemm_micro.xla_base_{m}x{k}x{n},{t_xla:.0f},")
        rows.append(f"gemm_micro.fip_ref_{m}x{k}x{n},{t_ref_fip:.0f},cpu-emulation-only")
        # measured multiply counts (the real claim):
        mb = fip.count_multiplies_in_jaxpr(lambda a, b: a @ b, a, b)
        mf = fip.count_multiplies_in_jaxpr(lambda a, b: fip.fip_matmul(a, b), a, b)
        rows.append(f"gemm_micro.mults_{m}x{k}x{n},{mf},"
                    f"ratio_vs_baseline={mf / mb:.4f} (Eq.5: "
                    f"{an.fip_mults(m, k, n) / an.baseline_mults(m, k, n):.4f})")
    # pallas kernels (interpret) on a small tile — correctness-mode timing
    a = jax.random.normal(key, (128, 128), jnp.float32)
    b = jax.random.normal(key, (128, 128), jnp.float32)
    for algo in ("baseline", "fip", "ffip"):
        t = _time(lambda a, b, al=algo: ops.matmul(a, b, algo=al, interpret=True),
                  a, b, iters=2)
        rows.append(f"gemm_micro.pallas_{algo}_128_interpret,{t:.0f},interpret-mode")
    return rows


def tuned_vs_default(*, shapes=((256, 256, 256),),
                     algos=("baseline", "fip", "ffip"),
                     dtypes=("float32", "int8"),
                     budget: int = 6, iters: int = 3, cache=None) -> dict:
    """Autotune each pallas kernel/dtype over ``shapes`` and report default
    vs tuned blocks + timings. Both numbers come from the SAME search sweep
    (the default is always candidate 0), so ``tuned_us <= default_us`` by
    construction and a warm cache re-measures NOTHING; only a cache entry
    tuned by an older build that lacks its default timing triggers a local
    re-measure of the two configurations."""
    from repro import tune
    from repro.tune import measure as tmeasure

    results = {}
    for (m, k, n) in shapes:
        for algo in algos:
            for dtype in dtypes:
                entry = tune.tune_gemm(m, n, k, jnp.dtype(dtype), algo=algo,
                                       budget=budget, iters=iters, cache=cache)
                tuned = entry["blocks"]
                default = entry["default_blocks"]
                t_tun, t_def = entry["us"], entry.get("default_us")
                if t_def is None:
                    a, b = tmeasure._gemm_operands(m, k, n, jnp.dtype(dtype))
                    t_def = round(tmeasure.time_gemm_blocks(
                        algo, a, b,
                        (default["bm"], default["bn"], default["bk"]),
                        iters=iters) * 1e6, 1)
                    t_tun = round(tmeasure.time_gemm_blocks(
                        algo, a, b, (tuned["bm"], tuned["bn"], tuned["bk"]),
                        iters=iters) * 1e6, 1)
                results[f"{algo}.{dtype}.{m}x{k}x{n}"] = {
                    "default_blocks": default,
                    "default_us": t_def,
                    "tuned_blocks": tuned,
                    "tuned_us": t_tun,
                    "speedup": round(t_def / max(t_tun, 1e-12), 3),
                    "search_candidates": entry["candidates"],
                }
    return results


def write_bench(*, budget: int = 6, iters: int = 3, shapes=None) -> dict:
    """Write benchmarks/BENCH_gemm.json (default-vs-tuned per kernel/dtype)."""
    from repro import tune

    shapes = shapes or ((256, 256, 256),)
    prior = None
    if OUT.exists():
        try:
            prior = json.loads(OUT.read_text())
            prior.pop("baseline_prev", None)   # keep one generation, not all
        except Exception:
            prior = None
    results = tuned_vs_default(shapes=shapes, budget=budget, iters=iters)
    out = {
        "bench": "gemm",
        "note": ("CPU containers time the interpret-mode harness, not "
                 "silicon; the tuned-vs-default ratio on THIS device_kind is "
                 "the load-bearing number. baseline_default = the static "
                 "blocks the kernels ship with (always search candidate 0); "
                 "default_us/tuned_us come from the same median-of-k search "
                 "sweep, so tuned <= default by construction and a warm "
                 "cache run re-measures nothing."),
        "device_kind": tune.device_kind(),
        "cache": str(tune.get_cache().path),
        "baseline_default": {k: {"blocks": v["default_blocks"],
                                 "us": v["default_us"]}
                             for k, v in results.items()},
        "results": results,
    }
    if prior is not None:
        out["baseline_prev"] = prior
    OUT.write_text(json.dumps(out, indent=2) + "\n")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=int, default=6,
                    help="max tuning candidates per kernel/dtype")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--shape", default="256,256,256",
                    help="m,k,n for the tuned-vs-default comparison")
    args = ap.parse_args()
    for r in run():
        print(r)
    m, k, n = (int(x) for x in args.shape.split(","))
    out = write_bench(budget=args.budget, iters=args.iters,
                      shapes=((m, k, n),))
    for name, r in out["results"].items():
        print(f"BENCH_gemm.{name},default={r['default_us']}us"
              f"({r['default_blocks']}),tuned={r['tuned_us']}us"
              f"({r['tuned_blocks']}),speedup={r['speedup']}")
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
