"""Architecture registry + reduced (smoke) config derivation."""
from __future__ import annotations

import dataclasses

from repro.configs.base import (EncoderConfig, MLAConfig, MoEConfig,
                                ModelConfig, SHAPES, SHAPE_BY_NAME,
                                ShapeConfig, SSMConfig, shape_supported)

from repro.configs.whisper_small import CONFIG as _whisper
from repro.configs.zamba2_1p2b import CONFIG as _zamba2
from repro.configs.deepseek_v2_lite_16b import CONFIG as _dsv2l
from repro.configs.mixtral_8x22b import CONFIG as _mixtral
from repro.configs.minicpm_2b import CONFIG as _minicpm
from repro.configs.starcoder2_3b import CONFIG as _starcoder2
from repro.configs.deepseek_coder_33b import CONFIG as _dscoder
from repro.configs.gemma3_4b import CONFIG as _gemma3
from repro.configs.falcon_mamba_7b import CONFIG as _falcon_mamba
from repro.configs.pixtral_12b import CONFIG as _pixtral

ARCHS = {
    "whisper-small": _whisper,
    "zamba2-1.2b": _zamba2,
    "deepseek-v2-lite-16b": _dsv2l,
    "mixtral-8x22b": _mixtral,
    "minicpm-2b": _minicpm,
    "starcoder2-3b": _starcoder2,
    "deepseek-coder-33b": _dscoder,
    "gemma3-4b": _gemma3,
    "falcon-mamba-7b": _falcon_mamba,
    "pixtral-12b": _pixtral,
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config: small widths/layers/experts/vocab, runs a
    forward/train step on CPU in seconds. Structure (family, MoE/MLA/SSM/
    hybrid/enc-dec/frontend) is preserved."""
    updates = dict(
        name=cfg.name + "-smoke",
        n_layers=5 if cfg.family == "hybrid" else 2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=128 if cfg.d_ff else 0,
        vocab=512,
        head_dim=16 if cfg.head_dim else 0,
        sliding_window=8 if cfg.sliding_window else 0,
        local_global_period=2 if cfg.local_global_period else 0,
        first_k_dense=min(cfg.first_k_dense, 1),
        hybrid_attn_period=2 if cfg.hybrid_attn_period else 0,
        frontend_tokens=8 if cfg.frontend_tokens else 0,
        param_dtype="float32",
    )
    if cfg.moe is not None:
        # capacity_factor = E/k makes dispatch lossless: smoke tests then
        # check prefill+decode == full-forward exactly (no capacity drops).
        updates["moe"] = MoEConfig(
            n_experts=4, top_k=2, d_ff_expert=32, capacity_factor=2.0,
            n_shared=min(cfg.moe.n_shared, 1), partition=cfg.moe.partition)
    if cfg.mla is not None:
        updates["mla"] = MLAConfig(kv_lora_rank=32, q_lora_rank=0,
                                   rope_head_dim=8, nope_head_dim=16,
                                   v_head_dim=16)
    if cfg.ssm is not None:
        updates["ssm"] = SSMConfig(version=cfg.ssm.version, d_state=8,
                                   d_conv=4, expand=2, head_dim=16,
                                   n_groups=1, dt_rank=8, chunk=8)
    if cfg.encoder is not None:
        updates["encoder"] = EncoderConfig(n_layers=2, n_frames=8)
    return dataclasses.replace(cfg, **updates)
