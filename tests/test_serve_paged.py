"""Block-paged KV cache serving (ISSUE 6).

Covers:
  * PageAllocator refcount/free-list invariants under random churn;
  * chained prefix keys (equal iff the whole prefix matches) and the
    LRU prefix index's reference discipline;
  * bit-identity of paged serving vs the retained contiguous oracle —
    float AND int8-FFIP, GQA (minicpm) AND absorbed-MLA (deepseek),
    decode_chunk 1 and 4, gather and flash paged attention — on a
    mixed-length shared-prefix workload;
  * chunked prefill == single-dispatch prefill, and its interleaving with
    decode (a long prompt must not stall active slots);
  * prefix sharing: shared pages prefilled once (hit counters), COW when a
    shared tail page is decoded into, identical greedy continuations;
  * paged capacity boundary (same cache_rows contract as contiguous),
    pool exhaustion (clean error, no hang) and leak-free teardown.

attention_impl is forced to "naive" so the contiguous oracle and the paged
gather path run literally the same einsums — bit-identity, not allclose.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.models.model import build_model
from repro.serve.batcher import BatchServer, Request
from repro.serve.lifecycle import AdmissionImpossibleError
from repro.serve.paged import (PageAllocator, PrefixIndex, page_keys,
                               partial_key)

MAX_LEN = 48
PS = 8

_MODELS = {}
_REF = {}


def _setup(arch):
    if arch not in _MODELS:
        cfg = configs.smoke_config(configs.get_config(arch))
        cfg = dataclasses.replace(cfg, attention_impl="naive")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        _MODELS[arch] = (cfg, model, params)
    return _MODELS[arch]


def _workload(cfg, seed=0):
    """Mixed lengths + shared prefixes + an exact resubmission."""
    rng = np.random.default_rng(seed)
    base = rng.integers(0, cfg.vocab, size=(20,))
    reqs = []
    for i in range(3):          # 3 prompts sharing a 16-token (2-page) prefix
        tail = rng.integers(0, cfg.vocab, size=(3 + i,))
        reqs.append((np.concatenate([base[:16], tail]), 6))
    reqs.append((reqs[0][0].copy(), 4))          # identical full prompt
    for n, m in [(5, 8), (30, 10), (1, 3), (44, 5)]:
        reqs.append((rng.integers(0, cfg.vocab, size=(n,)), m))
    return reqs


def _run(srv, reqs, params):
    for i, (p, m) in enumerate(reqs):
        srv.submit(Request(rid=i, prompt=p, max_new_tokens=m))
    done = srv.run_until_drained(params)
    return {r.rid: list(r.out_tokens) for r in done}


def _contiguous_ref(arch, quantized):
    key = (arch, quantized)
    if key not in _REF:
        cfg, model, params = _setup(arch)
        srv = BatchServer(model, batch_slots=3, max_len=MAX_LEN,
                          quantized=quantized)
        _REF[key] = _run(srv, _workload(cfg), params)
    return _REF[key]


# -- host-side bookkeeping ----------------------------------------------------

def test_page_allocator_invariants_under_churn():
    rng = np.random.default_rng(0)
    a = PageAllocator(32)
    refs = {}                                    # page -> expected refcount
    for _ in range(2000):
        op = int(rng.integers(0, 3))
        if op == 0 and a.free_count:
            p = a.alloc()
            assert p not in refs, "alloc returned a still-referenced page"
            refs[p] = 1
        elif op == 1 and refs:
            p = int(rng.choice(list(refs)))
            a.incref(p)
            refs[p] += 1
        elif op == 2 and refs:
            p = int(rng.choice(list(refs)))
            freed = a.decref(p)
            refs[p] -= 1
            assert freed == (refs[p] == 0)
            if refs[p] == 0:
                del refs[p]
        assert a.free_count + a.in_use == a.num_pages
        assert a.in_use == len(refs)
        for p, r in refs.items():
            assert a.refcount(p) == r
    while a.free_count:
        refs[a.alloc()] = 1
    assert a.peak_in_use == a.num_pages
    with pytest.raises(RuntimeError):
        a.alloc()


def test_prefix_keys_chained():
    rng = np.random.default_rng(1)
    a = rng.integers(0, 1000, size=(25,))
    b = a.copy()
    b[18] += 1                                   # diverge inside page 2
    ka, kb = page_keys(a, 8), page_keys(b, 8)
    assert len(ka) == 3
    assert ka[:2] == kb[:2], "identical prefix pages must share keys"
    assert ka[2] != kb[2], "divergent page must differ"
    assert partial_key(a, 8) != partial_key(b, 8), \
        "partial key must commit to the whole upstream chain"
    assert partial_key(a[:24], 8) is None, "aligned prompt has no tail"
    assert partial_key(a[:20], 8) != partial_key(a[:21], 8), \
        "tail LENGTH is part of the key"
    d = a.copy()
    d[24] += 1
    assert partial_key(a, 8) != partial_key(d, 8), \
        "tail CONTENT is part of the key"


def test_prefix_index_holds_refs_and_evicts_lru():
    a = PageAllocator(8)
    idx = PrefixIndex(a)
    p0, p1 = a.alloc(), a.alloc()
    idx.register(b"k0", p0)
    idx.register(b"k1", p1)
    assert a.refcount(p0) == 2, "index holds its own reference"
    idx.register(b"k0", p0)                      # idempotent
    assert a.refcount(p0) == 2
    a.decref(p0)                                 # owner finishes
    assert idx.get(b"k0") == p0, "page outlives its owner via the index"
    assert a.refcount(p0) == 1
    # get(k0) promoted it, so the LRU victim is k1 — whose owner still
    # holds a reference: eviction drops the index entry, frees nothing.
    assert idx.evict_lru(1) == 0
    assert idx.get(b"k1") is None
    assert a.refcount(p1) == 1
    assert idx.evict_lru(1) == 1                 # k0 unreferenced -> freed
    assert len(idx) == 0
    assert a.in_use == 1                         # only p1's owner ref left


# -- bit-identity vs the contiguous oracle ------------------------------------

@pytest.mark.parametrize("arch", ["minicpm-2b", "deepseek-v2-lite-16b"])
@pytest.mark.parametrize("quantized,decode_chunk,paged_attention", [
    (False, 1, "gather"),
    (False, 4, "gather"),
    (True, 4, "gather"),
    (False, 4, "flash"),
])
def test_paged_bit_identical_to_contiguous(arch, quantized, decode_chunk,
                                           paged_attention):
    cfg, model, params = _setup(arch)
    want = _contiguous_ref(arch, quantized)
    srv = BatchServer(model, batch_slots=3, max_len=MAX_LEN,
                      quantized=quantized, decode_chunk=decode_chunk,
                      paged=True, page_size=PS, prefill_chunk=16,
                      paged_attention=paged_attention)
    got = _run(srv, _workload(cfg), params)
    assert got == want, {k: (got.get(k), want[k]) for k in want
                         if got.get(k) != want[k]}
    # prefix sharing keeps the footprint under the contiguous equivalent
    assert srv.stats["pages_peak"] < srv.b * srv.max_pages
    assert srv.stats["prefix_hit_tokens"] > 0
    assert srv._reserved == 0, "reservation ledger must drain"
    assert srv.alloc.free_count + srv.alloc.in_use == srv.alloc.num_pages


def test_chunked_prefill_equivalent_to_single_dispatch():
    cfg, model, params = _setup("minicpm-2b")
    want = _contiguous_ref("minicpm-2b", False)
    srv = BatchServer(model, batch_slots=3, max_len=MAX_LEN, paged=True,
                      page_size=PS, prefill_chunk=PS)   # smallest legal chunk
    got = _run(srv, _workload(cfg), params)
    assert got == want
    # the 30- and 44-token prompts really did split into several chunks
    assert srv.stats["prefill_chunks"] > len(want)


# -- prefix sharing & chunk interleaving --------------------------------------

def _run1(srv, params, rid, prompt, max_new):
    srv.submit(Request(rid=rid, prompt=prompt, max_new_tokens=max_new))
    done = srv.run_until_drained(params)
    assert [r.rid for r in done] == [rid]
    return list(done[0].out_tokens)


def test_prefix_sharing_prefills_once_and_cows_shared_tail():
    cfg, model, params = _setup("minicpm-2b")
    srv = BatchServer(model, batch_slots=1, max_len=MAX_LEN, paged=True,
                      page_size=PS, prefill_chunk=PS)
    rng = np.random.default_rng(7)
    base = rng.integers(0, cfg.vocab, size=(20,))    # 2 full pages + 4 tail
    a = _run1(srv, params, 0, base, 4)
    assert srv.stats["prefix_hit_tokens"] == 0
    assert srv.stats["prefill_tokens"] == 20
    # B shares A's two full pages, diverges after: only the new suffix runs
    b_prompt = np.concatenate([base[:16],
                               rng.integers(0, cfg.vocab, size=(6,))])
    _run1(srv, params, 1, b_prompt, 4)
    assert srv.stats["prefix_hit_tokens"] == 16
    assert srv.stats["prefill_tokens"] == 6
    # C resubmits A's prompt verbatim: whole-prompt hit including the
    # partial tail page. Only the LAST token is recomputed (its hidden
    # state feeds the first sample) and NOTHING is rewritten; the first
    # decode write then copy-on-writes the shared tail page.
    c = _run1(srv, params, 2, base, 4)
    assert srv.stats["prefix_hit_tokens"] == 20
    assert srv.stats["prefill_tokens"] == 1
    assert srv.stats["cow_copies"] == 1
    assert c == a, "greedy continuation of an identical prompt must match"
    assert srv._reserved == 0


def test_long_prefill_interleaves_with_decode():
    cfg, model, params = _setup("minicpm-2b")
    srv = BatchServer(model, batch_slots=2, max_len=MAX_LEN, paged=True,
                      page_size=PS, prefill_chunk=PS, prefix_sharing=False)
    rng = np.random.default_rng(9)
    srv.submit(Request(rid=0, prompt=rng.integers(0, cfg.vocab, size=(4,)),
                       max_new_tokens=20))
    srv.step(params)
    srv.step(params)                              # rid 0 is mid-decode
    srv.submit(Request(rid=1, prompt=rng.integers(0, cfg.vocab, size=(40,)),
                       max_new_tokens=4))
    srv.run_until_drained(params)
    ev = srv.events
    chunks = [i for i, e in enumerate(ev)
              if e[0] == "prefill_chunk" and e[1] == 1]
    assert len(chunks) == 5, "40-token prompt must split into 5 8-token chunks"
    for lo, hi in zip(chunks, chunks[1:]):
        assert any(e[0] == "decode" and 0 in e[1] for e in ev[lo:hi]), \
            "active slot must keep decoding between the long prompt's chunks"


# -- capacity, exhaustion, teardown -------------------------------------------

def test_paged_capacity_boundary_and_pool_exhaustion():
    cfg, model, params = _setup("minicpm-2b")
    rng = np.random.default_rng(11)
    p12 = rng.integers(0, cfg.vocab, size=(12,))
    # prompt + max_new - 1 == max_len fits exactly (same cache_rows contract
    # as the contiguous path) and uses exactly ceil(max_len / ps) pages
    srv = BatchServer(model, batch_slots=1, max_len=16, paged=True,
                      page_size=4)
    out = _run1(srv, params, 0, p12, 5)
    assert len(out) == 5
    assert srv.stats["pages_peak"] == 4
    with pytest.raises(ValueError):
        srv.submit(Request(rid=9, prompt=p12, max_new_tokens=6))
    # a request whose worst case exceeds the whole POOL fails loudly at
    # submit time (typed, still a ValueError) instead of entering the
    # queue and hanging it forever
    srv2 = BatchServer(model, batch_slots=2, max_len=16, paged=True,
                       page_size=4, num_pages=2)
    with pytest.raises(AdmissionImpossibleError):
        srv2.submit(Request(rid=0, prompt=p12, max_new_tokens=2))
    assert srv2._reserved == 0
    # a pool smaller than slots x max_pages just queues: admission waits for
    # running requests to release pages, everything still completes
    srv3 = BatchServer(model, batch_slots=2, max_len=16, paged=True,
                       page_size=4, num_pages=4, prefix_sharing=False)
    prompts = [rng.integers(0, cfg.vocab, size=(8,)) for _ in range(3)]
    for i, p in enumerate(prompts):                 # each needs 3 of 4 pages
        srv3.submit(Request(rid=i, prompt=p, max_new_tokens=4))
    done = srv3.run_until_drained(params)
    assert sorted(r.rid for r in done) == [0, 1, 2]
    assert all(len(r.out_tokens) == 4 for r in done)
    assert srv3.alloc.in_use == 0, "no sharing -> every page returns"
    assert srv3._reserved == 0


def test_paged_rejects_unsupported_configs():
    cfg, model, params = _setup("minicpm-2b")
    with pytest.raises(ValueError):                 # non-power-of-two page
        BatchServer(model, batch_slots=1, max_len=48, paged=True, page_size=6)
    with pytest.raises(ValueError):                 # max_len not page-aligned
        BatchServer(model, batch_slots=1, max_len=50, paged=True, page_size=8)
    with pytest.raises(ValueError):                 # chunk not page-aligned
        BatchServer(model, batch_slots=1, max_len=48, paged=True, page_size=8,
                    prefill_chunk=12)
    ssm = build_model(configs.smoke_config(configs.get_config(
        "falcon-mamba-7b")))
    with pytest.raises(ValueError):                 # SSM state is not rows
        BatchServer(ssm, batch_slots=1, max_len=48, paged=True, page_size=8)


# -- ISSUE 8 satellites: faulted/aborted requests must drain the ledger


def test_abort_mid_prefill_releases_reservation_and_keeps_index_clean():
    """Abort a request halfway through chunked prefill: its page
    reservation returns to the ledger (drains to 0), the allocator
    invariant holds, and only FULLY COMPUTED prompt pages were published to
    the prefix index — a resubmission completes with oracle tokens."""
    cfg, model, params = _setup("minicpm-2b")
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab, size=(30,))

    ref = BatchServer(model, batch_slots=1, max_len=MAX_LEN)
    ref.submit(Request(rid=0, prompt=prompt, max_new_tokens=5))
    want = list(ref.run_until_drained(params)[0].out_tokens)

    srv = BatchServer(model, batch_slots=2, max_len=MAX_LEN, paged=True,
                      page_size=PS, num_pages=12, prefill_chunk=PS)
    srv.submit(Request(rid=0, prompt=prompt, max_new_tokens=5))
    srv.step(params)                  # admit + first 8-token prefill chunk
    assert srv.request_phase(0) == "prefilling"
    assert srv._reserved > 0
    assert srv.abort(0)
    assert srv._reserved == 0
    assert srv.alloc.free_count + srv.alloc.in_use == srv.num_pages
    # only the one completed page is published; rows 8.. were never
    # computed, so their keys must NOT serve future prefix hits
    assert len(srv.prefix) <= 1
    srv.submit(Request(rid=1, prompt=prompt, max_new_tokens=5))
    done = srv.run_until_drained(params)
    assert len(done) == 1 and list(done[0].out_tokens) == want
    assert srv._reserved == 0


def test_pool_churn_with_mid_prefill_aborts_never_leaks():
    """Heavy churn through a small pool with prefix sharing and periodic
    mid-prefill aborts: LRU eviction keeps admission alive, every surviving
    request matches its fresh-server oracle, and the allocator/ledger end
    exactly clean."""
    cfg, model, params = _setup("minicpm-2b")
    rng = np.random.default_rng(12)
    base = rng.integers(0, cfg.vocab, size=(16,))
    prompts = [np.concatenate([base, rng.integers(0, cfg.vocab, size=(8,))])
               for _ in range(6)]

    def oracle(p):
        ref = BatchServer(model, batch_slots=1, max_len=MAX_LEN)
        ref.submit(Request(rid=0, prompt=p, max_new_tokens=4))
        return list(ref.run_until_drained(params)[0].out_tokens)

    srv = BatchServer(model, batch_slots=2, max_len=MAX_LEN, paged=True,
                      page_size=PS, num_pages=10, prefill_chunk=PS)
    survivors = {}
    for i, p in enumerate(prompts):
        srv.submit(Request(rid=i, prompt=p, max_new_tokens=4))
        if i % 2 == 0:
            srv.step(params)          # partway into prefill...
            srv.abort(i)              # ...then gone
        else:
            done = srv.run_until_drained(params)
            for r in done:
                survivors[r.rid] = list(r.out_tokens)
        assert srv._reserved == 0 or srv.request_phase(i) is not None
        assert srv.alloc.free_count + srv.alloc.in_use == srv.num_pages
    assert sorted(survivors) == [1, 3, 5]
    for rid, toks in survivors.items():
        assert toks == oracle(prompts[rid]), rid
    # end state: nothing reserved, every page accounted for, and the index
    # holds at most the pool (shared-prefix pages were evicted under churn)
    assert srv._reserved == 0
    assert srv.alloc.free_count + srv.alloc.in_use == srv.num_pages
    assert len(srv.prefix) <= srv.num_pages
