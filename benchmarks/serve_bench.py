"""Serving benchmark: continuous-batching throughput + per-phase timings.

    PYTHONPATH=src python benchmarks/serve_bench.py [--arch minicpm-2b]

Runs the continuous batcher (float and int8-FFIP quantized modes) over a
stream of mixed-length requests and writes ``benchmarks/BENCH_serve.json``:
tok/s plus the prefill / decode / host-overhead split from BatchServer.stats.

CAVEAT (same as gemm_micro): this container is CPU-only, so absolute timings
measure the XLA-CPU + interpret-mode harness, not accelerator silicon — the
load-bearing outputs are the phase RATIOS and the batched-vs-sequential
speedup, which show what the batcher amortizes. Note also that the first
prefill at each distinct prompt length traces+compiles inside the timed
region, so ``phase_s.prefill`` includes jit warmup (as a cold server would).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import numpy as np

from repro import configs
from repro.models.model import build_model
from repro.serve.batcher import BatchServer, Request

OUT = pathlib.Path(__file__).resolve().parent / "BENCH_serve.json"


def bench(arch: str, *, slots: int, requests: int, max_new: int,
          max_len: int, quantized: bool, seed: int = 0) -> dict:
    cfg = configs.smoke_config(configs.get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    srv = BatchServer(model, batch_slots=slots, max_len=max_len,
                      quantized=quantized)
    rng = np.random.default_rng(seed)
    lens = rng.integers(3, 12, requests)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=(int(l),)),
                    max_new_tokens=max_new) for i, l in enumerate(lens)]

    t0 = time.perf_counter()
    for r in reqs:
        srv.submit(r)
    done = srv.run_until_drained(params)
    wall = time.perf_counter() - t0
    assert len(done) == requests, "serve_bench: requests dropped"

    total = sum(len(r.out_tokens) for r in done)
    st = srv.stats
    return {
        "arch": cfg.name,
        "mode": "int8-ffip" if quantized else "float",
        "slots": slots,
        "requests": requests,
        "completed": len(done),
        "tokens_out": total,
        "decode_steps": st["steps"],
        "wall_s": round(wall, 3),
        "tok_per_s": round(total / wall, 2),
        "phase_s": {
            "prefill": round(st["prefill_s"], 3),
            "decode": round(st["decode_s"], 3),
            "host_other": round(wall - st["prefill_s"] - st["decode_s"], 3),
        },
        "prefill_tokens": st["prefill_tokens"],
        "decode_tokens": st["decode_tokens"],
        "decode_ms_per_step": round(1e3 * st["decode_s"] / max(st["steps"], 1), 2),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b",
                    choices=sorted(configs.ARCHS))
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    args = ap.parse_args()

    results = [
        bench(args.arch, slots=args.slots, requests=args.requests,
              max_new=args.max_new, max_len=args.max_len, quantized=q)
        for q in (False, True)
    ]
    out = {
        "bench": "serve",
        "note": ("CPU-only container: interpret-mode timings; ratios and "
                 "phase split are the load-bearing numbers"),
        "results": results,
    }
    OUT.write_text(json.dumps(out, indent=2) + "\n")
    for r in results:
        print(f"serve_bench.{r['arch']}.{r['mode']},{r['tok_per_s']} tok/s,"
              f"prefill={r['phase_s']['prefill']}s,"
              f"decode={r['phase_s']['decode']}s,"
              f"host={r['phase_s']['host_other']}s")
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
