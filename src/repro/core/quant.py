"""Quantization substrate + the paper's ML-specific (F)FIP optimizations (§3.3, §4.4).

Implements:
  * symmetric / asymmetric per-tensor & per-channel int8/int16 quantization
    (Jacob et al. scheme the paper builds on),
  * the "both signed or both unsigned" recommendation (§4.4) — the ``d``
    bit-growth parameter and range checks,
  * beta folding into the bias (Eqs. 15/16),
  * the zero-point adjuster (Eq. 20): for weights stored with a constant
    zero-point matrix R, A(B+R) = AB + AR, and AR_ij = r_j * rowsum(A)_i is
    computable with ONE multiplier per output — folded into the alpha path.

Everything integer is bit-exact: quantized FIP/FFIP GEMM == quantized
baseline GEMM, validated in tests/test_quant.py.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import fip

Array = jax.Array

_INT_INFO = {
    jnp.int8.dtype: (-128, 127),
    jnp.uint8.dtype: (0, 255),
    jnp.int16.dtype: (-(2 ** 15), 2 ** 15 - 1),
    jnp.uint16.dtype: (0, 2 ** 16 - 1),
}

# Offline-prep work counter: every per-layer weight quantization (dense AND
# conv — prepare_quantized_conv routes through prepare_quantized_dense) bumps
# it. repro.prepare snapshots it to prove a warm start re-quantized nothing.
counters = {"prepare_dense": 0}


@dataclasses.dataclass(frozen=True)
class QuantParams:
    """Affine quantization: real = scale * (q - zero_point)."""
    scale: Array          # () or (channels,)
    zero_point: Array     # same shape as scale, stored int32
    dtype: jnp.dtype      # target integer dtype
    axis: Optional[int] = None  # channel axis, None = per-tensor


def d_bit_growth(a_signed: bool, b_signed: bool) -> int:
    """§4.1: d = 1 if a and b are both signed or both unsigned, else 2."""
    return 1 if a_signed == b_signed else 2


def preadd_bits(w: int, a_signed: bool, b_signed: bool) -> int:
    """§4.4: bits needed for the pre-add (a ± b sums): w + d."""
    return w + d_bit_growth(a_signed, b_signed)


def calibrate(x: Array, dtype=jnp.int8, *, symmetric: bool = True,
              axis: Optional[int] = None) -> QuantParams:
    """Min/max calibration producing QuantParams."""
    qmin, qmax = _INT_INFO[jnp.dtype(dtype)]
    reduce_axes = tuple(i for i in range(x.ndim) if i != axis) if axis is not None else None
    if symmetric:
        amax = jnp.max(jnp.abs(x), axis=reduce_axes)
        # signed: +/-qmax around 0. unsigned: +/-(range/2) around midpoint zp.
        bound = qmax if qmin < 0 else (qmax - qmin) // 2
        scale = jnp.maximum(amax / bound, 1e-12)
        zp = (jnp.zeros_like(scale, jnp.int32) if qmin < 0
              else jnp.full_like(scale, (qmax + 1) // 2).astype(jnp.int32))
    else:
        xmin = jnp.min(x, axis=reduce_axes)
        xmax = jnp.max(x, axis=reduce_axes)
        scale = jnp.maximum((xmax - xmin) / (qmax - qmin), 1e-12)
        zp = jnp.clip(jnp.round(qmin - xmin / scale), qmin, qmax).astype(jnp.int32)
    return QuantParams(scale=scale, zero_point=zp, dtype=jnp.dtype(dtype), axis=axis)


def quantize(x: Array, qp: QuantParams) -> Array:
    qmin, qmax = _INT_INFO[qp.dtype]
    scale, zp = qp.scale, qp.zero_point
    if qp.axis is not None:
        shape = [1] * x.ndim
        shape[qp.axis] = -1
        scale = scale.reshape(shape)
        zp = zp.reshape(shape)
    q = jnp.round(x / scale) + zp
    return jnp.clip(q, qmin, qmax).astype(qp.dtype)


def dequantize(q: Array, qp: QuantParams) -> Array:
    scale, zp = qp.scale, qp.zero_point
    if qp.axis is not None:
        shape = [1] * q.ndim
        shape[qp.axis] = -1
        scale = scale.reshape(shape)
        zp = zp.reshape(shape)
    return (q.astype(jnp.int32) - zp).astype(jnp.float32) * scale


# ---------------------------------------------------------------------------
# Integer GEMM with zero-points — baseline and (F)FIP, bit-exact.
# ---------------------------------------------------------------------------

def int_gemm_baseline(aq: Array, bq: Array, za: Array, zb: Array) -> Array:
    """(A - za)(B - zb) in int32, the reference quantized GEMM."""
    a32 = aq.astype(jnp.int32) - za
    b32 = bq.astype(jnp.int32) - zb
    return jnp.matmul(a32, b32)


def zero_point_adjuster(aq: Array, zb: Array) -> Array:
    """Eq. (20) adjuster: AR_ij = zb_j * rowsum(A)_i, one multiply per element.

    The paper folds this into the alpha-generator row; here it is an explicit
    rank-1 term: outer(rowsum(A), zb). ``zb`` may be a per-tensor scalar or a
    per-channel ``(N,)`` vector of weight zero-points.
    """
    rowsum = jnp.sum(aq.astype(jnp.int32), axis=-1, keepdims=True)  # (..., M, 1)
    zb_vec = jnp.atleast_1d(jnp.asarray(zb, jnp.int32))             # (1,) or (N,)
    return rowsum * zb_vec                                          # (..., M, N)


def int_gemm_ffip(aq: Array, bq: Array, za: Array, zb: Array,
                  *, algo: str = "ffip") -> Array:
    """Quantized GEMM via FIP/FFIP with the paper's §3.3/§4.4 optimizations.

    Strategy (mirrors the hardware):
      * run (F)FIP on the RAW quantized integers (both-signed, d=1),
      * beta of the raw weights is folded into the bias offline (Eq. 15),
      * the zero-point contributions are removed via the adjuster (Eq. 20)
        plus the constant K*za*zb and za*colsum(B) terms,
    producing bit-exact int32 equality with :func:`int_gemm_baseline`.
    ``za`` is a per-tensor (or per-row ``(M, 1)``) activation zero-point;
    ``zb`` may be per-tensor or per-channel ``(N,)``.
    """
    k = aq.shape[-1]
    mm = fip.fip_matmul if algo == "fip" else fip.ffip_matmul
    raw = mm(aq.astype(jnp.int32), bq.astype(jnp.int32))       # A_q B_q
    # remove zero-point contributions:
    # (A-za)(B-zb) = AB - za*colsum(B) - zb*rowsum(A) + K*za*zb
    colsum_b = jnp.sum(bq.astype(jnp.int32), axis=0, keepdims=True)
    za = jnp.asarray(za, jnp.int32)
    zb = jnp.asarray(zb, jnp.int32)
    return raw - za * colsum_b - zero_point_adjuster(aq, zb) + k * za * zb


# ---------------------------------------------------------------------------
# Offline-prepared quantized dense layers — the serving decode path.
# ---------------------------------------------------------------------------

def prepare_quantized_dense(w: Array, *, dtype=jnp.int8,
                            symmetric: bool = False) -> dict:
    """Offline weight quantization for the serving path. ``w``: (..., K, N)
    (leading dims are stacked layer groups; each layer calibrates on its own).

    Per-output-channel affine quantization plus everything the paper computes
    once after training:
      * ``neg_beta``  — Eq. (15): -beta(W_q), folded into the integer bias so
        the FFIP beta subtraction costs nothing at inference,
      * ``colsum``    — colsum(W_q), the za-side zero-point term,
      * ``zp``        — per-channel zero-points consumed by the Eq. (20)
        adjuster at decode time.
    """
    counters["prepare_dense"] += 1
    qmin, qmax = _INT_INFO[jnp.dtype(dtype)]
    w = w.astype(jnp.float32)
    if symmetric:
        amax = jnp.max(jnp.abs(w), axis=-2)
        bound = qmax if qmin < 0 else (qmax - qmin) // 2
        scale = jnp.maximum(amax / bound, 1e-12)
        zp = (jnp.zeros_like(scale, jnp.int32) if qmin < 0
              else jnp.full_like(scale, (qmax + 1) // 2).astype(jnp.int32))
    else:
        wmin = jnp.min(w, axis=-2)
        wmax = jnp.max(w, axis=-2)
        scale = jnp.maximum((wmax - wmin) / (qmax - qmin), 1e-12)
        zp = jnp.clip(jnp.round(qmin - wmin / scale), qmin, qmax).astype(jnp.int32)
    qw = jnp.clip(jnp.round(w / scale[..., None, :]) + zp[..., None, :],
                  qmin, qmax).astype(dtype)
    q32 = qw.astype(jnp.int32)
    beta = jnp.sum(q32[..., 0::2, :] * q32[..., 1::2, :], axis=-2)  # Eq. (4)
    return {"qw": qw, "scale": scale, "zp": zp,
            "neg_beta": -beta, "colsum": jnp.sum(q32, axis=-2)}


def quantized_dense_apply(x: Array, q: dict, *, algo: str = "ffip") -> Array:
    """Apply a dense layer through its offline-prepared int8 weights.

    x: (M, K) float; q: per-layer dict from :func:`prepare_quantized_dense`
    (qw (K, N), scale/zp/neg_beta/colsum (N,)). Activations quantize
    dynamically PER TOKEN ROW (asymmetric int8) so a row's result never
    depends on what else is in the batch — continuous-batched decode stays
    bit-identical to sequential decode. Returns float32 (M, N) ~= x @ w.
    """
    qmin, qmax = _INT_INFO[jnp.int8.dtype]
    x32 = x.astype(jnp.float32)
    xmin = jnp.minimum(jnp.min(x32, axis=-1, keepdims=True), 0.0)
    xmax = jnp.maximum(jnp.max(x32, axis=-1, keepdims=True), 0.0)
    a_scale = jnp.maximum((xmax - xmin) / (qmax - qmin), 1e-12)    # (M, 1)
    a_zp = jnp.clip(jnp.round(qmin - xmin / a_scale),
                    qmin, qmax).astype(jnp.int32)                  # (M, 1)
    aq = jnp.clip(jnp.round(x32 / a_scale) + a_zp, qmin, qmax).astype(jnp.int8)

    a32 = aq.astype(jnp.int32)
    b32 = q["qw"].astype(jnp.int32)
    k = b32.shape[-2]
    if algo == "baseline":
        raw = jnp.matmul(a32, b32)                                 # A_q W_q
    elif algo == "ffip":
        # alpha is pair-swap invariant, so FFIP is the Eq. 16 form on the
        # pair-swapped operands with the same offline-folded beta
        raw = fip.fip_matmul_beta_folded(
            fip.pair_swap(a32), fip.pair_swap_rows(b32), q["neg_beta"])
    else:
        raw = fip.fip_matmul_beta_folded(a32, b32, q["neg_beta"])  # Eq. 15/16
    acc = (raw - a_zp * q["colsum"]                 # za * colsum(W_q)
           - zero_point_adjuster(aq, q["zp"])       # Eq. (20): zb_j * rowsum(A)_i
           + k * a_zp * q["zp"])
    return acc.astype(jnp.float32) * (a_scale * q["scale"])


def attach_quantized_weights(params, *, dtype=jnp.int8,
                             skip: Tuple[str, ...] = ("unembed",)) -> dict:
    """Walk a model param tree and attach a ``"q"`` entry (from
    :func:`prepare_quantized_dense`) next to every dense weight ``{"w": ...}``
    whose contraction dim is even. The added leaves carry the same leading
    stacked-layer dims as ``w``, so layer scans slice them transparently.
    Float weights/biases stay in place (gradients, fallback paths, logits —
    ``skip`` defaults to the unembed projection).
    """
    def walk(node):
        if not isinstance(node, dict):
            return node
        if "w" in node and not isinstance(node["w"], dict):
            w = node["w"]
            if w.ndim >= 2 and w.shape[-2] % 2 == 0:
                out = dict(node)
                out["q"] = prepare_quantized_dense(w, dtype=dtype)
                return out
            return node
        return {key: (val if key in skip else walk(val))
                for key, val in node.items()}

    return walk(params)


def quantized_dense_ffip(x: Array, w: Array, bias: Optional[Array],
                         xq: QuantParams, wq: QuantParams,
                         *, algo: str = "ffip") -> Array:
    """Full quantized dense layer: float in -> quant -> FFIP int GEMM -> dequant.

    beta folding: beta(W_q) is computed once from the quantized weights and
    folded into the integer bias (Eq. 15) — the (F)FIP beta subtraction then
    costs nothing at inference, exactly as in the paper.
    """
    aq = quantize(x, xq)
    bq = quantize(w, wq)
    k = aq.shape[-1]
    if k % 2 != 0:
        raise ValueError("pad K to even before quantized FFIP")
    a32 = aq.astype(jnp.int32)
    b32 = bq.astype(jnp.int32)
    beta_folded = fip.fold_beta_into_bias(b32)                    # -beta (Eq. 15)
    if algo == "ffip":
        raw = fip.fip_matmul_beta_folded(
            fip.pair_swap(a32), fip.pair_swap_rows(b32), beta_folded)
    else:
        raw = fip.fip_matmul_beta_folded(a32, b32, beta_folded)   # == A_q B_q
    colsum_b = jnp.sum(b32, axis=0, keepdims=True)
    acc = raw - xq.zero_point * colsum_b \
        - zero_point_adjuster(aq, wq.zero_point) \
        + k * xq.zero_point * wq.zero_point
    out = acc.astype(jnp.float32) * (xq.scale * wq.scale)
    if bias is not None:
        out = out + bias
    return out
