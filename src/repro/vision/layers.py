"""Vision layers — every conv routes through the GEMM provider config.

``conv2d`` is the conv analogue of ``repro.models.layers.dense``: the ambient
:class:`repro.core.gemm.GemmConfig` chooses the arithmetic (baseline / FIP /
FFIP), the implementation, the block policy AND the int8 mode:

  impl      float path                              quantized path ("q" in p)
  --------  --------------------------------------  -------------------------
  pallas    fused implicit-im2col kernels            fused int8 kernels
            (kernels/conv_gemm.py; A never in HBM)   (+ Eq. 15/20 epilogue)
  xla/ref   baseline -> lax.conv (the MXU path);     materializing int8
            fip/ffip -> Algorithm-1 materialized     reference (core.fip
            A + the provider's GEMM algebra          closed forms)

``block="auto"`` resolves fused-conv (bm, bn, bk) from the ``repro.tune``
schedule cache under the conv-specific key (bk aligned to Cin_g*KW), falling
back to the static defaults on a miss — identical contract to the GEMM
providers. BN folding (:func:`fold_bn`) happens offline, before quantization,
exactly as the paper's deployment flow folds beta into the bias.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import im2col, quant
from repro.core.gemm import GemmConfig, current_config, gemm
from repro.core.im2col import Size2, as_pair, conv_out_hw
from repro.kernels import conv_gemm

Array = jax.Array


def conv_init(key, kh: int, kw: int, cin: int, cout: int, *, groups: int = 1,
              bias: bool = True, dtype=jnp.float32) -> dict:
    """He-style init for a (KH, KW, Cin/groups, Cout) filter."""
    cin_g = cin // groups
    fan_in = kh * kw * cin_g
    std = (2.0 / fan_in) ** 0.5
    p = {"w": (jax.random.normal(key, (kh, kw, cin_g, cout), jnp.float32)
               * std).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((cout,), dtype)
    return p


def _effective_algo(cfg: GemmConfig) -> str:
    """Quantized mode runs the integer pair algebra; plain baseline keeps the
    reference integer path (mirrors models.layers.dense)."""
    return cfg.algo if cfg.algo != "baseline" else "ffip"


def _resolve_conv_blocks(cfg: GemmConfig, algo: str, dtype, *, oh: int,
                         ow: int, k: int, n: int, ckw: int,
                         ) -> Tuple[int, int, int]:
    """Trace-time (bm, bn, bk) for the fused conv kernels; (0, 0, 0) = static
    default. ``block="auto"`` consults the repro.tune conv schedules under
    ``algo`` — the algo the kernel will actually run (the quantized and
    float-fallback paths can differ from cfg.algo)."""
    if cfg.block is None:
        return (0, 0, 0)
    if isinstance(cfg.block, (tuple, list)):
        bm, bn, bk = cfg.block
        return (int(bm), int(bn), int(bk))
    if cfg.block == "auto":
        from repro import tune
        got = tune.lookup_conv_blocks(algo, dtype, oh * ow, n, k, ckw)
        return got if got is not None else (0, 0, 0)
    raise ValueError(
        f"GemmConfig.block must be None, 'auto' or (bm, bn, bk); "
        f"got {cfg.block!r}")


def conv2d(x: Array, p: dict, *, stride: Size2 = 1, pad: Size2 = 0,
           groups: int = 1) -> Array:
    """NHWC conv through the ambient GemmConfig. x: (B, H, W, Cin);
    p["w"]: (KH, KW, Cin/groups, Cout); optional p["b"], p["q"]."""
    cfg = current_config()
    w = p["w"]
    kh, kw, cin_g, cout = w.shape
    sh, sw = as_pair(stride)
    ph, pw = as_pair(pad)
    oh, ow = conv_out_hw(x.shape[1], x.shape[2], kh, kw, (sh, sw), (ph, pw))
    if cfg.quantized and "q" in p:
        algo = _effective_algo(cfg)
        if cfg.impl == "pallas":
            bm, bn, bk = _resolve_conv_blocks(
                cfg, algo, jnp.int8, oh=oh, ow=ow, k=kh * kw * cin_g,
                n=cout // groups, ckw=cin_g * kw)
            out = conv_gemm.quantized_conv_apply(
                x, p["q"], stride=(sh, sw), pad=(ph, pw), algo=algo,
                bm=bm, bn=bn, bk=bk, interpret=cfg.interpret)
        else:
            out = conv_gemm.quantized_conv_reference(
                x, p["q"], stride=(sh, sw), pad=(ph, pw), algo=algo)
        out = out.astype(x.dtype)
    elif cfg.impl == "pallas":
        bm, bn, bk = _resolve_conv_blocks(
            cfg, cfg.algo, jnp.result_type(x.dtype, w.dtype), oh=oh, ow=ow,
            k=kh * kw * cin_g, n=cout // groups, ckw=cin_g * kw)
        out = conv_gemm.conv_gemm_fused(
            x, w, stride=(sh, sw), pad=(ph, pw), groups=groups, algo=cfg.algo,
            bm=bm, bn=bn, bk=bk, interpret=cfg.interpret)
    elif cfg.algo == "baseline":
        out = jax.lax.conv_general_dilated(
            x, w, (sh, sw), [(ph, ph), (pw, pw)],
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=groups)
    else:
        # Algorithm-1 materializing path through the provider's algebra
        out = im2col.conv2d_via_gemm(
            x, w, stride=(sh, sw), pad=(ph, pw), groups=groups,
            gemm_fn=lambda a, b: gemm(a, b, cfg))
    if "b" in p:
        out = out + p["b"]
    return out


def relu(x: Array) -> Array:
    return jax.nn.relu(x)


def maxpool2d(x: Array, *, size: Size2 = 2, stride: Optional[Size2] = None,
              pad: Size2 = 0) -> Array:
    """NHWC max pool (AlexNet/VGG 3x3-s2 / 2x2-s2, ResNet stem 3x3-s2-p1)."""
    kh, kw = as_pair(size)
    sh, sw = as_pair(stride if stride is not None else size)
    ph, pw = as_pair(pad)
    neg = (jnp.finfo(x.dtype).min if jnp.issubdtype(x.dtype, jnp.floating)
           else jnp.iinfo(x.dtype).min)
    return jax.lax.reduce_window(
        x, neg, jax.lax.max, (1, kh, kw, 1), (1, sh, sw, 1),
        [(0, 0), (ph, ph), (pw, pw), (0, 0)])


def global_avgpool(x: Array) -> Array:
    """(B, H, W, C) -> (B, C)."""
    return jnp.mean(x, axis=(1, 2))


# ---------------------------------------------------------------------------
# BN folding — the offline inference transform (fold BEFORE quantization).
# ---------------------------------------------------------------------------

def bn_init(cout: int, dtype=jnp.float32) -> dict:
    return {"gamma": jnp.ones((cout,), dtype), "beta": jnp.zeros((cout,), dtype),
            "mean": jnp.zeros((cout,), dtype), "var": jnp.ones((cout,), dtype)}


def batchnorm(x: Array, bn: dict, eps: float = 1e-5) -> Array:
    """Inference-mode BN (running statistics) — the reference fold_bn must
    reproduce exactly through the conv."""
    inv = jax.lax.rsqrt(bn["var"].astype(jnp.float32) + eps)
    return ((x.astype(jnp.float32) - bn["mean"]) * inv * bn["gamma"]
            + bn["beta"]).astype(x.dtype)


def fold_bn(conv_p: dict, bn: dict, eps: float = 1e-5) -> dict:
    """Fold inference BN into the preceding conv: w' = w * g/sqrt(v+eps) per
    output channel, b' = (b - mean) * g/sqrt(v+eps) + beta. Run before
    ``prepare_quantized_conv`` so the int8 path quantizes the folded filter
    (the same offline ordering as the paper's Eq. 15 beta fold)."""
    inv = jax.lax.rsqrt(bn["var"].astype(jnp.float32) + eps)
    scale = (bn["gamma"].astype(jnp.float32) * inv)
    w = conv_p["w"].astype(jnp.float32) * scale          # broadcast over Cout
    b = conv_p.get("b")
    b = jnp.zeros_like(scale) if b is None else b.astype(jnp.float32)
    b = (b - bn["mean"].astype(jnp.float32)) * scale + bn["beta"].astype(jnp.float32)
    out = dict(conv_p)
    out["w"] = w.astype(conv_p["w"].dtype)
    out["b"] = b.astype(conv_p["w"].dtype)
    return out


def attach_quantized_conv(p: dict, *, groups: int = 1, dtype=jnp.int8) -> dict:
    """Attach the offline int8 entry next to a conv's float weights (the conv
    analogue of ``core.quant.attach_quantized_weights``)."""
    out = dict(p)
    out["q"] = conv_gemm.prepare_quantized_conv(p["w"], groups=groups,
                                                dtype=dtype)
    return out


def attach_quantized_fc(p: dict, *, dtype=jnp.int8) -> dict:
    """Attach the serving-style int8 entry to an FC layer when its
    contraction dim is even (odd-K layers stay float, as in the LM path)."""
    w = p["w"]
    if w.shape[-2] % 2 != 0:
        return p
    out = dict(p)
    out["q"] = quant.prepare_quantized_dense(w, dtype=dtype)
    return out
