"""Public jit'd wrappers over the Pallas GEMM kernels.

Handles: leading batch dims, dtype policy (int8→int32 accumulation,
bf16→f32), default block selection for VMEM fit (:func:`choose_blocks`),
and output casting. Padding to block multiples lives in the kernels
themselves (``baseline_gemm.pad_to_blocks`` — zero rows/cols are exact for
the baseline products and the FIP/FFIP cross/α/β algebra), so any caller —
this wrapper, the repro.tune measurement harness, or a direct kernel user —
gets the same pad-run-slice fallback.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.baseline_gemm import baseline_gemm
# Public surface for the Pallas API-drift shim (kernel modules import it from
# repro.kernels.compat to avoid a circular import with this module).
from repro.kernels.compat import resolve_interpret, tpu_compiler_params  # noqa: F401
from repro.kernels.fip_gemm import fip_gemm
from repro.kernels.ffip_gemm import ffip_gemm
from repro.obs import profile as _obs_profile

Array = jax.Array

# VMEM budget per operand block (bytes) used by the block chooser. A v5e core
# has ~16 MiB VMEM; the FIP cross tensor is (bm, bk/2, bn) so bk is the lever.
_VMEM_BUDGET = 6 * 1024 * 1024


def choose_blocks(m: int, n: int, k: int, algo: str,
                  itemsize: int = 4) -> Tuple[int, int, int]:
    bm = min(128, _round_up_pow2(m))
    bn = min(128, _round_up_pow2(n))
    if algo == "baseline":
        bk = min(512, _round_up_pow2(k))
    else:
        # fit 3 x (bm, bk/2, bn) f32 tensors in budget
        bk = 8
        while (3 * bm * bn * (bk) // 2 * itemsize) <= _VMEM_BUDGET and bk < 256:
            bk *= 2
        bk //= 2
        bk = max(2, min(bk, _round_up_pow2(k)))
    return bm, bn, bk


def _round_up_pow2(x: int) -> int:
    p = 8
    while p < x and p < 1024:
        p *= 2
    return p


def matmul(a: Array, b: Array, *, algo: str = "ffip", interpret=None,
           bm: int = 0, bn: int = 0, bk: int = 0) -> Array:
    """C = A @ B via the Pallas kernels. a: (..., M, K), b: (K, N).

    Returns the result cast back to the promoted input dtype for floats and
    int32 for integer inputs (hardware-accumulator semantics).
    ``interpret=None`` auto-detects the backend (kernels/compat.py); pass
    ``bm``/``bn``/``bk`` (e.g. from a ``repro.tune`` schedule) to override the
    static default blocks.

    Thin python wrapper over the jitted core so ``repro.obs.profile`` sees
    every dispatch (eager call = dispatch; tracer operands = compile-side).
    """
    _obs_profile.on_gemm(a, b, algo)
    return _matmul_jit(a, b, algo=algo, interpret=interpret,
                       bm=bm, bn=bn, bk=bk)


@functools.partial(jax.jit, static_argnames=("algo", "interpret", "bm", "bn", "bk"))
def _matmul_jit(a: Array, b: Array, *, algo: str = "ffip", interpret=None,
                bm: int = 0, bn: int = 0, bk: int = 0) -> Array:
    interpret = resolve_interpret(interpret)
    *batch, m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch {k} vs {k2}")
    a2 = a.reshape(-1, k) if batch else a
    mm = a2.shape[0]

    if not (bm and bn and bk):
        bm, bn, bk = choose_blocks(mm, n, k, algo)

    # non-divisible shapes are padded/sliced inside the kernels (exactly)
    if algo == "baseline":
        out = baseline_gemm(a2, b, bm=bm, bn=bn, bk=bk, interpret=interpret)
    elif algo == "fip":
        out = fip_gemm(a2, b, bm=bm, bn=bn, bk=bk, interpret=interpret)
    elif algo == "ffip":
        out = ffip_gemm(a2, b, bm=bm, bn=bn, bk=bk, interpret=interpret)
    else:
        raise ValueError(algo)

    if batch:
        out = out.reshape(*batch, m, n)
    if jnp.issubdtype(a.dtype, jnp.integer):
        return out  # int32 accumulator, caller rescales
    return out.astype(jnp.result_type(a.dtype, b.dtype))
