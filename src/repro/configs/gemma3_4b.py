"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144 — 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, d_ff=10240,
    vocab=262144, head_dim=256,
    sliding_window=1024, local_global_period=6,   # 5 local : 1 global
    rope_theta=10000.0, rope_theta_global=1e6,
    tie_embeddings=True, act="gelu",
    supports_long_context=True,   # 5/6 layers are 1k-window; global layers decode-linear
)
