"""Serving launcher: per-slot continuous batching over any arch.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --smoke \
        --requests 8 --slots 4 --decode-chunk 4

``--quantized`` routes the dense/attention projections through the int8 FFIP
decode path (offline-quantized weights, Eq. 15 folded beta, Eq. 20 zero-point
adjuster). ``--decode-chunk N`` fuses N decode steps into one dispatch
(sampling stays on device either way); bucketed batched prefill is on by
default (``--no-prefill-buckets`` forces the per-slot fallback).
``--gemm-impl pallas`` routes the serving projections through the Pallas
kernels and ``--gemm-block auto`` resolves their block shapes (plus flash
attention's) from the ``repro.tune`` schedule cache — pre-populate it with
``python -m repro.launch.tune``.

``--paged`` switches to the block-paged KV cache (page pool + per-slot page
tables, refcounted prefix sharing, chunked prefill); ``--shared-prefix``
makes the synthetic workload share a long prompt prefix so page reuse has
something to bite on, and ``--compare-contiguous`` re-runs the identical
workload on the contiguous cache and asserts BYTE-IDENTICAL outputs plus a
paged-footprint win. Exits non-zero if any request is dropped or over/under-
generates, so this doubles as the CI batcher-regression smoke.

``--replicas N`` serves the workload through the fault-tolerant
multi-replica router instead of a single server: N data-parallel
``BatchServer`` replicas (``--quantized-replicas M`` makes the last M of
them int8-FFIP shed targets) behind load-aware dispatch, bounded-queue
admission control, per-request deadlines (``--deadline-ms``), bounded
retries and a per-replica circuit breaker. ``--fault-plan`` installs a
deterministic chaos schedule — inline JSON, ``@path/to/plan.json``, or the
shorthand ``flaky`` (replica 0 flaps raise/hang) — driven on a fake clock;
the run must end with every request DONE (token-identical to a no-fault
oracle of its serving tier) or failed with a TYPED error, never stuck.

Observability (``repro.obs``): ``--metrics-json PATH`` dumps the run's
metric registry snapshot (plus the unified compile-counter snapshot) as
JSON at exit; ``--trace-out PATH`` writes the span trace — ``.jsonl`` for
the line-per-span form, anything else for Chrome ``trace_event`` JSON
(load in Perfetto / chrome://tracing); ``--metrics-port N`` serves live
Prometheus text on ``http://127.0.0.1:N/metrics`` for the duration of the
run. ``python -m repro.launch.obs_check`` validates the two files against
the workload (CI obs-smoke gate).

``--prepared DIR`` serves from a `repro.prepare` artifact (built with
``python -m repro.launch.prepare``) instead of preparing weights in-process:
warm start, zero re-quantization / y re-encode / re-tune. ``--mesh-model N``
runs tensor-parallel decode over the first N devices (the repro.dist rule
engine shards params + KV cache on the "model" axis) and
``--compare-single-device`` re-runs the workload without the mesh and asserts
byte-identical output tokens. ``--require-warm`` fails fast — listing the
missing keys — if any schedule-cache lookup missed or the artifact had to
recompute anything.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

import repro.obs as obs
from repro import configs
from repro.models.model import build_model
from repro.obs import profile as obs_profile
from repro.serve.batcher import BatchServer, Request


def _make_prompts(cfg, n_requests, shared_prefix, rng):
    lens = rng.integers(3, 12, n_requests)
    if not shared_prefix:
        return [rng.integers(0, cfg.vocab, size=(int(lens[i]),))
                for i in range(n_requests)]
    # half the requests carry a common 16-token prefix; one is an exact
    # duplicate of another (whole-prompt hit including the partial tail page)
    base = rng.integers(0, cfg.vocab, size=(16,))
    prompts = []
    for i in range(n_requests):
        if i % 2 == 0:
            tail = rng.integers(0, cfg.vocab, size=(int(lens[i]),))
            prompts.append(np.concatenate([base, tail]))
        else:
            prompts.append(rng.integers(0, cfg.vocab, size=(int(lens[i]),)))
    if n_requests >= 3:
        prompts[-1] = prompts[0].copy()
    return prompts


def _make_mesh(tp: int):
    from jax.sharding import Mesh
    n = len(jax.devices())
    if tp > n:
        raise SystemExit(f"--mesh-model {tp} but only {n} devices visible "
                         f"(XLA_FLAGS=--xla_force_host_platform_device_count="
                         f"{tp} forces host devices)")
    return Mesh(np.array(jax.devices()[:tp]).reshape(1, tp),
                ("data", "model"))


def _serve(model, params, prompts, max_new, args, *, paged, mesh=None,
           prepared=None):
    srv = BatchServer(
        model, batch_slots=args.slots, max_len=args.max_len,
        quantized=args.quantized, decode_chunk=args.decode_chunk,
        gemm_impl=args.gemm_impl, gemm_block=args.gemm_block_parsed,
        prefill_buckets=not args.no_prefill_buckets, paged=paged,
        page_size=args.page_size, num_pages=args.num_pages,
        prefill_chunk=args.prefill_chunk,
        paged_attention=args.paged_attention, mesh=mesh, prepared=prepared)
    t0 = time.perf_counter()
    for i, p in enumerate(prompts):
        srv.submit(Request(rid=i, prompt=p, max_new_tokens=max_new))
    done = srv.run_until_drained(params)
    return srv, done, time.perf_counter() - t0


def _serve_router(model, params, prompts, args, *, mesh=None, prepared=None):
    """Multi-replica serving path (--replicas): returns exit-gate failures."""
    from repro.serve.faults import FakeClock, FaultPlan
    from repro.serve.lifecycle import Lifecycle, ServeStallError
    from repro.serve.router import ReplicaRouter, RouterConfig

    plan = None
    if args.fault_plan:
        plan = (FaultPlan.flaky_replica(0) if args.fault_plan == "flaky"
                else FaultPlan.parse(args.fault_plan))
    nq = min(args.quantized_replicas, args.replicas)
    tiers = [i >= args.replicas - nq for i in range(args.replicas)]
    clock = FakeClock() if plan is not None else None

    objectives = None
    if args.slo:
        fast_s, slow_s = (float(x) for x in args.slo_windows.split(","))
        objectives = [obs.Objective.parse(
            spec, fast_window_s=fast_s, slow_window_s=slow_s,
            min_count=args.slo_min_count) for spec in args.slo]

    def mk(q):
        # clock threading: under a fault plan every replica reads the SAME
        # fake clock as the router, so spans/latency histograms line up with
        # the deterministic fault schedule.
        return BatchServer(
            model, batch_slots=args.slots, max_len=args.max_len,
            quantized=q, decode_chunk=args.decode_chunk,
            gemm_impl=args.gemm_impl, gemm_block=args.gemm_block_parsed,
            prefill_buckets=not args.no_prefill_buckets, paged=args.paged,
            page_size=args.page_size, num_pages=args.num_pages,
            prefill_chunk=args.prefill_chunk,
            paged_attention=args.paged_attention, mesh=mesh,
            prepared=prepared, clock=clock)

    servers = [mk(q or args.quantized) for q in tiers]
    rt = ReplicaRouter(servers, params, fault_plan=plan, clock=clock,
                       cfg=RouterConfig(
                           step_timeout_s=5.0, quarantine_s=0.2,
                           max_retries=4, objectives=objectives,
                           default_deadline_s=(args.deadline_ms / 1000.0
                                               if args.deadline_ms else
                                               None)))
    t0 = time.perf_counter()
    for i, p in enumerate(prompts):
        rt.submit(Request(rid=i, prompt=p, max_new_tokens=args.max_new,
                          eos_id=-1))
    try:
        recs = rt.drive(max_ticks=50_000)
    except ServeStallError as e:
        raise SystemExit(f"FAIL: {e}")
    # extra idle ticks so the burn windows can expire and the degradation
    # controller can walk back to healthy (the obs_check recovery gate)
    for _ in range(args.slo_drain_ticks):
        rt.step()
    dt = time.perf_counter() - t0

    # no-fault single-server oracle per tier that actually served work
    want = {}
    for q in sorted({rec.tier == "int8" for rec in recs.values()
                     if rec.state is Lifecycle.DONE}):
        ref = mk(q)
        for i, p in enumerate(prompts):
            ref.submit(Request(rid=i, prompt=p, max_new_tokens=args.max_new,
                               eos_id=-1))
        want[q] = {r.rid: list(r.out_tokens)
                   for r in ref.run_until_drained(params)}

    outcomes = rt.outcome_counts()
    done = [rec for rec in recs.values() if rec.state is Lifecycle.DONE]
    lat = np.array(sorted(rec.t_done - rec.t_submit for rec in done)) \
        if done else np.zeros((0,))
    unit = "fake-s" if clock is not None else "s"
    mode = (f"router x{args.replicas}"
            + (f" ({nq} int8 shed targets)" if nq else "")
            + ("/paged" if args.paged else "")
            + (f"/faults[{len(plan.faults)}]" if plan is not None else ""))
    print(f"[{mode}] {len(done)}/{len(prompts)} done in {dt:.2f}s wall — "
          f"outcomes {outcomes}")
    if len(lat):
        print(f"  e2e latency ({unit}): p50={np.percentile(lat, 50):.4f} "
              f"p99={np.percentile(lat, 99):.4f}")
    print(f"  router: {rt.stats}")
    if rt.slo is not None:
        states = {k: v.name for k, v in rt.slo.states().items()}
        ctl = {key[0]: int(c.value) for key, c in
               rt.registry.get("router_controller_total")._children.items()}
        print(f"  slo: states={states} controller={rt.ctl_state} "
              f"actions={ctl}")

    problems = []
    if any(not rec.terminal for rec in recs.values()):
        problems.append("non-terminal requests after drive()")
    for rec in recs.values():
        if rec.state is Lifecycle.DONE:
            if rec.tokens != want[rec.tier == "int8"][rec.req.rid]:
                problems.append(
                    f"rid {rec.req.rid}: tokens diverge from the no-fault "
                    f"{rec.tier} oracle")
        elif rec.error is None:
            problems.append(f"rid {rec.req.rid}: failed without a typed "
                            f"error ({rec.state.value})")
    if plan is None and args.deadline_ms is None and len(done) != len(recs):
        problems.append("requests failed with no faults injected")
    for s in servers:
        if s.paged and s._reserved != 0:
            problems.append("page reservation ledger did not drain to 0")
    return problems, rt


def _write_obs(args, tracer) -> None:
    """Dump --metrics-json / --trace-out. Called BEFORE the regression gates
    raise, so a failing run still leaves its telemetry behind for triage."""
    if args.metrics_json:
        import json
        payload = {"metrics": obs.get_registry().snapshot(),
                   "compile": obs_profile.compile_snapshot()}
        with open(args.metrics_json, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        print(f"  obs: metrics -> {args.metrics_json}")
    if args.trace_out and tracer is not None:
        tracer.write(args.trace_out)
        print(f"  obs: trace ({len(tracer.spans)} spans, "
              f"{tracer.dropped} dropped) -> {args.trace_out}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(configs.ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--quantized", action="store_true",
                    help="int8 FFIP decode path (offline weight quantization)")
    ap.add_argument("--decode-chunk", type=int, default=1,
                    help="decode steps fused into one dispatch (lax.scan)")
    ap.add_argument("--no-prefill-buckets", action="store_true",
                    help="disable bucketed batched prefill (per-slot fallback)")
    ap.add_argument("--gemm-impl", choices=["xla", "pallas"], default=None,
                    help="GEMM provider for the serving forward "
                         "(pallas = the paper's kernels)")
    ap.add_argument("--gemm-block", default=None,
                    help="'auto' (repro.tune schedule cache; also tunes flash "
                         "attention blocks) or explicit 'bm,bn,bk' (needs --gemm-impl pallas)")
    ap.add_argument("--paged", action="store_true",
                    help="block-paged KV cache (page pool + page tables, "
                         "prefix sharing, chunked prefill)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=None,
                    help="pool size; default slots * max_len / page_size")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="page-aligned prefill chunk width; default max_len "
                         "(one chunk per prompt)")
    ap.add_argument("--paged-attention", choices=["gather", "flash"],
                    default="gather",
                    help="gather = contiguous-view oracle math (bit-identical "
                         "to --no --paged); flash = paged Pallas kernel")
    ap.add_argument("--shared-prefix", action="store_true",
                    help="workload shares a 16-token prefix across half the "
                         "requests + one exact duplicate prompt")
    ap.add_argument("--compare-contiguous", action="store_true",
                    help="also run the contiguous cache on the same workload "
                         "and assert byte-identical outputs (needs --paged)")
    ap.add_argument("--replicas", type=int, default=0, metavar="N",
                    help="serve through the multi-replica router over N "
                         "data-parallel BatchServer replicas (0 = single "
                         "server, the default)")
    ap.add_argument("--quantized-replicas", type=int, default=0, metavar="M",
                    help="make the last M of --replicas int8-FFIP shed "
                         "targets (graceful degradation under pressure)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request end-to-end deadline for the router "
                         "path (typed TIMED_OUT past it)")
    ap.add_argument("--fault-plan", default=None, metavar="JSON|@FILE|flaky",
                    help="deterministic chaos schedule for the router path "
                         "(inline JSON, @path, or 'flaky'); runs on a fake "
                         "clock")
    ap.add_argument("--slo", action="append", default=None, metavar="SPEC",
                    help="SLO objective for the router path, repeatable — "
                         "'ttft_ms p99 < 2000' or 'error_rate < 0.25'; "
                         "enables the burn-rate degradation controller")
    ap.add_argument("--slo-windows", default="5,30", metavar="FAST,SLOW",
                    help="burn-rate window lengths in (fake) seconds "
                         "(default 5,30)")
    ap.add_argument("--slo-min-count", type=int, default=3,
                    help="min samples per window before an SLO can PAGE")
    ap.add_argument("--slo-drain-ticks", type=int, default=0, metavar="N",
                    help="idle router ticks after the workload drains, so "
                         "burn windows expire and the controller recovers")
    ap.add_argument("--prepared", default=None, metavar="DIR",
                    help="serve from a repro.prepare artifact "
                         "(python -m repro.launch.prepare)")
    ap.add_argument("--mesh-model", type=int, default=0, metavar="N",
                    help="tensor-parallel decode over the first N devices "
                         "(repro.dist sharding on the 'model' axis)")
    ap.add_argument("--compare-single-device", action="store_true",
                    help="re-run the workload without the mesh and assert "
                         "byte-identical output tokens (needs --mesh-model)")
    ap.add_argument("--require-warm", action="store_true",
                    help="fail fast (listing missing keys) if any schedule "
                         "lookup missed or the prepared artifact recomputed "
                         "offline work")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write the repro.obs metric-registry snapshot (+ "
                         "unified compile counters) as JSON at exit")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the span trace: *.jsonl = one span per line, "
                         "otherwise Chrome trace_event JSON (Perfetto)")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="N",
                    help="serve live Prometheus text on 127.0.0.1:N/metrics "
                         "for the duration of the run (0 = ephemeral port)")
    args = ap.parse_args()
    if args.slo and not args.replicas:
        raise SystemExit("--slo requires --replicas (the burn-rate "
                         "degradation controller lives in the router)")
    args.gemm_block_parsed = args.gemm_block
    if args.gemm_block and args.gemm_block != "auto":
        args.gemm_block_parsed = tuple(
            int(x) for x in args.gemm_block.split(","))

    # Fresh per-run registry + profiler so --metrics-json captures exactly
    # this run (servers/routers/kernel hooks all resolve the process default
    # at construction time).
    obs.set_registry(obs.Registry())
    obs_profile.set_profiler(None)
    if args.metrics_port is not None:
        httpd = obs.start_metrics_server(obs.get_registry(),
                                         port=args.metrics_port)
        print(f"metrics: http://{httpd.server_address[0]}:"
              f"{httpd.server_address[1]}/metrics")

    cfg = configs.get_config(args.arch)
    if args.smoke:
        cfg = configs.smoke_config(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    prepared = None
    if args.prepared:
        from repro import prepare
        from repro.prepare.artifact import ArtifactError
        t0 = time.perf_counter()
        try:
            prepared = prepare.load(args.prepared)
            print(f"loaded prepared artifact {args.prepared} "
                  f"({len(prepared.derived)} y-deltas, "
                  f"{len(prepared.schedule)} schedule entries, "
                  f"{time.perf_counter() - t0:.2f}s)")
        except ArtifactError as e:
            # graceful degradation: a corrupt artifact (already quarantined
            # by the loader) falls back to in-process preparation instead of
            # taking serving down — unless warm start was REQUIRED.
            if args.require_warm:
                raise SystemExit(f"--require-warm but the prepared artifact "
                                 f"is unusable: {e}")
            print(f"WARNING: prepared artifact unusable ({e}); falling back "
                  f"to in-process preparation", file=sys.stderr)
    mesh = _make_mesh(args.mesh_model) if args.mesh_model else None
    if args.require_warm:
        from repro import tune
        tune.reset_stats()

    rng = np.random.default_rng(0)
    prompts = _make_prompts(cfg, args.requests, args.shared_prefix, rng)

    if args.replicas:
        problems, rt = _serve_router(model, params, prompts, args, mesh=mesh,
                                     prepared=prepared)
        _write_obs(args, rt.tracer)
        if problems:
            print("FAIL:\n  " + "\n  ".join(problems), file=sys.stderr)
            raise SystemExit(1)
        print("OK")
        return

    srv, done, dt = _serve(model, params, prompts, args.max_new, args,
                           paged=args.paged, mesh=mesh, prepared=prepared)
    _write_obs(args, srv.tracer)

    total = sum(len(r.out_tokens) for r in done)
    mode = "int8-ffip" if args.quantized else "float"
    if args.paged:
        mode += f"/paged-{args.paged_attention}"
    if mesh is not None:
        mode += f"/tp{args.mesh_model}"
    if prepared is not None:
        mode += "/prepared"
    st = srv.stats
    print(f"[{mode}] {len(done)}/{args.requests} requests / {total} tokens "
          f"in {dt:.2f}s ({total / dt:.1f} tok/s host-side, "
          f"decode_chunk={args.decode_chunk})")
    print(f"  prefill {st['prefill_s']:.2f}s ({st['prefill_tokens']} tok / "
          f"{st['prefill_dispatches']} dispatches), "
          f"decode {st['decode_s']:.2f}s over {st['steps']} steps / "
          f"{st['decode_dispatches']} dispatches ({st['decode_tokens']} tok), "
          f"host/other {dt - st['prefill_s'] - st['decode_s']:.2f}s")
    print(f"  compiles: prefill={srv.compiles['prefill']} "
          f"decode={srv.compiles['decode']}, "
          f"host transfer {st['host_bytes_prefill'] + st['host_bytes_decode']}"
          f" B total "
          f"(sampling on device: ids only, never (B, V) logits)")
    if args.paged:
        cap = srv.b * srv.max_pages
        print(f"  paged: pages_peak={st['pages_peak']}/{srv.alloc.num_pages} "
              f"(contiguous equivalent {cap}), "
              f"prefix_hit_tokens={st['prefix_hit_tokens']}, "
              f"cow_copies={st['cow_copies']}, "
              f"prefill_chunks={st['prefill_chunks']}, "
              f"page-table upload {st['host_bytes_page_tables']} B")
    if args.gemm_block == "auto":
        from repro import tune
        print(f"  tune: {tune.stats['hits']} schedule hits / "
              f"{tune.stats['misses']} misses (cache: "
              f"{tune.get_cache().path})")

    # regression gates: nothing dropped, exact token budgets, valid ids
    assert len(done) == args.requests, "run_until_drained dropped requests"
    assert sorted(r.rid for r in done) == list(range(args.requests))
    for r in done:
        assert len(r.out_tokens) == r.max_new_tokens, \
            (r.rid, len(r.out_tokens), r.max_new_tokens)
        assert all(0 <= t < cfg.vocab for t in r.out_tokens), r.rid
    if args.paged:
        assert srv._reserved == 0, "page reservation ledger did not drain"
        assert (srv.alloc.free_count + srv.alloc.in_use
                == srv.alloc.num_pages), "page allocator leaked"
        if args.shared_prefix:
            assert st["prefix_hit_tokens"] > 0, "no prefix reuse observed"
            assert st["pages_peak"] < srv.b * srv.max_pages, \
                "paged footprint should beat slots x max_len under sharing"
    if args.compare_contiguous:
        if not args.paged:
            raise SystemExit("--compare-contiguous requires --paged")
        ref_srv, ref_done, _ = _serve(model, params, prompts, args.max_new,
                                      args, paged=False)
        got = {r.rid: r.out_tokens for r in done}
        want = {r.rid: r.out_tokens for r in ref_done}
        assert got == want, "paged outputs diverge from contiguous oracle"
        print(f"  compare-contiguous: {total} tokens byte-identical")
    if args.compare_single_device:
        if mesh is None:
            raise SystemExit("--compare-single-device requires --mesh-model")
        ref_srv, ref_done, _ = _serve(model, params, prompts, args.max_new,
                                      args, paged=args.paged, mesh=None,
                                      prepared=prepared)
        got = {r.rid: r.out_tokens for r in done}
        want = {r.rid: r.out_tokens for r in ref_done}
        assert got == want, \
            f"tp{args.mesh_model} tokens diverge from single-device"
        print(f"  compare-single-device: {total} tokens byte-identical "
              f"at tp={args.mesh_model}")
    if args.require_warm:
        from repro import tune
        problems = []
        if tune.stats["misses"]:
            problems.append(
                f"{tune.stats['misses']} schedule-cache misses fell back to "
                f"defaults:\n    " + "\n    ".join(sorted(tune._warned_keys)))
        if prepared is not None and prepared.recomputed:
            problems.append(
                f"prepared artifact recomputed offline work: "
                f"{prepared.recompute_report()}")
        if problems:
            print("--require-warm: FAIL\n  " + "\n  ".join(problems),
                  file=sys.stderr)
            raise SystemExit(1)
        checks = ["0 schedule misses"]
        if prepared is not None:
            checks.append("prepared.recomputed == 0")
        print(f"  require-warm: {', '.join(checks)}")
    print("OK")


if __name__ == "__main__":
    main()
