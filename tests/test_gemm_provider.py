"""The paper's central architectural claim, framework-scale: an (F)FIP
'systolic array' drops into the accelerator without changing anything else.
We swap the GEMM provider under real model families and assert identical
numerics (paper §1: 'without fundamentally altering the accelerator's
functionality or internal interfaces in any way')."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.gemm import GemmConfig, use_gemm
from repro.models.model import build_model
from repro.models import frontends

# one representative per family: dense, moe, mla+moe, ssm, hybrid, enc-dec, vlm
ARCHS = ["starcoder2-3b", "mixtral-8x22b", "deepseek-v2-lite-16b",
         "falcon-mamba-7b", "zamba2-1.2b", "whisper-small", "pixtral-12b"]


def _batch(cfg, key, batch=2, seq=16):
    b = {"tokens": jax.random.randint(key, (batch, seq), 0, cfg.vocab),
         "labels": jax.random.randint(key, (batch, seq), 0, cfg.vocab)}
    if cfg.encoder is not None:
        b["frames"] = frontends.audio_frames_stub(key, batch, cfg)
    if cfg.frontend == "vision":
        b["patches"] = frontends.vision_patches_stub(key, batch, cfg)
    return b


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("algo", ["fip", "ffip"])
def test_gemm_provider_archs(arch, algo):
    cfg = configs.smoke_config(configs.get_config(arch))
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch(cfg, key)
    base = float(model.loss(params, batch))
    with use_gemm(GemmConfig(algo=algo, impl="ref")):
        swapped = float(model.loss(params, batch))
    np.testing.assert_allclose(swapped, base, rtol=2e-3, atol=2e-3)


def test_gemm_provider_pallas_impl():
    """Pallas-kernel provider under a dense layer stack (small shapes)."""
    cfg = configs.smoke_config(configs.get_config("minicpm-2b"))
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    batch = _batch(cfg, key)
    base = float(model.loss(params, batch))
    with use_gemm(GemmConfig(algo="ffip", impl="pallas", interpret=True)):
        swapped = float(model.loss(params, batch))
    np.testing.assert_allclose(swapped, base, rtol=5e-3, atol=5e-3)
