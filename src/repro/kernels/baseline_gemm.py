"""Baseline systolic-array-style blocked GEMM as a Pallas TPU kernel.

The comparison baseline (Fig. 1a PEs): a straightforward MXU-mapped blocked
matmul with explicit BlockSpec VMEM tiling. Grid (M/bm, N/bn, K/bk), K
innermost for in-VMEM accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

Array = jax.Array


def _kernel(a_ref, b_ref, o_ref, *, acc_dtype):
    k = pl.program_id(2)
    a = a_ref[...].astype(acc_dtype)
    b = b_ref[...].astype(acc_dtype)
    if jnp.issubdtype(acc_dtype, jnp.integer):
        part = jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                                   preferred_element_type=acc_dtype)
    else:
        part = jnp.dot(a, b, preferred_element_type=acc_dtype)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = part

    @pl.when(k != 0)
    def _acc():
        o_ref[...] += part


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def baseline_gemm(a: Array, b: Array, *, bm: int = 128, bn: int = 128,
                  bk: int = 128, interpret: bool = True) -> Array:
    """a: (M, K), b: (K, N) -> (M, N) in the accumulation dtype.

    M, N, K must be multiples of the block sizes (ops.py pads).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0
    acc_dtype = (jnp.int32 if jnp.issubdtype(a.dtype, jnp.integer)
                 else jnp.float32)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_kernel, acc_dtype=acc_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), acc_dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
