"""The paper's deployment story: int8 quantized inference through FFIP with
every ML-specific optimization from §3.3/§4.4:

  * both-signed quantization (d=1 pre-adders),
  * beta folded into the bias (Eq. 15) — free at inference,
  * y-deltas precomputed from weights (Eq. 9),
  * zero-point contributions removed via the adjuster algebra (Eq. 20),
and verifies the int32 accumulators are BIT-EXACT vs the baseline quantized
GEMM while using ~half the multiplies.

    PYTHONPATH=src python examples/quantized_ffip_inference.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import analytical as an
from repro.core import fip, quant


def main():
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)

    # a 2-layer MLP "deployed" with 8-bit weights/activations
    x = jax.random.normal(k1, (32, 256))
    w1 = jax.random.normal(k2, (256, 512)) * 0.05
    w2 = jax.random.normal(k3, (512, 64)) * 0.05
    b1 = jnp.zeros((512,))
    b2 = jnp.zeros((64,))

    xq = quant.calibrate(x, jnp.int8, symmetric=False)     # activations: affine
    w1q = quant.calibrate(w1, jnp.int8, symmetric=True)    # weights: symmetric
    w2q_in_calib = None

    h_float = jax.nn.relu(x @ w1 + b1)
    y_float = h_float @ w2 + b2

    # layer 1 through FFIP int8
    h = quant.quantized_dense_ffip(x, w1, b1, xq, w1q, algo="ffip")
    h = jax.nn.relu(h)
    hq = quant.calibrate(h, jnp.int8, symmetric=False)
    w2q = quant.calibrate(w2, jnp.int8, symmetric=True)
    y = quant.quantized_dense_ffip(h, w2, b2, hq, w2q, algo="ffip")

    rms = float(jnp.sqrt(jnp.mean((y - y_float) ** 2)))
    ref = float(jnp.sqrt(jnp.mean(y_float ** 2)))
    print(f"quantization SNR: {20 * np.log10(ref / rms):.1f} dB "
          f"(int8 path vs float reference)")

    # bit-exactness of the arithmetic rearrangement itself
    aq = quant.quantize(x, xq)
    bq = quant.quantize(w1, w1q)
    base = quant.int_gemm_baseline(aq, bq, xq.zero_point, w1q.zero_point)
    ffip = quant.int_gemm_ffip(aq, bq, xq.zero_point, w1q.zero_point)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(ffip))
    print("int32 accumulators: FFIP == baseline, bit-exact")

    m, k, n = aq.shape[0], aq.shape[1], bq.shape[1]
    print(f"multiplies: baseline {an.baseline_mults(m, k, n)}, "
          f"ffip {an.fip_mults(m, k, n)} "
          f"({an.fip_mults(m, k, n) / an.baseline_mults(m, k, n):.3f}x)")

    # the 1-extra-bit y encoding (Eq. 9 + §4.4)
    y_enc = fip.make_y(bq.astype(jnp.int32))
    assert int(jnp.max(jnp.abs(y_enc))) < 2 ** 8  # fits 9 bits signed
    print("y-delta encoding fits w+1 bits — matches §4.4 storage claim")
    print("OK")


if __name__ == "__main__":
    main()
