"""Compat layer over Pallas TPU API drift + backend probes.

`pltpu.TPUCompilerParams` was renamed to `pltpu.CompilerParams` across JAX
releases; the installed toolchain may carry either name. Every kernel builds
its compiler params through :func:`tpu_compiler_params` so one probe point
absorbs the drift (tests/test_kernels.py exercises all kernels in interpret
mode at collection-adjacent cost precisely so this breaks loudly, not deep in
a smoke test).

This module is also the single place kernels ask "should Pallas run compiled
or interpreted?": every kernel entry point takes ``interpret=None`` meaning
"auto" and resolves it through :func:`resolve_interpret` — compiled on a TPU
backend, interpret-mode emulation everywhere else (the CPU CI container). An
explicit ``True``/``False`` always wins, so tests can force interpret mode on
any backend and a TPU user can force interpretation for debugging.
"""
from __future__ import annotations

import weakref
from typing import Callable, Optional

import jax
from jax.experimental.pallas import tpu as pltpu

_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams", None)


def tpu_compiler_params(**kwargs):
    """Build a Pallas TPU compiler-params object under either JAX spelling.

    kwargs are passed through (e.g. dimension_semantics=("parallel", ...)).
    Returns None when the installed Pallas exposes neither class, in which
    case pallas_call simply runs without TPU compiler hints — correct, if
    slower, which is the right degradation for interpret-mode CPU CI.
    """
    if _PARAMS_CLS is None:
        return None
    return _PARAMS_CLS(**kwargs)


def is_tpu_backend() -> bool:
    """True when jax's default backend is a real TPU (not forced-host CPU)."""
    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    """Pallas interpret-mode default: compiled on TPU, interpret elsewhere."""
    return not is_tpu_backend()


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Resolve a kernel's ``interpret`` kwarg: ``None`` = backend auto-detect
    (compiled on TPU, interpret on CPU/GPU hosts), an explicit bool wins."""
    return default_interpret() if interpret is None else bool(interpret)


def device_kind() -> str:
    """Schedule-cache device key: e.g. ``cpu``, ``TPU_v5e`` (spaces -> _)."""
    return jax.devices()[0].device_kind.replace(" ", "_")


class DerivedCache:
    """Per-array derived-value memo shared by the FFIP kernels.

    One implementation of the idiom that used to live twice (``ffip_gemm``'s
    y-delta cache and ``conv_gemm``'s ``_derived``): values derived from a
    concrete weight array (Eq. 9 y-deltas, evenized/stacked conv kernels) are
    keyed by ``(tag, id(array))`` with a weakref liveness guard — ``id()``
    alone could alias a new array allocated at a recycled address. Tracers
    are never cached: they are trace-local, and inside a jit the derivation
    is constant-folded anyway (and is NOT counted as offline recompute).

    ``seed()`` is the warm-start door: ``repro.prepare`` installs values it
    loaded from a serialized artifact, so the first eager use of a prepared
    weight is a hit, not a re-encode. ``stats["computed"]`` is the counter
    behind the artifact's zero-recompute guarantee.
    """

    def __init__(self):
        self._cache: dict = {}
        self.stats = {"computed": 0, "hits": 0, "seeded": 0}

    def get(self, tag: str, arr, fn: Callable):
        if isinstance(arr, jax.core.Tracer):
            return fn(arr)
        key = (tag, id(arr))
        hit = self._cache.get(key)
        if hit is not None and hit[0]() is arr:
            self.stats["hits"] += 1
            return hit[1]
        val = fn(arr)
        self.stats["computed"] += 1
        self._store(key, arr, val)
        return val

    def seed(self, tag: str, arr, val) -> None:
        if isinstance(arr, jax.core.Tracer):
            raise TypeError("cannot seed a derived value for a tracer")
        self.stats["seeded"] += 1
        self._store((tag, id(arr)), arr, val)

    def _store(self, key, arr, val) -> None:
        self._cache[key] = (
            weakref.ref(arr, lambda _, k=key: self._cache.pop(k, None)), val)

    def __len__(self) -> int:
        return len(self._cache)

    def clear(self) -> None:
        self._cache.clear()


# Process-wide instance used by ffip_gemm / conv_gemm and seeded by
# repro.prepare on artifact load.
derived = DerivedCache()
