"""repro.tune — autotuner subsystem tests.

Covers the ISSUE 4 contract:
  * every generated candidate is legal (divisibility-free by design, bk even
    for the FIP family, VMEM-bounded) and the ordering is deterministic with
    the static default first;
  * cache round-trip: write -> fresh instance reload -> identical schedule
    with ZERO re-measurement;
  * corrupted cache file recovers to empty (moved aside, next save clean);
  * tuned blocks are BIT-identical to default blocks for the int8 path and
    for integer-valued float32 inputs (every product/sum exact in f32, so any
    block partitioning must produce the same bits — a real-valued float test
    would only prove allclose, which is not the paper's claim);
  * GemmConfig(block="auto") resolves schedules from the cache inside the
    provider (hit) and falls back to defaults with a counted miss.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import tune
from repro.core.gemm import GemmConfig, gemm, use_gemm
from repro.kernels import ops
from repro.tune import measure, space
from repro.tune.cache import ScheduleCache


def _int_inputs(m, k, n, dtype, lo=-8, hi=8, seed=0):
    """Integer-valued operands: for float32 every FIP/FFIP pre-add, product,
    and partial sum is exactly representable, so results are order-invariant
    and block choice cannot change a single bit."""
    rng = np.random.RandomState(seed)
    a = rng.randint(lo, hi, size=(m, k)).astype(np.float32)
    b = rng.randint(lo, hi, size=(k, n)).astype(np.float32)
    if dtype == jnp.int8:
        return jnp.asarray(a, jnp.int8), jnp.asarray(b, jnp.int8)
    return jnp.asarray(a, dtype), jnp.asarray(b, dtype)


# --- search space -----------------------------------------------------------

@pytest.mark.parametrize("algo", ["baseline", "fip", "ffip"])
@pytest.mark.parametrize("m,k,n", [(2, 64, 512), (100, 60, 36), (256, 1024, 256)])
def test_candidates_legal_and_deterministic(algo, m, k, n):
    c1 = space.gemm_candidates(m, n, k, algo)
    c2 = space.gemm_candidates(m, n, k, algo)
    assert c1 == c2, "candidate ordering must be deterministic"
    assert c1[0] == tuple(ops.choose_blocks(m, n, k, algo)), \
        "static default must be candidate 0"
    assert len(c1) == len(set(c1)), "duplicate candidates"
    for bm, bn, bk in c1:
        assert space.gemm_block_legal(bm, bn, bk, algo), (bm, bn, bk)
        if algo in ("fip", "ffip"):
            assert bk % 2 == 0, "FIP pair algebra needs even bk"
            assert 3 * bm * bn * (bk // 2) * 4 <= ops._VMEM_BUDGET
        assert bm <= space.round_up_pow2(m)
        assert bn <= space.round_up_pow2(n)
        assert bk <= space.round_up_pow2(k)


def test_flash_candidates_default_first():
    cands = space.flash_candidates(512, 512)
    assert cands[0] == (128, 128)
    assert cands == space.flash_candidates(512, 512)
    assert all(bq in space.FLASH_BQ and bk in space.FLASH_BK
               for bq, bk in cands)


# --- cache ------------------------------------------------------------------

def test_cache_roundtrip_zero_remeasure(tmp_path):
    path = tmp_path / "sched.json"
    c1 = ScheduleCache(path)
    before = measure.counters["timed_candidates"]
    e1 = tune.tune_gemm(16, 32, 32, jnp.int8, algo="ffip", budget=2, iters=1,
                        cache=c1)
    assert measure.counters["timed_candidates"] > before, "cold run measures"
    assert path.exists()

    c2 = ScheduleCache(path)                 # fresh instance = fresh process
    mid = measure.counters["timed_candidates"]
    e2 = tune.tune_gemm(16, 32, 32, jnp.int8, algo="ffip", budget=2, iters=1,
                        cache=c2)
    assert e2["blocks"] == e1["blocks"]
    assert measure.counters["timed_candidates"] == mid, \
        "warm cache must not re-measure"
    # same bucket, different member shape -> same schedule, still no measure
    got = tune.lookup_gemm_blocks("ffip", jnp.int8, 13, 30, 27, cache=c2)
    assert got == (e1["blocks"]["bm"], e1["blocks"]["bn"], e1["blocks"]["bk"])
    assert measure.counters["timed_candidates"] == mid


def test_cache_lru_bounded(tmp_path):
    c = ScheduleCache(tmp_path / "s.json", lru_size=2)
    for i in range(5):
        c.put(f"k{i}", {"blocks": {"bm": 8, "bn": 32, "bk": 8}},
              persist=False)
    assert len(c._lru) == 2, "LRU must stay bounded"
    assert len(c) == 5, "persisted entries must NOT be evicted"
    assert c.lookup("k0") is not None, "evicted-from-LRU keys still resolve"


def test_corrupted_cache_recovers(tmp_path):
    path = tmp_path / "sched.json"
    path.write_text("{ this is not json !!!")
    c = ScheduleCache(path)
    assert c.lookup("anything") is None
    assert c.recovered, "corruption must be flagged"
    assert path.with_name(path.name + ".corrupt").exists(), \
        "corrupt file kept aside for debugging"
    # cache still fully functional: tune, persist, reload cleanly
    e = tune.tune_gemm(16, 16, 16, jnp.int8, algo="fip", budget=1, iters=1,
                       cache=c)
    raw = json.loads(path.read_text())
    assert raw["version"] == 1
    c2 = ScheduleCache(path)
    assert not c2.recovered
    key = tune.gemm_key("fip", jnp.int8, 16, 16, 16)
    assert c2.lookup(key)["blocks"] == e["blocks"]


def test_corrupted_cache_quarantined_at_save_time(tmp_path):
    """A cache instance that loaded a CLEAN file, then finds the on-disk file
    corrupted at save() time (crashed concurrent writer, hand edit), must
    quarantine the evidence exactly like the load-time path — not silently
    overwrite it."""
    path = tmp_path / "sched.json"
    c = ScheduleCache(path)
    c.lookup("warm")            # load: file absent, nothing to recover
    assert not c.recovered
    path.write_text("{ trashed between load and save !!!")
    c.put("a|f|i8|m8n8k8|cpu", {"blocks": {"bm": 8, "bn": 32, "bk": 8}})
    assert c.recovered, "save-time corruption must be flagged"
    corrupt = path.with_name(path.name + ".corrupt")
    assert corrupt.exists(), "corrupt file kept aside for debugging"
    assert corrupt.read_text().startswith("{ trashed"), \
        "quarantine must preserve the corrupt bytes, not our rewrite"
    # and the rewrite itself is clean and complete
    c2 = ScheduleCache(path)
    assert not c2.recovered
    assert c2.lookup("a|f|i8|m8n8k8|cpu") is not None


def test_cache_save_merges_concurrent_writers(tmp_path):
    """Two tuner processes sharing a path must not erase each other's
    buckets: save() re-reads and merges on-disk entries before writing."""
    path = tmp_path / "s.json"
    blocks = {"blocks": {"bm": 8, "bn": 32, "bk": 8}}
    c1, c2 = ScheduleCache(path), ScheduleCache(path)
    c1.lookup("warm")          # both load the (empty) file, like two
    c2.lookup("warm")          # processes starting together
    c1.put("a|f|i8|m8n8k8|cpu", blocks)
    c2.put("b|f|i8|m8n8k8|cpu", blocks)   # later writer, disjoint key
    fresh = ScheduleCache(path)
    assert fresh.lookup("a|f|i8|m8n8k8|cpu") is not None, \
        "first writer's entry lost"
    assert fresh.lookup("b|f|i8|m8n8k8|cpu") is not None


def test_cache_rejects_malformed_entries(tmp_path):
    path = tmp_path / "sched.json"
    path.write_text(json.dumps({"version": 1, "entries": {
        "good|x|y|z|cpu": {"blocks": {"bm": 8, "bn": 32, "bk": 8}},
        "bad1|x|y|z|cpu": {"blocks": "nope"},
        "bad2|x|y|z|cpu": ["not", "a", "dict"],
    }}))
    c = ScheduleCache(path)
    assert c.lookup("good|x|y|z|cpu") is not None
    assert c.lookup("bad1|x|y|z|cpu") is None
    assert c.lookup("bad2|x|y|z|cpu") is None
    assert not c.recovered, "entry-level filtering is not file corruption"


# --- bit-exactness across block choices ------------------------------------

@pytest.mark.parametrize("algo", ["baseline", "fip", "ffip"])
def test_tuned_blocks_bit_identical_int8(algo):
    m, k, n = 48, 40, 36
    a, b = _int_inputs(m, k, n, jnp.int8, lo=-128, hi=128)
    ref = np.asarray(ops.matmul(a, b, algo=algo, interpret=True))
    for bm, bn, bk in space.gemm_candidates(m, n, k, algo)[:4]:
        got = np.asarray(ops.matmul(a, b, algo=algo, interpret=True,
                                    bm=bm, bn=bn, bk=bk))
        np.testing.assert_array_equal(got, ref, err_msg=f"{(bm, bn, bk)}")


@pytest.mark.parametrize("algo", ["baseline", "fip", "ffip"])
def test_tuned_blocks_bit_identical_float(algo):
    m, k, n = 48, 40, 36
    a, b = _int_inputs(m, k, n, jnp.float32)
    ref = np.asarray(ops.matmul(a, b, algo=algo, interpret=True))
    for bm, bn, bk in space.gemm_candidates(m, n, k, algo)[:4]:
        got = np.asarray(ops.matmul(a, b, algo=algo, interpret=True,
                                    bm=bm, bn=bn, bk=bk))
        assert got.tobytes() == ref.tobytes(), \
            f"float bits changed under blocks {(bm, bn, bk)}"


def test_tuned_blocks_bit_identical_int8_ffip_quantized_path(tmp_path):
    """The serving int8-FFIP decode contract survives tuning: a GemmConfig
    with explicit tuned blocks produces bit-identical int32 accumulators."""
    a, b = _int_inputs(24, 32, 40, jnp.int8, lo=-128, hi=128)
    with use_gemm(GemmConfig(algo="ffip", impl="pallas")):
        ref = np.asarray(gemm(a, b))
    with use_gemm(GemmConfig(algo="ffip", impl="pallas", block=(8, 32, 16))):
        got = np.asarray(gemm(a, b))
    np.testing.assert_array_equal(got, ref)


# --- block="auto" resolution -----------------------------------------------

def test_auto_resolves_schedule_from_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "sched.json"))
    m, k, n = 16, 32, 48
    entry = tune.tune_gemm(m, n, k, jnp.int8, algo="ffip", budget=3, iters=1)

    used = {}
    orig = ops.matmul

    def spy(a, b, **kw):
        used.update(kw)
        return orig(a, b, **kw)

    monkeypatch.setattr("repro.kernels.ops.matmul", spy)
    tune.reset_stats()
    a, b = _int_inputs(m, k, n, jnp.int8, lo=-128, hi=128)
    with use_gemm(GemmConfig(algo="ffip", impl="pallas", block="auto")):
        got = np.asarray(gemm(a, b))
    assert tune.stats["hits"] >= 1 and tune.stats["misses"] == 0
    blocks = entry["blocks"]
    assert (used["bm"], used["bn"], used["bk"]) == \
        (blocks["bm"], blocks["bn"], blocks["bk"]), \
        "auto must hand the CACHED schedule to the kernel"
    np.testing.assert_array_equal(
        got, np.asarray(a, np.int64) @ np.asarray(b, np.int64))


def test_auto_miss_falls_back_to_defaults(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "empty.json"))
    tune.reset_stats()
    a, b = _int_inputs(8, 16, 16, jnp.int8, lo=-128, hi=128)
    with use_gemm(GemmConfig(algo="ffip", impl="pallas", block="auto")):
        got = np.asarray(gemm(a, b))
    assert tune.stats["misses"] >= 1, "miss must be counted, never silent"
    np.testing.assert_array_equal(
        got, np.asarray(a, np.int64) @ np.asarray(b, np.int64))


def test_auto_explicit_and_invalid_block_values():
    cfg = GemmConfig(algo="ffip", impl="pallas", block=(16, 32, 8))
    a, b = _int_inputs(16, 16, 16, jnp.int8, lo=-128, hi=128)
    with use_gemm(cfg):
        got = np.asarray(gemm(a, b))
    np.testing.assert_array_equal(
        got, np.asarray(a, np.int64) @ np.asarray(b, np.int64))
    with pytest.raises(ValueError, match="block"):
        with use_gemm(GemmConfig(impl="pallas", block="fastest")):
            gemm(a, b)


def test_flash_auto_roundtrip(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "sched.json"))
    entry = tune.tune_flash(4, 16, 16, 8, budget=2, iters=1)
    got = tune.lookup_flash_blocks(jnp.float32, 4, 16, 16, 8)
    assert got == (entry["blocks"]["bq"], entry["blocks"]["bk"])
    # flash numerics are block-partition invariant up to fp rounding; the
    # attention layer consumes the schedule through _flash_schedule
    from repro.models.attention import _flash_schedule
    with use_gemm(GemmConfig(block="auto")):
        bq, bk, _ = _flash_schedule(jnp.float32, 4, 16, 16, 8)
    assert (bq, bk) == got


def test_tuner_shapes_from_model_config():
    """launch.tune derives a non-empty, bucketable GEMM set from a config."""
    from repro import configs
    from repro.launch.tune import _arch_gemm_shapes
    cfg = configs.smoke_config(configs.get_config("minicpm-2b"))
    shapes = _arch_gemm_shapes(cfg, [2])
    assert shapes, "model config must yield dense GEMM shapes"
    assert all(m == 2 and k > 0 and n > 0 for m, k, n in shapes)
    assert (2, cfg.d_model, cfg.vocab) in shapes, "tied unembed included"
