"""Algorithm 1: in-place mapping of 2-D convolution to GEMM (§5.1).

The paper's memory subsystem walks conv inputs with multi-digit counters
(programmable digit sizes/strides, Fig. 5) so that the systolic array sees a
GEMM without a standalone im2col re-layout stage. We reproduce:

  * :class:`MultiDigitCounter` — the Fig.-5 counter (nested digits, each with
    a size and a stride; the emitted address is the sum of digit values),
  * :func:`conv_gemm_indices` — Algorithm 1 specialised to NHWC conv,
    producing (M, K) gather indices into the padded input,
  * :func:`conv2d_via_gemm` — materialises A via the indices and runs any
    GEMM provider (baseline / FIP / FFIP), validated against lax.conv.
  * :func:`partition_blocks` — the §5.1.1 B-way memory partitioning of the
    W dimension (interleaved submemories), with the kw-crossing adjustment.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

Size2 = Union[int, Tuple[int, int], Sequence[int]]


def as_pair(v: Size2) -> Tuple[int, int]:
    """Normalize a stride/padding argument to an (h, w) pair. A single int
    means symmetric; whisper-style (asymmetric) convs and AlexNet's stride-4
    conv1 share one code path this way."""
    if isinstance(v, (tuple, list)):
        if len(v) != 2:
            raise ValueError(f"expected (h, w) pair, got {v!r}")
        return int(v[0]), int(v[1])
    return int(v), int(v)


def conv_out_hw(h: int, w: int, kh: int, kw: int, stride: Size2 = 1,
                pad: Size2 = 0) -> Tuple[int, int]:
    """Conv/pool output spatial dims — THE output-size formula, shared by the
    workload tables, the fused kernels, the tuner keys and the vision layers
    (one place to change if dilation/SAME semantics ever arrive)."""
    sh, sw = as_pair(stride)
    ph, pw = as_pair(pad)
    return (h + 2 * ph - kh) // sh + 1, (w + 2 * pw - kw) // sw + 1


@dataclasses.dataclass
class Digit:
    """One digit of the Fig.-5 counter: iterates size times with given stride."""
    name: str
    size: int
    stride: int


class MultiDigitCounter:
    """Nested multi-digit counter: outer digits first (Algorithm 1 loop order).

    Emitted value = sum of (digit_index * stride) over digits — exactly the
    ``address = m_offset + k_offset`` composition in Algorithm 1.
    """

    def __init__(self, digits: Sequence[Digit]):
        self.digits = list(digits)

    def addresses(self) -> np.ndarray:
        grids = np.meshgrid(
            *[np.arange(d.size) * d.stride for d in self.digits], indexing="ij")
        out = np.zeros_like(grids[0])
        for g in grids:
            out = out + g
        return out.reshape(-1)


def conv_gemm_indices(h: int, w: int, cin: int, kh: int, kw: int,
                      stride: Size2 = 1, *, groups: int = 1,
                      group: int = 0) -> np.ndarray:
    """Algorithm-1 address pattern for one image: (M, K) indices into the
    flattened (H, W, Cin) input, M = OH*OW, K = KH*KW*(Cin/groups).

    Loop order mirrors Algorithm 1: the kernel-offset digits (kh, kw, cin)
    form K (k_offset), the spatial digits (h, w) form M (m_offset); the final
    address is their sum — no data movement, only address arithmetic.

    ``stride`` may be a single int or an (sh, sw) pair — asymmetric strides
    only change the per-digit stride constants, the counter is unchanged.
    For grouped convolution the cin digit walks the group's channel slice
    (size Cin/groups) and ``group`` adds the constant channel offset — the
    §5.1 counters realize a group as one more programmable base address.
    """
    sh, sw = as_pair(stride)
    if cin % groups:
        raise ValueError(f"cin={cin} not divisible by groups={groups}")
    cin_g = cin // groups
    oh, ow = conv_out_hw(h, w, kh, kw, (sh, sw))
    # m_offset counter: h (row stride = sh*W*Cin), w (sw*Cin)
    m_counter = MultiDigitCounter([
        Digit("h", oh, sh * w * cin),
        Digit("w", ow, sw * cin),
    ])
    # k_offset counter: kh (W*Cin), kw (Cin), cin (1, within the group slice)
    k_counter = MultiDigitCounter([
        Digit("kh", kh, w * cin),
        Digit("kw", kw, cin),
        Digit("cin", cin_g, 1),
    ])
    m_off = m_counter.addresses()            # (M,)
    k_off = k_counter.addresses() + group * cin_g   # (K,)
    return m_off[:, None] + k_off[None, :]   # (M, K)


def conv2d_via_gemm(x: Array, kernel: Array, *, stride: Size2 = 1,
                    pad: Size2 = 0, groups: int = 1,
                    gemm_fn: Callable[[Array, Array], Array] | None = None) -> Array:
    """NHWC conv via Algorithm-1 GEMM mapping (the materializing reference).

    x: (B, H, W, Cin); kernel: (KH, KW, Cin/groups, Cout) -> (B, OH, OW, Cout).
    ``stride``/``pad`` take an int or an (h, w) pair. Grouped convolution is
    the block-diagonal K split: group g contracts its own K = KH*KW*(Cin/g)
    slice against its own Cout/groups weight columns (validated against
    ``lax.conv_general_dilated(feature_group_count=groups)``).
    """
    if gemm_fn is None:
        gemm_fn = lambda a, b: jnp.matmul(a, b)
    b_, h, w, cin = x.shape
    kh, kw, cin_g, cout = kernel.shape
    if groups * cin_g != cin:
        raise ValueError(f"kernel expects cin/groups={cin_g}, "
                         f"got cin={cin} groups={groups}")
    if cout % groups:
        raise ValueError(f"cout={cout} not divisible by groups={groups}")
    sh, sw = as_pair(stride)
    ph, pw = as_pair(pad)
    if ph or pw:
        x = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
        h, w = h + 2 * ph, w + 2 * pw
    oh, ow = conv_out_hw(h, w, kh, kw, (sh, sw))
    flat = x.reshape(b_, h * w * cin)
    ng = cout // groups
    bmat = kernel.reshape(kh * kw * cin_g, cout)        # (K, Cout)
    outs = []
    for g in range(groups):
        idx = jnp.asarray(conv_gemm_indices(
            h, w, cin, kh, kw, (sh, sw), groups=groups, group=g))
        a = flat[:, idx]                                # (B, M, K) gather
        outs.append(gemm_fn(a, bmat[:, g * ng:(g + 1) * ng]))  # (B, M, Ng)
    c = outs[0] if groups == 1 else jnp.concatenate(outs, axis=-1)
    return c.reshape(b_, oh, ow, cout)


# ---------------------------------------------------------------------------
# §5.1.1: B-way memory partitioning of the W dimension
# ---------------------------------------------------------------------------

def partition_blocks(w_indices: np.ndarray, ws: int, n_blocks: int) -> List[np.ndarray]:
    """Split a stream of w-coordinates into B interleaved submemory streams.

    Each W slice is ``ws`` elements wide; slice s goes to block s % B. Returns
    per-block index arrays; the main clock interleaves them round-robin.
    """
    slice_id = w_indices // ws
    return [w_indices[slice_id % n_blocks == b] for b in range(n_blocks)]


def interleave_blocks(blocks: List[np.ndarray], order: np.ndarray | None = None) -> np.ndarray:
    """Round-robin re-interleave (the main-clock view). ``order`` permutes the
    block visiting order — the §5.1.1 kw-crossing adjustment rotates it when a
    kernel-window read starts inside a different block."""
    n = len(blocks)
    if order is None:
        order = np.arange(n)
    max_len = max(len(b) for b in blocks)
    out = []
    for i in range(max_len):
        for j in order:
            if i < len(blocks[j]):
                out.append(blocks[j][i])
    return np.asarray(out)
