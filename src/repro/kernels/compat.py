"""Compat layer over Pallas TPU API drift + backend probes.

`pltpu.TPUCompilerParams` was renamed to `pltpu.CompilerParams` across JAX
releases; the installed toolchain may carry either name. Every kernel builds
its compiler params through :func:`tpu_compiler_params` so one probe point
absorbs the drift (tests/test_kernels.py exercises all kernels in interpret
mode at collection-adjacent cost precisely so this breaks loudly, not deep in
a smoke test).

This module is also the single place kernels ask "should Pallas run compiled
or interpreted?": every kernel entry point takes ``interpret=None`` meaning
"auto" and resolves it through :func:`resolve_interpret` — compiled on a TPU
backend, interpret-mode emulation everywhere else (the CPU CI container). An
explicit ``True``/``False`` always wins, so tests can force interpret mode on
any backend and a TPU user can force interpretation for debugging.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.experimental.pallas import tpu as pltpu

_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams", None)


def tpu_compiler_params(**kwargs):
    """Build a Pallas TPU compiler-params object under either JAX spelling.

    kwargs are passed through (e.g. dimension_semantics=("parallel", ...)).
    Returns None when the installed Pallas exposes neither class, in which
    case pallas_call simply runs without TPU compiler hints — correct, if
    slower, which is the right degradation for interpret-mode CPU CI.
    """
    if _PARAMS_CLS is None:
        return None
    return _PARAMS_CLS(**kwargs)


def is_tpu_backend() -> bool:
    """True when jax's default backend is a real TPU (not forced-host CPU)."""
    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    """Pallas interpret-mode default: compiled on TPU, interpret elsewhere."""
    return not is_tpu_backend()


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Resolve a kernel's ``interpret`` kwarg: ``None`` = backend auto-detect
    (compiled on TPU, interpret on CPU/GPU hosts), an explicit bool wins."""
    return default_interpret() if interpret is None else bool(interpret)


def device_kind() -> str:
    """Schedule-cache device key: e.g. ``cpu``, ``TPU_v5e`` (spaces -> _)."""
    return jax.devices()[0].device_kind.replace(" ", "_")
