"""repro.obs — dependency-free observability: metrics, spans, kernel profile.

Three layers, one clock:

* :mod:`repro.obs.metrics` — counters / gauges / fixed-bucket histograms
  with labels, injectable registries, Prometheus text export.
* :mod:`repro.obs.trace` — ring-buffer span tracing with parent links and
  rid correlation; JSON-lines and Chrome ``trace_event`` export.
* :mod:`repro.obs.profile` — per-dispatch kernel hooks (counts, effective
  FLOPs, FIP/FFIP multiplier counts, bytes) and compile-event unification.

:func:`default_clock` is the single process timebase. Every component that
measures time (batcher, router, watchdog, tracer) calls its injected clock
or falls back to this one; :func:`set_default_clock` swaps the underlying
source (e.g. a ``serve.faults.FakeClock``) so an entire serving stack can
run on fake time without threading ``clock=`` through every constructor.
"""
from __future__ import annotations

import time
from typing import Callable

from repro.obs.metrics import (                                 # noqa: F401
    CardinalityError, Counter, Gauge, Histogram, Registry,
    get_registry, parse_help, parse_prometheus, set_registry,
    start_metrics_server)
from repro.obs.trace import Span, Tracer, load_jsonl, tree_from_spans  # noqa: F401
from repro.obs.profile import (                                 # noqa: F401
    KernelProfiler, compile_snapshot, get_profiler, set_profiler)
from repro.obs.window import WindowedCounter, WindowedHistogram  # noqa: F401
from repro.obs.slo import AlertState, Objective, SloMonitor, SloTracker  # noqa: F401

_clock: Callable[[], float] = time.perf_counter


def default_clock() -> float:
    """The process-wide timebase (seconds). Swappable: see
    :func:`set_default_clock`."""
    return _clock()


def set_default_clock(clock: Callable[[], float]) -> Callable[[], float]:
    """Replace the source behind :func:`default_clock`; returns the previous
    source so tests can restore it."""
    global _clock
    prev, _clock = _clock, clock
    return prev
