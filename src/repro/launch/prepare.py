"""Offline model-prep launcher: build and save a `repro.prepare` artifact.

    # LM artifact: int8 q entries + Eq. 9 y-deltas + tuned schedule slice
    PYTHONPATH=src python -m repro.launch.prepare --arch minicpm-2b --smoke \
        --quantized --out /tmp/minicpm.prepared

    # vision artifact (BN already folded at init; conv/FC int8 entries)
    PYTHONPATH=src python -m repro.launch.prepare --vision alexnet --smoke \
        --quantized --out /tmp/alexnet.prepared

This is the paper's §4.4 offline stage as a deployment step: everything a
serving process would otherwise compute lazily at startup — per-channel int8
quantization with Eq. 15 folded beta, Eq. 9 y-delta weight encodings, and the
device-keyed `repro.tune` schedule slice — is done HERE, once, and serialized.
`launch.serve --prepared DIR` (and `launch.vision --prepared DIR`) then load
it with the zero-recompute warm-start contract; ``--require-warm`` on the
serve side turns that contract into a hard failure.

Params are initialized from seed 0, matching the serve/vision launchers, so
an artifact prepared here is byte-compatible with their synthetic workloads.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax

from repro import configs, prepare
from repro.kernels import compat


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="build + save a repro.prepare artifact")
    src = ap.add_mutually_exclusive_group(required=True)
    src.add_argument("--arch", choices=sorted(configs.ARCHS),
                     help="LM architecture (params from seed 0, like "
                          "launch.serve)")
    src.add_argument("--vision", metavar="MODEL",
                     help="vision model name (see launch.vision)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny smoke-sized config (matches the serve/vision "
                         "launchers' --smoke)")
    ap.add_argument("--quantized", action="store_true",
                    help="attach per-channel int8 q entries (Eq. 15/20)")
    ap.add_argument("--no-y-deltas", action="store_true",
                    help="LM only: skip the Eq. 9 y-delta precompute")
    ap.add_argument("--out", required=True, help="artifact directory")
    args = ap.parse_args(argv)

    t0 = time.perf_counter()
    if args.arch:
        from repro.models.model import build_model
        cfg = configs.get_config(args.arch)
        if args.smoke:
            cfg = configs.smoke_config(cfg)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        pm = prepare.prepare_lm(params, quantized=args.quantized,
                                y_deltas=not args.no_y_deltas, name=cfg.name)
    else:
        from repro.vision import models as vm
        if args.vision not in vm.BUILDERS:
            ap.error(f"--vision must be one of {sorted(vm.BUILDERS)}")
        image_size = ((67 if args.vision == "alexnet" else 32) if args.smoke
                      else (227 if args.vision == "alexnet" else 224))
        model = vm.build(args.vision,
                         num_classes=10 if args.smoke else 1000,
                         image_size=image_size,
                         width_div=8 if args.smoke else 1)
        params = vm.init_params(model, jax.random.PRNGKey(0))
        pm = prepare.prepare_vision(model, params, quantized=args.quantized,
                                    name=args.vision)
    prep_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    out = pm.save(args.out)
    save_s = time.perf_counter() - t0

    n_leaves = len(jax.tree.leaves(pm.params))
    print(f"prepared {pm.kind} artifact '{pm.meta.get('name')}' -> {out}")
    print(f"  device_kind={pm.device} quantized={pm.quantized} "
          f"params_leaves={n_leaves} y_deltas={len(pm.derived)} "
          f"schedule_entries={len(pm.schedule)}")
    print(f"  offline work: quantize={prepare.counters_snapshot()['quantize']}"
          f" y_encode={compat.derived.stats['computed']} "
          f"(prep {prep_s:.2f}s, save {save_s:.2f}s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
