"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

input_specs(arch, shape) gives the jit-lowerable argument tree for the cell's
step function: train batches, prefill prompts, or decode steps with KV/SSM
caches. Modality frontends are stubs: frames/patches enter as precomputed
embedding specs (per the brief)."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.model import build_model

PyTree = Any


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def _frontend_specs(cfg: ModelConfig, batch: int) -> Dict[str, Any]:
    out = {}
    if cfg.encoder is not None:
        out["frames"] = sds((batch, cfg.encoder.n_frames, cfg.d_model), cfg.dtype)
    if cfg.frontend == "vision":
        out["patches"] = sds((batch, cfg.frontend_tokens, cfg.d_model), cfg.dtype)
    return out


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    out = {
        "tokens": sds((b, s), jnp.int32),
        "labels": sds((b, s), jnp.int32),
    }
    out.update(_frontend_specs(cfg, b))
    return out


def cache_specs_struct(cfg: ModelConfig, batch: int, max_len: int) -> PyTree:
    model = build_model(cfg)
    return jax.eval_shape(lambda: model.init_cache(batch, max_len))


def serve_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """decode: one new token against a seq_len cache. prefill: the full prompt."""
    b, s = shape.global_batch, shape.seq_len
    prefix = cfg.frontend_tokens if cfg.frontend == "vision" else 0
    if shape.kind == "prefill":
        out = {
            "tokens": sds((b, s), jnp.int32),
            "cache": cache_specs_struct(cfg, b, s + prefix),
        }
        out.update(_frontend_specs(cfg, b))
        return out
    # decode: cache of seq_len already-filled tokens, one token in flight.
    # pos is the per-slot (B,) position vector of the continuous batcher
    # (Model.decode_step also accepts a scalar for shared-offset decode).
    return {
        "token": sds((b, 1), jnp.int32),
        "cache": cache_specs_struct(cfg, b, s + prefix),
        "pos": sds((b,), jnp.int32),
    }


def params_specs_struct(cfg: ModelConfig) -> PyTree:
    model = build_model(cfg)
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def input_specs(arch: str, shape_name: str) -> Tuple[ModelConfig, ShapeConfig, Dict[str, Any]]:
    cfg = configs.get_config(arch)
    shape = configs.SHAPE_BY_NAME[shape_name]
    ok, why = configs.shape_supported(cfg, shape)
    if not ok:
        raise ValueError(f"{arch} x {shape_name}: {why}")
    if shape.kind == "train":
        return cfg, shape, train_batch_specs(cfg, shape)
    return cfg, shape, serve_specs(cfg, shape)
