"""`repro.vision` — the CNN inference pipeline over the fused
implicit-im2col conv kernels.

  * :mod:`repro.vision.layers` — conv/pool/BN-fold/ReLU layers routed
    through the ambient :class:`repro.core.gemm.GemmConfig` (algo, impl,
    ``quantized=``, ``block="auto"`` all apply to convs);
  * :mod:`repro.vision.models` — runnable AlexNet / VGG-16 / ResNet-50
    built from the ``core.workloads`` conv-spec tables;
  * the kernels themselves live in :mod:`repro.kernels.conv_gemm`.

CLI: ``python -m repro.launch.vision`` (classify smoke + conv tuning).
"""
from repro.vision import layers, models  # noqa: F401
