"""dist x serve: tensor-parallel decode through BatchServer(mesh=...) must be
bit-identical in OUTPUT TOKENS to single-device decode — float and int8-FFIP,
GQA and absorbed-MLA. Run under forced host devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest -q tests/test_dist_serve.py
"""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh

from repro import configs, prepare
from repro.models.model import build_model
from repro.serve.batcher import BatchServer, Request

MAX_LEN = 48


def _tp_mesh(tp=None):
    n = jax.device_count()
    if tp is None:
        tp = next((t for t in (4, 2) if n % t == 0 and n >= t), 1)
    if n < tp or tp < 2:
        pytest.skip(f"needs >= 2 devices for tensor parallelism, have {n}")
    return Mesh(np.array(jax.devices()[:tp]).reshape(1, tp),
                ("data", "model"))


def _setup(arch, seed=0):
    cfg = configs.smoke_config(configs.get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


def _run(model, params, prompts, *, quantized=False, mesh=None,
         prepared=None, decode_chunk=1):
    srv = BatchServer(model, batch_slots=2, max_len=MAX_LEN,
                      quantized=quantized, mesh=mesh, prepared=prepared,
                      decode_chunk=decode_chunk)
    for i, p in enumerate(prompts):
        srv.submit(Request(rid=i, prompt=p, max_new_tokens=4))
    done = srv.run_until_drained(params)
    return {r.rid: tuple(r.out_tokens) for r in done}


@pytest.mark.parametrize("arch", ["minicpm-2b", "deepseek-v2-lite-16b"])
@pytest.mark.parametrize("quantized", [False, True])
def test_tp_decode_tokens_identical_to_single_device(arch, quantized):
    """The ISSUE 7 acceptance bar: TP decode on the 'model' axis emits the
    same tokens as single-device, for GQA (minicpm) and absorbed-MLA
    (deepseek), float and int8-FFIP."""
    mesh = _tp_mesh()
    cfg, model, params = _setup(arch)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, size=(n,)) for n in (5, 9, 3)]
    tp = _run(model, params, prompts, quantized=quantized, mesh=mesh)
    ref = _run(model, params, prompts, quantized=quantized, mesh=None)
    assert tp == ref


def test_tp_decode_from_prepared_artifact(tmp_path):
    """mesh= composes with prepared=: a loaded artifact serves tensor-
    parallel, token-identical, with zero recompute."""
    mesh = _tp_mesh()
    cfg, model, params = _setup("minicpm-2b")
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, size=(n,)) for n in (4, 6)]
    ref = _run(model, params, prompts, quantized=True, mesh=None)
    prepare.prepare_lm(params, quantized=True).save(tmp_path / "a")
    pm = prepare.load(tmp_path / "a")
    tp = _run(model, params, prompts, quantized=True, mesh=mesh, prepared=pm)
    assert tp == ref
    assert pm.recomputed == 0, pm.recompute_report()


def test_tp_decode_chunk_fusion_identical(tp=2):
    """Fused multi-step decode under the mesh stays bit-identical too."""
    mesh = _tp_mesh(tp)
    cfg, model, params = _setup("minicpm-2b")
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab, size=(n,)) for n in (5, 8)]
    tp_out = _run(model, params, prompts, mesh=mesh, decode_chunk=2)
    ref = _run(model, params, prompts, mesh=None, decode_chunk=1)
    assert tp_out == ref


def test_mesh_rejects_paged():
    _, model, _ = _setup("minicpm-2b")
    mesh = _tp_mesh()
    with pytest.raises(NotImplementedError, match="paged"):
        BatchServer(model, batch_slots=2, max_len=MAX_LEN, mesh=mesh,
                    paged=True)


def test_prepared_kind_and_quantization_validated(tmp_path):
    _, model, params = _setup("minicpm-2b")
    prepare.prepare_lm(params, quantized=False,
                       y_deltas=False).save(tmp_path / "f")
    pm = prepare.load(tmp_path / "f")
    with pytest.raises(ValueError, match="no\\s+int8"):
        BatchServer(model, batch_slots=1, max_len=MAX_LEN, quantized=True,
                    prepared=pm)
    pm.kind = "vision"
    with pytest.raises(ValueError, match="'lm' artifact"):
        BatchServer(model, batch_slots=1, max_len=MAX_LEN, prepared=pm)
