"""Windowed metrics (repro.obs.window) + the metrics-layer satellites that
landed with them: sub-bucket boundary semantics under FakeClock, full-window
expiry on a clock jump, reservoir-overflow surfacing (windowed AND the base
Histogram), multi-window queries off one instrument, labeled-family
aggregation, HELP-text escaping round-trip, and snapshot determinism.

All timing uses binary-exact sub-bucket durations (1.0, 0.25) so epoch
arithmetic is exact — ``1.0 // 0.1 == 9.0`` is the float trap these tests
must not step on.
"""
import json

import numpy as np
import pytest

from repro.obs import (Registry, WindowedCounter, WindowedHistogram,
                       parse_help, parse_prometheus)
from repro.serve.faults import FakeClock


def _hist(clock, **kw):
    kw.setdefault("window_s", 4.0)
    kw.setdefault("sub_buckets", 4)          # sub_s = 1.0 (binary exact)
    return Registry().windowed_histogram("w_s", "t", clock=clock, **kw)


# -- ring / boundary semantics ------------------------------------------------

def test_boundary_observation_starts_new_subbucket_and_expires_exactly():
    """An observation exactly ON a sub-bucket boundary belongs to the NEW
    sub-bucket and stays live until exactly k boundaries later."""
    clock = FakeClock()
    h = _hist(clock)                         # window 4.0, sub_s 1.0
    clock.t = 1.0                            # exactly on the t=1 boundary
    h.observe(5.0)
    assert h.count(now=1.0) == 1
    # live through the whole window: epochs 1..4 cover it
    assert h.count(now=4.999) == 1
    # at now=5.0 the query spans epochs [2, 5] — epoch 1 just fell out
    assert h.count(now=5.0) == 0
    assert h.quantile(0.5, now=5.0) == 0.0


def test_partial_current_subbucket_is_included():
    clock = FakeClock()
    h = _hist(clock)
    clock.t = 3.5                            # mid sub-bucket
    h.observe(1.0)
    assert h.count(now=3.6) == 1             # current partial bucket counts
    assert h.quantile(1.0, now=3.6) == 1.0


def test_clock_jump_larger_than_window_empties_it():
    clock = FakeClock()
    h = _hist(clock)
    for i in range(4):
        clock.advance(1.0)
        h.observe(float(i))
    assert h.count() == 4
    clock.advance(100.0)                     # jump >> window: all epochs stale
    assert h.count() == 0
    assert h.samples() == []
    assert h.rate() == 0.0
    # the ring is still writable afterwards (lazy eviction reset the cells)
    h.observe(9.0)
    assert h.count() == 1 and h.quantile(0.5) == 9.0


def test_ring_reuse_evicts_old_epoch_lazily():
    """Writing into a cell whose epoch wrapped resets it — stale samples
    from window N must never leak into window N + sub_buckets."""
    clock = FakeClock()
    h = _hist(clock)
    clock.t = 0.5
    h.observe(111.0)
    clock.t = 4.5                            # same ring index (0.5 % 4), new epoch
    h.observe(222.0)
    assert h.samples() == [222.0]


# -- queries ------------------------------------------------------------------

def test_multi_window_query_off_one_instrument():
    """One instrument serves both burn windows: a query window shorter than
    the instrument window sees only the recent sub-buckets."""
    clock = FakeClock()
    h = _hist(clock, window_s=8.0, sub_buckets=8)
    clock.t = 0.5
    h.observe(100.0)                         # old
    clock.t = 7.5
    h.observe(1.0)                           # recent
    assert h.count(8.0) == 2
    assert h.count(2.0) == 1
    assert h.quantile(1.0, 2.0) == 1.0       # fast window misses the spike
    assert h.quantile(1.0, 8.0) == 100.0
    with pytest.raises(ValueError):
        h.quantile(0.5, window_s=9.0)        # beyond the instrument window
    with pytest.raises(ValueError):
        h.quantile(1.5)


def test_quantiles_match_numpy_linear():
    clock = FakeClock()
    h = _hist(clock, window_s=30.0, sub_buckets=30)
    vals = [0.3 * i for i in range(1, 40)]
    for v in vals:
        clock.advance(0.25)
        h.observe(v)
    for q in (0.0, 0.25, 0.5, 0.9, 0.99, 1.0):
        assert h.quantile(q) == pytest.approx(
            float(np.percentile(vals, 100 * q)))
    assert h.mean() == pytest.approx(float(np.mean(vals)))


def test_windowed_counter_rate():
    clock = FakeClock()
    c = Registry().windowed_counter("ev", "t", window_s=4.0, sub_buckets=4,
                                    clock=clock)
    for _ in range(8):
        clock.advance(0.25)
        c.inc()
    assert c.count() == 8
    assert c.rate() == pytest.approx(8 / 4.0)   # whole-sub-bucket span
    # at now=2.0 a 1 s query covers only the current sub-bucket (epoch 2),
    # which holds exactly the t=2.0 increment
    assert c.count(1.0) == 1
    assert c.rate(1.0) == pytest.approx(1.0)
    # a 2 s query adds epoch 1 (the four t in [1.0, 1.75] increments)
    assert c.count(2.0) == 5
    assert c.rate(2.0) == pytest.approx(2.5)
    with pytest.raises(ValueError):
        c.inc(-1.0)


# -- overflow surfacing (windowed + base Histogram satellite) -----------------

def test_windowed_reservoir_overflow_is_surfaced_never_silent():
    clock = FakeClock()
    h = _hist(clock, reservoir_per_bucket=4)
    clock.t = 0.5
    for v in range(10):                      # one sub-bucket, 10 observations
        h.observe(float(v))
    assert h.count() == 10                   # count is exact regardless
    assert h.samples_dropped() == 6
    assert h._snap({})["samples_dropped"] == 6
    text = "\n".join(h._prom("w_s", {}))
    assert "w_s_samples_dropped 6" in text


def test_base_histogram_overflow_surfaced_in_snapshot_and_prom():
    r = Registry()
    h = r.histogram("lat_s", "t", reservoir=3)
    for v in (0.1, 0.2, 0.3):
        h.observe(v)
    assert not h.overflowed and h.samples_dropped == 0
    h.observe(0.4)
    h.observe(0.5)
    assert h.overflowed and h.samples_dropped == 2
    s = r.snapshot()["lat_s"]["series"][0]
    assert s["samples_dropped"] == 2 and s["overflowed"] is True
    assert parse_prometheus(r.to_prometheus())[
        "lat_s_samples_dropped"][()] == 2.0


# -- labeled families ---------------------------------------------------------

def test_labeled_family_parent_aggregates_children():
    clock = FakeClock()
    r = Registry()
    h = r.windowed_histogram("ttft_s", "t", ("replica",), window_s=4.0,
                             sub_buckets=4, clock=clock)
    clock.t = 0.5
    h.labels(replica="0").observe(1.0)
    h.labels(replica="1").observe(3.0)
    assert h.count() == 2                    # parent = fleet-wide view
    assert h.quantile(0.5) == 2.0
    assert h.labels(replica="0").count() == 1
    with pytest.raises(ValueError):
        h.observe(1.0)                       # parent itself takes no writes
    snap = r.snapshot()["ttft_s"]["series"]
    assert {s["labels"]["replica"] for s in snap} == {"0", "1"}


# -- export / HELP escaping ---------------------------------------------------

def test_help_escaping_round_trip():
    r = Registry()
    help_text = 'tricky: back\\slash and\nnewline and "quotes"'
    r.counter("tricky_total", help_text).inc()
    text = r.to_prometheus()
    assert "\ntricky_total 1" in text        # exposition still one-line
    helps = parse_help(text)
    assert helps["tricky_total"] == help_text
    # values still parse around the escaped HELP line
    assert parse_prometheus(text)["tricky_total"][()] == 1.0


def test_windowed_prometheus_types_and_summary_shape():
    clock = FakeClock()
    r = Registry()
    h = r.windowed_histogram("w_s", "t", window_s=4.0, sub_buckets=4,
                             clock=clock)
    c = r.windowed_counter("wc", "t", window_s=4.0, sub_buckets=4,
                           clock=clock)
    clock.t = 0.5
    h.observe(2.0)
    c.inc()
    text = r.to_prometheus()
    assert "# TYPE w_s summary" in text      # windowed kinds map to standard
    assert "# TYPE wc gauge" in text         # types scrapers understand
    parsed = parse_prometheus(text)
    assert parsed["w_s"][(("quantile", "0.5"),)] == 2.0
    assert parsed["w_s_count"][()] == 1.0
    assert parsed["wc"][()] == 1.0


def test_snapshot_deterministic_under_fake_clock():
    def build():
        clock = FakeClock()
        r = Registry()
        h = r.windowed_histogram("w_s", "t", window_s=4.0, sub_buckets=4,
                                 clock=clock)
        c = r.windowed_counter("wc", "t", window_s=4.0, sub_buckets=4,
                               clock=clock)
        for i in range(9):
            clock.advance(0.25)
            h.observe(0.1 * i)
            c.inc()
        return json.dumps(r.snapshot(), sort_keys=True)
    assert build() == build()


def test_constructor_validation():
    clock = FakeClock()
    with pytest.raises(ValueError):
        Registry().windowed_histogram("bad", window_s=0.0, clock=clock)
    with pytest.raises(ValueError):
        Registry().windowed_histogram("bad", sub_buckets=0, clock=clock)
    # re-registration is idempotent, kind clash rejected
    r = Registry()
    a = r.windowed_histogram("w_s", clock=clock)
    assert r.windowed_histogram("w_s", clock=clock) is a
    with pytest.raises(ValueError):
        r.counter("w_s")
