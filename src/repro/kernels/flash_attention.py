"""Flash attention (fwd + bwd) as Pallas TPU kernels.

Beyond-paper optimization (EXPERIMENTS.md §Perf): the naive attention path
materializes (B,H,S,S) scores in HBM — the dominant memory-roofline term for
every full/windowed-attention train & prefill cell. These kernels keep score
blocks in VMEM (classic FlashAttention-2 scheme, re-tiled for TPU: 128-aligned
blocks for the MXU, f32 running stats in VMEM scratch).

Supports causal masking and sliding windows (window=0 -> full causal);
GQA handled by the caller mapping kv-head = q-head // group.

HBM traffic: q, o read/written once; k/v re-read once per q-block — exactly
what launch/costs.py accounts for pallas_call eqns.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import resolve_interpret, tpu_compiler_params
from repro.obs import profile as _obs_profile

Array = jax.Array
NEG_INF = -1e30


def _fwd_kernel(w_ref, q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr,
                acc_scr, *, scale, bq, bk, seq_k, causal):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                   # (bq, d)
    k = k_ref[0]                                   # (bk, d); v may have dv != d
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < seq_k
    if causal:
        mask = jnp.logical_and(mask, q_pos >= k_pos)
    w = w_ref[0, 0]   # dynamic sliding window; <=0 means full attention
    mask = jnp.logical_and(mask, jnp.logical_or(w <= 0, q_pos - k_pos < w))
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                            # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    # Zero p where masked: for a fully-masked block m_new stays NEG_INF and
    # exp(s - m_new) = exp(0) = 1 per entry, which would pollute l/acc with
    # bk phantom counts (and only self-correct if a LATER block has a valid
    # entry). Paged/chunked-prefill masks hit that case directly.
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)   # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)                # (bq, 1)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ki == pl.num_programs(2) - 1)
    def _fin():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)
        lse_ref[0] = (m_scr[...][:, 0] + jnp.log(l[:, 0]))


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def _flash_fwd(q, k, v, window, *, causal=True, bq=128, bk=128,
               interpret=None) -> Tuple[Array, Array]:
    """q: (BH, Sq, d), k/v: (BH, Sk, d), window: () int32 (traced OK, <=0 =
    full) -> (out (BH,Sq,d), lse (BH,Sq))."""
    interpret = resolve_interpret(interpret)
    window = jnp.asarray(window, jnp.int32).reshape(1, 1)
    bh, sq, d = q.shape
    _, sk, _ = k.shape
    dv = v.shape[-1]                     # MLA: value dim may differ from d_qk
    bq = min(bq, sq)
    bk = min(bk, sk)
    sq_pad = -(-sq // bq) * bq
    sk_pad = -(-sk // bk) * bk
    if sq_pad != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_pad - sq), (0, 0)))
    if sk_pad != sk:
        k = jnp.pad(k, ((0, 0), (0, sk_pad - sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk_pad - sk), (0, 0)))
    grid = (bh, sq_pad // bq, sk_pad // bk)
    scale = 1.0 / (d ** 0.5)
    out, lse = pl.pallas_call(
        functools.partial(_fwd_kernel, scale=scale, bq=bq, bk=bk, seq_k=sk,
                          causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, i, j: (0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, dv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, dv), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, sq_pad, dv), q.dtype),
            jax.ShapeDtypeStruct((bh, sq_pad), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dv), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(window, q, k, v)
    return out[:, :sq], lse[:, :sq]


def _bwd_kernel(w_ref, q_ref, k_ref, v_ref, do_ref, o_ref, lse_ref, dq_ref,
                dk_ref, dv_ref, *, scale, bq, bk, seq_k, causal):
    """One pass per (bh, kj, qi): accumulate dk/dv for this k block over q
    blocks (qi innermost), and contribute dq for each q block via accumulation.
    """
    kj = pl.program_id(1)
    qi = pl.program_id(2)

    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    do = do_ref[0].astype(jnp.float32)
    o = o_ref[0].astype(jnp.float32)
    lse = lse_ref[0]                                # (bq,)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < seq_k
    if causal:
        mask = jnp.logical_and(mask, q_pos >= k_pos)
    w = w_ref[0, 0]
    mask = jnp.logical_and(mask, jnp.logical_or(w <= 0, q_pos - k_pos < w))
    p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)   # (bq, bk)

    delta = jnp.sum(do * o, axis=1, keepdims=True)        # (bq, 1)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta) * scale                          # (bq, bk)

    @pl.when(qi == 0)
    def _init():
        dk_ref[0] = jnp.zeros_like(dk_ref[0])
        dv_ref[0] = jnp.zeros_like(dv_ref[0])

    dv_ref[0] += jax.lax.dot_general(
        p.astype(jnp.float32), do, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(dv_ref.dtype)
    dk_ref[0] += jax.lax.dot_general(
        ds, q.astype(jnp.float32), (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(dk_ref.dtype)
    # dq accumulated across k blocks: kj is the OUTER grid dim, so each
    # (qi) block is revisited once per kj -> accumulate into dq.
    dq_part = jax.lax.dot_general(ds, k.astype(jnp.float32),
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    @pl.when(kj == 0)
    def _dq_init():
        dq_ref[0] = dq_part.astype(dq_ref.dtype)

    @pl.when(kj != 0)
    def _dq_acc():
        dq_ref[0] += dq_part.astype(dq_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def _flash_bwd(q, k, v, o, lse, do, window, *, causal=True, bq=128, bk=128,
               interpret=None):
    interpret = resolve_interpret(interpret)
    window = jnp.asarray(window, jnp.int32).reshape(1, 1)
    bh, sq, d = q.shape
    _, sk, _ = k.shape
    dv = v.shape[-1]
    bq = min(bq, sq)
    bk = min(bk, sk)
    sq_pad = -(-sq // bq) * bq
    sk_pad = -(-sk // bk) * bk
    if sq_pad != sq:
        pad = ((0, 0), (0, sq_pad - sq), (0, 0))
        q = jnp.pad(q, pad)
        o = jnp.pad(o, pad)
        do = jnp.pad(do, pad)
        lse = jnp.pad(lse, ((0, 0), (0, sq_pad - sq)), constant_values=1e30)
    if sk_pad != sk:
        pad = ((0, 0), (0, sk_pad - sk), (0, 0))
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    # grid: kj outer / qi inner so dq blocks accumulate across consecutive steps
    grid = (bh, sk_pad // bk, sq_pad // bq)
    scale = 1.0 / (d ** 0.5)
    f32 = jnp.float32
    dq, dk, dv = pl.pallas_call(
        functools.partial(_bwd_kernel, scale=scale, bq=bq, bk=bk, seq_k=sk,
                          causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, j, i: (0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, bq, d), lambda b, j, i: (b, i, 0)),   # q
            pl.BlockSpec((1, bk, d), lambda b, j, i: (b, j, 0)),   # k
            pl.BlockSpec((1, bk, dv), lambda b, j, i: (b, j, 0)),  # v
            pl.BlockSpec((1, bq, dv), lambda b, j, i: (b, i, 0)),  # do
            pl.BlockSpec((1, bq, dv), lambda b, j, i: (b, i, 0)),  # o
            pl.BlockSpec((1, bq), lambda b, j, i: (b, i)),         # lse
        ],
        out_specs=[
            pl.BlockSpec((1, bq, d), lambda b, j, i: (b, i, 0)),   # dq
            pl.BlockSpec((1, bk, d), lambda b, j, i: (b, j, 0)),   # dk
            pl.BlockSpec((1, bk, dv), lambda b, j, i: (b, j, 0)),  # dv
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, sq_pad, d), f32),
            jax.ShapeDtypeStruct((bh, sk_pad, d), f32),
            jax.ShapeDtypeStruct((bh, sk_pad, dv), f32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(window, q, k, v, do, o, lse)
    return dq[:, :sq], dk[:, :sk], dv[:, :sk]


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def flash_attention(q: Array, k: Array, v: Array, window=0,
                    causal: bool = True, interpret=None,
                    bq: int = 128, bk: int = 128) -> Array:
    """q: (BH, Sq, d), k/v: (BH, Sk, d) -> (BH, Sq, d).

    ``window`` may be a TRACED int32 scalar (<=0 = full attention) — gemma3's
    per-layer local/global pattern rides through the layer scan this way.
    ``interpret=None`` auto-detects the backend (compat.py); ``bq``/``bk``
    are the q/k sequence block sizes — the attention layer resolves tuned
    values through ``repro.tune`` under ``GemmConfig(block="auto")``."""
    _obs_profile.on_flash(q, k, causal=causal)
    out, _ = _flash_fwd(q, k, v, window, causal=causal, interpret=interpret,
                        bq=bq, bk=bk)
    return out


def _fa_fwd(q, k, v, window, causal, interpret, bq, bk):
    out, lse = _flash_fwd(q, k, v, window, causal=causal, interpret=interpret,
                          bq=bq, bk=bk)
    return out, (q, k, v, out, lse, window)


def _fa_bwd(causal, interpret, bq, bk, res, do):
    import numpy as _np
    q, k, v, out, lse, window = res
    dq, dk, dv = _flash_bwd(q, k, v, out, lse, do, window, causal=causal,
                            bq=bq, bk=bk, interpret=interpret)
    dw = _np.zeros((), jax.dtypes.float0)   # int operand: symbolic zero grad
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype), dw


flash_attention.defvjp(_fa_fwd, _fa_bwd)


# -- paged decode attention ---------------------------------------------------

def _paged_fwd_kernel(pt_ref, len_ref, qs_ref, w_ref, q_ref, k_ref, v_ref,
                      o_ref, m_scr, l_scr, acc_scr, *, scale, sq, ps, causal):
    """One (b, h, page) step of the online softmax over paged k/v.

    k_ref/v_ref already hold the POOL page selected by the scalar-prefetch
    index map (page_table[b, j]); this body only has to mask by true length
    and fold the page into the running (m, l, acc) stats."""
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0]                                # (sq, d)
    k = k_ref[0, :, 0, :]                          # (ps, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    q_pos = qs_ref[b] + jax.lax.broadcasted_iota(jnp.int32, (sq, ps), 0)
    k_pos = j * ps + jax.lax.broadcasted_iota(jnp.int32, (sq, ps), 1)
    mask = k_pos < len_ref[b]
    if causal:
        mask = jnp.logical_and(mask, q_pos >= k_pos)
    w = w_ref[0]
    mask = jnp.logical_and(mask, jnp.logical_or(w <= 0, q_pos - k_pos < w))
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                            # (sq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)   # (sq, ps)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0, :, 0, :], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(j == pl.num_programs(2) - 1)
    def _fin():
        # Rows with zero valid keys keep l == 0 -> output exactly 0 (not the
        # mean of garbage v rows; see the masked-p note in _fwd_kernel).
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("scale", "causal", "interpret"))
def flash_attention_paged(q: Array, k_pool: Array, v_pool: Array,
                          page_table: Array, lengths: Array, q_start: Array,
                          window=0, *, scale: Optional[float] = None,
                          causal: bool = True, interpret=None) -> Array:
    """Decode-side paged attention: k/v live in a page pool and are gathered
    through the page table INSIDE the kernel (scalar-prefetch index maps pick
    the pool page per grid step — no materialized contiguous copy).

    q:          (B, H, Sq, d)   — Sq is the decode chunk (1 for single-step)
    k_pool:     (P, ps, KV, d)  — KV kv-heads, q-head h uses kv-head h*KV//H
    v_pool:     (P, ps, KV, dv) — dv may differ from d (absorbed MLA)
    page_table: (B, max_pages) int32 pool page ids (unallocated entries may
                be anything in range; they are masked by ``lengths``)
    lengths:    (B,) int32 — number of valid cache rows (keys) per sequence
    q_start:    (B,) int32 — absolute position of q row 0
    window:     () int32 (traced OK; <=0 = full attention)
    scale:      score scale; default 1/sqrt(d) (absorbed MLA passes the
                1/sqrt(nope+rope) of the pre-absorption head dim)
    -> (B, H, Sq, dv). Query rows with zero valid keys return exactly 0.
    """
    interpret = resolve_interpret(interpret)
    b, h, sq, d = q.shape
    n_pages, ps, kv, _ = k_pool.shape
    dv = v_pool.shape[-1]
    max_pages = page_table.shape[1]
    group = max(h // kv, 1)
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    pt = jnp.asarray(page_table, jnp.int32)
    ln = jnp.asarray(lengths, jnp.int32).reshape(b)
    qs = jnp.asarray(q_start, jnp.int32).reshape(b)
    w = jnp.asarray(window, jnp.int32).reshape(1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(b, h, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, sq, d),
                         lambda bi, hi, j, pt, ln, qs, w: (bi, hi, 0, 0)),
            pl.BlockSpec((1, ps, 1, d),
                         lambda bi, hi, j, pt, ln, qs, w:
                         (pt[bi, j], 0, hi // group, 0)),
            pl.BlockSpec((1, ps, 1, dv),
                         lambda bi, hi, j, pt, ln, qs, w:
                         (pt[bi, j], 0, hi // group, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, sq, dv), lambda bi, hi, j, pt, ln, qs, w: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((sq, 1), jnp.float32),
            pltpu.VMEM((sq, 1), jnp.float32),
            pltpu.VMEM((sq, dv), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_fwd_kernel, scale=scale, sq=sq, ps=ps,
                          causal=causal),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, sq, dv), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(pt, ln, qs, w, q, k_pool, v_pool)
