"""Flash-attention kernel vs naive oracle: fwd + grads, shape/window sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention


def naive(q, k, v, causal=True, window=0):
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) / (d ** 0.5)
    qp = jnp.arange(q.shape[1])[:, None]
    kp = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones_like(s, bool)
    if causal:
        mask &= qp >= kp
    if window:
        mask &= (qp - kp) < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(v.dtype), v)


def mk(bh, sq, sk, d, dtype=jnp.float32, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(k1, (bh, sq, d), dtype),
            jax.random.normal(k2, (bh, sk, d), dtype),
            jax.random.normal(k3, (bh, sk, d), dtype))


@pytest.mark.parametrize("sq,sk,d,bq,bk", [
    (128, 128, 64, 128, 128),
    (256, 256, 64, 128, 128),
    (100, 100, 32, 64, 64),     # padded path
    (64, 192, 32, 32, 64),      # cross lengths
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_fwd_matches_naive(sq, sk, d, bq, bk, causal):
    q, k, v = mk(2, sq, sk, d)
    got = flash_attention(q, k, v, 0, causal, True)
    want = naive(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("window", [8, 64, 1024])
def test_flash_window_matches_naive(window):
    q, k, v = mk(2, 128, 128, 32, seed=1)
    got = flash_attention(q, k, v, window, True, True)
    want = naive(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_flash_grads_match_naive():
    q, k, v = mk(1, 64, 64, 32, seed=2)

    def loss_flash(q, k, v):
        return jnp.sum(jnp.sin(flash_attention(q, k, v, 0, True, True)))

    def loss_naive(q, k, v):
        return jnp.sum(jnp.sin(naive(q, k, v)))

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=5e-3, atol=5e-3)


def test_flash_grads_windowed():
    q, k, v = mk(1, 96, 96, 32, seed=3)
    g1 = jax.grad(lambda *a: jnp.sum(flash_attention(*a, 32, True, True) ** 2),
                  argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda *a: jnp.sum(naive(*a, causal=True, window=32) ** 2),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=5e-3, atol=5e-3)


def test_flash_bf16():
    q, k, v = mk(2, 128, 128, 64, jnp.bfloat16, seed=4)
    got = flash_attention(q, k, v, 0, True, True)
    want = naive(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_flash_traced_window():
    """window as a traced scalar under jit/scan (the gemma3 pattern)."""
    q, k, v = mk(1, 64, 64, 32, seed=5)

    @jax.jit
    def run(w):
        return flash_attention(q, k, v, w, True, True)

    for w in (0, 16):
        got = run(jnp.asarray(w, jnp.int32))
        want = naive(q, k, v, causal=True, window=w)
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)
