"""CI gate over the committed serving benchmark: re-run a slice of
``serve_bench`` and hold it against ``benchmarks/BENCH_serve.json``.

    PYTHONPATH=src python benchmarks/bench_gate.py [--max-slowdown 5.0]

Two classes of check, per gated row (``mode`` x ``decode_chunk``):

* **Deterministic fields must match EXACTLY.** The workload is seeded and
  greedy, so ``completed``, ``tokens_out``, ``decode_steps``,
  ``decode_dispatches``, ``prefill_tokens``, ``decode_tokens`` and
  ``host_bytes_per_step`` are functions of the code, not the machine — any
  drift means the serving hot path changed behaviour without the committed
  bench being regenerated (run serve_bench.py and commit the new JSON).

* **Timing may only degrade within a generous bound.** CI machines are
  slower and noisier than the box that produced the committed numbers, so
  timings are gated one-sided: fresh ``decode_ms_per_step`` must stay under
  ``committed * --max-slowdown`` (default 5x). Speedups always pass. This
  catches order-of-magnitude regressions (a de-jitted hot path, a
  host-sync re-introduced per token) without flaking on CPU noise.

The re-run itself also re-executes every in-bench telemetry cross-check
(windowed TTFT/ITL percentiles vs raw request records, zero
``samples_dropped``, e2e reservoir non-overflow), so a metrics-layer
regression fails the gate even when the timings look fine.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

from serve_bench import bench  # noqa: E402  (same directory)

BENCH = pathlib.Path(__file__).resolve().parent / "BENCH_serve.json"

# (mode, decode_chunk) rows re-run by the gate: the float fast path at the
# chunking extremes plus the quantized fused path. Keep this slice small —
# the gate runs per-PR; the full sweep is serve_bench's job.
GATED_ROWS = (("float", 1), ("float", 4), ("int8-ffip", 4))

EXACT_FIELDS = ("completed", "tokens_out", "decode_steps",
                "decode_dispatches", "prefill_tokens", "decode_tokens",
                "host_bytes_per_step")


def gate(*, max_slowdown: float, rows=GATED_ROWS) -> list:
    committed = json.loads(BENCH.read_text())
    by_key = {(r["mode"], r["decode_chunk"]): r
              for r in committed.get("results", [])}
    problems = []
    for mode, chunk in rows:
        base = by_key.get((mode, chunk))
        if base is None:
            problems.append(f"{mode}/chunk{chunk}: no committed row in "
                            f"{BENCH.name} (regenerate with serve_bench.py)")
            continue
        fresh = bench("minicpm-2b", slots=base["slots"],
                      requests=base["requests"], max_new=4,
                      max_len=64, quantized=(mode != "float"),
                      decode_chunk=chunk)
        for f in EXACT_FIELDS:
            if fresh[f] != base[f]:
                problems.append(
                    f"{mode}/chunk{chunk}: {f} = {fresh[f]} != committed "
                    f"{base[f]} (behaviour changed; regenerate "
                    f"BENCH_serve.json if intentional)")
        limit = base["decode_ms_per_step"] * max_slowdown
        if fresh["decode_ms_per_step"] > limit:
            problems.append(
                f"{mode}/chunk{chunk}: decode_ms_per_step "
                f"{fresh['decode_ms_per_step']} > {limit:.2f} "
                f"(committed {base['decode_ms_per_step']} x "
                f"--max-slowdown {max_slowdown})")
        tag = f"{mode}/chunk{chunk}:"
        verdict = ("DRIFTED" if any(p.startswith(tag) for p in problems)
                   else "MATCH")
        print(f"bench-gate {mode}/chunk{chunk}: "
              f"decode {fresh['decode_ms_per_step']}ms/step "
              f"(committed {base['decode_ms_per_step']}, "
              f"limit {limit:.2f}), deterministic fields {verdict}")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--max-slowdown", type=float, default=5.0,
                    help="one-sided timing bound: fresh decode_ms_per_step "
                         "must stay under committed * this (default 5.0)")
    args = ap.parse_args(argv)
    problems = gate(max_slowdown=args.max_slowdown)
    if problems:
        print("bench-gate FAIL:\n  " + "\n  ".join(problems),
              file=sys.stderr)
        return 1
    print(f"bench-gate OK: {len(GATED_ROWS)} rows vs {BENCH.name}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
