"""FFIP GEMM as a Pallas TPU kernel — Fig. 1c / Fig. 3 adapted to TPU.

Faithful free-pipeline dataflow: the kernel consumes the weight *deltas*
y (Eq. 9) rather than B, and reconstructs the g-term offsets by accumulating
y along the output-column direction — exactly what the FFIP PE chain does,
where each g register adds one y as the value hops to the next column's PE.

Mapping to a blocked kernel: grid is (M/bm, K/bk, N/bn) with the N axis
innermost. A VMEM scratch ``carry`` holds the running column prefix of y for
the current (m, k) stripe; within a block the prefix is a cumsum. Thus
B is never materialised in HBM — only y travels (the paper's §4.4 notes y can
be precomputed and stored at 1 extra bit).

The α row is computed in-kernel (the paper's extra MAC row, Fig. 3); β is
reconstructed from the carried prefix (or pre-folded into bias, Eq. 15).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat
from repro.kernels.baseline_gemm import pad_to_blocks
from repro.kernels.compat import resolve_interpret, tpu_compiler_params

from repro.core import fip

Array = jax.Array

# Per-weight y-delta cache (§4.4: y is precomputed offline and stored in
# place of B), shared with conv_gemm through compat.derived and seeded by
# repro.prepare on artifact warm start (tag "y").
Y_TAG = "y"


def _y_for(b: Array) -> Array:
    return compat.derived.get(Y_TAG, b, fip.make_y)


def ffip_tile(a, y, carry_ref, nn, *, fold_beta: bool):
    """Eqs. (7)-(9) on one tile: reconstruct the weight offsets from the y
    deltas via the column prefix carried in ``carry_ref`` (reset when the N
    sweep restarts at ``nn == 0``), then the pair product-sum minus alpha
    (and beta unless folded). SHARED between this GEMM kernel and the fused
    implicit-im2col conv kernels (kernels/conv_gemm.py) — one algebra, two
    A-tile sources, so fused conv == materialized GEMM bit-for-bit."""

    @pl.when(nn == 0)
    def _reset():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    # Free-pipeline reconstruction: b_{k,j} = b_{k,j-1} + y_{k,j} (Eq. 8c/9).
    b = carry_ref[...] + jnp.cumsum(y, axis=1)  # (bk, bn)
    carry_ref[...] = b[:, -1:]                  # prefix for the next N block

    # g terms (Eqs. 8a/8b): pair-swapped A plus the reconstructed offsets.
    a_odd, a_evn = a[:, 0::2], a[:, 1::2]
    b_odd, b_evn = b[0::2, :], b[1::2, :]
    g1 = a_evn[:, :, None] + b_odd[None, :, :]  # g_{i,2k-1}
    g2 = a_odd[:, :, None] + b_evn[None, :, :]  # g_{i,2k}
    cross = jnp.sum(g1 * g2, axis=1)            # Eq. (7) product-sum
    alpha = jnp.sum(a_odd * a_evn, axis=1)      # alpha MAC row (Fig. 3)
    part = cross - alpha[:, None]
    if not fold_beta:
        beta = jnp.sum(b_odd * b_evn, axis=0)
        part = part - beta[None, :]
    return part


def _kernel(a_ref, y_ref, o_ref, carry_ref, *, acc_dtype, fold_beta):
    kk = pl.program_id(1)
    nn = pl.program_id(2)
    a = a_ref[...].astype(acc_dtype)            # (bm, bk)
    y = y_ref[...].astype(acc_dtype)            # (bk, bn) weight deltas
    part = ffip_tile(a, y, carry_ref, nn, fold_beta=fold_beta)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = part

    @pl.when(kk != 0)
    def _acc():
        o_ref[...] += part


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret",
                                             "fold_beta"))
def ffip_gemm_y(a: Array, y: Array, *, bm: int = 128, bn: int = 128,
                bk: int = 64, interpret=None,
                fold_beta: bool = False) -> Array:
    """FFIP GEMM from precomputed y deltas. a: (M, K), y: (K, N) -> (M, N).

    Non-divisible shapes zero-pad and slice (exact for the returned corner:
    zero y rows reconstruct zero b rows against zero a columns, and padded N
    columns live at the tail of the final carry sweep so no real column reads
    their prefix). bk must be even; ``interpret=None`` = backend auto."""
    interpret = resolve_interpret(interpret)
    assert bk % 2 == 0
    m0, k0 = a.shape
    k2, n0 = y.shape
    assert k0 == k2
    a, y = pad_to_blocks(a, y, bm, bn, bk)
    m, k = a.shape
    n = y.shape[1]
    acc_dtype = (jnp.int32 if jnp.issubdtype(a.dtype, jnp.integer)
                 else jnp.float32)
    # grid: N innermost so the carry sweeps columns for a fixed (m, k) stripe.
    grid = (m // bm, k // bk, n // bn)
    out = pl.pallas_call(
        functools.partial(_kernel, acc_dtype=acc_dtype, fold_beta=fold_beta),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, kk, j: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, kk, j: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, kk, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), acc_dtype),
        scratch_shapes=[pltpu.VMEM((bk, 1), acc_dtype)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(a, y)
    return out[:m0, :n0]


def ffip_gemm(a: Array, b: Array, *, y: Array = None, **kw) -> Array:
    """Convenience: derive y from B (offline in deployment) then run FFIP.

    y is kept in the accumulation dtype (int32 / f32): the paper stores y with
    1 extra bit (§4.4) so the delta encoding is lossless; for bf16 weights the
    f32 deltas play that role (bf16 deltas would make the column prefix-sum
    reconstruction lossy).

    The derivation is MEMOIZED per weight array (or pass a precomputed ``y``
    directly), matching the paper's deployment story: y is an offline
    transform of the trained weights, not per-invocation work.
    """
    if y is None:
        y = _y_for(b)  # make_y already promotes to the accumulation dtype
    return ffip_gemm_y(a, y, **kw)
