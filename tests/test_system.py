"""End-to-end behaviour tests for the whole system: train -> crash ->
resume -> serve, exercising every substrate layer together."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models.model import build_model
from repro.serve.batcher import BatchServer, Request
from repro.train.loop import LoopConfig, train
from repro.train.step import TrainConfig
from repro.optim.adamw import AdamWConfig


def _loop_cfg(tmp_path, steps):
    return LoopConfig(total_steps=steps, global_batch=2, seq_len=32,
                      ckpt_dir=str(tmp_path / "ckpt"), ckpt_every=4,
                      log_every=2, seed=3)


def test_train_loss_decreases_and_resume_is_exact(tmp_path):
    """Train 8 steps with checkpoints; 'crash'; resume to 12; the resumed run
    must equal an uninterrupted 12-step run exactly (determinism contract:
    counter-based data + checkpointed optimizer state)."""
    cfg = configs.smoke_config(configs.get_config("minicpm-2b"))
    model = build_model(cfg)
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3, schedule="const",
                                             warmup_steps=0))

    out_a = train(model, loop_cfg=_loop_cfg(tmp_path / "a", 12),
                  train_cfg=tcfg)
    losses_a = [h["loss"] for h in out_a["history"]]
    assert losses_a[-1] < losses_a[0], "loss must decrease"

    # interrupted run: first 8 steps (ckpt at 4, 8), then resume to 12
    train(model, loop_cfg=_loop_cfg(tmp_path / "b", 8), train_cfg=tcfg)
    out_b = train(model, loop_cfg=_loop_cfg(tmp_path / "b", 12),
                  train_cfg=tcfg)

    pa = jax.tree_util.tree_leaves(out_a["params"])
    pb = jax.tree_util.tree_leaves(out_b["params"])
    for a, b in zip(pa, pb):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-5)


def test_trained_model_serves(tmp_path):
    """The training product plugs straight into the serving runtime."""
    cfg = configs.smoke_config(configs.get_config("starcoder2-3b"))
    model = build_model(cfg)
    out = train(model, loop_cfg=_loop_cfg(tmp_path, 4),
                train_cfg=TrainConfig())
    srv = BatchServer(model, batch_slots=2, max_len=48)
    rng = np.random.default_rng(1)
    for i in range(3):
        srv.submit(Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=(5,)),
                           max_new_tokens=4))
    done = srv.run_until_drained(out["params"])
    assert len(done) == 3
    assert all(len(r.out_tokens) == 4 for r in done)
    assert all(0 <= t < cfg.vocab for r in done for t in r.out_tokens)
