"""Shared EMA/dead-man watchdog for long-running drive loops.

One implementation serves both consumers:

  * the TRAINING loop (`repro.train.loop` via the thin
    `repro.train.watchdog.StepWatchdog` alias) — per-step heartbeats on a
    real clock;
  * the SERVING drive loop (`repro.serve.router.ReplicaRouter`) — per-tick
    heartbeats, usually on an injected :class:`repro.serve.faults.FakeClock`
    so hang detection is deterministic under fault injection.

Semantics (unchanged from the original train-only watchdog):

  * EMA step-time tracker; a step > ``threshold`` x EMA flags a straggler;
  * K consecutive straggler flags trigger the mitigation callback (in
    production: demote the host / quarantine the replica / re-shard);
  * a dead-man timer raises :class:`HangError` if no step completes within
    ``hang_timeout_s`` — the launcher catches it and restarts from the last
    checkpoint (train) or fails the stuck requests over to a healthy
    replica (serve).

The clock is injectable (any zero-arg callable returning seconds) so the
timeout logic is unit-testable without sleeping.

Telemetry: straggler flags and dead-man trips are emitted as ``repro.obs``
counters (``watchdog_straggler_flags_total`` / ``watchdog_deadman_trips_total``
labeled by ``loop``) FROM THIS MODULE ONLY — the ``train.watchdog`` shim is
a pure alias carrying no state of its own, so the two consumers can never
double-count (the regression test in test_obs.py pins this). The local
``events`` list is a bounded ring (the old unbounded list leaked on
long-running servers).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Optional

_EVENT_RING = 256


@dataclasses.dataclass
class WatchdogConfig:
    ema_decay: float = 0.9
    threshold: float = 2.5          # x EMA = straggler
    consecutive_to_act: int = 3
    hang_timeout_s: float = 600.0


class HangError(TimeoutError):
    """Dead-man timer expired: no step/tick observed within the timeout."""


class Watchdog:
    def __init__(self, cfg: WatchdogConfig = WatchdogConfig(),
                 on_straggler: Optional[Callable[[int, float, float],
                                                 None]] = None,
                 clock: Callable[[], float] = time.monotonic,
                 registry=None, loop: str = "serve"):
        self.cfg = cfg
        self.clock = clock
        self.ema: Optional[float] = None
        self.flags = 0
        self.events: "collections.deque[dict]" = collections.deque(
            maxlen=_EVENT_RING)
        self.on_straggler = on_straggler
        self._last_tick = clock()
        if registry is None:
            from repro.obs import get_registry
            registry = get_registry()
        self._m_stragglers = registry.counter(
            "watchdog_straggler_flags_total",
            "ticks exceeding threshold x EMA", ("loop",)).labels(loop=loop)
        self._m_deadman = registry.counter(
            "watchdog_deadman_trips_total",
            "dead-man timer expiries (HangError raised)",
            ("loop",)).labels(loop=loop)

    def observe(self, step: int, dt: float) -> bool:
        """Feed one step duration; returns True if mitigation fired."""
        self._last_tick = self.clock()
        fired = False
        if self.ema is None:
            self.ema = dt
        else:
            if dt > self.cfg.threshold * self.ema:
                self.flags += 1
                self.events.append(dict(step=step, dt=dt, ema=self.ema))
                self._m_stragglers.inc()
                if self.flags >= self.cfg.consecutive_to_act:
                    fired = True
                    self.flags = 0
                    if self.on_straggler is not None:
                        self.on_straggler(step, dt, self.ema)
            else:
                self.flags = 0
            # EMA excludes outliers so one straggler does not poison the baseline
            if dt <= self.cfg.threshold * self.ema:
                self.ema = (self.cfg.ema_decay * self.ema
                            + (1 - self.cfg.ema_decay) * dt)
        return fired

    def check_hang(self) -> None:
        if self.clock() - self._last_tick > self.cfg.hang_timeout_s:
            self._m_deadman.inc()
            raise HangError(
                f"no step for >{self.cfg.hang_timeout_s}s — restore the "
                "latest checkpoint / fail work over to a healthy replica "
                "and relaunch")
