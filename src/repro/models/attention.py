"""Attention variants: GQA (opt. sliding-window / local:global), MLA
(DeepSeek-V2), and cross-attention (enc-dec). All projections go through the
GEMM provider; score/context matmuls are activation-activation products (out
of FIP scope — the paper's technique targets weight GEMMs on the MXU).

Window convention: ``window`` is a (possibly traced) int32 scalar; 0 means
full attention. Traced windows let a scan-over-layers carry per-layer
local/global patterns (gemma3 5:1) without unrolling the stack.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L

Array = jax.Array
NEG_INF = -2.0e38


def gqa_init(key, cfg: ModelConfig, dtype) -> dict:
    d, hd = cfg.d_model, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": L.dense_init(k1, d, cfg.n_heads * hd, dtype, bias=cfg.qkv_bias),
        "wk": L.dense_init(k2, d, cfg.n_kv_heads * hd, dtype, bias=cfg.qkv_bias),
        "wv": L.dense_init(k3, d, cfg.n_kv_heads * hd, dtype, bias=cfg.qkv_bias),
        "wo": L.dense_init(k4, cfg.n_heads * hd, d, dtype),
    }


def _cache_write(buf: Array, new: Array, cache_pos,
                 write_mask: Optional[Array] = None) -> Array:
    """Write ``new`` (B, s, ...) rows into ``buf`` (B, S_max, ...) at
    ``cache_pos``.

    Scalar ``cache_pos``: shared offset (prefill / legacy decode) — a single
    dynamic slice. ``(B,)`` vector: per-slot offsets (continuous-batching
    decode) — one dynamic slice per batch row via vmap, lowering to a batched
    scatter. Slot i's row lands at ``buf[i, cache_pos[i]]``.

    ``write_mask`` (optional, (B,) bool): rows with a False mask keep their
    existing cache content — the bucketed batched prefill runs a full-width
    forward straight over the SHARED slot cache and only commits the rows
    being admitted, so live slots decoding next door are untouched. The
    masked form still lowers to one dynamic_update_slice per leaf (the slice
    is re-read, selected, and written back), never a per-leaf scatter.
    """
    new = new.astype(buf.dtype)
    pos = jnp.asarray(cache_pos, jnp.int32)
    if pos.ndim == 0:
        if write_mask is not None:
            cur = jax.lax.dynamic_slice_in_dim(buf, pos, new.shape[1], axis=1)
            keep = write_mask.reshape((-1,) + (1,) * (new.ndim - 1))
            new = jnp.where(keep, new, cur)
        return jax.lax.dynamic_update_slice_in_dim(buf, new, pos, axis=1)

    def one(row, n, p, m=None):
        if m is not None:
            cur = jax.lax.dynamic_slice_in_dim(row, p, n.shape[0], axis=0)
            n = jnp.where(m, n, cur)
        return jax.lax.dynamic_update_slice_in_dim(row, n, p, axis=0)

    if write_mask is not None:
        return jax.vmap(one)(buf, new, pos, write_mask)
    return jax.vmap(one)(buf, new, pos)


def _paged_write(pool: Array, new: Array, page_table: Array, cache_pos,
                 write_mask: Optional[Array] = None) -> Array:
    """Scatter ``new`` (B, s, ...) token rows into the page ``pool``
    (P, ps, ...) at logical positions ``cache_pos`` via the page table.

    ``page_table`` is (B, max_pages) int32 pool page ids; token ``t`` of
    sequence ``b`` lands in pool row ``page_table[b, t // ps] * ps + t % ps``.
    ``cache_pos``: scalar or (B,) first logical position of ``new``.
    ``write_mask``: None, (B,) or (B, s) bool — False rows are DROPPED (their
    scatter index is pushed out of range and ``mode="drop"`` discards it), so
    frozen/inactive slots never touch the shared pool. Rows whose logical
    position falls beyond the page table are likewise dropped.
    """
    new = new.astype(pool.dtype)
    n_pages, ps = pool.shape[:2]
    b, s = new.shape[:2]
    max_pages = page_table.shape[1]
    pos = jnp.broadcast_to(jnp.asarray(cache_pos, jnp.int32).reshape(-1), (b,))
    r = pos[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]      # (B, s)
    page = jnp.take_along_axis(page_table.astype(jnp.int32),
                               jnp.minimum(r // ps, max_pages - 1), axis=1)
    rows = page * ps + r % ps
    rows = jnp.where(r // ps < max_pages, rows, n_pages * ps)
    if write_mask is not None:
        wm = write_mask if write_mask.ndim == 2 else write_mask[:, None]
        rows = jnp.where(wm, rows, n_pages * ps)
    flat = pool.reshape((n_pages * ps,) + pool.shape[2:])
    flat = flat.at[rows.reshape(-1)].set(
        new.reshape((b * s,) + new.shape[2:]), mode="drop")
    return flat.reshape(pool.shape)


def _paged_view(pool: Array, page_table: Array) -> Array:
    """Gather pool pages into a (B, max_pages*ps, ...) contiguous view.

    With ``max_pages * ps == max_len`` the view has the contiguous cache's
    exact shape, so the downstream score/softmax/context math (and therefore
    the sampled tokens) is bit-identical to the contiguous-slot path —
    garbage in unallocated pages is masked by the caller's validity mask.
    """
    n_pages, ps = pool.shape[:2]
    b, max_pages = page_table.shape
    flat = pool.reshape((n_pages * ps,) + pool.shape[2:])
    rows = (page_table.astype(jnp.int32)[:, :, None] * ps
            + jnp.arange(ps, dtype=jnp.int32)[None, None, :])
    return flat[rows.reshape(b, max_pages * ps)]


def _cache_end(cache_pos, s: int) -> Array:
    """Exclusive end of valid cache rows per batch entry: (1, 1) for a shared
    scalar position, (B, 1) for per-slot positions — broadcasts against a
    (B or 1, S_max) key-position grid."""
    pos = jnp.asarray(cache_pos, jnp.int32)
    return jnp.reshape(pos + s, (-1, 1))


def _mask(q_pos: Array, k_pos: Array, window, causal: bool) -> Array:
    """(..., Sq, Sk) boolean keep-mask from positions + window scalar."""
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    keep = (diff >= 0) if causal else jnp.ones_like(diff, dtype=bool)
    windowed = jnp.logical_and(keep, diff < jnp.maximum(window, 1))
    return jnp.where(window > 0, windowed, keep)


def _flash_schedule(dtype, bh: int, sq: int, sk: int, d: int):
    """Flash block sizes + interpret mode from the ambient GEMM config.

    ``GemmConfig(block="auto")`` gives flash attention the same tuned-schedule
    treatment as the GEMM kernels: a trace-time lookup in the repro.tune
    cache for this shape bucket, defaults on a miss. ``interpret=None``
    passes backend auto-detection down to the kernel."""
    from repro.core.gemm import current_config
    cfg = current_config()
    bq, bk = 128, 128
    if cfg.block == "auto":
        from repro import tune
        got = tune.lookup_flash_blocks(dtype, bh, sq, sk, d)
        if got is not None:
            bq, bk = got
    return bq, bk, cfg.interpret


def _flash_sdpa(q: Array, k: Array, v: Array, window, causal: bool) -> Array:
    """Pallas flash path for full/prefill self- and cross-attention.

    q: (B,Sq,H,hd), k/v: (B,Sk,KV,hd). GQA via kv-head repeat (a view; the
    kernel re-reads k/v blocks per q block anyway). window may be traced.
    """
    from repro.kernels.flash_attention import flash_attention
    from repro.dist import context as dctx
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    if kv != h:
        k = jnp.repeat(k, h // kv, axis=2)
        v = jnp.repeat(v, h // kv, axis=2)
    qt = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kt = k.transpose(0, 2, 1, 3).reshape(b * h, k.shape[1], k.shape[-1])
    vt = v.transpose(0, 2, 1, 3).reshape(b * h, v.shape[1], v.shape[-1])
    w = window if window is not None else 0
    bq, bk, interp = _flash_schedule(qt.dtype, b * h, sq, kt.shape[1], hd)

    mesh = dctx.get_mesh()
    if mesh is None:
        out = flash_attention(qt, kt, vt, w, causal, interp, bq, bk)
    else:
        # shard_map over the fused (B*H) dim: flash is embarrassingly parallel
        # there; each device runs the kernel on its local rows with ZERO
        # collectives (without this, the SPMD partitioner gathers q/k/v around
        # the interpret-mode kernel — §Perf starcoder2 iter-1 found 88TB of
        # wire traffic).
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        bh = b * h
        batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        ladder = [batch_axes + (("model",) if "model" in mesh.axis_names else ()),
                  batch_axes, ()]
        axis_size = dict(zip(mesh.axis_names, mesh.devices.shape))
        spec_axes = next(axes for axes in ladder
                         if bh % max(1, int(np.prod([axis_size[a] for a in axes]
                                                    or [1]))) == 0)
        sp = P(spec_axes if spec_axes else None, None, None)
        out = shard_map(
            lambda q_, k_, v_, w_: flash_attention(q_, k_, v_, w_, causal,
                                                   interp, bq, bk),
            mesh=mesh, in_specs=(sp, sp, sp, P()), out_specs=sp,
            check_rep=False,
        )(qt, kt, vt, jnp.asarray(w, jnp.int32))
    dv = out.shape[-1]   # MLA: value dim differs from q/k head dim
    return out.reshape(b, h, sq, dv).transpose(0, 2, 1, 3)


def _sdpa(q: Array, k: Array, v: Array, keep: Optional[Array]) -> Array:
    """q: (B,Sq,H,hd), k/v: (B,Sk,KV,hd) -> (B,Sq,H,hd). GQA via head groups."""
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    group = h // kv
    q = q.reshape(b, sq, kv, group, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                        preferred_element_type=jnp.float32) / (hd ** 0.5)
    if keep is not None:
        scores = jnp.where(keep[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs.astype(v.dtype), v)
    return out.reshape(b, sq, h, hd)


def gqa_apply(p: dict, x: Array, *, cfg: ModelConfig, positions: Array,
              window=0, rope_theta=None, causal: bool = True,
              cache: Optional[dict] = None, cache_pos: Optional[Array] = None,
              cache_write_mask: Optional[Array] = None,
              prefill: bool = False, page_table: Optional[Array] = None,
              paged_impl: str = "gather") -> Tuple[Array, Optional[dict]]:
    """Full/prefill when cache is None; single-step decode when cache given.

    cache = {"k": (B, S_max, KV, hd), "v": ...}; cache_pos: scalar int32 —
    the number of tokens already in the cache (q is written at that offset).
    cache_write_mask: optional (B,) bool — rows with False keep their cached
    K/V (bucketed prefill into a shared slot cache).

    When ``page_table`` (B, max_pages) is given the cache leaves are page
    POOLS (P, ps, KV, hd) shared across sequences; k/v rows scatter through
    the table and attention runs either over the gathered contiguous view
    (``paged_impl="gather"`` — bit-identical to the contiguous decode branch)
    or the in-kernel-gather Pallas path (``paged_impl="flash"``). The paged
    branch serves both decode and chunked prefill (chunk rows attend the
    full gathered cache, so chunk boundaries never change the math).
    """
    b, s, d = x.shape
    hd = cfg.hd
    theta = cfg.rope_theta if rope_theta is None else rope_theta
    q = L.dense(x, p["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = L.dense(x, p["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
    v = L.dense(x, p["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
    q = L.apply_rope(q, positions, theta)
    k = L.apply_rope(k, positions, theta)

    if cache is None:
        if cfg.attention_impl == "flash":
            out = _flash_sdpa(q, k, v, window, causal)
        else:
            keep = _mask(positions if positions.ndim == 2 else positions[None, :],
                         positions if positions.ndim == 2 else positions[None, :],
                         window, causal)
            if keep.ndim == 2:
                keep = keep[None]
            out = _sdpa(q, k, v, keep)
        new_cache = None
    elif page_table is not None:
        k_pool = _paged_write(cache["k"], k, page_table, cache_pos,
                              cache_write_mask)
        v_pool = _paged_write(cache["v"], v, page_table, cache_pos,
                              cache_write_mask)
        pos = jnp.broadcast_to(jnp.asarray(cache_pos, jnp.int32).reshape(-1),
                               (b,))
        if paged_impl == "flash":
            from repro.core.gemm import current_config
            from repro.kernels.flash_attention import flash_attention_paged
            out = flash_attention_paged(
                q.transpose(0, 2, 1, 3), k_pool, v_pool, page_table,
                pos + s, pos, window if window is not None else 0,
                causal=causal, interpret=current_config().interpret)
            out = out.transpose(0, 2, 1, 3)
        else:
            kg = _paged_view(k_pool, page_table)
            vg = _paged_view(v_pool, page_table)
            s_max = kg.shape[1]
            k_pos = jnp.arange(s_max, dtype=jnp.int32)
            valid = k_pos[None, :] < _cache_end(pos, s)
            q_pos = positions if positions.ndim == 2 else positions[None, :]
            keep = _mask(q_pos, k_pos[None, :], window, causal) \
                & valid[:, None, :]
            out = _sdpa(q, kg, vg, keep)
        new_cache = {"k": k_pool, "v": v_pool}
    elif prefill and cfg.attention_impl == "flash":
        # prefill into EMPTY cache rows: attention over the prompt == flash
        # self-attention; k/v written at offset 0 (32k cells never touch an
        # (S,S) score tensor this way — §Perf)
        k_cache = _cache_write(cache["k"], k, cache_pos, cache_write_mask)
        v_cache = _cache_write(cache["v"], v, cache_pos, cache_write_mask)
        out = _flash_sdpa(q, k, v, window, causal)
        new_cache = {"k": k_cache, "v": v_cache}
    else:
        # decode: write this step's k/v at cache_pos (per-slot rows when
        # cache_pos is a (B,) vector), attend over the cache
        k_cache = _cache_write(cache["k"], k, cache_pos, cache_write_mask)
        v_cache = _cache_write(cache["v"], v, cache_pos, cache_write_mask)
        s_max = k_cache.shape[1]
        k_pos = jnp.arange(s_max, dtype=jnp.int32)
        valid = k_pos[None, :] < _cache_end(cache_pos, s)
        q_pos = positions if positions.ndim == 2 else positions[None, :]
        keep = _mask(q_pos, k_pos[None, :], window, causal) & valid[:, None, :]
        out = _sdpa(q, k_cache, v_cache, keep)
        new_cache = {"k": k_cache, "v": v_cache}
    return L.dense(out.reshape(b, s, cfg.n_heads * hd), p["wo"]), new_cache


# --- MLA (DeepSeek-V2) ------------------------------------------------------

def mla_init(key, cfg: ModelConfig, dtype) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    return {
        "wq": L.dense_init(k1, d, h * (m.nope_head_dim + m.rope_head_dim), dtype),
        "w_dkv": L.dense_init(k2, d, m.kv_lora_rank, dtype),    # compress
        "w_kr": L.dense_init(k3, d, m.rope_head_dim, dtype),    # shared rope key
        "w_ukv": L.dense_init(k4, m.kv_lora_rank,
                              h * (m.nope_head_dim + m.v_head_dim), dtype),
        "kv_norm": L.rmsnorm_init(m.kv_lora_rank, dtype),
        "wo": L.dense_init(k5, h * m.v_head_dim, d, dtype),
    }


def _mla_kv(p, c_kv: Array, cfg: ModelConfig) -> Tuple[Array, Array]:
    m = cfg.mla
    b, s, _ = c_kv.shape
    kv = L.dense(c_kv, p["w_ukv"]).reshape(b, s, cfg.n_heads,
                                           m.nope_head_dim + m.v_head_dim)
    return kv[..., :m.nope_head_dim], kv[..., m.nope_head_dim:]


def mla_apply(p: dict, x: Array, *, cfg: ModelConfig, positions: Array,
              window=0, cache: Optional[dict] = None,
              cache_pos: Optional[Array] = None,
              cache_write_mask: Optional[Array] = None,
              prefill: bool = False, page_table: Optional[Array] = None,
              paged_impl: str = "gather") -> Tuple[Array, Optional[dict]]:
    """MLA: the KV cache stores only (c_kv, k_rope) — rank-512+64 per token.

    cache = {"c_kv": (B, S_max, r), "k_rope": (B, S_max, rope_hd)};
    cache_write_mask as in :func:`gqa_apply`. With ``page_table`` set the
    leaves are pools (P, ps, r) / (P, ps, rope_hd) and the absorbed decode
    runs over the gathered view (or, for ``paged_impl="flash"``, the paged
    kernel with k = concat(c, rope), v = c and the pre-absorption scale —
    the flashinfer paged-MLA layout).
    """
    m = cfg.mla
    b, s, d = x.shape
    h = cfg.n_heads
    q = L.dense(x, p["wq"]).reshape(b, s, h, m.nope_head_dim + m.rope_head_dim)
    q_nope, q_rope = q[..., :m.nope_head_dim], q[..., m.nope_head_dim:]
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)
    c_kv = L.rmsnorm(L.dense(x, p["w_dkv"]), p["kv_norm"], cfg.norm_eps)
    k_rope = L.apply_rope(L.dense(x, p["w_kr"])[:, :, None, :], positions,
                          cfg.rope_theta)  # (B,S,1,rope_hd)

    if page_table is None and (cache is None
                               or (prefill and cfg.attention_impl == "flash")):
        k_nope, v = _mla_kv(p, c_kv, cfg)
        kr = k_rope
        kv_positions = positions if positions.ndim == 2 else positions[None, :]
        q_positions = kv_positions
        valid = None
        new_cache = None
        if cache is not None:   # prefill: write compressed cache, flash attn
            new_cache = {
                "c_kv": _cache_write(cache["c_kv"], c_kv, cache_pos,
                                     cache_write_mask),
                "k_rope": _cache_write(cache["k_rope"], k_rope[:, :, 0, :],
                                       cache_pos, cache_write_mask),
            }
        if cfg.attention_impl == "flash":
            # PERF (§Perf deepseek iter-1): flash for MLA — concat nope+rope
            # into q'/k' (d=192) with dv=128 values; no (S,S) scores in HBM.
            q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
            k_full = jnp.concatenate(
                [k_nope, jnp.broadcast_to(kr, (*k_nope.shape[:3], m.rope_head_dim))],
                axis=-1)
            out = _flash_sdpa(q_full, k_full, v, 0, True)
            out = out.reshape(b, s, h * m.v_head_dim)
            return L.dense(out, p["wo"]), new_cache
    else:
        # PERF (§Perf beyond-paper, deepseek decode): ABSORBED MLA decode.
        # Instead of decompressing k/v for the whole cache per token
        # (S*H*(nope+v)*r flops + a (B,S,H,256) transient -> useful-flops
        # ratio 0.00 in the baseline roofline), absorb W_uk into the query
        # and W_uv into the context: attention runs entirely in the rank-r
        # latent space against the compressed cache.
        w_ukv = p["w_ukv"]["w"].reshape(m.kv_lora_rank, h,
                                        m.nope_head_dim + m.v_head_dim)
        w_uk = w_ukv[..., :m.nope_head_dim]            # (r, H, nope)
        w_uv = w_ukv[..., m.nope_head_dim:]            # (r, H, v)
        q_eff = jnp.einsum("bqhn,rhn->bqhr", q_nope, w_uk)   # absorbed query
        if page_table is not None:
            c_pool = _paged_write(cache["c_kv"], c_kv, page_table, cache_pos,
                                  cache_write_mask)
            r_pool = _paged_write(cache["k_rope"], k_rope[:, :, 0, :],
                                  page_table, cache_pos, cache_write_mask)
            new_cache = {"c_kv": c_pool, "k_rope": r_pool}
            pos = jnp.broadcast_to(
                jnp.asarray(cache_pos, jnp.int32).reshape(-1), (b,))
            if paged_impl == "flash":
                from repro.core.gemm import current_config
                from repro.kernels.flash_attention import flash_attention_paged
                q_cat = jnp.concatenate([q_eff, q_rope], axis=-1)
                k_cat = jnp.concatenate([c_pool, r_pool], -1)[:, :, None, :]
                ctx = flash_attention_paged(
                    q_cat.transpose(0, 2, 1, 3), k_cat,
                    c_pool[:, :, None, :], page_table, pos + s, pos, 0,
                    scale=1.0 / ((m.nope_head_dim + m.rope_head_dim) ** 0.5),
                    interpret=current_config().interpret)
                ctx = ctx.transpose(0, 2, 1, 3)        # (B, s, H, r)
                out = jnp.einsum("bqhr,rhv->bqhv", ctx, w_uv)
                out = out.reshape(b, s, h * m.v_head_dim)
                return L.dense(out, p["wo"]), new_cache
            c_cache = _paged_view(c_pool, page_table)
            r_cache = _paged_view(r_pool, page_table)
            cache_pos = pos
        else:
            c_cache = _cache_write(cache["c_kv"], c_kv, cache_pos,
                                   cache_write_mask)
            r_cache = _cache_write(cache["k_rope"], k_rope[:, :, 0, :],
                                   cache_pos, cache_write_mask)
            new_cache = {"c_kv": c_cache, "k_rope": r_cache}
        s_max = c_cache.shape[1]
        scores = (jnp.einsum("bqhr,bsr->bhqs", q_eff.astype(jnp.float32),
                             c_cache.astype(jnp.float32))
                  + jnp.einsum("bqhd,bsd->bhqs", q_rope.astype(jnp.float32),
                               r_cache.astype(jnp.float32)))
        scores = scores / ((m.nope_head_dim + m.rope_head_dim) ** 0.5)
        kv_positions = jnp.broadcast_to(
            jnp.arange(s_max, dtype=jnp.int32)[None], (b, s_max))
        q_positions = positions if positions.ndim == 2 else positions[None, :]
        keep = _mask(q_positions, kv_positions, window, True) \
            & (kv_positions < _cache_end(cache_pos, s))[:, None, :]
        scores = jnp.where(keep[:, None, :, :], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhqs,bsr->bqhr", probs.astype(c_cache.dtype), c_cache)
        out = jnp.einsum("bqhr,rhv->bqhv", ctx, w_uv)   # absorbed values
        out = out.reshape(b, s, h * m.v_head_dim)
        return L.dense(out, p["wo"]), new_cache

    scores = (jnp.einsum("bqhd,bshd->bhqs", q_nope, k_nope,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bqhd,bsxd->bhqs", q_rope,
                           jnp.broadcast_to(kr, (*kr.shape[:2], 1, kr.shape[-1])),
                           preferred_element_type=jnp.float32))
    scores = scores / ((m.nope_head_dim + m.rope_head_dim) ** 0.5)
    keep = _mask(q_positions, kv_positions, window, True)
    if valid is not None:
        keep = keep & valid[:, None, :]
    scores = jnp.where(keep[:, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", probs.astype(v.dtype), v)
    out = out.reshape(b, s, h * m.v_head_dim)
    return L.dense(out, p["wo"]), new_cache


# --- Cross-attention (whisper decoder) ---------------------------------------

def cross_init(key, cfg: ModelConfig, dtype) -> dict:
    d, hd = cfg.d_model, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": L.dense_init(k1, d, cfg.n_heads * hd, dtype),
        "wk": L.dense_init(k2, d, cfg.n_kv_heads * hd, dtype),
        "wv": L.dense_init(k3, d, cfg.n_kv_heads * hd, dtype),
        "wo": L.dense_init(k4, cfg.n_heads * hd, d, dtype),
    }


def cross_apply(p: dict, x: Array, enc: Array, cfg: ModelConfig) -> Array:
    """x: (B,S,d) queries over encoder states enc: (B,T,d). No mask."""
    b, s, d = x.shape
    hd = cfg.hd
    q = L.dense(x, p["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = L.dense(enc, p["wk"]).reshape(b, enc.shape[1], cfg.n_kv_heads, hd)
    v = L.dense(enc, p["wv"]).reshape(b, enc.shape[1], cfg.n_kv_heads, hd)
    out = _sdpa(q, k, v, None)
    return L.dense(out.reshape(b, s, cfg.n_heads * hd), p["wo"])
