"""`repro.tune` — kernel autotuning with a persistent device-keyed cache.

The paper's §5 design-space sweep shows the FFIP advantage is only realized
when the array tiling matches the hardware; our Pallas kernels used to ship
ONE hardcoded block shape for every GEMM on every backend. This subsystem
closes that gap:

  * :mod:`repro.tune.space`   — legal, deterministically ordered candidates;
  * :mod:`repro.tune.measure` — compile-outside-timed-region, median-of-k;
  * :mod:`repro.tune.cache`   — persistent JSON schedule cache keyed by
    ``(kernel, algo, dtype, shape-bucket, device_kind)`` + in-process LRU.

Consumers:
  * ``GemmConfig(block="auto")`` (core/gemm.py) resolves tuned ``(bm,bn,bk)``
    for the pallas fip/ffip/baseline providers via :func:`lookup_gemm_blocks`
    at trace time — lookups only, never measurement, falling back to the
    static defaults on a miss with a one-time log + ``stats`` counter;
  * flash attention (models/attention.py) resolves tuned ``(bq, bk)`` the
    same way via :func:`lookup_flash_blocks`;
  * ``python -m repro.launch.tune`` (the offline CLI) pre-populates the cache
    for a model config's / CNN workload's GEMM shape set via :func:`tune_gemm`
    / :func:`tune_flash`.

Shape bucketing: each dim rounds up to a power of two, so one measured
schedule serves every shape in its bucket — the same bucketing the serving
prefill path already uses for prompts.
"""
from __future__ import annotations

import logging
from typing import Dict, Optional, Tuple

import jax.numpy as jnp

from repro.kernels.compat import device_kind
from repro.tune import measure, space
from repro.tune.cache import ScheduleCache, get_cache, make_key

__all__ = [
    "ScheduleCache", "get_cache", "make_key", "device_kind",
    "gemm_key", "flash_key", "conv_key",
    "lookup_gemm_blocks", "lookup_flash_blocks", "lookup_conv_blocks",
    "tune_gemm", "tune_flash", "tune_conv", "stats", "reset_stats",
]

logger = logging.getLogger("repro.tune")

# hit/miss telemetry for the "auto" resolution path: a silent fallback to the
# hardcoded constant is exactly the failure mode this subsystem exists to
# remove, so misses are counted and logged (once per distinct key).
stats: Dict[str, int] = {"hits": 0, "misses": 0}
_warned_keys: set = set()


def reset_stats():
    stats["hits"] = 0
    stats["misses"] = 0
    _warned_keys.clear()


def _dtype_name(dtype) -> str:
    return jnp.dtype(dtype).name


def _bucket(*dims: int) -> Tuple[int, ...]:
    return tuple(space.round_up_pow2(d) for d in dims)


def gemm_key(algo: str, dtype, m: int, n: int, k: int, *,
             device: Optional[str] = None) -> str:
    mb, nb, kb = _bucket(m, n, k)
    return make_key("gemm", algo, _dtype_name(dtype), f"m{mb}n{nb}k{kb}",
                    device or device_kind())


def flash_key(dtype, bh: int, sq: int, sk: int, d: int, *,
              device: Optional[str] = None) -> str:
    bhb, sqb, skb = _bucket(bh, sq, sk)
    return make_key("flash_attention", "fwd", _dtype_name(dtype),
                    f"bh{bhb}sq{sqb}sk{skb}d{d}", device or device_kind())


def conv_key(algo: str, dtype, m: int, n: int, k: int, ckw: int, *,
             device: Optional[str] = None) -> str:
    """Schedule key for the fused implicit-im2col conv kernels. Buckets the
    per-image GEMM view (m = OH*OW, n = Cout/groups, k = KH*KW*Cin_g) like
    the GEMM keys, but keeps ``ckw`` = Cin_g*KW exact — it defines the
    bk-alignment structure of the candidate space, so shapes that bucket
    together but gather differently don't share a schedule."""
    mb, nb, kb = _bucket(m, n, k)
    return make_key("conv", algo, _dtype_name(dtype),
                    f"m{mb}n{nb}k{kb}ckw{ckw}", device or device_kind())


def _miss(key: str) -> None:
    stats["misses"] += 1
    if key not in _warned_keys:
        _warned_keys.add(key)
        logger.info(
            "no tuned schedule for %s; using static default blocks "
            "(pre-populate with `python -m repro.launch.tune`)", key)
    return None


# -- lookup (hot path: trace-time, never measures) --------------------------

def lookup_gemm_blocks(algo: str, dtype, m: int, n: int, k: int, *,
                       cache: Optional[ScheduleCache] = None,
                       ) -> Optional[Tuple[int, int, int]]:
    key = gemm_key(algo, dtype, m, n, k)
    entry = (cache if cache is not None else get_cache()).lookup(key)
    if entry is None:
        return _miss(key)
    stats["hits"] += 1
    b = entry["blocks"]
    return (b["bm"], b["bn"], b["bk"])


def lookup_flash_blocks(dtype, bh: int, sq: int, sk: int, d: int, *,
                        cache: Optional[ScheduleCache] = None,
                        ) -> Optional[Tuple[int, int]]:
    key = flash_key(dtype, bh, sq, sk, d)
    entry = (cache if cache is not None else get_cache()).lookup(key)
    if entry is None:
        return _miss(key)
    stats["hits"] += 1
    b = entry["blocks"]
    return (b["bq"], b["bk"])


def lookup_conv_blocks(algo: str, dtype, m: int, n: int, k: int, ckw: int, *,
                       cache: Optional[ScheduleCache] = None,
                       ) -> Optional[Tuple[int, int, int]]:
    key = conv_key(algo, dtype, m, n, k, ckw)
    entry = (cache if cache is not None else get_cache()).lookup(key)
    if entry is None:
        return _miss(key)
    stats["hits"] += 1
    b = entry["blocks"]
    return (b["bm"], b["bn"], b["bk"])


# -- offline tuning ---------------------------------------------------------

def tune_gemm(m: int, n: int, k: int, dtype, *, algo: str = "ffip",
              budget: int = 0, iters: int = 3,
              interpret: Optional[bool] = None,
              cache: Optional[ScheduleCache] = None,
              force: bool = False, persist: bool = True) -> dict:
    """Tune one GEMM shape bucket; returns (and persists) the cache entry.

    Measures at the BUCKET shape so the entry serves every member shape.
    ``budget`` limits how many candidates are tried (0 = all; the default
    candidate is always index 0 so even budget=1 is a valid, default-keeping
    run). A warm cache returns without any measurement unless ``force``.
    ``persist=False`` defers the file write (call ``cache.save()`` once at
    the end of a sweep — the CLI does this to avoid O(n^2) rewrites).
    """
    cache = cache if cache is not None else get_cache()
    key = gemm_key(algo, dtype, m, n, k)
    entry = None if force else cache.lookup(key)
    if entry is not None:
        return entry
    mb, nb, kb = _bucket(m, n, k)
    cands = space.gemm_candidates(mb, nb, kb, algo)
    if budget:
        cands = cands[:budget]
    best, best_t, trace = measure.best_gemm_blocks(
        algo, mb, kb, nb, dtype, cands, interpret=interpret, iters=iters)
    default_t = next((t["us"] for t in trace
                      if tuple(t["blocks"]) == cands[0] and "us" in t), None)
    entry = {
        "blocks": {"bm": best[0], "bn": best[1], "bk": best[2]},
        "us": round(best_t * 1e6, 1),
        "default_blocks": {"bm": cands[0][0], "bn": cands[0][1],
                           "bk": cands[0][2]},
        "default_us": default_t,
        "candidates": len(trace),
        "iters": iters,
    }
    cache.put(key, entry, persist=persist)
    logger.info("tuned %s -> %s (%.1fus over %d candidates)", key,
                entry["blocks"], entry["us"], entry["candidates"])
    return entry


def tune_conv(batch: int, h: int, w: int, cin: int, cout: int, kh: int,
              kw: int, dtype, *, stride=1, pad=0, groups: int = 1,
              algo: str = "ffip", budget: int = 0, iters: int = 3,
              interpret: Optional[bool] = None,
              cache: Optional[ScheduleCache] = None,
              force: bool = False, persist: bool = True) -> dict:
    """Tune one fused-conv geometry; same contract as :func:`tune_gemm`.

    Measures the fused implicit-im2col kernel at the REAL geometry (the
    gather pattern is part of the cost), keyed by the bucketed per-image GEMM
    view + the exact ``ckw`` alignment — shapes sharing a bucket reuse the
    first-measured member's schedule (the CLI dedupes by key before tuning).
    """
    from repro.core.im2col import as_pair, conv_out_hw
    cache = cache if cache is not None else get_cache()
    sh, sw = as_pair(stride)
    ph, pw = as_pair(pad)
    cin_g = cin // groups
    k = kh * kw * cin_g
    ckw = cin_g * kw
    oh, ow = conv_out_hw(h, w, kh, kw, (sh, sw), (ph, pw))
    m, n = oh * ow, cout // groups
    key = conv_key(algo, dtype, m, n, k, ckw)
    entry = None if force else cache.lookup(key)
    if entry is not None:
        return entry
    cands = space.conv_candidates(m, n, k, ckw, algo)
    if budget:
        cands = cands[:budget]
    best, best_t, trace = measure.best_conv_blocks(
        algo, batch, h, w, cin, kh, kw, cout, dtype, cands,
        stride=(sh, sw), pad=(ph, pw), groups=groups, interpret=interpret,
        iters=iters)
    default_t = next((t["us"] for t in trace
                      if tuple(t["blocks"]) == cands[0] and "us" in t), None)
    entry = {
        "blocks": {"bm": best[0], "bn": best[1], "bk": best[2]},
        "us": round(best_t * 1e6, 1),
        "default_blocks": {"bm": cands[0][0], "bn": cands[0][1],
                           "bk": cands[0][2]},
        "default_us": default_t,
        "candidates": len(trace),
        "iters": iters,
        "geometry": {"batch": batch, "h": h, "w": w, "cin": cin, "cout": cout,
                     "kh": kh, "kw": kw, "stride": [sh, sw], "pad": [ph, pw],
                     "groups": groups},
    }
    cache.put(key, entry, persist=persist)
    logger.info("tuned %s -> %s (%.1fus over %d candidates)", key,
                entry["blocks"], entry["us"], entry["candidates"])
    return entry


def tune_flash(bh: int, sq: int, sk: int, d: int, dtype=jnp.float32, *,
               budget: int = 0, iters: int = 3,
               interpret: Optional[bool] = None,
               cache: Optional[ScheduleCache] = None,
               force: bool = False, persist: bool = True) -> dict:
    """Tune one flash-attention forward shape bucket; same contract as
    :func:`tune_gemm`."""
    cache = cache if cache is not None else get_cache()
    key = flash_key(dtype, bh, sq, sk, d)
    entry = None if force else cache.lookup(key)
    if entry is not None:
        return entry
    bhb, sqb, skb = _bucket(bh, sq, sk)
    cands = space.flash_candidates(sqb, skb)
    if budget:
        cands = cands[:budget]
    best, best_t, trace = measure.best_flash_blocks(
        bhb, sqb, skb, d, dtype, cands, interpret=interpret, iters=iters)
    default_t = next((t["us"] for t in trace
                      if tuple(t["blocks"]) == cands[0] and "us" in t), None)
    entry = {
        "blocks": {"bq": best[0], "bk": best[1]},
        "us": round(best_t * 1e6, 1),
        "default_blocks": {"bq": cands[0][0], "bk": cands[0][1]},
        "default_us": default_t,
        "candidates": len(trace),
        "iters": iters,
    }
    cache.put(key, entry, persist=persist)
    logger.info("tuned %s -> %s (%.1fus over %d candidates)", key,
                entry["blocks"], entry["us"], entry["candidates"])
    return entry
