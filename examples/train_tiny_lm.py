"""End-to-end training driver: ~100M-param LM, resumable, fault-tolerant.

    PYTHONPATH=src python examples/train_tiny_lm.py --steps 300          # full
    PYTHONPATH=src python examples/train_tiny_lm.py --preset micro --steps 30

Demonstrates the whole substrate: synthetic data pipeline (prefetch thread),
AdamW + WSD schedule, chunked-CE loss, checkpoint/restart (kill it mid-run and
re-invoke — it resumes from the last checkpoint), straggler watchdog, and the
crash-restart wiring (--simulate-crash N aborts at step N; the next invocation
resumes)."""
import argparse
import dataclasses
import sys

import jax

from repro.configs.base import ModelConfig
from repro.models.model import build_model
from repro.optim.adamw import AdamWConfig
from repro.train.loop import LoopConfig, train
from repro.train.step import TrainConfig

PRESETS = {
    # ~100M params: 131k vocab x 512 emb (67M) + 6-layer/512-wide backbone
    "100m": ModelConfig(name="tiny-lm-100m", family="dense", n_layers=6,
                        d_model=512, n_heads=8, n_kv_heads=8, d_ff=1536,
                        vocab=131072, tie_embeddings=True,
                        param_dtype="float32"),
    "micro": ModelConfig(name="tiny-lm-micro", family="dense", n_layers=2,
                         d_model=128, n_heads=4, n_kv_heads=4, d_ff=384,
                         vocab=2048, tie_embeddings=True,
                         param_dtype="float32"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="100m", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_tiny_lm_ckpt")
    ap.add_argument("--simulate-crash", type=int, default=0)
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    model = build_model(cfg)
    n_params = cfg.param_count()
    print(f"model {cfg.name}: ~{n_params / 1e6:.0f}M params")

    crash_at = args.simulate_crash

    def log(m):
        print(f"step {m['data_step']:>5}  loss {m['loss']:.4f}  "
              f"lr {m['lr']:.2e}  |g| {m['grad_norm']:.3f}", flush=True)
        if crash_at and m["data_step"] >= crash_at:
            print("SIMULATED CRASH — rerun to resume from checkpoint")
            sys.exit(42)

    out = train(
        model,
        loop_cfg=LoopConfig(total_steps=args.steps, global_batch=args.batch,
                            seq_len=args.seq, ckpt_dir=args.ckpt_dir,
                            ckpt_every=25, log_every=5),
        train_cfg=TrainConfig(optimizer=AdamWConfig(
            lr=3e-4, schedule="wsd", warmup_steps=20,
            total_steps=args.steps, decay_frac=0.2)),
        log_fn=log,
    )
    losses = [h["loss"] for h in out["history"]]
    print(f"first logged loss {losses[0]:.4f} -> last {losses[-1]:.4f}")
    assert losses[-1] < losses[0], "loss should decrease"
    print("OK")


if __name__ == "__main__":
    main()
