"""Runnable CNN models built from the core.workloads conv-spec tables.

The paper's headline workloads (AlexNet / VGG-16 / ResNet-50, Fig. 9 and
Tables 1-3) exist in ``repro.core.workloads`` as structured ConvSpec tables;
this module turns the same tables into runnable JAX models: channels,
kernels, strides, pads and groups come FROM the tables, while spatial dims
recompute from the actual input so a smoke-sized image flows through the
identical topology (``width_div`` shrinks channel counts for CI smokes; the
FC input dim is shape-inferred, never hardcoded).

Every conv routes through :func:`repro.vision.layers.conv2d`, i.e. through
the ambient GemmConfig — ``use_gemm(GemmConfig(algo="ffip", impl="pallas",
block="auto", quantized=True))`` swaps the whole model onto the fused int8
implicit-im2col kernels with tuned schedules, no model changes.

Classic normalization layers are treated the way the deployment flow would:
LRN (AlexNet) is omitted, BN (ResNet) initializes pre-folded — the
:func:`repro.vision.layers.fold_bn` transform is exercised at the layer
level, and :func:`attach_quantized` quantizes whatever the folded weights
are.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import workloads
from repro.vision import layers as vl

Array = jax.Array


# ---------------------------------------------------------------------------
# Layer descriptors (static topology; params live in a parallel pytree)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Conv:
    name: str
    kh: int
    kw: int
    cin: int
    cout: int
    stride: Tuple[int, int] = (1, 1)
    pad: Tuple[int, int] = (0, 0)
    groups: int = 1
    relu: bool = True


@dataclasses.dataclass(frozen=True)
class MaxPool:
    size: Tuple[int, int] = (2, 2)
    stride: Tuple[int, int] = (2, 2)
    pad: Tuple[int, int] = (0, 0)


@dataclasses.dataclass(frozen=True)
class GlobalAvgPool:
    pass


@dataclasses.dataclass(frozen=True)
class Flatten:
    pass


@dataclasses.dataclass(frozen=True)
class FC:
    name: str
    cin: int
    cout: int
    relu: bool = False


@dataclasses.dataclass(frozen=True)
class Bottleneck:
    """ResNet bottleneck: c1 -> c2 -> c3 (+ optional projection shortcut),
    ReLU after the residual add."""
    name: str
    c1: Conv
    c2: Conv
    c3: Conv
    proj: Optional[Conv] = None


Layer = Union[Conv, MaxPool, GlobalAvgPool, Flatten, FC, Bottleneck]


def _conv_apply(x: Array, layer: Conv, p: dict) -> Array:
    out = vl.conv2d(x, p, stride=layer.stride, pad=layer.pad,
                    groups=layer.groups)
    return vl.relu(out) if layer.relu else out


def init_params(model: Sequence[Layer], key, dtype=jnp.float32) -> list:
    """One params entry per layer (None for parameterless layers)."""
    params: list = []
    for layer in model:
        if isinstance(layer, Conv):
            key, sub = jax.random.split(key)
            params.append(vl.conv_init(sub, layer.kh, layer.kw, layer.cin,
                                       layer.cout, groups=layer.groups,
                                       dtype=dtype))
        elif isinstance(layer, FC):
            from repro.models.layers import dense_init
            key, sub = jax.random.split(key)
            params.append(dense_init(sub, layer.cin, layer.cout, dtype,
                                     bias=True))
        elif isinstance(layer, Bottleneck):
            entry = {}
            for field in ("c1", "c2", "c3", "proj"):
                conv = getattr(layer, field)
                if conv is None:
                    continue
                key, sub = jax.random.split(key)
                entry[field] = vl.conv_init(sub, conv.kh, conv.kw, conv.cin,
                                            conv.cout, groups=conv.groups,
                                            dtype=dtype)
            params.append(entry)
        else:
            params.append(None)
    return params


def apply(model: Sequence[Layer], params: Sequence, x: Array) -> Array:
    """Forward pass: (B, H, W, Cin) image -> (B, num_classes) logits."""
    from repro.models.layers import dense
    for layer, p in zip(model, params):
        if isinstance(layer, Conv):
            x = _conv_apply(x, layer, p)
        elif isinstance(layer, MaxPool):
            x = vl.maxpool2d(x, size=layer.size, stride=layer.stride,
                             pad=layer.pad)
        elif isinstance(layer, GlobalAvgPool):
            x = vl.global_avgpool(x)
        elif isinstance(layer, Flatten):
            x = x.reshape(x.shape[0], -1)
        elif isinstance(layer, FC):
            x = dense(x, p)
            if layer.relu:
                x = vl.relu(x)
        elif isinstance(layer, Bottleneck):
            y = _conv_apply(x, layer.c1, p["c1"])
            y = _conv_apply(y, layer.c2, p["c2"])
            y = _conv_apply(y, layer.c3, p["c3"])
            sc = (_conv_apply(x, layer.proj, p["proj"])
                  if layer.proj is not None else x)
            x = vl.relu(y + sc)
        else:
            raise TypeError(f"unknown layer {layer!r}")
    return x


def attach_quantized(model: Sequence[Layer], params: Sequence,
                     dtype=jnp.int8) -> list:
    """Offline int8 preparation for a whole vision model — thin wrapper over
    :func:`repro.prepare.prepare_vision`, which owns the transform (BN fold +
    conv/FC quantization) and can serialize the result as an artifact."""
    from repro import prepare
    return prepare.prepare_vision(model, params, quantized=True,
                                  dtype=dtype).params


def conv_layers(model: Sequence[Layer]) -> List[Conv]:
    """All convs in the model, bottlenecks flattened (tuning / benches)."""
    convs: List[Conv] = []
    for layer in model:
        if isinstance(layer, Conv):
            convs.append(layer)
        elif isinstance(layer, Bottleneck):
            convs += [c for c in (layer.c1, layer.c2, layer.c3, layer.proj)
                      if c is not None]
    return convs


def conv_geometries(model: Sequence[Layer],
                    image_size: int) -> List[Tuple[Conv, int, int]]:
    """(conv, input_h, input_w) for every conv, tracking the spatial flow
    from ``image_size`` — the geometry set the conv tuner measures at."""
    out: List[Tuple[Conv, int, int]] = []
    h = w = image_size
    for layer in model:
        if isinstance(layer, Conv):
            out.append((layer, h, w))
        elif isinstance(layer, Bottleneck):
            bh, bw = h, w
            for conv in (layer.c1, layer.c2, layer.c3):
                out.append((conv, bh, bw))
                bh, bw = _spatial(conv, bh, bw)
            if layer.proj is not None:
                out.append((layer.proj, h, w))
        h, w = _spatial(layer, h, w)
    return out


# ---------------------------------------------------------------------------
# Builders from the workload tables
# ---------------------------------------------------------------------------

def _div_ch(c: int, div: int, groups: int = 1) -> int:
    """Shrink a channel count for smoke models, keeping it a positive
    multiple of 2*groups (grouped convs stay grouped, K stays evenizable)."""
    unit = 2 * groups
    return max(unit, (c // div) // unit * unit)


def _spatial(layer, h: int, w: int) -> Tuple[int, int]:
    from repro.core.im2col import conv_out_hw
    if isinstance(layer, Conv):
        return conv_out_hw(h, w, layer.kh, layer.kw, layer.stride, layer.pad)
    if isinstance(layer, MaxPool):
        return conv_out_hw(h, w, layer.size[0], layer.size[1], layer.stride,
                           layer.pad)
    if isinstance(layer, Bottleneck):
        for conv in (layer.c1, layer.c2, layer.c3):
            h, w = _spatial(conv, h, w)
        return h, w
    return h, w


def _conv_from_spec(spec: workloads.ConvSpec, cin: int, cout: int,
                    relu: bool = True) -> Conv:
    return Conv(spec.name, spec.kh, spec.kw, cin, cout, spec.stride,
                spec.pad, spec.groups, relu)


def build_alexnet(num_classes: int = 1000, image_size: int = 227,
                  width_div: int = 1) -> List[Layer]:
    """AlexNet from workloads.alexnet_convs() (grouped conv2/4/5; LRN
    omitted). Pools after conv1/conv2/conv5 as in the original."""
    specs = {s.name: s for s in workloads.alexnet_convs()}
    chans = {"in": 3}
    for name in ("conv1", "conv2", "conv3", "conv4", "conv5"):
        s = specs[name]
        chans[name] = _div_ch(s.cout, width_div, s.groups)
    model: List[Layer] = []
    cin = 3
    h = w = image_size
    for name in ("conv1", "conv2", "conv3", "conv4", "conv5"):
        s = specs[name]
        conv = _conv_from_spec(s, cin, chans[name])
        model.append(conv)
        h, w = _spatial(conv, h, w)
        cin = chans[name]
        if name in ("conv1", "conv2", "conv5") and min(h, w) >= 3:
            pool = MaxPool((3, 3), (2, 2))
            model.append(pool)
            h, w = _spatial(pool, h, w)
    model.append(Flatten())
    flat = h * w * cin
    fcs = workloads.ALEXNET_FCS
    d6 = _div_ch(fcs[0][2], width_div)
    d7 = _div_ch(fcs[1][2], width_div)
    model += [FC("fc6", flat, d6, relu=True), FC("fc7", d6, d7, relu=True),
              FC("fc8", d7, num_classes)]
    return model


def build_vgg16(num_classes: int = 1000, image_size: int = 224,
                width_div: int = 1) -> List[Layer]:
    """VGG-16 from workloads.VGG16_PLAN (3x3 pad-1 stacks + 2x2 pools)."""
    model: List[Layer] = []
    cin = 3
    h = w = image_size
    for cout, reps, _res in workloads.VGG16_PLAN:
        cd = _div_ch(cout, width_div)
        for _ in range(reps):
            conv = Conv(f"conv{len([l for l in model if isinstance(l, Conv)]) + 1}",
                        3, 3, cin, cd, pad=(1, 1))
            model.append(conv)
            h, w = _spatial(conv, h, w)
            cin = cd
        if min(h, w) >= 2:
            pool = MaxPool((2, 2), (2, 2))
            model.append(pool)
            h, w = _spatial(pool, h, w)
    model.append(Flatten())
    flat = h * w * cin
    d1 = _div_ch(workloads.VGG16_FCS[0][2], width_div)
    d2 = _div_ch(workloads.VGG16_FCS[1][2], width_div)
    model += [FC("fc1", flat, d1, relu=True), FC("fc2", d1, d2, relu=True),
              FC("fc3", d2, num_classes)]
    return model


def build_resnet50(num_classes: int = 1000, image_size: int = 224,
                   width_div: int = 1) -> List[Layer]:
    """ResNet-50 from workloads.resnet_blocks (bottlenecks with projection
    shortcuts; BN pre-folded into the convs — see module docstring)."""
    stem_spec = workloads.RESNET_STEM
    c_stem = _div_ch(stem_spec.cout, width_div)
    model: List[Layer] = [
        _conv_from_spec(stem_spec, 3, c_stem),
        MaxPool((3, 3), (2, 2), pad=(1, 1)),
    ]
    cin = c_stem
    for blk in workloads.resnet_blocks(workloads.RESNET_STAGES["resnet50"]):
        width = _div_ch(blk.width, width_div)
        cout = _div_ch(blk.cout, width_div)
        st = (blk.stride, blk.stride)
        c1 = Conv(f"{blk.name}.c1", 1, 1, cin, width, stride=st)
        c2 = Conv(f"{blk.name}.c2", 3, 3, width, width, pad=(1, 1))
        c3 = Conv(f"{blk.name}.c3", 1, 1, width, cout, relu=False)
        proj = (Conv(f"{blk.name}.proj", 1, 1, cin, cout, stride=st,
                     relu=False)
                if (cin != cout or blk.stride != 1) else None)
        model.append(Bottleneck(blk.name, c1, c2, c3, proj))
        cin = cout
    model += [GlobalAvgPool(), FC("fc", cin, num_classes)]
    return model


BUILDERS = {
    "alexnet": build_alexnet,
    "vgg16": build_vgg16,
    "resnet50": build_resnet50,
}


def build(name: str, *, num_classes: int = 1000, image_size: int = 0,
          width_div: int = 1) -> List[Layer]:
    if name not in BUILDERS:
        raise ValueError(f"unknown vision model {name!r}; "
                         f"have {sorted(BUILDERS)}")
    default_size = 227 if name == "alexnet" else 224
    return BUILDERS[name](num_classes=num_classes,
                          image_size=image_size or default_size,
                          width_div=width_div)
