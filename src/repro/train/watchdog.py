"""Straggler mitigation + failure handling for the training loop.

On a real multi-host deployment this wraps per-host heartbeats; here the same
logic runs against observed step times so it is fully unit-testable:

  * EMA step-time tracker; a step > ``threshold`` x EMA flags a straggler;
  * K consecutive straggler flags trigger the mitigation callback (in
    production: demote the host / re-shard its data / trigger elastic
    down-scale via ckpt restore on a smaller mesh);
  * a dead-man timer raises if no step completes within ``hang_timeout`` —
    the launcher catches it, restores the latest checkpoint and relaunches
    (see examples/train_tiny_lm.py for the restart wiring).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional


@dataclasses.dataclass
class WatchdogConfig:
    ema_decay: float = 0.9
    threshold: float = 2.5          # x EMA = straggler
    consecutive_to_act: int = 3
    hang_timeout_s: float = 600.0


class StepWatchdog:
    def __init__(self, cfg: WatchdogConfig = WatchdogConfig(),
                 on_straggler: Optional[Callable[[int, float, float], None]] = None):
        self.cfg = cfg
        self.ema: Optional[float] = None
        self.flags = 0
        self.events: List[dict] = []
        self.on_straggler = on_straggler
        self._last_tick = time.monotonic()

    def observe(self, step: int, dt: float) -> bool:
        """Feed one step duration; returns True if mitigation fired."""
        self._last_tick = time.monotonic()
        fired = False
        if self.ema is None:
            self.ema = dt
        else:
            if dt > self.cfg.threshold * self.ema:
                self.flags += 1
                self.events.append(dict(step=step, dt=dt, ema=self.ema))
                if self.flags >= self.cfg.consecutive_to_act:
                    fired = True
                    self.flags = 0
                    if self.on_straggler is not None:
                        self.on_straggler(step, dt, self.ema)
            else:
                self.flags = 0
            # EMA excludes outliers so one straggler does not poison the baseline
            if dt <= self.cfg.threshold * self.ema:
                self.ema = (self.cfg.ema_decay * self.ema
                            + (1 - self.cfg.ema_decay) * dt)
        return fired

    def check_hang(self) -> None:
        if time.monotonic() - self._last_tick > self.cfg.hang_timeout_s:
            raise TimeoutError(
                f"no training step for >{self.cfg.hang_timeout_s}s — "
                "launcher should restore the latest checkpoint and relaunch")
