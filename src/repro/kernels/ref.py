"""Pure-jnp oracles for the Pallas kernels. Thin re-exports of core.fip so the
kernel tests have a single oracle import point (per-kernel allclose sweeps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import fip

Array = jax.Array


def matmul_ref(a: Array, b: Array, algo: str = "baseline") -> Array:
    """Oracle GEMM in the accumulation dtype (f32 / int32)."""
    if algo == "baseline":
        return fip.baseline_matmul(a, b)
    if algo == "fip":
        return fip.fip_matmul(a, b)
    if algo == "ffip":
        return fip.ffip_matmul(a, b)
    raise ValueError(algo)


def ffip_scan_ref(a: Array, b: Array) -> Array:
    """Dataflow-faithful FFIP oracle (explicit Eq. 8c column recurrence)."""
    y = fip.make_y(b)
    beta = fip.fip_beta(b)
    return fip.ffip_matmul_scan(a, y, beta=beta)
