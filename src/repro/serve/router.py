"""Fault-tolerant load-aware router over N data-parallel BatchServer replicas.

The ROADMAP's multi-replica front end: N independent :class:`BatchServer`
replicas (each optionally ``mesh=`` tensor-parallel and/or ``quantized=True``
int8-FFIP) behind one router that owns admission, placement, deadlines,
retries, and replica health — the piece that keeps the FFIP serving stack UP
when a replica stalls, crashes, exhausts its page pool, or returns garbage.

**Lifecycle.** Every request is a :class:`~repro.serve.lifecycle.RequestRecord`
moving QUEUED -> ADMITTED -> (PREFILLING ->) DECODING -> DONE / FAILED /
TIMED_OUT. Terminal states are final: a late or duplicate completion of a
retried request is dropped (counted, never re-emitted).

**Load-aware dispatch.** A request leaves the router queue only when some
healthy replica has a free slot AND (paged) enough page-pool headroom for its
worst-case reservation; among candidates the one with the fewest outstanding
cache rows wins. Admission control is a bounded queue — past ``max_queue``
the submit raises :class:`RejectedError` with a ``retry_after_s`` hint
(backpressure instead of unbounded memory).

**Graceful degradation.** In a mixed fleet, float replicas are preferred;
under pressure (router queue at ``shed_queue_depth``, or float replicas out
of headroom) requests are SHED to int8-FFIP replicas first — the paper's
half-the-MACs quantized path used as a live capacity lever — and only
rejected when even that capacity is gone.

**Failure handling.** A replica step that raises or overruns the step
timeout fails ALL its in-flight requests over: each is aborted on the
replica (pages released, reservation ledger drained, cached result dropped)
and re-queued with bounded retries + exponential backoff + jitter
(deterministic under an injected clock/rng). ``breaker_threshold``
consecutive failures quarantine the replica (outstanding work drains to the
queue); after an exponentially growing cool-down it gets ONE probe request —
success re-admits it, failure re-quarantines. Every completion passes the
cheap output-sanity check before being exposed; a poisoned batch is
discarded and retried elsewhere. Requests decode from scratch on retry, so a
completed request's tokens are identical to a no-fault run (greedy decode is
deterministic and batch-composition-independent — the bit-identity contract
the serve tests already prove).

The drive loop feeds the shared :class:`repro.watchdog.Watchdog` (the same
EMA/dead-man logic as the train loop) with per-tick durations; hang faults
show up as straggler events and wedged external drivers trip the dead-man.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

import repro.obs as obs
from repro.obs.slo import AlertState, Objective, SloMonitor
from repro.obs.trace import Tracer
from repro.serve import lifecycle as lc
from repro.serve.batcher import BatchServer, Request
from repro.serve.faults import FaultPlan, FaultSpec, InjectedFault
from repro.watchdog import Watchdog, WatchdogConfig

HEALTHY, PROBING, QUARANTINED = "healthy", "probing", "quarantined"

# degradation-controller states (distinct from per-replica health above):
# healthy -> degraded (WARN: shed to int8) -> tightened (PAGE: shed +
# shrunken admission) -> probing (burn cleared, on probation) -> healthy
CTL_HEALTHY, CTL_PROBING = "healthy", "probing"
CTL_DEGRADED, CTL_TIGHTENED = "degraded", "tightened"
_CTL_LEVEL = {CTL_HEALTHY: 0, CTL_PROBING: 1,
              CTL_DEGRADED: 2, CTL_TIGHTENED: 3}
_REPLICA_LEVEL = {HEALTHY: 0, PROBING: 1, QUARANTINED: 2}


@dataclasses.dataclass
class RouterConfig:
    max_queue: int = 64             # admission control: bounded router queue
    max_retries: int = 2            # retries per request beyond attempt 0
    backoff_base_s: float = 0.05    # exponential backoff base
    backoff_jitter: float = 0.5     # x rng.random() multiplier on top
    step_timeout_s: float = 30.0    # one replica dispatch > this == hang
    default_deadline_s: Optional[float] = None   # per-request e2e deadline
    # optional per-phase timeouts keyed by lifecycle value
    # ("queued"/"admitted"/"prefilling"/"decoding")
    phase_timeouts_s: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    breaker_threshold: int = 3      # consecutive failures -> quarantine
    quarantine_s: float = 1.0       # doubles per consecutive quarantine
    shed_queue_depth: int = 4       # queue depth counting as "pressure"
    tick_s: float = 0.01            # fake-clock advance per drive tick
    # -- SLO-driven degradation controller (None == controller off; the
    # shed_queue_depth comparison above is then the only pressure signal,
    # and stays in force as a FLOOR when the controller is on) ------------
    objectives: Optional[Sequence[Objective]] = None
    tighten_factor: int = 4         # PAGE: max_queue // this admission bound
    probe_s: float = 0.5            # probation after the burn clears


class _Replica:
    """Router-side handle: health state + outstanding work for one server."""

    def __init__(self, idx: int, server: BatchServer, params):
        self.idx = idx
        self.server = server
        self.params = params
        self.tier = "int8" if server.quantized else "float"
        self.state = HEALTHY
        self.consec_failures = 0
        self.quarantine_count = 0
        self.quarantined_until = 0.0
        self.outstanding: Dict[int, lc.RequestRecord] = {}
        self.dispatches = 0         # fault-plan step index
        self.held_pages: List[int] = []   # exhaust-fault allocator refs


class ReplicaRouter:
    def __init__(self, servers: Sequence[BatchServer], params, *,
                 cfg: Optional[RouterConfig] = None,
                 fault_plan: Optional[FaultPlan] = None,
                 clock=None, rng=None,
                 watchdog_cfg: Optional[WatchdogConfig] = None,
                 registry=None, tracer=None):
        if not servers:
            raise ValueError("need at least one replica")
        self.cfg = cfg or RouterConfig()
        self.clock = clock
        self._fake = hasattr(clock, "advance")
        self.plan = fault_plan
        if self.plan is not None and self.plan.has_hangs and not self._fake:
            raise ValueError(
                "hang faults need an injected FakeClock (a real hang cannot "
                "be interrupted deterministically)")
        seed = self.plan.seed if self.plan is not None else 0
        self.rng = rng if rng is not None else np.random.default_rng(seed)
        self.replicas = [_Replica(i, s, params)
                         for i, s in enumerate(servers)]
        self._mixed = len({r.tier for r in self.replicas}) > 1
        self.records: Dict[int, lc.RequestRecord] = {}
        self._rq: "collections.deque[int]" = collections.deque()
        self.ticks = 0
        self.events: List[Tuple] = []
        self.stats: Dict[str, int] = {
            "submitted": 0, "dedup_submits": 0, "rejected": 0,
            "dispatched": 0, "completed": 0, "failed": 0, "timed_out": 0,
            "retries": 0, "replica_failures": 0, "poisoned": 0,
            "shed_to_quantized": 0, "quarantines": 0, "probes": 0,
            "probe_successes": 0, "duplicate_emissions_dropped": 0,
        }
        # -- observability --------------------------------------------------
        # One tracer for the whole fleet: the router owns the per-rid root
        # "request" span and the lifecycle phase spans under it; the replicas
        # share the SAME tracer (and the router's clock), so their dispatch
        # spans land in the same ring with the same timebase and
        # span_tree(rid) reconstructs the full journey.
        self.registry = (registry if registry is not None
                         else obs.get_registry())
        self.tracer = tracer if tracer is not None else Tracer(
            clock=self._now)
        self._spans: Dict[int, Dict[str, Any]] = {}
        self._m_events = self.registry.counter(
            "router_events_total", "router lifecycle / fault events",
            ("kind",))
        self._m_queue_depth = self.registry.gauge(
            "router_queue_depth", "non-terminal requests in the router queue")
        self._m_e2e = self.registry.histogram(
            "router_request_e2e_seconds",
            "submit -> DONE on the router clock")
        for i, s in enumerate(servers):
            s.tracer = self.tracer
            s.trace_requests = False     # router owns the root request span
            s.set_obs_labels({"replica": str(i)})
        # -- SLO degradation controller -------------------------------------
        self.slo: Optional[SloMonitor] = None
        if self.cfg.objectives:
            self.slo = SloMonitor(list(self.cfg.objectives),
                                  registry=self.registry,
                                  tracer=self.tracer, clock=self._now)
        self.ctl_state = CTL_HEALTHY
        self._probe_until = 0.0
        win = max((o.slow_window_s for o in (self.cfg.objectives or ())),
                  default=30.0)
        self._w_ttft = self.registry.windowed_histogram(
            "router_ttft_ms_window",
            "router-level TTFT (ms; includes queueing and retries)",
            ("replica", "tier"), window_s=win, clock=self._now)
        self._m_ctl = self.registry.counter(
            "router_controller_total", "degradation-controller decisions",
            ("action",))
        self._g_ctl = self.registry.gauge(
            "router_controller_state",
            "0=healthy 1=probing 2=degraded 3=tightened")
        self._g_admit = self.registry.gauge(
            "router_admission_limit", "effective router queue bound")
        self._g_admit.set(self.admission_limit())
        self._g_replica = self.registry.gauge(
            "router_replica_state", "0=healthy 1=probing 2=quarantined",
            ("replica",))
        for r in self.replicas:
            self._g_replica.labels(replica=str(r.idx)).set(0)
        self.dog = Watchdog(
            watchdog_cfg or WatchdogConfig(), clock=self._now,
            registry=self.registry, loop="serve",
            on_straggler=lambda step, dt, ema: self.events.append(
                ("straggler_tick", step, dt, ema)))

    # -- time --------------------------------------------------------------
    def _now(self) -> float:
        return self.clock() if self.clock is not None else obs.default_clock()

    # -- observability helpers ---------------------------------------------
    def _bump(self, kind: str, n: int = 1) -> None:
        """stats dict (legacy surface) + obs counter mirror, one call."""
        self.stats[kind] = self.stats.get(kind, 0) + n
        self._m_events.labels(kind=kind).inc(n)

    def _root_sid(self, rid: int) -> Optional[int]:
        entry = self._spans.get(rid)
        root = entry.get("root") if entry else None
        return root.sid if root is not None else None

    def _on_transition(self, rec: lc.RequestRecord, state: lc.Lifecycle,
                       t: float) -> None:
        """Lifecycle observer: phase spans mirror the state machine — each
        non-terminal state is an open child span of the rid's root request
        span; a terminal state closes both."""
        rid = rec.req.rid
        entry = self._spans.get(rid)
        if entry is None:
            return
        phase = entry.pop("phase", None)
        if phase is not None:
            self.tracer.end(phase)
        if state in lc.TERMINAL:
            root = entry.pop("root", None)
            if root is not None:
                self.tracer.end(
                    root, outcome=state.value, attempts=rec.attempts,
                    tier=rec.tier,
                    error=(type(rec.error).__name__ if rec.error else None))
            self._spans.pop(rid, None)
            if state == lc.Lifecycle.DONE:
                self._m_e2e.observe(t - rec.t_submit)
        else:
            entry["phase"] = self.tracer.start(
                state.value, parent=self._root_sid(rid), rid=str(rid),
                replica=rec.replica, attempt=rec.attempts)

    # -- submission / admission control ------------------------------------
    def _fits_anywhere(self, req: Request) -> bool:
        return any(self._fits(r, req) for r in self.replicas)

    @staticmethod
    def _fits(r: _Replica, req: Request) -> bool:
        rows = BatchServer.cache_rows(len(req.prompt), req.max_new_tokens)
        if rows > r.server.max_len:
            return False
        if r.server.paged:
            return -(-rows // r.server.page_size) <= r.server.num_pages
        return True

    def submit(self, req: Request, *,
               deadline_s: Optional[float] = None) -> lc.RequestRecord:
        """Queue a request; returns its lifecycle record. Idempotent in the
        request id: resubmitting a rid returns the EXISTING record (with its
        cached tokens if already DONE) instead of decoding twice. Raises
        :class:`AdmissionImpossibleError` if no replica could ever hold the
        request, :class:`RejectedError` when the bounded queue is full."""
        now = self._now()
        rec = self.records.get(req.rid)
        if rec is not None:
            if BatchServer._req_key(rec.req) != BatchServer._req_key(req):
                raise lc.AdmissionImpossibleError(
                    f"rid {req.rid} resubmitted with a different "
                    f"prompt/budget")
            self._bump("dedup_submits")
            return rec
        if not self._fits_anywhere(req):
            raise lc.AdmissionImpossibleError(
                f"request {req.rid}: no replica can ever admit it "
                f"(prompt {len(req.prompt)} + max_new {req.max_new_tokens} "
                f"exceeds every replica's cache/pool)")
        depth = sum(1 for rid in self._rq
                    if not self.records[rid].terminal)
        limit = self.admission_limit()
        if depth >= limit:
            self._bump("rejected")
            tightened = "" if limit == self.cfg.max_queue else \
                f", tightened from {self.cfg.max_queue} by the " \
                f"degradation controller"
            raise lc.RejectedError(
                f"router queue full ({depth}/{limit}{tightened})",
                retry_after_s=self.cfg.backoff_base_s * (1 + depth))
        d = deadline_s if deadline_s is not None \
            else self.cfg.default_deadline_s
        rec = lc.RequestRecord(req=req, t_submit=now,
                               deadline=None if d is None else now + d)
        rec.history.append((lc.Lifecycle.QUEUED.value, now))
        rec.observer = self._on_transition
        root = self.tracer.start("request", rid=str(req.rid),
                                 prompt=len(req.prompt),
                                 max_new_tokens=req.max_new_tokens)
        self._spans[req.rid] = {
            "root": root,
            "phase": self.tracer.start("queued", parent=root.sid,
                                       rid=str(req.rid), attempt=0),
        }
        self.records[req.rid] = rec
        self._rq.append(req.rid)
        self._bump("submitted")
        return rec

    # -- drive loop --------------------------------------------------------
    def step(self) -> bool:
        """One drive tick: expire deadlines, revive quarantined replicas,
        dispatch queued work load-aware, run every replica that holds work
        (under fault injection when a plan is installed), collect + sanity-
        check completions. Returns True while any work remains."""
        self.ticks += 1
        if self._fake:
            self.clock.advance(self.cfg.tick_s)
        t0 = self._now()
        self._expire(t0)
        self._revive(t0)
        self._controller_tick(t0)
        self._dispatch(t0)
        for r in self.replicas:
            if r.state == QUARANTINED or not r.outstanding:
                continue
            self._drive_replica(r)
        self.dog.observe(self.ticks, self._now() - t0)
        self._m_queue_depth.set(
            sum(1 for rid in self._rq if not self.records[rid].terminal))
        return bool(self._rq) or any(r.outstanding for r in self.replicas)

    def drive(self, *, max_ticks: int = 10_000) -> Dict[int, lc.RequestRecord]:
        """Step until every record is terminal; raises
        :class:`ServeStallError` (listing the stuck requests) if the tick
        budget runs out first."""
        ticks = 0
        while any(not rec.terminal for rec in self.records.values()):
            if ticks >= max_ticks:
                stuck = {rid: f"{rec.state.value} (replica {rec.replica}, "
                              f"attempt {rec.attempts})"
                         for rid, rec in self.records.items()
                         if not rec.terminal}
                raise lc.ServeStallError(
                    f"router.drive hit max_ticks={max_ticks} with "
                    f"{len(stuck)} request(s) still live", stuck=stuck)
            self.step()
            self.dog.check_hang()
            ticks += 1
        return self.records

    # -- deadlines / phase timeouts ----------------------------------------
    def _expire(self, now: float):
        for rec in self.records.values():
            if rec.terminal:
                continue
            why = None
            if rec.deadline is not None and now > rec.deadline:
                why = f"request {rec.req.rid} exceeded its deadline"
            else:
                pt = self.cfg.phase_timeouts_s.get(rec.state.value)
                if pt is not None and now - rec.phase_entered > pt:
                    why = (f"request {rec.req.rid} spent "
                           f">{pt:.3f}s in {rec.state.value}")
            if why is None:
                continue
            if rec.replica is not None:
                r = self.replicas[rec.replica]
                r.server.abort(rec.req.rid)
                r.outstanding.pop(rec.req.rid, None)
            rec.error = lc.DeadlineExceededError(why, phase=rec.state.value)
            rec.transition(lc.Lifecycle.TIMED_OUT, now)
            self._bump("timed_out")
            if self.slo is not None:
                self.slo.observe_event("error_rate", False)
            self.events.append(("timed_out", rec.req.rid, rec.state.value))

    # -- health ------------------------------------------------------------
    def _revive(self, now: float):
        for r in self.replicas:
            if r.state == QUARANTINED and now >= r.quarantined_until:
                r.state = PROBING
                r.consec_failures = 0
                self._bump("probes")
                self.events.append(("probe", r.idx, self.ticks))

    def _quarantine(self, r: _Replica, cause: BaseException):
        r.quarantine_count += 1
        cool = self.cfg.quarantine_s * (2 ** (r.quarantine_count - 1))
        r.state = QUARANTINED
        r.quarantined_until = self._now() + cool
        self._bump("quarantines")
        self.events.append(("quarantine", r.idx, self.ticks, cool))
        # drain: every request still on the replica goes back to the queue
        err = lc.ReplicaFailedError(
            f"replica {r.idx} quarantined for {cool:.3f}s",
            replica=r.idx, cause=cause)
        for rid in list(r.outstanding):
            rec = r.outstanding.pop(rid)
            r.server.abort(rid)
            self._retry(rec, err)

    def _after_failure(self, r: _Replica, cause: BaseException):
        if r.state == PROBING or \
                r.consec_failures >= self.cfg.breaker_threshold:
            self._quarantine(r, cause)

    # -- retry path --------------------------------------------------------
    def _retry(self, rec: lc.RequestRecord, err: BaseException):
        if rec.terminal:
            return
        now = self._now()
        rec.replica = None
        rec.last_error = err
        if rec.attempts >= self.cfg.max_retries:
            rec.error = lc.RetriesExhaustedError(
                f"request {rec.req.rid} gave up",
                attempts=rec.attempts + 1, cause=err)
            rec.transition(lc.Lifecycle.FAILED, now)
            self._bump("failed")
            if self.slo is not None:
                self.slo.observe_event("error_rate", False)
            return
        rec.attempts += 1
        self._bump("retries")
        backoff = self.cfg.backoff_base_s * (2 ** (rec.attempts - 1))
        backoff *= 1.0 + self.cfg.backoff_jitter * float(self.rng.random())
        rec.next_eligible = now + backoff
        self.tracer.event("retry", parent=self._root_sid(rec.req.rid),
                          rid=str(rec.req.rid), attempt=rec.attempts,
                          error=type(err).__name__, backoff_s=backoff)
        rec.transition(lc.Lifecycle.QUEUED, now)
        self._rq.append(rec.req.rid)
        self.events.append(("retry", rec.req.rid, rec.attempts,
                            type(err).__name__))

    # -- SLO degradation controller ----------------------------------------
    def admission_limit(self) -> int:
        """The effective queue bound: ``max_queue`` normally, shrunk by
        ``tighten_factor`` while the controller is TIGHTENED (PAGE-level
        burn). Never below 1."""
        if self.ctl_state == CTL_TIGHTENED:
            return max(1, self.cfg.max_queue // self.cfg.tighten_factor)
        return self.cfg.max_queue

    def _ctl_move(self, to: str, action: str, alert: AlertState,
                  now: float) -> None:
        frm, self.ctl_state = self.ctl_state, to
        self._m_ctl.labels(action=action).inc()
        self._g_ctl.set(_CTL_LEVEL[to])
        self._g_admit.set(self.admission_limit())
        self.events.append(("controller", action, frm, to))
        self.tracer.event("controller", action=action, frm=frm, to=to,
                          alert=alert.name)

    def _controller_tick(self, now: float) -> None:
        """Evaluate the SLOs and advance the degradation ladder. Escalation
        is immediate; the way back down runs through the SLO trackers'
        ``clear_s`` hysteresis plus a ``probe_s`` probation window, so one
        good tick never flaps the fleet back to full admission."""
        for r in self.replicas:
            self._g_replica.labels(replica=str(r.idx)).set(
                _REPLICA_LEVEL[r.state])
        if self.slo is None:
            return
        alert = self.slo.evaluate(now)
        st = self.ctl_state
        if alert == AlertState.PAGE:
            if st != CTL_TIGHTENED:
                self._ctl_move(CTL_TIGHTENED, "tighten", alert, now)
        elif alert == AlertState.WARN:
            if st == CTL_TIGHTENED:
                self._ctl_move(CTL_DEGRADED, "relax", alert, now)
            elif st != CTL_DEGRADED:
                self._ctl_move(CTL_DEGRADED, "degrade", alert, now)
        else:  # AlertState.OK
            if st in (CTL_DEGRADED, CTL_TIGHTENED):
                self._probe_until = now + self.cfg.probe_s
                self._ctl_move(CTL_PROBING, "probe", alert, now)
            elif st == CTL_PROBING and now >= self._probe_until:
                self._ctl_move(CTL_HEALTHY, "recover", alert, now)

    # -- dispatch ----------------------------------------------------------
    def _dispatch(self, now: float):
        # burn-driven shed, with the static queue-depth knob as a floor
        pressure = (len(self._rq) >= self.cfg.shed_queue_depth
                    or self.ctl_state in (CTL_DEGRADED, CTL_TIGHTENED))
        held: List[int] = []
        while self._rq:
            rid = self._rq.popleft()
            rec = self.records[rid]
            if rec.terminal:
                continue
            if rec.next_eligible > now:
                held.append(rid)
                continue
            r = self._pick(rec, pressure)
            if r is None:
                held.append(rid)
                continue
            creq = Request(rid=rid, prompt=rec.req.prompt,
                           max_new_tokens=rec.req.max_new_tokens,
                           eos_id=rec.req.eos_id)
            r.server.submit(creq)
            rec.replica = r.idx
            rec.transition(lc.Lifecycle.ADMITTED, now)
            r.outstanding[rid] = rec
            self._bump("dispatched")
        self._rq.extend(held)

    def _pick(self, rec: lc.RequestRecord,
              pressure: bool) -> Optional[_Replica]:
        cands = []
        rows = BatchServer.cache_rows(len(rec.req.prompt),
                                      rec.req.max_new_tokens)
        for r in self.replicas:
            if r.state == QUARANTINED:
                continue
            if r.state == PROBING and r.outstanding:
                continue          # a probing replica gets ONE probe at a time
            if not self._fits(r, rec.req):
                continue
            # cap in-flight work at the slot count: backlog stays in the
            # ROUTER queue (shedable, observable, timeout-able) instead of
            # piling invisibly in replica-internal queues
            if len(r.outstanding) >= r.server.b or \
                    r.server.free_slots() == 0:
                continue
            if r.server.paged:
                pages = -(-rows // r.server.page_size)
                if r.server.page_headroom() < pages:
                    continue
            cands.append(r)
        if not cands:
            return None
        floats = [c for c in cands if c.tier == "float"]
        quants = [c for c in cands if c.tier == "int8"]
        if pressure and quants:
            pool = quants          # shed to half-the-MACs capacity first
        elif floats:
            pool = floats
        else:
            pool = cands
        best = min(pool, key=lambda r: (r.server.outstanding_rows(), r.idx))
        if self._mixed and best.tier == "int8":
            self._bump("shed_to_quantized")
            self.events.append(("shed", rec.req.rid, best.idx))
        return best

    # -- replica execution under fault injection ---------------------------
    def _apply_exhaust(self, r: _Replica, active: List[FaultSpec]):
        """Enter/leave the pool-exhaustion window: seize every free page
        with real allocator references (so mid-flight allocations hit
        genuine exhaustion) and release them when the window closes."""
        want = any(f.kind == "exhaust" for f in active)
        if want and r.server.paged and not r.held_pages:
            while r.server.alloc.free_count:
                r.held_pages.append(r.server.alloc.alloc())
            self.events.append(("exhaust_begin", r.idx,
                                len(r.held_pages)))
        elif not want and r.held_pages:
            for p in r.held_pages:
                r.server.alloc.decref(p)
            self.events.append(("exhaust_end", r.idx, len(r.held_pages)))
            r.held_pages = []

    def _drive_replica(self, r: _Replica):
        d = r.dispatches
        r.dispatches += 1
        active = self.plan.active(r.idx, d) if self.plan is not None else []
        kinds = {f.kind for f in active}
        self._apply_exhaust(r, active)
        t0 = self._now()
        try:
            if "raise" in kinds:
                raise InjectedFault("raise", r.idx, d)
            if "exhaust" in kinds and not r.server.paged:
                # no pool to drain on a contiguous replica: the fault
                # surfaces as the allocation failure it models
                raise InjectedFault("exhaust", r.idx, d)
            if "hang" in kinds:
                f = next(f for f in active if f.kind == "hang")
                self.clock.advance(f.hang_s or 2 * self.cfg.step_timeout_s)
            else:
                r.server.step(r.params)
        except Exception as e:     # noqa: BLE001 — any step failure fails over
            self._bump("replica_failures")
            r.consec_failures += 1
            self.events.append(("replica_failure", r.idx, self.ticks,
                                type(e).__name__))
            err = e if isinstance(e, lc.ServeError) else \
                lc.ReplicaFailedError(f"replica {r.idx} step raised: {e}",
                                      replica=r.idx, cause=e)
            for rid in list(r.outstanding):
                rec = r.outstanding.pop(rid)
                r.server.abort(rid)
                self._retry(rec, err)
            self._after_failure(r, err)
            return
        elapsed = self._now() - t0
        if elapsed > self.cfg.step_timeout_s:
            self._bump("replica_failures")
            r.consec_failures += 1
            self.events.append(("replica_hang", r.idx, self.ticks, elapsed))
            err = lc.ReplicaFailedError(
                f"replica {r.idx} step took {elapsed:.3f}s "
                f"(> step_timeout_s {self.cfg.step_timeout_s})",
                replica=r.idx, cause=TimeoutError(f"{elapsed:.3f}s"))
            for rid in list(r.outstanding):
                rec = r.outstanding.pop(rid)
                r.server.abort(rid)
                self._retry(rec, err)
            self._after_failure(r, err)
            return
        done = r.server.take_completed()
        if "poison" in kinds:
            bad = r.server.model.cfg.vocab + 7    # out-of-vocab sentinel
            for creq in done:
                if creq.out_tokens:
                    creq.out_tokens[-1] = bad
        clean = True
        for creq in done:
            clean &= self._on_complete(r, creq)
        if clean:
            r.consec_failures = 0
        self._update_phases(r)

    def _on_complete(self, r: _Replica, creq: Request) -> bool:
        now = self._now()
        rec = r.outstanding.pop(creq.rid, None)
        if rec is None or rec.terminal:
            # late completion of an aborted/retried/timed-out request:
            # never re-emitted (the duplicate-emission guard)
            self._bump("duplicate_emissions_dropped")
            return True
        defect = lc.output_sanity_error(
            creq.out_tokens, vocab=r.server.model.cfg.vocab,
            max_new=creq.max_new_tokens, eos_id=creq.eos_id)
        if defect is not None:
            r.server.abort(creq.rid)     # drop the poisoned cached result
            r.consec_failures += 1
            self._bump("poisoned")
            self.events.append(("poisoned", r.idx, creq.rid))
            err = lc.PoisonedOutputError(
                f"replica {r.idx} request {creq.rid}: {defect}")
            self._retry(rec, err)
            self._after_failure(r, err)
            return False
        rec.tokens = list(creq.out_tokens)
        rec.tier = r.tier
        rec.t_done = now
        rec.transition(lc.Lifecycle.DONE, now)
        self._bump("completed")
        # router-level TTFT: router submit -> first token on the (shared)
        # replica clock, so queueing, backoff, and retries all count
        if creq.t_first is not None:
            ttft_ms = (creq.t_first - rec.t_submit) * 1e3
            self._w_ttft.labels(replica=str(r.idx),
                                tier=r.tier).observe(ttft_ms)
            if self.slo is not None:
                self.slo.observe_latency("ttft_ms", ttft_ms)
        if self.slo is not None:
            for v in creq.itl_s or ():
                self.slo.observe_latency("itl_ms", v * 1e3)
            self.slo.observe_event("error_rate", True)
        if r.state == PROBING:
            r.state = HEALTHY
            r.quarantine_count = 0       # successful probe resets the cool-
            self._bump("probe_successes")   # down exponent too
            self.events.append(("probe_success", r.idx, self.ticks))
        return True

    def _update_phases(self, r: _Replica):
        now = self._now()
        phase_map = {"queued": lc.Lifecycle.ADMITTED,
                     "prefilling": lc.Lifecycle.PREFILLING,
                     "decoding": lc.Lifecycle.DECODING}
        for rid, rec in r.outstanding.items():
            phase = r.server.request_phase(rid)
            want = phase_map.get(phase)
            if want is not None and rec.state != want and not rec.terminal:
                rec.transition(want, now)

    # -- results -----------------------------------------------------------
    def completed_tokens(self) -> Dict[int, List[int]]:
        return {rid: rec.tokens for rid, rec in self.records.items()
                if rec.state == lc.Lifecycle.DONE}

    def outcome_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for rec in self.records.values():
            out[rec.state.value] = out.get(rec.state.value, 0) + 1
        return out
