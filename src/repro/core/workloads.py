"""CNN workload shape tables (AlexNet, VGG16, ResNet-50/101/152).

Used by the benchmark layer to drive the paper's deterministic cycle model
(Tables 1-3 reproduce GOPS / GOPS-per-multiplier / ops-per-mult-per-cycle on
these models). Conv layers are expressed as the GEMMs the accelerator's
in-place conv->GEMM mapping (Algorithm 1) produces:

    M = batch * OH * OW,   K = KH * KW * Cin,   N = Cout
"""
from __future__ import annotations

import math
from typing import List

from repro.core.analytical import GemmShape


def conv_gemm(name: str, batch: int, h: int, w: int, cin: int, cout: int,
              kh: int, kw: int, stride: int = 1, pad: int = 0,
              groups: int = 1) -> List[GemmShape]:
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w + 2 * pad - kw) // stride + 1
    return [GemmShape(m=batch * oh * ow, k=kh * kw * cin // groups,
                      n=cout // groups, name=f"{name}.g{g}" if groups > 1 else name)
            for g in range(groups)]


def fc_gemm(name: str, batch: int, cin: int, cout: int) -> List[GemmShape]:
    return [GemmShape(m=batch, k=cin, n=cout, name=name)]


def alexnet(batch: int = 1) -> List[GemmShape]:
    """AlexNet (Krizhevsky et al. 2012) with the original grouped conv2/4/5,
    ~1.45 GOP/inference."""
    return (
        conv_gemm("conv1", batch, 227, 227, 3, 96, 11, 11, stride=4)
        + conv_gemm("conv2", batch, 27, 27, 96, 256, 5, 5, pad=2, groups=2)
        + conv_gemm("conv3", batch, 13, 13, 256, 384, 3, 3, pad=1)
        + conv_gemm("conv4", batch, 13, 13, 384, 384, 3, 3, pad=1, groups=2)
        + conv_gemm("conv5", batch, 13, 13, 384, 256, 3, 3, pad=1, groups=2)
        + fc_gemm("fc6", batch, 256 * 6 * 6, 4096)
        + fc_gemm("fc7", batch, 4096, 4096)
        + fc_gemm("fc8", batch, 4096, 1000)
    )


def vgg16(batch: int = 1) -> List[GemmShape]:
    cfg = [(64, 2, 224), (128, 2, 112), (256, 3, 56), (512, 3, 28), (512, 3, 14)]
    layers: List[GemmShape] = []
    cin = 3
    idx = 1
    for cout, reps, res in cfg:
        for r in range(reps):
            layers += conv_gemm(f"conv{idx}", batch, res, res, cin, cout, 3, 3, pad=1)
            cin = cout
            idx += 1
    layers += fc_gemm("fc1", batch, 512 * 7 * 7, 4096)
    layers += fc_gemm("fc2", batch, 4096, 4096)
    layers += fc_gemm("fc3", batch, 4096, 1000)
    return layers


def _resnet(blocks_per_stage: List[int], batch: int) -> List[GemmShape]:
    layers = conv_gemm("conv1", batch, 224, 224, 3, 64, 7, 7, stride=2, pad=3)
    res = 56
    cin = 64
    for stage, blocks in enumerate(blocks_per_stage):
        width = 64 * (2 ** stage)
        cout = width * 4
        for b in range(blocks):
            stride = 2 if (b == 0 and stage > 0) else 1
            in_res = res * stride
            nm = f"s{stage+2}b{b+1}"
            layers += conv_gemm(f"{nm}.c1", batch, in_res, in_res, cin, width, 1, 1, stride=stride)
            layers += conv_gemm(f"{nm}.c2", batch, res, res, width, width, 3, 3, pad=1)
            layers += conv_gemm(f"{nm}.c3", batch, res, res, width, cout, 1, 1)
            if b == 0:
                layers += conv_gemm(f"{nm}.proj", batch, in_res, in_res, cin, cout, 1, 1, stride=stride)
            cin = cout
        res //= 2
    layers += fc_gemm("fc", batch, 2048, 1000)
    return layers


def resnet50(batch: int = 1) -> List[GemmShape]:
    return _resnet([3, 4, 6, 3], batch)


def resnet101(batch: int = 1) -> List[GemmShape]:
    return _resnet([3, 4, 23, 3], batch)


def resnet152(batch: int = 1) -> List[GemmShape]:
    return _resnet([3, 8, 36, 3], batch)


MODELS = {
    "alexnet": alexnet,
    "vgg16": vgg16,
    "resnet50": resnet50,
    "resnet101": resnet101,
    "resnet152": resnet152,
}


def model_gops(name: str, batch: int = 1) -> float:
    return sum(g.ops() for g in MODELS[name](batch)) * 1e-9
