"""launch.dash renders a registry snapshot (pure function, no HTTP): SLO
burn bars, controller/admission state, replica health, windowed percentile
rows, and router totals — tolerating both a bare ``/metrics.json`` snapshot
and the launcher's ``{"metrics": ...}`` dump payload."""
from repro.launch.dash import _bar, render
from repro.obs import Registry
from repro.serve.faults import FakeClock


def _snapshot():
    """A miniature fleet snapshot produced by the real Registry."""
    clock = FakeClock()
    r = Registry()
    r.gauge("slo_state", labels=("slo",)).labels(slo="ttft_ms").set(2)
    b = r.gauge("slo_burn_rate", labels=("slo", "window"))
    b.labels(slo="ttft_ms", window="fast").set(5.0)
    b.labels(slo="ttft_ms", window="slow").set(1.2)
    r.counter("slo_transitions_total", labels=("slo", "to")).labels(
        slo="ttft_ms", to="PAGE").inc()
    r.gauge("router_controller_state").set(3)
    r.gauge("router_admission_limit").set(16)
    r.counter("router_controller_total", labels=("action",)).labels(
        action="tighten").inc()
    d = r.counter("serve_dispatches_total", labels=("replica", "phase"))
    d.labels(replica="0", phase="prefill").inc(4)
    d.labels(replica="0", phase="decode").inc(9)
    r.counter("serve_tokens_total", labels=("replica", "phase")).labels(
        replica="0", phase="decode").inc(36)
    r.gauge("router_replica_state", labels=("replica",)).labels(
        replica="0").set(2)
    w = r.windowed_histogram("serve_ttft_window_seconds", "t",
                             ("replica", "tier"), window_s=30.0,
                             clock=clock)
    clock.t = 0.5
    for v in (0.002, 0.004):
        w.labels(replica="0", tier="float").observe(v)
    ev = r.counter("router_events_total", labels=("kind",))
    ev.labels(kind="submitted").inc(6)
    ev.labels(kind="completed").inc(5)
    ev.labels(kind="shed_to_quantized").inc(2)
    r.gauge("router_queue_depth").set(1)
    return r.snapshot()


def test_render_all_sections():
    out = render(_snapshot(), source="unit")
    assert "repro.serve dashboard — unit" in out
    assert "ttft_ms" in out and "[PAGE]" in out
    assert "5.00" in out                       # fast burn value
    assert "controller: tightened" in out
    assert "admission_limit=16" in out and "tighten=1" in out
    assert "quarantined" in out                # replica 0 state
    assert "decode_tokens=36" in out
    assert "p50     3.00ms" in out             # windowed ttft median
    assert "n=2" in out
    assert "submitted=6" in out and "shed_to_quantized=2" in out
    assert "queue_depth=1" in out


def test_render_tolerates_launcher_payload_and_empty_snapshot():
    snap = _snapshot()
    assert render({"metrics": snap, "compile": {}}) == render(snap)
    out = render({})                           # no metrics at all: header only
    assert out.startswith("repro.serve dashboard")
    assert "controller" not in out


def test_burn_bar_clamps():
    assert _bar(0.0, 4) == "...."
    assert _bar(0.5, 4) == "##.."
    assert _bar(7.0, 4) == "####"              # over-unity burn stays in box
    assert _bar(-1.0, 4) == "...."