"""Property-style coverage of the repro.dist rule engine beyond test_dist.py:
structural invariants on every arch x both MoE partition modes x both
production mesh shapes, to_named round-trips, and the paper's bit-exactness
claim for an int8 FFIP GEMM running under data-parallel sharding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.core import fip
from repro.dist import context as dctx
from repro.dist import sharding as shd
from repro.kernels import ops
from repro.launch.inputs import params_specs_struct


class Mesh16x16:
    axis_names = ("data", "model")

    class devices:  # noqa: D106 — shape-only stand-in for a 256-chip pod
        shape = (16, 16)


class Mesh2x16x16:
    axis_names = ("pod", "data", "model")

    class devices:  # noqa: D106 — the 512-chip multi-pod mesh
        shape = (2, 16, 16)


PROD_MESHES = [Mesh16x16, Mesh2x16x16]


def _axis_sizes(mesh):
    return dict(zip(mesh.axis_names, mesh.devices.shape))


@pytest.mark.parametrize("mesh", PROD_MESHES, ids=["16x16", "2x16x16"])
@pytest.mark.parametrize("mode", ["expert", "ffn"])
@pytest.mark.parametrize("arch", sorted(configs.ARCHS))
def test_every_arch_every_mode_specs_divisible(arch, mode, mesh):
    """Every leaf gets a full-rank spec; every assigned dim divides its axis."""
    sizes = _axis_sizes(mesh)
    cfg = configs.get_config(arch)
    params = params_specs_struct(cfg)
    specs = shd.param_specs(params, mesh, moe_partition=mode)
    leaves = jax.tree_util.tree_leaves_with_path(params)
    spec_leaves = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == len(spec_leaves)
    for (path, leaf), spec in zip(leaves, spec_leaves):
        assert len(spec) == len(leaf.shape), \
            (arch, jax.tree_util.keystr(path), leaf.shape, spec)
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            assert leaf.shape[dim] % sizes[ax] == 0, \
                (arch, mode, jax.tree_util.keystr(path), leaf.shape, spec)


def test_spec_tree_structure_mirrors_params():
    cfg = configs.get_config("mixtral-8x22b")
    params = params_specs_struct(cfg)
    specs = shd.param_specs(params, Mesh16x16, moe_partition="ffn")
    assert (jax.tree_util.tree_structure(params)
            == jax.tree_util.tree_structure(
                specs, is_leaf=lambda x: isinstance(x, P)))


@settings(max_examples=30, deadline=None)
@given(L=st.integers(1, 8), e=st.integers(1, 128), d=st.integers(1, 512),
       f=st.integers(1, 512))
def test_property_moe_rules_divisible_and_modes_differ(L, e, d, f):
    """For ANY expert-bank shape, both modes give divisible full-rank specs;
    when dims divide, expert mode shards E and ffn mode shards d_ff."""
    sizes = _axis_sizes(Mesh16x16)
    for name in ("w_gate", "w_up", "w_down"):
        shape = (L, e, d, f) if name != "w_down" else (L, e, f, d)
        for mode in ("expert", "ffn"):
            spec = shd._match_spec(f"layers/ffn/{name}", shape, Mesh16x16, mode)
            assert len(spec) == 4
            for dim, ax in enumerate(spec):
                assert ax is None or shape[dim] % sizes[ax] == 0
    if e % 16 == 0:
        s = shd._match_spec("layers/ffn/w_gate", (L, e, d, f), Mesh16x16,
                            "expert")
        assert s[1] == "model"
    if f % 16 == 0:
        s = shd._match_spec("layers/ffn/w_gate", (L, e, d, f), Mesh16x16,
                            "ffn")
        assert s[3] == "model"


@settings(max_examples=30, deadline=None)
@given(d0=st.integers(1, 64), d1=st.integers(1, 4096), d2=st.integers(1, 4096))
def test_property_guard_never_assigns_indivisible(d0, d1, d2):
    """The divisibility guard holds for arbitrary generic-weight shapes."""
    sizes = _axis_sizes(Mesh16x16)
    spec = shd._match_spec("layers/attn/wq/w", (d0, d1, d2), Mesh16x16,
                           "expert")
    for dim, ax in zip((d0, d1, d2), spec):
        assert ax is None or dim % sizes[ax] == 0


def test_moe_partition_mode_validated():
    with pytest.raises(ValueError):
        shd._match_spec("layers/ffn/w_gate", (2, 4, 8, 16), Mesh16x16, "bogus")


def test_data_and_cache_specs_shapes():
    batch = {"tokens": jax.ShapeDtypeStruct((32, 128), jnp.int32),
             "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    bs = shd.data_specs(batch, Mesh16x16)
    assert bs["tokens"] == P("data", None)
    assert bs["pos"] == P()
    # batch of 8 does not divide the 16-way data axis -> replicated
    small = shd.data_specs(jax.ShapeDtypeStruct((8, 128), jnp.int32), Mesh16x16)
    assert small == P(None, None)
    # multi-pod: batch dim splits over ("pod", "data") jointly (32 x 32-way)
    bs3 = shd.data_specs(batch, Mesh2x16x16)
    assert bs3["tokens"] == P(("pod", "data"), None)

    # batch divides "data" (16) but not pod*data (32): degrade to data-only
    # sharding, never silent full replication
    mid = shd.data_specs(jax.ShapeDtypeStruct((16, 128), jnp.int32),
                         Mesh2x16x16)
    assert mid == P("data", None)

    kv = {"k": jax.ShapeDtypeStruct((4, 32, 256, 16, 64), jnp.bfloat16)}
    cs = shd.cache_specs(kv, Mesh16x16, batch=32)
    assert cs["k"] == P(None, "data", None, "model", None)
    # kv-heads that do not divide the model axis stay replicated
    kv8 = {"k": jax.ShapeDtypeStruct((4, 32, 256, 8, 64), jnp.bfloat16)}
    assert shd.cache_specs(kv8, Mesh16x16, batch=32)["k"] \
        == P(None, "data", None, None, None)
    # hybrid layout (n_groups, period, B, ...): batch dim found structurally
    # even when a stack dim (period) collides with the batch size
    hyb = {"hybrid_groups": {
        "conv": jax.ShapeDtypeStruct((3, 32, 32, 3, 128), jnp.bfloat16)}}
    spec = shd.cache_specs(hyb, Mesh16x16, batch=32)
    assert spec["hybrid_groups"]["conv"] == P(None, None, "data", None, None)


def test_to_named_roundtrip_single_device():
    """device_put through to_named keeps every value bit-identical and
    attaches the requested sharding (trivially valid on a 1-device mesh)."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = configs.smoke_config(configs.get_config("minicpm-2b"))
    from repro.models.model import build_model
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    specs = shd.param_specs(params, mesh)
    named = shd.to_named(specs, mesh)
    placed = jax.device_put(params, named)
    for (path, a), b, ns in zip(
            jax.tree_util.tree_leaves_with_path(params),
            jax.tree_util.tree_leaves(placed),
            jax.tree_util.tree_leaves(
                named, is_leaf=lambda x: isinstance(x, NamedSharding))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=jax.tree_util.keystr(path))
        assert b.sharding.is_equivalent_to(ns, a.ndim), \
            (jax.tree_util.keystr(path), b.sharding, ns)


def test_sharded_ffip_gemm_bit_exact_int8():
    """Paper exactness claim under sharding: a batched int8 FFIP GEMM run
    through jit with data-parallel in_shardings equals baseline_matmul
    bit-for-bit (int32 accumulators; sharding never splits the K
    contraction of a kernel invocation)."""
    n = jax.device_count()
    mesh = jax.make_mesh((n, 1), ("data", "model"))
    ka, kb = jax.random.split(jax.random.PRNGKey(7))
    a = jax.random.randint(ka, (2 * n, 24, 32), -128, 128,
                           dtype=jnp.int32).astype(jnp.int8)
    b = jax.random.randint(kb, (32, 40), -128, 128,
                           dtype=jnp.int32).astype(jnp.int8)
    aspec = shd.data_specs(a, mesh)
    fn = jax.jit(
        lambda a_, b_: ops.matmul(a_, b_, algo="ffip", interpret=True),
        in_shardings=(shd.to_named(aspec, mesh), NamedSharding(mesh, P())))
    with dctx.mesh_context(mesh):
        got = fn(a, b)
    want = fip.baseline_matmul(a.astype(jnp.int32).reshape(-1, 32),
                               b.astype(jnp.int32)).reshape(2 * n, 24, 40)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_mesh_context_nests_and_clears():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    inner = jax.make_mesh((1, 1), ("data", "model"))
    assert dctx.get_mesh() is None
    with dctx.mesh_context(mesh):
        assert dctx.get_mesh() is mesh
        with dctx.mesh_context(inner):
            assert dctx.get_mesh() is inner
        assert dctx.get_mesh() is mesh
    assert dctx.get_mesh() is None
