"""CNN workload shape tables (AlexNet, VGG16, ResNet-50/101/152).

The single source of truth for the paper's CNN workloads. Each model is
declared as a structured :class:`ConvSpec` list (plus FC shapes); two
consumers derive from the same tables:

  * the benchmark/analytical layer reads the GEMMs the accelerator's
    in-place conv->GEMM mapping (Algorithm 1) produces:

        M = batch * OH * OW,   K = KH * KW * (Cin/groups),   N = Cout/groups

    (Tables 1-3 reproduce GOPS / GOPS-per-multiplier / ops-per-mult-per-cycle
    on these models);
  * ``repro.vision.models`` builds runnable JAX models (conv topology —
    channels, kernels, strides, pads, groups — comes from these specs; the
    spatial dims recompute from the actual input so smoke-sized inputs flow
    through the same tables).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Tuple

from repro.core.analytical import GemmShape
from repro.core.im2col import conv_out_hw


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """One conv layer at its canonical (paper) input resolution. ``stride``
    and ``pad`` are (h, w) pairs; grouped convs declare ``groups`` (AlexNet's
    conv2/4/5 use 2 — the block-diagonal K split in core.im2col)."""
    name: str
    h: int
    w: int
    cin: int
    cout: int
    kh: int
    kw: int
    stride: Tuple[int, int] = (1, 1)
    pad: Tuple[int, int] = (0, 0)
    groups: int = 1

    @property
    def oh(self) -> int:
        return self.out_hw(self.h, self.w)[0]

    @property
    def ow(self) -> int:
        return self.out_hw(self.h, self.w)[1]

    @property
    def k(self) -> int:
        """Contraction dim of the per-group GEMM: KH*KW*(Cin/groups)."""
        return self.kh * self.kw * (self.cin // self.groups)

    def out_hw(self, h: int, w: int) -> Tuple[int, int]:
        """Output spatial dims for an arbitrary (h, w) input (vision models
        run these specs at non-canonical resolutions for smoke tests)."""
        return conv_out_hw(h, w, self.kh, self.kw, self.stride, self.pad)

    def gemm_shapes(self, batch: int = 1) -> List[GemmShape]:
        """The Algorithm-1 GEMM(s) this conv maps to (one per group)."""
        return [GemmShape(
            m=batch * self.oh * self.ow, k=self.k, n=self.cout // self.groups,
            name=f"{self.name}.g{g}" if self.groups > 1 else self.name)
            for g in range(self.groups)]


def conv_gemm(name: str, batch: int, h: int, w: int, cin: int, cout: int,
              kh: int, kw: int, stride: int = 1, pad: int = 0,
              groups: int = 1) -> List[GemmShape]:
    """Back-compat shim: build the GEMM list straight from scalar args."""
    return ConvSpec(name, h, w, cin, cout, kh, kw, (stride, stride),
                    (pad, pad), groups).gemm_shapes(batch)


def fc_gemm(name: str, batch: int, cin: int, cout: int) -> List[GemmShape]:
    return [GemmShape(m=batch, k=cin, n=cout, name=name)]


# ---------------------------------------------------------------------------
# AlexNet (Krizhevsky et al. 2012), original grouped conv2/4/5, ~1.45 GOP.
# ---------------------------------------------------------------------------

def alexnet_convs() -> List[ConvSpec]:
    return [
        ConvSpec("conv1", 227, 227, 3, 96, 11, 11, stride=(4, 4)),
        ConvSpec("conv2", 27, 27, 96, 256, 5, 5, pad=(2, 2), groups=2),
        ConvSpec("conv3", 13, 13, 256, 384, 3, 3, pad=(1, 1)),
        ConvSpec("conv4", 13, 13, 384, 384, 3, 3, pad=(1, 1), groups=2),
        ConvSpec("conv5", 13, 13, 384, 256, 3, 3, pad=(1, 1), groups=2),
    ]


ALEXNET_FCS = [("fc6", 256 * 6 * 6, 4096), ("fc7", 4096, 4096),
               ("fc8", 4096, 1000)]


def alexnet(batch: int = 1) -> List[GemmShape]:
    layers: List[GemmShape] = []
    for spec in alexnet_convs():
        layers += spec.gemm_shapes(batch)
    for name, cin, cout in ALEXNET_FCS:
        layers += fc_gemm(name, batch, cin, cout)
    return layers


# ---------------------------------------------------------------------------
# VGG-16: (cout, repetitions, input resolution) per stage, 3x3 pad-1 convs
# with a 2x2 max-pool between stages.
# ---------------------------------------------------------------------------

VGG16_PLAN = [(64, 2, 224), (128, 2, 112), (256, 3, 56), (512, 3, 28),
              (512, 3, 14)]
VGG16_FCS = [("fc1", 512 * 7 * 7, 4096), ("fc2", 4096, 4096),
             ("fc3", 4096, 1000)]


def vgg16_convs() -> List[ConvSpec]:
    specs: List[ConvSpec] = []
    cin = 3
    idx = 1
    for cout, reps, res in VGG16_PLAN:
        for _ in range(reps):
            specs.append(ConvSpec(f"conv{idx}", res, res, cin, cout, 3, 3,
                                  pad=(1, 1)))
            cin = cout
            idx += 1
    return specs


def vgg16(batch: int = 1) -> List[GemmShape]:
    layers: List[GemmShape] = []
    for spec in vgg16_convs():
        layers += spec.gemm_shapes(batch)
    for name, cin, cout in VGG16_FCS:
        layers += fc_gemm(name, batch, cin, cout)
    return layers


# ---------------------------------------------------------------------------
# ResNet-50/101/152 bottleneck plans. resnet_plan yields one entry per
# bottleneck block so the runnable model and the GEMM tables agree on
# structure (stage width = 64 * 2**stage, expansion 4).
# ---------------------------------------------------------------------------

RESNET_STAGES = {"resnet50": [3, 4, 6, 3], "resnet101": [3, 4, 23, 3],
                 "resnet152": [3, 8, 36, 3]}
RESNET_STEM = ConvSpec("conv1", 224, 224, 3, 64, 7, 7, stride=(2, 2),
                       pad=(3, 3))


@dataclasses.dataclass(frozen=True)
class BottleneckSpec:
    """One ResNet bottleneck: 1x1 reduce -> 3x3 -> 1x1 expand (+ projection
    shortcut on the first block of a stage). ``res`` is the block's OUTPUT
    resolution at the canonical 224 input."""
    name: str
    cin: int
    width: int
    cout: int
    stride: int     # applied by c1 (and proj) on the first block of a stage
    res: int

    @property
    def in_res(self) -> int:
        return self.res * self.stride

    def convs(self) -> List[ConvSpec]:
        r, ir = self.res, self.in_res
        specs = [
            ConvSpec(f"{self.name}.c1", ir, ir, self.cin, self.width, 1, 1,
                     stride=(self.stride, self.stride)),
            ConvSpec(f"{self.name}.c2", r, r, self.width, self.width, 3, 3,
                     pad=(1, 1)),
            ConvSpec(f"{self.name}.c3", r, r, self.width, self.cout, 1, 1),
        ]
        if self.cin != self.cout or self.stride != 1:
            specs.append(ConvSpec(f"{self.name}.proj", ir, ir, self.cin,
                                  self.cout, 1, 1,
                                  stride=(self.stride, self.stride)))
        return specs


def resnet_blocks(blocks_per_stage: List[int]) -> List[BottleneckSpec]:
    blocks: List[BottleneckSpec] = []
    res = 56
    cin = 64
    for stage, n_blocks in enumerate(blocks_per_stage):
        width = 64 * (2 ** stage)
        cout = width * 4
        for b in range(n_blocks):
            stride = 2 if (b == 0 and stage > 0) else 1
            blocks.append(BottleneckSpec(f"s{stage + 2}b{b + 1}", cin, width,
                                         cout, stride, res))
            cin = cout
        res //= 2
    return blocks


def _resnet(blocks_per_stage: List[int], batch: int) -> List[GemmShape]:
    layers = RESNET_STEM.gemm_shapes(batch)
    for blk in resnet_blocks(blocks_per_stage):
        for spec in blk.convs():
            layers += spec.gemm_shapes(batch)
    layers += fc_gemm("fc", batch, 2048, 1000)
    return layers


def resnet50(batch: int = 1) -> List[GemmShape]:
    return _resnet(RESNET_STAGES["resnet50"], batch)


def resnet101(batch: int = 1) -> List[GemmShape]:
    return _resnet(RESNET_STAGES["resnet101"], batch)


def resnet152(batch: int = 1) -> List[GemmShape]:
    return _resnet(RESNET_STAGES["resnet152"], batch)


MODELS = {
    "alexnet": alexnet,
    "vgg16": vgg16,
    "resnet50": resnet50,
    "resnet101": resnet101,
    "resnet152": resnet152,
}

# Conv-spec tables for the runnable vision models (and conv tuning/benches).
CONV_SPECS = {
    "alexnet": alexnet_convs,
    "vgg16": vgg16_convs,
    "resnet50": lambda: [RESNET_STEM] + [
        s for blk in resnet_blocks(RESNET_STAGES["resnet50"])
        for s in blk.convs()],
}


def model_gops(name: str, batch: int = 1) -> float:
    return sum(g.ops() for g in MODELS[name](batch)) * 1e-9
