"""Tests for the paper's analytical model (Eqs. 5-6, 17-19, 22-31) against
both measured op counts (jaxpr instrumentation) and the paper's reported
numbers (Tables 1-2 resource columns)."""
import jax.numpy as jnp
import jax
import pytest

from repro.core import analytical as an
from repro.core import fip, workloads


def test_eq5_eq6_counts_match_instrumented_jaxpr():
    """Eq. (5)/(6) multiplication counts == multiplies actually present in the
    lowered FIP computation (measured, not assumed)."""
    m, k, n = 8, 16, 4
    a = jnp.zeros((m, k))
    b = jnp.zeros((k, n))
    measured = fip.count_multiplies_in_jaxpr(lambda a, b: fip.fip_matmul(a, b), a, b)
    assert measured == an.fip_mults(m, k, n)
    measured_base = fip.count_multiplies_in_jaxpr(lambda a, b: a @ b, a, b)
    assert measured_base == an.baseline_mults(m, k, n)


def test_mult_halving_ratio():
    """The headline claim: FIP mults -> ~half of baseline for large MNK."""
    m = k = n = 512
    ratio = an.fip_mults(m, k, n) / an.baseline_mults(m, k, n)
    assert 0.5 <= ratio < 0.51


def test_register_model_fig2():
    rows = an.fig2_table(x=64, d=1)
    by_w = {r["w"]: r for r in rows}
    # Eq. 17/18/19 spot values at w=8, X=64 (clog2=6):
    assert by_w[8]["fip"] == 6 * 8 + 6 + 1
    assert by_w[8]["fip_extra"] == 8 * 8 + 2 + 6 + 1
    assert by_w[8]["ffip"] == 6 * 8 + 2 + 6 + 3
    # FFIP < FIP+extra for all w >= 2 (Fig. 2's message)
    for r in rows:
        assert r["ffip"] < r["fip_extra"]


def test_mxu_resources_match_table1():
    """FFIP 64x64 on Arria 10: 1072 DSPs (Table 1) — our resource model."""
    cfg = an.MxuConfig(x=64, y=64, algo="ffip", w_bits=8)
    assert an.mxu_dsps(cfg) == 1072
    base = an.MxuConfig(x=64, y=64, algo="baseline", w_bits=8)
    assert an.mxu_dsps(base) == (64 * 64 + 64 + 1) // 2  # 2080
    # near-2x DSP reduction (the Fig. 9 claim)
    assert an.mxu_dsps(base) / an.mxu_dsps(cfg) > 1.9


def test_roofs():
    ffip = an.MxuConfig(x=64, y=64, algo="ffip", w_bits=8)
    base = an.MxuConfig(x=64, y=64, algo="baseline", w_bits=8)
    assert an.ops_per_mult_per_cycle_roof(ffip) == 4.0   # Eq. (30)
    assert an.ops_per_mult_per_cycle_roof(base) == 2.0   # Eq. (26)


def test_fmax_table_values():
    """Frequency constants reproduce Table 1/2 'Ours' rows at 64x64."""
    assert an.mxu_fmax_mhz(an.MxuConfig(64, 64, "ffip", 8)) == pytest.approx(388, abs=2)
    assert an.mxu_fmax_mhz(an.MxuConfig(64, 64, "ffip", 16)) == pytest.approx(346, abs=2)


def test_fip_fmax_30pct_below_baseline():
    f_fip = an.mxu_fmax_mhz(an.MxuConfig(64, 64, "fip", 8))
    f_base = an.mxu_fmax_mhz(an.MxuConfig(64, 64, "baseline", 8))
    assert 0.62 <= f_fip / f_base <= 0.78


def test_workload_gops_sane():
    """Model op counts match literature (AlexNet ~1.45 GOP, ResNet-50 ~7.7,
    VGG16 ~30.9, ResNet-152 ~22.6)."""
    assert workloads.model_gops("alexnet") == pytest.approx(1.45, rel=0.15)
    assert workloads.model_gops("resnet50") == pytest.approx(7.7, rel=0.15)
    assert workloads.model_gops("vgg16") == pytest.approx(30.9, rel=0.05)
    assert workloads.model_gops("resnet152") == pytest.approx(22.6, rel=0.15)


def test_cycle_model_utilization_bounds():
    cfg = an.MxuConfig(x=64, y=64, algo="ffip", w_bits=8)
    perf = an.model_performance(workloads.resnet50(batch=8), cfg)
    assert 0.3 < perf["utilization"] <= 1.0
    assert perf["gops"] <= perf["roof_gops"] * 1.001


def test_ffip_table1_gops_reproduction():
    """Reproduce Table 1 'Ours FFIP 64x64' GOPS within 15%.

    The paper's own estimator claims 1% vs silicon; ours re-derives the cycle
    model from the architecture description alone (their exact layer-IO
    pipelining depth is not published), so we accept a wider band. Operating
    points: streaming batch=2 for ResNets, batch=32 for AlexNet (fc weight
    loads amortize over a batch; the paper's AlexNet number implies the same).
    """
    cfg = an.MxuConfig(x=64, y=64, algo="ffip", w_bits=8)
    for model, batch, paper in [("resnet50", 2, 2529), ("resnet101", 2, 2752),
                                ("resnet152", 2, 2838), ("alexnet", 32, 2277)]:
        perf = an.model_performance(workloads.MODELS[model](batch), cfg)
        assert perf["gops"] == pytest.approx(paper, rel=0.15), (model, perf["gops"])


def test_ffip_table2_gops_reproduction_16bit():
    """Table 2 (16-bit FFIP 64x64): GOPS scale by fmax ratio, util unchanged —
    exactly the paper's behaviour (2258/2529 == 346/388)."""
    cfg = an.MxuConfig(x=64, y=64, algo="ffip", w_bits=16)
    for model, batch, paper in [("resnet50", 2, 2258), ("resnet152", 2, 2534),
                                ("alexnet", 32, 1974)]:
        perf = an.model_performance(workloads.MODELS[model](batch), cfg)
        assert perf["gops"] == pytest.approx(paper, rel=0.15), (model, perf["gops"])


def test_ops_per_mult_per_cycle_beats_baseline_2x():
    """The paper's Table 1 headline: FFIP reaches ~3.0-3.4 ops/mult/cycle,
    above the baseline theoretical max of 2 (Eq. 26)."""
    cfg = an.MxuConfig(x=64, y=64, algo="ffip", w_bits=8)
    perf = an.model_performance(workloads.resnet152(batch=2), cfg)
    assert perf["ops_per_mult_per_cycle"] > 2.0
    assert perf["ops_per_mult_per_cycle"] == pytest.approx(3.414, rel=0.15)


def test_tpu_roofline_terms():
    t = an.tpu_roofline_terms(1e15, 1e12, 1e11, 256)
    assert t["bottleneck"] == "compute_s"
    assert t["compute_s"] == pytest.approx(1e15 / (256 * 197e12))
