"""Serving hot-path overhaul: fused multi-step decode equivalence, bucketed
prefill equivalence, compile-count bounds, and on-device sampling transfer
sizes.

The load-bearing claim: ``decode_chunk > 1`` (one lax.scan dispatch per chunk
of steps, sampled tokens fed back on device) and bucketed batched prefill
change WHAT crosses the host boundary and HOW OFTEN — never the tokens. Every
test here compares against the chunk=1 path, which test_serve_batcher.py in
turn pins to one-at-a-time sequential generation."""
import jax
import numpy as np
import pytest

from repro import configs
from repro.models.model import build_model
from repro.serve.batcher import BatchServer, Request

MAX_LEN = 48


def _setup(arch, seed=0):
    cfg = configs.smoke_config(configs.get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, size=(l,)) for l in lens]


def _run(model, params, prompts, budgets, *, slots, decode_chunk,
         eos_id=-1, quantized=False, prefill_buckets=True):
    srv = BatchServer(model, batch_slots=slots, max_len=MAX_LEN,
                      quantized=quantized, decode_chunk=decode_chunk,
                      prefill_buckets=prefill_buckets)
    for i, p in enumerate(prompts):
        mx = budgets[i] if isinstance(budgets, (list, tuple)) else budgets
        srv.submit(Request(rid=i, prompt=p, max_new_tokens=mx, eos_id=eos_id))
    done = srv.run_until_drained(params)
    return done, srv


@pytest.mark.parametrize("quantized", [False, True],
                         ids=["float", "int8-ffip"])
@pytest.mark.parametrize("arch", ["minicpm-2b", "deepseek-v2-lite-16b"])
def test_fused_decode_chunk_equivalence(arch, quantized):
    """decode_chunk ∈ {1, 4} produce identical out_tokens and completion sets
    under mixed lengths, slot churn (5 requests / 2 slots), budgets not
    divisible by the chunk, and a budget-1 request that finishes at prefill —
    for the float AND the quantized int8 FFIP path, GQA and absorbed-MLA."""
    cfg, model, params = _setup(arch)
    lens = [3, 6, 9, 4, 7]
    budgets = [5, 1, 3, 6, 2]      # 5 and 6 straddle chunk=4 boundaries
    prompts = _prompts(cfg, lens, seed=7)
    done1, _ = _run(model, params, prompts, budgets, slots=2, decode_chunk=1,
                    quantized=quantized)
    done4, _ = _run(model, params, prompts, budgets, slots=2, decode_chunk=4,
                    quantized=quantized)
    assert sorted(r.rid for r in done1) == list(range(len(prompts)))
    assert sorted(r.rid for r in done4) == list(range(len(prompts)))
    got1 = {r.rid: r.out_tokens for r in done1}
    got4 = {r.rid: r.out_tokens for r in done4}
    for i in range(len(prompts)):
        assert len(got1[i]) == budgets[i], (arch, i, got1[i])
        assert got1[i] == got4[i], (arch, quantized, i, got1[i], got4[i])


def test_fused_decode_mid_chunk_eos():
    """A slot hitting EOS mid-chunk freezes on device: the trailing scan steps
    re-write its row with unchanged values, the host drops the post-EOS
    tokens, and the emitted stream matches chunk=1 exactly."""
    cfg, model, params = _setup("minicpm-2b")
    prompts = _prompts(cfg, [4, 6, 5], seed=3)
    free, _ = _run(model, params, prompts, 6, slots=3, decode_chunk=1)
    ref = {r.rid: list(r.out_tokens) for r in free}
    # an EOS that lands mid-stream (2nd token of rid 0) => mid-chunk for
    # chunk=4 (prefill emits token 1, the chunk then emits tokens 2..5)
    eos = ref[0][1]
    done1, _ = _run(model, params, prompts, 6, slots=3, decode_chunk=1,
                    eos_id=eos)
    done4, _ = _run(model, params, prompts, 6, slots=3, decode_chunk=4,
                    eos_id=eos)
    got1 = {r.rid: r.out_tokens for r in done1}
    got4 = {r.rid: r.out_tokens for r in done4}
    assert got1 == got4
    for rid, toks in got1.items():
        full = ref[rid]
        want = full[:full.index(eos) + 1] if eos in full else full
        assert toks == want, (rid, toks, want)


def test_bucketed_prefill_matches_per_slot_fallback():
    """Bucketed batched prefill (padded prompts, masked write into the shared
    cache) produces the same tokens as the per-slot scatter fallback."""
    cfg, model, params = _setup("minicpm-2b")
    prompts = _prompts(cfg, [3, 8, 5, 6, 12], seed=5)
    fast, _ = _run(model, params, prompts, 4, slots=3, decode_chunk=2,
                   prefill_buckets=True)
    slow, srv_slow = _run(model, params, prompts, 4, slots=3, decode_chunk=2,
                          prefill_buckets=False)
    assert not srv_slow._bucketed
    got_f = {r.rid: r.out_tokens for r in fast}
    got_s = {r.rid: r.out_tokens for r in slow}
    assert got_f == got_s


def test_compile_counts_bounded_by_buckets():
    """A mixed-length workload spanning >= 3 power-of-2 buckets compiles the
    prefill at most once per bucket (not once per distinct prompt length) and
    the decode program exactly once — the jit cache is O(log max_len)."""
    cfg, model, params = _setup("minicpm-2b")
    lens = [3, 4, 6, 7, 11, 14, 5, 9]            # buckets {4, 8, 16} only
    buckets = {max(4, 1 << (int(l) - 1).bit_length()) for l in lens}
    assert len(buckets) == 3
    prompts = _prompts(cfg, lens, seed=11)
    done, srv = _run(model, params, prompts, 3, slots=3, decode_chunk=4)
    assert sorted(r.rid for r in done) == list(range(len(lens)))
    assert srv.compiles["decode"] == 1, srv.compiles
    assert srv.compiles["prefill"] <= len(buckets), (srv.compiles, buckets)
    # and the cache stays warm: a second drain re-traces nothing
    for i, p in enumerate(prompts):
        srv.submit(Request(rid=100 + i, prompt=p, max_new_tokens=3))
    srv.run_until_drained(params)
    assert srv.compiles["prefill"] <= len(buckets)
    assert srv.compiles["decode"] == 1


def test_on_device_sampling_host_bytes():
    """Only int32 token ids cross per decode dispatch: chunk*B*4 bytes, vs the
    PR 2 hot path's B*V*4-byte logits transfer per step."""
    cfg, model, params = _setup("minicpm-2b")
    prompts = _prompts(cfg, [4, 6], seed=2)
    done, srv = _run(model, params, prompts, 4, slots=2, decode_chunk=4)
    st = srv.stats
    assert st["host_bytes_decode"] == st["decode_dispatches"] * 4 * srv.b * 4
    assert st["host_bytes_decode"] < srv.b * cfg.vocab * 4  # < ONE logit xfer
    assert st["host_bytes_prefill"] == st["prefill_dispatches"] * srv.b * 4


def test_sample_step_matches_decode_step_argmax():
    """Model.sample_step is decode_step + fused argmax (the (B, V) logits
    never leave the device on the serving path)."""
    cfg, model, params = _setup("minicpm-2b")
    cache = model.init_cache(2, 16)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab, size=(2, 5))
    cache, _ = model.prefill(params, toks, cache)
    pos = np.array([5, 5], np.int32)
    step_tok = np.array([[1], [2]], np.int32)
    _, logits = model.decode_step(params, step_tok, cache, pos)
    _, ids = model.sample_step(params, step_tok, cache, pos)
    np.testing.assert_array_equal(np.argmax(np.asarray(logits), -1),
                                  np.asarray(ids))
