"""Compat layer over Pallas TPU API drift.

`pltpu.TPUCompilerParams` was renamed to `pltpu.CompilerParams` across JAX
releases; the installed toolchain may carry either name. Every kernel builds
its compiler params through :func:`tpu_compiler_params` so one probe point
absorbs the drift (tests/test_kernels.py exercises all kernels in interpret
mode at collection-adjacent cost precisely so this breaks loudly, not deep in
a smoke test).
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams", None)


def tpu_compiler_params(**kwargs):
    """Build a Pallas TPU compiler-params object under either JAX spelling.

    kwargs are passed through (e.g. dimension_semantics=("parallel", ...)).
    Returns None when the installed Pallas exposes neither class, in which
    case pallas_call simply runs without TPU compiler hints — correct, if
    slower, which is the right degradation for interpret-mode CPU CI.
    """
    if _PARAMS_CLS is None:
        return None
    return _PARAMS_CLS(**kwargs)
