"""Fused selective-scan kernel vs the chunked-scan oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.selective_scan import selective_scan


def oracle(x, dt, b, c, a, h0):
    """Direct sequential recurrence in f64-ish f32."""
    bt, s, di = x.shape
    h = h0.astype(jnp.float32)
    ys = []
    for t in range(s):
        da = jnp.exp(dt[:, t, :, None] * a[None])          # (B,di,N)
        dbx = (dt[:, t] * x[:, t])[..., None] * b[:, t][:, None, :]
        h = da * h + dbx
        ys.append(jnp.sum(h * c[:, t][:, None, :], axis=-1))
    return jnp.stack(ys, axis=1), h


def mk(bt, s, di, n, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    x = jax.random.normal(ks[0], (bt, s, di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bt, s, di)) - 1)
    b = jax.random.normal(ks[2], (bt, s, n))
    c = jax.random.normal(ks[3], (bt, s, n))
    a = -jnp.exp(jax.random.normal(ks[4], (di, n)) * 0.3)
    h0 = jax.random.normal(ks[5], (bt, di, n)) * 0.1
    return x, dt, b, c, a, h0


@pytest.mark.parametrize("bt,s,di,n,chunk,bd", [
    (2, 32, 16, 8, 8, 8),
    (1, 64, 32, 16, 16, 16),
    (2, 16, 8, 4, 16, 8),    # single chunk
])
def test_kernel_matches_oracle(bt, s, di, n, chunk, bd):
    args = mk(bt, s, di, n)
    y, h, _ = selective_scan(*args, chunk=chunk, bd=bd, interpret=True)
    y_ref, h_ref = oracle(*args)
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(h, h_ref, rtol=1e-4, atol=1e-4)


def test_kernel_state_carry_across_calls():
    """h_final from one call feeds the next (streaming prefill contract)."""
    x, dt, b, c, a, h0 = mk(1, 32, 8, 4, seed=1)
    y_full, h_full, _ = selective_scan(x, dt, b, c, a, h0, chunk=8, bd=8)
    y1, h1, _ = selective_scan(x[:, :16], dt[:, :16], b[:, :16], c[:, :16], a,
                               h0, chunk=8, bd=8)
    y2, h2, _ = selective_scan(x[:, 16:], dt[:, 16:], b[:, 16:], c[:, 16:], a,
                               h1, chunk=8, bd=8)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full,
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(h2, h_full, rtol=1e-4, atol=1e-4)


def test_matches_model_chunked_scan():
    """Kernel == the model's differentiable chunked scan (_mamba1_scan)."""
    from repro.models.ssm import _mamba1_scan
    x, dt, b, c, a, h0 = mk(2, 64, 16, 8, seed=2)
    d_skip = jnp.zeros((16,))
    y_model, h_model = _mamba1_scan(x, dt, b, c, a, d_skip, h0, chunk=16)
    y_k, h_k, _ = selective_scan(x, dt, b, c, a, h0, chunk=16, bd=16)
    np.testing.assert_allclose(y_k, y_model, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(h_k, h_model, rtol=1e-4, atol=1e-4)


def test_trainable_grads_match_oracle():
    """Custom-VJP kernel pair: exact grads for x, dt, B, C, A."""
    from repro.kernels.selective_scan import selective_scan_trainable
    x, dt, b, c, a, h0 = mk(1, 32, 8, 4, seed=7)
    h0 = jnp.zeros_like(h0)   # train contract: zero initial state

    def loss_kernel(x, dt, b, c, a):
        return jnp.sum(jnp.sin(selective_scan_trainable(x, dt, b, c, a, h0,
                                                        8, 8)))

    def loss_oracle(x, dt, b, c, a):
        y, _ = oracle(x, dt, b, c, a, h0)
        return jnp.sum(jnp.sin(y))

    g1 = jax.grad(loss_kernel, argnums=(0, 1, 2, 3, 4))(x, dt, b, c, a)
    g2 = jax.grad(loss_oracle, argnums=(0, 1, 2, 3, 4))(x, dt, b, c, a)
    for name, u, v in zip("x dt B C A".split(), g1, g2):
        np.testing.assert_allclose(u, v, rtol=1e-3, atol=1e-3, err_msg=name)
