"""Offline kernel autotuner CLI — pre-populates the repro.tune schedule cache.

    # tune the GEMM shape set of a model config (dense/MoE/attention
    # projections + the tied unembed), plus its flash-attention buckets:
    PYTHONPATH=src python -m repro.launch.tune --arch minicpm-2b --smoke \
        --m 4,64 --budget 4

    # tune a CNN workload's conv-as-GEMM shape table (core.workloads):
    PYTHONPATH=src python -m repro.launch.tune --workload alexnet \
        --dtypes int8 --budget 6

Shapes are bucketed (pow2 per dim) and deduped before measuring, so the cost
is one tuning run per distinct bucket, not per layer. A warm cache is a
no-op: already-tuned buckets are reported as ``cached`` with ZERO
re-measurement — ``--expect-cached`` turns that into a hard assertion (the CI
tune-smoke job runs the tuner twice and requires the second run to measure
nothing). Serving picks the schedules up via ``--gemm-block auto``
(launch.serve / BatchServer) and ``GemmConfig(block="auto")``.

The ``--workload`` path tunes the conv-as-GEMM shape tables (the
materializing path); FUSED implicit-im2col conv schedules are tuned at real
conv geometry by ``python -m repro.launch.vision --model X --tune`` instead
(conv-specific candidate space: bk aligned to Cin_g*KW multiples).
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro import configs, tune
from repro.core import workloads
from repro.models.model import build_model
from repro.tune import measure


def _arch_gemm_shapes(cfg, m_values: List[int]) -> List[Tuple[int, int, int]]:
    """(m, k, n) set for a model config: every dense ``w`` leaf (attention /
    MLP / MoE projections — leading stacked-layer dims stripped) plus the
    tied-embedding unembed, crossed with the caller's M values (tokens per
    dispatch: decode = slots, prefill = slots x bucket)."""
    model = build_model(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    kn: set = set()

    def walk(node):
        if isinstance(node, dict):
            w = node.get("w")
            if w is not None and not isinstance(w, dict) and w.ndim >= 2:
                kn.add((int(w.shape[-2]), int(w.shape[-1])))
            tbl = node.get("table")
            if tbl is not None and not isinstance(tbl, dict) and tbl.ndim == 2:
                kn.add((int(tbl.shape[1]), int(tbl.shape[0])))  # unembed d->V
            for v in node.values():
                walk(v)

    walk(params)
    return [(m, k, n) for m in m_values for (k, n) in sorted(kn)]


def _workload_gemm_shapes(name: str, batch: int) -> List[Tuple[int, int, int]]:
    return [(g.m, g.k, g.n) for g in workloads.MODELS[name](batch)]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="pre-populate the repro.tune kernel schedule cache")
    src = ap.add_mutually_exclusive_group(required=True)
    src.add_argument("--arch", choices=sorted(configs.ARCHS))
    src.add_argument("--workload", choices=sorted(workloads.MODELS))
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config for --arch")
    ap.add_argument("--batch", type=int, default=1, help="--workload batch")
    ap.add_argument("--m", default="4,64,256",
                    help="comma-separated M values (tokens per dispatch) "
                         "crossed with the --arch (K, N) set")
    ap.add_argument("--slots", default="2,4",
                    help="comma-separated serving batch sizes for the --arch "
                         "flash buckets (prefill runs BH = slots x heads)")
    ap.add_argument("--seq", default="16,64",
                    help="comma-separated sequence lengths (prompt buckets) "
                         "for the --arch flash-attention jobs")
    ap.add_argument("--algos", default="baseline,fip,ffip")
    ap.add_argument("--dtypes", default="float32,int8")
    ap.add_argument("--budget", type=int, default=0,
                    help="max candidates per bucket (0 = full space)")
    ap.add_argument("--iters", type=int, default=3,
                    help="timing repetitions per candidate (median wins)")
    ap.add_argument("--limit", type=int, default=0,
                    help="cap the number of distinct buckets tuned (0 = all)")
    ap.add_argument("--no-flash", action="store_true",
                    help="skip flash-attention tuning for --arch")
    ap.add_argument("--expect-cached", action="store_true",
                    help="fail if anything had to be measured (warm-cache "
                         "assertion for CI)")
    ap.add_argument("--refresh-artifact", default=None, metavar="DIR",
                    help="after tuning, re-slice this repro.prepare "
                         "artifact's schedule from the cache and re-save it "
                         "(ships fresh schedules with the prepared weights)")
    args = ap.parse_args(argv)

    m_values = [int(x) for x in args.m.split(",") if x]
    algos = [a for a in args.algos.split(",") if a]
    dtypes = [jnp.dtype(d) for d in args.dtypes.split(",") if d]

    flash_jobs: List[Tuple[int, int, int, int]] = []
    if args.arch:
        cfg = configs.get_config(args.arch)
        if args.smoke:
            cfg = configs.smoke_config(cfg)
        shapes = _arch_gemm_shapes(cfg, m_values)
        if not args.no_flash:
            # q/k head dim as _flash_sdpa sees it: MLA prefill runs flash on
            # the decompressed nope+rope heads, everything else on cfg.hd.
            hd = (cfg.mla.nope_head_dim + cfg.mla.rope_head_dim
                  if cfg.mla is not None else cfg.hd)
            # key on the SERVING geometry: bucketed prefill dispatches the
            # forward over all batch_slots rows at the prompt-bucket width,
            # so flash sees BH = slots x heads and sq = sk = bucket. (The
            # --m values are tokens-per-dispatch for GEMMs, not batches.)
            flash_jobs = [(cfg.n_heads * b, s, s, hd)
                          for b in (int(x) for x in args.slots.split(",") if x)
                          for s in (int(x) for x in args.seq.split(",") if x)]
        label = cfg.name
    else:
        shapes = _workload_gemm_shapes(args.workload, args.batch)
        label = args.workload

    cache = tune.get_cache()
    timed0 = measure.counters["timed_candidates"]
    seen, jobs = set(), []
    for (m, k, n) in shapes:
        for algo in algos:
            for dt in dtypes:
                key = tune.gemm_key(algo, dt, m, n, k)
                if key not in seen:
                    seen.add(key)
                    jobs.append((key, m, k, n, algo, dt))
    if args.limit:
        # one cap over GEMM + flash buckets combined (GEMM jobs first)
        jobs = jobs[:args.limit]
        flash_jobs = flash_jobs[:max(0, args.limit - len(jobs))]

    t0 = time.perf_counter()
    measured = cached = 0
    for key, m, k, n, algo, dt in jobs:
        pre = measure.counters["timed_candidates"]
        entry = tune.tune_gemm(m, n, k, dt, algo=algo, budget=args.budget,
                               iters=args.iters, cache=cache, persist=False)
        fresh = measure.counters["timed_candidates"] > pre
        measured += fresh
        cached += not fresh
        b = entry["blocks"]
        status = "tuned " if fresh else "cached"
        print(f"[{status}] gemm {algo:8s} {jnp.dtype(dt).name:7s} "
              f"m{m} k{k} n{n} -> bm={b['bm']} bn={b['bn']} bk={b['bk']} "
              f"({entry['us']}us, {entry['candidates']} candidates)")

    flash_seen: set = set()
    for bh, sq, sk, d in flash_jobs:
        fkey = tune.flash_key(jnp.float32, bh, sq, sk, d)
        if fkey in flash_seen:       # slot counts sharing a pow2 BH bucket
            continue
        flash_seen.add(fkey)
        pre = measure.counters["timed_candidates"]
        entry = tune.tune_flash(bh, sq, sk, d, budget=args.budget,
                                iters=args.iters, cache=cache, persist=False)
        fresh = measure.counters["timed_candidates"] > pre
        measured += fresh
        cached += not fresh
        b = entry["blocks"]
        status = "tuned " if fresh else "cached"
        print(f"[{status}] flash fwd float32 bh{bh} sq{sq} sk{sk} d{d} "
              f"-> bq={b['bq']} bk={b['bk']} ({entry['us']}us)")

    if measured:
        cache.save()   # one write for the whole sweep, not one per bucket
    dt_s = time.perf_counter() - t0
    timed = measure.counters["timed_candidates"] - timed0
    print(f"{label}: {measured} buckets tuned / {cached} reused from cache "
          f"({timed} candidates timed, {dt_s:.1f}s) -> {cache.path}")
    if args.expect_cached and measured:
        print("--expect-cached: FAIL — warm cache still measured",
              file=sys.stderr)
        return 1
    if args.refresh_artifact:
        from repro import prepare
        from repro.kernels import compat
        pm = prepare.load(args.refresh_artifact)
        # re-slice for THIS device (the one we just tuned on) and re-stamp —
        # this is also the sanctioned way to re-home an artifact whose
        # schedule slice was dropped on a foreign device_kind.
        pm.device = compat.device_kind()
        pm.schedule = cache.entries_for_device(pm.device)
        pm.save(args.refresh_artifact)
        print(f"refreshed {args.refresh_artifact}: "
              f"{len(pm.schedule)} schedule entries for {pm.device}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
