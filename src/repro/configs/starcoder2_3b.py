"""starcoder2-3b [dense]: 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152 — GQA, RoPE. [arXiv:2402.19173; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, d_ff=12288,
    vocab=49152, norm="layernorm", act="gelu", qkv_bias=True,
    rope_theta=1e5, tie_embeddings=True,
    supports_long_context=False,
)
