"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=32000, ssm_state=64 — Mamba2 blocks + shared attention block.
[arXiv:2411.15242; hf]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab=32000,
    ssm=SSMConfig(version=2, d_state=64, d_conv=4, expand=2, head_dim=64,
                  n_groups=1, chunk=64),
    hybrid_attn_period=6,   # shared attn block every 6 mamba2 layers (simplified placement)
    supports_long_context=True,
)
