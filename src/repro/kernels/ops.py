"""Public jit'd wrappers over the Pallas GEMM kernels.

Handles: leading batch dims, padding M/N/K to block multiples (K padding is
exact for FIP/FFIP — zero rows of A and B contribute zero to cross/α/β),
dtype policy (int8→int32 accumulation, bf16→f32), block-size autotuning for
VMEM fit, and output slicing/casting.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.baseline_gemm import baseline_gemm
# Public surface for the Pallas API-drift shim (kernel modules import it from
# repro.kernels.compat to avoid a circular import with this module).
from repro.kernels.compat import tpu_compiler_params  # noqa: F401
from repro.kernels.fip_gemm import fip_gemm
from repro.kernels.ffip_gemm import ffip_gemm

Array = jax.Array

# VMEM budget per operand block (bytes) used by the block chooser. A v5e core
# has ~16 MiB VMEM; the FIP cross tensor is (bm, bk/2, bn) so bk is the lever.
_VMEM_BUDGET = 6 * 1024 * 1024


def choose_blocks(m: int, n: int, k: int, algo: str,
                  itemsize: int = 4) -> Tuple[int, int, int]:
    bm = min(128, _round_up_pow2(m))
    bn = min(128, _round_up_pow2(n))
    if algo == "baseline":
        bk = min(512, _round_up_pow2(k))
    else:
        # fit 3 x (bm, bk/2, bn) f32 tensors in budget
        bk = 8
        while (3 * bm * bn * (bk) // 2 * itemsize) <= _VMEM_BUDGET and bk < 256:
            bk *= 2
        bk //= 2
        bk = max(2, min(bk, _round_up_pow2(k)))
    return bm, bn, bk


def _round_up_pow2(x: int) -> int:
    p = 8
    while p < x and p < 1024:
        p *= 2
    return p


def _pad_to(x: Array, axis: int, mult: int) -> Array:
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


@functools.partial(jax.jit, static_argnames=("algo", "interpret", "bm", "bn", "bk"))
def matmul(a: Array, b: Array, *, algo: str = "ffip", interpret: bool = True,
           bm: int = 0, bn: int = 0, bk: int = 0) -> Array:
    """C = A @ B via the Pallas kernels. a: (..., M, K), b: (K, N).

    Returns the result cast back to the promoted input dtype for floats and
    int32 for integer inputs (hardware-accumulator semantics).
    """
    *batch, m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch {k} vs {k2}")
    a2 = a.reshape(-1, k) if batch else a
    mm = a2.shape[0]

    if not (bm and bn and bk):
        bm, bn, bk = choose_blocks(mm, n, k, algo)

    a2 = _pad_to(_pad_to(a2, 0, bm), 1, bk)
    b2 = _pad_to(_pad_to(b, 0, bk), 1, bn)

    if algo == "baseline":
        out = baseline_gemm(a2, b2, bm=bm, bn=bn, bk=bk, interpret=interpret)
    elif algo == "fip":
        out = fip_gemm(a2, b2, bm=bm, bn=bn, bk=bk, interpret=interpret)
    elif algo == "ffip":
        out = ffip_gemm(a2, b2, bm=bm, bn=bn, bk=bk, interpret=interpret)
    else:
        raise ValueError(algo)

    out = out[:mm, :n]
    if batch:
        out = out.reshape(*batch, m, n)
    if jnp.issubdtype(a.dtype, jnp.integer):
        return out  # int32 accumulator, caller rescales
    return out.astype(jnp.result_type(a.dtype, b.dtype))
