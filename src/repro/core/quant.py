"""Quantization substrate + the paper's ML-specific (F)FIP optimizations (§3.3, §4.4).

Implements:
  * symmetric / asymmetric per-tensor & per-channel int8/int16 quantization
    (Jacob et al. scheme the paper builds on),
  * the "both signed or both unsigned" recommendation (§4.4) — the ``d``
    bit-growth parameter and range checks,
  * beta folding into the bias (Eqs. 15/16),
  * the zero-point adjuster (Eq. 20): for weights stored with a constant
    zero-point matrix R, A(B+R) = AB + AR, and AR_ij = r_j * rowsum(A)_i is
    computable with ONE multiplier per output — folded into the alpha path.

Everything integer is bit-exact: quantized FIP/FFIP GEMM == quantized
baseline GEMM, validated in tests/test_quant.py.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import fip

Array = jax.Array

_INT_INFO = {
    jnp.int8.dtype: (-128, 127),
    jnp.uint8.dtype: (0, 255),
    jnp.int16.dtype: (-(2 ** 15), 2 ** 15 - 1),
    jnp.uint16.dtype: (0, 2 ** 16 - 1),
}


@dataclasses.dataclass(frozen=True)
class QuantParams:
    """Affine quantization: real = scale * (q - zero_point)."""
    scale: Array          # () or (channels,)
    zero_point: Array     # same shape as scale, stored int32
    dtype: jnp.dtype      # target integer dtype
    axis: Optional[int] = None  # channel axis, None = per-tensor


def d_bit_growth(a_signed: bool, b_signed: bool) -> int:
    """§4.1: d = 1 if a and b are both signed or both unsigned, else 2."""
    return 1 if a_signed == b_signed else 2


def preadd_bits(w: int, a_signed: bool, b_signed: bool) -> int:
    """§4.4: bits needed for the pre-add (a ± b sums): w + d."""
    return w + d_bit_growth(a_signed, b_signed)


def calibrate(x: Array, dtype=jnp.int8, *, symmetric: bool = True,
              axis: Optional[int] = None) -> QuantParams:
    """Min/max calibration producing QuantParams."""
    qmin, qmax = _INT_INFO[jnp.dtype(dtype)]
    reduce_axes = tuple(i for i in range(x.ndim) if i != axis) if axis is not None else None
    if symmetric:
        amax = jnp.max(jnp.abs(x), axis=reduce_axes)
        # signed: +/-qmax around 0. unsigned: +/-(range/2) around midpoint zp.
        bound = qmax if qmin < 0 else (qmax - qmin) // 2
        scale = jnp.maximum(amax / bound, 1e-12)
        zp = (jnp.zeros_like(scale, jnp.int32) if qmin < 0
              else jnp.full_like(scale, (qmax + 1) // 2).astype(jnp.int32))
    else:
        xmin = jnp.min(x, axis=reduce_axes)
        xmax = jnp.max(x, axis=reduce_axes)
        scale = jnp.maximum((xmax - xmin) / (qmax - qmin), 1e-12)
        zp = jnp.clip(jnp.round(qmin - xmin / scale), qmin, qmax).astype(jnp.int32)
    return QuantParams(scale=scale, zero_point=zp, dtype=jnp.dtype(dtype), axis=axis)


def quantize(x: Array, qp: QuantParams) -> Array:
    qmin, qmax = _INT_INFO[qp.dtype]
    scale, zp = qp.scale, qp.zero_point
    if qp.axis is not None:
        shape = [1] * x.ndim
        shape[qp.axis] = -1
        scale = scale.reshape(shape)
        zp = zp.reshape(shape)
    q = jnp.round(x / scale) + zp
    return jnp.clip(q, qmin, qmax).astype(qp.dtype)


def dequantize(q: Array, qp: QuantParams) -> Array:
    scale, zp = qp.scale, qp.zero_point
    if qp.axis is not None:
        shape = [1] * q.ndim
        shape[qp.axis] = -1
        scale = scale.reshape(shape)
        zp = zp.reshape(shape)
    return (q.astype(jnp.int32) - zp).astype(jnp.float32) * scale


# ---------------------------------------------------------------------------
# Integer GEMM with zero-points — baseline and (F)FIP, bit-exact.
# ---------------------------------------------------------------------------

def int_gemm_baseline(aq: Array, bq: Array, za: Array, zb: Array) -> Array:
    """(A - za)(B - zb) in int32, the reference quantized GEMM."""
    a32 = aq.astype(jnp.int32) - za
    b32 = bq.astype(jnp.int32) - zb
    return jnp.matmul(a32, b32)


def zero_point_adjuster(aq: Array, zb: Array, k: int) -> Array:
    """Eq. (20) adjuster: AR_ij = zb_j * rowsum(A)_i, one multiply per element.

    The paper folds this into the alpha-generator row; here it is an explicit
    rank-1 term: outer(rowsum(A), zb-broadcast).
    """
    rowsum = jnp.sum(aq.astype(jnp.int32), axis=-1)           # (..., M)
    zb_vec = jnp.broadcast_to(jnp.asarray(zb, jnp.int32), ())  # scalar zp
    return rowsum[..., :, None] * zb_vec                       # (..., M, 1) -> bcast


def int_gemm_ffip(aq: Array, bq: Array, za: Array, zb: Array,
                  *, algo: str = "ffip") -> Array:
    """Quantized GEMM via FIP/FFIP with the paper's §3.3/§4.4 optimizations.

    Strategy (mirrors the hardware):
      * run (F)FIP on the RAW quantized integers (both-signed, d=1),
      * beta of the raw weights is folded into the bias offline (Eq. 15),
      * the zero-point contributions are removed via the adjuster (Eq. 20)
        plus the constant K*za*zb and za*colsum(B) terms,
    producing bit-exact int32 equality with :func:`int_gemm_baseline`.
    """
    k = aq.shape[-1]
    mm = fip.fip_matmul if algo == "fip" else fip.ffip_matmul
    raw = mm(aq.astype(jnp.int32), bq.astype(jnp.int32))       # A_q B_q
    # remove zero-point contributions:
    # (A-za)(B-zb) = AB - za*colsum(B) - zb*rowsum(A) + K*za*zb
    rowsum_a = jnp.sum(aq.astype(jnp.int32), axis=-1, keepdims=True)
    colsum_b = jnp.sum(bq.astype(jnp.int32), axis=0, keepdims=True)
    za = jnp.asarray(za, jnp.int32)
    zb = jnp.asarray(zb, jnp.int32)
    return raw - za * colsum_b - zb * rowsum_a + k * za * zb


def quantized_dense_ffip(x: Array, w: Array, bias: Optional[Array],
                         xq: QuantParams, wq: QuantParams,
                         *, algo: str = "ffip") -> Array:
    """Full quantized dense layer: float in -> quant -> FFIP int GEMM -> dequant.

    beta folding: beta(W_q) is computed once from the quantized weights and
    folded into the integer bias (Eq. 15) — the (F)FIP beta subtraction then
    costs nothing at inference, exactly as in the paper.
    """
    aq = quantize(x, xq)
    bq = quantize(w, wq)
    k = aq.shape[-1]
    if k % 2 != 0:
        raise ValueError("pad K to even before quantized FFIP")
    mm_cross = fip.fip_cross_term(
        fip.pair_swap(aq.astype(jnp.int32)), fip.pair_swap_rows(bq.astype(jnp.int32))
    ) if algo == "ffip" else fip.fip_cross_term(
        aq.astype(jnp.int32), bq.astype(jnp.int32))
    alpha = fip.fip_alpha(aq.astype(jnp.int32))
    beta_folded = fip.fold_beta_into_bias(bq.astype(jnp.int32))   # -beta (Eq. 15)
    raw = mm_cross - alpha[..., :, None] + beta_folded            # == A_q B_q
    rowsum_a = jnp.sum(aq.astype(jnp.int32), axis=-1, keepdims=True)
    colsum_b = jnp.sum(bq.astype(jnp.int32), axis=0, keepdims=True)
    acc = raw - xq.zero_point * colsum_b - wq.zero_point * rowsum_a \
        + k * xq.zero_point * wq.zero_point
    out = acc.astype(jnp.float32) * (xq.scale * wq.scale)
    if bias is not None:
        out = out + bias
    return out
