"""Mixture-of-Experts: top-k router + capacity-bounded scatter dispatch.

Dispatch is the MaxText/Mesh-TF style position-in-expert scatter (no (T,E,C)
one-hot einsum tensor), shardable two ways (cfg.moe.partition):
  * "expert": expert axis sharded over `model` (EP) — DeepSeek (64 experts);
  * "ffn": d_ff of every expert sharded over `model` (TP-in-expert) — Mixtral
    (8 experts < 16-way model axis).
Aux load-balancing loss is the switch-transformer form.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

Array = jax.Array


def moe_init(key, cfg: ModelConfig, dtype) -> dict:
    m = cfg.moe
    d = cfg.d_model
    k_r, k_e, k_s = jax.random.split(key, 3)
    ke1, ke2, ke3 = jax.random.split(k_e, 3)
    e = m.n_experts
    std = 1.0 / (d ** 0.5)
    p = {
        "router": L.dense_init(k_r, d, e, dtype),
        "w_gate": (jax.random.normal(ke1, (e, d, m.d_ff_expert), jnp.float32) * std).astype(dtype),
        "w_up": (jax.random.normal(ke2, (e, d, m.d_ff_expert), jnp.float32) * std).astype(dtype),
        "w_down": (jax.random.normal(ke3, (e, m.d_ff_expert, d), jnp.float32)
                   * (1.0 / (m.d_ff_expert ** 0.5))).astype(dtype),
    }
    if m.n_shared:
        p["shared"] = L.mlp_init(k_s, d, m.d_ff_expert * m.n_shared, dtype)
    return p


def moe_apply(p: dict, x: Array, *, cfg: ModelConfig,
              ) -> Tuple[Array, Array]:
    """x: (B,S,d) -> (out, aux_loss)."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    logits = L.dense(xt, p["router"]).astype(jnp.float32)      # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, m.top_k)      # (T,k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # capacity-bounded positions: flatten (T,k) assignments in token order
    flat_e = expert_idx.reshape(-1)                            # (T*k,)
    onehot = jax.nn.one_hot(flat_e, m.n_experts, dtype=jnp.int32)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot             # (T*k,E)
    pos = jnp.sum(pos_in_e * onehot, axis=-1)                  # (T*k,)
    capacity = int(t * m.top_k * m.capacity_factor / m.n_experts) + 1
    keep = pos < capacity

    x_rep = jnp.repeat(xt, m.top_k, axis=0)                    # (T*k,d)
    buf = jnp.zeros((m.n_experts, capacity, d), x.dtype)
    buf = buf.at[flat_e, jnp.where(keep, pos, 0)].add(
        jnp.where(keep[:, None], x_rep, 0))

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"])       # (E,C,d)

    gathered = out_buf[flat_e, jnp.where(keep, pos, 0)]        # (T*k,d)
    gathered = jnp.where(keep[:, None], gathered, 0)
    weighted = gathered * gate_vals.reshape(-1)[:, None].astype(x.dtype)
    out = jnp.sum(weighted.reshape(t, m.top_k, d), axis=1)

    if m.n_shared:
        out = out + L.mlp(xt, p["shared"], cfg.act)

    # switch-style aux loss: E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)                               # (E,)
    ce = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], m.n_experts), axis=0)
    aux = m.n_experts * jnp.sum(me * ce) * m.router_aux_weight
    return out.reshape(b, s, d), aux
