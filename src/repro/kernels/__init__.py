from repro.kernels import conv_gemm, ops, ref  # noqa: F401
