"""Serving demo: continuous batching over a small model.

    PYTHONPATH=src python examples/serve_batch.py

Eight requests with different prompt lengths and token budgets stream through
four decode slots; each slot decodes at its OWN position (a (B,) position
vector flows through the fused decode program) and finished slots are
immediately refilled. Prompts prefill in power-of-2 length buckets, sampling
runs on device (only int32 ids reach the host), and ``decode_chunk`` fuses
several decode steps into one dispatch. Pass quantized=True to BatchServer to
route the projections through the int8 FFIP path instead."""
import time

import jax
import numpy as np

from repro import configs
from repro.models.model import build_model
from repro.serve.batcher import BatchServer, Request


def main():
    cfg = configs.smoke_config(configs.get_config("minicpm-2b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    srv = BatchServer(model, batch_slots=4, max_len=64, decode_chunk=2)

    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=(int(l),)),
                max_new_tokens=int(t))
        for i, (l, t) in enumerate(zip(rng.integers(3, 12, 8),
                                       rng.integers(2, 8, 8)))
    ]
    for r in reqs:
        srv.submit(r)

    t0 = time.perf_counter()
    steps = 0
    while True:
        n = srv.step(params)
        if n == 0 and not srv.has_queued():
            break
        steps += 1
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.out_tokens) for r in reqs)
    print(f"{len(reqs)} requests, {total_tokens} tokens in {steps} decode "
          f"steps ({dt:.2f}s host time)")
    for r in reqs:
        print(f"  rid={r.rid} prompt_len={len(r.prompt)} -> {r.out_tokens}")
    assert all(len(r.out_tokens) == r.max_new_tokens for r in reqs)
    print("OK")


if __name__ == "__main__":
    main()
