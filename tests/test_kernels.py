"""Per-kernel shape/dtype sweeps vs the pure-jnp oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref
from repro.kernels.ffip_gemm import ffip_gemm_y, ffip_gemm
from repro.kernels.fip_gemm import fip_gemm
from repro.kernels.baseline_gemm import baseline_gemm
from repro.core import fip

SHAPES = [
    (8, 8, 8),
    (16, 32, 16),
    (128, 128, 128),
    (64, 256, 32),
    (100, 60, 36),     # padding path
    (1, 130, 257),     # odd N, K padding
]
DTYPES = [jnp.float32, jnp.bfloat16, jnp.int8]
ALGOS = ["baseline", "fip", "ffip"]


def make_inputs(m, k, n, dtype, seed=0):
    ka, kb = jax.random.split(jax.random.PRNGKey(seed))
    if dtype == jnp.int8:
        a = jax.random.randint(ka, (m, k), -128, 128, dtype=jnp.int32).astype(jnp.int8)
        b = jax.random.randint(kb, (k, n), -128, 128, dtype=jnp.int32).astype(jnp.int8)
    else:
        a = jax.random.normal(ka, (m, k), jnp.float32).astype(dtype)
        b = jax.random.normal(kb, (k, n), jnp.float32).astype(dtype)
    return a, b


def tol_for(dtype, k):
    if dtype == jnp.bfloat16:
        return dict(rtol=5e-2, atol=5e-1)
    return dict(rtol=1e-4, atol=1e-3 * max(1, k // 64))


@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("m,k,n", SHAPES)
def test_kernel_matches_oracle(algo, dtype, m, k, n):
    a, b = make_inputs(m, k, n, dtype)
    got = ops.matmul(a, b, algo=algo, interpret=True)
    if dtype == jnp.int8:
        want = np.asarray(a, np.int64) @ np.asarray(b, np.int64)
        np.testing.assert_array_equal(np.asarray(got, np.int64), want)
    else:
        want = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
        np.testing.assert_allclose(np.asarray(got, np.float64), want,
                                   **tol_for(dtype, k))


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (16, 16, 4), (32, 8, 16)])
def test_block_shape_sweep_ffip(bm, bn, bk):
    m, k, n = 64, 32, 48
    a, b = make_inputs(m, k, n, jnp.float32, seed=3)
    got = ffip_gemm(a, b, bm=bm, bn=bn, bk=bk, interpret=True)
    want = ref.matmul_ref(a, b, "baseline")
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (16, 16, 4)])
def test_block_shape_sweep_fip(bm, bn, bk):
    m, k, n = 32, 16, 32
    a, b = make_inputs(m, k, n, jnp.float32, seed=4)
    got = fip_gemm(a, b, bm=bm, bn=bn, bk=bk, interpret=True)
    np.testing.assert_allclose(got, ref.matmul_ref(a, b, "baseline"),
                               rtol=1e-4, atol=1e-3)


def test_ffip_y_operand_never_materializes_b():
    """FFIP kernel consumes y only; reconstruct inside — int path bit-exact."""
    a, b = make_inputs(32, 16, 24, jnp.int8, seed=5)
    y = fip.make_y(b.astype(jnp.int32))   # 1-extra-bit storage, §4.4
    got = ffip_gemm_y(a.astype(jnp.int32), y, bm=8, bn=8, bk=8, interpret=True)
    want = np.asarray(a, np.int64) @ np.asarray(b, np.int64)
    np.testing.assert_array_equal(np.asarray(got, np.int64), want)


def test_ffip_y_memoized_per_weight(monkeypatch):
    """The paper deploys y as an OFFLINE weight transform (§4.4): repeated
    eager ffip_gemm calls against the same weight array derive y once, and a
    precomputed y can be passed in so make_y is never called at all."""
    from repro.kernels import ffip_gemm as FG
    a, b = make_inputs(16, 8, 8, jnp.int8, seed=8)
    a32, b32 = a.astype(jnp.int32), b.astype(jnp.int32)
    calls = []
    orig = fip.make_y
    monkeypatch.setattr(FG.fip, "make_y", lambda x: calls.append(1) or orig(x))
    want = np.asarray(a, np.int64) @ np.asarray(b, np.int64)
    for _ in range(3):
        got = FG.ffip_gemm(a32, b32, bm=8, bn=8, bk=8, interpret=True)
        np.testing.assert_array_equal(np.asarray(got, np.int64), want)
    assert len(calls) == 1, "make_y recomputed for a cached weight"
    got = FG.ffip_gemm(a32, b32, y=orig(b32), bm=8, bn=8, bk=8,
                       interpret=True)
    np.testing.assert_array_equal(np.asarray(got, np.int64), want)
    assert len(calls) == 1


def test_fold_beta_kernel_plus_bias():
    """Kernel with fold_beta=True + Eq.(15) bias == full product."""
    a, b = make_inputs(16, 8, 8, jnp.int8, seed=6)
    a32, b32 = a.astype(jnp.int32), b.astype(jnp.int32)
    folded = fip.fold_beta_into_bias(b32)
    got = fip_gemm(a32, b32, bm=8, bn=8, bk=8, interpret=True,
                   fold_beta=True) + folded[None, :]
    want = np.asarray(a, np.int64) @ np.asarray(b, np.int64)
    np.testing.assert_array_equal(np.asarray(got, np.int64), want)


def test_batched_wrapper():
    ka, kb = jax.random.split(jax.random.PRNGKey(7))
    a = jax.random.normal(ka, (2, 3, 16, 32))
    b = jax.random.normal(kb, (32, 8))
    got = ops.matmul(a, b, algo="ffip", interpret=True)
    np.testing.assert_allclose(got, a @ b, rtol=1e-4, atol=1e-3)


def test_baseline_kernel_large_block():
    a, b = make_inputs(256, 512, 128, jnp.float32, seed=8)
    got = baseline_gemm(a, b, bm=128, bn=128, bk=128, interpret=True)
    np.testing.assert_allclose(got, np.asarray(a, np.float64) @ np.asarray(b, np.float64),
                               rtol=1e-4, atol=1e-2)


# --- pad-run-slice fallback + backend-auto interpret -------------------------

@pytest.mark.parametrize("kernel", [baseline_gemm, fip_gemm, ffip_gemm])
def test_kernel_direct_nondivisible_shapes_pad_and_slice(kernel):
    """Raw kernels no longer hard-assert divisibility: shapes indivisible by
    every block dim zero-pad, run, and slice — exactly (int path bit-checked),
    so the tuner can consider any legal block on any shape and odd model dims
    don't crash."""
    a, b = make_inputs(20, 10, 13, jnp.int8, seed=21)
    got = kernel(a, b, bm=16, bn=8, bk=4, interpret=True)
    assert got.shape == (20, 13)
    want = np.asarray(a, np.int64) @ np.asarray(b, np.int64)
    np.testing.assert_array_equal(np.asarray(got, np.int64), want)


def test_kernel_interpret_auto_default():
    """interpret=None (the new default) resolves via the backend probe:
    interpret-mode on this CPU host, compiled on TPU; explicit bools win."""
    from repro.kernels import compat
    assert compat.resolve_interpret(None) == (not compat.is_tpu_backend())
    assert compat.resolve_interpret(True) is True
    assert compat.resolve_interpret(False) is False
    if compat.is_tpu_backend():   # container is CPU; guard for TPU runs
        pytest.skip("auto-default smoke below assumes a CPU host")
    a, b = make_inputs(16, 16, 16, jnp.float32, seed=22)
    got = fip_gemm(a, b, bm=8, bn=8, bk=8)          # no interpret kwarg
    np.testing.assert_allclose(got, a @ b, rtol=1e-4, atol=1e-3)


# --- Pallas API-drift canary --------------------------------------------------
# pltpu.CompilerParams/TPUCompilerParams has already been renamed once across
# JAX releases. Build AND run every kernel entry point in interpret mode so
# the next API break surfaces here, at unit-test cost, instead of deep inside
# a smoke or system test.

def _drift_baseline():
    a, b = make_inputs(16, 16, 16, jnp.float32, seed=11)
    return baseline_gemm(a, b, bm=8, bn=8, bk=8, interpret=True), a @ b


def _drift_fip():
    a, b = make_inputs(16, 16, 16, jnp.float32, seed=12)
    return fip_gemm(a, b, bm=8, bn=8, bk=8, interpret=True), a @ b


def _drift_ffip():
    a, b = make_inputs(16, 16, 16, jnp.float32, seed=13)
    return ffip_gemm(a, b, bm=8, bn=8, bk=8, interpret=True), a @ b


def _drift_flash_attention():
    from repro.kernels.flash_attention import flash_attention
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(14), 3)
    q = jax.random.normal(kq, (2, 16, 8))
    k = jax.random.normal(kk, (2, 16, 8))
    v = jax.random.normal(kv, (2, 16, 8))
    got = flash_attention(q, k, v, 0, True, True)
    s = jnp.einsum("bqd,bkd->bqk", q, k) / (8 ** 0.5)
    mask = jnp.tril(jnp.ones((16, 16), bool))
    s = jnp.where(mask, s, -1e30)
    want = jnp.einsum("bqk,bkd->bqd", jax.nn.softmax(s, axis=-1), v)
    return got, want


def _drift_flash_attention_bwd():
    from repro.kernels.flash_attention import flash_attention
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(15), 3)
    q = jax.random.normal(kq, (1, 16, 8))
    k = jax.random.normal(kk, (1, 16, 8))
    v = jax.random.normal(kv, (1, 16, 8))
    g = jax.grad(lambda q_: jnp.sum(flash_attention(q_, k, v, 0, True, True)))(q)
    return g, None  # build/run check; numerics covered in test_flash_attention

def _drift_selective_scan():
    from repro.kernels.selective_scan import selective_scan
    ks = jax.random.split(jax.random.PRNGKey(16), 5)
    bt, s, di, n = 1, 8, 8, 4
    x = jax.random.normal(ks[0], (bt, s, di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bt, s, di)))
    b = jax.random.normal(ks[2], (bt, s, n))
    c = jax.random.normal(ks[3], (bt, s, n))
    a = -jnp.exp(jax.random.normal(ks[4], (di, n)))
    h0 = jnp.zeros((bt, di, n))
    y, h, _ = selective_scan(x, dt, b, c, a, h0, chunk=8, bd=8, interpret=True)
    return jnp.concatenate([y.ravel(), h.ravel()]), None


def _drift_selective_scan_bwd():
    from repro.kernels.selective_scan import selective_scan_trainable
    ks = jax.random.split(jax.random.PRNGKey(17), 5)
    bt, s, di, n = 1, 8, 8, 4
    x = jax.random.normal(ks[0], (bt, s, di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bt, s, di)))
    b = jax.random.normal(ks[2], (bt, s, n))
    c = jax.random.normal(ks[3], (bt, s, n))
    a = -jnp.exp(jax.random.normal(ks[4], (di, n)))
    h0 = jnp.zeros((bt, di, n))
    g = jax.grad(lambda x_: jnp.sum(
        selective_scan_trainable(x_, dt, b, c, a, h0, 8, 8)))(x)
    return g.ravel(), None


_DRIFT_CASES = {
    "baseline_gemm": _drift_baseline,
    "fip_gemm": _drift_fip,
    "ffip_gemm": _drift_ffip,
    "flash_attention": _drift_flash_attention,
    "flash_attention_bwd": _drift_flash_attention_bwd,
    "selective_scan": _drift_selective_scan,
    "selective_scan_bwd": _drift_selective_scan_bwd,
}


def test_compiler_params_compat_alias():
    """The shim resolves whichever spelling the installed Pallas exposes."""
    from jax.experimental.pallas import tpu as pltpu
    from repro.kernels.compat import tpu_compiler_params
    assert hasattr(pltpu, "CompilerParams") or hasattr(pltpu, "TPUCompilerParams")
    params = tpu_compiler_params(
        dimension_semantics=("parallel", "arbitrary"))
    assert params is not None
    assert tuple(params.dimension_semantics) == ("parallel", "arbitrary")


@pytest.mark.parametrize("name", sorted(_DRIFT_CASES))
def test_kernel_builds_and_runs_interpret(name):
    got, want = _DRIFT_CASES[name]()
    got = np.asarray(got)
    assert np.all(np.isfinite(got)), name
    if want is not None:
        np.testing.assert_allclose(got, np.asarray(want), rtol=1e-4, atol=1e-3)
