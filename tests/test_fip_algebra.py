"""Unit + property tests for the FIP/FFIP algebra (paper §3, incl. §3.2.1 proof)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import fip


def rand(key, shape, dtype=jnp.float32, lo=-8, hi=8):
    if jnp.issubdtype(dtype, jnp.integer):
        return jax.random.randint(key, shape, lo, hi, dtype=dtype)
    return jax.random.normal(key, shape, dtype=dtype)


@pytest.mark.parametrize("m,k,n", [(4, 8, 6), (16, 32, 16), (1, 2, 1), (7, 10, 5)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
def test_fip_equals_baseline(m, k, n, dtype):
    ka, kb = jax.random.split(jax.random.PRNGKey(0))
    a = rand(ka, (m, k), dtype)
    b = rand(kb, (k, n), dtype)
    want = fip.baseline_matmul(a, b)
    got = fip.fip_matmul(a, b)
    if dtype == jnp.int32:
        np.testing.assert_array_equal(got, want)   # bit-exact for ints
    else:
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("m,k,n", [(4, 8, 6), (16, 32, 16), (3, 6, 9)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
def test_ffip_equals_baseline(m, k, n, dtype):
    ka, kb = jax.random.split(jax.random.PRNGKey(1))
    a = rand(ka, (m, k), dtype)
    b = rand(kb, (k, n), dtype)
    want = fip.baseline_matmul(a, b)
    got = fip.ffip_matmul(a, b)
    if dtype == jnp.int32:
        np.testing.assert_array_equal(got, want)
    else:
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_ffip_scan_dataflow_matches():
    """The literal Eq.(7)-(9) column recurrence (hardware dataflow) is exact."""
    ka, kb = jax.random.split(jax.random.PRNGKey(2))
    a = rand(ka, (12, 16), jnp.int32)
    b = rand(kb, (16, 10), jnp.int32)
    y = fip.make_y(b)
    got = fip.ffip_matmul_scan(a, y, beta=fip.fip_beta(b))
    np.testing.assert_array_equal(got, a @ b)


def test_y_roundtrip():
    b = rand(jax.random.PRNGKey(3), (16, 10), jnp.int32)
    np.testing.assert_array_equal(fip.y_to_b(fip.make_y(b)), b)


def test_beta_folding():
    """Eqs. (15)/(16): subtracting beta via bias is exact."""
    ka, kb = jax.random.split(jax.random.PRNGKey(4))
    a = rand(ka, (8, 12), jnp.int32)
    b = rand(kb, (12, 6), jnp.int32)
    bias = rand(jax.random.PRNGKey(5), (6,), jnp.int32)
    folded = fip.fold_beta_into_bias(b, bias)
    got = fip.fip_matmul_beta_folded(a, b, folded)
    np.testing.assert_array_equal(got, a @ b + bias)


def test_proof_replay_g_equals_h():
    """§3.2.1: the recurrence-built g^{(j)} equals the closed-form h^{(j)}."""
    ka, kb = jax.random.split(jax.random.PRNGKey(6))
    a = rand(ka, (5, 8), jnp.int32)
    b = rand(kb, (8, 7), jnp.int32)
    for j in range(b.shape[1]):
        g = fip.g_terms_by_recurrence(a, b, j)
        h = fip.h_terms(a, b, j)
        np.testing.assert_array_equal(g, h)


def test_pair_swap_involution():
    a = rand(jax.random.PRNGKey(7), (4, 10))
    np.testing.assert_array_equal(fip.pair_swap(fip.pair_swap(a)), a)


def test_odd_k_raises():
    a = jnp.ones((4, 5))
    b = jnp.ones((5, 3))
    with pytest.raises(ValueError):
        fip.fip_matmul(a, b)


def test_k_chunked_cross_term():
    ka, kb = jax.random.split(jax.random.PRNGKey(8))
    a = rand(ka, (8, 64))
    b = rand(kb, (64, 12))
    full = fip.fip_matmul(a, b)
    chunked = fip.fip_matmul(a, b, k_chunk=8)
    np.testing.assert_allclose(chunked, full, rtol=1e-5, atol=1e-4)


def test_batched_operands():
    ka, kb = jax.random.split(jax.random.PRNGKey(9))
    a = rand(ka, (3, 4, 8))
    b = rand(kb, (8, 6))
    np.testing.assert_allclose(fip.ffip_matmul(a, b), a @ b, rtol=1e-5, atol=1e-4)


def test_trainable_gradients_match_baseline():
    ka, kb = jax.random.split(jax.random.PRNGKey(10))
    a = rand(ka, (6, 8))
    b = rand(kb, (8, 4))

    def loss_fip(a, b):
        return jnp.sum(jnp.sin(fip.ffip_matmul_trainable(a, b, 0)))

    def loss_base(a, b):
        return jnp.sum(jnp.sin(a @ b))

    ga1, gb1 = jax.grad(loss_fip, argnums=(0, 1))(a, b)
    ga2, gb2 = jax.grad(loss_base, argnums=(0, 1))(a, b)
    np.testing.assert_allclose(ga1, ga2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(gb1, gb2, rtol=1e-5, atol=1e-5)


@settings(max_examples=50, deadline=None)
@given(
    m=st.integers(1, 12), kh=st.integers(1, 12), n=st.integers(1, 12),
    seed=st.integers(0, 2 ** 16),
)
def test_property_fip_ffip_int_exact(m, kh, n, seed):
    """Property: for any int matrices with even K, all three algorithms agree
    bit-exactly (the paper's central algebraic identity)."""
    k = 2 * kh
    ka, kb = jax.random.split(jax.random.PRNGKey(seed))
    a = jax.random.randint(ka, (m, k), -100, 100, dtype=jnp.int32)
    b = jax.random.randint(kb, (k, n), -100, 100, dtype=jnp.int32)
    want = np.asarray(a) @ np.asarray(b)
    np.testing.assert_array_equal(fip.fip_matmul(a, b), want)
    np.testing.assert_array_equal(fip.ffip_matmul(a, b), want)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2 ** 16), kh=st.integers(1, 8))
def test_property_int8_range_growth(seed, kh):
    """§4.4: both-signed int8 pre-adds fit in w+1 = 9 bits (d=1)."""
    k = 2 * kh
    ka, kb = jax.random.split(jax.random.PRNGKey(seed))
    a = jax.random.randint(ka, (4, k), -128, 128, dtype=jnp.int32)
    b = jax.random.randint(kb, (k, 4), -128, 128, dtype=jnp.int32)
    t1 = a[:, 0::2][:, :, None] + b[1::2, :][None, :, :]
    t2 = a[:, 1::2][:, :, None] + b[0::2, :][None, :, :]
    for t in (t1, t2):
        assert int(jnp.max(t)) <= 2 ** 8 - 1 + 2 ** 7  # < 2^8+2^7, fits 9-bit signed
        assert int(jnp.min(t)) >= -(2 ** 8)
