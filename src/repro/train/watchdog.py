"""Straggler mitigation + failure handling for the training loop.

The EMA/dead-man logic now lives in the shared :mod:`repro.watchdog` (the
serving replica router drives the SAME implementation against its tick
clock); this module keeps the training-facing names stable.
"""
from __future__ import annotations

from repro.watchdog import HangError, Watchdog, WatchdogConfig

__all__ = ["HangError", "StepWatchdog", "WatchdogConfig"]


class StepWatchdog(Watchdog):
    """Training-loop alias of the shared watchdog (real clock by default)."""
