"""Model stacks: decoder-only (dense/MoE/MLA), SSM, hybrid, and enc-dec.

All stacks scan over layers with stacked parameters so HLO size is
depth-independent (62-layer models compile like 2-layer ones). Per-layer
heterogeneity (gemma3 local:global windows/thetas, mixtral SWA) is carried as
scanned (L,)-arrays, never by unrolling.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as S

Array = jax.Array
PyTree = Any


def norm_init(cfg: ModelConfig, dtype):
    return (L.layernorm_init(cfg.d_model, dtype) if cfg.norm == "layernorm"
            else L.rmsnorm_init(cfg.d_model, dtype))


def norm_apply(x, p, cfg: ModelConfig):
    return (L.layernorm(x, p, cfg.norm_eps) if cfg.norm == "layernorm"
            else L.rmsnorm(x, p, cfg.norm_eps))


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def block_init(key, cfg: ModelConfig, dtype, *, kind: str) -> dict:
    """kind encodes attention x ffn: dense | moe | mla_moe | mla_dense |
    ssm1 | ssm2 | encdec | encoder. '*moe' kinds take the MoE FFN; 'mla*'
    kinds take MLA attention."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: dict = {"ln1": norm_init(cfg, dtype)}
    if kind.startswith("mla"):
        p["attn"] = A.mla_init(k1, cfg, dtype)
    elif kind == "ssm1":
        p["ssm"] = S.mamba1_init(k1, cfg, dtype)
        return p
    elif kind == "ssm2":
        p["ssm"] = S.mamba2_init(k1, cfg, dtype)
        return p
    else:
        p["attn"] = A.gqa_init(k1, cfg, dtype)
    p["ln2"] = norm_init(cfg, dtype)
    if kind.endswith("moe"):
        p["ffn"] = MOE.moe_init(k2, cfg, dtype)
    else:
        d_ff = cfg.d_ff
        p["ffn"] = L.mlp_init(k2, cfg.d_model, d_ff, dtype)
    if kind == "encdec":
        p["ln_x"] = norm_init(cfg, dtype)
        p["xattn"] = A.cross_init(k3, cfg, dtype)
    return p


def block_apply(p: dict, x: Array, *, cfg: ModelConfig, kind: str,
                positions: Array, window=0, theta=None, causal: bool = True,
                cache: Optional[dict] = None, cache_pos=None,
                cache_write_mask: Optional[Array] = None,
                enc: Optional[Array] = None,
                cross_kv: Optional[dict] = None, prefill: bool = False,
                page_table: Optional[Array] = None,
                paged_impl: str = "gather",
                ) -> Tuple[Array, Optional[dict], Array]:
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("ssm1", "ssm2"):
        if page_table is not None:
            raise ValueError("paged KV cache requires attention layers; "
                             f"got layer kind {kind!r}")
        if kind == "ssm1":
            h, new_cache = S.mamba1_apply(p["ssm"], norm_apply(x, p["ln1"], cfg),
                                          cfg=cfg, cache=cache, prefill=prefill)
        else:
            h, new_cache = S.mamba2_apply(p["ssm"], norm_apply(x, p["ln1"], cfg),
                                          cfg=cfg, cache=cache)
        return x + h, new_cache, aux

    attn_fn = (functools.partial(A.mla_apply, prefill=prefill)
               if kind.startswith("mla") else functools.partial(
                   A.gqa_apply, rope_theta=theta, causal=causal,
                   prefill=prefill))
    h, new_cache = attn_fn(p["attn"], norm_apply(x, p["ln1"], cfg), cfg=cfg,
                           positions=positions, window=window, cache=cache,
                           cache_pos=cache_pos,
                           cache_write_mask=cache_write_mask,
                           page_table=page_table, paged_impl=paged_impl)
    x = x + h
    if kind == "encdec":
        xh = A.cross_apply(p["xattn"], norm_apply(x, p["ln_x"], cfg),
                           enc, cfg) if cross_kv is None else \
            _cross_from_kv(p["xattn"], norm_apply(x, p["ln_x"], cfg), cross_kv, cfg)
        x = x + xh
    h2 = norm_apply(x, p["ln2"], cfg)
    if kind.endswith("moe"):
        f, aux = MOE.moe_apply(p["ffn"], h2, cfg=cfg)
    else:
        f = L.mlp(h2, p["ffn"], cfg.act)
    return x + f, new_cache, aux


def _cross_from_kv(p, x, cross_kv, cfg):
    """Cross-attention against cached encoder K/V (decode path)."""
    b, s, d = x.shape
    hd = cfg.hd
    q = L.dense(x, p["wq"]).reshape(b, s, cfg.n_heads, hd)
    out = A._sdpa(q, cross_kv["k"], cross_kv["v"], None)
    return L.dense(out.reshape(b, s, cfg.n_heads * hd), p["wo"])


def make_cross_kv(p_stacked: dict, enc: Array, cfg: ModelConfig) -> dict:
    """Precompute per-layer cross K/V from encoder output (prefill)."""
    def one(p):
        b, t, _ = enc.shape
        k = L.dense(enc, p["xattn"]["wk"]).reshape(b, t, cfg.n_kv_heads, cfg.hd)
        v = L.dense(enc, p["xattn"]["wv"]).reshape(b, t, cfg.n_kv_heads, cfg.hd)
        return {"k": k, "v": v}
    return jax.lax.map(one, p_stacked)


# ---------------------------------------------------------------------------
# Layer plans: what kind each scan-group is, plus per-layer window/theta arrays
# ---------------------------------------------------------------------------

def layer_plan(cfg: ModelConfig):
    """Returns list of (group_name, kind, n_layers). Scans run per group."""
    if cfg.family == "ssm":
        return [("layers", "ssm1" if cfg.ssm.version == 1 else "ssm2", cfg.n_layers)]
    if cfg.family == "hybrid":
        period = cfg.hybrid_attn_period or cfg.n_layers
        n_full = cfg.n_layers // period
        rem = cfg.n_layers - n_full * period
        plan = [("hybrid_groups", "ssm2", n_full * period)]
        if rem:
            plan.append(("tail", "ssm2", rem))
        return plan
    if cfg.family == "moe":
        plan = []
        if cfg.first_k_dense:
            plan.append(("dense_head", "mla_dense" if cfg.mla else "dense",
                         cfg.first_k_dense))
        plan.append(("layers", "mla_moe" if cfg.mla else "moe",
                     cfg.n_layers - cfg.first_k_dense))
        return plan
    if cfg.family == "enc-dec":
        return [("layers", "encdec", cfg.n_layers)]
    return [("layers", "dense", cfg.n_layers)]


def window_theta_arrays(cfg: ModelConfig, n: int, offset: int = 0):
    """(window, theta) per layer as numpy arrays for the scan."""
    win = np.zeros((n,), np.int32)
    theta = np.full((n,), cfg.rope_theta, np.float32)
    for i in range(n):
        li = i + offset
        if cfg.local_global_period:
            is_global = (li + 1) % cfg.local_global_period == 0
            win[i] = 0 if is_global else cfg.sliding_window
            theta[i] = (cfg.rope_theta_global or cfg.rope_theta) if is_global \
                else cfg.rope_theta
        elif cfg.sliding_window:
            win[i] = cfg.sliding_window
    return jnp.asarray(win), jnp.asarray(theta)


# ---------------------------------------------------------------------------
# Whole-model init
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig) -> PyTree:
    dtype = cfg.dtype
    keys = jax.random.split(key, 8)
    params: Dict[str, PyTree] = {
        "embed": L.embed_init(keys[0], cfg.vocab, cfg.d_model, dtype),
        "final_norm": norm_init(cfg, dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.dense_init(keys[1], cfg.d_model, cfg.vocab, dtype)

    def stacked(key, n, kind):
        ks = jax.random.split(key, n)
        return jax.vmap(lambda k: block_init(k, cfg, dtype, kind=kind))(ks)

    for gi, (name, kind, n) in enumerate(layer_plan(cfg)):
        params[name] = stacked(keys[2 + gi], n, kind)

    if cfg.family == "hybrid" and cfg.hybrid_attn_period:
        params["shared_attn"] = {
            "ln": norm_init(cfg, dtype),
            "attn": A.gqa_init(keys[6], cfg, dtype),
        }
    if cfg.encoder is not None:
        ks = jax.random.split(keys[7], cfg.encoder.n_layers)
        params["encoder"] = {
            "layers": jax.vmap(lambda k: block_init(k, cfg, dtype, kind="encoder"))(ks),
            "norm": norm_init(cfg, dtype),
        }
    return params


# ---------------------------------------------------------------------------
# Forward passes (training / prefill / decode share one scan machinery)
# ---------------------------------------------------------------------------

def _maybe_remat(body, cfg: ModelConfig):
    """Per-layer rematerialisation policy for the layer scans (train memory)."""
    if cfg.remat == "dots":
        return jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    if cfg.remat == "full":
        return jax.checkpoint(body)
    return body


def _scan_group(p_stacked, x, *, cfg, kind, positions, windows=None,
                thetas=None, causal=True, caches=None, cache_pos=None,
                cache_write_mask=None, enc=None, cross_kvs=None,
                prefill=False, page_table=None, paged_impl="gather"):
    """lax.scan over a stacked layer group. caches/cross_kvs are stacked on
    the leading (layer) axis when present."""
    n = jax.tree_util.tree_leaves(p_stacked)[0].shape[0]
    if windows is None:
        windows = jnp.zeros((n,), jnp.int32)
    if thetas is None:
        thetas = jnp.full((n,), cfg.rope_theta, jnp.float32)

    def body(carry, xs):
        x, aux_acc = carry
        if caches is not None and cross_kvs is not None:
            p, w, th, c, ckv = xs
        elif caches is not None:
            p, w, th, c = xs
            ckv = None
        elif cross_kvs is not None:
            p, w, th, ckv = xs
            c = None
        else:
            p, w, th = xs
            c, ckv = None, None
        x, new_c, aux = block_apply(
            p, x, cfg=cfg, kind=kind, positions=positions, window=w, theta=th,
            causal=causal, cache=c, cache_pos=cache_pos,
            cache_write_mask=cache_write_mask, enc=enc,
            cross_kv=ckv, prefill=prefill, page_table=page_table,
            paged_impl=paged_impl)
        return (x, aux_acc + aux), new_c

    body = _maybe_remat(body, cfg)
    xs = (p_stacked, windows, thetas)
    if caches is not None:
        xs = xs + (caches,)
    if cross_kvs is not None:
        xs = xs + (cross_kvs,)
    (x, aux), new_caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    return x, aux, new_caches


def _hybrid_forward(params, x, *, cfg, positions, caches=None, cache_pos=None,
                    prefill=False):
    """Zamba2: groups of `period` mamba2 layers, shared attn after each group."""
    period = cfg.hybrid_attn_period
    n_full = cfg.n_layers // period
    aux_total = jnp.zeros((), jnp.float32)

    p_groups = jax.tree.map(
        lambda t: t.reshape(n_full, period, *t.shape[1:]), params["hybrid_groups"])
    sa = params.get("shared_attn")

    def group_body(carry, xs):
        x, _ = carry
        p_grp, c_grp, sa_cache = xs if caches is not None else (xs, None, None)
        x, aux, new_c = _scan_group(p_grp, x, cfg=cfg, kind="ssm2",
                                    positions=positions, caches=c_grp,
                                    cache_pos=cache_pos)
        h, new_sa = A.gqa_apply(sa["attn"], norm_apply(x, sa["ln"], cfg),
                                cfg=cfg, positions=positions, window=0,
                                cache=sa_cache, cache_pos=cache_pos,
                                prefill=prefill)
        x = x + h
        return (x, aux), (new_c, new_sa)

    group_body = _maybe_remat(group_body, cfg)
    if caches is not None:
        xs = (p_groups, caches["hybrid_groups"], caches["shared_attn"])
    else:
        xs = p_groups
    (x, aux), outs = jax.lax.scan(group_body, (x, aux_total), xs)
    new_caches = {}
    if caches is not None:
        new_caches["hybrid_groups"], new_caches["shared_attn"] = outs
    if "tail" in params:
        tail_c = caches["tail"] if caches is not None else None
        x, aux2, new_tail = _scan_group(params["tail"], x, cfg=cfg, kind="ssm2",
                                        positions=positions, caches=tail_c,
                                        cache_pos=cache_pos)
        if caches is not None:
            new_caches["tail"] = new_tail
    return x, aux, (new_caches if caches is not None else None)


def encode(params, frames: Array, cfg: ModelConfig) -> Array:
    """Whisper encoder over (stub) precomputed frame embeddings."""
    t = frames.shape[1]
    positions = jnp.arange(t, dtype=jnp.int32)
    x, _, _ = _scan_group(params["encoder"]["layers"], frames, cfg=cfg,
                          kind="encoder", positions=positions, causal=False)
    return norm_apply(x, params["encoder"]["norm"], cfg)


def forward(params, tokens: Array, cfg: ModelConfig, *,
            frames: Optional[Array] = None,
            patches: Optional[Array] = None,
            caches: Optional[dict] = None, cache_pos=None,
            cache_write_mask: Optional[Array] = None,
            is_prefill: bool = False,
            page_table: Optional[Array] = None,
            paged_impl: str = "gather",
            ) -> Tuple[Array, Array, Optional[dict]]:
    """Token ids -> final hidden states. Returns (hidden, aux_loss, new_caches).

    * train/prefill: caches=None / caches=zeros, full sequence.
    * decode: tokens (B,1), caches + cache_pos set.
    * cache_write_mask: optional (B,) bool — batch rows with False leave the
      cache untouched (bucketed prefill runs over the SHARED slot cache and
      only commits the admitted rows; live slots keep their K/V). With a
      page table it may also be (B, S) bool — per-token masks for a padded
      prefill chunk's tail.
    * page_table: optional (B, max_pages) int32 — caches hold PAGE POOLS (see
      init_paged_cache) and attention layers address them through the table;
      paged_impl selects "gather" (bit-exact oracle) or "flash" (in-kernel
      gather).
    * frames: whisper encoder stub embeddings; patches: vlm prefix embeddings.
    """
    x = L.embed(tokens, params["embed"])
    b, s = tokens.shape[:2]
    n_prefix = 0
    if patches is not None:   # vlm prefix (train + prefill; decode passes None)
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
        n_prefix = patches.shape[1]
        s = x.shape[1]
    if cache_pos is not None:
        # cache_pos: scalar (shared offset — prefill / legacy decode) or a
        # (B,) per-slot position vector (continuous-batching decode).
        cp = jnp.asarray(cache_pos, jnp.int32)
        if cp.ndim == 1:
            positions = cp[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
        else:
            positions = cp + jnp.arange(s, dtype=jnp.int32)[None, :]
        positions = jnp.broadcast_to(positions, (b, s))
    else:
        positions = jnp.arange(s, dtype=jnp.int32)

    enc = None
    cross_kvs = None
    if cfg.encoder is not None:
        if frames is not None:
            enc = encode(params, frames, cfg)
            if caches is not None:   # prefill: cache per-layer cross K/V
                cross_kvs = make_cross_kv(params["layers"], enc, cfg)
        else:
            cross_kvs = caches["cross_kv"]   # decode: reuse cached cross K/V

    aux_total = jnp.zeros((), jnp.float32)
    new_caches: Optional[dict] = {} if caches is not None else None

    if cfg.family == "hybrid":
        if page_table is not None:
            raise ValueError("paged KV cache is not supported for hybrid "
                             "(SSM-state) stacks")
        x, aux_total, new_caches = _hybrid_forward(
            params, x, cfg=cfg, positions=positions, caches=caches,
            cache_pos=cache_pos, prefill=is_prefill)
    else:
        offset = 0
        for name, kind, n in layer_plan(cfg):
            win, theta = window_theta_arrays(cfg, n, offset)
            grp_cache = caches.get(name) if caches is not None else None
            grp_cross = cross_kvs if kind == "encdec" else None
            x, aux, new_c = _scan_group(
                p_stacked=params[name], x=x, cfg=cfg, kind=kind,
                positions=positions, windows=win, thetas=theta,
                caches=grp_cache, cache_pos=cache_pos,
                cache_write_mask=cache_write_mask, enc=enc,
                cross_kvs=grp_cross, prefill=is_prefill,
                page_table=page_table, paged_impl=paged_impl)
            aux_total = aux_total + aux
            if new_caches is not None:
                new_caches[name] = new_c
            offset += n

    x = norm_apply(x, params["final_norm"], cfg)
    if new_caches is not None and cross_kvs is not None:
        new_caches["cross_kv"] = cross_kvs
    if n_prefix:
        x = x[:, n_prefix:]
    return x, aux_total, new_caches


def logits_fn(params, hidden: Array, cfg: ModelConfig) -> Array:
    if cfg.tie_embeddings:
        return L.unembed(hidden, params["embed"])
    return L.dense(hidden, params["unembed"])


def sample_fn(params, hidden: Array, cfg: ModelConfig) -> Array:
    """Greedy sampling fused into the device program: unembed + argmax in one
    trace, so only (..., ) int32 token ids ever cross to the host — never the
    (..., V) float logits (the serving hot path's per-step host transfer drops
    from B×V floats to B int32s)."""
    return jnp.argmax(logits_fn(params, hidden, cfg), axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> dict:
    """Zero caches, stacked per layer group (shapes match forward's scans)."""
    dtype = dtype or cfg.dtype
    caches: Dict[str, PyTree] = {}

    def kv(n):
        return {"k": jnp.zeros((n, batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
                "v": jnp.zeros((n, batch, max_len, cfg.n_kv_heads, cfg.hd), dtype)}

    def mla_c(n):
        m = cfg.mla
        return {"c_kv": jnp.zeros((n, batch, max_len, m.kv_lora_rank), dtype),
                "k_rope": jnp.zeros((n, batch, max_len, m.rope_head_dim), dtype)}

    def ssm_c(n):
        s = cfg.ssm
        di = s.expand * cfg.d_model
        if s.version == 1:
            return {"conv": jnp.zeros((n, batch, s.d_conv - 1, di), dtype),
                    "ssm": jnp.zeros((n, batch, di, s.d_state), jnp.float32)}
        bc_dim = 2 * s.n_groups * s.d_state
        n_heads = di // s.head_dim
        return {"conv": jnp.zeros((n, batch, s.d_conv - 1, di), dtype),
                "conv_bc": jnp.zeros((n, batch, s.d_conv - 1, bc_dim), dtype),
                "ssm": jnp.zeros((n, batch, n_heads, s.head_dim, s.d_state),
                                 jnp.float32)}

    if cfg.family == "hybrid":
        period = cfg.hybrid_attn_period
        n_full = cfg.n_layers // period
        rem = cfg.n_layers - n_full * period
        grp = ssm_c(n_full * period)
        caches["hybrid_groups"] = jax.tree.map(
            lambda t: t.reshape(n_full, period, *t.shape[1:]), grp)
        caches["shared_attn"] = kv(n_full)
        if rem:
            caches["tail"] = ssm_c(rem)
        return caches

    for name, kind, n in layer_plan(cfg):
        if kind in ("ssm1", "ssm2"):
            caches[name] = ssm_c(n)
        elif kind.startswith("mla"):
            caches[name] = mla_c(n)
        else:
            caches[name] = kv(n)
    return caches


def paged_cache_supported(cfg: ModelConfig) -> bool:
    """True iff every cached layer is a (GQA or MLA) attention layer — SSM
    states and encoder cross-KV have no per-token rows to page."""
    if cfg.family in ("ssm", "hybrid") or cfg.encoder is not None:
        return False
    return all(kind not in ("ssm1", "ssm2") for _, kind, _ in layer_plan(cfg))


def init_paged_cache(cfg: ModelConfig, num_pages: int, page_size: int,
                     dtype=None) -> dict:
    """Zero page POOLS, stacked per layer group.

    Leaves mirror :func:`init_cache` but replace the (batch, max_len) row
    plane with a single shared (num_pages, page_size) pool: pool pages are
    batch-agnostic, so one pool serves the B-way decode batch and batch-1
    prefill chunks simultaneously, and two sequences can reference the same
    page (refcounted prefix sharing — serve/paged.py owns the allocator).
    """
    dtype = dtype or cfg.dtype
    if not paged_cache_supported(cfg):
        raise ValueError("paged KV cache requires a pure-attention decoder "
                         f"stack (family={cfg.family!r})")
    caches: Dict[str, PyTree] = {}

    def kv(n):
        shp = (n, num_pages, page_size, cfg.n_kv_heads, cfg.hd)
        return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}

    def mla_c(n):
        m = cfg.mla
        return {"c_kv": jnp.zeros((n, num_pages, page_size, m.kv_lora_rank),
                                  dtype),
                "k_rope": jnp.zeros((n, num_pages, page_size,
                                     m.rope_head_dim), dtype)}

    for name, kind, n in layer_plan(cfg):
        caches[name] = mla_c(n) if kind.startswith("mla") else kv(n)
    return caches
