"""Quantization + §3.3/§4.4 ML-specific optimization tests (bit-exactness)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import fip, quant


def test_d_bit_growth():
    assert quant.d_bit_growth(True, True) == 1     # both signed
    assert quant.d_bit_growth(False, False) == 1   # both unsigned
    assert quant.d_bit_growth(True, False) == 2    # mixed: the §4.4 penalty
    assert quant.preadd_bits(8, True, True) == 9
    assert quant.preadd_bits(8, True, False) == 10


@pytest.mark.parametrize("dtype", [jnp.int8, jnp.uint8, jnp.int16])
@pytest.mark.parametrize("symmetric", [True, False])
def test_quant_roundtrip_error_bounded(dtype, symmetric):
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 32)) * 3.0
    qp = quant.calibrate(x, dtype, symmetric=symmetric)
    err = jnp.abs(quant.dequantize(quant.quantize(x, qp), qp) - x)
    assert float(jnp.max(err)) <= float(jnp.max(qp.scale)) * 1.01


def test_per_channel_quant():
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 8)) * jnp.arange(1, 9)
    qp = quant.calibrate(x, jnp.int8, axis=1)
    assert qp.scale.shape == (8,)
    err = jnp.abs(quant.dequantize(quant.quantize(x, qp), qp) - x)
    assert float(jnp.max(err / jnp.maximum(qp.scale, 1e-9))) <= 1.01


def test_int_gemm_ffip_bit_exact_with_zero_points():
    """Eq. (20) zero-point elimination through the (F)FIP path is bit-exact."""
    ka, kb = jax.random.split(jax.random.PRNGKey(2))
    aq = jax.random.randint(ka, (12, 16), 0, 256, dtype=jnp.int32).astype(jnp.uint8)
    bq = jax.random.randint(kb, (16, 10), 0, 256, dtype=jnp.int32).astype(jnp.uint8)
    za, zb = 7, 13
    want = quant.int_gemm_baseline(aq, bq, za, zb)
    for algo in ("fip", "ffip"):
        got = quant.int_gemm_ffip(aq, bq, za, zb, algo=algo)
        np.testing.assert_array_equal(got, want)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2 ** 16), za=st.integers(-50, 50), zb=st.integers(-50, 50),
       kh=st.integers(1, 10))
def test_property_zero_point_elimination(seed, za, zb, kh):
    k = 2 * kh
    ka, kb = jax.random.split(jax.random.PRNGKey(seed))
    aq = jax.random.randint(ka, (6, k), -128, 128, dtype=jnp.int32).astype(jnp.int8)
    bq = jax.random.randint(kb, (k, 5), -128, 128, dtype=jnp.int32).astype(jnp.int8)
    want = quant.int_gemm_baseline(aq, bq, za, zb)
    got = quant.int_gemm_ffip(aq, bq, za, zb, algo="ffip")
    np.testing.assert_array_equal(got, want)


def test_quantized_dense_ffip_close_to_float():
    """End-to-end quantized dense: FFIP int path ~= float reference."""
    kx, kw, kb_ = jax.random.split(jax.random.PRNGKey(3), 3)
    x = jax.random.normal(kx, (32, 64))
    w = jax.random.normal(kw, (64, 16)) * 0.1
    bias = jax.random.normal(kb_, (16,)) * 0.01
    xq = quant.calibrate(x, jnp.int8, symmetric=False)
    wq = quant.calibrate(w, jnp.int8, symmetric=True)
    got = quant.quantized_dense_ffip(x, w, bias, xq, wq, algo="ffip")
    want = x @ w + bias
    # int8 quantization error budget: ~scale_x*scale_w*sqrt(K) per element
    rms = float(jnp.sqrt(jnp.mean((got - want) ** 2)))
    assert rms < 0.05, rms


def test_zero_point_adjuster_per_channel():
    """Eq. (20) with per-channel weight zero-points: AR_ij = zb_j*rowsum(A)_i."""
    aq = jax.random.randint(jax.random.PRNGKey(5), (6, 10), -128, 128,
                            dtype=jnp.int32).astype(jnp.int8)
    zb = jnp.asarray([3, -7, 0, 11, 2], jnp.int32)
    got = quant.zero_point_adjuster(aq, zb)
    rowsum = np.sum(np.asarray(aq, np.int32), axis=-1)
    np.testing.assert_array_equal(np.asarray(got), np.outer(rowsum, zb))
    # scalar zero-point still broadcasts
    got_s = quant.zero_point_adjuster(aq, 13)
    np.testing.assert_array_equal(np.asarray(got_s)[:, 0], rowsum * 13)


def test_int_gemm_ffip_per_channel_zero_points_bit_exact():
    """Per-channel zb (and per-row za) through the wired Eq. 20 adjuster."""
    ka, kb, kz = jax.random.split(jax.random.PRNGKey(6), 3)
    aq = jax.random.randint(ka, (9, 14), -128, 128, dtype=jnp.int32).astype(jnp.int8)
    bq = jax.random.randint(kb, (14, 7), -128, 128, dtype=jnp.int32).astype(jnp.int8)
    zb = jax.random.randint(kz, (7,), -30, 30, dtype=jnp.int32)
    za = jax.random.randint(kz, (9, 1), -30, 30, dtype=jnp.int32)
    want = quant.int_gemm_baseline(aq, bq, za, zb)
    for algo in ("fip", "ffip"):
        got = quant.int_gemm_ffip(aq, bq, za, zb, algo=algo)
        np.testing.assert_array_equal(got, want)


def test_prepare_quantized_dense_offline_terms():
    """The offline dict matches what the algebra needs: beta(W_q) folded
    (Eq. 15) and colsum(W_q); stacked (L, K, N) weights calibrate per layer."""
    w = jax.random.normal(jax.random.PRNGKey(7), (3, 16, 6)) * 0.3
    q = quant.prepare_quantized_dense(w)
    q32 = np.asarray(q["qw"], np.int32)
    np.testing.assert_array_equal(
        np.asarray(q["neg_beta"]),
        -np.sum(q32[:, 0::2, :] * q32[:, 1::2, :], axis=1))
    np.testing.assert_array_equal(np.asarray(q["colsum"]), q32.sum(axis=1))
    # per-layer slices equal independent per-layer preparation
    q0 = quant.prepare_quantized_dense(w[1])
    for key in q:
        np.testing.assert_array_equal(np.asarray(q[key][1]),
                                      np.asarray(q0[key]))


def test_quantized_dense_apply_ffip_equals_int_baseline_and_float():
    """Serving-path apply: FFIP ints == baseline ints (bit-exact accumulator)
    and the dequantized result tracks the float GEMM."""
    kx, kw = jax.random.split(jax.random.PRNGKey(8))
    x = jax.random.normal(kx, (12, 32))
    w = jax.random.normal(kw, (32, 10)) * 0.2
    q = quant.prepare_quantized_dense(w)
    got_ffip = quant.quantized_dense_apply(x, q, algo="ffip")
    got_fip = quant.quantized_dense_apply(x, q, algo="fip")
    got_base = quant.quantized_dense_apply(x, q, algo="baseline")
    np.testing.assert_array_equal(np.asarray(got_ffip), np.asarray(got_base))
    np.testing.assert_array_equal(np.asarray(got_fip), np.asarray(got_base))
    rms = float(jnp.sqrt(jnp.mean((got_ffip - x @ w) ** 2)))
    assert rms < 0.05, rms


def test_attach_quantized_weights_walks_stacked_tree():
    params = {
        "embed": {"table": jnp.ones((8, 4))},
        "unembed": {"w": jnp.ones((4, 8))},            # skipped: logits stay float
        "layers": {"attn": {"wq": {"w": jnp.ones((2, 4, 6))}},
                   "odd": {"w": jnp.ones((3, 6))}},    # odd K: float fallback
    }
    out = quant.attach_quantized_weights(params)
    assert "q" in out["layers"]["attn"]["wq"]
    assert out["layers"]["attn"]["wq"]["q"]["qw"].shape == (2, 4, 6)
    assert "q" not in out["layers"]["odd"]
    assert "q" not in out["unembed"]
    assert set(out["embed"]) == {"table"}


def test_quantized_ffip_equals_quantized_baseline_bitexact():
    """Same quantized network arithmetic, both orders — identical ints."""
    kx, kw = jax.random.split(jax.random.PRNGKey(4))
    x = jax.random.normal(kx, (8, 32))
    w = jax.random.normal(kw, (32, 8))
    xq = quant.calibrate(x, jnp.int8, symmetric=False)
    wq = quant.calibrate(w, jnp.int8, symmetric=False)
    aq, bq = quant.quantize(x, xq), quant.quantize(w, wq)
    base = quant.int_gemm_baseline(aq, bq, xq.zero_point, wq.zero_point)
    ffip = quant.int_gemm_ffip(aq, bq, xq.zero_point, wq.zero_point)
    np.testing.assert_array_equal(base, ffip)
