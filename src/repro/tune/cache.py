"""Persistent schedule cache for the kernel autotuner.

One JSON file maps cache keys — ``kernel|algo|dtype|shape-bucket|device_kind``
strings — to tuned schedules (block sizes plus the measurements that chose
them). The file is the durable artifact the offline tuner
(``python -m repro.launch.tune``) writes and every ``GemmConfig(block="auto")``
lookup reads; an in-process LRU sits on top so hot-path lookups during jit
tracing never touch the filesystem after first load.

Robustness contract (tests/test_tune.py):
  * round-trip: write -> new process/instance -> lookup returns the identical
    schedule with zero re-measurement;
  * corruption: an unreadable/garbage file is moved aside to ``*.corrupt`` and
    the cache restarts empty (a tuner run then rebuilds it) — never a crash;
  * writes are atomic (tmp file + rename) so a killed tuner can't corrupt a
    good cache.

Location: ``$REPRO_TUNE_CACHE`` if set, else
``$XDG_CACHE_HOME|~/.cache / repro / tune_schedules.json``.
"""
from __future__ import annotations

import json
import os
import threading
from collections import OrderedDict
from pathlib import Path
from typing import Dict, Optional

_VERSION = 1


def _valid_entry(v) -> bool:
    return (isinstance(v, dict) and isinstance(v.get("blocks"), dict)
            and all(isinstance(x, int) for x in v["blocks"].values()))


def _read_entries(path: Path) -> Dict[str, dict]:
    """Parse a cache file into its valid entries; raises on corruption."""
    raw = json.loads(path.read_text())
    entries = raw["entries"]
    if raw.get("version") != _VERSION or not isinstance(entries, dict):
        raise ValueError("schedule cache version/shape mismatch")
    return {k: v for k, v in entries.items() if _valid_entry(v)}


def default_cache_path() -> Path:
    env = os.environ.get("REPRO_TUNE_CACHE")
    if env:
        return Path(env)
    base = Path(os.environ.get("XDG_CACHE_HOME", str(Path.home() / ".cache")))
    return base / "repro" / "tune_schedules.json"


def make_key(kernel: str, algo: str, dtype: str, shape_bucket: str,
             device: str) -> str:
    return "|".join((kernel, algo, dtype, shape_bucket, device))


class ScheduleCache:
    """JSON-file-backed schedule store with a bounded in-process LRU on top.

    ``_entries`` mirrors the whole file (entries are ~100 bytes each; the file
    is the source of truth and is rewritten whole on save). ``_lru`` is the
    read cache: lookups promote their key, and it is bounded so a pathological
    sweep over thousands of distinct shapes cannot grow lookup state without
    bound — evicted keys simply fall back to the ``_entries`` dict once.
    """

    def __init__(self, path: Optional[os.PathLike] = None, *,
                 lru_size: int = 1024):
        self.path = Path(path) if path is not None else default_cache_path()
        self.lru_size = lru_size
        self.recovered = False          # True if a corrupt file was replaced
        self._entries: Dict[str, dict] = {}
        self._lru: "OrderedDict[str, dict]" = OrderedDict()
        self._loaded = False
        self._lock = threading.Lock()

    # -- persistence -------------------------------------------------------
    def _load_locked(self):
        if self._loaded:
            return
        self._loaded = True
        try:
            self._entries = _read_entries(self.path)
        except FileNotFoundError:
            self._entries = {}
        except Exception:
            # Corrupted cache: recover to empty, keep the evidence aside so a
            # bad deploy is debuggable, and let the next save rewrite cleanly.
            self.recovered = True
            self._entries = {}
            try:
                self.path.rename(self.path.with_name(self.path.name +
                                                     ".corrupt"))
            except OSError:
                pass

    def save(self):
        with self._lock:
            self._load_locked()
            # Re-read and merge the on-disk entries before writing: two
            # tuner processes sharing a path (different archs, tune CLI +
            # gemm_micro) must not erase each other's buckets. Our in-memory
            # entries win per KEY; the atomic tmp+rename below only prevents
            # torn files, not this lost-update race.
            try:
                self._entries = {**_read_entries(self.path), **self._entries}
            except FileNotFoundError:
                pass   # nothing on disk yet: ours is the truth
            except Exception:
                # Corrupt on-disk file at SAVE time (e.g. another process
                # crashed mid-write before the atomic rename existed, or the
                # file was hand-edited). Overwriting it here would DESTROY
                # the evidence the load-time path carefully preserves —
                # quarantine it the same way before rewriting cleanly.
                self.recovered = True
                try:
                    self.path.rename(self.path.with_name(self.path.name +
                                                         ".corrupt"))
                except OSError:
                    pass
            payload = {"version": _VERSION, "entries": self._entries}
            self.path.parent.mkdir(parents=True, exist_ok=True)
            tmp = self.path.with_name(self.path.name + ".tmp")
            tmp.write_text(json.dumps(payload, indent=1, sort_keys=True) + "\n")
            tmp.replace(self.path)

    # -- access ------------------------------------------------------------
    def _touch_locked(self, key: str, value: dict):
        self._lru[key] = value
        self._lru.move_to_end(key)
        while len(self._lru) > self.lru_size:
            self._lru.popitem(last=False)

    def lookup(self, key: str) -> Optional[dict]:
        with self._lock:
            hit = self._lru.get(key)
            if hit is not None:
                self._lru.move_to_end(key)
                return hit
            self._load_locked()
            hit = self._entries.get(key)
            if hit is not None:
                self._touch_locked(key, hit)
            return hit

    def put(self, key: str, value: dict, *, persist: bool = True):
        with self._lock:
            self._load_locked()
            self._entries[key] = value
            self._touch_locked(key, value)
        if persist:
            self.save()

    def keys(self):
        with self._lock:
            self._load_locked()
            return sorted(self._entries)

    # -- artifact integration (repro.prepare) ------------------------------
    def entries_for_device(self, device: str) -> Dict[str, dict]:
        """Deep-copied slice of entries keyed to one ``device_kind`` — the
        export path: ``repro.prepare`` bundles this slice with the weights so
        a warm start on the same device kind never re-tunes."""
        with self._lock:
            self._load_locked()
            return {k: json.loads(json.dumps(v))
                    for k, v in self._entries.items()
                    if k.rsplit("|", 1)[-1] == device}

    def merge_entries(self, entries: Dict[str, dict], *,
                      persist: bool = False) -> int:
        """Install a slice (e.g. from a loaded artifact) into this cache;
        invalid entries are skipped, not fatal. Returns the count installed.
        In-memory by default — artifact schedules don't overwrite the user's
        cache file unless asked."""
        n = 0
        with self._lock:
            self._load_locked()
            for k, v in entries.items():
                if isinstance(k, str) and _valid_entry(v):
                    self._entries[k] = json.loads(json.dumps(v))
                    self._touch_locked(k, self._entries[k])
                    n += 1
        if persist and n:
            self.save()
        return n

    def __len__(self) -> int:
        with self._lock:
            self._load_locked()
            return len(self._entries)


_global: Optional[ScheduleCache] = None
_global_lock = threading.Lock()


def get_cache() -> ScheduleCache:
    """Process-wide cache at the current default path. Re-resolves the path on
    every call so tests (and CLIs) can retarget via $REPRO_TUNE_CACHE."""
    global _global
    path = default_cache_path()
    with _global_lock:
        if _global is None or _global.path != path:
            _global = ScheduleCache(path)
        return _global
