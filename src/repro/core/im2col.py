"""Algorithm 1: in-place mapping of 2-D convolution to GEMM (§5.1).

The paper's memory subsystem walks conv inputs with multi-digit counters
(programmable digit sizes/strides, Fig. 5) so that the systolic array sees a
GEMM without a standalone im2col re-layout stage. We reproduce:

  * :class:`MultiDigitCounter` — the Fig.-5 counter (nested digits, each with
    a size and a stride; the emitted address is the sum of digit values),
  * :func:`conv_gemm_indices` — Algorithm 1 specialised to NHWC conv,
    producing (M, K) gather indices into the padded input,
  * :func:`conv2d_via_gemm` — materialises A via the indices and runs any
    GEMM provider (baseline / FIP / FFIP), validated against lax.conv.
  * :func:`partition_blocks` — the §5.1.1 B-way memory partitioning of the
    W dimension (interleaved submemories), with the kw-crossing adjustment.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass
class Digit:
    """One digit of the Fig.-5 counter: iterates size times with given stride."""
    name: str
    size: int
    stride: int


class MultiDigitCounter:
    """Nested multi-digit counter: outer digits first (Algorithm 1 loop order).

    Emitted value = sum of (digit_index * stride) over digits — exactly the
    ``address = m_offset + k_offset`` composition in Algorithm 1.
    """

    def __init__(self, digits: Sequence[Digit]):
        self.digits = list(digits)

    def addresses(self) -> np.ndarray:
        grids = np.meshgrid(
            *[np.arange(d.size) * d.stride for d in self.digits], indexing="ij")
        out = np.zeros_like(grids[0])
        for g in grids:
            out = out + g
        return out.reshape(-1)


def conv_gemm_indices(h: int, w: int, cin: int, kh: int, kw: int,
                      stride: int = 1) -> np.ndarray:
    """Algorithm-1 address pattern for one image: (M, K) indices into the
    flattened (H, W, Cin) input, M = OH*OW, K = KH*KW*Cin.

    Loop order mirrors Algorithm 1: the kernel-offset digits (kh, kw, cin)
    form K (k_offset), the spatial digits (h, w) form M (m_offset); the final
    address is their sum — no data movement, only address arithmetic.
    """
    oh = (h - kh) // stride + 1
    ow = (w - kw) // stride + 1
    # m_offset counter: h (row stride = stride*W*Cin), w (stride*Cin)
    m_counter = MultiDigitCounter([
        Digit("h", oh, stride * w * cin),
        Digit("w", ow, stride * cin),
    ])
    # k_offset counter: kh (W*Cin), kw (Cin), cin (1)
    k_counter = MultiDigitCounter([
        Digit("kh", kh, w * cin),
        Digit("kw", kw, cin),
        Digit("cin", cin, 1),
    ])
    m_off = m_counter.addresses()            # (M,)
    k_off = k_counter.addresses()            # (K,)
    return m_off[:, None] + k_off[None, :]   # (M, K)


def conv2d_via_gemm(x: Array, kernel: Array, *, stride: int = 1, pad: int = 0,
                    gemm_fn: Callable[[Array, Array], Array] | None = None) -> Array:
    """NHWC conv via Algorithm-1 GEMM mapping.

    x: (B, H, W, Cin); kernel: (KH, KW, Cin, Cout) -> (B, OH, OW, Cout).
    """
    if gemm_fn is None:
        gemm_fn = lambda a, b: jnp.matmul(a, b)
    b_, h, w, cin = x.shape
    kh, kw, _, cout = kernel.shape
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
        h, w = h + 2 * pad, w + 2 * pad
    idx = jnp.asarray(conv_gemm_indices(h, w, cin, kh, kw, stride))  # (M, K)
    oh = (h - kh) // stride + 1
    ow = (w - kw) // stride + 1
    flat = x.reshape(b_, h * w * cin)
    a = flat[:, idx]                                # (B, M, K) gather, in-place map
    bmat = kernel.reshape(kh * kw * cin, cout)      # (K, N)
    c = gemm_fn(a, bmat)                            # (B, M, N)
    return c.reshape(b_, oh, ow, cout)


# ---------------------------------------------------------------------------
# §5.1.1: B-way memory partitioning of the W dimension
# ---------------------------------------------------------------------------

def partition_blocks(w_indices: np.ndarray, ws: int, n_blocks: int) -> List[np.ndarray]:
    """Split a stream of w-coordinates into B interleaved submemory streams.

    Each W slice is ``ws`` elements wide; slice s goes to block s % B. Returns
    per-block index arrays; the main clock interleaves them round-robin.
    """
    slice_id = w_indices // ws
    return [w_indices[slice_id % n_blocks == b] for b in range(n_blocks)]


def interleave_blocks(blocks: List[np.ndarray], order: np.ndarray | None = None) -> np.ndarray:
    """Round-robin re-interleave (the main-clock view). ``order`` permutes the
    block visiting order — the §5.1.1 kw-crossing adjustment rotates it when a
    kernel-window read starts inside a different block."""
    n = len(blocks)
    if order is None:
        order = np.arange(n)
    max_len = max(len(b) for b in blocks)
    out = []
    for i in range(max_len):
        for j in order:
            if i < len(blocks[j]):
                out.append(blocks[j][i])
    return np.asarray(out)
