"""Quickstart: the paper's FFIP arithmetic end-to-end in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

1. Shows FIP/FFIP computing exact matmuls with ~half the multiplications.
2. Runs the FFIP Pallas TPU kernel (interpret mode on CPU) vs the oracle.
3. Swaps the GEMM provider under a real model (starcoder2 smoke config) and
   trains a few steps — same loss curve, halved multiply count.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import analytical as an
from repro.core import fip
from repro.core.gemm import GemmConfig, use_gemm
from repro.kernels import ops
from repro.models.model import build_model
from repro.optim import adamw
from repro.train.step import TrainConfig, make_train_step


def main():
    key = jax.random.PRNGKey(0)

    # --- 1. the algebra ----------------------------------------------------
    m, k, n = 64, 128, 32
    a = jax.random.normal(key, (m, k))
    b = jax.random.normal(key, (k, n))
    c_base = a @ b
    c_fip = fip.fip_matmul(a, b)
    c_ffip = fip.ffip_matmul(a, b)
    print("max |FIP - baseline| :", float(jnp.max(jnp.abs(c_fip - c_base))))
    print("max |FFIP - baseline|:", float(jnp.max(jnp.abs(c_ffip - c_base))))
    print(f"multiplications: baseline={an.baseline_mults(m, k, n)} "
          f"fip={an.fip_mults(m, k, n)} "
          f"(ratio {an.fip_mults(m, k, n) / an.baseline_mults(m, k, n):.3f})")

    # --- 2. the Pallas kernel ----------------------------------------------
    c_kernel = ops.matmul(a, b, algo="ffip", interpret=True)
    print("max |FFIP kernel - baseline|:",
          float(jnp.max(jnp.abs(c_kernel - c_base))))

    # --- 3. under a real model ----------------------------------------------
    cfg = configs.smoke_config(configs.get_config("starcoder2-3b"))
    model = build_model(cfg)
    params = model.init(key)
    opt = adamw.init(params)
    step = jax.jit(make_train_step(model, TrainConfig()))
    batch = {
        "tokens": jax.random.randint(key, (2, 32), 0, cfg.vocab),
        "labels": jax.random.randint(key, (2, 32), 0, cfg.vocab),
    }
    with use_gemm(GemmConfig(algo="ffip", impl="ref")):
        _, _, m_ffip = step(params, opt, batch)
    _, _, m_base = step(params, opt, batch)
    print(f"loss with FFIP GEMM provider: {float(m_ffip['loss']):.4f}")
    print(f"loss with baseline provider : {float(m_base['loss']):.4f}")
    np.testing.assert_allclose(float(m_ffip["loss"]), float(m_base["loss"]),
                               rtol=1e-3)
    print("OK: identical model, identical numerics, half the multiplies.")


if __name__ == "__main__":
    main()
