import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract memory / cost / collective analyses.

MUST be run as its own process (the two lines above must execute before any
jax import anywhere):

    PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x22b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Results land in benchmarks/results/dryrun/<arch>__<shape>__<mesh>.json;
EXPERIMENTS.md §Dry-run / §Roofline are generated from them.
"""
import argparse   # noqa: E402
import dataclasses  # noqa: E402
import json       # noqa: E402
import pathlib    # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax        # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import configs                    # noqa: E402
from repro.configs.base import SHAPES        # noqa: E402
from repro.dist import context as dctx       # noqa: E402
from repro.dist import sharding as shd       # noqa: E402
from repro.launch import inputs as inp       # noqa: E402
from repro.launch import costs as jcosts     # noqa: E402
from repro.launch import roofline as roof    # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.model import build_model   # noqa: E402
from repro.optim import adamw                # noqa: E402
from repro.train.step import TrainConfig, make_train_step  # noqa: E402

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               sharding_overrides=None, remat: str = ""):
    """Lower + compile one cell. Returns (compiled, lowered, meta)."""
    cfg, shape, specs = inp.input_specs(arch, shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size

    if shape.kind == "train":
        # default train remat policy: save dot outputs (cheap recompute of
        # elementwise only); --remat full for strict O(1)-activation memory
        cfg = dataclasses.replace(cfg, remat=remat or "dots")
    model = build_model(cfg)

    params_sds = inp.params_specs_struct(cfg)
    pspecs = shd.param_specs(params_sds, mesh,
                             moe_partition=cfg.moe.partition if cfg.moe else "expert")
    if sharding_overrides:
        pspecs = sharding_overrides(pspecs, cfg, mesh)

    if shape.kind == "train":
        opt_sds = jax.eval_shape(adamw.init, params_sds)
        ospecs = adamw.AdamWState(step=P(), m=pspecs, v=pspecs)
        bspecs = shd.data_specs(specs, mesh)
        step = make_train_step(model, TrainConfig())
        jitted = jax.jit(
            step,
            in_shardings=(shd.to_named(pspecs, mesh),
                          shd.to_named(ospecs, mesh),
                          shd.to_named(bspecs, mesh)),
            donate_argnums=(0, 1),
        )
        args = (params_sds, opt_sds, specs)
    elif shape.kind == "prefill":
        cspecs = shd.cache_specs(specs["cache"], mesh, batch=shape.global_batch)
        bspecs = shd.data_specs(
            {k: v for k, v in specs.items() if k != "cache"}, mesh)

        def prefill(params, cache, tokens, frames=None, patches=None):
            kw = {}
            if frames is not None:
                kw["frames"] = frames
            if patches is not None:
                kw["patches"] = patches
            return model.prefill(params, tokens, cache, **kw)

        extra = {k: specs[k] for k in ("frames", "patches") if k in specs}
        jitted = jax.jit(
            prefill,
            in_shardings=(shd.to_named(pspecs, mesh),
                          shd.to_named(cspecs, mesh),
                          shd.to_named(bspecs["tokens"], mesh),
                          *(shd.to_named(bspecs[k], mesh) for k in extra)),
            donate_argnums=(1,),
        )
        args = (params_sds, specs["cache"], specs["tokens"], *extra.values())
    else:  # decode
        cspecs = shd.cache_specs(specs["cache"], mesh, batch=shape.global_batch)
        tok_spec = shd.data_specs(specs["token"], mesh)

        def decode(params, cache, token, pos):
            return model.decode_step(params, token, cache, pos)

        jitted = jax.jit(
            decode,
            in_shardings=(shd.to_named(pspecs, mesh),
                          shd.to_named(cspecs, mesh),
                          shd.to_named(tok_spec, mesh),
                          NamedSharding(mesh, P())),
            donate_argnums=(1,),
        )
        args = (params_sds, specs["cache"], specs["token"], specs["pos"])

    t0 = time.time()
    with dctx.mesh_context(mesh):
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    meta = dict(arch=arch, shape=shape_name,
                mesh="2x16x16" if multi_pod else "16x16",
                chips=chips, kind=shape.kind,
                compile_s=round(time.time() - t0, 1))
    # un-jitted callable + abstract args for the scan-aware jaxpr cost model
    meta["_costable"] = (jitted.__wrapped__, args)
    return compiled, lowered, meta


def _model_flops(cfg, shape) -> float:
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch          # decode: 1 token each


def analyze(compiled, meta, cfg, shape) -> dict:
    # global, scan-aware FLOPs/bytes from the jaxpr (XLA's cost_analysis is
    # per-partition and counts while bodies once — see launch/costs.py)
    fn, args = meta.pop("_costable")
    jc = jcosts.fn_cost(fn, *args)
    xla_cost = compiled.cost_analysis() or {}
    if isinstance(xla_cost, (list, tuple)):   # older JAX: one dict per program
        xla_cost = xla_cost[0] if xla_cost else {}
    hlo = compiled.as_text()
    coll = roof.collective_bytes(hlo)
    mem = compiled.memory_analysis()
    report = roof.roofline_report(
        jc.flops, jc.bytes, coll, meta["chips"],
        model_flops=_model_flops(cfg, shape))
    out = dict(meta)
    out.update(
        hlo_flops=jc.flops, hlo_bytes=jc.bytes,
        xla_flops_per_device=float(xla_cost.get("flops", 0.0)),
        xla_bytes_per_device=float(xla_cost.get("bytes accessed", 0.0)),
        bytes_per_device=getattr(mem, "temp_size_in_bytes", None),
        argument_bytes=getattr(mem, "argument_size_in_bytes", None),
        output_bytes=getattr(mem, "output_size_in_bytes", None),
        **report,
    )
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: pathlib.Path,
             remat: str = ""):
    cfg = configs.get_config(arch)
    shape = configs.SHAPE_BY_NAME[shape_name]
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    path = out_dir / f"{arch}__{shape_name}__{mesh_tag}.json"
    ok, why = configs.shape_supported(cfg, shape)
    if not ok:
        path.write_text(json.dumps(dict(arch=arch, shape=shape_name,
                                        mesh=mesh_tag, status="skipped",
                                        reason=why), indent=1))
        print(f"SKIP {arch} x {shape_name} [{mesh_tag}]: {why}")
        return True
    try:
        compiled, lowered, meta = lower_cell(arch, shape_name,
                                             multi_pod=multi_pod, remat=remat)
        result = analyze(compiled, meta, cfg, shape)
        result["status"] = "ok"
        path.write_text(json.dumps(result, indent=1, default=str))
        print(f"OK   {arch} x {shape_name} [{mesh_tag}] "
              f"compile={meta['compile_s']}s bottleneck={result['bottleneck']} "
              f"roofline_frac={result['roofline_fraction']:.3f}")
        return True
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        path.write_text(json.dumps(dict(
            arch=arch, shape=shape_name, mesh=mesh_tag, status="failed",
            error=f"{type(e).__name__}: {e}",
            traceback=traceback.format_exc()[-4000:]), indent=1))
        print(f"FAIL {arch} x {shape_name} [{mesh_tag}]: {type(e).__name__}: {e}")
        return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="")
    ap.add_argument("--shape", default="")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--remat", default="", choices=["", "none", "dots", "full"])
    ap.add_argument("--out", default=str(RESULTS_DIR))
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    cells = []
    archs = sorted(configs.ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = [s.name for s in SHAPES] if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    failures = 0
    for a, s, mp in cells:
        if not run_cell(a, s, mp, out_dir, remat=args.remat):
            failures += 1
    print(f"done: {len(cells) - failures}/{len(cells)} cells ok")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
