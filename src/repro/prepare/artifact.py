"""`PreparedModel` — the serializable offline-prep artifact.

The paper's §4.4 point is that everything expensive about deploying a
quantized FFIP model is *offline* work: per-channel int8 weight encoding with
beta folded into the integer bias (Eq. 15) and colsums precomputed, the Eq. 9
y-delta encoding of the weights, BN folding for the vision stacks, and — in
this codebase — the `repro.tune` schedule measurements. Before this module
those transforms lived in four unrelated places and none survived a process
restart. `PreparedModel` owns all of them behind one interface and serializes
to a single directory (atomic tmp-dir + rename, `ckpt/manager.py`-style;
the `computation_cache` / `expected_weights_desc` idiom from ideep is the
reference shape).

Warm-start contract (counter-proved, tests/test_prepare.py + CI smoke):
loading an artifact and serving from it performs **zero** re-quantization
(`core.quant.counters`), zero y re-encoding (`kernels.compat.derived.stats`
— loads are seeded into the shared per-weight memo), and zero tuning
measurements (`tune.measure.counters`); ``prepared.recomputed`` sums the
deltas since load and must stay 0.

Portability: the tuned schedule slice is keyed by ``device_kind`` and only
rides on matching hardware — loading under a different device kind keeps the
quantized weights and y-deltas (they are device-independent integer math) but
drops the schedule slice with a one-time warning. A corrupt artifact is
quarantined to ``<dir>.corrupt`` exactly like `tune/cache.py` quarantines its
JSON file, so a bad fleet push is debuggable instead of crash-looping.
"""
from __future__ import annotations

import dataclasses
import json
import logging
import shutil
import time
from pathlib import Path
from typing import Any, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import tune
from repro.core import fip, quant
from repro.kernels import compat
from repro.kernels.ffip_gemm import Y_TAG
from repro.tune import measure

log = logging.getLogger("repro.prepare")

_VERSION = 1
_MANIFEST = "manifest.json"

# one-time-warning memory for schedule-slice drops (per artifact+device pair)
_warned_drops: set = set()


class ArtifactError(RuntimeError):
    """A prepared artifact is missing or corrupt (corrupt => quarantined)."""


def counters_snapshot() -> Dict[str, int]:
    """Current offline-work counters: quantization runs, y encodings, tuning
    measurements. `PreparedModel.recomputed` is the delta since construction
    — the zero-recompute warm-start proof reads it."""
    return {
        "quantize": quant.counters["prepare_dense"],
        "y_encode": compat.derived.stats["computed"],
        "tune": measure.counters["timed_candidates"],
    }


# ---------------------------------------------------------------------------
# Structure codec: params trees are dicts/lists/tuples of arrays plus python
# scalars (the conv q entries carry k_real/kh/kw/groups ints that must stay
# python ints — they drive static kernel geometry). Arrays go to .npy files;
# the structure itself goes into the manifest, so load needs NO template and
# therefore no recompute to build one.
# ---------------------------------------------------------------------------

def _encode(obj: Any, leaves: list) -> dict:
    if obj is None:
        return {"t": "none"}
    if isinstance(obj, dict):
        keys = list(obj.keys())
        if not all(isinstance(k, str) for k in keys):
            raise TypeError(f"artifact dicts need str keys, got {keys!r}")
        return {"t": "dict", "k": keys,
                "v": [_encode(obj[k], leaves) for k in keys]}
    if isinstance(obj, (list, tuple)):
        return {"t": "list" if isinstance(obj, list) else "tuple",
                "v": [_encode(x, leaves) for x in obj]}
    if isinstance(obj, (bool, int, float, str)) and not hasattr(obj, "shape"):
        return {"t": "py", "v": obj}
    leaves.append(np.asarray(obj))
    return {"t": "arr", "i": len(leaves) - 1}


def _decode(node: dict, leaves: list) -> Any:
    t = node["t"]
    if t == "none":
        return None
    if t == "dict":
        return {k: _decode(v, leaves) for k, v in zip(node["k"], node["v"])}
    if t in ("list", "tuple"):
        seq = [_decode(v, leaves) for v in node["v"]]
        return seq if t == "list" else tuple(seq)
    if t == "py":
        return node["v"]
    if t == "arr":
        return jnp.asarray(leaves[node["i"]])
    raise ValueError(f"unknown artifact node type {t!r}")


def _iter_dense_w(node: Any, path: Tuple[str, ...] = ()
                  ) -> Iterator[Tuple[str, Any]]:
    """Yield ("a/b/w", w) for every even-K dense weight in the tree — the
    leaves eligible for the Eq. 9 y-delta precompute. Leading dims are
    stacked layer groups (the transformer scans over them)."""
    if isinstance(node, dict):
        w = node.get("w")
        if (w is not None and not isinstance(w, (dict, list, tuple))
                and getattr(w, "ndim", 0) >= 2 and w.shape[-2] % 2 == 0):
            yield "/".join(path + ("w",)), w
        for k, v in node.items():
            yield from _iter_dense_w(v, path + (str(k),))
    elif isinstance(node, (list, tuple)):
        for i, v in enumerate(node):
            yield from _iter_dense_w(v, path + (str(i),))


def _make_y_nd(w):
    """Eq. 9 y encoding; leading stacked-layer dims are mapped over."""
    if w.ndim == 2:
        return fip.make_y(w)
    flat = w.reshape((-1,) + w.shape[-2:])
    return jax.vmap(fip.make_y)(flat).reshape(w.shape)


def _leaf_at(tree: Any, path: str) -> Optional[Any]:
    node = tree
    for seg in path.split("/"):
        if isinstance(node, dict):
            if seg not in node:
                return None
            node = node[seg]
        elif isinstance(node, (list, tuple)):
            try:
                node = node[int(seg)]
            except (ValueError, IndexError):
                return None
        else:
            return None
    return node


# ---------------------------------------------------------------------------
# The artifact
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PreparedModel:
    """Run-ready offline-prepared model: params with int8 ``q`` entries
    attached, precomputed y-deltas, and the device-keyed schedule slice.

    ``params`` is the full tree (float weights retained for the float path,
    logits, fallbacks), so the artifact is a self-contained deployable.
    ``derived`` maps ``"path/to/w"`` -> Eq. 9 y-delta array; on load it is
    seeded into the shared per-weight memo so eager FFIP kernels never
    re-encode. ``schedule`` is the `repro.tune` entries slice for ``device``.
    """
    kind: str                               # "lm" | "vision"
    device: str                             # device_kind at prepare time
    quantized: bool
    params: Any
    derived: Dict[str, Any] = dataclasses.field(default_factory=dict)
    schedule: Dict[str, dict] = dataclasses.field(default_factory=dict)
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # snapshot of the global offline-work counters at construction/load time;
    # all PreparedModels in a process share the underlying counters, so the
    # delta is "offline work done anywhere since this artifact became ready".
    baseline: Dict[str, int] = dataclasses.field(
        default_factory=counters_snapshot)

    @property
    def recomputed(self) -> int:
        """Offline transforms recomputed since this artifact was prepared or
        loaded. The warm-start contract is ``recomputed == 0``."""
        return sum(self.recompute_report().values())

    def recompute_report(self) -> Dict[str, int]:
        now = counters_snapshot()
        return {k: now[k] - self.baseline[k] for k in now}

    # -- persistence -------------------------------------------------------
    def save(self, directory, *, overwrite: bool = True) -> Path:
        """Atomic directory write: everything lands in ``<dir>.tmp`` first,
        then one rename commits — a killed writer can't leave a torn
        artifact at the final path."""
        final = Path(directory)
        tmp = final.with_name(final.name + ".tmp")
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        leaves: list = []
        tree = _encode({"params": self.params, "derived": self.derived},
                       leaves)
        for i, arr in enumerate(leaves):
            np.save(tmp / f"arr_{i:05d}.npy", arr)
        manifest = {
            "version": _VERSION,
            "kind": self.kind,
            "device": self.device,
            "quantized": self.quantized,
            "schedule": self.schedule,
            "meta": self.meta,
            "tree": tree,
            "n_arrays": len(leaves),
            "time": time.time(),
        }
        (tmp / _MANIFEST).write_text(json.dumps(manifest) + "\n")
        if final.exists():
            if not overwrite:
                raise FileExistsError(f"artifact already exists at {final}")
            shutil.rmtree(final)
        tmp.rename(final)
        return final


def load(directory, *, device: Optional[str] = None) -> PreparedModel:
    """Load an artifact with the zero-recompute guarantee.

    Same ``device_kind``: the schedule slice is installed into the process
    tune cache (in-memory — the user's cache file is not rewritten), so
    ``block="auto"`` lookups hit without re-measuring. Different kind: the
    slice is dropped with a one-time warning; weights/y-deltas still load.
    Corruption quarantines the directory to ``<dir>.corrupt`` and raises
    :class:`ArtifactError`.
    """
    path = Path(directory)
    try:
        manifest = json.loads((path / _MANIFEST).read_text())
        if manifest.get("version") != _VERSION:
            raise ValueError(
                f"artifact version {manifest.get('version')!r} != {_VERSION}")
        if manifest.get("kind") not in ("lm", "vision"):
            raise ValueError(f"bad artifact kind {manifest.get('kind')!r}")
        n = int(manifest["n_arrays"])
        leaves = [np.load(path / f"arr_{i:05d}.npy") for i in range(n)]
        obj = _decode(manifest["tree"], leaves)
        params, derived = obj["params"], obj["derived"]
    except ArtifactError:
        raise
    except Exception as e:
        if path.exists():
            corrupt = path.with_name(path.name + ".corrupt")
            shutil.rmtree(corrupt, ignore_errors=True)
            where = ""
            try:
                path.rename(corrupt)
                where = f" (quarantined to {corrupt})"
            except OSError:
                pass
            raise ArtifactError(
                f"corrupt prepared artifact at {path}{where}: {e}") from e
        raise ArtifactError(f"no prepared artifact at {path}") from e

    dev = device or compat.device_kind()
    schedule = manifest.get("schedule") or {}
    if manifest["device"] != dev:
        if schedule:
            key = (str(path), manifest["device"], dev)
            if key not in _warned_drops:
                _warned_drops.add(key)
                log.warning(
                    "prepared artifact %s was tuned for device_kind=%r but "
                    "this process runs %r: dropping its %d schedule entries "
                    "(weights/y-deltas still apply; re-tune with "
                    "`python -m repro.launch.tune` for this device)",
                    path, manifest["device"], dev, len(schedule))
            schedule = {}
    elif schedule:
        tune.get_cache().merge_entries(schedule)

    # Seed the shared per-weight memo so eager FFIP GEMMs over these exact
    # loaded arrays are warm-start hits, never re-encodes.
    for wpath, y in derived.items():
        w = _leaf_at(params, wpath)
        if w is not None and getattr(w, "shape", None) == y.shape:
            compat.derived.seed(Y_TAG, w, y)

    return PreparedModel(
        kind=manifest["kind"], device=manifest["device"],
        quantized=bool(manifest["quantized"]), params=params,
        derived=derived, schedule=schedule, meta=manifest.get("meta") or {})


# ---------------------------------------------------------------------------
# Builders — the one interface every former private prep path now routes
# through (serve/batcher, vision.attach_quantized, launch CLIs).
# ---------------------------------------------------------------------------

def prepare_lm(params, *, quantized: bool = True, dtype=jnp.int8,
               y_deltas: bool = True, device: Optional[str] = None,
               name: Optional[str] = None) -> PreparedModel:
    """Prepare a language-model param tree for serving.

    * ``quantized``: attach per-channel int8 ``q`` entries (Eq. 15 folded
      beta + colsums + Eq. 20 zero-points) next to every even-K dense ``w``;
    * ``y_deltas``: precompute the Eq. 9 y encoding for every 2-D even-K
      dense weight (the float Pallas FFIP operand), memoized into the shared
      per-weight cache so the serving process reuses them immediately;
    * the current `repro.tune` schedule slice for ``device`` rides along.
    """
    dev = device or compat.device_kind()
    p = quant.attach_quantized_weights(params, dtype=dtype) \
        if quantized else params
    derived: Dict[str, Any] = {}
    if y_deltas:
        for wpath, w in _iter_dense_w(p):
            derived[wpath] = compat.derived.get(Y_TAG, w, _make_y_nd)
    schedule = tune.get_cache().entries_for_device(dev)
    return PreparedModel(kind="lm", device=dev, quantized=quantized,
                         params=p, derived=derived, schedule=schedule,
                         meta={"name": name, "dtype": jnp.dtype(dtype).name,
                               "y_deltas": y_deltas})


def prepare_vision(model, params, *, quantized: bool = True, dtype=jnp.int8,
                   bn_stats=None, device: Optional[str] = None,
                   name: Optional[str] = None) -> PreparedModel:
    """Prepare a vision model (layer-descriptor list + parallel param list).

    Owns the whole offline chain: optional BN folding into the conv weights
    (``bn_stats``: per-layer dict of gamma/beta/mean/var or None, parallel to
    ``params``), then per-layer int8 quantization — convs through the fused
    implicit-im2col q entry (flattened KH*KW*Cin_g axis), even-K FCs through
    the serving dense q entry. ``vision.models.attach_quantized`` is now a
    thin wrapper over this function.
    """
    from repro.vision import layers as vl
    from repro.vision import models as vm

    dev = device or compat.device_kind()
    p = list(params)
    folded = 0
    if bn_stats is not None:
        if len(bn_stats) != len(p):
            raise ValueError("bn_stats must be parallel to params")
        p = [vl.fold_bn(lp, bn) if bn is not None else lp
             for lp, bn in zip(p, bn_stats)]
        folded = sum(1 for bn in bn_stats if bn is not None)

    if quantized:
        out: list = []
        for layer, lp in zip(model, p):
            if isinstance(layer, vm.Conv):
                out.append(vl.attach_quantized_conv(
                    lp, groups=layer.groups, dtype=dtype))
            elif isinstance(layer, vm.FC):
                out.append(vl.attach_quantized_fc(lp, dtype=dtype))
            elif isinstance(layer, vm.Bottleneck):
                entry = dict(lp)
                for field in ("c1", "c2", "c3", "proj"):
                    conv = getattr(layer, field)
                    if conv is not None:
                        entry[field] = vl.attach_quantized_conv(
                            lp[field], groups=conv.groups, dtype=dtype)
                out.append(entry)
            else:
                out.append(lp)
        p = out

    schedule = tune.get_cache().entries_for_device(dev)
    return PreparedModel(kind="vision", device=dev, quantized=quantized,
                         params=p, derived={}, schedule=schedule,
                         meta={"name": name, "dtype": jnp.dtype(dtype).name,
                               "bn_folded": folded})
