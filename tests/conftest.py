"""Test bootstrap: src-layout path + gated dev-dependency fallbacks.

1. Puts `src/` on sys.path so `python -m pytest` works with or without
   PYTHONPATH=src (the tier-1 command in ROADMAP.md sets it; CI and bare
   local runs may not).
2. Install-checks the declared dev dependencies (pyproject.toml). `pytest`
   is trivially present; if `hypothesis` is missing — this container cannot
   pip install — a minimal deterministic stand-in
   (repro._compat.hypothesis_mini) is registered in sys.modules BEFORE test
   modules import it, so the property tests collect and run everywhere
   instead of erroring at collection time. Real hypothesis, when installed,
   always wins.
"""
import pathlib
import sys

_SRC = pathlib.Path(__file__).resolve().parents[1] / "src"
if str(_SRC) not in sys.path:
    sys.path.insert(0, str(_SRC))

try:
    import hypothesis  # noqa: F401  (real package present — use it)
except ImportError:
    from repro._compat import hypothesis_mini

    sys.modules["hypothesis"] = hypothesis_mini
    sys.modules["hypothesis.strategies"] = hypothesis_mini.strategies


def pytest_report_header(config):
    impl = sys.modules.get("hypothesis")
    mini = getattr(impl, "__version__", "") == "0.0-repro-mini"
    return ("hypothesis: repro._compat.hypothesis_mini fallback "
            "(pip install hypothesis for full property coverage)"
            if mini else f"hypothesis: {impl.__version__}")
