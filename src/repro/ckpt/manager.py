"""Checkpointing: sharded save, atomic commit, async writer, keep-N GC, and
elastic restore (re-shard onto a different mesh).

Layout:
    <dir>/step_000123.tmp/...      (in-flight)
    <dir>/step_000123/manifest.json
    <dir>/step_000123/arr_00000.npy ...

Fault-tolerance contract: a checkpoint is valid iff its directory name has no
.tmp suffix (atomic rename on completion). Restore picks the latest valid
step; interrupted writes are garbage-collected on the next save. Restore may
target a different mesh/sharding than save (elastic up/down-scale): leaves are
loaded as full host arrays and re-placed with the new NamedShardings.
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, async_save: bool = True):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: PyTree, *, extra: Optional[dict] = None,
             block: bool = False) -> None:
        """Snapshot to host memory synchronously, write asynchronously."""
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        host_leaves = [np.asarray(l) for l in leaves]   # device->host now
        meta = {
            "step": step,
            "treedef": str(treedef),
            "n_leaves": len(host_leaves),
            "time": time.time(),
            "extra": extra or {},
            "shapes": [list(l.shape) for l in host_leaves],
            "dtypes": [str(l.dtype) for l in host_leaves],
        }
        self.wait()   # one in-flight write at a time

        def write():
            tmp = self.dir / f"step_{step:08d}.tmp"
            final = self.dir / f"step_{step:08d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            for i, arr in enumerate(host_leaves):
                np.save(tmp / f"arr_{i:05d}.npy", arr)
            (tmp / "manifest.json").write_text(json.dumps(meta))
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)                      # atomic commit
            self._gc()

        if self.async_save and not block:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        # drop stale .tmp dirs + keep newest N valid checkpoints
        for tmp in self.dir.glob("*.tmp"):
            shutil.rmtree(tmp, ignore_errors=True)
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: PyTree, *, step: Optional[int] = None,
                shardings: Optional[PyTree] = None) -> tuple[PyTree, dict]:
        """Restore into the structure of ``template``. ``shardings`` (a tree of
        NamedSharding matching template) enables elastic re-sharding onto any
        mesh — leaves are device_put with the NEW shardings regardless of how
        they were sharded at save time."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step:08d}"
        meta = json.loads((d / "manifest.json").read_text())
        leaves, treedef = jax.tree_util.tree_flatten(template)
        assert meta["n_leaves"] == len(leaves), \
            f"tree mismatch: ckpt {meta['n_leaves']} vs template {len(leaves)}"
        shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                        if shardings is not None else [None] * len(leaves))
        out = []
        for i, (tmpl, shard) in enumerate(zip(leaves, shard_leaves)):
            arr = np.load(d / f"arr_{i:05d}.npy")
            assert list(arr.shape) == list(tmpl.shape), (i, arr.shape, tmpl.shape)
            arr = arr.astype(tmpl.dtype)
            out.append(jax.device_put(arr, shard) if shard is not None
                       else jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, out), meta["extra"]
