"""Process-global mesh context.

The launch stack enters `mesh_context(mesh)` once around lowering/training;
model internals call `get_mesh()` at trace time to decide whether to
shard_map a Pallas kernel over the mesh (see models/attention.py and
models/ssm.py). Keeping this ambient rather than threading a mesh argument
through every layer keeps the model code identical between the single-device
smoke path and the production 16x16 / 2x16x16 meshes.

Nesting is supported (a stack): the innermost context wins, matching the
semantics of `with mesh:` itself. The real `jax.sharding.Mesh` context is
entered too, so bare `PartitionSpec`s in `jax.jit` in_shardings resolve
against the same mesh the model code sees.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Iterator, Optional

from jax.sharding import Mesh

_state = threading.local()


def _stack() -> list:
    if not hasattr(_state, "meshes"):
        _state.meshes = []
    return _state.meshes


@contextlib.contextmanager
def mesh_context(mesh: Mesh) -> Iterator[Mesh]:
    """Make `mesh` the ambient mesh for the dynamic extent of the block."""
    stack = _stack()
    stack.append(mesh)
    try:
        # Mesh is its own context manager (sets jax's thread-local physical
        # mesh); duck-typed so shape-only stand-ins work in unit tests.
        if hasattr(mesh, "__enter__"):
            with mesh:
                yield mesh
        else:
            yield mesh
    finally:
        stack.pop()


def get_mesh() -> Optional[Mesh]:
    """The innermost active mesh, or None outside any mesh_context."""
    stack = _stack()
    return stack[-1] if stack else None
