from repro.core import analytical, fip, gemm, im2col, quant, workloads  # noqa: F401
