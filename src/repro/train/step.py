"""Train-step factory: loss -> grads -> AdamW, with optional microbatch
gradient accumulation and donated buffers."""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.optim import adamw

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: adamw.AdamWConfig = adamw.AdamWConfig()
    microbatch: int = 0        # 0 = no accumulation; else per-step microbatch


def make_train_step(model: Model, tcfg: TrainConfig):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def grads_of(params, batch):
        if not tcfg.microbatch:
            return jax.value_and_grad(loss_fn)(params, batch)
        # gradient accumulation over microbatches along the batch dim
        b = batch["tokens"].shape[0]
        mb = tcfg.microbatch
        n = b // mb
        assert n * mb == b, "microbatch must divide batch"

        def body(carry, idx):
            acc, loss_acc = carry
            sub = {k: jax.lax.dynamic_slice_in_dim(v, idx * mb, mb, 0)
                   for k, v in batch.items()}
            l, g = jax.value_and_grad(loss_fn)(params, sub)
            acc = jax.tree.map(jnp.add, acc, g)
            return (acc, loss_acc + l), None

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, lsum), _ = jax.lax.scan(body, (zero, jnp.zeros(())),
                                       jnp.arange(n))
        return lsum / n, jax.tree.map(lambda g: g / n, gsum)

    def step(params, opt_state, batch):
        loss, grads = grads_of(params, batch)
        new_params, new_state = adamw.update(tcfg.optimizer, grads, opt_state,
                                             params)
        metrics = {
            "loss": loss,
            "grad_norm": adamw.global_norm(grads),
            "lr": adamw.schedule_lr(tcfg.optimizer, new_state.step),
            "step": new_state.step,
        }
        return new_params, new_state, metrics

    return step


def make_serve_step(model: Model):
    """Returns decode(params, token, cache, pos) -> (cache, logits)."""

    def serve_step(params, token, cache, pos):
        return model.decode_step(params, token, cache, pos)

    return serve_step
