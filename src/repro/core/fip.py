"""FIP / FFIP inner-product algebra (Pogue & Nicolici, IEEE TC 2023).

Implements, in pure JAX:

  * Eq. (1)  baseline inner product          -> :func:`baseline_matmul`
  * Eq. (2)  Fast Inner Product (FIP)        -> :func:`fip_matmul`
  * Eqs. (3)/(4)  alpha / beta correction terms
  * Eqs. (7)-(9)  Free-pipeline FIP (FFIP)   -> :func:`ffip_matmul`
  * Eq. (9)  y-delta weight encoding         -> :func:`make_y` / :func:`y_to_b`
  * Eqs. (15)-(16)  beta folding into bias   -> :func:`fold_beta_into_bias`,
    :func:`fip_matmul_beta_folded`

All functions are shape-polymorphic over leading batch dims of ``a`` and are
exact (same algebra, reordered) — for integer dtypes the results are
bit-exact against the baseline; for floats they agree to rounding error.

Conventions: the paper uses 1-based indices; ``a_{i,2k-1}`` (odd positions)
maps to ``a[..., 0::2]`` and ``a_{i,2k}`` (even positions) to ``a[..., 1::2]``.
K must be even (callers pad via :mod:`repro.kernels.ops` otherwise).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


def _check_even_k(k: int) -> None:
    if k % 2 != 0:
        raise ValueError(
            f"FIP/FFIP require an even contraction dim K, got K={k}. "
            "Pad with zeros (repro.kernels.ops handles this) first."
        )


def _acc_dtype(dtype: jnp.dtype) -> jnp.dtype:
    """Accumulation dtype: int32 for sub-32-bit ints, f32 for sub-32-bit floats."""
    if jnp.issubdtype(dtype, jnp.integer):
        return jnp.int32 if jnp.dtype(dtype).itemsize < 8 else dtype
    if dtype in (jnp.bfloat16, jnp.float16):
        return jnp.float32
    return dtype


# ---------------------------------------------------------------------------
# Eq. (1): baseline
# ---------------------------------------------------------------------------

def baseline_matmul(a: Array, b: Array, *, precision=jax.lax.Precision.HIGHEST) -> Array:
    """Traditional inner product, Eq. (1). a: (..., M, K), b: (K, N)."""
    acc = _acc_dtype(jnp.result_type(a.dtype, b.dtype))
    return jnp.matmul(a.astype(acc), b.astype(acc), precision=precision)


# ---------------------------------------------------------------------------
# Eqs. (3) / (4): correction terms
# ---------------------------------------------------------------------------

def fip_alpha(a: Array) -> Array:
    """Eq. (3): alpha_i = sum_j a_{i,2j-1} * a_{i,2j}.  a: (..., M, K) -> (..., M)."""
    _check_even_k(a.shape[-1])
    acc = _acc_dtype(a.dtype)
    a = a.astype(acc)
    return jnp.sum(a[..., 0::2] * a[..., 1::2], axis=-1)


def fip_beta(b: Array) -> Array:
    """Eq. (4): beta_j = sum_i b_{2i-1,j} * b_{2i,j}.  b: (K, N) -> (N,)."""
    _check_even_k(b.shape[0])
    acc = _acc_dtype(b.dtype)
    b = b.astype(acc)
    return jnp.sum(b[0::2, :] * b[1::2, :], axis=0)


# ---------------------------------------------------------------------------
# Eq. (2): FIP
# ---------------------------------------------------------------------------

def fip_cross_term(a: Array, b: Array, *, k_chunk: int = 0) -> Array:
    """The summation term of Eq. (2) (without the -alpha -beta corrections).

    cross_ij = sum_{k=1..K/2} (a_{i,2k-1} + b_{2k,j}) * (a_{i,2k} + b_{2k-1,j})

    The (.., M, K/2, N) intermediate is materialized; ``k_chunk`` > 0 chunks
    the K/2 axis with a scan to bound memory (used by larger refs/tests).
    """
    _check_even_k(a.shape[-1])
    acc = _acc_dtype(jnp.result_type(a.dtype, b.dtype))
    a = a.astype(acc)
    b = b.astype(acc)
    a_odd, a_evn = a[..., 0::2], a[..., 1::2]          # a_{i,2k-1}, a_{i,2k}
    b_odd, b_evn = b[0::2, :], b[1::2, :]              # b_{2k-1,j}, b_{2k,j}

    def chunk_sum(ao, ae, bo, be):
        t1 = ao[..., :, :, None] + be[None, :, :]      # a_{i,2k-1} + b_{2k,j}
        t2 = ae[..., :, :, None] + bo[None, :, :]      # a_{i,2k}   + b_{2k-1,j}
        return jnp.sum(t1 * t2, axis=-2)

    kh = a_odd.shape[-1]
    if not k_chunk or k_chunk >= kh:
        return chunk_sum(a_odd, a_evn, b_odd, b_evn)

    if kh % k_chunk != 0:
        raise ValueError(f"k_chunk={k_chunk} must divide K/2={kh}")
    n_chunks = kh // k_chunk

    def body(carry, idx):
        sl = lambda x, ax: jax.lax.dynamic_slice_in_dim(x, idx * k_chunk, k_chunk, ax)
        part = chunk_sum(sl(a_odd, -1), sl(a_evn, -1), sl(b_odd, 0), sl(b_evn, 0))
        return carry + part, None

    zero = jnp.zeros((*a.shape[:-1], b.shape[-1]), acc)
    out, _ = jax.lax.scan(body, zero, jnp.arange(n_chunks))
    return out


def fip_matmul(a: Array, b: Array, *, k_chunk: int = 0) -> Array:
    """Eq. (2): FIP matmul. Exactly equals a @ b (bit-exact for ints)."""
    cross = fip_cross_term(a, b, k_chunk=k_chunk)
    alpha = fip_alpha(a)
    beta = fip_beta(b)
    return cross - alpha[..., :, None] - beta


def fip_matmul_beta_folded(a: Array, b: Array, bias_folded: Array,
                           *, k_chunk: int = 0) -> Array:
    """Eq. (16): c'_ij + folded bias, where beta was pre-folded via Eq. (15).

    ``bias_folded`` must come from :func:`fold_beta_into_bias`.
    """
    cross = fip_cross_term(a, b, k_chunk=k_chunk)
    alpha = fip_alpha(a)
    return cross - alpha[..., :, None] + bias_folded


def fold_beta_into_bias(b: Array, bias: Optional[Array] = None) -> Array:
    """Eq. (15): bias_j <- bias_j - beta_j (beta precomputed after training)."""
    beta = fip_beta(b)
    if bias is None:
        return -beta
    return bias.astype(beta.dtype) - beta


# ---------------------------------------------------------------------------
# Eq. (9): y encoding (weight-column deltas), and its inverse
# ---------------------------------------------------------------------------

def make_y(b: Array) -> Array:
    """Eq. (9): y_{i,1} = b_{i,1}; y_{i,j} = b_{i,j} - b_{i,j-1} for j>1."""
    acc = _acc_dtype(b.dtype)  # deltas need one extra bit for ints (paper §4.4)
    b = b.astype(acc)
    return jnp.concatenate([b[:, :1], b[:, 1:] - b[:, :-1]], axis=1)


def y_to_b(y: Array) -> Array:
    """Inverse of :func:`make_y` — the prefix sum the FFIP pipeline performs."""
    return jnp.cumsum(y, axis=1)


# ---------------------------------------------------------------------------
# Eqs. (7)-(9): FFIP
# ---------------------------------------------------------------------------

def ffip_matmul_scan(a: Array, y: Array, *, beta: Optional[Array] = None,
                     bias_folded: Optional[Array] = None) -> Array:
    """FFIP via the literal Eqs. (7)-(9) column recurrence (dataflow-faithful).

    Emulates the free-pipeline systolic dataflow: the g terms for output
    column j are formed by adding the weight delta ``y[:, j]`` to the g terms
    of column j-1 (Eq. 8c), exactly as the FFIP PE array does in hardware.

    a: (M, K); y: (K, N) from :func:`make_y`. Supply either ``beta`` (Eq. 7)
    or ``bias_folded`` (Eq. 16) or neither (pure c' + 0 bias).
    """
    _check_even_k(a.shape[-1])
    if a.ndim != 2:
        raise ValueError("ffip_matmul_scan is the 2-D dataflow reference; "
                         "use ffip_matmul for batched operands.")
    acc = _acc_dtype(jnp.result_type(a.dtype, y.dtype))
    a = a.astype(acc)
    y = y.astype(acc)
    alpha = fip_alpha(a)

    # g init (Eqs. 8a/8b): pairwise-swapped A, before any y column is added.
    a_swapped = pair_swap(a)                      # (M, K): [a2,a1,a4,a3,...]

    def step(g, y_col):                           # g: (M, K), y_col: (K,)
        g = g + y_col[None, :]                    # Eq. (8c)
        prod = g[:, 0::2] * g[:, 1::2]            # g_{i,2k-1} * g_{i,2k}
        c_col = jnp.sum(prod, axis=-1) - alpha    # Eq. (16) form (no beta yet)
        return g, c_col

    _, cols = jax.lax.scan(step, a_swapped, y.T)  # scan over j columns
    c_prime = cols.T                              # (M, N)
    if beta is not None:
        return c_prime - beta
    if bias_folded is not None:
        return c_prime + bias_folded
    return c_prime


def pair_swap(a: Array) -> Array:
    """Swap adjacent element pairs along the last axis: [x0,x1,x2,x3] -> [x1,x0,x3,x2].

    This realizes Eqs. (8a)/(8b): g_{i,2k-1} starts from a_{i,2k} and vice versa.
    """
    _check_even_k(a.shape[-1])
    shp = a.shape
    return a.reshape(*shp[:-1], shp[-1] // 2, 2)[..., ::-1].reshape(shp)


def ffip_matmul(a: Array, b: Array, *, k_chunk: int = 0) -> Array:
    """FFIP matmul in closed form.

    Because g^{(j)}_{i,k} = a_swapped_{i,k} + b_{k,j} (prefix-summed y == b,
    proven in §3.2.1 / tests), FFIP computes the same cross term as FIP with
    the roles of the a-pair swapped. This is the vectorized (non-scan) form —
    the scan form is :func:`ffip_matmul_scan`.
    """
    cross = fip_cross_term(pair_swap(a), pair_swap_rows(b), k_chunk=k_chunk)
    alpha = fip_alpha(a)
    beta = fip_beta(b)
    return cross - alpha[..., :, None] - beta


def pair_swap_rows(b: Array) -> Array:
    """Pair-swap along axis 0 (for the B operand)."""
    _check_even_k(b.shape[0])
    k, n = b.shape
    return b.reshape(k // 2, 2, n)[:, ::-1, :].reshape(k, n)


# ---------------------------------------------------------------------------
# §3.2.1 proof replay helpers (used by tests to 'replay' the induction)
# ---------------------------------------------------------------------------

def h_terms(a: Array, b: Array, j: int) -> Array:
    """Eqs. (11)/(12): h^{(j)}_{i,k} for output column j (0-based here).

    h_{i,2k-1}^{(j)} = a_{i,2k} + b_{2k-1,j};  h_{i,2k}^{(j)} = a_{i,2k-1} + b_{2k,j}
    i.e. h^{(j)} = pair_swap(a) + b[:, j].
    """
    return pair_swap(a.astype(_acc_dtype(a.dtype))) + b[:, j][None, :].astype(
        _acc_dtype(b.dtype))


def g_terms_by_recurrence(a: Array, b: Array, j: int) -> Array:
    """g^{(j)} built strictly by the Eq. (8) recurrence (j is 0-based)."""
    y = make_y(b)
    g = pair_swap(a.astype(_acc_dtype(a.dtype)))
    for jj in range(j + 1):
        g = g + y[:, jj][None, :]
    return g


# ---------------------------------------------------------------------------
# Differentiable wrappers: FIP/FFIP forward, analytic (baseline) backward.
# The algebra is exact, so d(a@b) gradients are the correct gradients; using
# them avoids differentiating through the (M,K/2,N) intermediate.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def fip_matmul_trainable(a: Array, b: Array, k_chunk: int = 0) -> Array:
    return fip_matmul(a, b, k_chunk=k_chunk)


def _fip_fwd(a, b, k_chunk):
    return fip_matmul(a, b, k_chunk=k_chunk), (a, b)


def _fip_bwd(k_chunk, res, ct):
    a, b = res
    ga = jnp.matmul(ct, b.T.astype(ct.dtype)).astype(a.dtype)
    bt = jnp.swapaxes(a, -1, -2).astype(ct.dtype)
    gb = jnp.matmul(bt, ct)
    # collapse leading batch dims of gb into the (K, N) param grad
    while gb.ndim > 2:
        gb = gb.sum(axis=0)
    return ga, gb.astype(b.dtype)


fip_matmul_trainable.defvjp(_fip_fwd, _fip_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def ffip_matmul_trainable(a: Array, b: Array, k_chunk: int = 0) -> Array:
    return ffip_matmul(a, b, k_chunk=k_chunk)


def _ffip_fwd(a, b, k_chunk):
    return ffip_matmul(a, b, k_chunk=k_chunk), (a, b)


ffip_matmul_trainable.defvjp(_ffip_fwd, _fip_bwd)


# ---------------------------------------------------------------------------
# Arithmetic-complexity counters (Eqs. 5/6 live in core.analytical; these are
# instrumented *measured* counts used by tests to confirm the halving claim).
# ---------------------------------------------------------------------------

def count_multiplies_in_jaxpr(fn, *args) -> int:
    """Count scalar multiplies in the jaxpr of fn(*args) (dot counts M*N*K)."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    total = 0

    def visit(jx):
        nonlocal total
        for eqn in jx.eqns:
            if eqn.primitive.name == "mul":
                aval = eqn.outvars[0].aval
                # skip integer *index* arithmetic (iota*stride from slicing)
                if aval.ndim < 2 and jnp.issubdtype(aval.dtype, jnp.integer):
                    continue
                shp = aval.shape
                n = 1
                for s in shp:
                    n *= s
                total += n
            elif eqn.primitive.name in ("dot_general",):
                lhs, rhs = eqn.invars[0].aval.shape, eqn.invars[1].aval.shape
                dnums = eqn.params["dimension_numbers"]
                (lc, rc), (lb, rb) = dnums
                m = 1
                for i, s in enumerate(lhs):
                    if i not in lc and i not in lb:
                        m *= s
                n = 1
                for i, s in enumerate(rhs):
                    if i not in rc and i not in rb:
                        n *= s
                k = 1
                for i in lc:
                    k *= lhs[i]
                batch = 1
                for i in lb:
                    batch *= lhs[i]
                total += batch * m * n * k
            for sub in eqn.params.values():
                inner = getattr(sub, "jaxpr", None)
                if inner is not None:
                    visit(getattr(inner, "jaxpr", inner))

    visit(jaxpr.jaxpr)
    return total
