"""Straggler mitigation + failure handling for the training loop.

The EMA/dead-man logic now lives in the shared :mod:`repro.watchdog` (the
serving replica router drives the SAME implementation against its tick
clock); this module keeps the training-facing names stable. The alias
carries ZERO logic of its own — it only defaults the telemetry label to
``loop="train"`` so the shared module's obs counters distinguish the two
consumers; ``observe``/``check_hang`` are the shared methods, verbatim
(test_obs pins this so the old double-bookkeeping can't creep back).
"""
from __future__ import annotations

from repro.watchdog import HangError, Watchdog, WatchdogConfig

__all__ = ["HangError", "StepWatchdog", "WatchdogConfig"]


class StepWatchdog(Watchdog):
    """Training-loop alias of the shared watchdog (real clock by default)."""

    def __init__(self, *args, **kwargs):
        kwargs.setdefault("loop", "train")
        super().__init__(*args, **kwargs)
