"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8e top-2, SWA. [arXiv:2401.04088; hf]"""
from repro.configs.base import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab=32768, sliding_window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=16384, n_shared=0,
                  partition="ffn"),   # 8 experts < 16-way model axis -> TP-in-expert
    tie_embeddings=False, rope_theta=1e6,
    supports_long_context=True,   # SWA: per-layer window is O(S*W)
)
