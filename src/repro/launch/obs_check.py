"""CI gate for the repro.obs telemetry files a serving run leaves behind.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --smoke \
        --replicas 2 --fault-plan flaky \
        --metrics-json /tmp/m.json --trace-out /tmp/t.jsonl
    PYTHONPATH=src python -m repro.launch.obs_check \
        --metrics-json /tmp/m.json --trace /tmp/t.jsonl \
        --replicas 2 --requests 8 --min-retries 1

Checks (each failure is listed; exit 1 if any):
  * every replica 0..N-1 recorded NONZERO prefill and decode dispatches
    (``serve_dispatches_total{replica,phase}``) — a silent replica means the
    router never actually spread load, or the metrics plumbing is dead;
  * router accounting closes: ``submitted`` == ``--requests``, ``completed``
    == ``--requests`` (unless ``--allow-failures``), ``retries`` >=
    ``--min-retries`` (the fault plan's injected failures must be VISIBLE in
    telemetry, not just survived);
  * the trace parses and every rid 0..R-1 reconstructs to ONE complete span
    tree: a single ``request`` root, ended (t1 set), with at least one child
    phase span;
  * with ``--expect-slo NAME``: the SLO loop closed — ``slo_state{slo=NAME}``
    exists, at least ``--min-alerts`` transitions fired
    (``slo_transitions_total``), the trace carries ``slo_alert`` and
    ``controller`` point events, and every action listed in
    ``--expect-controller`` was counted in ``router_controller_total``;
    ``--expect-recovery`` additionally requires the final state back at
    OK/healthy (burn recovered, controller walked back down the ladder).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List

from repro.obs.trace import load_jsonl, tree_from_spans


def _series_value(metrics: dict, name: str, **labels) -> float:
    """Sum of every series of ``name`` whose labels include ``labels``."""
    fam = metrics.get(name)
    if not fam:
        return 0.0
    total = 0.0
    for s in fam["series"]:
        if all(s["labels"].get(k) == v for k, v in labels.items()):
            total += s.get("value", s.get("count", 0.0))
    return total


def check_metrics(payload: dict, *, replicas: int, requests: int,
                  min_retries: int, allow_failures: bool) -> List[str]:
    problems: List[str] = []
    metrics = payload.get("metrics", payload)   # tolerate a bare snapshot
    for i in range(replicas):
        for phase in ("prefill", "decode"):
            v = _series_value(metrics, "serve_dispatches_total",
                              replica=str(i), phase=phase)
            if v <= 0:
                problems.append(f"replica {i}: zero {phase} dispatches "
                                f"recorded")
    ev = {k: _series_value(metrics, "router_events_total", kind=k)
          for k in ("submitted", "completed", "retries", "replica_failures")}
    if ev["submitted"] != requests:
        problems.append(f"router submitted {ev['submitted']:.0f} != "
                        f"--requests {requests}")
    if not allow_failures and ev["completed"] != requests:
        problems.append(f"router completed {ev['completed']:.0f} != "
                        f"--requests {requests}")
    if ev["retries"] < min_retries:
        problems.append(f"router retries {ev['retries']:.0f} < --min-retries "
                        f"{min_retries} (fault plan not visible in "
                        f"telemetry)")
    if min_retries and ev["replica_failures"] <= 0:
        problems.append("retries expected but zero replica_failures "
                        "recorded")
    return problems


def check_slo(payload: dict, trace_path: str, *, slos: List[str],
              min_alerts: int, controller_actions: List[str],
              expect_recovery: bool) -> List[str]:
    """The closed-loop gate: breach -> alert -> controller action (->
    recovery) must all be VISIBLE in the metrics snapshot and the trace."""
    problems: List[str] = []
    metrics = payload.get("metrics", payload)
    for name in slos:
        if not any(s["labels"].get("slo") == name
                   for s in metrics.get("slo_state", {}).get("series", [])):
            problems.append(f"slo {name}: no slo_state series recorded")
            continue
        fired = _series_value(metrics, "slo_transitions_total", slo=name)
        if fired < min_alerts:
            problems.append(f"slo {name}: {fired:.0f} alert transitions < "
                            f"--min-alerts {min_alerts}")
        if expect_recovery:
            final = _series_value(metrics, "slo_state", slo=name)
            if final != 0:
                problems.append(f"slo {name}: final state {final:.0f} != OK "
                                f"(burn never recovered)")
    for action in controller_actions:
        if _series_value(metrics, "router_controller_total",
                         action=action) <= 0:
            problems.append(f"controller action {action!r} never counted in "
                            f"router_controller_total")
    if expect_recovery and controller_actions:
        if _series_value(metrics, "router_controller_state") != 0:
            problems.append("router_controller_state != healthy at exit")
    if trace_path.endswith(".jsonl"):
        try:
            spans = load_jsonl(trace_path)
        except Exception as e:                          # noqa: BLE001
            return problems + [f"trace unreadable for slo events: {e}"]
        names = {s.name for s in spans}
        if slos and "slo_alert" not in names:
            problems.append("no slo_alert events in the trace")
        if controller_actions and "controller" not in names:
            problems.append("no controller events in the trace")
    return problems


def check_trace(path: str, *, requests: int) -> List[str]:
    problems: List[str] = []
    if not path.endswith(".jsonl"):
        try:
            with open(path) as f:
                doc = json.load(f)
            n = len(doc.get("traceEvents", []))
        except Exception as e:                          # noqa: BLE001
            return [f"chrome trace unreadable: {e}"]
        if n == 0:
            problems.append("chrome trace has no events")
        return problems

    try:
        spans = load_jsonl(path)
    except Exception as e:                              # noqa: BLE001
        return [f"trace unreadable: {e}"]
    by_rid: Dict[str, int] = {}
    for s in spans:
        if s.rid is not None:
            by_rid[s.rid] = by_rid.get(s.rid, 0) + 1
    for rid in (str(r) for r in range(requests)):
        roots = [s for s in spans if s.rid == rid and s.name == "request"]
        if len(roots) != 1:
            problems.append(f"rid {rid}: {len(roots)} 'request' root spans "
                            f"(want exactly 1)")
            continue
        if roots[0].t1 is None:
            problems.append(f"rid {rid}: request root never ended")
        tree = tree_from_spans(spans, rid)
        if tree is None or tree["name"] != "request":
            problems.append(f"rid {rid}: span tree did not reconstruct")
        elif not tree["children"]:
            problems.append(f"rid {rid}: request tree has no phase children")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--metrics-json", required=True)
    ap.add_argument("--trace", required=True)
    ap.add_argument("--replicas", type=int, required=True)
    ap.add_argument("--requests", type=int, required=True)
    ap.add_argument("--min-retries", type=int, default=0,
                    help="fault plans must surface at least this many "
                         "retries in router_events_total")
    ap.add_argument("--allow-failures", action="store_true",
                    help="don't require completed == requests (deadline "
                         "runs legitimately time requests out)")
    ap.add_argument("--expect-slo", action="append", default=[],
                    metavar="NAME",
                    help="require the SLO loop closed for this objective "
                         "(repeatable): slo_state series + alert "
                         "transitions + slo_alert trace events")
    ap.add_argument("--min-alerts", type=int, default=1,
                    help="min alert transitions per --expect-slo objective")
    ap.add_argument("--expect-controller", default=None, metavar="A,B,...",
                    help="comma list of degradation-controller actions that "
                         "must appear in router_controller_total "
                         "(e.g. tighten,probe,recover)")
    ap.add_argument("--expect-recovery", action="store_true",
                    help="require final slo_state == OK and the controller "
                         "back at healthy (the full closed loop)")
    args = ap.parse_args(argv)

    with open(args.metrics_json) as f:
        payload = json.load(f)
    problems = check_metrics(payload, replicas=args.replicas,
                             requests=args.requests,
                             min_retries=args.min_retries,
                             allow_failures=args.allow_failures)
    problems += check_trace(args.trace, requests=args.requests)
    actions = ([a for a in args.expect_controller.split(",") if a]
               if args.expect_controller else [])
    if args.expect_slo or actions:
        problems += check_slo(payload, args.trace, slos=args.expect_slo,
                              min_alerts=args.min_alerts,
                              controller_actions=actions,
                              expect_recovery=args.expect_recovery)
    if problems:
        print("obs-check FAIL:\n  " + "\n  ".join(problems), file=sys.stderr)
        return 1
    extras = ""
    if args.expect_slo:
        extras = (f", slo loop closed for {args.expect_slo}"
                  + (" with recovery" if args.expect_recovery else ""))
    print(f"obs-check OK: {args.replicas} replicas active, "
          f"{args.requests} span trees complete{extras}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
