"""Minimal, deterministic stand-in for the `hypothesis` property-testing API.

The test suite's property tests use a small slice of hypothesis:
`@settings(max_examples=N, deadline=None)`, `@given(x=st.integers(lo, hi))`.
When the real package is unavailable (this container cannot pip install),
tests/conftest.py registers this module as `hypothesis` in sys.modules so the
suite still *collects and runs* the properties — over a deterministic,
seeded sample of the strategy space — instead of erroring at import time.

Determinism contract: the example stream is a function of the test's qualname
only, so failures reproduce across runs and machines. When real hypothesis is
installed (see pyproject.toml [project.optional-dependencies] dev), it takes
precedence and this module is never imported.

Example count: bounded by min(settings.max_examples, REPRO_MINIHYP_EXAMPLES
[default 12]) to keep CPU suite time sane; the env var raises it for
thorough local runs.
"""
from __future__ import annotations

import os
import random
import types
import zlib

__version__ = "0.0-repro-mini"

_DEFAULT_MAX_EXAMPLES = 100
_EXAMPLE_CAP = int(os.environ.get("REPRO_MINIHYP_EXAMPLES", "12"))


class _Strategy:
    def __init__(self, sample_fn, describe):
        self._sample = sample_fn
        self._describe = describe

    def sample(self, rng: random.Random):
        return self._sample(rng)

    def __repr__(self):
        return self._describe


def integers(min_value=None, max_value=None) -> _Strategy:
    lo = -(2 ** 16) if min_value is None else int(min_value)
    hi = 2 ** 16 if max_value is None else int(max_value)

    def sample(rng):
        return rng.randint(lo, hi)

    return _Strategy(sample, f"integers({lo}, {hi})")


def booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5, "booleans()")


def floats(min_value=None, max_value=None, **_ignored) -> _Strategy:
    # Unbounded defaults sample a wide signed range (real hypothesis explores
    # the full float space; don't let the fallback silently stay in [0, 1]).
    lo = -1e9 if min_value is None else float(min_value)
    hi = 1e9 if max_value is None else float(max_value)
    return _Strategy(lambda rng: rng.uniform(lo, hi), f"floats({lo}, {hi})")


def sampled_from(elements) -> _Strategy:
    pool = list(elements)
    return _Strategy(lambda rng: rng.choice(pool), f"sampled_from({pool!r})")


def lists(elements: _Strategy, min_size=0, max_size=8) -> _Strategy:
    def sample(rng):
        n = rng.randint(min_size, max_size)
        return [elements.sample(rng) for _ in range(n)]

    return _Strategy(sample, f"lists({elements!r})")


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    """Decorator recording the requested example count on the test."""

    def deco(fn):
        fn._mini_max_examples = max_examples
        return fn

    return deco


class _UnsatisfiedAssumption(Exception):
    pass


def given(*arg_strategies, **kw_strategies):
    """Decorator: run the test over a deterministic sample of the strategies.

    Only keyword strategies are supported (the suite uses none positionally).
    The wrapper deliberately does NOT set __wrapped__: pytest would follow it
    and demand fixtures for the property arguments.
    """
    if arg_strategies:
        raise NotImplementedError(
            "hypothesis_mini supports keyword strategies only")

    def deco(fn):
        def wrapper():
            requested = getattr(wrapper, "_mini_max_examples",
                                getattr(fn, "_mini_max_examples",
                                        _DEFAULT_MAX_EXAMPLES))
            n = max(1, min(int(requested), _EXAMPLE_CAP))
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for i in range(n):
                example = {k: s.sample(rng) for k, s in kw_strategies.items()}
                try:
                    fn(**example)
                except _UnsatisfiedAssumption:
                    continue
                except Exception as e:  # re-raise with the falsifying example
                    raise AssertionError(
                        f"falsifying example ({i + 1}/{n}): "
                        f"{fn.__name__}(**{example!r})") from e

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return wrapper

    return deco


class HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    all = classmethod(lambda cls: [cls.too_slow, cls.data_too_large])


def assume(condition) -> bool:
    """Best-effort assume: abort the example silently when unsatisfied."""
    if not condition:
        raise _UnsatisfiedAssumption()
    return True


# `from hypothesis import strategies as st` needs a module-like attribute.
strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = integers
strategies.booleans = booleans
strategies.floats = floats
strategies.sampled_from = sampled_from
strategies.lists = lists
