"""minicpm-2b [dense]: 40L d_model=2304 36H (GQA kv=36) d_ff=5760
vocab=122753 — llama-like arch; WSD schedule handled in optim.
[arXiv:2404.06395; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36, d_ff=5760,
    vocab=122753, tie_embeddings=True,
    supports_long_context=False,
)
