"""Declarative SLOs evaluated as multi-window burn rates.

An :class:`Objective` states what "healthy" means in one line — the DSL the
launcher exposes::

    Objective.parse("ttft_ms p99 < 200")     # latency quantile bound
    Objective.parse("error_rate < 0.1")      # failed / total bound

Evaluation follows the SRE-workbook multi-window shape: the **burn rate**
(observed value / threshold) must exceed the trigger in BOTH a fast window
(is it happening *now*?) and a slow window (is it *sustained*?) before the
alert escalates. Both windows are served by one :class:`WindowedHistogram`
(or good/bad :class:`WindowedCounter` pair) per objective, so the whole
thing is exact and deterministic under ``FakeClock``.

Alert state is a ladder — ``OK → WARN → PAGE`` — with asymmetric
hysteresis: escalation is immediate, de-escalation requires the burn to
stay below the trigger for ``clear_s`` continuously. Together with the
``min_count`` floor (fewer samples than this in a window can never PAGE) a
single latency spike cannot flap OK→PAGE→OK: it either lacks the sample
support to page at all, or pages and then *stays* paged for ``clear_s``.

Every transition is recorded three ways (the "obs events and spans" the
router's degradation controller consumes):

* counter ``slo_transitions_total{slo, to}``
* gauges ``slo_state{slo}`` (0/1/2) and ``slo_burn_rate{slo, window}``
* a ``slo_alert`` point event on the tracer with from/to/burn attrs.
"""
from __future__ import annotations

import dataclasses
import enum
import re
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.obs.metrics import Registry
from repro.obs.trace import Tracer


class AlertState(enum.IntEnum):
    OK = 0
    WARN = 1
    PAGE = 2


_SPEC_RE = re.compile(
    r"^\s*(?P<metric>[A-Za-z_][\w]*)"
    r"(?:\s+p(?P<q>\d+(?:\.\d+)?))?"
    r"\s*<\s*(?P<thr>[0-9.eE+-]+)\s*$")


@dataclasses.dataclass(frozen=True)
class Objective:
    """One SLO: a metric, a threshold, and the burn-rate evaluation knobs.

    ``kind`` is ``"latency"`` (windowed quantile of observed values vs
    ``threshold``) or ``"error_rate"`` (windowed bad/total ratio vs
    ``threshold``). Units are the caller's: a ``ttft_ms`` objective is fed
    milliseconds via :meth:`SloMonitor.observe_latency`.
    """

    name: str
    threshold: float
    kind: str = "latency"                  # "latency" | "error_rate"
    quantile: float = 0.99
    fast_window_s: float = 5.0
    slow_window_s: float = 30.0
    warn_burn: float = 1.0                 # slow-window burn to WARN
    page_burn: float = 1.0                 # fast AND slow burn to PAGE
    clear_s: Optional[float] = None        # default: slow_window_s / 3
    min_count: int = 3                     # sample floor per window to PAGE

    def __post_init__(self):
        if self.kind not in ("latency", "error_rate"):
            raise ValueError(f"unknown SLO kind {self.kind!r}")
        if self.threshold <= 0:
            raise ValueError(f"{self.name}: threshold must be > 0")
        if self.fast_window_s >= self.slow_window_s:
            raise ValueError(f"{self.name}: fast window must be shorter "
                             f"than slow window")

    @property
    def effective_clear_s(self) -> float:
        return self.slow_window_s / 3.0 if self.clear_s is None else self.clear_s

    @classmethod
    def parse(cls, spec: str, **overrides) -> "Objective":
        """``"ttft_ms p99 < 200"`` or ``"error_rate < 0.1"``; keyword
        overrides adjust windows/hysteresis."""
        m = _SPEC_RE.match(spec)
        if m is None:
            raise ValueError(
                f"bad SLO spec {spec!r} (want '<metric> p99 < X' or "
                f"'error_rate < Y')")
        metric = m.group("metric")
        kw: dict = {"name": metric, "threshold": float(m.group("thr"))}
        if metric == "error_rate":
            kw["kind"] = "error_rate"
            if m.group("q") is not None:
                raise ValueError(f"{spec!r}: error_rate takes no quantile")
        else:
            kw["kind"] = "latency"
            if m.group("q") is not None:
                kw["quantile"] = float(m.group("q")) / 100.0
        kw.update(overrides)
        return cls(**kw)


class SloTracker:
    """Evaluation state for one objective: windowed instruments + the
    alert ladder with hysteresis."""

    def __init__(self, obj: Objective, *, registry: Registry,
                 clock: Callable[[], float]):
        self.obj = obj
        self.state = AlertState.OK
        self.last_burns: Tuple[float, float] = (0.0, 0.0)
        self._below_since: Optional[float] = None
        # sub-bucket = a quarter of the fast window, so the fast query is
        # whole sub-buckets and the slow window is an integer multiple-ish
        sub_s = obj.fast_window_s / 4.0
        n = max(1, int(round(obj.slow_window_s / sub_s)))
        if obj.kind == "latency":
            self._hist = registry.windowed_histogram(
                f"slo_{obj.name}_window",
                f"windowed observations backing SLO {obj.name}",
                window_s=obj.slow_window_s, sub_buckets=n, clock=clock)
            self._good = self._bad = None
        else:
            self._hist = None
            self._good = registry.windowed_counter(
                f"slo_{obj.name}_good_window",
                f"windowed good events backing SLO {obj.name}",
                window_s=obj.slow_window_s, sub_buckets=n, clock=clock)
            self._bad = registry.windowed_counter(
                f"slo_{obj.name}_bad_window",
                f"windowed bad events backing SLO {obj.name}",
                window_s=obj.slow_window_s, sub_buckets=n, clock=clock)

    # -- feeding -------------------------------------------------------------
    def observe(self, value: float) -> None:
        if self._hist is None:
            raise TypeError(f"{self.obj.name}: error_rate SLO takes "
                            f"observe_event(ok), not latency values")
        self._hist.observe(value)

    def observe_event(self, ok: bool) -> None:
        if self._good is None:
            raise TypeError(f"{self.obj.name}: latency SLO takes "
                            f"observe(value), not outcomes")
        (self._good if ok else self._bad).inc()

    # -- evaluation ----------------------------------------------------------
    def _burn(self, window_s: float, now: float) -> Tuple[float, int]:
        """(burn rate, sample count) over one window."""
        o = self.obj
        if o.kind == "latency":
            n = self._hist.count(window_s, now)
            if n == 0:
                return 0.0, 0
            return self._hist.quantile(o.quantile, window_s, now) / o.threshold, n
        good = self._good.count(window_s, now)
        bad = self._bad.count(window_s, now)
        total = good + bad
        if total == 0:
            return 0.0, 0
        return (bad / total) / o.threshold, total

    def burns(self, now: float) -> Tuple[float, float]:
        bf, _ = self._burn(self.obj.fast_window_s, now)
        bs, _ = self._burn(self.obj.slow_window_s, now)
        return bf, bs

    def evaluate(self, now: float
                 ) -> Optional[Tuple[AlertState, AlertState]]:
        """Advance the ladder; returns (old, new) on a transition."""
        o = self.obj
        bf, cf = self._burn(o.fast_window_s, now)
        bs, cs = self._burn(o.slow_window_s, now)
        if (bf >= o.page_burn and bs >= o.page_burn
                and cf >= o.min_count and cs >= o.min_count):
            target = AlertState.PAGE
        elif bs >= o.warn_burn and cs >= o.min_count:
            target = AlertState.WARN
        else:
            target = AlertState.OK
        old = self.state
        if target > self.state:                      # escalate immediately
            self.state = target
            self._below_since = None
        elif target < self.state:                    # de-escalate after clear_s
            if self._below_since is None:
                self._below_since = now
            elif now - self._below_since >= o.effective_clear_s:
                self.state = target
                self._below_since = None
        else:
            self._below_since = None
        self.last_burns = (bf, bs)
        return (old, self.state) if self.state != old else None


class SloMonitor:
    """A set of objectives sharing one registry/tracer/clock. The router
    feeds it per-request measurements and calls :meth:`evaluate` once per
    scheduler tick; the max objective state is the fleet alert level."""

    def __init__(self, objectives: Sequence[Objective], *,
                 registry: Registry, tracer: Optional[Tracer] = None,
                 clock: Callable[[], float]):
        if not objectives:
            raise ValueError("SloMonitor needs at least one objective")
        names = [o.name for o in objectives]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate objective names: {names}")
        self.registry = registry
        self.tracer = tracer
        self.clock = clock
        self.trackers: Dict[str, SloTracker] = {
            o.name: SloTracker(o, registry=registry, clock=clock)
            for o in objectives}
        self._g_state = registry.gauge(
            "slo_state", "alert state per SLO (0=OK 1=WARN 2=PAGE)",
            labels=("slo",))
        self._g_burn = registry.gauge(
            "slo_burn_rate", "burn rate per SLO and window",
            labels=("slo", "window"))
        self._c_trans = registry.counter(
            "slo_transitions_total", "alert-state transitions per SLO",
            labels=("slo", "to"))
        for name in self.trackers:
            self._g_state.labels(slo=name).set(0)

    def observe_latency(self, name: str, value: float) -> None:
        t = self.trackers.get(name)
        if t is not None and t.obj.kind == "latency":
            t.observe(value)

    def observe_event(self, name: str, ok: bool) -> None:
        t = self.trackers.get(name)
        if t is not None and t.obj.kind == "error_rate":
            t.observe_event(ok)

    def evaluate(self, now: Optional[float] = None) -> AlertState:
        """Evaluate every objective; record transitions; return the max
        (worst) alert state across objectives."""
        if now is None:
            now = self.clock()
        worst = AlertState.OK
        for name, t in self.trackers.items():
            moved = t.evaluate(now)
            bf, bs = t.last_burns
            self._g_burn.labels(slo=name, window="fast").set(bf)
            self._g_burn.labels(slo=name, window="slow").set(bs)
            self._g_state.labels(slo=name).set(int(t.state))
            if moved is not None:
                old, new = moved
                self._c_trans.labels(slo=name, to=new.name).inc()
                if self.tracer is not None:
                    self.tracer.event(
                        "slo_alert", slo=name, frm=old.name, to=new.name,
                        burn_fast=round(bf, 6), burn_slow=round(bs, 6))
            if t.state > worst:
                worst = t.state
        return worst

    def states(self) -> Dict[str, AlertState]:
        return {name: t.state for name, t in self.trackers.items()}
