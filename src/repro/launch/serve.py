"""Serving launcher: per-slot continuous batching over any arch.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --smoke \
        --requests 8 --slots 4 --decode-chunk 4

``--quantized`` routes the dense/attention projections through the int8 FFIP
decode path (offline-quantized weights, Eq. 15 folded beta, Eq. 20 zero-point
adjuster). ``--decode-chunk N`` fuses N decode steps into one dispatch
(sampling stays on device either way); bucketed batched prefill is on by
default (``--no-prefill-buckets`` forces the per-slot fallback).
``--gemm-impl pallas`` routes the serving projections through the Pallas
kernels and ``--gemm-block auto`` resolves their block shapes (plus flash
attention's) from the ``repro.tune`` schedule cache — pre-populate it with
``python -m repro.launch.tune``. Exits non-zero if any request is dropped or
over/under-generates, so this doubles as the CI batcher-regression smoke.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models.model import build_model
from repro.serve.batcher import BatchServer, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(configs.ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--quantized", action="store_true",
                    help="int8 FFIP decode path (offline weight quantization)")
    ap.add_argument("--decode-chunk", type=int, default=1,
                    help="decode steps fused into one dispatch (lax.scan)")
    ap.add_argument("--no-prefill-buckets", action="store_true",
                    help="disable bucketed batched prefill (per-slot fallback)")
    ap.add_argument("--gemm-impl", choices=["xla", "pallas"], default=None,
                    help="GEMM provider for the serving forward "
                         "(pallas = the paper's kernels)")
    ap.add_argument("--gemm-block", default=None,
                    help="'auto' (repro.tune schedule cache; also tunes flash "
                         "attention blocks) or explicit 'bm,bn,bk' (needs --gemm-impl pallas)")
    args = ap.parse_args()
    gemm_block = args.gemm_block
    if gemm_block and gemm_block != "auto":
        gemm_block = tuple(int(x) for x in gemm_block.split(","))

    cfg = configs.get_config(args.arch)
    if args.smoke:
        cfg = configs.smoke_config(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    srv = BatchServer(model, batch_slots=args.slots, max_len=args.max_len,
                      quantized=args.quantized, decode_chunk=args.decode_chunk,
                      gemm_impl=args.gemm_impl, gemm_block=gemm_block,
                      prefill_buckets=not args.no_prefill_buckets)

    rng = np.random.default_rng(0)
    lens = rng.integers(3, 12, args.requests)
    t0 = time.perf_counter()
    for i in range(args.requests):
        srv.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab, size=(int(lens[i]),)),
            max_new_tokens=args.max_new))
    done = srv.run_until_drained(params)
    dt = time.perf_counter() - t0

    total = sum(len(r.out_tokens) for r in done)
    mode = "int8-ffip" if args.quantized else "float"
    st = srv.stats
    print(f"[{mode}] {len(done)}/{args.requests} requests / {total} tokens "
          f"in {dt:.2f}s ({total / dt:.1f} tok/s host-side, "
          f"decode_chunk={args.decode_chunk})")
    print(f"  prefill {st['prefill_s']:.2f}s ({st['prefill_tokens']} tok / "
          f"{st['prefill_dispatches']} dispatches), "
          f"decode {st['decode_s']:.2f}s over {st['steps']} steps / "
          f"{st['decode_dispatches']} dispatches ({st['decode_tokens']} tok), "
          f"host/other {dt - st['prefill_s'] - st['decode_s']:.2f}s")
    print(f"  compiles: prefill={srv.compiles['prefill']} "
          f"decode={srv.compiles['decode']}, "
          f"host transfer {st['host_bytes_prefill'] + st['host_bytes_decode']}"
          f" B total "
          f"(sampling on device: ids only, never (B, V) logits)")
    if args.gemm_block == "auto":
        from repro import tune
        print(f"  tune: {tune.stats['hits']} schedule hits / "
              f"{tune.stats['misses']} misses (cache: "
              f"{tune.get_cache().path})")

    # regression gates: nothing dropped, exact token budgets, valid ids
    assert len(done) == args.requests, "run_until_drained dropped requests"
    assert sorted(r.rid for r in done) == list(range(args.requests))
    for r in done:
        assert len(r.out_tokens) == r.max_new_tokens, \
            (r.rid, len(r.out_tokens), r.max_new_tokens)
        assert all(0 <= t < cfg.vocab for t in r.out_tokens), r.rid
    print("OK")


if __name__ == "__main__":
    main()
