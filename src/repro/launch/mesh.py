"""Production meshes. Functions only — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Elastic helper: arbitrary (pods, data, model) topologies — used by the
    checkpoint-reshard path when scaling a job up/down."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh():
    """Single-device mesh for smoke/e2e tests on CPU."""
    return jax.make_mesh((1, 1), ("data", "model"))
