"""Per-request lifecycle state + the typed serving-error taxonomy.

Every request moving through the serving stack is in exactly one state:

    QUEUED -> ADMITTED -> PREFILLING -> DECODING -> DONE
       \\________________________________________/-> FAILED | TIMED_OUT

(The PREFILLING state is observable in paged chunked-prefill mode, where a
prompt runs one page-aligned chunk per drive tick; contiguous prefill is
atomic inside a single replica step, so contiguous requests go straight
from ADMITTED to DECODING.)

A terminal state is FINAL: :meth:`RequestRecord.transition` refuses to leave
it, which is the router's duplicate-emission guard — a late completion (or a
second completion of a retried request) can never overwrite a result that
was already exposed.

Every failure mode has a TYPED error, so callers can distinguish "shed this
and retry later" (:class:`RejectedError`, carries ``retry_after_s``) from
"this request can never run" (:class:`AdmissionImpossibleError`) from "the
serving loop itself wedged" (:class:`ServeStallError`, lists the stuck
requests). :class:`AdmissionImpossibleError` subclasses ``ValueError`` and
:class:`ServeStallError` subclasses ``RuntimeError`` so pre-existing broad
handlers keep working.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable, Dict, List, Optional, Tuple


class Lifecycle(enum.Enum):
    QUEUED = "queued"            # in the router (or server) queue
    ADMITTED = "admitted"        # handed to a replica, not yet running
    PREFILLING = "prefilling"    # prompt chunks running (paged chunked mode)
    DECODING = "decoding"        # occupying a slot, emitting tokens
    DONE = "done"                # completed; tokens exposed exactly once
    FAILED = "failed"            # typed error after bounded retries
    TIMED_OUT = "timed_out"      # deadline / per-phase timeout exceeded


TERMINAL = frozenset(
    {Lifecycle.DONE, Lifecycle.FAILED, Lifecycle.TIMED_OUT})


class ServeError(Exception):
    """Base of every typed serving failure."""


class RejectedError(ServeError):
    """Admission control shed this request — resubmit after ``retry_after_s``
    (backpressure, not a permanent failure)."""

    def __init__(self, msg: str, *, retry_after_s: float):
        super().__init__(f"{msg} (retry after {retry_after_s:.3f}s)")
        self.retry_after_s = retry_after_s


class AdmissionImpossibleError(ServeError, ValueError):
    """The request can NEVER be admitted (needs more cache rows than
    ``max_len`` or more pages than the pool holds) — failing it at submit
    time beats letting it sit in a queue forever."""


class ServeStallError(ServeError, RuntimeError):
    """The drive loop exhausted its step budget with requests still live.
    ``stuck`` maps request id -> a human-readable description of where each
    one was wedged."""

    def __init__(self, msg: str, *, stuck: Dict[int, str]):
        detail = "; ".join(f"rid {rid}: {where}"
                           for rid, where in sorted(stuck.items()))
        super().__init__(f"{msg} — stuck: {detail}")
        self.stuck = dict(stuck)


class DeadlineExceededError(ServeError, TimeoutError):
    """A request blew its end-to-end deadline or a per-phase timeout;
    ``phase`` records the lifecycle state it was in."""

    def __init__(self, msg: str, *, phase: str):
        super().__init__(f"{msg} (phase: {phase})")
        self.phase = phase


class PoisonedOutputError(ServeError):
    """A replica returned output that failed the cheap sanity check
    (out-of-vocabulary token / wrong emission count) — the emission is
    discarded and the request retried on another replica."""


class ReplicaFailedError(ServeError):
    """A replica's step raised or hung; ``replica`` is its index and
    ``cause`` the underlying exception."""

    def __init__(self, msg: str, *, replica: int,
                 cause: Optional[BaseException] = None):
        super().__init__(msg)
        self.replica = replica
        self.cause = cause


class RetriesExhaustedError(ServeError):
    """The bounded retry budget ran out; ``cause`` is the LAST failure."""

    def __init__(self, msg: str, *, attempts: int,
                 cause: Optional[BaseException] = None):
        super().__init__(f"{msg} (attempts: {attempts}, last cause: "
                         f"{type(cause).__name__ if cause else None})")
        self.attempts = attempts
        self.cause = cause


def output_sanity_error(tokens, *, vocab: int, max_new: int,
                        eos_id: int) -> Optional[str]:
    """Cheap output-sanity check run on every completion BEFORE it is
    exposed: token ids in range, emission count consistent with the token
    budget / EOS contract. Returns a description of the defect, or None.
    (This is intentionally O(tokens) host work — it guards against a
    poisoned/corrupt batch, not numerical drift.)"""
    if tokens is None or len(tokens) == 0:
        return "no tokens emitted"
    if len(tokens) > max_new:
        return f"emitted {len(tokens)} > max_new_tokens {max_new}"
    bad = [t for t in tokens if not 0 <= int(t) < vocab]
    if bad:
        return f"out-of-vocabulary token(s) {bad[:4]} (vocab {vocab})"
    if len(tokens) < max_new and int(tokens[-1]) != eos_id:
        return (f"short emission ({len(tokens)}/{max_new}) without a "
                f"terminal EOS ({eos_id})")
    return None


@dataclasses.dataclass
class RequestRecord:
    """Router-side lifecycle record for one request (the ``Request`` object
    handed to replicas is a fresh copy per attempt, so a failed attempt can
    never leak partial tokens into the exposed result)."""
    req: Any                                  # serve.batcher.Request
    state: Lifecycle = Lifecycle.QUEUED
    deadline: Optional[float] = None          # absolute clock time, or None
    attempts: int = 0                         # retries consumed so far
    replica: Optional[int] = None             # current replica index
    tier: Optional[str] = None                # tier that produced `tokens`
    tokens: Optional[List[int]] = None        # exposed exactly once, at DONE
    error: Optional[BaseException] = None     # terminal failure cause
    last_error: Optional[BaseException] = None   # most recent retried cause
    next_eligible: float = 0.0                # backoff gate for re-dispatch
    t_submit: float = 0.0
    t_done: float = 0.0
    history: List[Tuple[str, float]] = dataclasses.field(default_factory=list)
    # observability hook: called AFTER every state change with
    # (record, new_state, t). The router installs one that mirrors the state
    # machine into obs phase spans; the record itself stays telemetry-free.
    observer: Optional[Callable[["RequestRecord", "Lifecycle", float], None]] \
        = dataclasses.field(default=None, repr=False, compare=False)

    def transition(self, state: Lifecycle, t: float):
        if self.state in TERMINAL:
            raise AssertionError(
                f"request {self.req.rid}: illegal transition "
                f"{self.state.value} -> {state.value} (terminal is final)")
        self.state = state
        self.history.append((state.value, t))
        if self.observer is not None:
            self.observer(self, state, t)

    @property
    def terminal(self) -> bool:
        return self.state in TERMINAL

    @property
    def phase_entered(self) -> float:
        """Clock time the CURRENT state was entered (per-phase timeouts)."""
        return self.history[-1][1] if self.history else self.t_submit
