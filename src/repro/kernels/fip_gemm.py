"""FIP (Winograd 1968) GEMM as a Pallas TPU kernel — Fig. 1b adapted to TPU.

TPU adaptation (DESIGN.md §2): the FIP PE trades half the multipliers for
pre-adders. On TPU there is no MXU mapping for the (i,j)-coupled pre-add, so
the kernel performs the halved-multiplication algebra on the VPU with explicit
VMEM blocking: per (bm, bk, bn) tile it forms the two pre-add tensors
(bm, bk/2, bn), multiplies elementwise, reduces over the pair axis, and
accumulates cross − α_blk − β_blk into the output block. The α row of the
paper's MXU (Fig. 3) corresponds to the in-kernel α_blk computation; β may be
pre-folded into the bias by the caller (Eq. 15), in which case the kernel
skips the β term.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.baseline_gemm import pad_to_blocks
from repro.kernels.compat import resolve_interpret, tpu_compiler_params

Array = jax.Array


def fip_tile(a, b, *, fold_beta: bool):
    """Eq. (2) on one (bm, bk) x (bk, bn) tile: pre-add, multiply, reduce
    over the pair axis, subtract the alpha (and beta unless folded) rows.
    SHARED between this GEMM kernel and the fused implicit-im2col conv
    kernels (kernels/conv_gemm.py) — one algebra, two A-tile sources, so the
    fused conv is bit-identical to the materialized GEMM by construction."""
    a_odd, a_evn = a[:, 0::2], a[:, 1::2]      # a_{i,2k-1}, a_{i,2k}
    b_odd, b_evn = b[0::2, :], b[1::2, :]      # b_{2k-1,j}, b_{2k,j}
    # Eq. (2) cross term on this tile: the FIP PE pre-adds then multiplies.
    t1 = a_odd[:, :, None] + b_evn[None, :, :]   # (bm, bk/2, bn)
    t2 = a_evn[:, :, None] + b_odd[None, :, :]
    cross = jnp.sum(t1 * t2, axis=1)             # (bm, bn)
    alpha = jnp.sum(a_odd * a_evn, axis=1)       # Eq. (3), the alpha MAC row
    part = cross - alpha[:, None]
    if not fold_beta:
        beta = jnp.sum(b_odd * b_evn, axis=0)    # Eq. (4)
        part = part - beta[None, :]
    return part


def _kernel(a_ref, b_ref, o_ref, *, acc_dtype, fold_beta):
    kk = pl.program_id(2)
    a = a_ref[...].astype(acc_dtype)           # (bm, bk)
    b = b_ref[...].astype(acc_dtype)           # (bk, bn)
    part = fip_tile(a, b, fold_beta=fold_beta)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = part

    @pl.when(kk != 0)
    def _acc():
        o_ref[...] += part


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret",
                                             "fold_beta"))
def fip_gemm(a: Array, b: Array, *, bm: int = 128, bn: int = 128, bk: int = 64,
             interpret=None, fold_beta: bool = False) -> Array:
    """a: (M, K), b: (K, N) -> (M, N) via Eq. (2). bk must be even (pairs);
    shapes not divisible by the blocks are zero-padded and the result sliced
    (zero pairs pre-add to zero, so cross/alpha/beta are unchanged — exact).
    With ``fold_beta=True`` the caller is expected to add
    ``fold_beta_into_bias(b)`` (Eq. 15) afterwards — the hardware's free beta
    handling. ``interpret=None`` auto-detects the backend (compat.py)."""
    interpret = resolve_interpret(interpret)
    assert bk % 2 == 0
    m0, k0 = a.shape
    k2, n0 = b.shape
    assert k0 == k2
    a, b = pad_to_blocks(a, b, bm, bn, bk)
    m, k = a.shape
    n = b.shape[1]
    acc_dtype = (jnp.int32 if jnp.issubdtype(a.dtype, jnp.integer)
                 else jnp.float32)
    grid = (m // bm, n // bn, k // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, acc_dtype=acc_dtype, fold_beta=fold_beta),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), acc_dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
    return out[:m0, :n0]
