"""Kernel profiling hooks: per-dispatch counts, achieved GOPS, bytes moved.

The paper's headline numbers (§6, Eqs. 31a-c) are *rates* — GOPS, GOPS per
multiplier — which until now only existed inside bench scripts. This module
gives every Pallas call site one place to record:

* **dispatches** — a thin hook in ``kernels/ops.matmul``,
  ``kernels/conv_gemm.conv_gemm_fused`` and
  ``kernels/flash_attention.flash_attention`` calls
  :meth:`KernelProfiler.record_gemm` / ``record_conv`` / ``record_flash``.
  Eager calls count as dispatches; calls made while JAX is tracing (operands
  are ``Tracer``\\s) count separately as ``traces`` — a traced call runs the
  python body once per compilation, not per step, so folding the two
  together would overcount by exactly the compile amortization the serving
  stack works to achieve.

* **work done** — effective (baseline-equivalent) FLOPs from
  ``core/analytical`` Eq. (1), algo-specific multiplier counts from
  Eqs. (5)/(7) so FIP/FFIP's 2x multiply reduction is visible in telemetry,
  and operand+result bytes for roofline placement.

* **achieved rates** — ``record_timed`` (called by ``tune/measure``'s
  timing harness) turns a measured wall time into achieved GOPS
  (histogram + last-value gauge per ``{kernel, algo, dtype}``).

* **compile events** — :func:`compile_snapshot` unifies the previously
  scattered counters: ``kernels/compat.DerivedCache.stats``,
  ``tune.stats`` (schedule-cache hits/misses) and ``tune/measure.counters``
  (candidates timed/failed) into one dict. Imports are lazy: this module
  must stay importable from ``kernels/``, so it never imports ``kernels``
  or ``tune`` at module level.

Metric families (all labeled ``{kernel, algo, dtype}``):
``repro_kernel_dispatches_total``, ``repro_kernel_traces_total``,
``repro_kernel_flops_total``, ``repro_kernel_mults_total``,
``repro_kernel_bytes_total``, ``repro_kernel_measured_gops`` (gauge),
``repro_kernel_measured_seconds`` (histogram).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

from repro.core import analytical

_TIMING_BUCKETS = (1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3,
                   5e-3, 1e-2, 2.5e-2, 5e-2, 0.1, 0.25, 0.5, 1.0)

_LABELS = ("kernel", "algo", "dtype")


def _is_tracer(*xs) -> bool:
    try:
        import jax
        return any(isinstance(x, jax.core.Tracer) for x in xs)
    except Exception:               # jax unavailable / API drift: count eager
        return False


def _dtype_name(x) -> str:
    d = getattr(x, "dtype", x)
    return getattr(d, "name", str(d))


class KernelProfiler:
    """Records kernel-level telemetry into a metrics registry."""

    def __init__(self, registry=None):
        if registry is None:
            from repro.obs.metrics import get_registry
            registry = get_registry()
        self.registry = registry
        r = registry
        self.dispatches = r.counter(
            "repro_kernel_dispatches_total",
            "eager kernel launches", _LABELS)
        self.traces = r.counter(
            "repro_kernel_traces_total",
            "kernel call sites hit during jax tracing (compile-side)",
            _LABELS)
        self.flops = r.counter(
            "repro_kernel_flops_total",
            "effective baseline-equivalent ops (Eq. 1)", _LABELS)
        self.mults = r.counter(
            "repro_kernel_mults_total",
            "algo-specific multiplications (Eqs. 5/7 for fip/ffip)", _LABELS)
        self.bytes = r.counter(
            "repro_kernel_bytes_total",
            "operand + result bytes moved", _LABELS)
        self.measured_gops = r.gauge(
            "repro_kernel_measured_gops",
            "last measured achieved GOPS (tune harness)", _LABELS)
        self.measured_seconds = r.histogram(
            "repro_kernel_measured_seconds",
            "measured kernel wall time (tune harness)", _LABELS,
            buckets=_TIMING_BUCKETS)

    # -- shape accounting ---------------------------------------------------
    def _record(self, kernel: str, algo: str, dtype: str, *, traced: bool,
                flops: float, mults: float, bytes_moved: float) -> None:
        lab = dict(kernel=kernel, algo=algo, dtype=dtype)
        if traced:
            self.traces.labels(**lab).inc()
            return
        self.dispatches.labels(**lab).inc()
        self.flops.labels(**lab).inc(flops)
        self.mults.labels(**lab).inc(mults)
        self.bytes.labels(**lab).inc(bytes_moved)

    @staticmethod
    def _gemm_work(m: int, k: int, n: int, algo: str,
                   itemsize: int) -> Tuple[float, float, float]:
        flops = analytical.baseline_mults(m, k, n) + \
            analytical.baseline_adds(m, k, n)
        if algo in ("fip", "ffip") and k % 2 == 0:
            mults = analytical.fip_mults(m, k, n)
        else:
            mults = analytical.baseline_mults(m, k, n)
        bytes_moved = (m * k + k * n + m * n) * itemsize
        return float(flops), float(mults), float(bytes_moved)

    def record_gemm(self, m: int, k: int, n: int, *, algo: str, dtype: Any,
                    traced: bool = False, batch: int = 1) -> None:
        f, mu, by = self._gemm_work(m, k, n, algo,
                                    _itemsize(dtype))
        self._record("gemm", algo, _dtype_name(dtype), traced=traced,
                     flops=f * batch, mults=mu * batch,
                     bytes_moved=by * batch)

    def record_conv(self, *, batch: int, oh: int, ow: int, cin: int,
                    kh: int, kw: int, cout: int, groups: int, algo: str,
                    dtype: Any, traced: bool = False) -> None:
        """Implicit-im2col conv == GEMM of (B*OH*OW) x (KH*KW*Cin/g) x
        (Cout/g), per group."""
        m = batch * oh * ow
        kdim = kh * kw * (cin // max(groups, 1))
        n = cout // max(groups, 1)
        f, mu, by = self._gemm_work(m, kdim, n, algo, _itemsize(dtype))
        g = max(groups, 1)
        self._record("conv", algo, _dtype_name(dtype), traced=traced,
                     flops=f * g, mults=mu * g, bytes_moved=by * g)

    def record_flash(self, *, bh: int, sq: int, sk: int, d: int, dtype: Any,
                     causal: bool = True, traced: bool = False) -> None:
        """QK^T + PV: two (sq x d x sk)-class matmuls per batch*head;
        causal halves the score rectangle."""
        scale = 0.5 if causal and sq == sk else 1.0
        per = 4.0 * sq * sk * d * scale          # 2 matmuls * 2 ops/MAC
        by = (sq * d + 2 * sk * d + sq * d) * _itemsize(dtype)
        self._record("flash", "dot", _dtype_name(dtype), traced=traced,
                     flops=per * bh, mults=per * bh / 2.0,
                     bytes_moved=float(by * bh))

    # -- measured rates (tune harness) --------------------------------------
    def record_timed(self, kernel: str, seconds: float, *, flops: float,
                     algo: str = "ffip", dtype: Any = "float32") -> None:
        lab = dict(kernel=kernel, algo=algo, dtype=_dtype_name(dtype))
        self.measured_seconds.labels(**lab).observe(seconds)
        if seconds > 0:
            self.measured_gops.labels(**lab).set(flops / seconds * 1e-9)


def _itemsize(dtype) -> int:
    try:
        import numpy as np
        return int(np.dtype(getattr(dtype, "name", dtype)).itemsize)
    except Exception:
        return 4


# -- module-level hooks (what the kernel call sites invoke) ------------------

_profiler: Optional[KernelProfiler] = None
_enabled = True


def get_profiler() -> KernelProfiler:
    global _profiler
    if _profiler is None:
        _profiler = KernelProfiler()
    return _profiler


def set_profiler(p: Optional[KernelProfiler]) -> Optional[KernelProfiler]:
    """Swap the process profiler (tests inject one with a fresh registry);
    returns the previous instance. ``None`` resets to lazy re-creation
    against the (possibly swapped) default registry."""
    global _profiler
    prev, _profiler = _profiler, p
    return prev


def enable(on: bool = True) -> None:
    global _enabled
    _enabled = on


def on_gemm(a, b, algo: str) -> None:
    """Hook called by ``kernels.ops.matmul`` — must never raise."""
    if not _enabled:
        return
    try:
        *lead, m, k = a.shape
        n = b.shape[-1]
        batch = 1
        for d in lead:
            batch *= int(d)
        get_profiler().record_gemm(int(m), int(k), int(n), algo=algo,
                                   dtype=a.dtype, traced=_is_tracer(a, b),
                                   batch=max(batch, 1))
    except Exception:
        pass


def on_conv(x, kernel, *, oh: int, ow: int, groups: int, algo: str) -> None:
    """Hook called by ``kernels.conv_gemm.conv_gemm_fused``."""
    if not _enabled:
        return
    try:
        b, _, _, cin = x.shape
        kh, kw, _, cout = kernel.shape
        get_profiler().record_conv(
            batch=int(b), oh=int(oh), ow=int(ow), cin=int(cin), kh=int(kh),
            kw=int(kw), cout=int(cout), groups=groups, algo=algo,
            dtype=x.dtype, traced=_is_tracer(x, kernel))
    except Exception:
        pass


def on_flash(q, k, *, causal: bool) -> None:
    """Hook called by ``kernels.flash_attention.flash_attention``."""
    if not _enabled:
        return
    try:
        bh, sq, d = q.shape
        sk = k.shape[-2]
        get_profiler().record_flash(bh=int(bh), sq=int(sq), sk=int(sk),
                                    d=int(d), dtype=q.dtype, causal=causal,
                                    traced=_is_tracer(q, k))
    except Exception:
        pass


# -- cost derivation / compile-event unification -----------------------------

def dispatch_cost(fn, *args) -> Optional[Tuple[float, float]]:
    """(flops, bytes) for one dispatch of ``fn(*args)`` via the jaxpr cost
    model in ``launch/costs.py``. Returns None when tracing fails (cost
    accounting must never break serving). NOTE: tracing a jit-wrapped fn
    re-runs its python body — callers that carry compile counters (the
    batcher) must pass the underlying impl, not the jitted wrapper."""
    try:
        from repro.launch import costs
        c = costs.fn_cost(fn, *args)
        return float(c.flops), float(c.bytes)
    except Exception:
        return None


def compile_snapshot() -> Dict[str, Dict[str, int]]:
    """One dict unifying every compile-side counter in the codebase:

    - ``derived_cache``: ``kernels/compat.DerivedCache.stats`` (computed /
      hits / seeded weight-transform cache entries)
    - ``schedule_cache``: ``repro.tune.stats`` (tuned-schedule lookups)
    - ``measure``: ``tune/measure.counters`` (candidates timed / failed)

    Lazy imports; a missing subsystem contributes ``{}`` instead of raising.
    """
    out: Dict[str, Dict[str, int]] = {}
    try:
        from repro.kernels import compat
        out["derived_cache"] = dict(compat.derived.stats)
    except Exception:
        out["derived_cache"] = {}
    try:
        import repro.tune as tune
        out["schedule_cache"] = dict(tune.stats)
    except Exception:
        out["schedule_cache"] = {}
    try:
        from repro.tune import measure
        out["measure"] = dict(measure.counters)
    except Exception:
        out["measure"] = {}
    return out
