"""Measurement harness for the kernel autotuner.

Discipline (the part micro-benchmarks usually get wrong):
  * compilation happens OUTSIDE the timed region — one untimed warmup call per
    candidate pays the jit/pallas build before any timer starts;
  * median-of-k timing (default k=3) so one scheduler hiccup can't crown the
    wrong candidate;
  * candidates are timed in the deterministic order space.py emits, with a
    first-wins tie-break, so a tuning run is reproducible bit-for-bit in its
    *choice* even when wall-clock noise wiggles.

``counters`` tracks how many candidates were actually timed — the cache tests
assert ZERO new measurements on a warm-cache run, which is the whole point of
persisting schedules.
"""
from __future__ import annotations

import statistics
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.kernels.compat import resolve_interpret
from repro.obs import profile as _obs_profile

counters: Dict[str, int] = {"timed_candidates": 0, "failed_candidates": 0}


def _record_timed(kernel: str, seconds: float, *, flops: float, algo: str,
                  dtype) -> None:
    """Mirror a measured candidate into obs (achieved GOPS gauge + wall-time
    histogram). Telemetry must never fail a tuning run."""
    try:
        _obs_profile.get_profiler().record_timed(
            kernel, seconds, flops=flops, algo=algo, dtype=dtype)
    except Exception:               # noqa: BLE001
        pass


def median_time_s(fn: Callable, *args, iters: int = 3) -> float:
    """Median wall time of ``fn(*args)`` over ``iters`` runs; the compile (and
    any lazy constant folding) is flushed by one untimed warmup call."""
    jax.block_until_ready(fn(*args))           # compile outside timed region
    times: List[float] = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return statistics.median(times)


def _gemm_operands(m: int, k: int, n: int, dtype) -> Tuple[jax.Array, jax.Array]:
    """Deterministic operands (seeded host RNG, so the tuner itself never
    perturbs jax PRNG state or depends on it)."""
    rng = np.random.RandomState(0)
    if jnp.issubdtype(jnp.dtype(dtype), jnp.integer):
        a = rng.randint(-128, 128, size=(m, k)).astype(np.int8)
        b = rng.randint(-128, 128, size=(k, n)).astype(np.int8)
    else:
        a = rng.standard_normal((m, k)).astype(np.float32)
        b = rng.standard_normal((k, n)).astype(np.float32)
    return (jnp.asarray(a).astype(dtype), jnp.asarray(b).astype(dtype))


def time_gemm_blocks(algo: str, a: jax.Array, b: jax.Array,
                     blocks: Tuple[int, int, int], *,
                     interpret: Optional[bool] = None,
                     iters: int = 3) -> float:
    bm, bn, bk = blocks
    counters["timed_candidates"] += 1
    fn = lambda a_, b_: kops.matmul(a_, b_, algo=algo, bm=bm, bn=bn, bk=bk,
                                    interpret=resolve_interpret(interpret))
    t = median_time_s(fn, a, b, iters=iters)
    m, k = a.shape[-2], a.shape[-1]
    n = b.shape[-1]
    _record_timed("gemm", t, flops=2.0 * m * k * n - m * n, algo=algo,
                  dtype=a.dtype)
    return t


def best_gemm_blocks(algo: str, m: int, k: int, n: int, dtype,
                     candidates: Sequence[Tuple[int, int, int]], *,
                     interpret: Optional[bool] = None,
                     iters: int = 3) -> Tuple[Tuple[int, int, int], float,
                                              List[dict]]:
    """Time every candidate on fresh deterministic operands; return
    (best_blocks, best_seconds, per-candidate trace). First-listed wins ties;
    a candidate that fails to build/run is recorded and skipped (never fatal —
    the search space is allowed to be optimistic about odd backends)."""
    a, b = _gemm_operands(m, k, n, dtype)
    trace: List[dict] = []
    best: Optional[Tuple[int, int, int]] = None
    best_t = float("inf")
    for blocks in candidates:
        try:
            t = time_gemm_blocks(algo, a, b, blocks, interpret=interpret,
                                 iters=iters)
        except Exception as e:                      # noqa: BLE001
            counters["failed_candidates"] += 1
            trace.append({"blocks": list(blocks), "error": str(e)[:200]})
            continue
        trace.append({"blocks": list(blocks), "us": round(t * 1e6, 1)})
        if t < best_t:                              # strict <: first wins ties
            best, best_t = blocks, t
    if best is None:
        raise RuntimeError(f"no GEMM candidate ran for {algo} "
                           f"{m}x{k}x{n} {jnp.dtype(dtype).name}")
    return best, best_t, trace


def _conv_operands(batch: int, h: int, w: int, cin: int, kh: int, kw: int,
                   cout: int, groups: int, dtype):
    rng = np.random.RandomState(0)
    if jnp.issubdtype(jnp.dtype(dtype), jnp.integer):
        x = rng.randint(-128, 128, size=(batch, h, w, cin)).astype(np.int8)
        k = rng.randint(-128, 128,
                        size=(kh, kw, cin // groups, cout)).astype(np.int8)
    else:
        x = rng.standard_normal((batch, h, w, cin)).astype(np.float32)
        k = rng.standard_normal((kh, kw, cin // groups, cout)).astype(np.float32)
    return (jnp.asarray(x).astype(dtype), jnp.asarray(k).astype(dtype))


def time_conv_blocks(algo: str, x: jax.Array, kernel: jax.Array,
                     blocks: Tuple[int, int, int], *, stride=1, pad=0,
                     groups: int = 1, interpret: Optional[bool] = None,
                     iters: int = 3) -> float:
    from repro.kernels import conv_gemm
    bm, bn, bk = blocks
    counters["timed_candidates"] += 1
    fn = lambda x_, k_: conv_gemm.conv_gemm_fused(
        x_, k_, stride=stride, pad=pad, groups=groups, algo=algo,
        bm=bm, bn=bn, bk=bk, interpret=resolve_interpret(interpret))
    t = median_time_s(fn, x, kernel, iters=iters)
    from repro.core.im2col import as_pair
    b, h, w, cin = x.shape
    kh, kw, _, cout = kernel.shape
    sh, sw = as_pair(stride)
    ph, pw = as_pair(pad)
    g = max(groups, 1)
    m = b * ((h + 2 * ph - kh) // sh + 1) * ((w + 2 * pw - kw) // sw + 1)
    kdim, n = kh * kw * (cin // g), cout // g
    _record_timed("conv", t, flops=(2.0 * m * kdim * n - m * n) * g,
                  algo=algo, dtype=x.dtype)
    return t


def best_conv_blocks(algo: str, batch: int, h: int, w: int, cin: int,
                     kh: int, kw: int, cout: int, dtype,
                     candidates: Sequence[Tuple[int, int, int]], *,
                     stride=1, pad=0, groups: int = 1,
                     interpret: Optional[bool] = None,
                     iters: int = 3) -> Tuple[Tuple[int, int, int], float,
                                              List[dict]]:
    """Time the fused implicit-im2col conv kernel over the candidate blocks
    at the REAL conv geometry (the gather address pattern is part of what a
    block choice changes, so conv schedules are measured on the conv kernel,
    not on an equivalent GEMM). Same contract as :func:`best_gemm_blocks`."""
    x, kernel = _conv_operands(batch, h, w, cin, kh, kw, cout, groups, dtype)
    trace: List[dict] = []
    best: Optional[Tuple[int, int, int]] = None
    best_t = float("inf")
    for blocks in candidates:
        try:
            t = time_conv_blocks(algo, x, kernel, blocks, stride=stride,
                                 pad=pad, groups=groups, interpret=interpret,
                                 iters=iters)
        except Exception as e:                      # noqa: BLE001
            counters["failed_candidates"] += 1
            trace.append({"blocks": list(blocks), "error": str(e)[:200]})
            continue
        trace.append({"blocks": list(blocks), "us": round(t * 1e6, 1)})
        if t < best_t:                              # strict <: first wins ties
            best, best_t = blocks, t
    if best is None:
        raise RuntimeError(f"no conv candidate ran for {algo} "
                           f"{batch}x{h}x{w}x{cin} k{kh}x{kw} "
                           f"{jnp.dtype(dtype).name}")
    return best, best_t, trace


def best_flash_blocks(bh: int, sq: int, sk: int, d: int, dtype,
                      candidates: Sequence[Tuple[int, int]], *,
                      interpret: Optional[bool] = None,
                      iters: int = 3) -> Tuple[Tuple[int, int], float,
                                               List[dict]]:
    """Same contract as :func:`best_gemm_blocks` for the flash-attention
    forward kernel (the serving prefill/train hot path)."""
    from repro.kernels.flash_attention import flash_attention
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.standard_normal((bh, sq, d)).astype(np.float32),
                    dtype=dtype)
    k = jnp.asarray(rng.standard_normal((bh, sk, d)).astype(np.float32),
                    dtype=dtype)
    v = jnp.asarray(rng.standard_normal((bh, sk, d)).astype(np.float32),
                    dtype=dtype)
    itp = resolve_interpret(interpret)
    trace: List[dict] = []
    best: Optional[Tuple[int, int]] = None
    best_t = float("inf")
    for bq, bk in candidates:
        try:
            counters["timed_candidates"] += 1
            fn = lambda q_, k_, v_: flash_attention(q_, k_, v_, 0, True, itp,
                                                    bq, bk)
            t = median_time_s(fn, q, k, v, iters=iters)
            _record_timed(
                "flash", t, algo="dot", dtype=dtype,
                flops=4.0 * bh * sq * sk * d * (0.5 if sq == sk else 1.0))
        except Exception as e:                      # noqa: BLE001
            counters["failed_candidates"] += 1
            trace.append({"blocks": [bq, bk], "error": str(e)[:200]})
            continue
        trace.append({"blocks": [bq, bk], "us": round(t * 1e6, 1)})
        if t < best_t:
            best, best_t = (bq, bk), t
    if best is None:
        raise RuntimeError(f"no flash candidate ran for bh={bh} sq={sq} "
                           f"sk={sk} d={d}")
    return best, best_t, trace
