"""Dependency-compat fallbacks (gated stand-ins for optional dev deps)."""
