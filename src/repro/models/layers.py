"""Primitive layers. Every matmul routes through the GEMM provider (core.gemm)
so the paper's FIP/FFIP arithmetic can be swapped in under any model."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.gemm import current_config, gemm

Array = jax.Array


def dense_init(key, d_in: int, d_out: int, dtype, *, bias: bool = False,
               scale: float = 1.0) -> dict:
    std = scale / (d_in ** 0.5)
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(x: Array, p: dict) -> Array:
    """x: (..., d_in) @ w: (d_in, d_out). Routed through the GEMM provider.

    When the provider is in quantized mode AND the param dict carries an
    offline-prepared ``"q"`` entry (core.quant.attach_quantized_weights), the
    matmul runs as an int8 (F)FIP GEMM with per-token activation quantization
    — the serving decode path of ISSUE 2. Bias stays float either way.
    """
    *lead, d_in = x.shape
    cfg = current_config()
    if cfg.quantized and "q" in p:
        algo = cfg.algo if cfg.algo != "baseline" else "ffip"
        out = quant.quantized_dense_apply(x.reshape(-1, d_in), p["q"],
                                          algo=algo).astype(x.dtype)
    else:
        out = gemm(x.reshape(-1, d_in), p["w"])
    out = out.reshape(*lead, -1)
    if "b" in p:
        out = out + p["b"]
    return out


def rmsnorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(x: Array, p: dict, eps: float = 1e-5) -> Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(x: Array, p: dict, eps: float = 1e-5) -> Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


def embed_init(key, vocab: int, d: int, dtype) -> dict:
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)}


def embed(tokens: Array, p: dict) -> Array:
    return p["table"][tokens]


def unembed(x: Array, p: dict) -> Array:
    """Logits via tied table: (..., d) @ (d, vocab)."""
    *lead, d = x.shape
    out = gemm(x.reshape(-1, d), p["table"].T)
    return out.reshape(*lead, -1)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def mlp_init(key, d: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "up": dense_init(k1, d, d_ff, dtype),
        "gate": dense_init(k2, d, d_ff, dtype),
        "down": dense_init(k3, d_ff, d, dtype),
    }


def mlp(x: Array, p: dict, act: str = "silu") -> Array:
    """Gated MLP (SwiGLU-style; universal across the assigned archs)."""
    return dense(act_fn(act)(dense(x, p["gate"])) * dense(x, p["up"]), p["down"])


# --- RoPE ------------------------------------------------------------------

def rope_freqs(hd: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: Array, positions: Array, theta) -> Array:
    """x: (B, S, H, hd); positions: (B, S) or (S,). theta may be a traced scalar
    (gemma3 passes per-layer theta through the layer scan)."""
    hd = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
