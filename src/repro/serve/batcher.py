"""Serving runtime: slot-based continuous batching over prefill/decode steps.

A fixed pool of B slots; requests occupy a slot, prefill writes their prompt
into the slot's cache region, then all active slots decode in lockstep at
their OWN positions: a ``(B,)`` position vector flows through
``Model.decode_step``, so each slot writes its KV rows, applies rope, and
masks attention at its true offset (mixed-length prompts decode correctly
side by side). Finished slots (EOS or max_tokens) are immediately refilled
from the queue — the standard continuous-batching scheme (vLLM-style,
simplified to fixed-shape slots so XLA shapes stay static).

With ``quantized=True`` the dense/attention projections of the serving
forward route through the paper's int8 FFIP path: weights are quantized
OFFLINE (per-output-channel, asymmetric) with beta folded into the integer
bias (Eq. 15) and colsums precomputed; at decode time the Eq. 20 zero-point
adjuster removes the zero-point cross terms. Activations quantize per token
row, so batched and sequential decoding stay bit-identical.
"""
from __future__ import annotations

import contextlib
import dataclasses
import queue
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.gemm import GemmConfig, use_gemm
from repro.models.model import Model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    eos_id: int = -1              # -1: never
    out_tokens: Optional[List[int]] = None


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0                  # tokens currently in this slot's cache rows
    remaining: int = 0


def _cache_batch_axes(model: Model, batch: int, max_len: int):
    """Locate the batch axis of every cache leaf STRUCTURALLY: the axis whose
    size changes when init_cache's batch argument changes. Unlike sniffing for
    a dim that equals the slot count, this can never confuse a stacked layer
    (or head/state) dim that happens to equal the number of slots."""
    c_a = jax.eval_shape(lambda: model.init_cache(batch, max_len))
    c_b = jax.eval_shape(lambda: model.init_cache(batch + 1, max_len))

    def axis(a, b):
        return next(i for i, (sa, sb) in enumerate(zip(a.shape, b.shape))
                    if sa != sb)

    return jax.tree.map(axis, c_a, c_b)


class BatchServer:
    """Single-host reference implementation (the multi-pod serve path lowers
    the same decode step through launch/dryrun.py)."""

    def __init__(self, model: Model, *, batch_slots: int, max_len: int,
                 greedy: bool = True, quantized: bool = False,
                 gemm_algo: str = "ffip"):
        if not greedy:
            raise NotImplementedError("only greedy decoding is implemented")
        self.model = model
        self.b = batch_slots
        self.max_len = max_len
        self.cache = model.init_cache(batch_slots, max_len)
        self.slots = [_Slot() for _ in range(batch_slots)]
        self.queue: "queue.Queue[Request]" = queue.Queue()
        self._completed: List[Request] = []
        self._batch_axes = _cache_batch_axes(model, batch_slots, max_len)
        self._gemm_cfg = (GemmConfig(algo=gemm_algo, quantized=True)
                          if quantized else None)
        self._qparams = None
        self._qparams_src = None
        self._decode = jax.jit(model.decode_step, donate_argnums=(2,))
        # per-slot prefill: batch-1 prefill into the slot's cache rows
        self._prefill_one = jax.jit(self._prefill_impl, donate_argnums=(2,))
        self.stats: Dict[str, Any] = {
            "prefill_s": 0.0, "decode_s": 0.0, "steps": 0,
            "prefill_tokens": 0, "decode_tokens": 0,
        }

    # -- quantized decode mode --------------------------------------------
    def _gemm_scope(self):
        """Trace/serving-time GEMM provider scope (FFIP int8 when quantized)."""
        if self._gemm_cfg is None:
            return contextlib.nullcontext()
        return use_gemm(self._gemm_cfg)

    def _params_for(self, params):
        """Float path: passthrough. Quantized: attach the offline int8 weight
        tree (per-channel scales/zero-points, Eq. 15 folded beta, colsums)
        once per distinct params object."""
        if self._gemm_cfg is None:
            return params
        if self._qparams_src is not params:
            self._qparams = quant.attach_quantized_weights(params)
            self._qparams_src = params
        return self._qparams

    # -- prefill -----------------------------------------------------------
    def _prefill_impl(self, params, tokens, cache, slot_idx):
        # run a batch-1 forward and scatter its cache rows into slot_idx
        one_cache = self.model.init_cache(1, self.max_len)
        new_one, logits = self.model.prefill(params, tokens, one_cache)

        def put(full, one, axis):
            idx = [slice(None)] * full.ndim
            idx[axis] = slot_idx
            return full.at[tuple(idx)].set(
                one.squeeze(axis=axis).astype(full.dtype))

        return jax.tree.map(put, cache, new_one, self._batch_axes), logits

    def submit(self, req: Request):
        if len(req.prompt) + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt ({len(req.prompt)}) + "
                f"max_new_tokens ({req.max_new_tokens}) exceeds "
                f"max_len ({self.max_len})")
        req.out_tokens = []
        self.queue.put(req)

    def _finish(self, req: Request):
        self._completed.append(req)

    def _admit(self, params):
        for i, slot in enumerate(self.slots):
            while slot.req is None:
                try:
                    req = self.queue.get_nowait()
                except queue.Empty:
                    return
                toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
                t0 = time.perf_counter()
                with self._gemm_scope():
                    self.cache, logits = self._prefill_one(
                        params, toks, self.cache, i)
                first = int(np.argmax(jax.device_get(logits[0])))
                self.stats["prefill_s"] += time.perf_counter() - t0
                self.stats["prefill_tokens"] += len(req.prompt)
                req.out_tokens.append(first)
                if req.max_new_tokens <= 1 or first == req.eos_id:
                    # finished at prefill (token budget of 1, or EOS on the
                    # first token): never occupies the slot — keep admitting.
                    self._finish(req)
                    continue
                slot.req = req
                slot.pos = len(req.prompt)   # prompt rows in cache; the first
                slot.remaining = req.max_new_tokens - 1   # generated token is
                # in flight and will be written at row `pos` by the next step

    # -- decode ------------------------------------------------------------
    def step(self, params) -> int:
        """One lockstep decode over all active slots; returns #active."""
        params = self._params_for(params)
        self._admit(params)
        active = [i for i, s in enumerate(self.slots) if s.req is not None]
        if not active:
            return 0
        last = np.zeros((self.b, 1), np.int32)
        pos = np.zeros((self.b,), np.int32)
        for i in active:
            last[i, 0] = self.slots[i].req.out_tokens[-1]
            pos[i] = self.slots[i].pos
        # per-slot position vector: slot i writes KV at row pos[i] and masks
        # rows >= pos[i] + 1; inactive slots decode garbage at row 0, which
        # the next prefill into that slot overwrites before it is ever read.
        t0 = time.perf_counter()
        with self._gemm_scope():
            self.cache, logits = self._decode(
                params, jnp.asarray(last), self.cache,
                jnp.asarray(pos, jnp.int32))
        logits_h = jax.device_get(logits)
        self.stats["decode_s"] += time.perf_counter() - t0
        self.stats["decode_tokens"] += len(active)
        self.stats["steps"] += 1
        for i in active:
            slot = self.slots[i]
            nxt = int(np.argmax(logits_h[i]))
            slot.req.out_tokens.append(nxt)
            slot.pos += 1
            slot.remaining -= 1
            if slot.remaining <= 0 or nxt == slot.req.eos_id:
                self._finish(slot.req)
                slot.req = None   # slot freed -> next _admit refills it
        return len(active)

    def run_until_drained(self, params, *, max_steps: int = 10_000,
                          ) -> List[Request]:
        """Step until the queue and all slots drain. Returns the finished
        requests in COMPLETION order — including requests admitted and
        completed within a single step (e.g. max_new_tokens=1). ``stats``
        describe this run only (reset here alongside the completion list)."""
        self._completed = []
        self.stats = {"prefill_s": 0.0, "decode_s": 0.0, "steps": 0,
                      "prefill_tokens": 0, "decode_tokens": 0}
        for _ in range(max_steps):
            if self.step(params) == 0 and self.queue.empty():
                break
        return self._completed
