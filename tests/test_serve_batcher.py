"""Continuous-batching correctness: per-slot positions, slot churn, EOS,
token budgets — and bit-identity of batched vs. one-at-a-time sequential
greedy generation, for both the float and the quantized int8 FFIP paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.model import build_model
from repro.serve.batcher import BatchServer, Request

MAX_LEN = 48


def _setup(arch, seed=0):
    cfg = configs.smoke_config(configs.get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, size=(l,)) for l in lens]


def _sequential(model, params, prompts, max_new, *, eos_id=-1,
                quantized=False):
    """One-at-a-time reference: a single 1-slot server, one request at a
    time (also exercises cache-row reuse across consecutive requests)."""
    srv = BatchServer(model, batch_slots=1, max_len=MAX_LEN,
                      quantized=quantized)
    outs = []
    for i, p in enumerate(prompts):
        mx = max_new[i] if isinstance(max_new, (list, tuple)) else max_new
        srv.submit(Request(rid=i, prompt=p, max_new_tokens=mx, eos_id=eos_id))
        done = srv.run_until_drained(params)
        assert len(done) == 1
        outs.append(list(done[0].out_tokens))
    return outs


def _batched(model, params, prompts, max_new, *, slots, eos_id=-1,
             quantized=False):
    srv = BatchServer(model, batch_slots=slots, max_len=MAX_LEN,
                      quantized=quantized)
    for i, p in enumerate(prompts):
        mx = max_new[i] if isinstance(max_new, (list, tuple)) else max_new
        srv.submit(Request(rid=i, prompt=p, max_new_tokens=mx, eos_id=eos_id))
    done = srv.run_until_drained(params)
    return done


@pytest.mark.parametrize("arch", ["minicpm-2b", "deepseek-v2-lite-16b"])
def test_mixed_lengths_bit_identical_to_sequential(arch):
    """4 mixed-length prompts decoding side by side in 4 slots produce the
    SAME tokens as one-at-a-time generation (per-slot position contract)."""
    cfg, model, params = _setup(arch)
    prompts = _prompts(cfg, [3, 7, 5, 9])
    want = _sequential(model, params, prompts, 5)
    done = _batched(model, params, prompts, 5, slots=4)
    assert len(done) == len(prompts)
    got = {r.rid: r.out_tokens for r in done}
    for i in range(len(prompts)):
        assert got[i] == want[i], (arch, i, got[i], want[i])


def test_slot_churn_more_requests_than_slots():
    """7 requests through 2 slots (mixed lengths AND mixed budgets): nothing
    dropped, every budget honored exactly, tokens == sequential."""
    cfg, model, params = _setup("minicpm-2b")
    lens = [3, 6, 4, 8, 5, 3, 7]
    budgets = [4, 1, 3, 2, 5, 1, 4]
    prompts = _prompts(cfg, lens, seed=1)
    want = _sequential(model, params, prompts, budgets)
    done = _batched(model, params, prompts, budgets, slots=2)
    assert sorted(r.rid for r in done) == list(range(7))
    for r in done:
        assert len(r.out_tokens) == budgets[r.rid], (r.rid, r.out_tokens)
        assert r.out_tokens == want[r.rid], r.rid


def test_max_new_tokens_one_exact_and_not_dropped():
    """max_new_tokens=1 requests finish at prefill with EXACTLY one token and
    are still returned by run_until_drained (the admitted-and-completed-
    within-one-step drop regression)."""
    cfg, model, params = _setup("minicpm-2b")
    prompts = _prompts(cfg, [4, 4, 4, 4, 4], seed=2)
    done = _batched(model, params, prompts, 1, slots=2)
    assert sorted(r.rid for r in done) == list(range(5))
    for r in done:
        assert len(r.out_tokens) == 1, (r.rid, r.out_tokens)


def test_eos_honored_including_first_prefill_token():
    """eos_id terminates the stream wherever it appears — including on the
    very first token produced by prefill — and frees the slot for the queue."""
    cfg, model, params = _setup("minicpm-2b")
    prompts = _prompts(cfg, [4, 6, 5], seed=3)
    free = _batched(model, params, prompts, 6, slots=2)
    ref = {r.rid: list(r.out_tokens) for r in free}
    # pick rid 0's first token as EOS: rid 0 must now stop right at prefill
    eos = ref[0][0]
    done = _batched(model, params, prompts, 6, slots=2, eos_id=eos)
    assert sorted(r.rid for r in done) == [0, 1, 2]
    got = {r.rid: r.out_tokens for r in done}
    assert got[0] == [eos]
    for rid in (1, 2):
        full = ref[rid]
        want = full[:full.index(eos) + 1] if eos in full else full
        assert got[rid] == want, (rid, got[rid], want)


def test_completion_order():
    """run_until_drained returns requests in completion order."""
    cfg, model, params = _setup("minicpm-2b")
    prompts = _prompts(cfg, [4, 4, 4], seed=4)
    done = _batched(model, params, prompts, [1, 6, 2], slots=2)
    assert [r.rid for r in done] == [0, 2, 1]


def test_quantized_int8_ffip_bit_identical_to_sequential():
    """The quantized decode path (per-token activation quant + offline
    per-channel weights) is batch-size invariant: batched == sequential."""
    cfg, model, params = _setup("minicpm-2b")
    prompts = _prompts(cfg, [3, 8, 5, 6], seed=5)
    want = _sequential(model, params, prompts, 4, quantized=True)
    done = _batched(model, params, prompts, 4, slots=3, quantized=True)
    got = {r.rid: r.out_tokens for r in done}
    for i in range(len(prompts)):
        assert got[i] == want[i], (i, got[i], want[i])
        assert all(0 <= t < cfg.vocab for t in got[i])


def test_submit_rejects_overlong_request():
    cfg, model, params = _setup("minicpm-2b")
    srv = BatchServer(model, batch_slots=1, max_len=8)
    with pytest.raises(ValueError):
        srv.submit(Request(rid=0, prompt=np.zeros(6, np.int64),
                           max_new_tokens=4))      # 6 + 4 - 1 = 9 rows > 8


def test_submit_capacity_boundary_last_token_needs_no_row():
    """Off-by-one regression: the FINAL sampled token is emitted but never
    written back (no decode step follows it), so a request needs exactly
    prompt + max_new - 1 cache rows. Equality with max_len must be ADMITTED
    and complete with the full budget; one more must be rejected."""
    cfg, model, params = _setup("minicpm-2b")
    srv = BatchServer(model, batch_slots=1, max_len=16)
    p = _prompts(cfg, [12], seed=6)[0]
    srv.submit(Request(rid=0, prompt=p, max_new_tokens=5))   # 12+5-1 == 16
    done = srv.run_until_drained(params)
    assert len(done) == 1 and len(done[0].out_tokens) == 5
    with pytest.raises(ValueError):
        srv.submit(Request(rid=1, prompt=p, max_new_tokens=6))
    # a prompt filling the WHOLE cache still admits a single-token request
    full = _prompts(cfg, [16], seed=7)[0]
    srv.submit(Request(rid=2, prompt=full, max_new_tokens=1))
    done = srv.run_until_drained(params)
    assert len(done) == 1 and len(done[0].out_tokens) == 1


# -- ISSUE 8 satellites: typed stalls, fail-fast admission, idempotent rids


def test_run_until_drained_stall_raises_typed_error():
    """Exhausting max_steps with live requests raises ServeStallError
    listing every stuck rid and where it was wedged — never a silently
    short completion list."""
    from repro.serve.lifecycle import ServeStallError

    cfg, model, params = _setup("minicpm-2b")
    srv = BatchServer(model, batch_slots=1, max_len=MAX_LEN)
    prompts = _prompts(cfg, [4, 6])
    srv.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=12))
    srv.submit(Request(rid=1, prompt=prompts[1], max_new_tokens=12))
    with pytest.raises(ServeStallError) as ei:
        srv.run_until_drained(params, max_steps=2)
    assert set(ei.value.stuck) == {0, 1}
    assert "queued" in ei.value.stuck[1]          # rid 1 never got the slot
    assert isinstance(ei.value, RuntimeError)     # backcompat contract
    # the server is still usable: a fresh drain finishes both
    done = srv.run_until_drained(params)
    assert sorted(r.rid for r in done) == [0, 1]


def test_submit_impossible_is_typed_and_fails_fast():
    """Never-admittable requests fail AT SUBMIT with the typed error, for
    both capacity models: contiguous (rows > max_len) and paged (worst-case
    pages > whole pool) — not after sitting in a queue forever."""
    from repro.serve.lifecycle import AdmissionImpossibleError

    cfg, model, params = _setup("minicpm-2b")
    srv = BatchServer(model, batch_slots=1, max_len=8)
    with pytest.raises(AdmissionImpossibleError):
        srv.submit(Request(rid=0, prompt=np.zeros(6, np.int64),
                           max_new_tokens=4))
    pg = BatchServer(model, batch_slots=1, max_len=MAX_LEN, paged=True,
                     page_size=4, num_pages=3)    # pool: 12 rows max
    with pytest.raises(AdmissionImpossibleError):
        pg.submit(Request(rid=0, prompt=np.zeros(10, np.int64),
                          max_new_tokens=8))      # 17 rows -> 5 pages > 3
    assert not pg.has_queued()
    assert pg._reserved == 0


def test_duplicate_rid_after_done_returns_cached_completion():
    cfg, model, params = _setup("minicpm-2b")
    srv = BatchServer(model, batch_slots=2, max_len=MAX_LEN)
    p = _prompts(cfg, [5])[0]
    srv.submit(Request(rid=7, prompt=p, max_new_tokens=4))
    first = srv.run_until_drained(params)
    want = list(first[0].out_tokens)
    # resubmit the SAME rid+payload: cached tokens, zero device work
    srv.submit(Request(rid=7, prompt=p, max_new_tokens=4))
    again = srv.run_until_drained(params)
    assert len(again) == 1 and list(again[0].out_tokens) == want
    assert srv.stats["decode_dispatches"] == 0
    assert srv.stats["prefill_dispatches"] == 0


def test_duplicate_rid_while_inflight_decodes_once():
    cfg, model, params = _setup("minicpm-2b")
    srv = BatchServer(model, batch_slots=2, max_len=MAX_LEN)
    p = _prompts(cfg, [6], seed=3)[0]
    srv.submit(Request(rid=9, prompt=p, max_new_tokens=5))
    srv.submit(Request(rid=9, prompt=p, max_new_tokens=5))   # dup, queued
    done = srv.run_until_drained(params)
    # both submissions complete with identical tokens from ONE decode
    assert len(done) == 2
    assert done[0].out_tokens == done[1].out_tokens
    assert srv.stats["prefill_tokens"] == len(p)             # prefilled once


def test_duplicate_rid_with_different_payload_rejected():
    from repro.serve.lifecycle import AdmissionImpossibleError

    cfg, model, params = _setup("minicpm-2b")
    srv = BatchServer(model, batch_slots=2, max_len=MAX_LEN)
    p, q = _prompts(cfg, [5, 6], seed=4)
    srv.submit(Request(rid=1, prompt=p, max_new_tokens=4))
    with pytest.raises(AdmissionImpossibleError):
        srv.submit(Request(rid=1, prompt=q, max_new_tokens=4))   # inflight
    srv.run_until_drained(params)
    with pytest.raises(AdmissionImpossibleError):
        srv.submit(Request(rid=1, prompt=p, max_new_tokens=9))   # vs cached
