"""Paper-table reproductions (Fig. 2, Fig. 9, Tables 1-3) from the analytical
accelerator model driven by real workload GEMM shapes.

Every row prints: ours (modeled) vs paper (measured) with the delta, so the
reproduction quality is visible in bench_output.txt.
"""
from __future__ import annotations

import json
import pathlib
from typing import List

from repro.core import analytical as an
from repro.core import workloads

ARRIA10_GX1150_DSPS = 1518
ARRIA10_SX660_DSPS = 1687

BENCH_CONV = pathlib.Path(__file__).resolve().parent / "BENCH_conv.json"


def fig2_registers() -> List[str]:
    rows = ["fig2.w,fip_regs,fip_extra_regs,ffip_regs"]
    for r in an.fig2_table(x=64, d=1):
        rows.append(f"fig2.w={r['w']},{r['fip']},{r['fip_extra']},{r['ffip']}")
    return rows


def fig9_sweep() -> List[str]:
    """Baseline/FIP/FFIP MXUs from 32..88, 8-bit: DSPs, fmax, ResNet-50 GOPS.
    Derived checks: baseline no longer fits past 56x56 on the SX660 (paper);
    (F)FIP fits to 80x80 — the paper's '2x effective PEs' headline."""
    rows = ["fig9.algo_size,dsps,fits_sx660,fmax_mhz,resnet50_gops"]
    gemms = workloads.resnet50(batch=2)
    for algo in ("baseline", "fip", "ffip"):
        for size in range(32, 96, 8):
            cfg = an.MxuConfig(x=size, y=size, algo=algo, w_bits=8)
            dsps = an.mxu_dsps(cfg)
            fits = dsps <= ARRIA10_SX660_DSPS
            perf = an.model_performance(gemms, cfg) if fits else None
            rows.append(
                f"fig9.{algo}_{size}x{size},{dsps},{int(fits)},"
                f"{an.mxu_fmax_mhz(cfg):.0f},"
                f"{perf['gops']:.0f}" if fits else
                f"fig9.{algo}_{size}x{size},{dsps},0,-,-")
    # headline derived facts
    base_56 = an.mxu_dsps(an.MxuConfig(56, 56, "baseline", 8))
    base_64 = an.mxu_dsps(an.MxuConfig(64, 64, "baseline", 8))
    ffip_80 = an.mxu_dsps(an.MxuConfig(80, 80, "ffip", 8))
    ffip_88 = an.mxu_dsps(an.MxuConfig(88, 88, "ffip", 8))
    rows.append(f"fig9.derived.baseline_56_fits,{int(base_56 <= ARRIA10_SX660_DSPS)},expect,1")
    rows.append(f"fig9.derived.baseline_64_fits,{int(base_64 <= ARRIA10_SX660_DSPS)},expect,0")
    rows.append(f"fig9.derived.ffip_80_fits,{int(ffip_80 <= ARRIA10_SX660_DSPS)},expect,1")
    rows.append(f"fig9.derived.ffip_88_fits,{int(ffip_88 <= ARRIA10_SX660_DSPS)},expect,0")
    rows.append("fig9.derived.effective_pe_ratio,"
                f"{80 * 80 / (56 * 56):.2f},expect,>2")
    return rows


_T1 = [  # (model, batch, paper_gops) 8-bit FFIP 64x64 @388MHz, Table 1
    ("alexnet", 32, 2277), ("resnet50", 2, 2529),
    ("resnet101", 2, 2752), ("resnet152", 2, 2838),
]
_T2 = [  # 16-bit FFIP 64x64 @346MHz, Table 2
    ("alexnet", 32, 1974), ("resnet50", 2, 2258),
    ("resnet101", 2, 2458), ("resnet152", 2, 2534),
]


def _table(rows_spec, w_bits: int, tag: str) -> List[str]:
    rows = [f"{tag}.model,ours_gops,paper_gops,delta_pct,"
            f"ours_gops_per_mult,ours_ops_per_mult_cycle,paper_ops_per_mult_cycle_max4"]
    cfg = an.MxuConfig(x=64, y=64, algo="ffip", w_bits=w_bits)
    for model, batch, paper in rows_spec:
        perf = an.model_performance(workloads.MODELS[model](batch), cfg)
        delta = 100 * (perf["gops"] - paper) / paper
        rows.append(
            f"{tag}.{model},{perf['gops']:.0f},{paper},{delta:+.1f},"
            f"{perf['gops_per_multiplier']:.3f},"
            f"{perf['ops_per_mult_per_cycle']:.3f},4.0")
    return rows


def table1() -> List[str]:
    return _table(_T1, 8, "table1")


def table2() -> List[str]:
    return _table(_T2, 16, "table2")


def table3() -> List[str]:
    """Cross-FPGA comparison: the paper's own rows are reused from T1/T2; the
    reproduction contribution here is the prior-work comparison metrics, which
    are the paper's reported numbers (we list ours vs best-in-class prior)."""
    prior_best = {  # best prior ops/mult/cycle per column of Table 3
        "alexnet_16b": 1.657, "resnet50_8b": 1.289, "resnet50_16b": 0.823,
        "resnet101_16b": 1.922, "resnet152_16b": 0.957,
    }
    ours = {
        "alexnet_16b": ("alexnet", 32, 16), "resnet50_8b": ("resnet50", 2, 8),
        "resnet50_16b": ("resnet50", 2, 16),
        "resnet101_16b": ("resnet101", 2, 16),
        "resnet152_16b": ("resnet152", 2, 16),
    }
    rows = ["table3.column,ours_ops_per_mult_cycle,best_prior,speedup"]
    for col, (model, batch, bits) in ours.items():
        cfg = an.MxuConfig(x=64, y=64, algo="ffip", w_bits=bits)
        perf = an.model_performance(workloads.MODELS[model](batch), cfg)
        v = perf["ops_per_mult_per_cycle"]
        rows.append(f"table3.{col},{v:.3f},{prior_best[col]},{v / prior_best[col]:.2f}x")
    return rows


def fig9_measured_crosscheck() -> List[str]:
    """Optional Fig. 9 cross-check: when ``benchmarks/BENCH_conv.json``
    exists (conv_bench.py), re-run the analytical cycle model on the SAME
    (possibly spatially scaled) ResNet-50 GEMM shapes the bench measured and
    put modeled GOPS next to measured fused-kernel GOPS per layer.

    On a CPU container the measured column times interpret-mode emulation, so
    the absolute ratio is meaningless there — the row exists so a TPU run of
    conv_bench.py drops straight into this table (the JSON records the
    device_kind). The modeled column is the paper's Fig. 9 machinery applied
    to the benched shapes, so shape-dependent EFFECTS (utilization dips on
    small-M layers etc.) are comparable even on CPU.
    """
    rows = ["fig9x.layer,gemm_mkn,modeled_gops_ffip64,measured_fused_gops,"
            "measured_device,modeled_over_measured"]
    if not BENCH_CONV.exists():
        rows.append("fig9x.none,-,-,-,-,run benchmarks/conv_bench.py first")
        return rows
    try:
        bench = json.loads(BENCH_CONV.read_text())
        layers = bench["models"]["resnet50"]["layers"]
    except Exception:
        rows.append("fig9x.none,-,-,-,-,BENCH_conv.json unreadable or has no "
                    "resnet50 section")
        return rows
    cfg = an.MxuConfig(x=64, y=64, algo="ffip", w_bits=8)
    device = bench.get("device_kind", "?")
    for layer in layers:
        g = layer["gemm"]
        shapes = [an.GemmShape(m=g["m"], k=g["k"], n=g["n"])
                  for _ in range(g.get("per_group", 1))]
        modeled = an.model_performance(shapes, cfg)["gops"]
        r = layer["results"].get("ffip.int8")
        if r is None:
            continue
        ops = sum(s.ops() for s in shapes)
        measured = ops / (r["fused_us"] * 1e-6) * 1e-9
        rows.append(
            f"fig9x.{layer['name']},{g['m']}x{g['k']}x{g['n']}"
            f"(x{g.get('per_group', 1)}),{modeled:.1f},{measured:.4f},"
            f"{device},{modeled / max(measured, 1e-12):.0f}")
    return rows


def fip_vs_ffip_vs_baseline() -> List[str]:
    """§6.1 core claims at 64x64, 8-bit."""
    rows = ["sec6p1.metric,baseline,fip,ffip"]
    cfgs = {a: an.MxuConfig(64, 64, a, 8) for a in ("baseline", "fip", "ffip")}
    gemms = workloads.resnet50(batch=2)
    perfs = {a: an.model_performance(gemms, c) for a, c in cfgs.items()}
    rows.append("sec6p1.dsps," + ",".join(str(perfs[a]["dsps"]) for a in perfs))
    rows.append("sec6p1.fmax_mhz," + ",".join(f"{perfs[a]['fmax_mhz']:.0f}" for a in perfs))
    rows.append("sec6p1.gops," + ",".join(f"{perfs[a]['gops']:.0f}" for a in perfs))
    rows.append("sec6p1.ops_per_mult_cycle," +
                ",".join(f"{perfs[a]['ops_per_mult_per_cycle']:.2f}" for a in perfs))
    f_fip = perfs["fip"]["fmax_mhz"] / perfs["baseline"]["fmax_mhz"]
    f_ffip = perfs["ffip"]["fmax_mhz"] / perfs["fip"]["fmax_mhz"]
    rows.append(f"sec6p1.derived.fip_freq_penalty,{f_fip:.2f},expect,~0.70")
    rows.append(f"sec6p1.derived.ffip_freq_recovery,{f_ffip:.2f},expect,>1.30")
    rows.append(f"sec6p1.derived.dsp_reduction,"
                f"{perfs['baseline']['dsps'] / perfs['ffip']['dsps']:.2f},expect,~1.94")
    return rows
