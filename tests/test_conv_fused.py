"""Fused implicit-im2col conv kernel tests (ISSUE 5 acceptance criteria).

The load-bearing claims:
  * fused conv == lax.conv (allclose) across stride x pad x groups x kernel
    x dtype — the property sweep;
  * fused conv is BIT-IDENTICAL to the materializing reference
    (conv2d_via_gemm through the same Pallas GEMM blocks) for baseline / fip
    / ffip x {float32, int8};
  * the (M, K) im2col matrix never exists outside VMEM tiles (structural
    jaxpr check);
  * the int8 quantized path is bit-identical fused-vs-reference and across
    block choices / algos (mirrors test_tune.py's GEMM identity tests).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import im2col
from repro.kernels import conv_gemm as cg
from repro.kernels import ops as kops


def _lax_conv(x, kernel, stride, pad, groups):
    sh, sw = im2col.as_pair(stride)
    ph, pw = im2col.as_pair(pad)
    return jax.lax.conv_general_dilated(
        x, kernel, (sh, sw), [(ph, ph), (pw, pw)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)


def _operands(h, w, cin, cout, kh, kw, groups, dtype, seed=0):
    rng = np.random.RandomState(seed)
    if jnp.issubdtype(jnp.dtype(dtype), jnp.integer):
        x = jnp.asarray(rng.randint(-16, 16, size=(2, h, w, cin)), dtype)
        k = jnp.asarray(rng.randint(-16, 16,
                                    size=(kh, kw, cin // groups, cout)), dtype)
    else:
        x = jnp.asarray(rng.standard_normal((2, h, w, cin)), dtype)
        k = jnp.asarray(rng.standard_normal((kh, kw, cin // groups, cout)),
                        dtype)
    return x, k


# the property sweep: stride x pad x groups x kh/kw (incl. non-square and
# odd-K geometries) — each case runs all three algos in both dtypes
SWEEP = [
    # h, w, cin, cout, kh, kw, stride, pad, groups
    (8, 8, 4, 8, 3, 3, 1, 0, 1),
    (8, 8, 4, 8, 3, 3, 2, 1, 1),
    (7, 7, 2, 4, 1, 1, 1, 0, 1),          # 1x1 (the ResNet reduce convs)
    (9, 9, 3, 4, 5, 5, 2, 2, 1),          # K = 75, odd -> evenized pairs
    (9, 7, 6, 9, 3, 2, (2, 1), (0, 1), 3),  # asymmetric everything + groups
    (12, 12, 8, 8, 3, 3, 1, 1, 2),        # grouped (AlexNet conv2-style)
    (11, 11, 3, 8, 4, 4, (3, 2), (1, 0), 1),
]


@pytest.mark.parametrize("case", SWEEP,
                         ids=[f"h{c[0]}w{c[1]}c{c[2]}k{c[4]}x{c[5]}"
                              f"s{c[6]}p{c[7]}g{c[8]}" for c in SWEEP])
@pytest.mark.parametrize("algo", ["baseline", "fip", "ffip"])
def test_fused_conv_sweep_float(case, algo):
    h, w, cin, cout, kh, kw, stride, pad, groups = case
    x, kernel = _operands(h, w, cin, cout, kh, kw, groups, jnp.float32)
    got = cg.conv_gemm_fused(x, kernel, stride=stride, pad=pad,
                             groups=groups, algo=algo)
    want = _lax_conv(x, kernel, stride, pad, groups)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("case", SWEEP[:5],
                         ids=[f"h{c[0]}w{c[1]}c{c[2]}k{c[4]}x{c[5]}"
                              f"s{c[6]}p{c[7]}g{c[8]}" for c in SWEEP[:5]])
@pytest.mark.parametrize("algo", ["baseline", "fip", "ffip"])
def test_fused_conv_sweep_int8_exact(case, algo):
    """Integer fused conv == integer materialized conv, bit-exact."""
    h, w, cin, cout, kh, kw, stride, pad, groups = case
    x, kernel = _operands(h, w, cin, cout, kh, kw, groups, jnp.int8)
    got = cg.conv_gemm_fused(x, kernel, stride=stride, pad=pad,
                             groups=groups, algo=algo)
    want = _lax_conv(x.astype(jnp.int32), kernel.astype(jnp.int32),
                     stride, pad, groups)
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("algo", ["baseline", "fip", "ffip"])
def test_fused_bit_identical_to_materialized_reference(algo):
    """Same blocks -> the fused kernel and conv2d_via_gemm over the SAME
    Pallas GEMM produce bit-identical float32 bits (same accumulation
    order; the gather location is the only difference)."""
    x, kernel = _operands(9, 9, 4, 8, 3, 3, 1, jnp.float32)
    bm, bn, bk = 16, 8, 8
    got = cg.conv_gemm_fused(x, kernel, stride=2, pad=1, algo=algo,
                             bm=bm, bn=bn, bk=bk)
    ref = im2col.conv2d_via_gemm(
        x, kernel, stride=2, pad=1,
        gemm_fn=lambda a, b: kops.matmul(a, b, algo=algo,
                                         bm=bm, bn=bn, bk=bk))
    assert (np.asarray(got) == np.asarray(ref)).all()


def _max_intermediate_size(fn, *args) -> int:
    """Largest intermediate array (element count) anywhere in fn's jaxpr,
    including sub-jaxprs (pallas_call bodies, scans...)."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    biggest = 0

    def visit(jx):
        nonlocal biggest
        for eqn in jx.eqns:
            for var in eqn.outvars:
                size = 1
                for s in getattr(var.aval, "shape", ()):
                    size *= s
                biggest = max(biggest, size)
            for sub in eqn.params.values():
                inner = getattr(sub, "jaxpr", None)
                if inner is not None:
                    visit(getattr(inner, "jaxpr", inner))

    visit(jaxpr.jaxpr)
    return biggest


def test_fused_never_materializes_im2col():
    """Structural acceptance check: the fused path's largest intermediate is
    far below the (B, M, K) im2col size; the materializing reference trips
    the same detector (so the detector itself is proven live)."""
    x, kernel = _operands(16, 16, 4, 8, 3, 3, 1, jnp.float32)
    m, k = 14 * 14, 3 * 3 * 4
    im2col_elems = x.shape[0] * m * k
    blocks = dict(bm=16, bn=8, bk=12)
    fused_max = _max_intermediate_size(
        lambda x_, k_: cg.conv_gemm_fused(x_, k_, algo="ffip", **blocks),
        x, kernel)
    mat_max = _max_intermediate_size(
        lambda x_, k_: im2col.conv2d_via_gemm(
            x_, k_, gemm_fn=lambda a, b: kops.matmul(a, b, algo="ffip",
                                                     **blocks)),
        x, kernel)
    assert mat_max >= im2col_elems          # detector sees the HBM gather
    assert fused_max < im2col_elems // 2    # fused path never builds it


# ---------------------------------------------------------------------------
# Quantized path
# ---------------------------------------------------------------------------

QCASES = [
    (8, 8, 4, 8, 3, 3, 1, 1, 1),
    (9, 9, 3, 4, 5, 5, 2, 2, 1),           # odd K
    (12, 12, 8, 16, 3, 3, 1, 1, 2),        # grouped
    (9, 7, 6, 9, 3, 2, (2, 1), (0, 1), 3),
]


@pytest.mark.parametrize("case", QCASES,
                         ids=[f"h{c[0]}c{c[2]}k{c[4]}x{c[5]}g{c[8]}"
                              for c in QCASES])
@pytest.mark.parametrize("algo", ["baseline", "fip", "ffip"])
def test_quantized_fused_bit_identical_to_reference(case, algo):
    h, w, cin, cout, kh, kw, stride, pad, groups = case
    x, kernel = _operands(h, w, cin, cout, kh, kw, groups, jnp.float32)
    kernel = kernel * 0.2
    q = cg.prepare_quantized_conv(kernel, groups=groups)
    fused = cg.quantized_conv_apply(x, q, stride=stride, pad=pad, algo=algo)
    ref = cg.quantized_conv_reference(x, q, stride=stride, pad=pad, algo=algo)
    assert (np.asarray(fused) == np.asarray(ref)).all()
    # and the quantization is actually a good approximation of the float conv
    want = _lax_conv(x, kernel, stride, pad, groups)
    rel = float(jnp.max(jnp.abs(fused - want))
                / (jnp.max(jnp.abs(want)) + 1e-9))
    assert rel < 0.1


def test_quantized_bit_identity_across_blocks_and_algos():
    """The int8 fused conv result is one exact integer answer: every legal
    block choice and every algo produce identical bits (int32 accumulation
    is associative) — the conv mirror of test_tune.py's GEMM identity."""
    x, kernel = _operands(10, 10, 6, 8, 3, 3, 1, jnp.float32)
    kernel = kernel * 0.3
    q = cg.prepare_quantized_conv(kernel)
    base = cg.quantized_conv_apply(x, q, stride=1, pad=1, algo="ffip")
    for blocks in [(8, 8, 2), (16, 8, 6), (32, 16, 18), (128, 128, 64)]:
        bm, bn, bk = blocks
        got = cg.quantized_conv_apply(x, q, stride=1, pad=1, algo="ffip",
                                      bm=bm, bn=bn, bk=bk)
        assert (np.asarray(got) == np.asarray(base)).all(), blocks
    for algo in ("baseline", "fip"):
        got = cg.quantized_conv_apply(x, q, stride=1, pad=1, algo=algo)
        assert (np.asarray(got) == np.asarray(base)).all(), algo


def test_conv_rowsums_matches_materialized():
    """The windowed row-sum (Eq. 20 adjuster input) equals rowsum of the
    materialized A_q, per group — without ever building A_q."""
    rng = np.random.RandomState(0)
    xq = jnp.asarray(rng.randint(-128, 128, size=(2, 9, 9, 6)), jnp.int8)
    kh, kw, groups, stride = 3, 3, 2, (2, 1)
    rs = cg.conv_rowsums(xq, kh=kh, kw=kw, stride=stride, groups=groups)
    h, w, cin = 9, 9, 6
    flat = xq.reshape(2, -1).astype(jnp.int32)
    for g in range(groups):
        idx = im2col.conv_gemm_indices(h, w, cin, kh, kw, stride,
                                       groups=groups, group=g)
        want = flat[:, jnp.asarray(idx)].sum(-1)        # (B, M)
        got = rs[..., g].reshape(2, -1)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_weight_derivations_memoized():
    """The offline transforms (group stack, K evenize, Eq. 9 y-deltas) are
    derived ONCE per weight array — a second eager forward reuses the exact
    cached objects from the SHARED per-weight memo (kernels/compat.py's
    DerivedCache, the §4.4 deployment story — one cache for ffip_gemm and
    the fused conv path alike)."""
    from repro.kernels import compat
    x, kernel = _operands(8, 8, 4, 8, 3, 3, 1, jnp.float32)
    compat.derived.clear()
    cg.conv_gemm_fused(x, kernel, algo="ffip")
    first = {k: v[1] for k, v in compat.derived._cache.items()}
    assert len(first) >= 2                  # stack + y_even at minimum
    computed = compat.derived.stats["computed"]
    cg.conv_gemm_fused(x, kernel, algo="ffip")
    second = {k: v[1] for k, v in compat.derived._cache.items()}
    assert second.keys() == first.keys()
    assert all(second[k] is first[k] for k in first)
    assert compat.derived.stats["computed"] == computed  # pure hits


def test_fused_conv_rejects_bad_shapes():
    x, kernel = _operands(8, 8, 4, 8, 3, 3, 1, jnp.float32)
    with pytest.raises(ValueError):
        cg.conv_gemm_fused(x, kernel, groups=3)          # cout % groups
    with pytest.raises(ValueError):
        cg.conv_gemm_fused(x, kernel, algo="fip", bm=8, bn=8, bk=3)  # odd bk
