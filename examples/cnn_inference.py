"""CNN inference through the fused implicit-im2col (F)FIP conv kernels.

The paper's headline workloads are CNNs executed on an array that maps conv
to GEMM *on the fly* with the §5.1 address counters — no im2col matrix ever
exists in memory. This example runs a small AlexNet three ways and checks
they agree:

  1. float reference (XLA conv — the MXU path),
  2. fused implicit-im2col FFIP Pallas kernels (A only in VMEM tiles),
  3. the int8 quantized path (offline weights: Eq. 15 folded beta + colsums
     on the flattened KH*KW*Cin axis; Eq. 20 zero-point adjuster with
     windowed row-sums).

    PYTHONPATH=src python examples/cnn_inference.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gemm import GemmConfig, use_gemm
from repro.vision import models as vm


def main():
    key = jax.random.PRNGKey(0)
    model = vm.build("alexnet", num_classes=10, image_size=67, width_div=8)
    params = vm.init_params(model, key)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 67, 67, 3))

    # 1) float reference (default config: baseline algo, XLA conv)
    ref = vm.apply(model, params, x)
    print("float logits:", np.round(np.asarray(ref[0, :5]), 3))

    # 2) fused implicit-im2col FFIP — same weights, same topology, the conv
    #    -> GEMM mapping now happens inside the kernel per (bm, bk) block
    with use_gemm(GemmConfig(algo="ffip", impl="pallas")):
        fused = vm.apply(model, params, x)
    err = float(jnp.max(jnp.abs(fused - ref)))
    print(f"fused FFIP max |delta| vs float: {err:.2e}")
    assert err < 1e-2

    # 3) int8 quantized: BN-fold/weight prep happens offline (attach_quantized),
    #    then the same forward runs on raw int8 operands
    qparams = vm.attach_quantized(model, params)
    with use_gemm(GemmConfig(algo="ffip", impl="pallas", quantized=True)):
        q_logits = vm.apply(model, qparams, x)
    rel = float(jnp.linalg.norm(q_logits - ref) / jnp.linalg.norm(ref))
    agree = float((jnp.argmax(q_logits, -1) == jnp.argmax(ref, -1)).mean())
    print(f"int8 FFIP rel err: {rel:.4f}  top-1 agreement: {agree:.0%}")
    assert rel < 0.35

    print("OK: conv -> GEMM mapped on the fly; im2col never materialized.")


if __name__ == "__main__":
    main()
