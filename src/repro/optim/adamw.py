"""AdamW + schedules (incl. MiniCPM's WSD) + clipping + optional int8
error-feedback gradient compression for cross-pod all-reduce.

Pure-pytree implementation (no optax dependency): opt_state mirrors params and
shards identically (ZeRO-style: the specs applied to params apply to m/v)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    step: jax.Array
    m: PyTree
    v: PyTree


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "cosine"        # cosine | wsd | const
    warmup_steps: int = 100
    total_steps: int = 10_000
    decay_frac: float = 0.1         # WSD: final fraction of steps in decay
    min_lr_frac: float = 0.1


def schedule_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Warmup -> (cosine | WSD-stable+decay | const)."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    if cfg.schedule == "cosine":
        mult = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    elif cfg.schedule == "wsd":
        # Warmup-Stable-Decay (MiniCPM): stable at peak, then sharp decay tail
        decay_start = 1.0 - cfg.decay_frac
        d = jnp.clip((t - decay_start) / cfg.decay_frac, 0.0, 1.0)
        mult = jnp.where(t < decay_start, 1.0,
                         cfg.min_lr_frac ** d)       # exponential-style tail
    else:
        mult = jnp.ones_like(t)
    return cfg.lr * warm * mult


def init(params: PyTree) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree_util.tree_leaves(tree)))


def update(cfg: AdamWConfig, grads: PyTree, state: AdamWState, params: PyTree,
           ) -> Tuple[PyTree, AdamWState]:
    step = state.step + 1
    lr = schedule_lr(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) if cfg.grad_clip else 1.0

    b1t = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2t = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_ = cfg.b1 * m + (1 - cfg.b1) * g
        v_ = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m_ / b1t
        vh = v_ / b2t
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_, v_

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v)


# ---------------------------------------------------------------------------
# int8 error-feedback gradient compression (cross-pod all-reduce trick)
# ---------------------------------------------------------------------------

def compress_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 quantization of a gradient."""
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress_tree(grads: PyTree, error: PyTree) -> Tuple[PyTree, PyTree, PyTree]:
    """Error-feedback compression: quantize (g + e); new error = input - deq.

    Returns (quantized, scales, new_error). Used on the cross-pod reduction
    path; the residual error re-enters the next step so the compression is
    unbiased over time (standard EF-SGD argument)."""
    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, s = compress_int8(x)
        return q, s, x - decompress_int8(q, s)
    out = jax.tree.map(one, grads, error)
    q = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    s = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    e = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return q, s, e
