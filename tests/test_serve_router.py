"""Fault-tolerant multi-replica serving (ISSUE 8).

The deterministic fault matrix is the heart: every fault kind
(raise / hang / exhaust / poison) x {float, int8-FFIP} x {contiguous,
paged} drives a seeded FaultPlan against a 2-replica fleet and must end
with EVERY request DONE, token-identical to a no-fault single-server
oracle — zero stuck requests, zero duplicate emissions, bounded retries,
and (paged) the admission reservation ledger drained to 0. On top of
that: deadlines and per-phase timeouts, bounded-queue backpressure,
fail-fast admission, router-level idempotent rids, shed-to-quantized
degradation, and the circuit breaker's quarantine -> probe -> re-admission
cycle.

attention_impl is forced to "naive" (as in test_serve_paged) so paged and
contiguous runs share literally the same einsums — bit-identity, not
allclose.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.models.model import build_model
from repro.serve import lifecycle as lc
from repro.serve.batcher import BatchServer, Request
from repro.serve.faults import FakeClock, FaultPlan, FaultSpec, InjectedFault
from repro.serve.lifecycle import Lifecycle
from repro.serve.router import (HEALTHY, QUARANTINED, ReplicaRouter,
                                RouterConfig)
from repro.watchdog import WatchdogConfig

MAX_LEN = 48
LENS = [3, 7, 5, 9, 4, 6]
MAX_NEW = 5

_STATE = {}


def _setup():
    if not _STATE:
        cfg = configs.smoke_config(configs.get_config("minicpm-2b"))
        cfg = dataclasses.replace(cfg, attention_impl="naive")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        _STATE["m"] = (cfg, model, params)
        _STATE["oracle"] = {}
    return _STATE["m"]


def _prompts(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, size=(n,)) for n in LENS]


def _oracle(quantized):
    """No-fault single-server reference tokens, computed once per tier."""
    cfg, model, params = _setup()
    if quantized not in _STATE["oracle"]:
        srv = BatchServer(model, batch_slots=2, max_len=MAX_LEN,
                          quantized=quantized)
        for i, p in enumerate(_prompts(cfg)):
            srv.submit(Request(rid=i, prompt=p, max_new_tokens=MAX_NEW,
                               eos_id=-1))
        done = srv.run_until_drained(params)
        _STATE["oracle"][quantized] = {r.rid: list(r.out_tokens)
                                       for r in done}
    return _STATE["oracle"][quantized]


def _fleet(n, *, quantized=False, paged=False, slots=2):
    cfg, model, params = _setup()
    kw = dict(paged=True, page_size=4, num_pages=24) if paged else {}
    if isinstance(quantized, bool):
        quantized = [quantized] * n
    return [BatchServer(model, batch_slots=slots, max_len=MAX_LEN,
                        quantized=q, **kw) for q in quantized], params


def _submit_all(rt, cfg, **kw):
    for i, p in enumerate(_prompts(cfg)):
        rt.submit(Request(rid=i, prompt=p, max_new_tokens=MAX_NEW,
                          eos_id=-1), **kw)


# fault windows tuned so every kind actually FIRES against this workload
# (asserted below — a fault plan that no-ops tests nothing)
_PLANS = {
    "raise": FaultPlan([FaultSpec(kind="raise", replica=0, at_dispatch=1,
                                  duration=2)], seed=3),
    "hang": FaultPlan([FaultSpec(kind="hang", replica=0, at_dispatch=1,
                                 duration=2)], seed=3),
    "exhaust": FaultPlan([FaultSpec(kind="exhaust", replica=0,
                                    at_dispatch=0, duration=3)], seed=3),
    "poison": FaultPlan([FaultSpec(kind="poison", replica=0, at_dispatch=0,
                                   duration=8)], seed=3),
}


@pytest.mark.parametrize("kind", sorted(_PLANS))
@pytest.mark.parametrize("paged", [False, True], ids=["contig", "paged"])
@pytest.mark.parametrize("quantized", [False, True], ids=["float", "int8"])
def test_fault_matrix_completes_token_identical(kind, paged, quantized):
    """Every injected fault ends in completion with the no-fault oracle's
    exact tokens — never a stuck queue, never a duplicate emission."""
    cfg, model, params = _setup()
    want = _oracle(quantized)
    servers, params = _fleet(2, quantized=quantized, paged=paged)
    rt = ReplicaRouter(servers, params,
                       cfg=RouterConfig(step_timeout_s=5.0,
                                        quarantine_s=0.2, max_retries=4),
                       fault_plan=_PLANS[kind], clock=FakeClock())
    _submit_all(rt, cfg)
    recs = rt.drive(max_ticks=2000)

    assert all(r.terminal for r in recs.values())
    toks = rt.completed_tokens()
    assert sorted(toks) == list(range(len(LENS))), rt.outcome_counts()
    for i, t in toks.items():
        assert t == want[i], (kind, paged, quantized, i)
    # the fault actually fired
    assert rt.stats["replica_failures"] + rt.stats["poisoned"] >= 1, rt.stats
    # bounded retries: every attempt count within budget
    assert all(r.attempts <= rt.cfg.max_retries for r in recs.values())
    # a completion is exposed exactly once per rid (terminal-is-final)
    assert rt.stats["completed"] == len(LENS)
    for s in servers:
        if s.paged:      # reservation ledger drains to 0, pool is leak-free
            assert s._reserved == 0
            assert s.alloc.free_count + s.alloc.in_use == s.num_pages


def test_retries_exhausted_is_typed_and_bounded():
    """A fleet whose only replica always raises fails every request with
    RetriesExhaustedError after exactly max_retries+1 attempts — no hang."""
    cfg, model, params = _setup()
    plan = FaultPlan([FaultSpec(kind="raise", replica=0, at_dispatch=0,
                                duration=10_000)])
    servers, params = _fleet(1)
    # breaker disabled: with it on, the lone replica would sit quarantined
    # and requests would wait QUEUED (that path is covered by the drain
    # test); here every dispatch must fail so the retry budget burns down
    rt = ReplicaRouter(servers, params,
                       cfg=RouterConfig(max_retries=2, quarantine_s=0.05,
                                        step_timeout_s=5.0,
                                        breaker_threshold=10**6),
                       fault_plan=plan, clock=FakeClock())
    _submit_all(rt, cfg)
    recs = rt.drive(max_ticks=2000)
    for rec in recs.values():
        assert rec.state is Lifecycle.FAILED
        assert isinstance(rec.error, lc.RetriesExhaustedError)
        assert rec.error.attempts == 3
        assert isinstance(rec.error.cause, lc.ReplicaFailedError)


def test_deadline_and_phase_timeouts():
    cfg, model, params = _setup()
    servers, params = _fleet(1, slots=1)
    clock = FakeClock()
    rt = ReplicaRouter(servers, params, clock=clock,
                       cfg=RouterConfig(tick_s=0.01,
                                        phase_timeouts_s={"queued": 0.02}))
    prompts = _prompts(cfg)
    # rid 0: normal; rid 1: deadline so tight it expires before dispatch
    rt.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=MAX_NEW,
                      eos_id=-1))
    rt.submit(Request(rid=1, prompt=prompts[1], max_new_tokens=MAX_NEW,
                      eos_id=-1), deadline_s=0.005)
    # rids 2..4: behind a 1-slot replica, the queued-phase timeout reaps
    # whatever is still waiting after 2 ticks in the queue
    for i in (2, 3, 4):
        rt.submit(Request(rid=i, prompt=prompts[i], max_new_tokens=MAX_NEW,
                          eos_id=-1))
    recs = rt.drive(max_ticks=2000)
    assert recs[0].state is Lifecycle.DONE
    assert recs[0].tokens == _oracle(False)[0]
    assert recs[1].state is Lifecycle.TIMED_OUT
    assert isinstance(recs[1].error, lc.DeadlineExceededError)
    assert recs[1].error.phase == "queued"
    timed_out = [i for i in (2, 3, 4)
                 if recs[i].state is Lifecycle.TIMED_OUT]
    assert timed_out, "queued-phase timeout never fired"
    for i in timed_out:
        assert isinstance(recs[i].error, lc.DeadlineExceededError)
    # ledger still clean after timeout-driven aborts
    assert rt.stats["timed_out"] == len(timed_out) + 1


def test_backpressure_bounded_queue_rejects_with_retry_hint():
    cfg, model, params = _setup()
    servers, params = _fleet(1, slots=1)
    rt = ReplicaRouter(servers, params, cfg=RouterConfig(max_queue=2),
                       clock=FakeClock())
    prompts = _prompts(cfg)
    rt.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=2, eos_id=-1))
    rt.submit(Request(rid=1, prompt=prompts[1], max_new_tokens=2, eos_id=-1))
    with pytest.raises(lc.RejectedError) as ei:
        rt.submit(Request(rid=2, prompt=prompts[2], max_new_tokens=2,
                          eos_id=-1))
    assert ei.value.retry_after_s > 0
    assert rt.stats["rejected"] == 1
    # the admitted work still completes
    recs = rt.drive(max_ticks=2000)
    assert recs[0].state is Lifecycle.DONE
    assert recs[1].state is Lifecycle.DONE


def test_admission_impossible_fails_fast_at_router():
    cfg, model, params = _setup()
    servers, params = _fleet(2, paged=True)
    rt = ReplicaRouter(servers, params, clock=FakeClock())
    big = np.zeros((MAX_LEN + 10,), np.int64)
    with pytest.raises(lc.AdmissionImpossibleError):
        rt.submit(Request(rid=0, prompt=big, max_new_tokens=4, eos_id=-1))
    assert not rt.records         # nothing queued


def test_router_idempotent_duplicate_rids():
    cfg, model, params = _setup()
    servers, params = _fleet(1)
    rt = ReplicaRouter(servers, params, clock=FakeClock())
    prompts = _prompts(cfg)
    req = Request(rid=0, prompt=prompts[0], max_new_tokens=MAX_NEW,
                  eos_id=-1)
    rec = rt.submit(req)
    # duplicate while queued: the SAME record, no second entry
    dup = Request(rid=0, prompt=prompts[0], max_new_tokens=MAX_NEW,
                  eos_id=-1)
    assert rt.submit(dup) is rec
    assert rt.stats["dedup_submits"] == 1
    assert rt.stats["submitted"] == 1
    rt.drive(max_ticks=2000)
    # duplicate after DONE: cached completion, no recompute
    dispatched = rt.stats["dispatched"]
    again = rt.submit(Request(rid=0, prompt=prompts[0],
                              max_new_tokens=MAX_NEW, eos_id=-1))
    assert again.state is Lifecycle.DONE
    assert again.tokens == _oracle(False)[0]
    assert rt.stats["dispatched"] == dispatched
    # same rid with a DIFFERENT payload is a contract violation
    with pytest.raises(lc.AdmissionImpossibleError):
        rt.submit(Request(rid=0, prompt=prompts[1], max_new_tokens=MAX_NEW,
                          eos_id=-1))


def test_shed_to_quantized_under_pressure():
    """Mixed fleet: queue pressure sheds work to the int8-FFIP replica
    (half-the-MACs capacity) instead of rejecting; every completion matches
    the oracle of the TIER that served it."""
    cfg, model, params = _setup()
    servers, params = _fleet(2, quantized=[False, True], slots=1)
    rt = ReplicaRouter(servers, params, clock=FakeClock(),
                       cfg=RouterConfig(shed_queue_depth=2))
    _submit_all(rt, cfg)
    recs = rt.drive(max_ticks=2000)
    assert all(r.state is Lifecycle.DONE for r in recs.values())
    assert rt.stats["shed_to_quantized"] >= 1
    tiers = {rec.tier for rec in recs.values()}
    assert tiers == {"float", "int8"}          # both tiers actually served
    for rid, rec in recs.items():
        assert rec.tokens == _oracle(rec.tier == "int8")[rid], (rid, rec.tier)


def test_circuit_breaker_quarantine_probe_readmission():
    """3 consecutive failures quarantine the replica; after the cool-down it
    gets ONE probe, and a successful probe re-admits it as healthy."""
    cfg, model, params = _setup()
    plan = FaultPlan([FaultSpec(kind="raise", replica=0, at_dispatch=0,
                                duration=3)])
    # 1-slot replicas keep a backlog queued long enough that the revived
    # replica's probe actually has a request to prove itself on
    servers, params = _fleet(2, slots=1)
    clock = FakeClock()
    rt = ReplicaRouter(servers, params, clock=clock,
                       cfg=RouterConfig(breaker_threshold=3,
                                        quarantine_s=0.02, max_retries=5,
                                        step_timeout_s=5.0),
                       fault_plan=plan)
    _submit_all(rt, cfg)
    recs = rt.drive(max_ticks=2000)
    assert all(r.state is Lifecycle.DONE for r in recs.values())
    kinds = [e[0] for e in rt.events]
    assert "quarantine" in kinds
    assert "probe" in kinds
    assert rt.stats["quarantines"] >= 1
    assert rt.stats["probes"] >= 1
    assert rt.stats["probe_successes"] >= 1
    assert rt.replicas[0].state == HEALTHY     # re-admitted after the probe
    toks = rt.completed_tokens()
    want = _oracle(False)
    assert all(toks[i] == want[i] for i in toks)


def test_quarantined_replica_drains_work_to_queue():
    cfg, model, params = _setup()
    plan = FaultPlan([FaultSpec(kind="raise", replica=0, at_dispatch=0,
                                duration=10_000)])
    servers, params = _fleet(2)
    rt = ReplicaRouter(servers, params, clock=FakeClock(),
                       cfg=RouterConfig(breaker_threshold=1,
                                        quarantine_s=1000.0, max_retries=4,
                                        step_timeout_s=5.0),
                       fault_plan=plan)
    _submit_all(rt, cfg)
    recs = rt.drive(max_ticks=2000)
    # replica 0 stays quarantined; replica 1 serves EVERYTHING correctly
    assert rt.replicas[0].state == QUARANTINED
    assert not rt.replicas[0].outstanding
    want = _oracle(False)
    for rid, rec in recs.items():
        assert rec.state is Lifecycle.DONE
        assert rec.tokens == want[rid]


def test_hang_faults_require_fake_clock():
    servers, params = _fleet(1)
    plan = FaultPlan([FaultSpec(kind="hang", replica=0, at_dispatch=0)])
    with pytest.raises(ValueError, match="FakeClock"):
        ReplicaRouter(servers, params, fault_plan=plan)   # real clock


def test_watchdog_sees_hung_replica_as_straggler():
    """The shared train/serve watchdog flags the hang tick (its duration
    explodes vs the EMA of healthy ticks)."""
    cfg, model, params = _setup()
    plan = FaultPlan([FaultSpec(kind="hang", replica=0, at_dispatch=2)])
    servers, params = _fleet(2)
    rt = ReplicaRouter(servers, params, clock=FakeClock(), fault_plan=plan,
                       cfg=RouterConfig(step_timeout_s=5.0, max_retries=4),
                       watchdog_cfg=WatchdogConfig(consecutive_to_act=1))
    _submit_all(rt, cfg)
    rt.drive(max_ticks=2000)
    assert any(e[0] == "straggler_tick" for e in rt.events)


def test_fault_plan_roundtrip_and_parse():
    plan = FaultPlan.flaky_replica(0, start=2, period=4, rounds=3, seed=7)
    back = FaultPlan.parse(plan.to_json())
    assert back.faults == plan.faults
    assert back.seed == 7
    assert plan.has_hangs
    with pytest.raises(ValueError):
        FaultSpec(kind="meteor", replica=0, at_dispatch=0)
    clock = FakeClock()
    clock.advance(1.5)
    assert clock() == 1.5
    with pytest.raises(ValueError):
        clock.advance(-1.0)
