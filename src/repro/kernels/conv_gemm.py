"""Fused implicit-im2col conv kernels — Algorithm 1 executed INSIDE the GEMM.

The paper's memory subsystem never materializes an im2col matrix: the §5.1
multi-digit address counters (Fig. 5) generate the conv->GEMM gather
addresses on the fly while the systolic array consumes the stream. The
``conv2d_via_gemm`` reference in :mod:`repro.core.im2col` models the counters
but still gathers the full (M, K) A matrix into HBM before calling a dense
GEMM. These kernels close that gap: the Algorithm-1 address arithmetic runs
*inside* the Pallas kernel, per (bm, bk) block —

    m digit -> (oh, ow) spatial position   (stride (sh, sw))
    k digit -> (kh, kw, cin-in-group)      (kernel offsets + channel)
    addr    = ((oh*sh + kh) * Wp + (ow*sw + kw)) * Cin + g*Cin_g + cin

— so the A matrix only ever exists as (bm, bk) VMEM tiles; HBM holds the
spatially-padded input exactly once. The arithmetic bodies mirror the GEMM
kernels (baseline dot / FIP pair algebra / FFIP y-delta carry) operation for
operation, so for a fixed (bn, bk) a fused conv is BIT-IDENTICAL to running
the same Pallas GEMM over the materialized A — the reference oracle tests
rely on this.

Int8 path (§3.3/§4.4): :func:`prepare_quantized_conv` quantizes the filter
per output channel on the flattened KH*KW*Cin_g axis and precomputes the
Eq. 15 folded beta plus colsums; :func:`quantized_conv_apply` quantizes the
(spatially padded) input per tensor, runs the fused kernels on the raw int8
operands, and removes the zero-point terms with the Eq. 20 adjuster — the
row-sums come from a windowed reduction over the input, never from a
materialized A. Bit-exact against :func:`quantized_conv_reference`.

VMEM note: each grid step holds one padded input image in VMEM (the role the
paper's partitioned activation submemories play); full-resolution early VGG
layers exceed a real core's VMEM — the CPU CI runs interpret mode where this
is only a host buffer. Tiling the gather source is future work.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import fip
from repro.kernels import compat
from repro.core.im2col import as_pair, conv_out_hw, Size2
from repro.kernels.compat import resolve_interpret, tpu_compiler_params
from repro.kernels.ffip_gemm import ffip_tile
from repro.kernels.fip_gemm import fip_tile
from repro.kernels import ops as kops
from repro.obs import profile as _obs_profile

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ConvGeom:
    """Static conv geometry threaded into the kernels (hashable for jit).
    Batch rides in the array shapes, not here — the address arithmetic is
    per image."""
    h: int          # padded input height
    w: int          # padded input width
    cin: int
    kh: int
    kw: int
    sh: int
    sw: int
    groups: int
    ng: int         # output channels per group

    @property
    def cin_g(self) -> int:
        return self.cin // self.groups

    @property
    def oh(self) -> int:
        return conv_out_hw(self.h, self.w, self.kh, self.kw,
                           (self.sh, self.sw))[0]

    @property
    def ow(self) -> int:
        return conv_out_hw(self.h, self.w, self.kh, self.kw,
                           (self.sh, self.sw))[1]

    @property
    def m(self) -> int:
        return self.oh * self.ow

    @property
    def k(self) -> int:
        """Gather-valid contraction length KH*KW*Cin_g (the b-stack may carry
        an extra zero row when K is odd — evenized for the pair algebra)."""
        return self.kh * self.kw * self.cin_g


def _gather_tile(x_ref, g, mi, ki, *, bm: int, bk: int, geom: ConvGeom):
    """The in-kernel Algorithm-1 counter: materialize the (bm, bk) A tile for
    grid position (group g, m block mi, k block ki) by address arithmetic +
    gather from the flat padded image. k columns past the real K are zeroed
    (exact for the baseline products and the FIP pair algebra)."""
    m_idx = mi * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, bk), 0)
    k_idx = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bm, bk), 1)
    # clamp padded rows/cols so addresses stay in range; masked/sliced later
    m_c = jnp.minimum(m_idx, geom.m - 1)
    k_c = jnp.minimum(k_idx, geom.k - 1)
    oh_i = m_c // geom.ow                        # spatial digits (m_offset)
    ow_i = m_c % geom.ow
    c_i = k_c % geom.cin_g                       # kernel digits (k_offset)
    rem = k_c // geom.cin_g
    kw_i = rem % geom.kw
    kh_i = rem // geom.kw
    row = oh_i * geom.sh + kh_i
    col = ow_i * geom.sw + kw_i
    addr = (row * geom.w + col) * geom.cin + g * geom.cin_g + c_i
    flat = x_ref[0]                              # (Hp*Wp*Cin,) in VMEM
    a = jnp.take(flat, addr.reshape(-1), axis=0).reshape(bm, bk)
    return jnp.where(k_idx < geom.k, a, jnp.zeros_like(a))


def _conv_kernel_mac(x_ref, b_ref, o_ref, *, acc_dtype, algo: str,
                     fold_beta: bool, bm: int, bk: int, geom: ConvGeom):
    """Baseline / FIP bodies; grid (B, G, M/bm, N/bn, K/bk), K innermost.
    Mirrors baseline_gemm/fip_gemm exactly, with A gathered, not loaded."""
    g = pl.program_id(1)
    mi = pl.program_id(2)
    ki = pl.program_id(4)
    a = _gather_tile(x_ref, g, mi, ki, bm=bm, bk=bk, geom=geom).astype(acc_dtype)
    b = b_ref[0].astype(acc_dtype)               # (bk, bn)
    if algo == "baseline":
        if jnp.issubdtype(acc_dtype, jnp.integer):
            part = jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                                       preferred_element_type=acc_dtype)
        else:
            part = jnp.dot(a, b, preferred_element_type=acc_dtype)
    else:
        part = fip_tile(a, b, fold_beta=fold_beta)   # shared Eq. (2) algebra

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = part[None, None]

    @pl.when(ki != 0)
    def _acc():
        o_ref[...] += part[None, None]


def _conv_kernel_ffip(x_ref, y_ref, o_ref, carry_ref, *, acc_dtype,
                      fold_beta: bool, bm: int, bk: int, geom: ConvGeom):
    """FFIP body; grid (B, G, M/bm, K/bk, N/bn), N innermost so the carry
    sweeps output columns for a fixed (m, k) stripe — mirrors ffip_gemm."""
    g = pl.program_id(1)
    mi = pl.program_id(2)
    ki = pl.program_id(3)
    nn = pl.program_id(4)
    a = _gather_tile(x_ref, g, mi, ki, bm=bm, bk=bk, geom=geom).astype(acc_dtype)
    y = y_ref[0].astype(acc_dtype)               # (bk, bn) weight deltas
    part = ffip_tile(a, y, carry_ref, nn, fold_beta=fold_beta)  # shared

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = part[None, None]

    @pl.when(ki != 0)
    def _acc():
        o_ref[...] += part[None, None]


@functools.partial(jax.jit, static_argnames=("geom", "algo", "bm", "bn", "bk",
                                             "interpret", "fold_beta"))
def _fused_flat(xf: Array, bg: Array, *, geom: ConvGeom, algo: str, bm: int,
                bn: int, bk: int, interpret: bool, fold_beta: bool) -> Array:
    """xf: (B, Hp*Wp*Cin) flat padded input; bg: (G, Ks, Ng) weights (or y
    deltas for ffip) -> (B, G, Mp, Np) accumulator-dtype output."""
    n_b, length = xf.shape
    n_g, ks, ng = bg.shape
    acc_dtype = (jnp.int32 if jnp.issubdtype(xf.dtype, jnp.integer)
                 else jnp.float32)
    mp = -(-geom.m // bm) * bm
    kp = -(-ks // bk) * bk
    np_ = -(-ng // bn) * bn
    if (kp, np_) != (ks, ng):
        bg = jnp.pad(bg, ((0, 0), (0, kp - ks), (0, np_ - ng)))
    x_spec = pl.BlockSpec((1, length), lambda bi, g, i, p3, p4: (bi, 0))
    if algo == "ffip":
        grid = (n_b, n_g, mp // bm, kp // bk, np_ // bn)   # N innermost
        kernel = functools.partial(_conv_kernel_ffip, acc_dtype=acc_dtype,
                                   fold_beta=fold_beta, bm=bm, bk=bk,
                                   geom=geom)
        in_specs = [x_spec,
                    pl.BlockSpec((1, bk, bn), lambda bi, g, i, kk, j: (g, kk, j))]
        out_spec = pl.BlockSpec((1, 1, bm, bn),
                                lambda bi, g, i, kk, j: (bi, g, i, j))
        scratch = [pltpu.VMEM((bk, 1), acc_dtype)]
        semantics = ("parallel", "parallel", "parallel", "arbitrary",
                     "arbitrary")
    else:
        grid = (n_b, n_g, mp // bm, np_ // bn, kp // bk)   # K innermost
        kernel = functools.partial(_conv_kernel_mac, acc_dtype=acc_dtype,
                                   algo=algo, fold_beta=fold_beta, bm=bm,
                                   bk=bk, geom=geom)
        in_specs = [x_spec,
                    pl.BlockSpec((1, bk, bn), lambda bi, g, i, j, kk: (g, kk, j))]
        out_spec = pl.BlockSpec((1, 1, bm, bn),
                                lambda bi, g, i, j, kk: (bi, g, i, j))
        scratch = []
        semantics = ("parallel", "parallel", "parallel", "parallel",
                     "arbitrary")
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((n_b, n_g, mp, np_), acc_dtype),
        scratch_shapes=scratch,
        compiler_params=tpu_compiler_params(dimension_semantics=semantics),
        interpret=interpret,
    )(xf, bg)


# Offline weight derivations (stack / evenize / y-deltas) memoize through the
# shared compat.derived cache (one id+weakref+tracer-bypass implementation for
# this module and ffip_gemm). Without it every eager FFIP conv forward would
# re-encode its filters (§4.4 says y is an OFFLINE transform of the weights).
def _derived(tag: str, arr: Array, fn: Callable[[Array], Array]) -> Array:
    return compat.derived.get(tag, arr, fn)


def _kernel_to_stack(kernel: Array, groups: int) -> Array:
    """(KH, KW, Cin_g, Cout) -> (G, KH*KW*Cin_g, Cout/G): the per-group B
    operands on the flattened (kh, kw, cin) contraction axis."""
    kh, kw, cin_g, cout = kernel.shape
    if cout % groups:
        raise ValueError(f"cout={cout} not divisible by groups={groups}")
    ng = cout // groups
    b2 = kernel.reshape(kh * kw * cin_g, cout)
    return jnp.moveaxis(b2.reshape(kh * kw * cin_g, groups, ng), 1, 0)


def _evenize_k(bg: Array) -> Array:
    """Zero-pad the contraction axis to even length (the FIP pair algebra
    consumes K in pairs; a zero row pairs exactly — mixed pairs reduce to the
    plain product term)."""
    if bg.shape[1] % 2:
        bg = jnp.pad(bg, ((0, 0), (0, 1), (0, 0)))
    return bg


def fused_conv_raw(x: Array, bg: Array, *, kh: int, kw: int,
                   stride: Size2 = 1, groups: int = 1, algo: str = "ffip",
                   bm: int = 0, bn: int = 0, bk: int = 0,
                   interpret: Optional[bool] = None,
                   fold_beta: bool = False) -> Array:
    """Raw fused conv on an ALREADY spatially-padded input.

    x: (B, Hp, Wp, Cin) (any float or int dtype); bg: (G, Ks, Ng) per-group
    weight stack on the flattened (kh, kw, cin_g) axis (Ks may be the
    evenized K). Returns (B, OH, OW, Cout) in the accumulation dtype
    (int32 for ints, float32 for floats) — callers cast/rescale.
    """
    interpret = resolve_interpret(interpret)
    n_b, h, w, cin = x.shape
    sh, sw = as_pair(stride)
    n_g, ks, ng = bg.shape
    if n_g != groups:
        raise ValueError(f"b-stack has {n_g} groups, expected {groups}")
    geom = ConvGeom(h=h, w=w, cin=cin, kh=kh, kw=kw, sh=sh, sw=sw,
                    groups=groups, ng=ng)
    if ks not in (geom.k, geom.k + geom.k % 2):
        raise ValueError(f"b-stack K={ks} does not match KH*KW*Cin_g={geom.k}")
    if algo == "ffip":
        # evenize + Eq. 9 y-delta encoding per group — an offline transform
        # of the weights (§4.4), memoized per source array like the GEMM path
        bg = _derived("y_even", bg,
                      lambda b: jax.vmap(fip.make_y)(_evenize_k(b)))
        ks = bg.shape[1]
    elif algo == "fip":
        bg = _derived("even", bg, _evenize_k)
        ks = bg.shape[1]
    if not (bm and bn and bk):
        bm, bn, bk = kops.choose_blocks(geom.m, ng, ks, algo)
    if algo in ("fip", "ffip") and bk % 2:
        raise ValueError(f"bk={bk} must be even for the FIP pair algebra")
    xf = x.reshape(n_b, h * w * cin)
    out = _fused_flat(xf, bg, geom=geom, algo=algo, bm=bm, bn=bn, bk=bk,
                      interpret=interpret, fold_beta=fold_beta)
    out = out[:, :, :geom.m, :ng]                        # (B, G, M, Ng)
    out = jnp.moveaxis(out, 1, 2).reshape(n_b, geom.oh, geom.ow, groups * ng)
    return out


def conv_gemm_fused(x: Array, kernel: Array, *, stride: Size2 = 1,
                    pad: Size2 = 0, groups: int = 1, algo: str = "ffip",
                    bm: int = 0, bn: int = 0, bk: int = 0,
                    interpret: Optional[bool] = None) -> Array:
    """NHWC conv via the fused implicit-im2col kernels (float front door).

    x: (B, H, W, Cin); kernel: (KH, KW, Cin/groups, Cout). Drop-in for
    :func:`repro.core.im2col.conv2d_via_gemm` — same (B, OH, OW, Cout)
    result, but the im2col matrix never exists outside VMEM tiles.
    """
    ph, pw = as_pair(pad)
    if ph or pw:
        x = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    kh, kw, _, _ = kernel.shape
    sh, sw = as_pair(stride)
    _obs_profile.on_conv(x, kernel, oh=(x.shape[1] - kh) // sh + 1,
                         ow=(x.shape[2] - kw) // sw + 1, groups=groups,
                         algo=algo)
    bg = _derived(f"stack{groups}", kernel,
                  lambda k_: _kernel_to_stack(k_, groups))
    out = fused_conv_raw(x, bg, kh=kh, kw=kw, stride=stride, groups=groups,
                         algo=algo, bm=bm, bn=bn, bk=bk, interpret=interpret)
    if jnp.issubdtype(x.dtype, jnp.integer):
        return out                                   # int32 accumulator
    return out.astype(jnp.result_type(x.dtype, kernel.dtype))


# ---------------------------------------------------------------------------
# Quantized conv (§3.3/§4.4 on the flattened KH*KW*Cin_g axis)
# ---------------------------------------------------------------------------

def prepare_quantized_conv(kernel: Array, *, groups: int = 1,
                           dtype=jnp.int8) -> dict:
    """Offline filter quantization for the int8 conv path.

    kernel: (KH, KW, Cin/groups, Cout). Quantizes per output channel on the
    flattened KH*KW*Cin_g contraction axis via
    :func:`repro.core.quant.prepare_quantized_dense` (so the conv path
    inherits the Eq. 15 folded beta and the colsum terms), with the K axis
    zero-evenized for the pair algebra. Returns the per-group stacked dict
    plus the conv bookkeeping (k_real, kh, kw, groups).
    """
    from repro.core import quant
    kh, kw, cin_g, cout = kernel.shape
    bg = _kernel_to_stack(kernel, groups)            # (G, K, Ng) float
    bg = _evenize_k(bg)
    q = quant.prepare_quantized_dense(bg, dtype=dtype)
    q.update(k_real=kh * kw * cin_g, kh=kh, kw=kw, groups=groups)
    return q


def quantize_input_per_tensor(xp: Array) -> Tuple[Array, Array, Array]:
    """Per-tensor asymmetric int8 quantization of a spatially PADDED input
    (pad first: real 0.0 then quantizes exactly to the zero point, so border
    windows stay faithful). Returns (xq int8, scale f32, zero_point i32)."""
    x32 = xp.astype(jnp.float32)
    xmin = jnp.minimum(jnp.min(x32), 0.0)
    xmax = jnp.maximum(jnp.max(x32), 0.0)
    scale = jnp.maximum((xmax - xmin) / 255.0, 1e-12)
    zp = jnp.clip(jnp.round(-128 - xmin / scale), -128, 127).astype(jnp.int32)
    xq = jnp.clip(jnp.round(x32 / scale) + zp, -128, 127).astype(jnp.int8)
    return xq, scale, zp


def conv_rowsums(xq: Array, *, kh: int, kw: int, stride: Size2,
                 groups: int = 1) -> Array:
    """rowsum(A_q) for the implicit im2col matrix, per group, WITHOUT
    materializing A: sum the (already padded, already quantized) input over
    each group's channels, then box-reduce over the kernel window.
    xq: (B, Hp, Wp, Cin) -> (B, OH, OW, G) int32 — the Eq. 20 adjuster input.
    """
    sh, sw = as_pair(stride)
    n_b, h, w, cin = xq.shape
    cin_g = cin // groups
    xs = xq.astype(jnp.int32).reshape(n_b, h, w, groups, cin_g).sum(-1)
    return jax.lax.reduce_window(
        xs, jnp.int32(0), jax.lax.add,
        window_dimensions=(1, kh, kw, 1), window_strides=(1, sh, sw, 1),
        padding="VALID")


def quantized_conv_apply(x: Array, q: dict, *, stride: Size2 = 1,
                         pad: Size2 = 0, algo: str = "ffip",
                         bm: int = 0, bn: int = 0, bk: int = 0,
                         interpret: Optional[bool] = None) -> Array:
    """Int8 conv through offline-prepared weights, fused implicit im2col.

    Mirrors ``core.quant.quantized_dense_apply`` with the hardware's conv
    strategy: raw (F)FIP on the quantized integers (both-signed, d=1, beta
    folded offline per Eq. 15), zero-point contributions removed via the
    Eq. 20 adjuster with windowed row-sums and the offline colsums. Returns
    float32 (B, OH, OW, Cout) ~= conv(x, w).
    """
    ph, pw = as_pair(pad)
    if ph or pw:
        x = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    xq, a_scale, a_zp = quantize_input_per_tensor(x)
    groups, kh, kw = q["groups"], q["kh"], q["kw"]
    fold = algo in ("fip", "ffip")
    raw = fused_conv_raw(xq, q["qw"], kh=kh, kw=kw, stride=stride,
                         groups=groups, algo=algo, bm=bm, bn=bn, bk=bk,
                         interpret=interpret, fold_beta=fold)
    return _dequantize_conv(raw, xq, q, a_scale, a_zp, stride=stride,
                            fold_beta=fold)


def _dequantize_conv(raw: Array, xq: Array, q: dict, a_scale: Array,
                     a_zp: Array, *, stride: Size2, fold_beta: bool) -> Array:
    """Shared epilogue: folded beta + zero-point corrections + rescale.
    raw: (B, OH, OW, Cout) int32 = A_q W_q (cross - alpha when fold_beta)."""
    groups, kh, kw = q["groups"], q["kh"], q["kw"]
    ng = q["qw"].shape[-1]
    n_b = raw.shape[0]
    oh, ow = raw.shape[1], raw.shape[2]
    acc = raw.reshape(n_b, oh, ow, groups, ng)
    if fold_beta:
        acc = acc + q["neg_beta"]                    # Eq. 15: + (-beta(W_q))
    rs = conv_rowsums(xq, kh=kh, kw=kw, stride=stride, groups=groups)
    acc = (acc
           - a_zp * q["colsum"]                      # za * colsum(W_q)
           - rs[..., None] * q["zp"]                 # Eq. 20: zb_j * rowsum(A)_i
           + q["k_real"] * a_zp * q["zp"])
    out = acc.astype(jnp.float32) * (a_scale * q["scale"])
    return out.reshape(n_b, oh, ow, groups * ng)


def quantized_conv_reference(x: Array, q: dict, *, stride: Size2 = 1,
                             pad: Size2 = 0, algo: str = "ffip") -> Array:
    """Materializing oracle for :func:`quantized_conv_apply`: gathers the
    full A_q via the Algorithm-1 indices (core.im2col) and runs the same
    integer algebra through the core.fip closed forms. Bit-identical to the
    fused path for every legal block choice (int32 addition is exact)."""
    from repro.core.im2col import conv_gemm_indices
    ph, pw = as_pair(pad)
    if ph or pw:
        x = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    xq, a_scale, a_zp = quantize_input_per_tensor(x)
    groups, kh, kw = q["groups"], q["kh"], q["kw"]
    n_b, h, w, cin = xq.shape
    sh, sw = as_pair(stride)
    oh, ow = conv_out_hw(h, w, kh, kw, (sh, sw))
    flat = xq.reshape(n_b, h * w * cin)
    zero_bias = jnp.zeros((), jnp.int32)             # beta re-added in epilogue
    raws = []
    for g in range(groups):
        idx = jnp.asarray(conv_gemm_indices(h, w, cin, kh, kw, (sh, sw),
                                            groups=groups, group=g))
        aq = flat[:, idx].astype(jnp.int32)          # (B, M, K) materialized
        if aq.shape[-1] < q["qw"].shape[1]:          # evenized weight K
            aq = jnp.pad(aq, ((0, 0), (0, 0),
                              (0, q["qw"].shape[1] - aq.shape[-1])))
        b32 = q["qw"][g].astype(jnp.int32)
        if algo == "baseline":
            raws.append(jnp.matmul(aq, b32))
        elif algo == "ffip":
            raws.append(fip.fip_matmul_beta_folded(
                fip.pair_swap(aq), fip.pair_swap_rows(b32), zero_bias))
        else:
            raws.append(fip.fip_matmul_beta_folded(aq, b32, zero_bias))
    raw = jnp.stack(raws, axis=1)                    # (B, G, M, Ng)
    ng = q["qw"].shape[-1]
    raw = jnp.moveaxis(raw, 1, 2).reshape(n_b, oh, ow, groups * ng)
    return _dequantize_conv(raw, xq, q, a_scale, a_zp, stride=stride,
                            fold_beta=(algo != "baseline"))
