"""EXPERIMENTS.md §Dry-run + §Roofline table generator.

    PYTHONPATH=src python -m repro.launch.report   # prints markdown to stdout
"""
from __future__ import annotations

import json
import pathlib
import sys

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB", "PB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}EB"


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 0.1:
        return f"{x:.3f}s"
    if x >= 1e-4:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}us"


def load(mesh: str):
    rows = []
    for f in sorted(RESULTS.glob(f"*__{mesh}.json")):
        rows.append(json.loads(f.read_text()))
    return rows


def dryrun_section() -> str:
    out = ["## §Dry-run", "",
           "Every (arch x shape) cell lowered + compiled with pjit on the "
           "single-pod 16x16 mesh (256 chips) AND the multi-pod 2x16x16 mesh "
           "(512 chips). `bytes/dev` is XLA's per-device temp allocation from "
           "`compiled.memory_analysis()`; collective mix from the post-SPMD "
           "optimized HLO (while-loop aware).", ""]
    for mesh in ("16x16", "2x16x16"):
        rows = load(mesh)
        ok = sum(1 for r in rows if r.get("status") == "ok")
        skip = sum(1 for r in rows if r.get("status") == "skipped")
        fail = [r for r in rows if r.get("status") == "failed"]
        out.append(f"### mesh {mesh}: {ok} compiled, {skip} skipped, {len(fail)} failed")
        out.append("")
        out.append("| arch | shape | status | compile | bytes/dev | collectives (count) | wire bytes |")
        out.append("|---|---|---|---|---|---|---|")
        for r in rows:
            if r.get("status") == "ok":
                colls = ", ".join(f"{k}:{v}" for k, v in
                                  sorted(r.get("collective_counts", {}).items()))
                out.append(
                    f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']}s | "
                    f"{fmt_bytes(r.get('bytes_per_device'))} | {colls or '-'} | "
                    f"{fmt_bytes(r.get('collective_bytes'))} |")
            elif r.get("status") == "skipped":
                out.append(f"| {r['arch']} | {r['shape']} | skipped | - | - | "
                           f"{r.get('reason', '')[:60]} | - |")
            else:
                out.append(f"| {r['arch']} | {r['shape']} | FAILED | - | - | "
                           f"{r.get('error', '')[:60]} | - |")
        out.append("")
    return "\n".join(out)


def roofline_section() -> str:
    out = ["## §Roofline", "",
           "Single-pod (16x16, 256 chips) terms per the brief: "
           "compute = FLOPs/(chips x 197 TF/s), memory = bytes/(chips x 819 GB/s), "
           "collective = wire-bytes/(chips x 50 GB/s). FLOPs/bytes are GLOBAL, "
           "scan-aware jaxpr counts (launch/costs.py — XLA cost_analysis counts "
           "while bodies once and is per-partition; recorded alongside). "
           "`useful` = MODEL_FLOPS / HLO_FLOPs where MODEL_FLOPS = 6*N_active*D "
           "(train) or 2*N_active*D (inference).", "",
           "| arch | shape | compute | memory | collective | bottleneck | "
           "roofline frac | useful flops |",
           "|---|---|---|---|---|---|---|---|"]
    worst = []
    for r in load("16x16"):
        if r.get("status") != "ok":
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"{r['bottleneck'].replace('_s', '')} | "
            f"{r['roofline_fraction']:.3f} | "
            f"{r.get('useful_flops_ratio', 0):.2f} |")
        worst.append((r["roofline_fraction"], r["arch"], r["shape"],
                      r["bottleneck"]))
    out.append("")
    worst.sort()
    out.append("Lowest roofline fractions (hillclimb candidates): " +
               "; ".join(f"{a} x {s} ({f:.3f}, {b.replace('_s','')}-bound)"
                         for f, a, s, b in worst[:6]))
    out.append("")
    return "\n".join(out)


def main():
    print(dryrun_section())
    print(roofline_section())


if __name__ == "__main__":
    main()
