"""Name/shape-pattern sharding rules -> PartitionSpec trees.

One rule engine covers every leaf of all 10 arch configs (attention, MoE,
SSM, conv frontends, enc-dec) on the 2-D ("data", "model") production mesh
(and its 3-D ("pod", "data", "model") multi-pod variant):

  * column-parallel weights (wq/wk/wv, mlp up/gate, router, x_proj, ...):
    input dim sharded over "data" (ZeRO/FSDP-style), output dim over "model"
    (Megatron tensor parallelism);
  * row-parallel weights (wo, mlp down, out_proj): input dim over "model"
    so they consume model-sharded activations, output dim over "data";
  * MoE expert banks (w_gate/w_up/w_down, shape (L, E, d, f)):
      - moe_partition="expert": expert axis E over "model" (expert
        parallelism — DeepSeek, 64 experts >= 16-way axis), d_model over
        "data";
      - moe_partition="ffn": d_ff_expert over "model" (tensor parallelism
        inside each expert — Mixtral, 8 experts < 16-way axis), d_model over
        "data";
  * embedding table (V, d): vocab over "model" (the tied unembed projection
    is then column-parallel), d over "data";
  * biases, norm scales and other vectors/scalars: replicated.

Every assignment passes a HARD divisibility guard: a dim whose size does not
divide its mesh-axis size stays unsharded (None). This is what makes one
table safe across the whole zoo — e.g. gemma3's 8 KV-head projection stays
replicated on a 16-way model axis instead of crashing the partitioner.

FFIP exactness note: these specs shard the *operands* of the GEMM provider;
data-parallel batch splits and output-dim (N) tensor splits never split the
inner K contraction of a kernel invocation, and K-dim ("data") sharding is
combined by XLA's all-gather/reduce in int32 accumulators, so the paper's
bit-exact int8 claim survives sharding (tests/test_dist_rules.py proves it).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

PyTree = Any

# Leaves that are never worth sharding (biases, norm params, scalars, and
# the tiny per-output-channel int8 epilogue vectors from repro.prepare).
_REPLICATED_LEAVES = frozenset({"b", "bias", "scale", "step", "pos",
                                "zp", "neg_beta", "colsum"})
# Row-parallel projections: they consume model-sharded activations.
_ROW_PARALLEL_PARENTS = frozenset({"wo", "down", "out_proj"})
# Stacked per-expert weight banks from moe_init.
_MOE_EXPERT_LEAVES = frozenset({"w_gate", "w_up", "w_down"})


def _axis_sizes(mesh) -> Dict[str, int]:
    """{axis_name: size} — duck-typed so shape-only mesh stand-ins work."""
    return dict(zip(tuple(mesh.axis_names), tuple(mesh.devices.shape)))


def _batch_axes(mesh, batch_size: Optional[int] = None):
    """The mesh axes a batch dim is split over, degrading gracefully.

    Prefers ("pod", "data") jointly, then "data", then "pod": a batch that
    divides the data axis but not pod*data still gets data-parallel sharding
    instead of silently replicating across every chip (same ladder idea as
    the shard_map spec chooser in models/attention.py). With no batch_size
    the full ladder head is returned and the caller's guard decides.
    """
    names = tuple(mesh.axis_names)
    present = tuple(a for a in ("pod", "data") if a in names)
    if not present:
        return None
    sizes = _axis_sizes(mesh)
    singles = sorted(((a,) for a in present),
                     key=lambda c: -sizes[c[0]])   # widest axis first
    ladder = ([present] if len(present) > 1 else []) + singles
    if batch_size is None:
        axes = ladder[0]
    else:
        axes = next((cand for cand in ladder
                     if batch_size % _axes_size(cand, sizes) == 0), None)
        if axes is None:
            return None
    return axes if len(axes) > 1 else axes[0]


def _axes_size(axes, sizes: Dict[str, int]) -> int:
    if axes is None:
        return 1
    if isinstance(axes, tuple):
        n = 1
        for a in axes:
            n *= sizes[a]
        return n
    return sizes[axes]


def _guarded(axes_per_dim, shape, sizes) -> P:
    """Apply the divisibility guard: drop any axis that does not divide."""
    out = []
    for dim, axes in enumerate(axes_per_dim):
        n = _axes_size(axes, sizes)
        out.append(axes if (axes is not None and n > 0
                            and shape[dim] % n == 0) else None)
    return P(*out)


def _match_spec(path: str, shape: Tuple[int, ...], mesh,
                moe_partition: str = "expert") -> P:
    """Rule table for a single parameter leaf.

    path: "/"-joined tree path, e.g. "layers/attn/wq/w"; shape: leaf shape.
    Returns a PartitionSpec with exactly len(shape) entries.
    """
    if moe_partition not in ("expert", "ffn"):
        raise ValueError(f"moe_partition must be 'expert' or 'ffn', "
                         f"got {moe_partition!r}")
    sizes = _axis_sizes(mesh)
    parts = [p for p in path.split("/") if p]
    leaf = parts[-1] if parts else ""
    parent = parts[-2] if len(parts) > 1 else ""
    if parent == "q" and len(parts) > 2:
        # offline-quantized leaves (qw/neg_beta/colsum under a "q" subtree,
        # repro.prepare) shard like the projection that owns them, so e.g.
        # wo/q/qw is row-parallel exactly like wo/w.
        parent = parts[-3]
    ndim = len(shape)
    axes: list = [None] * ndim

    if ndim <= 1 or leaf in _REPLICATED_LEAVES:
        return P(*axes)

    if leaf in _MOE_EXPERT_LEAVES and ndim >= 3:
        # (..., E, d_model, d_ff) for w_gate/w_up; (..., E, d_ff, d_model)
        # for w_down. Leading dims (layer stack) stay replicated.
        e, d_in, d_out = ndim - 3, ndim - 2, ndim - 1
        dm = d_in if leaf != "w_down" else d_out      # the d_model dim
        df = d_out if leaf != "w_down" else d_in      # the d_ff_expert dim
        if moe_partition == "expert":
            axes[e] = "model"
            axes[dm] = "data"
        else:  # "ffn": TP inside every expert
            axes[df] = "model"
            axes[dm] = "data"
    elif leaf == "table":
        # embedding (V, d): vocab over model => tied unembed is column-parallel
        axes[ndim - 2] = "model"
        axes[ndim - 1] = "data"
    elif parent in _ROW_PARALLEL_PARENTS:
        axes[ndim - 2] = "model"
        axes[ndim - 1] = "data"
    else:
        # generic column-parallel dense / conv / SSM weight
        axes[ndim - 2] = "data"
        axes[ndim - 1] = "model"

    if "model" in axes and "model" not in sizes:
        axes = [None if a == "model" else a for a in axes]
    if "data" in axes and "data" not in sizes:
        axes = [None if a == "data" else a for a in axes]
    return _guarded(axes, shape, sizes)


def _path_str(key_path) -> str:
    out = []
    for k in key_path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "name"):
            out.append(str(k.name))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return "/".join(out)


def param_specs(params: PyTree, mesh, moe_partition: str = "expert") -> PyTree:
    """PartitionSpec tree mirroring `params` (works on ShapeDtypeStructs)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _match_spec(_path_str(path), tuple(leaf.shape),
                                       mesh, moe_partition),
        params)


def data_specs(batch: PyTree, mesh) -> PyTree:
    """Data-parallel input specs: dim 0 over ("pod",)"data", rest replicated.

    Scalars are fully replicated; the (B,) per-slot decode position vector
    shards over the batch axes exactly like the (B, 1) token it accompanies.
    The divisibility guard applies: a global batch that does not divide the
    data axes is replicated rather than rejected.
    """
    sizes = _axis_sizes(mesh)

    def one(leaf):
        shape = tuple(leaf.shape)
        if not shape:
            return P()
        baxes = _batch_axes(mesh, shape[0])
        return _guarded([baxes] + [None] * (len(shape) - 1), shape, sizes)

    return jax.tree_util.tree_map(one, batch)


def cache_specs(cache: PyTree, mesh, *, batch: int) -> PyTree:
    """Decode/prefill cache specs: the batch dim is data-parallel.

    Cache leaves are stacked on leading layer-group dims — (L, B, ...), or
    (n_groups, period, B, ...) under the "hybrid_groups" subtree — so the
    batch dim position is known structurally from the path (init_cache's
    layout), with a size-equality scan only as fallback for foreign trees;
    size-matching alone would mis-shard when a stack dim happens to equal
    the batch size. KV caches additionally shard the kv-head dim
    (second-to-last) over "model" when it divides, mirroring the attention
    projections.
    """
    sizes = _axis_sizes(mesh)

    def one(path, leaf):
        shape = tuple(leaf.shape)
        ndim = len(shape)
        if ndim == 0:
            return P()
        axes: list = [None] * ndim
        parts = _path_str(path).split("/")
        bdim = 2 if parts[0] == "hybrid_groups" else 1
        if not (bdim < ndim and shape[bdim] == batch):
            bdim = next((d for d in range(ndim) if shape[d] == batch),
                        None)
        if bdim is not None:
            axes[bdim] = _batch_axes(mesh, batch)
        leaf_name = parts[-1]
        if leaf_name in ("k", "v") and ndim >= 4:
            axes[ndim - 2] = "model" if "model" in sizes else None
        return _guarded(axes, shape, sizes)

    return jax.tree_util.tree_map_with_path(one, cache)


def to_named(specs: PyTree, mesh) -> PyTree:
    """PartitionSpec tree -> NamedSharding tree on `mesh` (jit in_shardings)."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
