"""Serving launcher: continuous batching over any arch.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --smoke \
        --requests 8 --slots 4
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models.model import build_model
from repro.serve.batcher import BatchServer, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(configs.ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch)
    if args.smoke:
        cfg = configs.smoke_config(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    srv = BatchServer(model, batch_slots=args.slots, max_len=args.max_len)

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        srv.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab, size=(8,)),
            max_new_tokens=args.max_new))
    done = srv.run_until_drained(params)
    dt = time.perf_counter() - t0
    total = sum(len(r.out_tokens) for r in done)
    print(f"{len(done)} requests / {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s host-side)")


if __name__ == "__main__":
    main()
