"""The repro.obs telemetry subsystem (metrics / trace / profile) and the
contracts it enforces across the serving stack:

* metrics layer: bucket-boundary (``le``) correctness, exact-reservoir
  quantiles, the label-cardinality guard (per-request ids are REJECTED),
  Prometheus text round-trip, snapshot determinism under FakeClock, and the
  scrape endpoint;
* trace layer: ring-buffer bounding, span-tree reconstruction (including
  a retried + fault-injected request across two replicas), JSONL/Chrome
  export round-trip;
* profiler: FIP/FFIP multiplier accounting (Eqs. 1/5/7), the eager-dispatch
  vs compile-trace split at the real kernel call site;
* serving integration satellites: BatchServer clock injection, the
  ``_fresh_stats`` per-drain reset contract, the bounded ``events`` ring,
  and the train-watchdog shim that must never re-grow its own bookkeeping.
"""
import dataclasses
import inspect
import json

import jax
import numpy as np
import pytest

import repro.obs as obs
from repro import configs
from repro.models.model import build_model
from repro.obs import (CardinalityError, Registry, Tracer, load_jsonl,
                       parse_prometheus, start_metrics_server,
                       tree_from_spans)
from repro.obs import profile as obs_profile
from repro.serve.batcher import BatchServer, Request
from repro.serve.faults import FakeClock, FaultPlan, FaultSpec
from repro.serve.lifecycle import Lifecycle
from repro.serve.router import ReplicaRouter, RouterConfig
from repro.watchdog import HangError, Watchdog, WatchdogConfig
from repro.train.watchdog import StepWatchdog

MAX_LEN = 48
MAX_NEW = 4
LENS = [3, 7, 5]

_STATE = {}


def _setup():
    if not _STATE:
        cfg = configs.smoke_config(configs.get_config("minicpm-2b"))
        cfg = dataclasses.replace(cfg, attention_impl="naive")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        _STATE["m"] = (cfg, model, params)
    return _STATE["m"]


def _prompts(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, size=(n,)) for n in LENS]


# -- metrics layer -----------------------------------------------------------

def test_histogram_bucket_boundaries():
    """le semantics: a value EQUAL to a bound lands in that bound's bucket;
    export is cumulative."""
    r = Registry()
    h = r.histogram("lat_s", buckets=(1.0, 2.0))
    for v in (0.5, 1.0, 2.0, 2.5):
        h.observe(v)
    snap = r.snapshot()["lat_s"]["series"][0]
    assert snap["count"] == 4 and snap["sum"] == pytest.approx(6.0)
    by_le = {b["le"]: b["count"] for b in snap["buckets"]}
    assert by_le == {1.0: 2, 2.0: 3, "+Inf": 4}


def test_histogram_quantile_exact_then_interpolated():
    r = Registry()
    h = r.histogram("q_s", buckets=(1.0, 2.0, 4.0), reservoir=100)
    vals = [0.1 * i for i in range(1, 42)]
    for v in vals:
        h.observe(v)
    for q in (0.0, 0.25, 0.5, 0.99, 1.0):
        assert h.quantile(q) == pytest.approx(
            float(np.percentile(vals, 100 * q)))
    # past the reservoir the quantile degrades to bucket interpolation but
    # must stay inside the containing bucket
    tiny = r.histogram("tiny_s", buckets=(1.0, 2.0, 4.0), reservoir=4)
    for v in (0.5, 1.5, 1.6, 3.0, 3.5):
        tiny.observe(v)
    assert 2.0 <= tiny.quantile(0.9) <= 4.0


def test_label_cardinality_guard():
    r = Registry()
    for bad in ("rid", "request_id", "req_id"):
        with pytest.raises(CardinalityError):
            r.counter(f"x_{bad}_total", "t", (bad,))
    c = r.counter("caps_total", "t", ("k",))
    for i in range(c.max_label_sets):
        c.labels(k=str(i)).inc()
    with pytest.raises(CardinalityError):
        c.labels(k="one-too-many")


def test_unbound_labeled_family_rejects_observations():
    r = Registry()
    with pytest.raises(ValueError, match="bind with .labels"):
        r.counter("fam_total", "t", ("phase",)).inc()


def test_registry_idempotent_reregistration():
    r = Registry()
    assert r.counter("same_total", "t") is r.counter("same_total", "t")
    with pytest.raises(ValueError):
        r.gauge("same_total")


def test_prometheus_round_trip():
    r = Registry()
    r.counter("req_total", "requests", ("replica",)).labels(replica="0").inc(3)
    r.gauge("depth").set(2.5)
    h = r.histogram("lat_s", "latency", ("phase",), buckets=(0.01, 0.1))
    h.labels(phase="decode").observe(0.01)
    h.labels(phase="decode").observe(0.5)
    parsed = parse_prometheus(r.to_prometheus())
    assert parsed["req_total"][(("replica", "0"),)] == 3.0
    assert parsed["depth"][()] == 2.5
    dec = (("phase", "decode"),)
    assert parsed["lat_s_count"][dec] == 2.0
    assert parsed["lat_s_sum"][dec] == pytest.approx(0.51)
    assert parsed["lat_s_bucket"][(("phase", "decode"), ("le", "0.01"))] == 1.0
    assert parsed["lat_s_bucket"][(("phase", "decode"), ("le", "+Inf"))] == 2.0


def test_snapshot_deterministic_under_fake_clock():
    """Byte-identical snapshots from identical FakeClock-timed runs — the
    metrics layer itself never reads a clock."""
    def build():
        clock = FakeClock()
        r = Registry()
        t = Tracer(clock=clock)
        h = r.histogram("work_s", buckets=(0.1, 1.0))
        for i in range(5):
            s = t.start("step", rid=str(i % 2))
            clock.advance(0.05 * (i + 1))
            t.end(s)
            h.observe(s.duration)
            r.counter("steps_total").inc()
        return json.dumps(r.snapshot(), sort_keys=True), t.to_jsonl()
    assert build() == build()


def test_metrics_http_endpoint_scrapes():
    import urllib.request
    r = Registry()
    r.counter("scrape_total").inc(7)
    srv = start_metrics_server(r, port=0)
    try:
        port = srv.server_address[1]
        txt = urllib.request.urlopen(
            f"http://127.0.0.1:{port}/metrics").read().decode()
        assert parse_prometheus(txt)["scrape_total"][()] == 7.0
        blob = urllib.request.urlopen(
            f"http://127.0.0.1:{port}/metrics.json").read()
        assert json.loads(blob)["scrape_total"]["series"][0]["value"] == 7.0
    finally:
        srv.shutdown()


# -- trace layer -------------------------------------------------------------

def test_tracer_ring_bounded():
    t = Tracer(clock=FakeClock(), capacity=8)
    for i in range(20):
        t.end(t.start("s", rid=str(i)))
    assert len(t.spans) == 8
    assert t.dropped == 12


def test_span_tree_and_export_round_trip(tmp_path):
    clock = FakeClock()
    t = Tracer(clock=clock)
    root = t.start("request", rid="7")
    a = t.start("queued", parent=root.sid, rid="7")
    clock.advance(0.01)
    t.end(a)
    b = t.start("decoding", parent=root.sid, rid="7")
    clock.advance(0.02)
    t.end(b)
    t.end(root, outcome="done")

    tree = t.span_tree("7")
    assert tree["name"] == "request" and tree["attrs"]["outcome"] == "done"
    assert [c["name"] for c in tree["children"]] == ["queued", "decoding"]

    p = tmp_path / "trace.jsonl"
    t.write(str(p))
    assert tree_from_spans(load_jsonl(str(p)), "7") == tree

    chrome = t.to_chrome_trace()
    names = {e["name"] for e in chrome["traceEvents"]}
    assert {"request", "queued", "decoding", "thread_name"} <= names


# -- profiler ----------------------------------------------------------------

def test_profiler_fip_multiplier_accounting():
    """Eq. 1 effective ops; Eqs. 5/7 multiplier counts (FIP/FFIP halve the
    multiplies for even K; baseline and odd-K fall back to m*k*n)."""
    r = Registry()
    p = obs_profile.KernelProfiler(r)
    p.record_gemm(16, 8, 12, algo="ffip", dtype="float32")
    p.record_gemm(16, 8, 12, algo="baseline", dtype="float32")
    def mults(algo):
        return r.get("repro_kernel_mults_total").labels(
            kernel="gemm", algo=algo, dtype="float32").value
    assert r.get("repro_kernel_flops_total").labels(
        kernel="gemm", algo="ffip", dtype="float32").value == 2880.0
    assert mults("ffip") == 880.0          # (mkn + mk + nk) / 2
    assert mults("baseline") == 1536.0     # mkn
    # traced calls count compilations, not work
    p.record_gemm(16, 8, 12, algo="ffip", dtype="float32", traced=True)
    assert r.get("repro_kernel_traces_total").labels(
        kernel="gemm", algo="ffip", dtype="float32").value == 1.0
    assert r.get("repro_kernel_dispatches_total").labels(
        kernel="gemm", algo="ffip", dtype="float32").value == 1.0


def test_kernel_hook_splits_dispatch_from_trace():
    """The real kernels.ops.matmul call site: an eager call is a dispatch;
    the same call under jax.jit is a compile-side trace."""
    from repro.kernels import ops
    prev = obs_profile.set_profiler(obs_profile.KernelProfiler(Registry()))
    try:
        prof = obs_profile.get_profiler()
        a = np.ones((16, 8), np.float32)
        b = np.ones((8, 16), np.float32)
        np.testing.assert_allclose(
            ops.matmul(jax.numpy.asarray(a), jax.numpy.asarray(b),
                       algo="ffip", interpret=True), a @ b, rtol=1e-6)
        lab = dict(kernel="gemm", algo="ffip", dtype="float32")
        assert prof.dispatches.labels(**lab).value == 1.0

        jax.jit(lambda x, y: ops.matmul(x, y, algo="ffip", interpret=True))(
            jax.numpy.asarray(a), jax.numpy.asarray(b)).block_until_ready()
        assert prof.traces.labels(**lab).value == 1.0
        assert prof.dispatches.labels(**lab).value == 1.0   # unchanged
    finally:
        obs_profile.set_profiler(prev)


def test_compile_snapshot_unifies_legacy_counters():
    snap = obs_profile.compile_snapshot()
    assert set(snap) == {"derived_cache", "schedule_cache", "measure"}
    assert "timed_candidates" in snap["measure"]


# -- watchdog single-source telemetry ----------------------------------------

def test_train_watchdog_shim_cannot_diverge():
    """The train shim is a pure alias: shared methods verbatim, no state of
    its own beyond the loop label default — double-bookkeeping is dead."""
    assert StepWatchdog.observe is Watchdog.observe
    assert StepWatchdog.check_hang is Watchdog.check_hang
    assert set(vars(StepWatchdog)) <= {"__init__", "__doc__", "__module__",
                                       "__qualname__", "__firstlineno__",
                                       "__static_attributes__"}


def test_watchdog_counters_labeled_by_loop():
    r = Registry()
    clock = FakeClock()
    cfg = WatchdogConfig(threshold=2.0, consecutive_to_act=2,
                         hang_timeout_s=5.0)
    train = StepWatchdog(cfg, clock=clock, registry=r)
    serve = Watchdog(cfg, clock=clock, registry=r, loop="serve")
    for dog in (train, serve):
        dog.observe(0, 1.0)
        dog.observe(1, 10.0)            # straggler
    straggler = r.get("watchdog_straggler_flags_total")
    assert straggler.labels(loop="train").value == 1.0
    assert straggler.labels(loop="serve").value == 1.0
    clock.advance(10.0)
    with pytest.raises(HangError):
        serve.check_hang()
    assert r.get("watchdog_deadman_trips_total").labels(
        loop="serve").value == 1.0
    assert len(train.events) <= train.events.maxlen


# -- serving integration -----------------------------------------------------

def test_batcher_clock_injection_and_fresh_stats_contract():
    """All batcher wall-clock reads go through the injected clock (a frozen
    FakeClock yields all-zero timings), and run_until_drained resets stats
    per drain while the obs registry + compile counts stay cumulative."""
    cfg, model, params = _setup()
    assert "perf_counter" not in inspect.getsource(
        __import__("repro.serve.batcher", fromlist=["batcher"]))
    clock = FakeClock()
    reg = Registry()
    srv = BatchServer(model, batch_slots=2, max_len=MAX_LEN, clock=clock,
                      registry=reg)
    prompts = _prompts(cfg)
    srv.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=MAX_NEW,
                       eos_id=-1))
    done = srv.run_until_drained(params)
    assert len(done) == 1
    first = dict(srv.stats)
    assert first["prefill_s"] == 0.0 and first["decode_s"] == 0.0
    assert done[0].t_done == done[0].t_submit == 0.0

    srv.submit(Request(rid=1, prompt=prompts[1], max_new_tokens=MAX_NEW,
                       eos_id=-1))
    srv.run_until_drained(params)
    second = dict(srv.stats)
    # per-drain: the second dict describes ONLY the second request
    assert second["prefill_tokens"] == len(prompts[1])
    assert second["decode_tokens"] == MAX_NEW - 1
    # cumulative surfaces: registry counters span both drains
    tok = reg.get("serve_tokens_total")
    assert tok.labels(replica="solo", phase="prefill").value == \
        len(prompts[0]) + len(prompts[1])
    e2e = reg.get("serve_request_e2e_seconds").labels(replica="solo")
    assert e2e.count == 2 and e2e.quantile(0.99) == 0.0
    assert srv.compiles["prefill"] >= 1     # never reset by a drain
    assert reg.get("serve_compiles_total").labels(
        replica="solo", phase="prefill").value == srv.compiles["prefill"]


def test_batcher_events_ring_is_bounded():
    """The legacy ``events`` view is reconstructed from the span ring, so a
    long-running server can no longer leak dispatch tuples without bound."""
    cfg, model, params = _setup()
    srv = BatchServer(model, batch_slots=2, max_len=MAX_LEN, paged=True,
                      page_size=4, num_pages=24, prefill_chunk=4,
                      trace_capacity=6)
    for i, p in enumerate(_prompts(cfg)):
        srv.submit(Request(rid=i, prompt=p, max_new_tokens=MAX_NEW,
                           eos_id=-1))
    srv.run_until_drained(params)
    assert len(srv.tracer.spans) <= 6 and srv.tracer.dropped > 0
    ev = srv.events
    assert ev, "events view empty"
    for e in ev:
        assert e[0] in ("prefill_chunk", "decode")
        if e[0] == "prefill_chunk":
            _, rid, start, end = e
            assert isinstance(rid, int) and 0 <= start < end
        else:
            assert isinstance(e[1], tuple)


def test_router_span_tree_for_retried_faulted_request():
    """ISSUE 9 acceptance: one request, retried across two replicas under a
    fault plan, reconstructs to a SINGLE span tree — root request span,
    lifecycle phase children in order, the retry event carrying the typed
    error, and both attempts' replica assignments visible."""
    cfg, model, params = _setup()
    servers = [BatchServer(model, batch_slots=2, max_len=MAX_LEN)
               for _ in range(2)]
    plan = FaultPlan([FaultSpec(kind="raise", replica=0, at_dispatch=0,
                                duration=2)], seed=3)
    rt = ReplicaRouter(servers, params, fault_plan=plan, clock=FakeClock(),
                       cfg=RouterConfig(step_timeout_s=5.0, quarantine_s=0.2,
                                        max_retries=4))
    for i, p in enumerate(_prompts(cfg)):
        rt.submit(Request(rid=i, prompt=p, max_new_tokens=MAX_NEW, eos_id=-1))
    recs = rt.drive(max_ticks=2000)
    assert all(r.state is Lifecycle.DONE for r in recs.values())
    assert rt.stats["retries"] >= 1

    # every rid has exactly one complete tree
    for rid in map(str, range(len(LENS))):
        spans = rt.tracer.completed(rid)
        roots = [s for s in spans if s.name == "request"]
        assert len(roots) == 1 and roots[0].t1 is not None, rid
        tree = rt.tracer.span_tree(rid)
        assert tree["attrs"]["outcome"] == "done"
        assert tree["children"], rid

    retried = [s.rid for s in rt.tracer.spans if s.name == "retry"]
    assert retried, "fault plan produced no retry event"
    tree = rt.tracer.span_tree(retried[0])
    flat = tree["children"]
    kinds = [c["name"] for c in flat]
    assert kinds[0] == "queued" and "retry" in kinds
    retry = next(c for c in flat if c["name"] == "retry")
    assert retry["attrs"]["error"] == "ReplicaFailedError"
    attempts = {c["attrs"].get("attempt") for c in flat}
    assert {0, 1} <= attempts
    # mirrored stats: every router stat equals its obs counter series
    for kind, v in rt.stats.items():
        got = rt.registry.get("router_events_total").labels(kind=kind).value
        assert got == v, (kind, got, v)


def test_router_e2e_histogram_feeds_quantiles():
    cfg, model, params = _setup()
    reg = Registry()
    servers = [BatchServer(model, batch_slots=2, max_len=MAX_LEN,
                           registry=reg)]
    rt = ReplicaRouter(servers, params, clock=FakeClock(), registry=reg,
                       cfg=RouterConfig(step_timeout_s=5.0))
    for i, p in enumerate(_prompts(cfg)):
        rt.submit(Request(rid=i, prompt=p, max_new_tokens=MAX_NEW, eos_id=-1))
    recs = rt.drive(max_ticks=2000)
    lat = sorted(r.t_done - r.t_submit for r in recs.values())
    h = reg.get("router_request_e2e_seconds")
    assert h.count == len(LENS)
    assert h.quantile(0.5) == pytest.approx(float(np.percentile(lat, 50)))
