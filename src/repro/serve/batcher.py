"""Serving runtime: slot-based continuous batching over prefill/decode steps.

A fixed pool of B slots; requests occupy a slot, prefill writes their prompt
into the slot's cache region, then all active slots decode in lockstep (one
jitted decode per step — the dry-run's ``decode_*`` cells are exactly this
step). Finished slots (EOS or max_tokens) are immediately refilled from the
queue — the standard continuous-batching scheme (vLLM-style, simplified to
fixed-shape slots so XLA shapes stay static).
"""
from __future__ import annotations

import dataclasses
import queue
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    eos_id: int = -1              # -1: never
    out_tokens: Optional[List[int]] = None


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0                  # tokens currently in this slot's cache rows
    remaining: int = 0


class BatchServer:
    """Single-host reference implementation (the multi-pod serve path lowers
    the same decode step through launch/dryrun.py)."""

    def __init__(self, model: Model, *, batch_slots: int, max_len: int,
                 greedy: bool = True):
        self.model = model
        self.b = batch_slots
        self.max_len = max_len
        self.cache = model.init_cache(batch_slots, max_len)
        self.slots = [_Slot() for _ in range(batch_slots)]
        self.queue: "queue.Queue[Request]" = queue.Queue()
        self._decode = jax.jit(model.decode_step, donate_argnums=(2,))
        # per-slot prefill: batch-1 prefill into the slot's cache rows
        self._prefill_one = jax.jit(self._prefill_impl, donate_argnums=(2,))

    def _prefill_impl(self, params, tokens, cache, slot_idx):
        sub = jax.tree.map(lambda c: c, cache)  # alias; updates sliced per slot

        # run a batch-1 forward and scatter its cache rows into slot_idx
        one_cache = self.model.init_cache(1, self.max_len)
        new_one, logits = self.model.prefill(params, tokens, one_cache)

        def put(full, one):
            # batch axis: where the full cache has b slots and the batch-1
            # cache has 1 (never confuses a stacked layer dim that equals b)
            axis = next(i for i, (sf, so) in
                        enumerate(zip(full.shape, one.shape))
                        if sf == self.b and so == 1)
            idx = [slice(None)] * full.ndim
            idx[axis] = slot_idx
            return full.at[tuple(idx)].set(one.squeeze(axis=axis).astype(full.dtype))

        return jax.tree.map(put, sub, new_one), logits

    def submit(self, req: Request):
        req.out_tokens = []
        self.queue.put(req)

    def _admit(self, params):
        for i, slot in enumerate(self.slots):
            if slot.req is None:
                try:
                    req = self.queue.get_nowait()
                except queue.Empty:
                    return
                toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
                self.cache, logits = self._prefill_one(
                    params, toks, self.cache, i)
                first = int(jnp.argmax(logits[0]))
                req.out_tokens.append(first)
                slot.req = req
                slot.pos = len(req.prompt) + 1
                slot.remaining = req.max_new_tokens - 1

    def step(self, params) -> int:
        """One lockstep decode over all active slots; returns #active."""
        self._admit(params)
        active = [i for i, s in enumerate(self.slots) if s.req is not None]
        if not active:
            return 0
        last = np.zeros((self.b, 1), np.int32)
        for i in active:
            last[i, 0] = self.slots[i].req.out_tokens[-1]
        # NOTE: slots decode against their own pos; we use per-slot masks via
        # max pos — positions beyond a slot's pos hold zeros (masked by cache
        # validity). Single shared pos = max(pos) keeps shapes static.
        pos = max(self.slots[i].pos for i in active)
        self.cache, logits = self._decode(params, jnp.asarray(last),
                                          self.cache,
                                          jnp.asarray(pos, jnp.int32))
        for i in active:
            slot = self.slots[i]
            nxt = int(jnp.argmax(logits[i]))
            slot.req.out_tokens.append(nxt)
            slot.pos += 1
            slot.remaining -= 1
            if slot.remaining <= 0 or nxt == slot.req.eos_id:
                slot.req = None   # slot freed -> next _admit refills it
        return len(active)

    def run_until_drained(self, params, *, max_steps: int = 10_000) -> List[Request]:
        done: List[Request] = []
        seen: Dict[int, Request] = {}
        for _ in range(max_steps):
            for s in self.slots:
                if s.req is not None:
                    seen[s.req.rid] = s.req
            if self.step(params) == 0 and self.queue.empty():
                break
        return list(seen.values())
