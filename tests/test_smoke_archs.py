"""Per-architecture smoke tests: reduced same-family config, one forward +
one train-grad step on CPU, asserting output shapes and no NaNs (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.model import build_model
from repro.models import frontends

ARCH_IDS = sorted(configs.ARCHS)


def make_batch(cfg, key, batch=2, seq=16):
    kt, kf = jax.random.split(key)
    b = {
        "tokens": jax.random.randint(kt, (batch, seq), 0, cfg.vocab),
        "labels": jax.random.randint(kf, (batch, seq), 0, cfg.vocab),
    }
    if cfg.encoder is not None:
        b["frames"] = frontends.audio_frames_stub(kf, batch, cfg)
    if cfg.frontend == "vision":
        b["patches"] = frontends.vision_patches_stub(kf, batch, cfg)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_grad(arch):
    cfg = configs.smoke_config(configs.get_config(arch))
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = make_batch(cfg, key)

    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert np.isfinite(float(loss)), (arch, loss)
    flat = jax.tree_util.tree_leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g))) for g in flat), arch
    # at least one nonzero grad per top-level group
    norms = jax.tree.map(lambda g: float(jnp.sum(jnp.abs(g))), grads)
    total = sum(jax.tree_util.tree_leaves(norms))
    assert total > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode_consistency(arch):
    """Prefill+decode equals full forward on the same tokens (cache paths)."""
    cfg = configs.smoke_config(configs.get_config(arch))
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    batch, seq = 2, 8
    toks = jax.random.randint(key, (batch, seq), 0, cfg.vocab)
    kwargs = {}
    if cfg.encoder is not None:
        kwargs["frames"] = frontends.audio_frames_stub(key, batch, cfg)
    if cfg.frontend == "vision":
        kwargs["patches"] = frontends.vision_patches_stub(key, batch, cfg)

    # full forward logits at last position
    from repro.models import transformer as T
    hidden, _, _ = T.forward(params, toks, cfg, **{k: v for k, v in kwargs.items()})
    full_logits = T.logits_fn(params, hidden[:, -1:], cfg)[:, 0]

    # prefill seq-1 tokens, decode the last one
    n_prefix = cfg.frontend_tokens if cfg.frontend == "vision" else 0
    cache = model.init_cache(batch, max_len=seq + n_prefix + 4)
    cache, _ = model.prefill(params, toks[:, :-1], cache, **kwargs)
    cache, step_logits = model.decode_step(
        params, toks[:, -1:], cache, jnp.asarray(n_prefix + seq - 1, jnp.int32))

    np.testing.assert_allclose(np.asarray(step_logits), np.asarray(full_logits),
                               rtol=2e-2, atol=2e-2)


def test_param_count_analytic_vs_actual():
    """cfg.param_count() tracks actual init sizes within 10% (smoke configs)."""
    for arch in ARCH_IDS:
        cfg = configs.smoke_config(configs.get_config(arch))
        model = build_model(cfg)
        params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        actual = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params))
        est = cfg.param_count()
        assert 0.5 < est / actual < 2.0, (arch, est, actual)
