"""Roofline-term extraction from compiled dry-run artifacts.

compute/memory terms come from compiled.cost_analysis(); the collective term
is parsed out of the post-SPMD optimized HLO (collective ops do not appear in
cost_analysis). Bytes-on-wire model per op (ring algorithms, group size N):

    all-gather:          out_bytes * (N-1)/N        (out is the gathered buf)
    reduce-scatter:      out_bytes * (N-1)          (operand = out * N)
    all-reduce:          2 * bytes * (N-1)/N        (RS + AG phases)
    all-to-all:          bytes * (N-1)/N
    collective-permute:  bytes
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(?)((?:[a-z0-9]+\[[0-9,]*\][^\s]*(?:,\s*)?)+)\)?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9,\s]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _tensor_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))   # [ngroups, group_size]
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_by_kind: Dict[str, float]

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_WHILE_RE = re.compile(r"while\(.*?\), condition=%?([\w\.\-]+), body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    entry: Optional[str] = None
    for line in hlo_text.splitlines():
        m = _COMP_HDR_RE.match(line.strip()) if "{" in line and "->" in line else None
        if m and cur is None:
            cur = m.group(1)
            comps[cur] = []
            if line.strip().startswith("ENTRY"):
                entry = cur
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    if entry is not None:
        comps["__entry__"] = comps[entry]
    return comps


def _line_collective(line: str):
    m = _COLL_RE.search(line)
    if not m or "-done(" in line:
        return None
    type_str, kind = m.group(1), m.group(2)
    size = _tensor_bytes(type_str)
    n = _group_size(line)
    if kind == "all-gather":
        wire = size * (n - 1) / max(n, 1)
    elif kind == "reduce-scatter":
        wire = size * (n - 1)
    elif kind == "all-reduce":
        wire = 2 * size * (n - 1) / max(n, 1)
    elif kind == "all-to-all":
        wire = size * (n - 1) / max(n, 1)
    else:
        wire = float(size)
    return kind, wire


def _trip_count(cond_lines: List[str]) -> int:
    """Loop bound heuristic: the largest integer constant in the while cond."""
    best = 1
    for line in cond_lines:
        for c in _CONST_RE.findall(line):
            best = max(best, int(c))
    return best


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Modeled bytes-on-wire per collective kind, WHILE-LOOP AWARE: collectives
    inside scan/while bodies are multiplied by the loop trip count (XLA's own
    cost analysis counts them once, which silently hides per-layer traffic)."""
    comps = _split_computations(hlo_text)
    memo: Dict[str, Tuple[Dict[str, int], Dict[str, float]]] = {}

    def walk(name: str, stack=()) -> Tuple[Dict[str, int], Dict[str, float]]:
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return {}, {}
        counts: Dict[str, int] = {}
        by_kind: Dict[str, float] = {}
        for line in comps[name]:
            hit = _line_collective(line)
            if hit:
                kind, wire = hit
                counts[kind] = counts.get(kind, 0) + 1
                by_kind[kind] = by_kind.get(kind, 0.0) + wire
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trips = _trip_count(comps.get(cond, []))
                sub_counts, sub_bytes = walk(body, stack + (name,))
                for k, v in sub_counts.items():
                    counts[k] = counts.get(k, 0) + v * trips
                for k, v in sub_bytes.items():
                    by_kind[k] = by_kind.get(k, 0.0) + v * trips
        memo[name] = (counts, by_kind)
        return memo[name]

    counts, by_kind = walk("__entry__")
    if not counts and not by_kind:
        # fallback: flat scan over all lines (entry parse failed)
        for line in hlo_text.splitlines():
            hit = _line_collective(line)
            if hit:
                kind, wire = hit
                counts[kind] = counts.get(kind, 0) + 1
                by_kind[kind] = by_kind.get(kind, 0.0) + wire
    return CollectiveStats(counts=counts, bytes_by_kind=by_kind)


def remat_duplication(hlo_text: str) -> float:
    """Heuristic recompute indicator: ratio of dot/convolution ops to unique
    fusion-site names (duplicate op base-names signal remat-inserted clones)."""
    names = re.findall(r"%([a-z_\.\-0-9]+) = [a-z0-9]+\[", hlo_text)
    base = [n.rsplit(".", 1)[0] for n in names]
    if not base:
        return 0.0
    return 1.0 - len(set(base)) / len(base)


def roofline_report(flops: float, hlo_bytes: float, coll: CollectiveStats,
                    chips: int, *, peak_flops: float = 197e12,
                    hbm_bw: float = 819e9, ici_bw: float = 50e9,
                    model_flops: Optional[float] = None) -> dict:
    compute_s = flops / (chips * peak_flops)
    memory_s = hlo_bytes / (chips * hbm_bw)
    collective_s = coll.total_bytes / (chips * ici_bw)
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    out = dict(terms)
    out.update(
        bottleneck=dominant,
        step_time_lower_bound_s=bound,
        # fraction of the step-time bound that is *useful compute*: 1.0 means
        # perfectly compute-bound (the roofline optimum for a given algorithm)
        roofline_fraction=(compute_s / bound) if bound else 0.0,
        collective_counts=coll.counts,
        collective_bytes=coll.total_bytes,
    )
    if model_flops:
        out["model_flops"] = model_flops
        out["useful_flops_ratio"] = model_flops / flops if flops else 0.0
    return out
