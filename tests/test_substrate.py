"""Substrate tests: data determinism, checkpoint atomicity + elastic reshard,
optimizer/WSD, gradient compression, watchdog, serving batcher."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.ckpt.manager import CheckpointManager
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.models.model import build_model
from repro.optim import adamw
from repro.train.watchdog import StepWatchdog, WatchdogConfig
from repro.serve.batcher import BatchServer, Request


# --- data --------------------------------------------------------------------

def test_data_deterministic_and_resumable():
    cfg = DataConfig(global_batch=4, seq_len=32, vocab=128, seed=7)
    ds = SyntheticLM(cfg)
    a = ds.batch_at(5)
    b = ds.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(ds.batch_at(6)["tokens"], a["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_data_host_sharding_partitions_batch():
    full = SyntheticLM(DataConfig(global_batch=4, seq_len=16, vocab=64,
                                  n_hosts=1, host_id=0))
    h0 = SyntheticLM(DataConfig(global_batch=4, seq_len=16, vocab=64,
                                n_hosts=2, host_id=0))
    h1 = SyntheticLM(DataConfig(global_batch=4, seq_len=16, vocab=64,
                                n_hosts=2, host_id=1))
    assert h0.batch_at(0)["tokens"].shape[0] == 2
    assert h1.batch_at(0)["tokens"].shape[0] == 2
    # different hosts generate different rows
    assert not np.array_equal(h0.batch_at(0)["tokens"], h1.batch_at(0)["tokens"])


def test_prefetcher_overlaps_and_orders():
    ds = SyntheticLM(DataConfig(global_batch=2, seq_len=8, vocab=32))
    pf = Prefetcher(ds, start_step=3)
    steps = [next(pf)[0] for _ in range(4)]
    pf.close()
    assert steps == [3, 4, 5, 6]


# --- checkpointing -------------------------------------------------------------

def _tree(key, scale=1.0):
    k1, k2 = jax.random.split(key)
    return {"w": jax.random.normal(k1, (8, 16)) * scale,
            "b": {"x": jax.random.normal(k2, (4,)) * scale}}


def test_ckpt_roundtrip_and_keepN(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    t0 = _tree(jax.random.PRNGKey(0))
    for s in (10, 20, 30):
        mgr.save(s, t0, extra={"data_step": s})
    assert mgr.all_steps() == [20, 30]       # keep-2 GC
    restored, extra = mgr.restore(t0)
    assert extra["data_step"] == 30
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), t0, restored)


def test_ckpt_atomicity_interrupted_write_ignored(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3, async_save=False)
    t0 = _tree(jax.random.PRNGKey(1))
    mgr.save(1, t0)
    # simulate a crash mid-write: stale .tmp dir with garbage
    broken = tmp_path / "step_00000002.tmp"
    broken.mkdir()
    (broken / "arr_00000.npy").write_bytes(b"garbage")
    assert mgr.latest_step() == 1            # .tmp never counts
    restored, _ = mgr.restore(t0)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), t0, restored)
    mgr.save(3, t0)                          # next save GCs the .tmp
    assert not broken.exists()


def test_ckpt_elastic_reshard(tmp_path):
    """Save on mesh A (1x1), restore with explicit shardings on mesh B (2x...)
    if >1 device, else same mesh — the reshard path is exercised either way."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(tmp_path, async_save=False)
    t0 = _tree(jax.random.PRNGKey(2))
    mgr.save(5, t0)
    n = jax.device_count()
    mesh = jax.make_mesh((n,), ("data",))
    shard = {"w": NamedSharding(mesh, P("data" if 8 % n == 0 else None)),
             "b": {"x": NamedSharding(mesh, P())}}
    restored, _ = mgr.restore(t0, shardings=shard)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), t0, restored)
    assert restored["w"].sharding == shard["w"]


# --- optimizer -----------------------------------------------------------------

def test_adamw_decreases_quadratic_loss():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, schedule="const",
                            warmup_steps=0, grad_clip=0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = adamw.update(cfg, g, state, params)
    assert float(loss(params)) < 1e-3


def test_wsd_schedule_shape():
    cfg = adamw.AdamWConfig(lr=1.0, schedule="wsd", warmup_steps=10,
                            total_steps=100, decay_frac=0.2, min_lr_frac=0.1)
    lr = lambda s: float(adamw.schedule_lr(cfg, jnp.asarray(s)))
    assert lr(0) == 0.0
    assert lr(5) == pytest.approx(0.5)       # warmup
    assert lr(50) == pytest.approx(1.0)      # stable plateau (the WSD point)
    assert lr(79) == pytest.approx(1.0, abs=0.02)
    assert lr(100) == pytest.approx(0.1, rel=0.05)   # decayed tail
    # cosine reference decays earlier
    ccfg = adamw.AdamWConfig(lr=1.0, schedule="cosine", warmup_steps=10,
                             total_steps=100)
    assert float(adamw.schedule_lr(ccfg, jnp.asarray(50))) < 0.7


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_property_ef_compression_unbiased_over_time(seed):
    """Error-feedback int8 compression: accumulated deq error stays bounded
    (the residual does not drift), so long-run updates are unbiased."""
    key = jax.random.PRNGKey(seed)
    g = {"w": jax.random.normal(key, (64,))}
    err = jax.tree.map(lambda x: jnp.zeros_like(x), g)
    total_true = jnp.zeros((64,))
    total_sent = jnp.zeros((64,))
    for i in range(30):
        gi = jax.tree.map(lambda x: x * (1 + 0.01 * i), g)
        q, s, err = adamw.ef_compress_tree(gi, err)
        total_true = total_true + gi["w"]
        total_sent = total_sent + adamw.decompress_int8(q["w"], s["w"])
    resid = float(jnp.max(jnp.abs(total_true - total_sent)))
    scale = float(jnp.max(jnp.abs(total_true))) + 1e-6
    assert resid / scale < 0.05   # bounded by one quantization step, not 30


# --- watchdog -------------------------------------------------------------------

def test_watchdog_flags_stragglers():
    fired = []
    dog = StepWatchdog(WatchdogConfig(threshold=2.0, consecutive_to_act=2),
                       on_straggler=lambda s, dt, ema: fired.append(s))
    for s in range(10):
        dog.observe(s, 1.0)
    dog.observe(10, 5.0)
    assert not fired
    dog.observe(11, 5.0)
    assert fired == [11]
    assert dog.ema == pytest.approx(1.0, rel=0.01)   # outliers excluded from EMA


# --- serving batcher ---------------------------------------------------------

def test_batch_server_continuous_batching():
    cfg = configs.smoke_config(configs.get_config("minicpm-2b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    srv = BatchServer(model, batch_slots=2, max_len=32)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=(4,)),
                    max_new_tokens=3) for i in range(5)]
    for r in reqs:
        srv.submit(r)
    done = srv.run_until_drained(params)
    assert len(done) == 5
    for r in done:
        assert len(r.out_tokens) == 3        # exact token budget

    # batched output == sequential single-slot output (slot independence)
    srv2 = BatchServer(model, batch_slots=1, max_len=32)
    srv2.submit(Request(rid=99, prompt=reqs[0].prompt, max_new_tokens=3))
    solo = srv2.run_until_drained(params)[0]
    assert solo.out_tokens == done[0].out_tokens
