"""Modality frontend STUBS (per the brief: audio/vision entries specify the
transformer backbone only; input_specs provides precomputed frame/patch
embeddings). These helpers generate synthetic stub embeddings for tests and
document the real frontends they stand in for."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def audio_frames_stub(key, batch: int, cfg: ModelConfig, n_frames: int = 0):
    """Whisper: stands in for the 2x conv1d + GELU mel-spectrogram frontend
    (stride-2 conv halves 3000 mel frames to 1500)."""
    n = n_frames or cfg.encoder.n_frames
    return jax.random.normal(key, (batch, n, cfg.d_model), cfg.dtype) * 0.02


def vision_patches_stub(key, batch: int, cfg: ModelConfig, n_patches: int = 0):
    """Pixtral: stands in for the Pixtral-ViT patch encoder + adapter
    (1024x1024 image -> 16x16 patches -> adapter to backbone d_model)."""
    n = n_patches or cfg.frontend_tokens
    return jax.random.normal(key, (batch, n, cfg.d_model), cfg.dtype) * 0.02
