"""repro.prepare artifact contract: save/load roundtrip, the zero-recompute
warm-start guarantee (counter-proved), y-delta memo seeding, schedule-slice
portability (foreign device_kind drops with a one-time warning), corruption
quarantine, and the thin-wrapper equivalence of the legacy prep paths."""
import logging

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, prepare, tune
from repro.core import fip
from repro.kernels import compat
from repro.kernels.ffip_gemm import Y_TAG, ffip_gemm
from repro.models.model import build_model
from repro.prepare import artifact as art
from repro.serve.batcher import BatchServer, Request

MAX_LEN = 48


def _setup(arch="minicpm-2b", seed=0):
    cfg = configs.smoke_config(configs.get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


def _serve_tokens(model, params, prompts, *, quantized=False, prepared=None):
    srv = BatchServer(model, batch_slots=2, max_len=MAX_LEN,
                      quantized=quantized, prepared=prepared)
    for i, p in enumerate(prompts):
        srv.submit(Request(rid=i, prompt=p, max_new_tokens=4))
    done = srv.run_until_drained(params)
    return {r.rid: tuple(r.out_tokens) for r in done}


def _tiny_params():
    k = jax.random.PRNGKey(3)
    return {"lin": {"w": jax.random.normal(k, (8, 6)), "b": jnp.zeros((6,))}}


# -- roundtrip + zero recompute ---------------------------------------------

def test_roundtrip_bit_identical_and_zero_recompute(tmp_path):
    _, _, params = _setup()
    pm = prepare.prepare_lm(params, quantized=True)
    assert pm.kind == "lm" and pm.quantized
    assert pm.derived, "stacked dense weights should yield y-deltas"
    out = pm.save(tmp_path / "art")
    assert (out / "manifest.json").exists()

    pm2 = prepare.load(tmp_path / "art")
    assert pm2.recomputed == 0, pm2.recompute_report()
    a = jax.tree.leaves(pm.params)
    b = jax.tree.leaves(pm2.params)
    assert len(a) == len(b)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # derived y-deltas survive too
    assert set(pm2.derived) == set(pm.derived)


def test_python_scalars_survive_roundtrip(tmp_path):
    """Conv q entries carry static-geometry python ints (k_real/kh/kw/groups)
    that must NOT come back as 0-d arrays — they drive kernel geometry."""
    pm = art.PreparedModel(
        kind="lm", device="cpu", quantized=False,
        params={"meta": {"k_real": 27, "pad": (1, 2), "name": "c1",
                         "flag": True}, "w": jnp.ones((4, 4))})
    pm.save(tmp_path / "a")
    p = prepare.load(tmp_path / "a").params
    assert p["meta"]["k_real"] == 27 and type(p["meta"]["k_real"]) is int
    assert p["meta"]["pad"] == (1, 2) and type(p["meta"]["pad"]) is tuple
    assert p["meta"]["flag"] is True
    assert p["meta"]["name"] == "c1"


def test_loaded_artifact_serves_identically_warm(tmp_path):
    """The tentpole contract end to end: tokens from a server fed a loaded
    artifact match a cold in-process prep, with ZERO offline transforms
    recomputed after load (quantize / y-encode / tune counters frozen)."""
    cfg, model, params = _setup()
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab, size=(n,)) for n in (4, 7, 3)]

    cold = _serve_tokens(model, params, prompts, quantized=True)
    prepare.prepare_lm(params, quantized=True).save(tmp_path / "art")

    pm = prepare.load(tmp_path / "art")
    warm = _serve_tokens(model, params, prompts, quantized=True, prepared=pm)
    assert warm == cold
    assert pm.recomputed == 0, pm.recompute_report()


def test_y_delta_seeding_makes_eager_ffip_warm(tmp_path):
    """Loading seeds the shared per-weight memo: an eager FFIP GEMM over the
    loaded weight is a HIT, never a re-encode."""
    params = _tiny_params()
    prepare.prepare_lm(params, quantized=False).save(tmp_path / "a")
    pm = prepare.load(tmp_path / "a")
    w = pm.params["lin"]["w"]
    before = dict(compat.derived.stats)
    a = jnp.ones((4, 8), jnp.float32)
    got = ffip_gemm(a, w)
    assert compat.derived.stats["computed"] == before["computed"]
    assert compat.derived.stats["hits"] == before["hits"] + 1
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ w),
                               rtol=1e-5, atol=1e-5)
    # and the seeded delta IS the Eq. 9 encoding
    np.testing.assert_allclose(np.asarray(pm.derived["lin/w"]),
                               np.asarray(fip.make_y(w)), rtol=1e-6)


# -- schedule slice portability ---------------------------------------------

_ENTRY = {"blocks": {"bm": 8, "bn": 128, "bk": 64}, "us": 10, "candidates": 2}


def test_schedule_slice_rides_and_installs(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "sched.json"))
    dev = compat.device_kind()
    key = f"gemm|ffip|int8|m4|n128|k64|{dev}"
    tune.get_cache().merge_entries({key: _ENTRY,
                                    "gemm|ffip|int8|m4|n128|k64|other_dev":
                                    _ENTRY})
    pm = prepare.prepare_lm(_tiny_params(), quantized=False)
    assert set(pm.schedule) == {key}, "slice must be device-keyed"
    pm.save(tmp_path / "a")

    # fresh process-like cache: point at an empty path, then load
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "fresh.json"))
    pm2 = prepare.load(tmp_path / "a")
    assert pm2.schedule == {key: _ENTRY}
    assert tune.get_cache().lookup(key) == _ENTRY, \
        "load must install the slice into the process tune cache"


def test_foreign_device_drops_schedule_once(tmp_path, monkeypatch, caplog):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "sched.json"))
    tune.get_cache().merge_entries(
        {"gemm|ffip|int8|m4|n128|k64|faketpu_v9": _ENTRY})
    pm = prepare.prepare_lm(_tiny_params(), quantized=True,
                            device="faketpu_v9")
    assert pm.schedule
    pm.save(tmp_path / "a")

    with caplog.at_level(logging.WARNING, logger="repro.prepare"):
        pm2 = prepare.load(tmp_path / "a")
        pm3 = prepare.load(tmp_path / "a")
    # weights + y-deltas still load; only the schedule slice is dropped
    assert pm2.quantized and pm2.schedule == {} and pm3.schedule == {}
    drops = [r for r in caplog.records if "dropping" in r.message]
    assert len(drops) == 1, "foreign-device drop must warn exactly once"


# -- corruption quarantine ---------------------------------------------------

def test_corrupt_artifact_quarantined(tmp_path):
    bad = tmp_path / "art"
    bad.mkdir()
    (bad / "manifest.json").write_text("{not json")
    with pytest.raises(prepare.ArtifactError, match="corrupt"):
        prepare.load(bad)
    assert not bad.exists()
    assert (tmp_path / "art.corrupt" / "manifest.json").exists()


def test_missing_artifact_raises_without_quarantine(tmp_path):
    with pytest.raises(prepare.ArtifactError, match="no prepared artifact"):
        prepare.load(tmp_path / "nope")
    assert not (tmp_path / "nope.corrupt").exists()


def test_save_is_atomic_under_overwrite(tmp_path):
    pm = prepare.prepare_lm(_tiny_params(), quantized=False)
    pm.save(tmp_path / "a")
    pm.save(tmp_path / "a")          # overwrite in place
    assert prepare.load(tmp_path / "a").recomputed == 0
    with pytest.raises(FileExistsError):
        pm.save(tmp_path / "a", overwrite=False)


# -- legacy path equivalence --------------------------------------------------

def test_batcher_quantized_path_is_prepare_lm():
    """BatchServer's in-process quantized prep now routes through
    repro.prepare and matches a direct prepare_lm tree."""
    _, model, params = _setup()
    srv = BatchServer(model, batch_slots=1, max_len=MAX_LEN, quantized=True)
    got = srv._params_for(params)
    want = prepare.prepare_lm(params, quantized=True, y_deltas=False).params
    for x, y in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_vision_attach_quantized_is_prepare_vision():
    from repro.vision import models as vm
    model = vm.build("alexnet", num_classes=10, image_size=67, width_div=8)
    params = vm.init_params(model, jax.random.PRNGKey(0))
    a = vm.attach_quantized(model, params)
    b = prepare.prepare_vision(model, params, quantized=True).params
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_vision_artifact_roundtrip_preserves_static_geometry(tmp_path):
    from repro.vision import models as vm
    model = vm.build("alexnet", num_classes=10, image_size=67, width_div=8)
    params = vm.init_params(model, jax.random.PRNGKey(0))
    pm = prepare.prepare_vision(model, params, quantized=True)
    pm.save(tmp_path / "v")
    pm2 = prepare.load(tmp_path / "v")
    assert pm2.kind == "vision" and pm2.recomputed == 0
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 67, 67, 3))
    ref = vm.apply(model, pm.params, x)
    got = vm.apply(model, pm2.params, x)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))
