"""Public model API: init / train loss (chunked CE) / prefill / decode."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T

Array = jax.Array
PyTree = Any


def chunked_cross_entropy(params, hidden: Array, labels: Array,
                          cfg: ModelConfig, *, chunk: int = 512) -> Array:
    """CE over the vocab without materializing (B,S,V) f32 logits at once.

    Scans over sequence chunks; each chunk computes (B,c,V) logits, its CE,
    and discards them — essential for vocab=262144 archs (gemma3)."""
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    if s % chunk != 0:
        chunk = s  # fallback: shapes in the grid keep s % 512 == 0
    n_chunks = s // chunk
    h = hidden.reshape(b, n_chunks, chunk, d)
    y = labels.reshape(b, n_chunks, chunk)

    def body(acc, inp):
        hc, yc = inp                                    # (B,c,d), (B,c)
        logits = T.logits_fn(params, hc, cfg).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(
        body, jnp.zeros((), jnp.float32),
        (jnp.moveaxis(h, 1, 0), jnp.moveaxis(y, 1, 0)))
    return total / (b * s)


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # -- init ------------------------------------------------------------
    def init(self, key) -> PyTree:
        return T.init_params(key, self.cfg)

    def init_cache(self, batch: int, max_len: int) -> dict:
        cache = T.init_cache(self.cfg, batch, max_len)
        if self.cfg.encoder is not None:
            t = self.cfg.encoder.n_frames
            cache["cross_kv"] = {
                "k": jnp.zeros((self.cfg.n_layers, batch, t,
                                self.cfg.n_kv_heads, self.cfg.hd), self.cfg.dtype),
                "v": jnp.zeros((self.cfg.n_layers, batch, t,
                                self.cfg.n_kv_heads, self.cfg.hd), self.cfg.dtype),
            }
        return cache

    def init_paged_cache(self, num_pages: int, page_size: int) -> dict:
        """Shared page pools instead of per-slot rows; see
        transformer.init_paged_cache. Sequences address the pools through a
        (B, max_pages) page table owned by the serving layer."""
        return T.init_paged_cache(self.cfg, num_pages, page_size)

    # -- training ----------------------------------------------------------
    def loss(self, params, batch: Dict[str, Array]) -> Array:
        """batch: tokens (B,S), labels (B,S), + frames/patches stubs."""
        hidden, aux, _ = T.forward(
            params, batch["tokens"], self.cfg,
            frames=batch.get("frames"), patches=batch.get("patches"))
        ce = chunked_cross_entropy(params, hidden, batch["labels"], self.cfg)
        return ce + aux

    # -- serving -----------------------------------------------------------
    def prefill(self, params, tokens: Array, cache: dict,
                frames: Optional[Array] = None,
                patches: Optional[Array] = None) -> Tuple[dict, Array]:
        """Fill the cache with a prompt; returns (cache, last-token logits)."""
        hidden, _, new_cache = T.forward(
            params, tokens, self.cfg, frames=frames, patches=patches,
            caches=cache, cache_pos=jnp.zeros((), jnp.int32),
            is_prefill=True)
        logits = T.logits_fn(params, hidden[:, -1:], self.cfg)
        return new_cache, logits[:, 0]

    def decode_step(self, params, token: Array, cache: dict, pos: Array,
                    ) -> Tuple[dict, Array]:
        """One decode step. token: (B,1); pos: count of cached tokens — a
        scalar (all rows share one offset) or a (B,) int32 vector of per-slot
        positions (continuous batching: row i writes its KV at ``pos[i]``,
        applies rope at ``pos[i]``, and attends rows ``< pos[i] + 1``)."""
        hidden, _, new_cache = T.forward(
            params, token, self.cfg, caches=cache, cache_pos=pos)
        logits = T.logits_fn(params, hidden, self.cfg)
        return new_cache, logits[:, 0]

    def sample_step(self, params, token: Array, cache: dict, pos: Array,
                    *, page_table: Optional[Array] = None,
                    paged_impl: str = "gather",
                    write_mask: Optional[Array] = None) -> Tuple[dict, Array]:
        """decode_step with greedy sampling fused into the device program:
        returns (cache, (B,) int32 token ids) — the (B, V) float logits never
        leave the device. With ``page_table`` the cache leaves are page pools
        and ``write_mask`` (B,) gates pool writes (a masked-out slot must not
        touch SHARED pool rows, unlike the harmless private-row rewrite of
        the contiguous path)."""
        hidden, _, new_cache = T.forward(
            params, token, self.cfg, caches=cache, cache_pos=pos,
            cache_write_mask=write_mask, page_table=page_table,
            paged_impl=paged_impl)
        return new_cache, T.sample_fn(params, hidden, self.cfg)[:, 0]

    def sample_steps(self, params, token: Array, cache: dict, pos: Array,
                     live: Array, remaining: Array, eos_id: Array,
                     *, steps: int, page_table: Optional[Array] = None,
                     paged_impl: str = "gather") -> Tuple[dict, Array]:
        """Fused multi-step greedy decode: a ``lax.scan`` over ``steps`` decode
        steps that feeds each sampled token straight back on device — one host
        round-trip (and one (steps, B) int32 transfer) per ``steps`` tokens.

        token/pos/remaining/eos_id: (B,) int32; live: (B,) bool. Per-slot
        termination is tracked ON DEVICE so the scan is bit-identical to
        stepping one token at a time: a slot that hits EOS or exhausts its
        budget mid-chunk FREEZES — its pos and token stop advancing, so every
        remaining step re-writes the same K/V values into the same cache row
        (k/v depend only on (token, position), not on the cache), leaving the
        cache bit-identical to sequential decode. The host replays the same
        (eos, remaining) bookkeeping on the returned (steps, B) token block to
        decide what was actually emitted.
        """
        def body(carry, _):
            cache, tok, pos, live, rem = carry
            cache, nxt = self.sample_step(
                params, tok[:, None], cache, pos, page_table=page_table,
                paged_impl=paged_impl,
                write_mask=(live if page_table is not None else None))
            rem = jnp.where(live, rem - 1, rem)
            finished = live & ((nxt == eos_id) | (rem <= 0))
            live2 = live & ~finished
            pos2 = jnp.where(live2, pos + 1, pos)
            tok2 = jnp.where(live2, nxt, tok)
            return (cache, tok2, pos2, live2, rem), nxt

        (cache, *_), toks = jax.lax.scan(
            body, (cache, token, pos, live, remaining), None, length=steps)
        return cache, toks

    def prefill_sample(self, params, tokens: Array, cache: dict,
                       lengths: Array, slot_mask: Array,
                       ) -> Tuple[dict, Array]:
        """Bucketed batched prefill straight into the SHARED slot cache.

        tokens: (B, L) prompts right-padded to the bucket length L;
        lengths: (B,) true prompt lengths; slot_mask: (B,) bool — rows being
        admitted. Masked-out rows (live or idle slots) keep their cache
        content bit-for-bit; admitted rows get their prompt K/V written at
        rows [0, L) (pad rows hold garbage but sit beyond the row's valid
        region, so decode masks them until it overwrites them). Returns
        (cache, (B,) int32 first sampled token per row — argmax at each row's
        OWN last prompt position, on device)."""
        b = tokens.shape[0]
        hidden, _, new_cache = T.forward(
            params, tokens, self.cfg, caches=cache,
            cache_pos=jnp.zeros((), jnp.int32),
            cache_write_mask=slot_mask, is_prefill=True)
        last = hidden[jnp.arange(b), lengths - 1]          # (B, d)
        return new_cache, T.sample_fn(params, last[:, None], self.cfg)[:, 0]

    def prefill_chunk_paged(self, params, tokens: Array, cache: dict,
                            page_table: Array, offset: Array, valid_len: Array,
                            write_start: Array, *, paged_impl: str = "gather",
                            ) -> Tuple[dict, Array]:
        """One page-aligned prefill chunk of a single sequence into the pools.

        tokens: (1, C) chunk right-padded to the fixed chunk width C (one jit
        compile covers every chunk of every prompt); page_table: (1, max_pages)
        this sequence's table; offset: () int32 logical position of
        tokens[0, 0]; valid_len: () int32 real token count in the chunk;
        write_start: () int32 first logical row to WRITE — rows below it are
        already in the pool (shared prefix pages), and the
        recompute-only-the-last-token case of a fully shared prompt sets
        write_start past every row so the forward touches nothing. Returns
        (cache, () int32 greedy token sampled at the chunk's last valid
        position — meaningful only on a prompt's final chunk).

        Chunked == single-dispatch bit-exactness: the paged branch always
        attends over the full gathered cache (never chunk-local flash), and
        every per-row op is row-independent, so splitting a prompt across
        chunks cannot change any written row or the sampled token.
        """
        rows = (jnp.asarray(offset, jnp.int32)
                + jnp.arange(tokens.shape[1], dtype=jnp.int32))[None, :]
        wm = (rows >= write_start) & (rows < offset + valid_len)
        hidden, _, new_cache = T.forward(
            params, tokens, self.cfg, caches=cache,
            cache_pos=jnp.reshape(jnp.asarray(offset, jnp.int32), (1,)),
            cache_write_mask=wm, is_prefill=True, page_table=page_table,
            paged_impl=paged_impl)
        last = hidden[:, valid_len - 1]                    # (1, d)
        return new_cache, T.sample_fn(params, last[:, None], self.cfg)[0, 0]


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
