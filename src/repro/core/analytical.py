"""The paper's arithmetic-complexity / resource / throughput model (§3, §4, §6.2.1).

Pure-python analytical layer. Everything here is an equation from the paper:

  * Eq. (1) op counts, Eqs. (5)/(6) FIP/FFIP op counts,
  * Eqs. (17)-(19) PE register costs (Fig. 2),
  * Eqs. (22)-(30) throughput / throughput-per-compute-area roofs,
  * Eqs. (31a-c) evaluation metrics (GOPS, GOPS/multiplier, ops/mult/cycle),
  * a deterministic MXU cycle model (§4.3/§5: weight-stationary tiles,
    double-buffered weight loads, alpha row) used to reproduce Fig. 9 and
    Tables 1-3 — the paper itself uses such a model ("accurate throughput
    estimation ... predicts the actual model throughputs within 1%").

The frequency constants are calibrated to the paper's measured Fig. 9 /
Table 1-2 numbers (Arria 10, quartus results); they are MEASURED-BY-THE-PAPER
constants, not re-derived — flagged as such for honesty in benchmarks.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, List, Literal, Sequence, Tuple

Algo = Literal["baseline", "fip", "ffip"]


# ---------------------------------------------------------------------------
# Eqs. (1), (5), (6): arithmetic complexity of C = A(MxK) @ B(KxN)
# ---------------------------------------------------------------------------

def baseline_mults(m: int, k: int, n: int) -> int:
    return m * n * k


def baseline_adds(m: int, k: int, n: int) -> int:
    return m * n * (k - 1)


def fip_mults(m: int, k: int, n: int) -> int:
    """Eq. (5), even K: (MNK + MK + NK) / 2."""
    assert k % 2 == 0
    return (m * n * k + m * k + n * k) // 2


def fip_adds(m: int, k: int, n: int) -> int:
    """Eq. (6): (3MNK + MK + NK)/2 - MN - M - N."""
    assert k % 2 == 0
    return (3 * m * n * k + m * k + n * k) // 2 - m * n - m - n


ffip_mults = fip_mults   # Eq. (7) has identical counts (§3.2)
ffip_adds = fip_adds


# ---------------------------------------------------------------------------
# Eqs. (17)-(19): PE register requirements (bits), Fig. 2
# ---------------------------------------------------------------------------

def clog2(x: int) -> int:
    return max(1, math.ceil(math.log2(max(x, 2))))


def fip_pe_registers(w: int, x: int) -> int:
    """Eq. (17): 6w + clog2(X) + 1."""
    return 6 * w + clog2(x) + 1


def fip_pe_registers_extra(w: int, x: int, d: int = 1) -> int:
    """Eq. (18): FIP PE + multiplier-input registers: 8w + 2d + clog2(X) + 1."""
    return 8 * w + 2 * d + clog2(x) + 1


def ffip_pe_registers(w: int, x: int, d: int = 1) -> int:
    """Eq. (19): 6w + 2d + clog2(X) + 3."""
    return 6 * w + 2 * d + clog2(x) + 3


def baseline_pe_registers(w: int, x: int) -> int:
    """Two baseline PEs (Fig. 1a) ~ comparable compute power: each holds
    a, b, and the 2w+clog2(X)+1 accumulator: 2*(2w + (2w+clog2(X)+1))."""
    return 2 * (2 * w + (2 * w + clog2(x) + 1))


def fig2_table(x: int = 64, d: int = 1, widths: Sequence[int] = tuple(range(2, 17))):
    """Reproduces Fig. 2's three curves."""
    return [
        dict(w=w,
             fip=fip_pe_registers(w, x),
             fip_extra=fip_pe_registers_extra(w, x, d),
             ffip=ffip_pe_registers(w, x, d))
        for w in widths
    ]


# ---------------------------------------------------------------------------
# §4.1 / §6: MXU resource model (multipliers / DSPs)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MxuConfig:
    x: int                 # effective MAC columns (K-dim)
    y: int                 # effective MAC rows (N-dim)
    algo: Algo = "ffip"
    w_bits: int = 8        # input bitwidth
    mults_per_dsp: int = 2  # Arria 10: two 18x19 mults per DSP


def mxu_multipliers(cfg: MxuConfig) -> int:
    """Physical multipliers instantiated, §4.1 + post-GEMM rescale row (§6).

    baseline: X*Y MACs. (F)FIP: X/2 columns * (Y+1) rows (the +1 row is the
    alpha generator). All variants: + Y rescale multipliers in the post-GEMM
    unit (the paper: 'requires an additional Y multipliers').
    """
    if cfg.algo == "baseline":
        core = cfg.x * cfg.y
    else:
        core = (cfg.x // 2) * (cfg.y + 1)
    return core + cfg.y


def mxu_dsps(cfg: MxuConfig) -> int:
    return math.ceil(mxu_multipliers(cfg) / cfg.mults_per_dsp)


def mxu_effective_macs(cfg: MxuConfig) -> int:
    """Effective MACs/cycle (what throughput sees): X*Y for every algo."""
    return cfg.x * cfg.y


# ---------------------------------------------------------------------------
# Frequency model — constants measured by the paper (Fig. 9 / Tables 1-2).
# ---------------------------------------------------------------------------

_FMAX_MHZ = {
    # (algo, w_bits) -> (f at size 32, slope MHz per +8 PEs of size)
    ("baseline", 8): (440.0, -9.0),    # ~386 MHz at 64x64, Fig. 9 trend
    ("fip", 8): (310.0, -7.0),         # ~30% below baseline (paper §6.1)
    ("ffip", 8): (424.0, -9.0),        # 388 MHz at 64x64 (Table 1)
    ("baseline", 16): (392.0, -8.0),
    ("fip", 16): (274.0, -6.0),
    ("ffip", 16): (378.0, -8.0),       # 346 MHz at 64x64 (Table 2)
}


def mxu_fmax_mhz(cfg: MxuConfig) -> float:
    base, slope = _FMAX_MHZ[(cfg.algo, cfg.w_bits)]
    return base + slope * (cfg.x - 32) / 8.0


# ---------------------------------------------------------------------------
# Eqs. (22)-(30): roofs
# ---------------------------------------------------------------------------

def ops_roof(cfg: MxuConfig) -> float:
    """Eq. (24c)/(28c): 2*#mult*f (baseline) or 4*#mult*f ((F)FIP), ops/s."""
    f = mxu_fmax_mhz(cfg) * 1e6
    nmul = mxu_multipliers(cfg)
    factor = 2.0 if cfg.algo == "baseline" else 4.0
    return factor * nmul * f


def throughput_per_area_roof(cfg: MxuConfig) -> float:
    """Eq. (25)/(29): ops/s per multiplier."""
    return ops_roof(cfg) / mxu_multipliers(cfg)


def ops_per_mult_per_cycle_roof(cfg: MxuConfig) -> float:
    """Eq. (26)/(30): 2 (baseline) or 4 ((F)FIP)."""
    return 2.0 if cfg.algo == "baseline" else 4.0


# ---------------------------------------------------------------------------
# Deterministic MXU cycle model for GEMM workloads (§4.3, §5.2)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GemmShape:
    m: int
    k: int
    n: int
    name: str = ""

    def ops(self) -> int:
        """Effective (baseline-equivalent) op count, Eq. (21d)."""
        return baseline_mults(self.m, self.k, self.n) + baseline_adds(self.m, self.k, self.n)


def gemm_cycles(shape: GemmShape, cfg: MxuConfig, *, pipeline_fill: bool = True) -> int:
    """Cycles to run one GEMM on the MXU, weight-stationary tiling (§4.3).

    B is tiled (X x Y); a tile stays in place while M rows of A stream
    through, one row/cycle. Weight loads are double-buffered and hidden iff
    the A-tile height >= weight-load cycles; (F)FIP loads weights every other
    cycle (§5.2) but K-tiles are X/2 deep, so the hide condition matches the
    paper's 'M_t >= 2*N_t' remark. Pipeline fill/drain: X (baseline) or
    X/2 ((F)FIP) cycles per K-tile column (§4.2: latency is X/2 fewer).
    """
    kx = cfg.x
    tiles_k = math.ceil(shape.k / kx)
    tiles_n = math.ceil(shape.n / cfg.y)
    stream = shape.m                     # one A row per cycle per tile
    fill = (kx if cfg.algo == "baseline" else kx // 2) if pipeline_fill else 0
    # weight-load stall per tile: load Y columns, every-other-cycle for FFIP
    load = cfg.y * (2 if cfg.algo != "baseline" else 1)
    stall = max(0, load - stream)        # hidden when A-stream is long enough
    per_tile = stream + stall
    return tiles_k * tiles_n * per_tile + fill * tiles_k


def model_performance(gemms: Iterable[GemmShape], cfg: MxuConfig) -> dict:
    """Runs the cycle model over a workload; returns the paper's metrics."""
    gemms = list(gemms)
    total_ops = sum(g.ops() for g in gemms)
    total_cycles = sum(gemm_cycles(g, cfg) for g in gemms)
    f_hz = mxu_fmax_mhz(cfg) * 1e6
    seconds = total_cycles / f_hz
    ops_s = total_ops / seconds
    nmul = mxu_multipliers(cfg)
    return dict(
        algo=cfg.algo,
        mxu=f"{cfg.x}x{cfg.y}",
        w_bits=cfg.w_bits,
        multipliers=nmul,
        dsps=mxu_dsps(cfg),
        fmax_mhz=mxu_fmax_mhz(cfg),
        cycles=total_cycles,
        gops=ops_s * 1e-9,                                   # Eq. (31a)
        gops_per_multiplier=ops_s * 1e-9 / nmul,             # Eq. (31b)
        ops_per_mult_per_cycle=ops_s / nmul / f_hz,          # Eq. (31c)
        utilization=total_ops / (2.0 * mxu_effective_macs(cfg) * total_cycles),
        roof_gops=ops_roof(cfg) * 1e-9,
    )


# ---------------------------------------------------------------------------
# TPU-side roofline constants (brief-specified v5e-class targets)
# ---------------------------------------------------------------------------

TPU_PEAK_FLOPS_BF16 = 197e12      # per chip
TPU_HBM_BW = 819e9                # bytes/s per chip
TPU_ICI_BW = 50e9                 # bytes/s per link


def tpu_roofline_terms(hlo_flops: float, hlo_bytes: float,
                       collective_bytes: float, chips: int) -> dict:
    compute = hlo_flops / (chips * TPU_PEAK_FLOPS_BF16)
    memory = hlo_bytes / (chips * TPU_HBM_BW)
    collective = collective_bytes / (chips * TPU_ICI_BW)
    terms = dict(compute_s=compute, memory_s=memory, collective_s=collective)
    terms["bottleneck"] = max(terms, key=lambda k: terms[k] if k.endswith("_s") else -1)
    return terms
