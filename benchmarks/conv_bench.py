"""Conv benchmark: materialized im2col+GEMM vs the fused implicit-im2col
kernels, per algo/dtype, on the AlexNet and ResNet-50 layer sets.

Writes ``benchmarks/BENCH_conv.json``. Both paths run the SAME Pallas GEMM
arithmetic with the SAME block shapes; the only difference is where the A
matrix lives:

  * materialized: Algorithm-1 gather into an HBM (B, M, K) array, then the
    GEMM kernel (``core.im2col.conv2d_via_gemm`` + ``kernels.ops.matmul``);
  * fused: the gather addresses are computed inside the kernel per (bm, bk)
    block — A exists only as VMEM tiles (``kernels.conv_gemm``).

CAVEAT printed with results: this container is CPU-only; interpret-mode
timings measure the emulation harness, not silicon. The load-bearing,
platform-independent number is ``im2col_bytes`` — the HBM traffic/footprint
the fused path deletes per image. Spatial dims are divided by ``--scale``
(default 4) to keep interpret-mode runtimes sane; the JSON records it.

    PYTHONPATH=src python benchmarks/conv_bench.py [--scale 4] [--limit 4]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time
from typing import List

import jax.numpy as jnp

from repro.core import im2col, workloads
from repro.kernels import conv_gemm, ops as kops
from repro.tune import measure

OUT = pathlib.Path(__file__).resolve().parent / "BENCH_conv.json"

ALGOS = ("baseline", "fip", "ffip")
DTYPES = ("float32", "int8")


def _median_us(fn, *args, iters: int = 2) -> float:
    # repro.tune.measure owns the timing discipline (compile outside the
    # timed region, median-of-k) — one implementation for tuner and benches
    return measure.median_time_s(fn, *args, iters=iters) * 1e6


def _scaled_specs(name: str, scale: int, limit: int) -> List[workloads.ConvSpec]:
    """Distinct conv geometries of a model, spatial dims divided by
    ``scale`` (floor at the kernel size), deduped by everything that changes
    the kernels' work, largest-GEMM-first, capped at ``limit``."""
    seen = set()
    specs = []
    for s in workloads.CONV_SPECS[name]():
        h = max(s.kh, s.h // scale)
        w = max(s.kw, s.w // scale)
        scaled = workloads.ConvSpec(s.name, h, w, s.cin, s.cout, s.kh, s.kw,
                                    s.stride, s.pad, s.groups)
        key = (h, w, s.cin, s.cout, s.kh, s.kw, s.stride, s.pad, s.groups)
        if key not in seen:
            seen.add(key)
            specs.append(scaled)
    specs.sort(key=lambda s: -(s.oh * s.ow * s.k * s.cout))
    dropped = len(specs) - limit
    if limit and dropped > 0:
        print(f"[{name}] capping {len(specs)} distinct conv geometries to "
              f"{limit} (--limit); {dropped} smaller layers skipped")
        specs = specs[:limit]
    return specs


def _operands(spec: workloads.ConvSpec, batch: int, dtype: str):
    return measure._conv_operands(batch, spec.h, spec.w, spec.cin, spec.kh,
                                  spec.kw, spec.cout, spec.groups,
                                  jnp.dtype(dtype))


def bench_layer(spec: workloads.ConvSpec, *, batch: int, iters: int) -> dict:
    gemm_m = batch * spec.oh * spec.ow
    entry = {
        "name": spec.name,
        "h": spec.h, "w": spec.w, "cin": spec.cin, "cout": spec.cout,
        "kh": spec.kh, "kw": spec.kw, "stride": list(spec.stride),
        "pad": list(spec.pad), "groups": spec.groups,
        "gemm": {"m": gemm_m, "k": spec.k, "n": spec.cout // spec.groups,
                 "per_group": spec.groups},
        "im2col_bytes": {},          # per dtype: the HBM A-matrix footprint
        "results": {},
    }
    for dtype in DTYPES:
        x, kernel = _operands(spec, batch, dtype)
        itemsize = jnp.dtype(dtype).itemsize
        entry["im2col_bytes"][dtype] = (batch * spec.oh * spec.ow * spec.k
                                        * spec.groups * itemsize)
        for algo in ALGOS:
            bm, bn, bk = kops.choose_blocks(spec.oh * spec.ow,
                                            spec.cout // spec.groups,
                                            spec.k, algo)
            fused = lambda x_, k_: conv_gemm.conv_gemm_fused(
                x_, k_, stride=spec.stride, pad=spec.pad, groups=spec.groups,
                algo=algo, bm=bm, bn=bn, bk=bk)
            mat = lambda x_, k_: im2col.conv2d_via_gemm(
                x_, k_, stride=spec.stride, pad=spec.pad, groups=spec.groups,
                gemm_fn=lambda a, b: kops.matmul(a, b, algo=algo,
                                                 bm=bm, bn=bn, bk=bk))
            t_fused = _median_us(fused, x, kernel, iters=iters)
            t_mat = _median_us(mat, x, kernel, iters=iters)
            entry["results"][f"{algo}.{dtype}"] = {
                "blocks": {"bm": bm, "bn": bn, "bk": bk},
                "fused_us": round(t_fused, 1),
                "materialized_us": round(t_mat, 1),
                "fused_over_materialized": round(t_fused / max(t_mat, 1e-9), 3),
            }
    return entry


def write_bench(*, models=("alexnet", "resnet50"), scale: int = 4,
                limit: int = 4, batch: int = 1, iters: int = 2) -> dict:
    from repro.kernels.compat import device_kind
    prior = None
    if OUT.exists():
        try:
            prior = json.loads(OUT.read_text())
            prior.pop("baseline_prev", None)      # keep one generation
        except Exception:
            prior = None
    out = {
        "bench": "conv",
        "note": ("materialized = Algorithm-1 gather into HBM + Pallas GEMM; "
                 "fused = same GEMM arithmetic with the gather inside the "
                 "kernel (A only in VMEM tiles). Same blocks both sides. "
                 "CPU containers time interpret-mode emulation, not silicon; "
                 "im2col_bytes is the platform-independent HBM footprint the "
                 "fused path removes. Spatial dims divided by 'scale'."),
        "device_kind": device_kind(),
        "scale": scale,
        "batch": batch,
        "models": {},
    }
    for name in models:
        specs = _scaled_specs(name, scale, limit)
        layers = []
        for spec in specs:
            t0 = time.perf_counter()
            layers.append(bench_layer(spec, batch=batch, iters=iters))
            print(f"[{name}] {spec.name}: {spec.h}x{spec.w}x{spec.cin}"
                  f"->{spec.cout} k{spec.kh}x{spec.kw} g{spec.groups} "
                  f"({time.perf_counter() - t0:.1f}s)")
        out["models"][name] = {"layers": layers}
    if prior is not None:
        out["baseline_prev"] = prior
    OUT.write_text(json.dumps(out, indent=2) + "\n")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default="alexnet,resnet50")
    ap.add_argument("--scale", type=int, default=4,
                    help="divide spatial dims (interpret-mode runtime knob)")
    ap.add_argument("--limit", type=int, default=4,
                    help="max distinct conv geometries per model (0 = all)")
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--iters", type=int, default=2)
    args = ap.parse_args()
    out = write_bench(models=tuple(m for m in args.models.split(",") if m),
                      scale=args.scale, limit=args.limit, batch=args.batch,
                      iters=args.iters)
    for name, m in out["models"].items():
        for layer in m["layers"]:
            for key, r in layer["results"].items():
                print(f"BENCH_conv.{name}.{layer['name']}.{key},"
                      f"fused={r['fused_us']}us,"
                      f"materialized={r['materialized_us']}us,"
                      f"ratio={r['fused_over_materialized']}")
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
