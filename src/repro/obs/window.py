"""Time-windowed metrics: sliding histograms and rate counters.

The PR-9 metrics in :mod:`repro.obs.metrics` are process-lifetime-scoped —
fine for "how many dispatches ever", useless for "TTFT p99 over the last
30 s", which is what an SLO evaluates. This module adds the windowed layer:

* :class:`WindowedHistogram` — raw observations bucketed into a ring of
  fixed-duration **sub-buckets**. An observation at time ``t`` lands in
  sub-bucket ``floor(t / sub_s)``; a query at time ``now`` covers the last
  ``k = ceil(window / sub_s)`` sub-buckets *including the current partial
  one* (so an observation exactly on a sub-bucket boundary starts the new
  sub-bucket, and expires exactly ``k`` boundaries later). Quantiles are
  EXACT (numpy 'linear' interpolation over the retained raw samples) as
  long as no sub-bucket overflowed its per-bucket reservoir — overflow is
  surfaced, never silent (``samples_dropped``).

* :class:`WindowedCounter` — the same ring holding plain sums, for
  windowed rates (``errors over the last 5 s``).

Both read time from an injectable clock (defaulting to
:func:`repro.obs.default_clock`), and expiry happens lazily at read/write
time — there is no background thread — so a ``FakeClock``-driven run is
exact and deterministic: the same fake timeline produces byte-identical
windows, including a clock jump larger than the whole window (every stale
sub-bucket's epoch falls out of range and the window reads empty).

Sub-bucket granularity is the resolution limit: a query window is rounded
up to whole sub-buckets. Queries may ask for any ``window_s`` up to the
instrument's full ``window_s`` — one instrument serves both the fast and
slow windows of a multi-window burn-rate alert.

Labeled families aggregate: calling ``quantile``/``count``/``rate`` on the
*parent* of a labeled windowed metric merges all children, which is how an
SLO over ``{replica, tier}``-labeled TTFT sees fleet-wide latency.
"""
from __future__ import annotations

import math
from typing import Callable, List, Optional, Sequence, Tuple

from repro.obs.metrics import Metric, _fmt, _fmt_labels


def _default_clock() -> float:
    from repro import obs
    return obs.default_clock()


class _Cell:
    """One sub-bucket of the ring: samples + sum/count for a single epoch."""

    __slots__ = ("epoch", "count", "sum", "samples", "dropped")

    def __init__(self):
        self.epoch = -1          # absolute sub-bucket index, -1 == never used
        self.count = 0
        self.sum = 0.0
        self.samples: List[float] = []
        self.dropped = 0

    def reset(self, epoch: int) -> None:
        self.epoch = epoch
        self.count = 0
        self.sum = 0.0
        self.samples = []
        self.dropped = 0


class _WindowedBase(Metric):
    """Shared ring mechanics for windowed histogram / counter."""

    def __init__(self, name: str, help: str = "",
                 labels: Sequence[str] = (), *,
                 window_s: float = 30.0, sub_buckets: int = 30,
                 reservoir_per_bucket: int = 256,
                 clock: Optional[Callable[[], float]] = None,
                 max_label_sets: int = 64):
        super().__init__(name, help, labels, max_label_sets=max_label_sets)
        if window_s <= 0 or sub_buckets < 1:
            raise ValueError(f"{name}: window_s must be > 0 and "
                             f"sub_buckets >= 1")
        self.window_s = float(window_s)
        self.sub_buckets = int(sub_buckets)
        self.sub_s = self.window_s / self.sub_buckets
        self.reservoir_per_bucket = int(reservoir_per_bucket)
        self._clock = clock or _default_clock
        self._ring = [_Cell() for _ in range(self.sub_buckets)]

    def _new_child(self):
        return type(self)(self.name, self.help,
                          window_s=self.window_s,
                          sub_buckets=self.sub_buckets,
                          reservoir_per_bucket=self.reservoir_per_bucket,
                          clock=self._clock)

    # -- ring addressing -----------------------------------------------------
    def _epoch(self, t: float) -> int:
        return int(math.floor(t / self.sub_s))

    def _cell_for_write(self, t: float) -> _Cell:
        e = self._epoch(t)
        cell = self._ring[e % self.sub_buckets]
        if cell.epoch != e:          # lazily evict whatever epoch lived here
            cell.reset(e)
        return cell

    def _span(self, window_s: Optional[float],
              now: Optional[float]) -> Tuple[float, int, int]:
        """(now, min live epoch, covered sub-bucket count) for a query."""
        if now is None:
            now = self._clock()
        w = self.window_s if window_s is None else float(window_s)
        if w <= 0 or w - self.window_s > 1e-12:
            raise ValueError(
                f"{self.name}: query window {w} outside (0, {self.window_s}]")
        k = min(self.sub_buckets, max(1, int(math.ceil(w / self.sub_s - 1e-9))))
        return now, self._epoch(now) - k + 1, k

    def _live(self, window_s: Optional[float] = None,
              now: Optional[float] = None) -> List[_Cell]:
        """Live cells for a query, oldest epoch first (deterministic). When
        aggregating a labeled family, merges every child's ring."""
        holders = ([c for _, c in self._series()]
                   if self.label_names and self._parent is None else [self])
        cells: List[_Cell] = []
        for h in holders:
            now, lo, _ = h._span(window_s, now)  # same clock across children
            cells.extend(c for c in h._ring if c.epoch >= lo)
        cells.sort(key=lambda c: c.epoch)
        return cells

    # -- shared queries ------------------------------------------------------
    def count(self, window_s: Optional[float] = None,
              now: Optional[float] = None) -> int:
        return sum(c.count for c in self._live(window_s, now))

    def total(self, window_s: Optional[float] = None,
              now: Optional[float] = None) -> float:
        return sum(c.sum for c in self._live(window_s, now))

    def rate(self, window_s: Optional[float] = None,
             now: Optional[float] = None) -> float:
        """Windowed sum per second, over the covered whole-sub-bucket span."""
        if now is None:
            now = self._clock()
        _, _, k = self._span(window_s, now)
        return self.total(window_s, now) / (k * self.sub_s)


class WindowedHistogram(_WindowedBase):
    """Sliding-window histogram; exact quantiles over retained raw samples."""

    kind = "windowed_histogram"

    def observe(self, value: float) -> None:
        self._require_unlabeled()
        v = float(value)
        cell = self._cell_for_write(self._clock())
        cell.count += 1
        cell.sum += v
        if len(cell.samples) < self.reservoir_per_bucket:
            cell.samples.append(v)
        else:
            cell.dropped += 1

    def samples(self, window_s: Optional[float] = None,
                now: Optional[float] = None) -> List[float]:
        out: List[float] = []
        for c in self._live(window_s, now):
            out.extend(c.samples)
        return out

    def samples_dropped(self, window_s: Optional[float] = None,
                        now: Optional[float] = None) -> int:
        return sum(c.dropped for c in self._live(window_s, now))

    def quantile(self, q: float, window_s: Optional[float] = None,
                 now: Optional[float] = None) -> float:
        """q in [0, 1] over the live window; numpy 'linear' interpolation
        over retained samples (exact unless a sub-bucket overflowed its
        reservoir — check :meth:`samples_dropped`); 0.0 when empty."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        s = sorted(self.samples(window_s, now))
        if not s:
            return 0.0
        pos = q * (len(s) - 1)
        lo = int(pos)
        hi = min(lo + 1, len(s) - 1)
        return s[lo] + (s[hi] - s[lo]) * (pos - lo)

    def mean(self, window_s: Optional[float] = None,
             now: Optional[float] = None) -> float:
        n = self.count(window_s, now)
        return self.total(window_s, now) / n if n else 0.0

    # -- export protocol (quantiles computed at snapshot time against this
    # instrument's clock, so FakeClock runs snapshot deterministically) -----
    def _window_stats(self):
        now = self._clock()
        s = sorted(self.samples(now=now))

        def q(p: float) -> float:
            if not s:
                return 0.0
            pos = p * (len(s) - 1)
            lo = int(pos)
            hi = min(lo + 1, len(s) - 1)
            return s[lo] + (s[hi] - s[lo]) * (pos - lo)

        return {
            "window_s": self.window_s, "sub_s": self.sub_s,
            "count": self.count(now=now), "sum": self.total(now=now),
            "rate_per_s": self.rate(now=now),
            "p50": q(0.5), "p90": q(0.9), "p99": q(0.99),
            "max": s[-1] if s else 0.0,
            "samples_dropped": self.samples_dropped(now=now),
        }

    def _snap(self, labels):
        return {"labels": labels, **self._window_stats()}

    def _prom(self, name, lab):
        st = self._window_stats()
        lines = [
            f"{name}{_fmt_labels({**lab, 'quantile': '0.5'})} "
            f"{_fmt(st['p50'])}",
            f"{name}{_fmt_labels({**lab, 'quantile': '0.9'})} "
            f"{_fmt(st['p90'])}",
            f"{name}{_fmt_labels({**lab, 'quantile': '0.99'})} "
            f"{_fmt(st['p99'])}",
            f"{name}_sum{_fmt_labels(lab)} {_fmt(st['sum'])}",
            f"{name}_count{_fmt_labels(lab)} {st['count']}",
            f"{name}_rate{_fmt_labels(lab)} {_fmt(st['rate_per_s'])}",
            f"{name}_samples_dropped{_fmt_labels(lab)} "
            f"{st['samples_dropped']}",
        ]
        return lines


class WindowedCounter(_WindowedBase):
    """Sliding-window counter: ``rate()`` = events/s over the last N s."""

    kind = "windowed_counter"

    def inc(self, amount: float = 1.0) -> None:
        self._require_unlabeled()
        if amount < 0:
            raise ValueError(f"{self.name}: counters only go up "
                             f"(inc({amount}))")
        cell = self._cell_for_write(self._clock())
        cell.count += 1
        cell.sum += float(amount)

    def _snap(self, labels):
        now = self._clock()
        return {"labels": labels, "window_s": self.window_s,
                "count": self.count(now=now), "total": self.total(now=now),
                "rate_per_s": self.rate(now=now)}

    def _prom(self, name, lab):
        now = self._clock()
        return [
            f"{name}{_fmt_labels(lab)} {_fmt(self.total(now=now))}",
            f"{name}_rate{_fmt_labels(lab)} {_fmt(self.rate(now=now))}",
        ]
