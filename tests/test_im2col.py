"""Algorithm 1 (conv->GEMM in-place mapping) + §5.1.1 partitioning tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import im2col
from repro.core.gemm import GemmConfig, gemm


@pytest.mark.parametrize("h,w,cin,cout,kh,kw,stride,pad", [
    (8, 8, 3, 4, 3, 3, 1, 1),
    (12, 10, 2, 5, 3, 3, 2, 0),
    (7, 7, 4, 4, 1, 1, 1, 0),
    (9, 9, 3, 2, 5, 5, 2, 2),
])
def test_conv_via_gemm_matches_lax_conv(h, w, cin, cout, kh, kw, stride, pad):
    kx, kk = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (2, h, w, cin))
    kernel = jax.random.normal(kk, (kh, kw, cin, cout))
    got = im2col.conv2d_via_gemm(x, kernel, stride=stride, pad=pad)
    want = jax.lax.conv_general_dilated(
        x, kernel, (stride, stride), [(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_conv_via_ffip_gemm():
    """The paper's full pipeline: Algorithm-1 mapping + FFIP arithmetic."""
    kx, kk = jax.random.split(jax.random.PRNGKey(1))
    x = jax.random.normal(kx, (1, 8, 8, 4))
    kernel = jax.random.normal(kk, (3, 3, 4, 8))
    ffip_fn = lambda a, b: gemm(a, b, GemmConfig(algo="ffip", impl="ref"))
    got = im2col.conv2d_via_gemm(x, kernel, stride=1, pad=1, gemm_fn=ffip_fn)
    want = jax.lax.conv_general_dilated(
        x, kernel, (1, 1), [(1, 1), (1, 1)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("h,w,cin,cout,kh,kw,stride,pad,groups", [
    (27, 27, 8, 16, 5, 5, 1, 2, 2),       # AlexNet conv2-style grouped
    (13, 13, 12, 12, 3, 3, 1, 1, 4),
    (10, 12, 6, 9, 3, 2, (2, 1), (0, 1), 3),  # asymmetric + grouped
    (9, 9, 3, 4, 2, 2, (2, 2), (1, 1), 1),
])
def test_grouped_asymmetric_conv_via_gemm(h, w, cin, cout, kh, kw, stride,
                                          pad, groups):
    """Satellites: block-diagonal K split for groups and (sh, sw)/(ph, pw)
    tuples, both validated against lax.conv feature_group_count."""
    kx, kk = jax.random.split(jax.random.PRNGKey(2))
    x = jax.random.normal(kx, (2, h, w, cin))
    kernel = jax.random.normal(kk, (kh, kw, cin // groups, cout))
    got = im2col.conv2d_via_gemm(x, kernel, stride=stride, pad=pad,
                                 groups=groups)
    sh, sw = im2col.as_pair(stride)
    ph, pw = im2col.as_pair(pad)
    want = jax.lax.conv_general_dilated(
        x, kernel, (sh, sw), [(ph, ph), (pw, pw)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_conv_gemm_indices_asymmetric_stride():
    """The (sh, sw) counter walks rows with stride sh*W*Cin and columns with
    sw*Cin — checked against an explicit nested loop."""
    h, w, cin, kh, kw, sh, sw = 9, 11, 2, 3, 2, 2, 3
    idx = im2col.conv_gemm_indices(h, w, cin, kh, kw, (sh, sw))
    oh = (h - kh) // sh + 1
    ow = (w - kw) // sw + 1
    want = np.asarray([
        [((r * sh + dkh) * w + (c * sw + dkw)) * cin + dc
         for dkh in range(kh) for dkw in range(kw) for dc in range(cin)]
        for r in range(oh) for c in range(ow)])
    np.testing.assert_array_equal(idx, want)


def test_conv_gemm_indices_group_offset():
    """Group g's indices are group 0's shifted by g * Cin/groups — the
    §5.1 counters realize grouping as one extra base address."""
    idx0 = im2col.conv_gemm_indices(8, 8, 6, 3, 3, 1, groups=3, group=0)
    idx2 = im2col.conv_gemm_indices(8, 8, 6, 3, 3, 1, groups=3, group=2)
    np.testing.assert_array_equal(idx2, idx0 + 4)


def test_conv2d_via_gemm_validates_groups():
    x = jnp.zeros((1, 8, 8, 6))
    kernel = jnp.zeros((3, 3, 2, 9))
    with pytest.raises(ValueError):
        im2col.conv2d_via_gemm(x, kernel, groups=2)   # cin/groups mismatch
    with pytest.raises(ValueError):
        im2col.conv2d_via_gemm(x, jnp.zeros((3, 3, 3, 9)), groups=2)  # cout%g


def test_multi_digit_counter_matches_nested_loops():
    """The Fig.-5 counter reproduces Algorithm 1's nested-loop addresses."""
    digits = [im2col.Digit("kh", 3, 100), im2col.Digit("kw", 2, 10),
              im2col.Digit("c", 4, 1)]
    got = im2col.MultiDigitCounter(digits).addresses()
    want = [kh * 100 + kw * 10 + c
            for kh in range(3) for kw in range(2) for c in range(4)]
    np.testing.assert_array_equal(got, np.asarray(want))


def test_partition_interleave_roundtrip():
    """§5.1.1: B-way partition + round-robin interleave is lossless when the
    stream walks slices in order."""
    ws, n_blocks = 2, 2
    w_idx = np.repeat(np.arange(8), 1)   # walk w = 0..7, slices of width 2
    blocks = im2col.partition_blocks(w_idx, ws, n_blocks)
    assert all(len(b) == 4 for b in blocks)
    merged = im2col.interleave_blocks(
        [b.reshape(-1, ws) for b in blocks])  # interleave slice-wise
    np.testing.assert_array_equal(np.concatenate(merged.reshape(-1, ws)), w_idx)
