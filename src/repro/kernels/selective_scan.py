"""Mamba1 selective scan as a fused Pallas TPU kernel (inference paths).

The pure-JAX chunked scan pays HBM round-trips for the (B, d_inner, N) state
carry on every time step (launch/costs.py charges it; a real TPU pays it too
once the carry exceeds registers). This kernel keeps the state in VMEM
scratch across the whole sequence: per grid cell it streams (chunk, bd)
blocks of x/dt and (chunk, N) blocks of B/C, runs the recurrence in VMEM, and
writes y blocks — HBM traffic is exactly inputs+outputs.

Forward-only paths use :func:`selective_scan`; training uses
:func:`selective_scan_trainable`, whose custom VJP runs :func:`_bwd_kernel` —
a reverse-time kernel that recomputes h within each chunk from checkpointed
chunk-start states (stored by the fwd kernel) and carries the adjoint state
in VMEM. Exact gradients for x/dt/B/C/A.

Layout: grid (B, d_inner/bd, S/c) with the sequence dim innermost/sequential;
scratch h: (bd, N) f32 persists across the S sweep for each (b, d-block).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import resolve_interpret, tpu_compiler_params

Array = jax.Array


def _kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, h0_ref, y_ref, hout_ref,
            hstart_ref, h_scr, *, chunk, n_state):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)      # (bd, N)

    hstart_ref[0, 0] = h_scr[...]                       # chunk-start checkpoint

    x = x_ref[0].astype(jnp.float32)                    # (c, bd)
    dt = dt_ref[0].astype(jnp.float32)                  # (c, bd)
    bmat = b_ref[0].astype(jnp.float32)                 # (c, N)
    cmat = c_ref[0].astype(jnp.float32)                 # (c, N)
    a = a_ref[...].astype(jnp.float32)                  # (bd, N)

    def step(t, carry):
        h, y_acc = carry                                # h: (bd, N)
        da = jnp.exp(dt[t][:, None] * a)                # (bd, N)
        dbx = (dt[t] * x[t])[:, None] * bmat[t][None, :]
        h = da * h + dbx
        y_t = jnp.sum(h * cmat[t][None, :], axis=1)     # (bd,)
        y_acc = jax.lax.dynamic_update_slice_in_dim(
            y_acc, y_t[None, :], t, axis=0)
        return h, y_acc

    h, y = jax.lax.fori_loop(
        0, chunk, step,
        (h_scr[...], jnp.zeros((chunk, x.shape[1]), jnp.float32)))
    h_scr[...] = h
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(si == pl.num_programs(2) - 1)
    def _fin():
        hout_ref[0] = h_scr[...].astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "bd", "interpret"))
def selective_scan(x: Array, dt: Array, b: Array, c: Array, a: Array,
                   h0: Array, *, chunk: int = 128, bd: int = 512,
                   interpret=None) -> Tuple[Array, Array]:
    """x, dt: (B, S, di); b, c: (B, S, N); a: (di, N); h0: (B, di, N).

    Returns (y (B,S,di), h_final (B,di,N), h_starts (B,S/chunk,di,N) —
    chunk-start state checkpoints consumed by the bwd kernel). S % chunk and
    di % bd must hold (callers pad; config shapes already align).
    ``interpret=None`` = backend auto (compat.py)."""
    interpret = resolve_interpret(interpret)
    bt, s, di = x.shape
    n = a.shape[-1]
    chunk = min(chunk, s)
    bd = min(bd, di)
    assert s % chunk == 0 and di % bd == 0
    grid = (bt, di // bd, s // chunk)
    y, h_fin, h_starts = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, n_state=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, bd), lambda b_, d, t: (b_, t, d)),  # x
            pl.BlockSpec((1, chunk, bd), lambda b_, d, t: (b_, t, d)),  # dt
            pl.BlockSpec((1, chunk, n), lambda b_, d, t: (b_, t, 0)),   # B
            pl.BlockSpec((1, chunk, n), lambda b_, d, t: (b_, t, 0)),   # C
            pl.BlockSpec((bd, n), lambda b_, d, t: (d, 0)),             # A
            pl.BlockSpec((1, bd, n), lambda b_, d, t: (b_, d, 0)),      # h0
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, bd), lambda b_, d, t: (b_, t, d)),
            pl.BlockSpec((1, bd, n), lambda b_, d, t: (b_, d, 0)),
            pl.BlockSpec((1, 1, bd, n), lambda b_, d, t: (b_, t, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bt, s, di), x.dtype),
            jax.ShapeDtypeStruct((bt, di, n), h0.dtype),
            jax.ShapeDtypeStruct((bt, s // chunk, di, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, b, c, a, h0)
    return y, h_fin, h_starts


def _bwd_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, hstart_ref, dy_ref,
                dx_ref, ddt_ref, db_ref, dc_ref, da_ref, dh_scr, da_scr,
                *, chunk):
    """Reverse-time pass, seq grid dim pre-reversed by the index maps.

    Per chunk: recompute h_t forward from the checkpoint into VMEM, then run
    the adjoint recurrence dh_{t-1} = exp(dt_t A) dh_t backwards, emitting
    dx/ddt (c,bd) and per-d-block partial dB/dC (c,N) (summed over d-blocks
    outside the kernel)."""
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        dh_scr[...] = jnp.zeros_like(dh_scr)
        da_scr[...] = jnp.zeros_like(da_scr)

    x = x_ref[0].astype(jnp.float32)          # (c, bd)
    dt = dt_ref[0].astype(jnp.float32)
    bmat = b_ref[0].astype(jnp.float32)       # (c, N)
    cmat = c_ref[0].astype(jnp.float32)
    a = a_ref[...].astype(jnp.float32)        # (bd, N)
    dy = dy_ref[0].astype(jnp.float32)        # (c, bd)
    h_prev0 = hstart_ref[0, 0]                # (bd, N) state entering the chunk

    c_len, bd = x.shape
    n = a.shape[-1]

    # forward recompute: store h_{t-1} (pre-step state) for every t in VMEM
    def fwd(t, carry):
        h, hprevs = carry
        hprevs = jax.lax.dynamic_update_slice_in_dim(
            hprevs, h[None], t, axis=0)
        da = jnp.exp(dt[t][:, None] * a)
        h = da * h + (dt[t] * x[t])[:, None] * bmat[t][None, :]
        return h, hprevs

    _, hprevs = jax.lax.fori_loop(
        0, c_len, fwd, (h_prev0, jnp.zeros((c_len, bd, n), jnp.float32)))

    def bwd(i, carry):
        t = c_len - 1 - i
        dh, dx, ddt, db, dc, dacc = carry
        h_prev = hprevs[t]                            # (bd, N)
        da = jnp.exp(dt[t][:, None] * a)
        dbx_coef = (dt[t] * x[t])[:, None]            # (bd, 1)
        h_t = da * h_prev + dbx_coef * bmat[t][None, :]
        # y_t = <h_t, C_t>
        dh_t = dh + dy[t][:, None] * cmat[t][None, :]
        dc_t = jnp.sum(h_t * dy[t][:, None], axis=0)  # (N,) partial over bd
        # dbx path
        db_t = jnp.sum(dh_t * dbx_coef, axis=0)       # (N,)
        dx_t = jnp.sum(dh_t * bmat[t][None, :], axis=1) * dt[t]
        ddt_t = (jnp.sum(dh_t * bmat[t][None, :], axis=1) * x[t]
                 + jnp.sum(dh_t * da * h_prev * a, axis=1))
        dacc = dacc + dh_t * da * h_prev * dt[t][:, None]   # exact dA term
        dh_next = da * dh_t
        dx = jax.lax.dynamic_update_slice_in_dim(dx, dx_t[None], t, 0)
        ddt = jax.lax.dynamic_update_slice_in_dim(ddt, ddt_t[None], t, 0)
        db = jax.lax.dynamic_update_slice_in_dim(db, db_t[None], t, 0)
        dc = jax.lax.dynamic_update_slice_in_dim(dc, dc_t[None], t, 0)
        return dh_next, dx, ddt, db, dc, dacc

    z2 = jnp.zeros((c_len, bd), jnp.float32)
    zn = jnp.zeros((c_len, n), jnp.float32)
    dh, dx, ddt, db, dc, dacc = jax.lax.fori_loop(
        0, c_len, bwd, (dh_scr[...], z2, z2, zn, zn, da_scr[...]))
    dh_scr[...] = dh
    da_scr[...] = dacc
    dx_ref[0] = dx.astype(dx_ref.dtype)
    ddt_ref[0] = ddt.astype(ddt_ref.dtype)
    db_ref[0, :, 0] = db.astype(db_ref.dtype)
    dc_ref[0, :, 0] = dc.astype(dc_ref.dtype)

    @pl.when(si == pl.num_programs(2) - 1)
    def _fin():
        da_ref[0] = da_scr[...].astype(da_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "bd", "interpret"))
def selective_scan_bwd(x, dt, b, c, a, h_starts, dy, *, chunk=128, bd=512,
                       interpret=None):
    """Adjoints (dx, ddt, db, dc, da) — exact; dh0 handled by the wrapper
    (training starts from h0 = 0)."""
    interpret = resolve_interpret(interpret)
    bt, s, di = x.shape
    n = a.shape[-1]
    chunk = min(chunk, s)
    bd = min(bd, di)
    assert s % chunk == 0 and di % bd == 0
    nd = di // bd
    grid = (bt, nd, s // chunk)
    rev = lambda t, total: total - 1 - t
    nch = s // chunk
    f32 = jnp.float32
    dx, ddt, db_p, dc_p, da_p = pl.pallas_call(
        functools.partial(_bwd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, bd), lambda b_, d, t: (b_, nch - 1 - t, d)),
            pl.BlockSpec((1, chunk, bd), lambda b_, d, t: (b_, nch - 1 - t, d)),
            pl.BlockSpec((1, chunk, n), lambda b_, d, t: (b_, nch - 1 - t, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, d, t: (b_, nch - 1 - t, 0)),
            pl.BlockSpec((bd, n), lambda b_, d, t: (d, 0)),
            pl.BlockSpec((1, 1, bd, n), lambda b_, d, t: (b_, nch - 1 - t, d, 0)),
            pl.BlockSpec((1, chunk, bd), lambda b_, d, t: (b_, nch - 1 - t, d)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, bd), lambda b_, d, t: (b_, nch - 1 - t, d)),
            pl.BlockSpec((1, chunk, bd), lambda b_, d, t: (b_, nch - 1 - t, d)),
            pl.BlockSpec((1, chunk, 1, n), lambda b_, d, t: (b_, nch - 1 - t, d, 0)),
            pl.BlockSpec((1, chunk, 1, n), lambda b_, d, t: (b_, nch - 1 - t, d, 0)),
            pl.BlockSpec((1, bd, n), lambda b_, d, t: (b_, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bt, s, di), f32),
            jax.ShapeDtypeStruct((bt, s, di), f32),
            jax.ShapeDtypeStruct((bt, s, nd, n), f32),
            jax.ShapeDtypeStruct((bt, s, nd, n), f32),
            jax.ShapeDtypeStruct((bt, di, n), f32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32),
                        pltpu.VMEM((bd, n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, b, c, a, h_starts, dy)
    return dx, ddt, db_p.sum(axis=2), dc_p.sum(axis=2), da_p.sum(axis=0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def selective_scan_trainable(x, dt, b, c, a, h0, chunk=128, bd=512):
    """Differentiable fused scan: y only (h_final not exposed — train path).

    Note dA flows through the ddt-style term accumulated in the bwd kernel's
    ddt computation via the chain rule below; h0 grad returned as zeros (train
    always starts from h0 = 0)."""
    y, _, _ = selective_scan(x, dt, b, c, a, h0, chunk=chunk, bd=bd)
    return y


def _sst_fwd(x, dt, b, c, a, h0, chunk, bd):
    y, _, h_starts = selective_scan(x, dt, b, c, a, h0, chunk=chunk, bd=bd)
    return y, (x, dt, b, c, a, h0, h_starts)


def _sst_bwd(chunk, bd, res, dy):
    x, dt, b, c, a, h0, h_starts = res
    dx, ddt, db, dc, da = selective_scan_bwd(
        x.astype(jnp.float32), dt.astype(jnp.float32), b.astype(jnp.float32),
        c.astype(jnp.float32), a, h_starts, dy.astype(jnp.float32),
        chunk=chunk, bd=bd)
    dh0 = jnp.zeros_like(h0)   # training always starts from h0 = 0
    return (dx.astype(x.dtype), ddt.astype(dt.dtype), db.astype(b.dtype),
            dc.astype(c.dtype), da.astype(a.dtype), dh0)


selective_scan_trainable.defvjp(_sst_fwd, _sst_bwd)
