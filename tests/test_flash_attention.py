"""Flash-attention kernel vs naive oracle: fwd + grads, shape/window sweeps,
fully-masked-row regression, and the paged (page-pool + page-table) kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention, flash_attention_paged


def naive(q, k, v, causal=True, window=0):
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) / (d ** 0.5)
    qp = jnp.arange(q.shape[1])[:, None]
    kp = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones_like(s, bool)
    if causal:
        mask &= qp >= kp
    if window:
        mask &= (qp - kp) < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(v.dtype), v)


def mk(bh, sq, sk, d, dtype=jnp.float32, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(k1, (bh, sq, d), dtype),
            jax.random.normal(k2, (bh, sk, d), dtype),
            jax.random.normal(k3, (bh, sk, d), dtype))


@pytest.mark.parametrize("sq,sk,d,bq,bk", [
    (128, 128, 64, 128, 128),
    (256, 256, 64, 128, 128),
    (100, 100, 32, 64, 64),     # padded path
    (64, 192, 32, 32, 64),      # cross lengths
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_fwd_matches_naive(sq, sk, d, bq, bk, causal):
    q, k, v = mk(2, sq, sk, d)
    got = flash_attention(q, k, v, 0, causal, True)
    want = naive(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("window", [8, 64, 1024])
def test_flash_window_matches_naive(window):
    q, k, v = mk(2, 128, 128, 32, seed=1)
    got = flash_attention(q, k, v, window, True, True)
    want = naive(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_flash_grads_match_naive():
    q, k, v = mk(1, 64, 64, 32, seed=2)

    def loss_flash(q, k, v):
        return jnp.sum(jnp.sin(flash_attention(q, k, v, 0, True, True)))

    def loss_naive(q, k, v):
        return jnp.sum(jnp.sin(naive(q, k, v)))

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=5e-3, atol=5e-3)


def test_flash_grads_windowed():
    q, k, v = mk(1, 96, 96, 32, seed=3)
    g1 = jax.grad(lambda *a: jnp.sum(flash_attention(*a, 32, True, True) ** 2),
                  argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda *a: jnp.sum(naive(*a, causal=True, window=32) ** 2),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=5e-3, atol=5e-3)


def test_flash_bf16():
    q, k, v = mk(2, 128, 128, 64, jnp.bfloat16, seed=4)
    got = flash_attention(q, k, v, 0, True, True)
    want = naive(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_flash_fully_masked_rows_exact_zero():
    """Regression (ISSUE 6): a q row whose sliding window lies entirely
    beyond the available keys has NO valid entry in ANY k block. The online
    softmax left m_new at NEG_INF for such blocks, so every masked entry
    contributed exp(s - m_new) = exp(0) = 1 of phantom mass — the row came
    out as the MEAN of all v rows instead of 0. With sq=256, sk=128, w=16,
    rows >= sk - 1 + w = 143 are fully masked (k only covers positions
    <= 127 but the window demands k_pos > q_pos - 16 >= 127)."""
    sq, sk, w = 256, 128, 16
    q, k, v = mk(2, sq, sk, 32, seed=6)
    got = np.asarray(flash_attention(q, k, v, w, True, True))
    dead = sk - 1 + w
    assert np.all(got[:, dead:] == 0.0), \
        "fully-masked rows must be exactly 0, not mean(v)"
    assert np.any(got[:, dead:dead + 1] != got[:, :1])  # sanity: not all-0
    want = np.asarray(naive(q, k, v, causal=True, window=w))
    np.testing.assert_allclose(got[:, :dead], want[:, :dead],
                               rtol=2e-3, atol=2e-3)


# -- paged kernel -------------------------------------------------------------

def _paged_ref(q, k_pool, v_pool, pt, lengths, q_start, window=0, scale=None,
               causal=True):
    """Gather-then-softmax oracle for the paged kernel."""
    b, h, sq, d = q.shape
    _, ps, kv, _ = k_pool.shape
    group = max(h // kv, 1)
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    out = np.zeros(q.shape[:3] + (v_pool.shape[-1],), np.float32)
    for bi in range(b):
        kg = np.concatenate([np.asarray(k_pool)[p] for p in pt[bi]], 0)
        vg = np.concatenate([np.asarray(v_pool)[p] for p in pt[bi]], 0)
        for hi in range(h):
            s = (np.asarray(q[bi, hi], np.float32)
                 @ kg[:, hi // group].astype(np.float32).T) * scale
            qp = q_start[bi] + np.arange(sq)[:, None]
            kp = np.arange(kg.shape[0])[None, :]
            m = (kp < lengths[bi]) & np.ones((sq, 1), bool)
            if causal:
                m = m & (qp >= kp)
            if window:
                m = m & ((qp - kp) < window)
            s = np.where(m, s, -np.inf)
            with np.errstate(invalid="ignore"):
                p = np.exp(s - s.max(1, keepdims=True))
                p = np.nan_to_num(p / np.maximum(p.sum(1, keepdims=True),
                                                 1e-30))
            p = np.where(m, p, 0.0)
            out[bi, hi] = p @ vg[:, hi // group].astype(np.float32)
    return out


def _mk_paged(b, h, kv, sq, d, dv, n_pages, ps, max_pages, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, h, sq, d)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(n_pages, ps, kv, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(n_pages, ps, kv, dv)), jnp.float32)
    # page tables deliberately permuted: physical order != logical order
    pt = np.stack([rng.permutation(n_pages)[:max_pages] for _ in range(b)])
    return q, kp, vp, pt.astype(np.int32)


@pytest.mark.parametrize("sq,window", [(1, 0), (4, 0), (4, 24)])
def test_paged_matches_gathered_reference(sq, window):
    b, h, kv, d, ps, mp = 2, 4, 2, 32, 8, 6
    q, kp, vp, pt = _mk_paged(b, h, kv, sq, d, d, 16, ps, mp, seed=1)
    lengths = np.asarray([ps * mp, 19], np.int32)     # full + ragged
    q_start = lengths - sq                            # decode chunk at the end
    got = flash_attention_paged(q, kp, vp, pt, lengths, q_start, window,
                                interpret=True)
    want = _paged_ref(q, kp, vp, pt, lengths, q_start, window)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3, atol=2e-3)


def test_paged_fully_masked_rows_exact_zero():
    """Same NEG_INF regression surface as the dense kernel, hit the way the
    serving path hits it: a decode chunk whose early rows out-window every
    valid key. Also: a zero-length sequence returns exactly 0."""
    b, h, kv, d, ps, mp = 2, 2, 2, 32, 8, 4
    q, kp, vp, pt = _mk_paged(b, h, kv, 8, d, d, 8, ps, mp, seed=2)
    lengths = np.asarray([16, 0], np.int32)
    q_start = np.asarray([30, 0], np.int32)   # rows at 30.. vs keys < 16
    got = np.asarray(flash_attention_paged(q, kp, vp, pt, lengths, q_start,
                                           16, interpret=True))
    # row position p attends (p-16, p]: p >= 31 sees nothing of keys < 16
    assert np.all(got[0, :, 1:] == 0.0)
    assert np.all(got[1] == 0.0), "zero-length sequence must output 0"
    want = _paged_ref(q, kp, vp, pt, lengths, q_start, 16)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_paged_mla_shape_and_scale():
    """Absorbed-MLA decode shape: KV=1 head, dv != d, explicit scale."""
    b, h, d, dv, ps, mp = 2, 4, 40, 32, 4, 4
    q, kp, vp, pt = _mk_paged(b, h, 1, 1, d, dv, 8, ps, mp, seed=3)
    lengths = np.asarray([13, 9], np.int32)
    q_start = lengths - 1
    scale = 1.0 / (48 ** 0.5)       # pre-absorption head dim, not d
    got = flash_attention_paged(q, kp, vp, pt, lengths, q_start, 0,
                                scale=scale, interpret=True)
    want = _paged_ref(q, kp, vp, pt, lengths, q_start, 0, scale=scale)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3, atol=2e-3)


def test_flash_traced_window():
    """window as a traced scalar under jit/scan (the gemma3 pattern)."""
    q, k, v = mk(1, 64, 64, 32, seed=5)

    @jax.jit
    def run(w):
        return flash_attention(q, k, v, w, True, True)

    for w in (0, 16):
        got = run(jnp.asarray(w, jnp.int32))
        want = naive(q, k, v, causal=True, window=w)
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)
