"""Per-kernel shape/dtype sweeps vs the pure-jnp oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref
from repro.kernels.ffip_gemm import ffip_gemm_y, ffip_gemm
from repro.kernels.fip_gemm import fip_gemm
from repro.kernels.baseline_gemm import baseline_gemm
from repro.core import fip

SHAPES = [
    (8, 8, 8),
    (16, 32, 16),
    (128, 128, 128),
    (64, 256, 32),
    (100, 60, 36),     # padding path
    (1, 130, 257),     # odd N, K padding
]
DTYPES = [jnp.float32, jnp.bfloat16, jnp.int8]
ALGOS = ["baseline", "fip", "ffip"]


def make_inputs(m, k, n, dtype, seed=0):
    ka, kb = jax.random.split(jax.random.PRNGKey(seed))
    if dtype == jnp.int8:
        a = jax.random.randint(ka, (m, k), -128, 128, dtype=jnp.int32).astype(jnp.int8)
        b = jax.random.randint(kb, (k, n), -128, 128, dtype=jnp.int32).astype(jnp.int8)
    else:
        a = jax.random.normal(ka, (m, k), jnp.float32).astype(dtype)
        b = jax.random.normal(kb, (k, n), jnp.float32).astype(dtype)
    return a, b


def tol_for(dtype, k):
    if dtype == jnp.bfloat16:
        return dict(rtol=5e-2, atol=5e-1)
    return dict(rtol=1e-4, atol=1e-3 * max(1, k // 64))


@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("m,k,n", SHAPES)
def test_kernel_matches_oracle(algo, dtype, m, k, n):
    a, b = make_inputs(m, k, n, dtype)
    got = ops.matmul(a, b, algo=algo, interpret=True)
    if dtype == jnp.int8:
        want = np.asarray(a, np.int64) @ np.asarray(b, np.int64)
        np.testing.assert_array_equal(np.asarray(got, np.int64), want)
    else:
        want = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
        np.testing.assert_allclose(np.asarray(got, np.float64), want,
                                   **tol_for(dtype, k))


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (16, 16, 4), (32, 8, 16)])
def test_block_shape_sweep_ffip(bm, bn, bk):
    m, k, n = 64, 32, 48
    a, b = make_inputs(m, k, n, jnp.float32, seed=3)
    got = ffip_gemm(a, b, bm=bm, bn=bn, bk=bk, interpret=True)
    want = ref.matmul_ref(a, b, "baseline")
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (16, 16, 4)])
def test_block_shape_sweep_fip(bm, bn, bk):
    m, k, n = 32, 16, 32
    a, b = make_inputs(m, k, n, jnp.float32, seed=4)
    got = fip_gemm(a, b, bm=bm, bn=bn, bk=bk, interpret=True)
    np.testing.assert_allclose(got, ref.matmul_ref(a, b, "baseline"),
                               rtol=1e-4, atol=1e-3)


def test_ffip_y_operand_never_materializes_b():
    """FFIP kernel consumes y only; reconstruct inside — int path bit-exact."""
    a, b = make_inputs(32, 16, 24, jnp.int8, seed=5)
    y = fip.make_y(b.astype(jnp.int32))   # 1-extra-bit storage, §4.4
    got = ffip_gemm_y(a.astype(jnp.int32), y, bm=8, bn=8, bk=8, interpret=True)
    want = np.asarray(a, np.int64) @ np.asarray(b, np.int64)
    np.testing.assert_array_equal(np.asarray(got, np.int64), want)


def test_fold_beta_kernel_plus_bias():
    """Kernel with fold_beta=True + Eq.(15) bias == full product."""
    a, b = make_inputs(16, 8, 8, jnp.int8, seed=6)
    a32, b32 = a.astype(jnp.int32), b.astype(jnp.int32)
    folded = fip.fold_beta_into_bias(b32)
    got = fip_gemm(a32, b32, bm=8, bn=8, bk=8, interpret=True,
                   fold_beta=True) + folded[None, :]
    want = np.asarray(a, np.int64) @ np.asarray(b, np.int64)
    np.testing.assert_array_equal(np.asarray(got, np.int64), want)


def test_batched_wrapper():
    ka, kb = jax.random.split(jax.random.PRNGKey(7))
    a = jax.random.normal(ka, (2, 3, 16, 32))
    b = jax.random.normal(kb, (32, 8))
    got = ops.matmul(a, b, algo="ffip", interpret=True)
    np.testing.assert_allclose(got, a @ b, rtol=1e-4, atol=1e-3)


def test_baseline_kernel_large_block():
    a, b = make_inputs(256, 512, 128, jnp.float32, seed=8)
    got = baseline_gemm(a, b, bm=128, bn=128, bk=128, interpret=True)
    np.testing.assert_allclose(got, np.asarray(a, np.float64) @ np.asarray(b, np.float64),
                               rtol=1e-4, atol=1e-2)
