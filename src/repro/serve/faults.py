"""Deterministic fault injection for multi-replica serving.

A :class:`FaultPlan` is a seeded, fully explicit schedule of faults keyed by
``(replica index, replica-local dispatch index)`` — no wall-clock, no
randomness at fire time — so a chaos run is exactly reproducible: the same
plan over the same workload produces the same retries, the same failovers,
and the same final tokens. Four fault kinds cover the serving failure
surface:

``raise``
    The replica's step raises :class:`InjectedFault` mid-dispatch (models a
    device error / XLA crash). Outstanding requests are aborted and retried
    on a healthy replica.
``hang``
    The replica's step consumes ``hang_s`` seconds of the injected
    :class:`FakeClock` and does no work; the router's step timeout fires and
    treats it as a wedged replica. (Hang faults REQUIRE a fake clock — a
    real hang cannot be interrupted deterministically.)
``exhaust``
    The replica's page pool is drained for ``duration`` dispatches (the
    router seizes every free page, holding real allocator references), so
    mid-flight allocations hit genuine pool exhaustion and admission loses
    all headroom. Contiguous replicas, having no pool, raise an
    :class:`InjectedFault` instead. Pages are released when the window ends.
``poison``
    The replica's step completes but every completion surfaced in the window
    has its final token corrupted to an out-of-vocabulary id — the router's
    output-sanity check must catch it and retry on another replica.

Plans serialize to/from JSON (``--fault-plan`` on the serve launcher accepts
an inline JSON object or ``@path/to/plan.json``).
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import List, Sequence, Tuple

KINDS = ("raise", "hang", "exhaust", "poison")


class InjectedFault(RuntimeError):
    """A fault fired by a FaultPlan (never raised in production serving)."""

    def __init__(self, kind: str, replica: int, dispatch: int):
        super().__init__(f"injected fault kind={kind!r} on replica "
                         f"{replica} at dispatch {dispatch}")
        self.kind = kind
        self.replica = replica
        self.dispatch = dispatch


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    kind: str                 # one of KINDS
    replica: int              # replica index the fault targets
    at_dispatch: int          # replica-local dispatch index it first fires
    duration: int = 1         # consecutive dispatches it stays active
    hang_s: float = 0.0       # hang only; 0 => 2x the router step timeout

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(expected one of {KINDS})")
        if self.replica < 0 or self.at_dispatch < 0 or self.duration < 1:
            raise ValueError(f"bad fault spec: {self}")

    def active_at(self, dispatch: int) -> bool:
        return self.at_dispatch <= dispatch < self.at_dispatch + self.duration


class FaultPlan:
    """An immutable, seeded schedule of :class:`FaultSpec` entries.

    ``seed`` feeds the ROUTER's jitter rng (retry backoff), not the fault
    schedule itself — firing is purely positional, so determinism never
    depends on timing.
    """

    def __init__(self, faults: Sequence[FaultSpec] = (), *, seed: int = 0):
        self.faults: Tuple[FaultSpec, ...] = tuple(
            f if isinstance(f, FaultSpec) else FaultSpec(**f) for f in faults)
        self.seed = seed

    def active(self, replica: int, dispatch: int) -> List[FaultSpec]:
        return [f for f in self.faults
                if f.replica == replica and f.active_at(dispatch)]

    @property
    def has_hangs(self) -> bool:
        return any(f.kind == "hang" for f in self.faults)

    def to_json(self) -> str:
        return json.dumps({
            "seed": self.seed,
            "faults": [dataclasses.asdict(f) for f in self.faults]})

    @classmethod
    def parse(cls, text: str) -> "FaultPlan":
        """Inline JSON object, or ``@path`` to a JSON file."""
        if text.startswith("@"):
            text = pathlib.Path(text[1:]).read_text()
        obj = json.loads(text)
        return cls([FaultSpec(**f) for f in obj.get("faults", [])],
                   seed=int(obj.get("seed", 0)))

    @classmethod
    def flaky_replica(cls, replica: int = 0, *, start: int = 2,
                      period: int = 4, rounds: int = 4,
                      kinds: Sequence[str] = ("raise", "hang"),
                      seed: int = 0) -> "FaultPlan":
        """A replica that flaps: every ``period`` dispatches it fails once,
        cycling through ``kinds`` — the serve_bench ``results_faults``
        workload."""
        faults = [FaultSpec(kind=kinds[i % len(kinds)], replica=replica,
                            at_dispatch=start + i * period)
                  for i in range(rounds)]
        return cls(faults, seed=seed)


class FakeClock:
    """Deterministic monotonic clock: callable like ``time.monotonic`` but
    only moves when told to. The router advances it a fixed ``tick_s`` per
    drive tick; hang faults advance it past the step timeout in one jump."""

    def __init__(self, t0: float = 0.0):
        self.t = float(t0)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"clock cannot go backwards (dt={dt})")
        self.t += dt
        return self.t
