"""GEMM micro-benchmarks: the three arithmetic paths, timed on this host.

CAVEAT printed with results: this container is CPU-only; interpret-mode Pallas
timings measure the emulation harness, not TPU silicon. The load-bearing
numbers are the arithmetic-complexity counters (measured multiplies via jaxpr
instrumentation), which are platform-independent — those are the paper's Eq.5/6.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from repro.core import analytical as an
from repro.core import fip
from repro.kernels import ops


def _time(fn, *args, iters: int = 3) -> float:
    # warmup: ONE call (jax.block_until_ready handles tuples/pytrees). The
    # old isinstance-probe evaluated fn(*args) twice, doubling compile+run
    # warmup cost for every timed entry.
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> List[str]:
    rows = ["gemm_micro.name,us_per_call,derived"]
    key = jax.random.PRNGKey(0)
    for m, k, n in [(256, 256, 256), (512, 1024, 512)]:
        ka, kb = jax.random.split(key)
        a = jax.random.normal(ka, (m, k), jnp.float32)
        b = jax.random.normal(kb, (k, n), jnp.float32)
        t_xla = _time(jax.jit(lambda a, b: a @ b), a, b)
        t_ref_fip = _time(jax.jit(lambda a, b: fip.fip_matmul(a, b, k_chunk=32)), a, b)
        rows.append(f"gemm_micro.xla_base_{m}x{k}x{n},{t_xla:.0f},")
        rows.append(f"gemm_micro.fip_ref_{m}x{k}x{n},{t_ref_fip:.0f},cpu-emulation-only")
        # measured multiply counts (the real claim):
        mb = fip.count_multiplies_in_jaxpr(lambda a, b: a @ b, a, b)
        mf = fip.count_multiplies_in_jaxpr(lambda a, b: fip.fip_matmul(a, b), a, b)
        rows.append(f"gemm_micro.mults_{m}x{k}x{n},{mf},"
                    f"ratio_vs_baseline={mf / mb:.4f} (Eq.5: "
                    f"{an.fip_mults(m, k, n) / an.baseline_mults(m, k, n):.4f})")
    # pallas kernels (interpret) on a small tile — correctness-mode timing
    a = jax.random.normal(key, (128, 128), jnp.float32)
    b = jax.random.normal(key, (128, 128), jnp.float32)
    for algo in ("baseline", "fip", "ffip"):
        t = _time(lambda a, b, al=algo: ops.matmul(a, b, algo=al, interpret=True),
                  a, b, iters=2)
        rows.append(f"gemm_micro.pallas_{algo}_128_interpret,{t:.0f},interpret-mode")
    return rows
