"""Distribution layer: process-global mesh context + sharding rule engine.

`repro.dist.context` carries the active `jax.sharding.Mesh` so model code
(flash attention, selective scan) can shard_map itself without threading the
mesh through every call signature; `repro.dist.sharding` turns parameter /
batch / cache pytrees into `PartitionSpec` trees via a name/shape rule table
with hard divisibility guards.
"""
from repro.dist.context import get_mesh, mesh_context  # noqa: F401
from repro.dist.sharding import (  # noqa: F401
    cache_specs, data_specs, param_specs, to_named)
