"""Resumable training loop: data + step + checkpoints + watchdog + metrics."""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.ckpt.manager import CheckpointManager
from repro.data.pipeline import make_pipeline
from repro.models.model import Model
from repro.optim import adamw
from repro.train.step import TrainConfig, make_train_step
from repro.train.watchdog import StepWatchdog, WatchdogConfig

PyTree = Any


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    global_batch: int = 8
    seq_len: int = 128
    ckpt_dir: str = ""
    ckpt_every: int = 50
    log_every: int = 10
    seed: int = 0


def train(model: Model, *, loop_cfg: LoopConfig,
          train_cfg: Optional[TrainConfig] = None,
          log_fn: Callable[[Dict], None] = lambda m: None,
          ) -> Dict[str, Any]:
    """Runs (or resumes) training; returns final params + history."""
    tcfg = train_cfg or TrainConfig()
    step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0, 1))

    params = model.init(jax.random.PRNGKey(loop_cfg.seed))
    opt_state = adamw.init(params)
    start_step = 0

    mgr = None
    if loop_cfg.ckpt_dir:
        mgr = CheckpointManager(loop_cfg.ckpt_dir)
        if mgr.latest_step() is not None:
            (params, opt_state), extra = mgr.restore((params, opt_state))
            start_step = int(extra.get("data_step", mgr.latest_step()))

    pipe = make_pipeline(model.cfg, loop_cfg.global_batch, loop_cfg.seq_len,
                         seed=loop_cfg.seed, start_step=start_step)
    dog = StepWatchdog(WatchdogConfig())
    history = []
    try:
        t_prev = time.monotonic()
        for _ in range(start_step, loop_cfg.total_steps):
            data_step, batch = next(pipe)
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            now = time.monotonic()
            dog.observe(data_step, now - t_prev)
            t_prev = now
            if data_step % loop_cfg.log_every == 0 or \
                    data_step == loop_cfg.total_steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m["data_step"] = data_step
                history.append(m)
                log_fn(m)
            if mgr is not None and loop_cfg.ckpt_every and \
                    (data_step + 1) % loop_cfg.ckpt_every == 0:
                mgr.save(data_step + 1, (params, opt_state),
                         extra={"data_step": data_step + 1})
    finally:
        pipe.close()
        if mgr is not None:
            mgr.wait()
    return dict(params=params, opt_state=opt_state, history=history,
                straggler_events=dog.events)
