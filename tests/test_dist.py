"""Distribution-layer tests: sharding rules, cost model, HLO collective parser,
and a real multi-device pjit train step (8 host devices via subprocess-free
check when available)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro import configs
from repro.dist import sharding as shd
from repro.launch import costs, roofline
from repro.launch.inputs import params_specs_struct


def make_mesh_2d(data=2, model=2):
    n = jax.device_count()
    if n < data * model:
        pytest.skip(f"needs {data * model} devices, have {n}")
    return jax.make_mesh((data, model), ("data", "model"))


def test_param_specs_divisibility_guard():
    """No rule ever assigns an axis that does not divide the dim."""
    mesh16 = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    # emulate 16x16 shapes by checking with the real production mesh object is
    # impossible on 1 device; instead check the rule function directly.
    from repro.dist.sharding import _match_spec

    class FakeMesh:
        axis_names = ("data", "model")
        class devices:  # noqa
            shape = (16, 16)

    # gemma3: 8 q-heads -> wq out dim 2048 divisible, KV cache kv=4 not
    spec = _match_spec("layers/attn/wq/w", (34, 2560, 2048), FakeMesh, "expert")
    assert spec == P(None, "data", "model")
    # a dim of 8 on a 16-way axis must stay unsharded
    spec = _match_spec("layers/attn/wq/b", (34, 8), FakeMesh, "expert")
    assert spec == P(None, None)


def test_param_specs_cover_all_archs():
    """Every leaf of every arch gets a spec; dims always divisible."""
    class FakeMesh:
        axis_names = ("data", "model")
        class devices:  # noqa
            shape = (16, 16)

    axis_size = {"data": 16, "model": 16}
    for arch in sorted(configs.ARCHS):
        cfg = configs.get_config(arch)
        params = params_specs_struct(cfg)
        specs = shd.param_specs(params, FakeMesh,
                                moe_partition=cfg.moe.partition if cfg.moe else "expert")
        leaves = jax.tree_util.tree_leaves_with_path(params)
        spec_leaves = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        assert len(leaves) == len(spec_leaves)
        for (path, leaf), spec in zip(leaves, spec_leaves):
            for dim, ax in enumerate(spec):
                if ax is None:
                    continue
                assert leaf.shape[dim] % axis_size[ax] == 0, \
                    (arch, jax.tree_util.keystr(path), leaf.shape, spec)


def test_moe_partition_modes_differ():
    class FakeMesh:
        axis_names = ("data", "model")
        class devices:  # noqa
            shape = (16, 16)

    cfg = configs.get_config("deepseek-v2-lite-16b")
    params = params_specs_struct(cfg)
    s_expert = shd.param_specs(params, FakeMesh, moe_partition="expert")
    s_ffn = shd.param_specs(params, FakeMesh, moe_partition="ffn")
    def get(t):  # first w_gate spec
        return t["layers"]["ffn"]["w_gate"]
    assert get(s_expert)[1] == "model"       # (L, E, d, f): E sharded
    assert get(s_ffn)[3] == "model"          # f sharded


def test_pjit_train_step_multi_device():
    """Real sharded train step on all host devices (data-parallel)."""
    n = jax.device_count()
    if n < 2:
        pytest.skip("single device")
    mesh = jax.make_mesh((n, 1), ("data", "model"))
    cfg = configs.smoke_config(configs.get_config("minicpm-2b"))
    from repro.models.model import build_model
    from repro.optim import adamw
    from repro.train.step import TrainConfig, make_train_step
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw.init(params)
    pspecs = shd.param_specs(params, mesh)
    batch = {
        "tokens": jnp.zeros((2 * n, 16), jnp.int32),
        "labels": jnp.zeros((2 * n, 16), jnp.int32),
    }
    bspecs = shd.data_specs(batch, mesh)
    step = jax.jit(make_train_step(model, TrainConfig()),
                   in_shardings=(shd.to_named(pspecs, mesh),
                                 shd.to_named(adamw.AdamWState(
                                     step=P(), m=pspecs, v=pspecs), mesh),
                                 shd.to_named(bspecs, mesh)))
    with mesh:
        p2, o2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))


# --- cost model ---------------------------------------------------------------

def test_jaxpr_cost_scan_multiplies_trip_count():
    def body_mm(a, b):
        def f(x, _):
            return x @ b, None
        out, _ = jax.lax.scan(f, a, None, length=7)
        return out

    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c1 = costs.fn_cost(lambda a, b: a @ b, a, b)
    c7 = costs.fn_cost(body_mm, a, b)
    assert c7.flops == pytest.approx(7 * c1.flops, rel=0.05)


def test_jaxpr_cost_dot_flops_exact():
    a = jax.ShapeDtypeStruct((32, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 16), jnp.float32)
    c = costs.fn_cost(lambda a, b: a @ b, a, b)
    assert c.flops == 2 * 32 * 128 * 16


# --- HLO collective parser ------------------------------------------------------

HLO_SAMPLE = """
HloModule test

%while_body.1 (p: (s32[], f32[128])) -> (s32[], f32[128]) {
  %ar = f32[128]{0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
}

%while_cond.1 (p: (s32[], f32[128])) -> pred[] {
  %c = s32[] constant(30)
  %cmp = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[256]) -> f32[256] {
  %ag = f32[256]{0} all-gather(%a), replica_groups=[2,8]<=[16], dimensions={0}
  %w = (s32[], f32[128]) while(%t), condition=%while_cond.1, body=%while_body.1
}
"""


def test_collective_parser_while_aware():
    stats = roofline.collective_bytes(HLO_SAMPLE)
    # all-gather once: 256*4 bytes * (8-1)/8
    assert stats.counts["all-gather"] == 1
    assert stats.bytes_by_kind["all-gather"] == pytest.approx(256 * 4 * 7 / 8)
    # all-reduce inside while x30 trips: 2*128*4*(3/4) each
    assert stats.counts["all-reduce"] == 30
    assert stats.bytes_by_kind["all-reduce"] == pytest.approx(30 * 2 * 128 * 4 * 3 / 4)


def test_roofline_fraction_definition():
    stats = roofline.CollectiveStats(counts={}, bytes_by_kind={})
    r = roofline.roofline_report(197e12 * 256, 0.0, stats, 256)
    assert r["roofline_fraction"] == pytest.approx(1.0)   # pure compute = 1.0
    assert r["bottleneck"] == "compute_s"
