"""Algorithm 1 (conv->GEMM in-place mapping) + §5.1.1 partitioning tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import im2col
from repro.core.gemm import GemmConfig, gemm


@pytest.mark.parametrize("h,w,cin,cout,kh,kw,stride,pad", [
    (8, 8, 3, 4, 3, 3, 1, 1),
    (12, 10, 2, 5, 3, 3, 2, 0),
    (7, 7, 4, 4, 1, 1, 1, 0),
    (9, 9, 3, 2, 5, 5, 2, 2),
])
def test_conv_via_gemm_matches_lax_conv(h, w, cin, cout, kh, kw, stride, pad):
    kx, kk = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (2, h, w, cin))
    kernel = jax.random.normal(kk, (kh, kw, cin, cout))
    got = im2col.conv2d_via_gemm(x, kernel, stride=stride, pad=pad)
    want = jax.lax.conv_general_dilated(
        x, kernel, (stride, stride), [(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_conv_via_ffip_gemm():
    """The paper's full pipeline: Algorithm-1 mapping + FFIP arithmetic."""
    kx, kk = jax.random.split(jax.random.PRNGKey(1))
    x = jax.random.normal(kx, (1, 8, 8, 4))
    kernel = jax.random.normal(kk, (3, 3, 4, 8))
    ffip_fn = lambda a, b: gemm(a, b, GemmConfig(algo="ffip", impl="ref"))
    got = im2col.conv2d_via_gemm(x, kernel, stride=1, pad=1, gemm_fn=ffip_fn)
    want = jax.lax.conv_general_dilated(
        x, kernel, (1, 1), [(1, 1), (1, 1)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def test_multi_digit_counter_matches_nested_loops():
    """The Fig.-5 counter reproduces Algorithm 1's nested-loop addresses."""
    digits = [im2col.Digit("kh", 3, 100), im2col.Digit("kw", 2, 10),
              im2col.Digit("c", 4, 1)]
    got = im2col.MultiDigitCounter(digits).addresses()
    want = [kh * 100 + kw * 10 + c
            for kh in range(3) for kw in range(2) for c in range(4)]
    np.testing.assert_array_equal(got, np.asarray(want))


def test_partition_interleave_roundtrip():
    """§5.1.1: B-way partition + round-robin interleave is lossless when the
    stream walks slices in order."""
    ws, n_blocks = 2, 2
    w_idx = np.repeat(np.arange(8), 1)   # walk w = 0..7, slices of width 2
    blocks = im2col.partition_blocks(w_idx, ws, n_blocks)
    assert all(len(b) == 4 for b in blocks)
    merged = im2col.interleave_blocks(
        [b.reshape(-1, ws) for b in blocks])  # interleave slice-wise
    np.testing.assert_array_equal(np.concatenate(merged.reshape(-1, ws)), w_idx)
