"""Host-side bookkeeping for the block-paged KV cache.

The device side (models/attention.py, kernels/flash_attention.py) only sees
a page POOL per cache leaf plus a ``(B, max_pages)`` int32 page table; this
module owns everything that decides WHAT those tables contain:

* :class:`PageAllocator` — a refcounted free list over the pool. A page is
  held by every sequence whose table references it plus (optionally) the
  prefix index, and returns to the free list when the last reference drops.
* :func:`page_keys` / :func:`partial_key` — rolling (chained) hashes of full
  prompt-token pages. Chaining makes a page's key depend on its entire
  prefix, so equal keys imply equal KV content and a lookup can only match a
  page whose WHOLE history matches — matching is a simple walk that stops at
  the first miss.
* :class:`PrefixIndex` — hash -> page id map with LRU eviction. The index
  holds its own reference on every registered page, so a prefix page
  outlives the request that computed it until memory pressure evicts it.

Copy-on-write lives in the batcher (it owns the device cache): a shared page
is never written through — a writer holding a page with refcount > 1 copies
it to a fresh page first (``BatchServer._ensure_pages``).
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import List, Optional

import numpy as np


class PageAllocator:
    """Refcounted fixed-pool page allocator (host side, O(1) ops).

    Invariants (tests/test_serve_paged.py churns these):
      * ``free_count + in_use == num_pages``
      * every allocated page has refcount >= 1; free pages have refcount 0
      * ``alloc`` never returns a page that is still referenced
    """

    def __init__(self, num_pages: int):
        if num_pages < 1:
            raise ValueError(f"num_pages must be >= 1, got {num_pages}")
        self.num_pages = num_pages
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._refs = np.zeros((num_pages,), np.int32)
        self.peak_in_use = 0

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.num_pages - len(self._free)

    def refcount(self, page: int) -> int:
        return int(self._refs[page])

    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError("page pool exhausted")
        page = self._free.pop()
        assert self._refs[page] == 0, f"free page {page} had references"
        self._refs[page] = 1
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return page

    def incref(self, page: int):
        assert self._refs[page] > 0, f"incref on unallocated page {page}"
        self._refs[page] += 1

    def decref(self, page: int) -> bool:
        """Drop one reference; returns True if the page was freed."""
        assert self._refs[page] > 0, f"decref on unallocated page {page}"
        self._refs[page] -= 1
        if self._refs[page] == 0:
            self._free.append(page)
            return True
        return False


def _tok_bytes(tokens) -> bytes:
    return np.ascontiguousarray(np.asarray(tokens, np.int64)).tobytes()


def page_keys(prompt, page_size: int) -> List[bytes]:
    """Chained digest per FULL prompt page: key_i commits to tokens [0,
    (i+1)*page_size), so two prompts share key_i iff their first i+1 pages
    of tokens are identical."""
    keys = []
    prev = b""
    n_full = len(prompt) // page_size
    for i in range(n_full):
        page = prompt[i * page_size:(i + 1) * page_size]
        prev = hashlib.sha1(prev + _tok_bytes(page)).digest()
        keys.append(prev)
    return keys


def partial_key(prompt, page_size: int) -> Optional[bytes]:
    """Key of the terminal PARTIAL page (None if the prompt is page-aligned).
    Commits to the full-page chain, the tail length, and the tail tokens —
    only an exact whole-prompt match can hit it."""
    n = len(prompt)
    tail = n % page_size
    if tail == 0:
        return None
    prev = page_keys(prompt, page_size)
    prev = prev[-1] if prev else b""
    return hashlib.sha1(prev + b"partial:%d:" % tail
                        + _tok_bytes(prompt[n - tail:])).digest()


class PrefixIndex:
    """LRU map from chained page keys to pool page ids.

    Holds one allocator reference per registered page. Eviction only drops
    the INDEX's reference — sequences currently using the page are
    unaffected; the page is freed once the last of them finishes.
    """

    def __init__(self, allocator: PageAllocator):
        self._alloc = allocator
        self._by_key: "OrderedDict[bytes, int]" = OrderedDict()

    def __len__(self) -> int:
        return len(self._by_key)

    def get(self, key: bytes) -> Optional[int]:
        page = self._by_key.get(key)
        if page is not None:
            self._by_key.move_to_end(key)
        return page

    def register(self, key: bytes, page: int):
        """Idempotent: a key that is already registered keeps its existing
        page (the content is identical by construction of the chained key)."""
        if key in self._by_key:
            self._by_key.move_to_end(key)
            return
        self._alloc.incref(page)
        self._by_key[key] = page

    def evict_lru(self, n: int = 1) -> int:
        """Drop the n least-recently-used entries; returns pages FREED (an
        entry whose page is still referenced elsewhere frees nothing now)."""
        freed = 0
        for _ in range(min(n, len(self._by_key))):
            _, page = self._by_key.popitem(last=False)
            freed += bool(self._alloc.decref(page))
        return freed
