"""Pluggable GEMM provider — the paper's 'drop-in systolic array swap'.

The paper's headline architectural claim is that an FFIP MXU substitutes for a
traditional systolic array "without fundamentally altering the accelerator's
functionality or internal interfaces in any way". We realize that claim at
the framework level: every matmul in the model zoo calls :func:`gemm`, and a
context-scoped :class:`GemmConfig` chooses

    algo ∈ {baseline, fip, ffip}   ×   impl ∈ {xla, ref, pallas}

with identical numerics (bit-exact for ints, allclose for floats). The
default production path is (baseline, xla) — the MXU path; see DESIGN.md §2
for why FIP arithmetic is not a throughput win on TPU silicon.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
import threading
from typing import Literal, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import fip

Array = jax.Array
Algo = Literal["baseline", "fip", "ffip"]
Impl = Literal["xla", "ref", "pallas"]
# Block-size policy for the pallas kernels (and flash attention, which reads
# the ambient config in models/attention.py):
#   None          -> the kernels' static defaults (ops.choose_blocks)
#   "auto"        -> tuned schedule from the repro.tune persistent cache,
#                    falling back to the defaults on a miss (counted + logged
#                    once per key — never a silent constant)
#   (bm, bn, bk)  -> explicit override
Block = Union[None, str, Tuple[int, int, int]]


@dataclasses.dataclass(frozen=True)
class GemmConfig:
    algo: Algo = "baseline"
    impl: Impl = "xla"
    k_chunk: int = 0           # chunking for ref fip/ffip cross-term
    # pallas interpret mode: None = backend auto (compiled on TPU, interpret
    # on CPU/GPU hosts — kernels/compat.py); bools force either way.
    interpret: Optional[bool] = None
    # int8 inference mode (§3.3/§4.4): dense layers whose params carry an
    # offline-prepared "q" entry (core.quant.attach_quantized_weights) run the
    # integer (F)FIP path with Eq. 15 folded beta + the Eq. 20 zero-point
    # adjuster; layers without one fall back to the float `algo` path.
    quantized: bool = False
    block: Block = None


_state = threading.local()


def current_config() -> GemmConfig:
    return getattr(_state, "cfg", GemmConfig())


@contextlib.contextmanager
def use_gemm(cfg: GemmConfig):
    prev = getattr(_state, "cfg", None)
    _state.cfg = cfg
    try:
        yield
    finally:
        if prev is None:
            del _state.cfg
        else:
            _state.cfg = prev


def _pad_even_k(a: Array, b: Array):
    k = a.shape[-1]
    if k % 2 == 0:
        return a, b
    pad_a = [(0, 0)] * (a.ndim - 1) + [(0, 1)]
    return jnp.pad(a, pad_a), jnp.pad(b, ((0, 1), (0, 0)))


def resolve_blocks(cfg: GemmConfig, algo: str, a: Array, b: Array,
                   ) -> Tuple[int, int, int]:
    """Trace-time block resolution for the pallas providers. (0, 0, 0) means
    "use the kernel's static default" (ops.choose_blocks); ``block="auto"``
    consults the repro.tune schedule cache for this (algo, dtype,
    shape-bucket, device) — a pure lookup, never a measurement — and falls
    back to the default on a miss (tune.stats counts it)."""
    if cfg.block is None:
        return (0, 0, 0)
    if isinstance(cfg.block, (tuple, list)):
        bm, bn, bk = cfg.block
        return (int(bm), int(bn), int(bk))
    if cfg.block == "auto":
        from repro import tune
        m = math.prod(a.shape[:-1])
        got = tune.lookup_gemm_blocks(
            algo, jnp.result_type(a.dtype, b.dtype),
            m, b.shape[-1], a.shape[-1])
        return got if got is not None else (0, 0, 0)
    raise ValueError(
        f"GemmConfig.block must be None, 'auto' or (bm, bn, bk); "
        f"got {cfg.block!r}")


def gemm(a: Array, b: Array, cfg: Optional[GemmConfig] = None) -> Array:
    """C = A @ B through the configured provider. a: (..., M, K), b: (K, N)."""
    cfg = cfg or current_config()
    if cfg.algo == "baseline":
        if cfg.impl == "pallas":
            from repro.kernels import ops as kops
            bm, bn, bk = resolve_blocks(cfg, "baseline", a, b)
            return kops.matmul(a, b, algo="baseline", interpret=cfg.interpret,
                               bm=bm, bn=bn, bk=bk)
        return jnp.matmul(a, b)

    a, b = _pad_even_k(a, b)
    if cfg.impl == "pallas":
        from repro.kernels import ops as kops
        bm, bn, bk = resolve_blocks(cfg, cfg.algo, a, b)
        return kops.matmul(a, b, algo=cfg.algo, interpret=cfg.interpret,
                           bm=bm, bn=bn, bk=bk)
    # 'xla' and 'ref' for fip/ffip both lower the exact algebra through XLA;
    # trainable wrappers give analytic (baseline) gradients.
    fn = (fip.fip_matmul_trainable if cfg.algo == "fip"
          else fip.ffip_matmul_trainable)
    out = fn(a, b, cfg.k_chunk)
    return out.astype(jnp.result_type(a.dtype, b.dtype))
