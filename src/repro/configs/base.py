"""Config dataclasses for the model zoo + shape grid (assigned architectures)."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0            # shared experts applied to every token
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    partition: str = "expert"    # "expert" (EP over model axis) | "ffn" (TP inside expert)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 0         # 0 = no q compression (V2-Lite)
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    version: int = 1             # 1 = Mamba1 selective scan, 2 = Mamba2 SSD
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64           # Mamba2 only
    n_groups: int = 1            # Mamba2 B/C groups
    dt_rank: int = 0             # Mamba1; 0 -> ceil(d_model/16)
    chunk: int = 128             # SSD chunk length


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    n_layers: int
    n_frames: int = 1500         # whisper 30s audio -> 1500 frames
    cross_attention: bool = True


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | enc-dec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    rope_theta: float = 10000.0
    rope_theta_global: float = 0.0   # gemma3: different theta for global layers
    sliding_window: int = 0      # 0 = full attention
    local_global_period: int = 0  # gemma3: every Nth layer is global
    first_k_dense: int = 0       # deepseek: first k layers use dense FFN
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid_attn_period: int = 0  # zamba2: shared attn block every N ssm layers
    encoder: Optional[EncoderConfig] = None
    frontend: str = ""           # "audio" | "vision" | ""
    frontend_tokens: int = 0     # stub prefix embeddings (vlm)
    tie_embeddings: bool = True
    norm_eps: float = 1e-5
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    act: str = "silu"
    qkv_bias: bool = False
    param_dtype: str = "bfloat16"
    remat: str = "none"          # none | dots | full (per-layer rematerialisation)
    attention_impl: str = "flash"  # flash (Pallas, VMEM scores) | naive
    # which shapes this arch supports (brief rules)
    supports_long_context: bool = False   # sub-quadratic path exists
    is_encoder_decoder: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    def param_count(self) -> int:
        """Analytic parameter count (approx; embeddings + blocks)."""
        d, v, L = self.d_model, self.vocab, self.n_layers
        total = v * d * (1 if self.tie_embeddings else 2)
        hd = self.hd
        if self.ssm is not None and self.family in ("ssm", "hybrid"):
            di = self.ssm.expand * d
            if self.ssm.version == 1:
                dt_rank = self.ssm.dt_rank or -(-d // 16)
                per = (d * 2 * di + di * (dt_rank + 2 * self.ssm.d_state)
                       + dt_rank * di + di * d + di * self.ssm.d_conv)
            else:
                n_h = di // self.ssm.head_dim
                per = (d * (2 * di + 2 * self.ssm.n_groups * self.ssm.d_state + n_h)
                       + di * d + 3 * di * self.ssm.d_conv)
            total += L * per
            if self.hybrid_attn_period:
                total += d * hd * (2 * self.n_heads + 2 * self.n_kv_heads)  # shared attn
        else:
            attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            if self.mla is not None:
                m = self.mla
                attn = (d * self.n_heads * (m.nope_head_dim + m.rope_head_dim)
                        + d * (m.kv_lora_rank + m.rope_head_dim)
                        + m.kv_lora_rank * self.n_heads * (m.nope_head_dim + m.v_head_dim)
                        + self.n_heads * m.v_head_dim * d)
            if self.moe is not None:
                ff_dense = 3 * d * self.d_ff
                ff_moe = (self.moe.n_experts + self.moe.n_shared) * 3 * d * self.moe.d_ff_expert
                total += self.first_k_dense * (attn + ff_dense)
                total += (L - self.first_k_dense) * (attn + ff_moe)
                total += (L - self.first_k_dense) * d * self.moe.n_experts  # router
            else:
                total += L * (attn + 3 * d * self.d_ff)
        if self.encoder is not None:
            # encoder blocks + decoder cross-attn
            enc = self.encoder.n_layers * (4 * d * d + 3 * d * self.d_ff)
            total += enc + L * 4 * d * d
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed-active experts)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        inactive = ((self.n_layers - self.first_k_dense)
                    * (self.moe.n_experts - self.moe.top_k) * 3
                    * self.d_model * self.moe.d_ff_expert)
        return full - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                    # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode

SHAPES = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}


def shape_supported(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Brief rules: long_500k only for sub-quadratic archs; decode only for
    archs with a decoder (all of ours have one)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("skipped: pure full-attention arch — 500k context "
                       "requires a sub-quadratic path (DESIGN.md §5)")
    return True, ""
