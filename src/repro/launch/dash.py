"""Live terminal dashboard over the repro.obs metrics snapshot (stdlib only).

    # live, against a serving fleet exporting /metrics.json:
    PYTHONPATH=src python -m repro.launch.serve ... --metrics-port 9400 &
    PYTHONPATH=src python -m repro.launch.dash --url http://127.0.0.1:9400

    # one frame from a --metrics-json dump (CI smoke / post-mortem):
    PYTHONPATH=src python -m repro.launch.dash --file /tmp/m.json --frames 1

Renders, from nothing but the registry snapshot (so it works identically
against a live scrape endpoint, a dumped file, or an in-process registry):

  * SLO burn gauges — per objective: alert state (OK/WARN/PAGE), fast/slow
    burn rates as bars, and the alert-transition counts;
  * the degradation controller — state ladder position and effective
    admission limit, plus every counted controller action;
  * replica health — circuit-breaker state (healthy/probing/quarantined)
    and per-replica dispatch/e2e numbers;
  * windowed percentiles — sliding-window TTFT / inter-token latency per
    {replica, tier} from ``serve_*_window_seconds`` (and the router-level
    ``router_ttft_ms_window``);
  * router totals (``router_events_total``) and queue depth.

``render(snapshot)`` is a pure function of the snapshot dict — the tests
drive it directly; the CLI just polls and repaints.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import urllib.request
from typing import Dict, List, Optional

_CLEAR = "\x1b[2J\x1b[H"
_REPLICA_STATE = {0: "healthy", 1: "probing", 2: "quarantined"}
_CTL_STATE = {0: "healthy", 1: "probing", 2: "degraded", 3: "tightened"}
_ALERT = {0: "OK", 1: "WARN", 2: "PAGE"}


def _bar(frac: float, width: int = 20) -> str:
    frac = max(0.0, min(1.0, frac))
    n = int(round(frac * width))
    return "#" * n + "." * (width - n)


def _series(metrics: dict, name: str) -> List[dict]:
    return metrics.get(name, {}).get("series", [])


def _value(metrics: dict, name: str, **labels) -> float:
    total = 0.0
    for s in _series(metrics, name):
        if all(s["labels"].get(k) == v for k, v in labels.items()):
            total += s.get("value", s.get("count", 0.0))
    return total


def _fmt_ms(seconds: float) -> str:
    return f"{seconds * 1e3:8.2f}ms"


def render(snapshot: dict, *, source: str = "") -> str:
    """One dashboard frame from a registry snapshot (or the --metrics-json
    payload wrapping one under "metrics")."""
    m = snapshot.get("metrics", snapshot)
    out: List[str] = []
    title = "repro.serve dashboard"
    if source:
        title += f" — {source}"
    out.append(title)
    out.append("=" * len(title))

    # -- SLOs ---------------------------------------------------------------
    slo_states = {s["labels"]["slo"]: int(s["value"])
                  for s in _series(m, "slo_state")}
    if slo_states:
        out.append("")
        out.append("SLO burn")
        for name in sorted(slo_states):
            bf = _value(m, "slo_burn_rate", slo=name, window="fast")
            bs = _value(m, "slo_burn_rate", slo=name, window="slow")
            trans = sum(s.get("value", 0) for s in
                        _series(m, "slo_transitions_total")
                        if s["labels"].get("slo") == name)
            out.append(
                f"  {name:<12} [{_ALERT.get(slo_states[name], '?'):>4}]  "
                f"fast {_bar(bf / 2)} {bf:6.2f}  "
                f"slow {_bar(bs / 2)} {bs:6.2f}  "
                f"({trans:.0f} transitions)")

    # -- degradation controller --------------------------------------------
    if "router_controller_state" in m:
        ctl = _CTL_STATE.get(int(_value(m, "router_controller_state")), "?")
        limit = _value(m, "router_admission_limit")
        actions = {s["labels"]["action"]: int(s["value"])
                   for s in _series(m, "router_controller_total")}
        acts = " ".join(f"{k}={v}" for k, v in sorted(actions.items())) \
            or "none yet"
        out.append("")
        out.append(f"controller: {ctl:<10} admission_limit={limit:.0f}  "
                   f"actions: {acts}")

    # -- replicas -----------------------------------------------------------
    reps = sorted({s["labels"]["replica"]
                   for s in _series(m, "serve_dispatches_total")})
    if reps:
        out.append("")
        out.append("replicas")
        for rep in reps:
            st = _REPLICA_STATE.get(
                int(_value(m, "router_replica_state", replica=rep)), "-")
            pre = _value(m, "serve_dispatches_total", replica=rep,
                         phase="prefill")
            dec = _value(m, "serve_dispatches_total", replica=rep,
                         phase="decode")
            toks = _value(m, "serve_tokens_total", replica=rep,
                          phase="decode")
            out.append(f"  r{rep:<4} {st:<12} dispatches p={pre:.0f} "
                       f"d={dec:.0f}  decode_tokens={toks:.0f}")

    # -- windowed percentiles ----------------------------------------------
    winrows = []
    for fam, label in (("serve_ttft_window_seconds", "ttft"),
                       ("serve_itl_window_seconds", "itl")):
        for s in _series(m, fam):
            if not s.get("count"):
                continue
            lab = s["labels"]
            winrows.append(
                f"  {label:<5} r{lab.get('replica', '?'):<4} "
                f"{lab.get('tier', '?'):<6} "
                f"p50 {_fmt_ms(s['p50'])}  p99 {_fmt_ms(s['p99'])}  "
                f"{s['rate_per_s']:7.2f}/s  n={s['count']}"
                + ("  DROPPED" if s.get("samples_dropped") else ""))
    for s in _series(m, "router_ttft_ms_window"):
        if not s.get("count"):
            continue
        lab = s["labels"]
        winrows.append(
            f"  ttft* r{lab.get('replica', '?'):<4} "
            f"{lab.get('tier', '?'):<6} "
            f"p50 {s['p50']:8.2f}ms  p99 {s['p99']:8.2f}ms  "
            f"{s['rate_per_s']:7.2f}/s  n={s['count']}")
    if winrows:
        out.append("")
        w = next((s for s in _series(m, "serve_ttft_window_seconds")), None)
        span = f" (last {w['window_s']:.0f}s)" if w else ""
        out.append(f"windows{span}   [ttft* = router-level, incl. queueing]")
        out.extend(winrows)

    # -- router totals ------------------------------------------------------
    ev = {s["labels"]["kind"]: int(s["value"])
          for s in _series(m, "router_events_total")}
    if ev:
        keys = ("submitted", "completed", "failed", "timed_out", "retries",
                "shed_to_quantized", "rejected", "quarantines")
        line = " ".join(f"{k}={ev.get(k, 0)}" for k in keys)
        out.append("")
        out.append(f"router: {line}  queue_depth="
                   f"{_value(m, 'router_queue_depth'):.0f}")
    return "\n".join(out) + "\n"


def _fetch(url: Optional[str], path: Optional[str]) -> dict:
    if url is not None:
        with urllib.request.urlopen(url, timeout=5) as r:
            return json.loads(r.read().decode())
    with open(path) as f:
        return json.load(f)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    src = ap.add_mutually_exclusive_group(required=True)
    src.add_argument("--url", default=None,
                     help="metrics endpoint base (http://host:port) or a "
                          "full .../metrics.json URL")
    src.add_argument("--file", default=None,
                     help="a --metrics-json dump (rendered as one frame "
                          "unless the file keeps changing)")
    ap.add_argument("--interval", type=float, default=1.0,
                    help="seconds between repaints (default 1.0)")
    ap.add_argument("--frames", type=int, default=0, metavar="N",
                    help="exit after N frames (0 = run until interrupted)")
    ap.add_argument("--no-clear", action="store_true",
                    help="append frames instead of repainting (logs/CI)")
    args = ap.parse_args(argv)

    url = args.url
    if url is not None and not url.rstrip("/").endswith("metrics.json"):
        url = url.rstrip("/") + "/metrics.json"
    source = url or args.file

    n = 0
    try:
        while True:
            try:
                snap = _fetch(url, args.file)
            except Exception as e:                      # noqa: BLE001
                print(f"dash: cannot read {source}: {e}", file=sys.stderr)
                return 1
            frame = render(snap, source=source)
            if not args.no_clear:
                sys.stdout.write(_CLEAR)
            sys.stdout.write(frame)
            sys.stdout.flush()
            n += 1
            if args.frames and n >= args.frames:
                return 0
            time.sleep(args.interval)
    except KeyboardInterrupt:
        return 0


if __name__ == "__main__":
    raise SystemExit(main())
