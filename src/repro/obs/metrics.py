"""Metrics registry: named counters, gauges, and fixed-bucket histograms.

Dependency-free (stdlib only) and deterministic: nothing in here reads a
clock — every duration that lands in a histogram was measured by the CALLER
against its own (injectable) clock, so the same FakeClock-driven serving run
produces byte-identical snapshots.

Design points, in the order the serving stack needs them:

* **Labels** are declared up front (``registry.counter(name, labels=("replica",
  "phase"))``) and bound per observation site with :meth:`Metric.labels`.
  Label VALUES must stay low-cardinality — per-request ids belong in spans
  (`repro.obs.trace`), not metrics — so label names that smell like request
  ids are rejected outright and each metric caps its distinct label sets
  (:class:`CardinalityError` past ``max_label_sets``). A metrics store that
  grows with traffic is a memory leak wearing a dashboard.

* **Histograms** use fixed upper bounds with Prometheus ``le`` semantics
  (cumulative on export, a value equal to a bound falls in that bound's
  bucket). On top of the buckets each histogram keeps a bounded reservoir of
  the most recent raw observations, so :meth:`Histogram.quantile` is EXACT
  (numpy-style linear interpolation) while the observation count fits the
  reservoir and falls back to in-bucket interpolation beyond it — which is
  how serve_bench's p50/p99 stay bit-comparable with the pre-obs numbers.

* **Registries** are injectable for test isolation; :func:`get_registry`
  returns the process-global default the serving stack uses when none is
  passed. Re-registering an existing (name, type, labels) triple returns the
  existing metric, so module-level call sites stay idempotent.

* **Export**: :meth:`Registry.snapshot` (plain sorted dicts, json-safe),
  :meth:`Registry.to_prometheus` (text exposition format 0.0.4) and
  :func:`parse_prometheus` (the round-trip used by tests and the scrape
  smoke), plus :func:`start_metrics_server` — a stdlib ``http.server``
  exposition endpoint so a running fleet can be scraped.
"""
from __future__ import annotations

import http.server
import json
import threading
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

# Label names that would key a metric by request identity. Unbounded-by-
# construction: every request mints a new time series. Spans carry rids.
FORBIDDEN_LABELS = frozenset({"rid", "request_id", "req_id"})

# Latency-shaped default bounds (seconds): sub-millisecond kernel dispatches
# through multi-second prefills, exponential-ish spacing.
DEFAULT_BUCKETS: Tuple[float, ...] = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0)


class CardinalityError(ValueError):
    """A metric exceeded its distinct-label-set cap (or used a forbidden
    per-request label name) — the failure mode the guard exists to catch."""


def _check_label_names(names: Sequence[str]) -> Tuple[str, ...]:
    for n in names:
        if n in FORBIDDEN_LABELS:
            raise CardinalityError(
                f"label {n!r} is per-request (unbounded cardinality); "
                f"request ids belong in spans, not metric labels")
    return tuple(names)


class Metric:
    """Base: a named family of children keyed by label-value tuples."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 labels: Sequence[str] = (), *, max_label_sets: int = 64):
        self.name = name
        self.help = help
        self.label_names = _check_label_names(labels)
        self.max_label_sets = max_label_sets
        self._children: Dict[Tuple[str, ...], "Metric"] = {}
        self._parent: Optional["Metric"] = None

    # -- label binding ------------------------------------------------------
    def labels(self, **kv) -> "Metric":
        if self._parent is not None:
            raise TypeError("labels() on an already-bound child")
        if set(kv) != set(self.label_names):
            raise ValueError(
                f"{self.name}: expected labels {self.label_names}, "
                f"got {tuple(sorted(kv))}")
        key = tuple(str(kv[n]) for n in self.label_names)
        child = self._children.get(key)
        if child is None:
            if len(self._children) >= self.max_label_sets:
                raise CardinalityError(
                    f"{self.name}: more than {self.max_label_sets} distinct "
                    f"label sets — a label value is unbounded (rids? raw "
                    f"shapes?); bucket it or move it into a span")
            child = self._new_child()
            child._parent = self
            self._children[key] = child
        return child

    def _require_unlabeled(self) -> None:
        """Observing on a labeled family without binding is a bug."""
        if self.label_names and self._parent is None:
            raise ValueError(f"{self.name} declares labels "
                             f"{self.label_names}; bind with .labels()")

    def _new_child(self) -> "Metric":
        raise NotImplementedError

    # -- iteration for export ----------------------------------------------
    def _series(self) -> Iterable[Tuple[Tuple[str, ...], "Metric"]]:
        """(label-values, holder) pairs; an unlabeled metric IS its own
        single series (state lives on the parent object directly)."""
        if not self.label_names:
            return [((), self)]
        return sorted(self._children.items())

    # -- export protocol (one series = one bound child) ---------------------
    def _snap(self, labels: Dict[str, str]) -> dict:
        """One json-safe snapshot entry for this series."""
        return {"labels": labels, "value": self.value}

    def _prom(self, name: str, lab: Dict[str, str]) -> List[str]:
        """Exposition lines for this series."""
        return [f"{name}{_fmt_labels(lab)} {_fmt(self.value)}"]


class Counter(Metric):
    kind = "counter"

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self.value = 0.0

    def _new_child(self) -> "Counter":
        return Counter(self.name, self.help)

    def inc(self, amount: float = 1.0) -> None:
        self._require_unlabeled()
        if amount < 0:
            raise ValueError(f"{self.name}: counters only go up "
                             f"(inc({amount}))")
        self.value += amount

    def get(self, **kv) -> float:
        return self.labels(**kv).value if kv else self.value


class Gauge(Metric):
    kind = "gauge"

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self.value = 0.0

    def _new_child(self) -> "Gauge":
        return Gauge(self.name, self.help)

    def set(self, value: float) -> None:
        self._require_unlabeled()
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self._require_unlabeled()
        self.value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.inc(-amount)

    def get(self, **kv) -> float:
        return self.labels(**kv).value if kv else self.value


class Histogram(Metric):
    """Fixed-bucket histogram with an exact-quantile reservoir.

    ``buckets`` are inclusive upper bounds (``le``); an implicit +Inf bucket
    catches the rest. ``observe`` is O(#buckets); ``quantile`` is exact while
    total observations <= ``reservoir`` (numpy 'linear' interpolation over
    the raw samples) and degrades to in-bucket linear interpolation after.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "", labels: Sequence[str] = (),
                 *, buckets: Sequence[float] = DEFAULT_BUCKETS,
                 reservoir: int = 1024, max_label_sets: int = 64):
        super().__init__(name, help, labels, max_label_sets=max_label_sets)
        bs = tuple(float(b) for b in buckets)
        if not bs or any(a >= b for a, b in zip(bs, bs[1:])):
            raise ValueError(f"{name}: bucket bounds must be strictly "
                             f"increasing and non-empty, got {bs}")
        self.buckets = bs
        self.reservoir = reservoir
        self.counts: List[int] = [0] * (len(bs) + 1)   # per-bucket, not cum.
        self.sum = 0.0
        self.count = 0
        self.samples_dropped = 0      # observations past the reservoir cap
        self._samples: List[float] = []

    def _new_child(self) -> "Histogram":
        return Histogram(self.name, self.help, buckets=self.buckets,
                         reservoir=self.reservoir)

    def observe(self, value: float) -> None:
        self._require_unlabeled()
        v = float(value)
        i = len(self.buckets)
        for j, b in enumerate(self.buckets):     # le: v == bound -> bucket j
            if v <= b:
                i = j
                break
        self.counts[i] += 1
        self.sum += v
        self.count += 1
        if len(self._samples) < self.reservoir:
            self._samples.append(v)
        else:
            self.samples_dropped += 1

    @property
    def overflowed(self) -> bool:
        """True once the reservoir stopped retaining raw samples — from then
        on :meth:`quantile` is bucket-interpolated, not exact."""
        return self.samples_dropped > 0

    def quantile(self, q: float) -> float:
        """q in [0, 1]. Exact (numpy 'linear') while the reservoir holds
        every observation; bucket-interpolated past that; 0.0 when empty."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        if self.count == 0:
            return 0.0
        if self.count <= len(self._samples):
            s = sorted(self._samples)
            pos = q * (len(s) - 1)
            lo = int(pos)
            hi = min(lo + 1, len(s) - 1)
            return s[lo] + (s[hi] - s[lo]) * (pos - lo)
        # bucket interpolation: find the bucket holding the q-th observation
        target = q * self.count
        seen = 0.0
        lo_bound = 0.0
        for i, c in enumerate(self.counts):
            hi_bound = (self.buckets[i] if i < len(self.buckets)
                        else self.buckets[-1])
            if seen + c >= target and c:
                frac = (target - seen) / c
                return lo_bound + (hi_bound - lo_bound) * min(frac, 1.0)
            seen += c
            lo_bound = hi_bound
        return self.buckets[-1]

    def _snap(self, labels: Dict[str, str]) -> dict:
        cum, running = [], 0
        for c in self.counts:
            running += c
            cum.append(running)
        return {
            "labels": labels, "sum": self.sum, "count": self.count,
            "samples_dropped": self.samples_dropped,
            "overflowed": self.overflowed,
            "buckets": [
                {"le": (self.buckets[i] if i < len(self.buckets)
                        else "+Inf"), "count": cum[i]}
                for i in range(len(self.counts))],
        }

    def _prom(self, name: str, lab: Dict[str, str]) -> List[str]:
        lines, running = [], 0
        for i, c in enumerate(self.counts):
            running += c
            le = _fmt(self.buckets[i]) if i < len(self.buckets) else "+Inf"
            lines.append(f"{name}_bucket{_fmt_labels({**lab, 'le': le})} "
                         f"{running}")
        lines.append(f"{name}_sum{_fmt_labels(lab)} {_fmt(self.sum)}")
        lines.append(f"{name}_count{_fmt_labels(lab)} {self.count}")
        lines.append(f"{name}_samples_dropped{_fmt_labels(lab)} "
                     f"{self.samples_dropped}")
        return lines


class Registry:
    """A namespace of metrics. The serving stack takes ``registry=`` per
    component (test isolation) and defaults to the process-global one."""

    def __init__(self):
        self._metrics: Dict[str, Metric] = {}
        self._lock = threading.Lock()

    def _register(self, cls, name: str, help: str, labels: Sequence[str],
                  **kwargs) -> Metric:
        with self._lock:
            existing = self._metrics.get(name)
            if existing is not None:
                if type(existing) is not cls or \
                        existing.label_names != tuple(labels):
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{existing.kind} with labels {existing.label_names}")
                return existing
            m = cls(name, help, labels, **kwargs)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> Counter:
        return self._register(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> Gauge:
        return self._register(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (), *,
                  buckets: Sequence[float] = DEFAULT_BUCKETS,
                  reservoir: int = 1024) -> Histogram:
        return self._register(Histogram, name, help, labels,
                              buckets=buckets, reservoir=reservoir)

    def windowed_histogram(self, name: str, help: str = "",
                           labels: Sequence[str] = (), *,
                           window_s: float = 30.0, sub_buckets: int = 30,
                           reservoir_per_bucket: int = 256, clock=None):
        from repro.obs.window import WindowedHistogram
        return self._register(WindowedHistogram, name, help, labels,
                              window_s=window_s, sub_buckets=sub_buckets,
                              reservoir_per_bucket=reservoir_per_bucket,
                              clock=clock)

    def windowed_counter(self, name: str, help: str = "",
                         labels: Sequence[str] = (), *,
                         window_s: float = 30.0, sub_buckets: int = 30,
                         clock=None):
        from repro.obs.window import WindowedCounter
        return self._register(WindowedCounter, name, help, labels,
                              window_s=window_s, sub_buckets=sub_buckets,
                              clock=clock)

    def get(self, name: str) -> Optional[Metric]:
        return self._metrics.get(name)

    # -- export -------------------------------------------------------------
    def snapshot(self) -> dict:
        """Plain json-safe dict, deterministically ordered: metric name ->
        {kind, help, series: [...]} — each series shape is owned by the
        metric type (``Metric._snap``)."""
        out: Dict[str, dict] = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            series = [child._snap(dict(zip(m.label_names, key)))
                      for key, child in m._series()]
            out[name] = {"kind": m.kind, "help": m.help, "series": series}
        return out

    def to_prometheus(self) -> str:
        """Text exposition format (0.0.4)."""
        lines: List[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m.help:
                lines.append(f"# HELP {name} {_escape_help(m.help)}")
            # windowed kinds map onto the nearest standard exposition type
            ptype = {"windowed_histogram": "summary",
                     "windowed_counter": "gauge"}.get(m.kind, m.kind)
            lines.append(f"# TYPE {name} {ptype}")
            for key, child in m._series():
                lines.extend(child._prom(name, dict(zip(m.label_names, key))))
        return "\n".join(lines) + "\n"

    def to_json(self) -> str:
        return json.dumps(self.snapshot(), indent=2, sort_keys=True)


def _fmt(v: float) -> str:
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(v)


def _fmt_labels(lab: Dict[str, str]) -> str:
    if not lab:
        return ""
    inner = ",".join(f'{k}="{_escape(str(v))}"' for k, v in lab.items())
    return "{" + inner + "}"


def _escape(s: str) -> str:
    return s.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _escape_help(s: str) -> str:
    """HELP text escaping per the exposition spec: only backslash and
    newline (quotes stay literal). Unescaped, an embedded newline splits
    the HELP line and the remainder parses as a garbage sample."""
    return s.replace("\\", "\\\\").replace("\n", "\\n")


def _unescape_help(s: str) -> str:
    out, i = [], 0
    while i < len(s):
        if s[i] == "\\" and i + 1 < len(s):
            nxt = s[i + 1]
            if nxt == "n":
                out.append("\n")
                i += 2
                continue
            if nxt == "\\":
                out.append("\\")
                i += 2
                continue
        out.append(s[i])
        i += 1
    return "".join(out)


def parse_help(text: str) -> Dict[str, str]:
    """Extract ``# HELP`` lines back into {name: unescaped help} — the other
    half of the HELP round-trip."""
    out: Dict[str, str] = {}
    for line in text.splitlines():
        line = line.strip()
        if line.startswith("# HELP "):
            rest = line[len("# HELP "):]
            name, _, help_text = rest.partition(" ")
            out[name] = _unescape_help(help_text)
    return out


def parse_prometheus(text: str) -> Dict[str, Dict[Tuple[Tuple[str, str], ...],
                                                  float]]:
    """Parse exposition text back into {name: {labels-tuple: value}} — the
    round-trip half used by tests and the scrape smoke. Ignores comments
    (see :func:`parse_help` for the HELP side of the round-trip)."""
    out: Dict[str, Dict[Tuple[Tuple[str, str], ...], float]] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        head, _, val = line.rpartition(" ")
        if "{" in head:
            name, _, rest = head.partition("{")
            rest = rest.rstrip("}")
            labels = []
            for part in _split_labels(rest):
                k, _, v = part.partition("=")
                labels.append((k, v.strip('"').replace('\\"', '"')
                               .replace("\\n", "\n").replace("\\\\", "\\")))
            key = tuple(labels)
        else:
            name, key = head, ()
        out.setdefault(name, {})[key] = float(val)
    return out


def _split_labels(s: str) -> List[str]:
    parts, depth, cur = [], False, []
    for ch in s:
        if ch == '"':
            depth = not depth
            cur.append(ch)
        elif ch == "," and not depth:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return parts


# -- process-global default registry ----------------------------------------

_default = Registry()


def get_registry() -> Registry:
    """The process-global default registry (serving components use it when no
    ``registry=`` is injected)."""
    return _default


def set_registry(registry: Registry) -> Registry:
    """Swap the process-global default (tests); returns the previous one."""
    global _default
    prev, _default = _default, registry
    return prev


# -- stdlib scrape endpoint --------------------------------------------------

class _MetricsHandler(http.server.BaseHTTPRequestHandler):
    registry: Registry = _default

    def do_GET(self):  # noqa: N802 (stdlib API)
        if self.path.rstrip("/") in ("", "/metrics"):
            body = self.registry.to_prometheus().encode()
            ctype = "text/plain; version=0.0.4"
        elif self.path.rstrip("/") == "/metrics.json":
            body = self.registry.to_json().encode()
            ctype = "application/json"
        else:
            self.send_response(404)
            self.end_headers()
            return
        self.send_response(200)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, *args):   # quiet: scrapes are high-frequency
        pass


def start_metrics_server(registry: Optional[Registry] = None,
                         port: int = 0, host: str = "127.0.0.1"):
    """Serve ``/metrics`` (Prometheus text) and ``/metrics.json`` on a
    daemon thread. Returns the ``HTTPServer`` — read ``.server_address[1]``
    for the bound port (``port=0`` picks a free one), call ``.shutdown()``
    to stop."""
    handler = type("Handler", (_MetricsHandler,),
                   {"registry": registry or get_registry()})
    srv = http.server.ThreadingHTTPServer((host, port), handler)
    t = threading.Thread(target=srv.serve_forever, daemon=True)
    t.start()
    return srv
