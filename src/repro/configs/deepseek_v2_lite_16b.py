"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=102400, MoE 64e top-6 — MLA kv_lora=512, 2 shared experts, first layer
dense. [arXiv:2405.04434; hf]

Note: the assignment comment mentions '160 routed' (full V2); the primary spec
'MoE 64e top-6' matches the hf V2-Lite config and is what we implement."""
from repro.configs.base import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab=102400, first_k_dense=1,
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2,
                  partition="expert"),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
    tie_embeddings=False,
    supports_long_context=False,
)
