"""Baseline systolic-array-style blocked GEMM as a Pallas TPU kernel.

The comparison baseline (Fig. 1a PEs): a straightforward MXU-mapped blocked
matmul with explicit BlockSpec VMEM tiling. Grid (M/bm, N/bn, K/bk), K
innermost for in-VMEM accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import resolve_interpret, tpu_compiler_params

Array = jax.Array


def pad_to_blocks(a: Array, b: Array, bm: int, bn: int, bk: int):
    """Zero-pad (M, K) x (K, N) operands to block multiples. Shared by every
    GEMM kernel's pad-run-slice fallback: zero rows/columns contribute zero to
    baseline products AND to the FIP-family cross/alpha/beta terms (pairs of
    zeros pre-add to zero), so padding is exact — the caller slices the
    (m, n) corner back out. Keeps the tuner free to consider any legal block
    on any shape, and odd model dims out of the assert graveyard."""
    m, k = a.shape
    n = b.shape[1]
    mp, kp, np_ = -(-m // bm) * bm, -(-k // bk) * bk, -(-n // bn) * bn
    if (mp, kp) != (m, k):
        a = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    if (kp, np_) != (k, n):
        b = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
    return a, b


def _kernel(a_ref, b_ref, o_ref, *, acc_dtype):
    k = pl.program_id(2)
    a = a_ref[...].astype(acc_dtype)
    b = b_ref[...].astype(acc_dtype)
    if jnp.issubdtype(acc_dtype, jnp.integer):
        part = jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                                   preferred_element_type=acc_dtype)
    else:
        part = jnp.dot(a, b, preferred_element_type=acc_dtype)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = part

    @pl.when(k != 0)
    def _acc():
        o_ref[...] += part


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def baseline_gemm(a: Array, b: Array, *, bm: int = 128, bn: int = 128,
                  bk: int = 128, interpret=None) -> Array:
    """a: (M, K), b: (K, N) -> (M, N) in the accumulation dtype.

    Shapes not divisible by the blocks are zero-padded and the result sliced
    (exact). ``interpret=None`` auto-detects: compiled on TPU, interpret mode
    elsewhere (kernels/compat.py); pass a bool to override.
    """
    interpret = resolve_interpret(interpret)
    m0, k0 = a.shape
    k2, n0 = b.shape
    assert k0 == k2
    a, b = pad_to_blocks(a, b, bm, bn, bk)
    m, k = a.shape
    n = b.shape[1]
    acc_dtype = (jnp.int32 if jnp.issubdtype(a.dtype, jnp.integer)
                 else jnp.float32)
    grid = (m // bm, n // bn, k // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, acc_dtype=acc_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), acc_dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
    return out[:m0, :n0]
