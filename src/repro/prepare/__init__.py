"""`repro.prepare` — unified offline model preparation (§4.4, offline).

One interface over every offline transform the serving/vision paths need —
per-channel int8 weight encoding with Eq. 15 folded beta + colsums, Eq. 9
FFIP y-deltas, folded BN, and the device-keyed `repro.tune` schedule slice —
serializable to a single artifact directory with a counter-proved
zero-recompute warm start. See :mod:`repro.prepare.artifact`.

    pm = prepare.prepare_lm(params, quantized=True)
    pm.save("artifacts/minicpm")
    ...
    pm = prepare.load("artifacts/minicpm")     # new process
    assert pm.recomputed == 0                  # nothing re-derived

CLI: ``python -m repro.launch.prepare``.
"""
from repro.prepare.artifact import (ArtifactError, PreparedModel,
                                    counters_snapshot, load, prepare_lm,
                                    prepare_vision)

__all__ = ["ArtifactError", "PreparedModel", "counters_snapshot", "load",
           "prepare_lm", "prepare_vision"]
