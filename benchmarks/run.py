"""Benchmark orchestrator — one section per paper table/figure.

Usage: PYTHONPATH=src python -m benchmarks.run
Prints ``name,value,...`` CSV blocks; each maps to a paper artifact:
  fig2.*    PE register model (Eqs. 17-19)
  fig9.*    MXU sweep: resources/frequency/throughput + fit limits
  table1.*  8-bit FFIP vs paper Table 1 (GOPS et al.)
  table2.*  16-bit FFIP vs paper Table 2
  table3.*  ops/multiplier/cycle vs best prior works (Table 3)
  sec6p1.*  baseline vs FIP vs FFIP core claims
  fig9x.*   modeled vs measured cross-check (reads benchmarks/BENCH_conv.json)
  gemm_micro.*  arithmetic-complexity measurements + host timings
  roofline.*    TPU dry-run roofline summary (reads benchmarks/results/dryrun)
"""
from __future__ import annotations

import json
import pathlib


def roofline_summary():
    rows = ["roofline.cell,bottleneck,compute_s,memory_s,collective_s,roofline_frac,status"]
    d = pathlib.Path(__file__).parent / "results" / "dryrun"
    if not d.exists():
        rows.append("roofline.none,-,-,-,-,-,run launch.dryrun first")
        return rows
    for f in sorted(d.glob("*__16x16.json")):
        r = json.loads(f.read_text())
        if r.get("status") == "ok":
            rows.append(
                f"roofline.{r['arch']}__{r['shape']},{r['bottleneck']},"
                f"{r['compute_s']:.4f},{r['memory_s']:.4f},"
                f"{r['collective_s']:.4f},{r['roofline_fraction']:.3f},ok")
        else:
            rows.append(f"roofline.{r['arch']}__{r['shape']},-,-,-,-,-,{r['status']}")
    return rows


def main() -> None:
    from benchmarks import accel_tables, gemm_micro
    sections = [
        accel_tables.fig2_registers(),
        accel_tables.fig9_sweep(),
        accel_tables.table1(),
        accel_tables.table2(),
        accel_tables.table3(),
        accel_tables.fip_vs_ffip_vs_baseline(),
        accel_tables.fig9_measured_crosscheck(),
        gemm_micro.run(),
        roofline_summary(),
    ]
    for rows in sections:
        for r in rows:
            print(r)
        print()


if __name__ == "__main__":
    main()
