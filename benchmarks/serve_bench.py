"""Serving benchmark: continuous-batching throughput + per-phase timings.

    PYTHONPATH=src python benchmarks/serve_bench.py [--arch minicpm-2b]

Runs the continuous batcher (float and int8-FFIP quantized modes) over a
stream of mixed-length requests, sweeping the fused-decode ``decode_chunk``
knob, and writes ``benchmarks/BENCH_serve.json``: tok/s, steps/s, the
prefill / decode / host-overhead split from BatchServer.stats, per-step host
transfer, TTFT, e2e p50/p99 request latency, and compile counts.

Schema note (since the repro.obs subsystem landed): the latency percentiles
in ``results*`` — ``e2e_ms`` per contiguous/paged row and ``e2e_fake_s`` in
``results_faults`` — are computed from the obs latency histograms
(``serve_request_e2e_seconds`` / ``router_request_e2e_seconds``, each run on
its own fresh ``obs.Registry``), cross-checked in-process against the raw
per-request records (exact-reservoir quantiles, so the numbers are
bit-comparable with the pre-obs percentile math). ``ttft_ms`` p50/p99 and
``itl_ms`` come from the WINDOWED histograms
(``serve_ttft_window_seconds`` / ``serve_itl_window_seconds``, window pinned
to 3600 s so the whole timed run stays live), likewise cross-checked against
the raw per-request lists with a zero-``samples_dropped`` assertion — the
bench is the proof the SLO-facing windowed percentiles are exact. Numbers
measured before earlier refactors stay verbatim under ``baseline_pr2`` /
``baseline_prev``.

``results_faults`` drives the multi-replica router with 1-of-3 replicas
flapping on a seeded FaultPlan (raise/hang, fake clock) and records outcome
counts, retries/failovers/quarantines, and the e2e latency tax of failover
vs the identical fleet with no faults — asserting every completion stays
token-identical to the no-fault run (``--skip-faults`` skips it).

Jit warmup runs OUTSIDE the timed region (a covering workload — every prompt
bucket plus a decode dispatch — compiles first; its wall time is reported
separately as ``compile_s``), so the timed numbers are steady-state serving.
The PR 2 hot path (host-side argmax over (B, V) logits, one dispatch per
token, one prefill compile per prompt length, warmup inside the timed
region) is kept in the file verbatim under ``baseline_pr2``, and the
contiguous-cache numbers measured immediately before the block-paged KV
change live under ``baseline_prev`` — so the trajectory stays visible in one
file; ``comparison`` reports the decode speedup and the host-transfer
reduction against PR 2.

The paged section (``results_paged``) runs the block-paged KV cache on a
shared-prefix workload plus one long prompt, with chunked prefill, over a
WARM prefix cache (the untimed warmup run registers the prefix pages), and
records the paged-only metrics: pages_peak vs the contiguous-equivalent page
count, resident prefix-cache pages after drain, prefix_hit_tokens,
cow_copies, prefill_chunks, page-table upload bytes, and TTFT under the
long-prefill + decode mix. ``comparison_paged`` re-runs the identical mix on
the contiguous cache and reports the TTFT and footprint side by side (and
asserts the paged gather outputs are byte-identical to contiguous).

``results_prepared`` times the repro.prepare warm-start contract: cold
in-process offline prep (int8 quantization + Eq. 9 y-deltas) vs saving and
loading the serialized artifact, then serves from the loaded artifact and
asserts ``recomputed == 0``. ``results_tp`` sweeps tensor-parallel decode
(BatchServer ``mesh=``, model axis 1/2/4 over the visible devices — force
host devices with XLA_FLAGS to sweep past 1) and asserts output tokens stay
identical across TP widths.

CAVEAT (same as gemm_micro): this container is CPU-only, so absolute timings
measure the XLA-CPU + interpret-mode harness, not accelerator silicon — the
load-bearing outputs are the phase RATIOS, the chunk-sweep trend, the
host-transfer reduction, and the paged footprint/prefix-hit counters, which
show what the fused hot path and the paged cache amortize.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import numpy as np

import repro.obs as obs
from repro import configs
from repro.models.model import build_model
from repro.serve.batcher import BatchServer, Request

OUT = pathlib.Path(__file__).resolve().parent / "BENCH_serve.json"

# PR 2 numbers measured in this container on the identical workload
# (minicpm-2b-smoke, 4 slots, 6 requests, max_new=4, seed 0) with the PR 2
# hot path. Kept verbatim so the trajectory stays visible in one file.
BASELINE_PR2 = [
    {"arch": "minicpm-2b-smoke", "mode": "float", "slots": 4, "requests": 6,
     "tokens_out": 24, "decode_steps": 6, "wall_s": 4.921, "tok_per_s": 4.88,
     "phase_s": {"prefill": 4.121, "decode": 0.615, "host_other": 0.186},
     "decode_ms_per_step": 102.42},
    {"arch": "minicpm-2b-smoke", "mode": "int8-ffip", "slots": 4,
     "requests": 6, "tokens_out": 24, "decode_steps": 6, "wall_s": 14.343,
     "tok_per_s": 1.67,
     "phase_s": {"prefill": 10.156, "decode": 1.882, "host_other": 2.306},
     "decode_ms_per_step": 313.59},
]

# Contiguous-cache numbers measured in this container immediately before the
# multi-replica router landed (same sweep, same workload/seed as below), so
# the router refactor's effect on the untouched single-server hot path stays
# auditable: the contiguous sweep in ``results`` should match these within
# CPU noise.
BASELINE_PREV = [
    {"mode": "float", "decode_chunk": 1, "tok_per_s": 2282.0,
     "steps_per_s": 1045.13, "decode_ms_per_step": 0.96,
     "host_bytes_per_step": 16.0},
    {"mode": "float", "decode_chunk": 2, "tok_per_s": 2682.74,
     "steps_per_s": 1372.14, "decode_ms_per_step": 0.73,
     "host_bytes_per_step": 21.3},
    {"mode": "float", "decode_chunk": 4, "tok_per_s": 2423.44,
     "steps_per_s": 1138.85, "decode_ms_per_step": 0.88,
     "host_bytes_per_step": 21.3},
    {"mode": "float", "decode_chunk": 8, "tok_per_s": 2772.5,
     "steps_per_s": 1460.74, "decode_ms_per_step": 0.68,
     "host_bytes_per_step": 42.7},
    {"mode": "int8-ffip", "decode_chunk": 1, "tok_per_s": 1096.68,
     "steps_per_s": 630.68, "decode_ms_per_step": 1.59,
     "host_bytes_per_step": 16.0},
    {"mode": "int8-ffip", "decode_chunk": 4, "tok_per_s": 1533.34,
     "steps_per_s": 1471.05, "decode_ms_per_step": 0.68,
     "host_bytes_per_step": 21.3},
]


def _requests(cfg, requests: int, max_new: int, seed: int):
    rng = np.random.default_rng(seed)
    lens = rng.integers(3, 12, requests)
    return [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=(int(l),)),
                    max_new_tokens=max_new) for i, l in enumerate(lens)]


def _mix_requests(cfg, requests: int, max_new: int, seed: int, *,
                  long_len: int):
    """Shared-prefix workload + one long prompt: half the requests carry a
    common 16-token prefix (page reuse), the final request is a long prompt
    whose chunked prefill must interleave with the others' decode."""
    rng = np.random.default_rng(seed)
    lens = rng.integers(3, 12, requests)
    base = rng.integers(0, cfg.vocab, size=(16,))
    reqs = []
    for i, l in enumerate(lens):
        p = rng.integers(0, cfg.vocab, size=(int(l),))
        if i % 2 == 0:
            p = np.concatenate([base, p])
        reqs.append(Request(rid=i, prompt=p, max_new_tokens=max_new))
    reqs.append(Request(rid=requests,
                        prompt=rng.integers(0, cfg.vocab, size=(long_len,)),
                        max_new_tokens=max_new))
    return reqs


def bench(arch: str, *, slots: int, requests: int, max_new: int,
          max_len: int, quantized: bool, decode_chunk: int,
          gemm_impl=None, gemm_block=None, seed: int = 0,
          paged: bool = False, page_size: int = 16, prefill_chunk=None,
          paged_attention: str = "gather", mix_long_len: int = 0,
          mesh=None, prepared=None, keep_tokens: bool = False) -> dict:
    cfg = configs.smoke_config(configs.get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # obs_window_s=3600: the windowed TTFT/ITL histograms must cover the
    # whole timed run so nothing expires mid-bench and the windowed
    # percentiles are exact over every steady-state request.
    srv = BatchServer(model, batch_slots=slots, max_len=max_len,
                      quantized=quantized, decode_chunk=decode_chunk,
                      gemm_impl=gemm_impl, gemm_block=gemm_block,
                      paged=paged, page_size=page_size,
                      prefill_chunk=prefill_chunk,
                      paged_attention=paged_attention,
                      mesh=mesh, prepared=prepared,
                      registry=obs.Registry(), obs_window_s=3600.0)

    def _workload(budget, s):
        if mix_long_len:
            return _mix_requests(cfg, requests, budget, s,
                                 long_len=mix_long_len)
        return _requests(cfg, requests, budget, s)

    # --- warmup (untimed region): compile every prompt bucket + the decode
    # program, using the same length distribution as the measured workload.
    # Budget 2: the minimum that reaches a decode dispatch (token 1 comes
    # from prefill), keeping warmup cheap regardless of --max-new. In paged
    # mode this also REGISTERS the prompts' prefix pages, so the timed run
    # measures serving over a warm prefix cache (prefill collapses to the
    # recompute-last-token chunk).
    warm = _workload(2, seed)
    t0 = time.perf_counter()
    for r in warm:
        # request ids are idempotency keys now: the warmup run must not
        # collide with the timed run's rids (same rid => same payload)
        r.rid += 1_000_000
        srv.submit(r)
    srv.run_until_drained(params)
    compile_s = time.perf_counter() - t0

    # fresh registry between warmup and the timed run, so the obs histograms
    # the percentiles come from hold ONLY the steady-state requests
    srv.registry = obs.Registry()
    srv.set_obs_labels(srv.obs_labels)

    # --- timed steady-state run
    reqs = _workload(max_new, seed)
    n_reqs = len(reqs)
    t0 = time.perf_counter()
    for r in reqs:
        srv.submit(r)
    done = srv.run_until_drained(params)
    wall = time.perf_counter() - t0
    assert len(done) == n_reqs, "serve_bench: requests dropped"

    total = sum(len(r.out_tokens) for r in done)
    ttft = [r.t_first - r.t_submit for r in done]
    # e2e percentiles come from the obs latency histogram (exact while the
    # reservoir holds every observation — these workloads are far under it);
    # cross-check against the per-request records so a telemetry regression
    # can never silently skew the bench numbers.
    e2e_hist = srv.registry.get("serve_request_e2e_seconds").labels(
        replica=srv.obs_labels.get("replica", "solo"))
    assert not e2e_hist.overflowed, \
        "e2e reservoir overflowed: percentiles would be partial, not exact"
    e2e = np.array(sorted(r.t_done - r.t_submit for r in done))
    for q, pct in ((0.50, 50), (0.99, 99)):
        assert abs(e2e_hist.quantile(q) - float(np.percentile(e2e, pct))) \
            < 1e-9, "obs e2e histogram diverges from request records"
    # TTFT / inter-token-latency percentiles from the WINDOWED histograms
    # (the same instruments an SLO burns against), cross-checked against the
    # raw per-request lists; the 3600 s window covers the whole timed run.
    w_ttft = srv.registry.get("serve_ttft_window_seconds")
    w_itl = srv.registry.get("serve_itl_window_seconds")
    itl = sorted(v for r in done for v in (r.itl_s or ()))
    for wh, raw in ((w_ttft, sorted(ttft)), (w_itl, itl)):
        assert wh.samples_dropped() == 0, \
            f"{wh.name}: windowed reservoir overflowed during the bench"
        assert wh.count() == len(raw), \
            f"{wh.name}: windowed count {wh.count()} != {len(raw)} raw"
        for q, pct in ((0.50, 50), (0.99, 99)):
            assert abs(wh.quantile(q) - float(np.percentile(raw, pct))) \
                < 1e-9, f"{wh.name} diverges from raw request records"
    st = srv.stats
    steps = st["steps"]
    out = {
        "arch": cfg.name,
        "mode": "int8-ffip" if quantized else "float",
        "gemm": {"impl": gemm_impl or "xla",
                 "block": list(gemm_block) if isinstance(gemm_block, tuple)
                 else gemm_block},
        "slots": slots,
        "requests": n_reqs,
        "decode_chunk": decode_chunk,
        "completed": len(done),
        "tokens_out": total,
        "decode_steps": steps,
        "decode_dispatches": st["decode_dispatches"],
        "compile_s": round(compile_s, 3),
        "wall_s": round(wall, 3),
        "tok_per_s": round(total / wall, 2),
        "steps_per_s": round(steps / max(st["decode_s"], 1e-9), 2),
        "phase_s": {
            "prefill": round(st["prefill_s"], 3),
            "decode": round(st["decode_s"], 3),
            "host_other": round(wall - st["prefill_s"] - st["decode_s"], 3),
        },
        "prefill_tokens": st["prefill_tokens"],
        "prefill_dispatches": st["prefill_dispatches"],
        "decode_tokens": st["decode_tokens"],
        "decode_ms_per_step": round(1e3 * st["decode_s"] / max(steps, 1), 2),
        # queue wait + prefill until the first token, per request; p50/p99
        # sourced from the windowed histogram serve_ttft_window_seconds
        "ttft_ms": {"mean": round(1e3 * sum(ttft) / len(ttft), 2),
                    "max": round(1e3 * max(ttft), 2),
                    "p50": round(1e3 * w_ttft.quantile(0.50), 2),
                    "p99": round(1e3 * w_ttft.quantile(0.99), 2)},
        # per emitted token, from serve_itl_window_seconds (fused decode
        # chunks amortize: each of the k tokens is charged dispatch_dt / k)
        "itl_ms": {"p50": round(1e3 * w_itl.quantile(0.50), 2),
                   "p99": round(1e3 * w_itl.quantile(0.99), 2)},
        # submit -> last token, per request (queue wait included); sourced
        # from the obs histogram serve_request_e2e_seconds
        "e2e_ms": {"p50": round(1e3 * e2e_hist.quantile(0.50), 2),
                   "p99": round(1e3 * e2e_hist.quantile(0.99), 2)},
        # on-device sampling: ids, not logits, cross per decode step
        "host_bytes_per_step": round(st["host_bytes_decode"] / max(steps, 1), 1),
        "host_bytes_per_step_pr2": slots * cfg.vocab * 4,   # (B, V) f32 logits
        "compiles": dict(srv.compiles),
    }
    if paged:
        assert srv._reserved == 0, "page reservation ledger did not drain"
        assert (srv.alloc.free_count + srv.alloc.in_use
                == srv.alloc.num_pages), "page allocator leaked"
        out["tokens_by_rid"] = {r.rid: list(r.out_tokens) for r in done}
        out["paged"] = {
            "attention": paged_attention,
            "page_size": page_size,
            "num_pages": srv.alloc.num_pages,
            "prefill_chunk": srv.prefill_chunk,
            "pages_peak": st["pages_peak"],
            "contiguous_equiv_pages": slots * (max_len // page_size),
            # pages still held by the prefix index after drain (warm cache)
            "prefix_cache_pages_resident": srv.alloc.in_use,
            "prefix_hit_tokens": st["prefix_hit_tokens"],
            "cow_copies": st["cow_copies"],
            "prefill_chunks": st["prefill_chunks"],
            "host_bytes_page_tables": st["host_bytes_page_tables"],
        }
    elif mix_long_len or keep_tokens:
        out["tokens_by_rid"] = {r.rid: list(r.out_tokens) for r in done}
    return out


def bench_prepared(arch: str, *, slots: int, requests: int, max_new: int,
                   max_len: int) -> dict:
    """Cold offline prep vs artifact warm start (the repro.prepare contract):
    time the in-process prep (quantize + Eq. 9 y-deltas), the artifact
    save/load roundtrip, and a warm serve from the loaded artifact with the
    zero-recompute assertion."""
    import shutil
    import tempfile

    from repro import prepare

    cfg = configs.smoke_config(configs.get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    t0 = time.perf_counter()
    pm = prepare.prepare_lm(params, quantized=True)
    # the transforms are lazy jax ops until materialized — block before
    # stopping the clock so cold_prep_s is the real offline cost
    jax.block_until_ready(jax.tree.leaves(pm.params))
    jax.block_until_ready(list(pm.derived.values()))
    cold_prep_s = time.perf_counter() - t0

    tmp = tempfile.mkdtemp(prefix="serve_bench_prep_")
    art_dir = pathlib.Path(tmp) / "artifact"
    try:
        t0 = time.perf_counter()
        pm.save(art_dir)
        save_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        pm2 = prepare.load(art_dir)
        jax.block_until_ready(jax.tree.leaves(pm2.params))
        warm_load_s = time.perf_counter() - t0
        nbytes = sum(f.stat().st_size for f in art_dir.iterdir())

        srv = BatchServer(model, batch_slots=slots, max_len=max_len,
                          quantized=True, decode_chunk=4, prepared=pm2)
        for r in _requests(cfg, requests, max_new, 0):
            srv.submit(r)
        done = srv.run_until_drained(params)
        assert len(done) == requests, "serve_bench: requests dropped"
        assert pm2.recomputed == 0, pm2.recompute_report()
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    st = srv.stats
    return {
        "arch": cfg.name,
        "cold_prep_s": round(cold_prep_s, 3),
        "save_s": round(save_s, 3),
        "warm_load_s": round(warm_load_s, 3),
        "prep_over_load": round(cold_prep_s / max(warm_load_s, 1e-9), 1),
        "artifact_bytes": nbytes,
        "y_deltas": len(pm.derived),
        "recomputed_after_warm_serve": pm2.recomputed,
        "warm_serve_decode_ms_per_step":
            round(1e3 * st["decode_s"] / max(st["steps"], 1), 2),
    }


def bench_faults(arch: str, *, slots: int, requests: int, max_new: int,
                 max_len: int) -> dict:
    """Fault-tolerance section: 3 replicas, replica 0 flapping on a seeded
    plan (raise/hang alternating, fake clock), vs the same fleet with no
    faults. Records outcome counts, retries/failovers, e2e p50/p99 (fake
    seconds — queue wait + retries dominate, which is the point), and
    asserts every completion is token-identical to the no-fault run."""
    from repro.serve.faults import FakeClock, FaultPlan
    from repro.serve.lifecycle import Lifecycle
    from repro.serve.router import ReplicaRouter, RouterConfig

    cfg = configs.smoke_config(configs.get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def run(plan):
        reg = obs.Registry()
        servers = [BatchServer(model, batch_slots=slots, max_len=max_len,
                               registry=reg)
                   for _ in range(3)]
        rt = ReplicaRouter(
            servers, params, fault_plan=plan, clock=FakeClock(),
            registry=reg,
            cfg=RouterConfig(step_timeout_s=5.0, quarantine_s=0.2,
                             max_retries=4))
        t0 = time.perf_counter()
        for r in _requests(cfg, requests, max_new, 0):
            rt.submit(r)
        recs = rt.drive(max_ticks=20_000)
        wall = time.perf_counter() - t0
        done = [rec for rec in recs.values()
                if rec.state is Lifecycle.DONE]
        # fake-clock e2e percentiles from the router's obs histogram,
        # cross-checked against the lifecycle records
        hist = reg.get("router_request_e2e_seconds")
        lat = np.array(sorted(rec.t_done - rec.t_submit for rec in done))
        for q, pct in ((0.50, 50), (0.99, 99)):
            assert abs(hist.quantile(q) - float(np.percentile(lat, pct))) \
                < 1e-9, "obs router e2e histogram diverges from records"
        return recs, rt, wall, hist

    quiet_plan = FaultPlan([], seed=0)
    flaky_plan = FaultPlan.flaky_replica(0, start=2, period=4, rounds=4,
                                         seed=0)
    ref, _, quiet_wall, quiet_hist = run(quiet_plan)
    recs, rt, wall, hist = run(flaky_plan)
    for rid, rec in recs.items():
        assert rec.terminal, f"rid {rid} not terminal under faults"
        if rec.state is Lifecycle.DONE:
            assert rec.tokens == ref[rid].tokens, \
                f"rid {rid} diverges from the no-fault fleet"
    return {
        "arch": cfg.name,
        "fleet": {"replicas": 3, "flaky": "replica 0 (raise/hang, "
                                          "4 rounds, period 4)"},
        "plan": json.loads(flaky_plan.to_json()),
        "outcomes": rt.outcome_counts(),
        "router": dict(rt.stats),
        "wall_s": round(wall, 3),
        "wall_s_no_fault": round(quiet_wall, 3),
        # fake-clock seconds: queue wait + backoff + failover, not compute;
        # sourced from the obs histogram router_request_e2e_seconds
        "e2e_fake_s": {
            "no_fault": {"p50": round(quiet_hist.quantile(0.50), 3),
                         "p99": round(quiet_hist.quantile(0.99), 3)},
            "flaky": {"p50": round(hist.quantile(0.50), 3),
                      "p99": round(hist.quantile(0.99), 3)},
        },
        "tokens_identical_to_no_fault": True,
    }


def bench_tp(arch: str, *, slots: int, requests: int, max_new: int,
             max_len: int) -> list:
    """Tensor-parallel decode sweep: ms/step at model-parallel 1/2/4 over
    whatever devices are visible (force host devices with
    XLA_FLAGS=--xla_force_host_platform_device_count=8 to sweep past 1).
    Output tokens are asserted identical across TP widths."""
    from jax.sharding import Mesh

    n = jax.device_count()
    rows, ref_tokens = [], None
    for tp in (1, 2, 4):
        if tp > n:
            continue
        mesh = (Mesh(np.array(jax.devices()[:tp]).reshape(1, tp),
                     ("data", "model")) if tp > 1 else None)
        for quantized in (False, True):
            r = bench(arch, slots=slots, requests=requests, max_new=max_new,
                      max_len=max_len, quantized=quantized, decode_chunk=1,
                      mesh=mesh, keep_tokens=True)
            toks = r.pop("tokens_by_rid")
            key = r["mode"]
            if tp == 1:
                ref_tokens = ref_tokens or {}
                ref_tokens[key] = toks
            elif ref_tokens and key in ref_tokens:
                assert toks == ref_tokens[key], \
                    f"tp={tp} {key} tokens diverge from single-device"
            rows.append({"tp": tp, "mode": r["mode"],
                         "decode_ms_per_step": r["decode_ms_per_step"],
                         "tok_per_s": r["tok_per_s"],
                         "compile_s": r["compile_s"]})
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b",
                    choices=sorted(configs.ARCHS))
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--chunks", type=int, nargs="+", default=[1, 2, 4, 8],
                    help="decode_chunk sweep (quantized mode, being ~5x "
                         "slower, runs only the first value and 4, deduped)")
    ap.add_argument("--gemm-impl", choices=["xla", "pallas"], default=None,
                    help="GEMM provider for the serving forward")
    ap.add_argument("--gemm-block", default=None,
                    help="'auto' = repro.tune schedule cache (tunes flash "
                         "attention blocks too) or explicit 'bm,bn,bk' (needs --gemm-impl pallas)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="paged-section prefill chunk (page-aligned)")
    ap.add_argument("--long-len", type=int, default=48,
                    help="long-prompt length in the paged TTFT mix")
    ap.add_argument("--skip-paged", action="store_true",
                    help="contiguous sweep only")
    ap.add_argument("--skip-prepared", action="store_true",
                    help="skip the prepared-artifact warm-start section")
    ap.add_argument("--skip-tp", action="store_true",
                    help="skip the tensor-parallel decode sweep")
    ap.add_argument("--skip-faults", action="store_true",
                    help="skip the flaky-replica router section")
    args = ap.parse_args()
    gemm_block = args.gemm_block
    if gemm_block and gemm_block != "auto":
        gemm_block = tuple(int(x) for x in gemm_block.split(","))

    results = []
    for quantized in (False, True):
        chunks = args.chunks if not quantized else sorted({args.chunks[0], 4})
        for chunk in chunks:
            results.append(bench(
                args.arch, slots=args.slots, requests=args.requests,
                max_new=args.max_new, max_len=args.max_len,
                quantized=quantized, decode_chunk=chunk,
                gemm_impl=args.gemm_impl, gemm_block=gemm_block))

    def _best(rs, mode):
        return max((r for r in rs if r["mode"] == mode),
                   key=lambda r: r["steps_per_s"])

    # the PR2 baseline was measured on one specific workload; only claim a
    # speedup when this run reproduces it (otherwise skip the comparison
    # rather than divide numbers from different workloads).
    comparable = (args.arch == "minicpm-2b" and args.slots == 4
                  and args.requests == 6 and args.max_new == 4)
    comparison = {}
    for base in BASELINE_PR2 if comparable else []:
        new = _best(results, base["mode"])
        comparison[base["mode"]] = {
            "decode_ms_per_step": {"pr2": base["decode_ms_per_step"],
                                   "now": new["decode_ms_per_step"],
                                   "best_chunk": new["decode_chunk"]},
            "decode_speedup": round(base["decode_ms_per_step"]
                                    / new["decode_ms_per_step"], 2),
            "tok_per_s": {"pr2": base["tok_per_s"], "now": new["tok_per_s"]},
            "host_bytes_per_step": {"pr2": new["host_bytes_per_step_pr2"],
                                    "now": new["host_bytes_per_step"]},
        }

    # --- paged section: shared-prefix + one long prompt, chunked prefill,
    # gather attention (bit-identical math to the contiguous oracle), plus
    # the SAME mix run contiguously so TTFT/footprint sit side by side.
    results_paged, comparison_paged = [], {}
    if not args.skip_paged:
        mix = dict(slots=args.slots, requests=args.requests,
                   max_new=args.max_new, max_len=args.max_len,
                   gemm_impl=args.gemm_impl, gemm_block=gemm_block,
                   mix_long_len=args.long_len)
        for quantized, chunks in ((False, (1, 4)), (True, (4,))):
            for chunk in chunks:
                results_paged.append(bench(
                    args.arch, quantized=quantized, decode_chunk=chunk,
                    paged=True, page_size=args.page_size,
                    prefill_chunk=args.prefill_chunk, **mix))
        ref = bench(args.arch, quantized=False, decode_chunk=4, **mix)
        pg = next(r for r in results_paged
                  if r["mode"] == "float" and r["decode_chunk"] == 4)
        assert pg.pop("tokens_by_rid") == ref.pop("tokens_by_rid"), \
            "paged gather outputs diverge from contiguous on the mix workload"
        for r in results_paged:
            r.pop("tokens_by_rid", None)
        comparison_paged = {
            "workload": (f"{args.requests} shared-prefix requests + one "
                         f"{args.long_len}-token prompt, prefill_chunk="
                         f"{args.prefill_chunk} (chunks interleave with "
                         "decode), warm prefix cache, outputs byte-identical"),
            "ttft_ms": {"contiguous": ref["ttft_ms"],
                        "paged_chunked": pg["ttft_ms"]},
            "prefill_tokens": {"contiguous": ref["prefill_tokens"],
                               "paged_warm_prefix": pg["prefill_tokens"]},
            "pages_peak": pg["paged"]["pages_peak"],
            "contiguous_equiv_pages": pg["paged"]["contiguous_equiv_pages"],
            "prefix_hit_tokens": pg["paged"]["prefix_hit_tokens"],
        }

    # --- prepared-artifact warm start + tensor-parallel decode sections
    results_prepared = {} if args.skip_prepared else bench_prepared(
        args.arch, slots=args.slots, requests=args.requests,
        max_new=args.max_new, max_len=args.max_len)
    results_tp = [] if args.skip_tp else bench_tp(
        args.arch, slots=args.slots, requests=args.requests,
        max_new=args.max_new, max_len=args.max_len)
    results_faults = {} if args.skip_faults else bench_faults(
        args.arch, slots=args.slots, requests=args.requests,
        max_new=args.max_new, max_len=args.max_len)

    out = {
        "bench": "serve",
        "note": ("CPU-only container: interpret-mode timings; ratios, the "
                 "chunk sweep, and the host-transfer reduction are the "
                 "load-bearing numbers. compile_s is jit warmup, excluded "
                 "from wall_s (baseline_pr2 wall_s includes it). "
                 "baseline_prev = contiguous numbers from just before the "
                 "block-paged KV cache landed. Paged rows time the GATHER "
                 "oracle + per-chunk host dispatch on CPU (worst case for "
                 "paging); the load-bearing paged outputs are the footprint "
                 "(pages_peak vs contiguous_equiv_pages) and the "
                 "prefix-hit / prefill-token collapse, not tok/s. "
                 "e2e_ms / e2e_fake_s percentiles are sourced from the "
                 "repro.obs latency histograms (exact-reservoir quantiles, "
                 "cross-checked against per-request records in-process)."),
        "baseline_pr2": BASELINE_PR2,
        "baseline_prev": BASELINE_PREV,
        "comparison": comparison,
        "comparison_paged": comparison_paged,
        "results": results,
        "results_paged": results_paged,
        # repro.prepare warm start: cold offline prep vs artifact load, plus
        # a warm serve with the zero-recompute assertion
        "results_prepared": results_prepared,
        # tensor-parallel decode ms/step at model-parallel 1/2/4 (widths
        # beyond the visible device count are skipped; tokens asserted
        # identical across widths)
        "results_tp": results_tp,
        # multi-replica router with 1-of-3 replicas flapping on a seeded
        # plan: outcome counts, retries/failovers, and the e2e latency tax
        # of failover vs the no-fault fleet (completions token-identical)
        "results_faults": results_faults,
    }
    OUT.write_text(json.dumps(out, indent=2) + "\n")
    for r in results:
        print(f"serve_bench.{r['arch']}.{r['mode']}.chunk{r['decode_chunk']},"
              f"{r['tok_per_s']} tok/s,{r['steps_per_s']} steps/s,"
              f"decode={r['phase_s']['decode']}s,"
              f"ttft_p99={r['ttft_ms']['p99']}ms,"
              f"itl_p99={r['itl_ms']['p99']}ms,"
              f"compile={r['compile_s']}s,"
              f"host_B/step={r['host_bytes_per_step']}")
    for r in results_paged:
        p = r["paged"]
        print(f"serve_bench.{r['arch']}.{r['mode']}.paged-chunk"
              f"{r['decode_chunk']},{r['tok_per_s']} tok/s,"
              f"ttft_mean={r['ttft_ms']['mean']}ms,"
              f"pages_peak={p['pages_peak']}/{p['contiguous_equiv_pages']},"
              f"prefix_hit={p['prefix_hit_tokens']} tok,"
              f"cow={p['cow_copies']},chunks={p['prefill_chunks']}")
    for mode, c in comparison.items():
        print(f"vs PR2 [{mode}]: decode {c['decode_ms_per_step']['pr2']}ms -> "
              f"{c['decode_ms_per_step']['now']}ms/step "
              f"({c['decode_speedup']}x), host bytes/step "
              f"{c['host_bytes_per_step']['pr2']} -> "
              f"{c['host_bytes_per_step']['now']}")
    if comparison_paged:
        c = comparison_paged
        print(f"paged mix: ttft mean {c['ttft_ms']['contiguous']['mean']}ms "
              f"(contiguous) vs {c['ttft_ms']['paged_chunked']['mean']}ms "
              f"(paged+chunked, warm prefix), prefill tokens "
              f"{c['prefill_tokens']['contiguous']} -> "
              f"{c['prefill_tokens']['paged_warm_prefix']}, pages_peak "
              f"{c['pages_peak']}/{c['contiguous_equiv_pages']}")
    if results_prepared:
        p = results_prepared
        print(f"prepared: cold prep {p['cold_prep_s']}s vs warm load "
              f"{p['warm_load_s']}s ({p['prep_over_load']}x), "
              f"{p['y_deltas']} y-deltas, {p['artifact_bytes']} B, "
              f"recomputed={p['recomputed_after_warm_serve']}")
    for r in results_tp:
        print(f"serve_bench.tp{r['tp']}.{r['mode']},"
              f"decode_ms_per_step={r['decode_ms_per_step']},"
              f"{r['tok_per_s']} tok/s")
    if results_faults:
        f = results_faults
        print(f"faults: outcomes={f['outcomes']}, "
              f"retries={f['router']['retries']}, "
              f"failures={f['router']['replica_failures']}, "
              f"quarantines={f['router']['quarantines']}, "
              f"e2e p99 {f['e2e_fake_s']['no_fault']['p99']} -> "
              f"{f['e2e_fake_s']['flaky']['p99']} fake-s, "
              f"tokens identical: {f['tokens_identical_to_no_fault']}")
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
