"""Scan-aware analytic cost model over jaxprs.

XLA's HloCostAnalysis counts while-loop bodies ONCE (trip counts unknown to
it) and reports per-partition numbers, which makes it useless for
scan-over-layers models. This walker computes GLOBAL HLO-level FLOPs and HBM
bytes from the closed jaxpr, multiplying scan/while bodies by their trip
counts.

Byte model (what hits HBM on TPU, post-fusion):
  * dot_general / conv: operands read + result written;
  * reduce / gather / scatter / sort / cumsum: operands + results;
  * scan: per-iteration carry read+write + xs/ys slices (+ body costs x length);
  * elementwise & broadcasts: assumed fused into neighbours (0 bytes, flops
    still counted);
  * entry params + outputs counted once (weights stream in every step).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np

_ELEMENTWISE_FLOPS = {
    "add": 1, "sub": 1, "mul": 1, "div": 1, "max": 1, "min": 1, "neg": 1,
    "exp": 4, "log": 4, "tanh": 6, "logistic": 6, "erf": 6, "rsqrt": 2,
    "sqrt": 2, "pow": 6, "integer_pow": 2, "cos": 4, "sin": 4,
    "select_n": 1, "and": 1, "or": 1, "not": 1, "xor": 1,
    "eq": 1, "ne": 1, "lt": 1, "le": 1, "gt": 1, "ge": 1, "sign": 1, "abs": 1,
    "floor": 1, "ceil": 1, "round": 1, "clamp": 2, "rem": 2, "cumsum": 1,
    "cumlogsumexp": 6, "cumprod": 1, "cummax": 1,
}

_MATERIALIZING = {"reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
                  "reduce_and", "reduce_or", "argmax", "argmin",
                  "gather", "scatter", "scatter-add", "scatter_add",
                  "sort", "top_k", "cumsum", "cumprod", "cummax",
                  "dynamic_slice", "dynamic_update_slice"}


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0

    def __iadd__(self, o):
        self.flops += o.flops
        self.bytes += o.bytes
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k)


def _nbytes(aval) -> float:
    return float(np.prod(aval.shape)) * aval.dtype.itemsize if aval.shape else aval.dtype.itemsize


def _nelems(aval) -> float:
    return float(np.prod(aval.shape)) if aval.shape else 1.0


def _dot_flops(eqn) -> float:
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    m = np.prod([s for i, s in enumerate(lhs.shape) if i not in lc and i not in lb]) if lhs.shape else 1
    n = np.prod([s for i, s in enumerate(rhs.shape) if i not in rc and i not in rb]) if rhs.shape else 1
    k = np.prod([lhs.shape[i] for i in lc]) if lc else 1
    b = np.prod([lhs.shape[i] for i in lb]) if lb else 1
    return 2.0 * float(b) * float(m) * float(n) * float(k)


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    # flops = 2 * out_elems * (kernel spatial x in-features)
    dn = eqn.params["dimension_numbers"]
    k_spatial = np.prod([rhs.shape[i] for i in dn.rhs_spec[2:]]) if len(rhs.shape) > 2 else 1
    cin = rhs.shape[dn.rhs_spec[1]]
    return 2.0 * _nelems(out) * float(k_spatial) * float(cin)


def jaxpr_cost(jaxpr) -> Cost:
    total = Cost()
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        out_aval = eqn.outvars[0].aval if eqn.outvars else None
        if prim == "dot_general":
            total += Cost(_dot_flops(eqn),
                          sum(_nbytes(v.aval) for v in eqn.invars)
                          + _nbytes(out_aval))
        elif prim in ("conv_general_dilated",):
            total += Cost(_conv_flops(eqn),
                          sum(_nbytes(v.aval) for v in eqn.invars)
                          + _nbytes(out_aval))
        elif prim == "scan":
            body = eqn.params["jaxpr"].jaxpr
            length = eqn.params["length"]
            n_carry = eqn.params["num_carry"]
            n_consts = eqn.params["num_consts"]
            inner = jaxpr_cost(body)
            carry_bytes = sum(_nbytes(v.aval)
                              for v in eqn.invars[n_consts:n_consts + n_carry]) * 2
            xs_bytes = sum(_nbytes(v.aval) / max(length, 1)
                           for v in eqn.invars[n_consts + n_carry:])
            ys_bytes = sum(_nbytes(v.aval) / max(length, 1)
                           for v in eqn.outvars[n_carry:])
            total += inner.scaled(length)
            total += Cost(0.0, length * (carry_bytes + xs_bytes + ys_bytes))
        elif prim == "while":
            body = eqn.params["body_jaxpr"].jaxpr
            trip = 1.0  # unknown; our models use scan, not raw while
            total += jaxpr_cost(body).scaled(trip)
        elif prim == "cond":
            branches = eqn.params["branches"]
            costs = [jaxpr_cost(b.jaxpr) for b in branches]
            worst = max(costs, key=lambda c: c.flops) if costs else Cost()
            total += worst
        elif prim == "pallas_call":
            total += _pallas_cost(eqn)
        elif prim in ("pjit", "custom_jvp_call", "custom_vjp_call",
                      "custom_vjp_call_jaxpr", "closed_call", "core_call",
                      "remat_call", "checkpoint", "custom_lin"):
            sub = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
            if sub is not None:
                total += jaxpr_cost(getattr(sub, "jaxpr", sub))
        elif prim in _ELEMENTWISE_FLOPS:
            total += Cost(_ELEMENTWISE_FLOPS[prim] * _nelems(out_aval), 0.0)
        elif prim in _MATERIALIZING:
            total += Cost(0.0, sum(_nbytes(v.aval) for v in eqn.invars)
                          + sum(_nbytes(v.aval) for v in eqn.outvars))
        elif prim in ("reduce_sum", "reduce_max"):
            pass
        else:
            # softmax building blocks etc. arrive as primitives above; anything
            # else (reshape/transpose/broadcast/slice/convert) is fusion-free.
            for sub_name in ("jaxpr", "call_jaxpr", "body_jaxpr"):
                sub = eqn.params.get(sub_name) if hasattr(eqn, "params") else None
                if sub is not None:
                    total += jaxpr_cost(getattr(sub, "jaxpr", sub))
                    break
    return total


def _pallas_cost(eqn) -> Cost:
    """Cost of a Pallas kernel call — the whole point of VMEM blocking.

    FLOPs: kernel-body cost x number of grid points. HBM bytes: per operand,
    block_bytes x number of block FETCHES — a block is re-fetched when a grid
    dim its index_map ignores iterates SLOWER than (left of) its own fastest
    referenced dim (Pallas keeps a block resident across consecutive grid
    steps that map to the same block index). Scratch (VMEM) is free — that is
    precisely the flash-attention saving vs naive score materialization.
    """
    gm = eqn.params["grid_mapping"]
    grid = tuple(int(g) for g in gm.grid)
    n_pts = float(np.prod(grid)) if grid else 1.0
    body = eqn.params["jaxpr"]
    inner = jaxpr_cost(getattr(body, "jaxpr", body))
    bytes_total = 0.0
    for bm in gm.block_mappings:
        blk_aval = bm.block_aval
        shape = getattr(blk_aval, "shape", ())
        blk_bytes = float(np.prod(shape)) * blk_aval.dtype.itemsize if shape \
            else blk_aval.dtype.itemsize
        # which grid dims does this block's index depend on?
        imj = bm.index_map_jaxpr.jaxpr
        used = set()
        for outv in imj.outvars:
            # walk back: any invar (grid index) reachable -> conservative: mark
            # all invars appearing in eqns feeding outvars. Simple approach:
            pass
        # conservative dependence: an invar is 'used' if it appears anywhere
        # in the index-map jaxpr outputs or equations.
        live = {id(v) for v in imj.outvars}
        changed = True
        eqs = list(imj.eqns)
        while changed:
            changed = False
            for e in eqs:
                if any(id(ov) in live for ov in e.outvars):
                    for iv in e.invars:
                        if type(iv).__name__ != "Literal" and id(iv) not in live:
                            live.add(id(iv))
                            changed = True
        used = {i for i, v in enumerate(imj.invars) if id(v) in live}
        if used:
            rightmost = max(used)
            fetches = np.prod([grid[d] for d in used]) * np.prod(
                [grid[d] for d in range(len(grid))
                 if d not in used and d < rightmost] or [1])
        else:
            fetches = 1.0
        bytes_total += blk_bytes * float(fetches)
    return Cost(inner.flops * n_pts, bytes_total)


def fn_cost(fn, *args, **kwargs) -> Cost:
    """Trace fn abstractly (ShapeDtypeStructs fine) and cost its jaxpr.
    Adds entry params/outputs bytes once (weight streaming + output write)."""
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    c = jaxpr_cost(closed.jaxpr)
    c += Cost(0.0, sum(_nbytes(v.aval) for v in closed.jaxpr.invars)
              + sum(_nbytes(v.aval) for v in closed.jaxpr.outvars))
    return c


def jaxpr_cost_breakdown(jaxpr, scale: float = 1.0, out=None, prefix=""):
    """Per-primitive (flops, bytes) attribution, scan-scaled — the dry-run
    'profile' used by the §Perf hypothesis loop."""
    if out is None:
        out = {}
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "scan":
            body = eqn.params["jaxpr"].jaxpr
            jaxpr_cost_breakdown(body, scale * eqn.params["length"], out,
                                 prefix)
            continue
        if prim == "pallas_call":
            c = _pallas_cost(eqn)
            cur = out.setdefault(f"pallas:{eqn.params.get('name', '?')}", Cost())
            cur.flops += c.flops * scale
            cur.bytes += c.bytes * scale
            continue
        if prim in ("pjit", "custom_vjp_call", "custom_jvp_call", "cond",
                    "while", "checkpoint", "remat", "remat2", "closed_call",
                    "core_closed_call", "custom_lin", "custom_vjp_call_jaxpr"):
            for key in ("jaxpr", "call_jaxpr", "body_jaxpr", "fun_jaxpr"):
                sub = eqn.params.get(key)
                if sub is not None:
                    jaxpr_cost_breakdown(getattr(sub, "jaxpr", sub), scale,
                                         out, prefix)
                    break
            if prim == "cond":
                for b in eqn.params.get("branches", []):
                    jaxpr_cost_breakdown(b.jaxpr, scale, out, prefix)
            continue
        single = Cost()
        tmp_jaxpr = type("J", (), {"eqns": [eqn]})()
        single = jaxpr_cost(tmp_jaxpr)
        if single.flops or single.bytes:
            # tag dots with their shape signature for actionable output
            tag = prim
            if prim == "dot_general":
                lhs = "x".join(map(str, eqn.invars[0].aval.shape))
                rhs = "x".join(map(str, eqn.invars[1].aval.shape))
                tag = f"dot {lhs} @ {rhs}"
            cur = out.setdefault(tag, Cost())
            cur.flops += single.flops * scale
            cur.bytes += single.bytes * scale
    return out


def top_costs(fn, *args, n: int = 15, by: str = "bytes"):
    closed = jax.make_jaxpr(fn)(*args)
    detail = jaxpr_cost_breakdown(closed.jaxpr)
    rows = sorted(detail.items(), key=lambda kv: -getattr(kv[1], by))[:n]
    return [(k, v.flops, v.bytes) for k, v in rows]
