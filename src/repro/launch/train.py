"""Distributed training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b \
        --steps 100 --batch 8 --seq 256 [--smoke] [--ckpt-dir DIR]

On a real TPU slice this runs under `jax.distributed.initialize()` with the
production mesh; on this CPU container use --smoke (reduced config, host
mesh). The step function is identical to the one the dry-run lowers for the
16x16 / 2x16x16 meshes.
"""
from __future__ import annotations

import argparse

import jax

from repro import configs
from repro.dist import context as dctx
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.model import build_model
from repro.optim.adamw import AdamWConfig
from repro.train.loop import LoopConfig, train
from repro.train.step import TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(configs.ARCHS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--schedule", default="cosine",
                    choices=["cosine", "wsd", "const"])
    args = ap.parse_args()

    cfg = configs.get_config(args.arch)
    if args.smoke:
        cfg = configs.smoke_config(cfg)
        mesh = make_host_mesh()
    else:
        if jax.device_count() < 256:
            raise SystemExit(
                "full configs need the production mesh; run the dry-run for "
                "lowering checks on CPU, or pass --smoke")
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    # MiniCPM trains with WSD per its paper
    sched = "wsd" if (args.arch == "minicpm-2b" and args.schedule == "cosine") \
        else args.schedule
    model = build_model(cfg)
    with dctx.mesh_context(mesh):
        out = train(
            model,
            loop_cfg=LoopConfig(total_steps=args.steps,
                                global_batch=args.batch, seq_len=args.seq,
                                ckpt_dir=args.ckpt_dir, log_every=5),
            train_cfg=TrainConfig(optimizer=AdamWConfig(
                schedule=sched, warmup_steps=max(1, args.steps // 10),
                total_steps=args.steps)),
            log_fn=lambda m: print(
                f"step {m['data_step']:>5} loss {m['loss']:.4f} "
                f"lr {m['lr']:.2e}", flush=True),
        )
    print(f"done; final loss {out['history'][-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
