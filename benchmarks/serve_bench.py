"""Serving benchmark: continuous-batching throughput + per-phase timings.

    PYTHONPATH=src python benchmarks/serve_bench.py [--arch minicpm-2b]

Runs the continuous batcher (float and int8-FFIP quantized modes) over a
stream of mixed-length requests, sweeping the fused-decode ``decode_chunk``
knob, and writes ``benchmarks/BENCH_serve.json``: tok/s, steps/s, the
prefill / decode / host-overhead split from BatchServer.stats, per-step host
transfer, and compile counts.

Jit warmup runs OUTSIDE the timed region (a covering workload — every prompt
bucket plus a decode dispatch — compiles first; its wall time is reported
separately as ``compile_s``), so the timed numbers are steady-state serving.
The PR 2 hot path (host-side argmax over (B, V) logits, one dispatch per
token, one prefill compile per prompt length, warmup inside the timed
region) is kept in the file verbatim under ``baseline_pr2`` for trajectory
comparison; ``comparison`` reports the decode speedup and the host-transfer
reduction against it.

CAVEAT (same as gemm_micro): this container is CPU-only, so absolute timings
measure the XLA-CPU + interpret-mode harness, not accelerator silicon — the
load-bearing outputs are the phase RATIOS, the chunk-sweep trend, and the
host-transfer reduction, which show what the fused hot path amortizes.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import numpy as np

from repro import configs
from repro.models.model import build_model
from repro.serve.batcher import BatchServer, Request

OUT = pathlib.Path(__file__).resolve().parent / "BENCH_serve.json"

# PR 2 numbers measured in this container on the identical workload
# (minicpm-2b-smoke, 4 slots, 6 requests, max_new=4, seed 0) with the PR 2
# hot path. Kept verbatim so the trajectory stays visible in one file.
BASELINE_PR2 = [
    {"arch": "minicpm-2b-smoke", "mode": "float", "slots": 4, "requests": 6,
     "tokens_out": 24, "decode_steps": 6, "wall_s": 4.921, "tok_per_s": 4.88,
     "phase_s": {"prefill": 4.121, "decode": 0.615, "host_other": 0.186},
     "decode_ms_per_step": 102.42},
    {"arch": "minicpm-2b-smoke", "mode": "int8-ffip", "slots": 4,
     "requests": 6, "tokens_out": 24, "decode_steps": 6, "wall_s": 14.343,
     "tok_per_s": 1.67,
     "phase_s": {"prefill": 10.156, "decode": 1.882, "host_other": 2.306},
     "decode_ms_per_step": 313.59},
]


def _requests(cfg, requests: int, max_new: int, seed: int):
    rng = np.random.default_rng(seed)
    lens = rng.integers(3, 12, requests)
    return [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=(int(l),)),
                    max_new_tokens=max_new) for i, l in enumerate(lens)]


def bench(arch: str, *, slots: int, requests: int, max_new: int,
          max_len: int, quantized: bool, decode_chunk: int,
          gemm_impl=None, gemm_block=None, seed: int = 0) -> dict:
    cfg = configs.smoke_config(configs.get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    srv = BatchServer(model, batch_slots=slots, max_len=max_len,
                      quantized=quantized, decode_chunk=decode_chunk,
                      gemm_impl=gemm_impl, gemm_block=gemm_block)

    # --- warmup (untimed region): compile every prompt bucket + the decode
    # program, using the same length distribution as the measured workload.
    # Budget 2: the minimum that reaches a decode dispatch (token 1 comes
    # from prefill), keeping warmup cheap regardless of --max-new.
    warm = _requests(cfg, requests, 2, seed)
    t0 = time.perf_counter()
    for r in warm:
        srv.submit(r)
    srv.run_until_drained(params)
    compile_s = time.perf_counter() - t0

    # --- timed steady-state run
    reqs = _requests(cfg, requests, max_new, seed)
    t0 = time.perf_counter()
    for r in reqs:
        srv.submit(r)
    done = srv.run_until_drained(params)
    wall = time.perf_counter() - t0
    assert len(done) == requests, "serve_bench: requests dropped"

    total = sum(len(r.out_tokens) for r in done)
    st = srv.stats
    steps = st["steps"]
    return {
        "arch": cfg.name,
        "mode": "int8-ffip" if quantized else "float",
        "gemm": {"impl": gemm_impl or "xla",
                 "block": list(gemm_block) if isinstance(gemm_block, tuple)
                 else gemm_block},
        "slots": slots,
        "requests": requests,
        "decode_chunk": decode_chunk,
        "completed": len(done),
        "tokens_out": total,
        "decode_steps": steps,
        "decode_dispatches": st["decode_dispatches"],
        "compile_s": round(compile_s, 3),
        "wall_s": round(wall, 3),
        "tok_per_s": round(total / wall, 2),
        "steps_per_s": round(steps / max(st["decode_s"], 1e-9), 2),
        "phase_s": {
            "prefill": round(st["prefill_s"], 3),
            "decode": round(st["decode_s"], 3),
            "host_other": round(wall - st["prefill_s"] - st["decode_s"], 3),
        },
        "prefill_tokens": st["prefill_tokens"],
        "prefill_dispatches": st["prefill_dispatches"],
        "decode_tokens": st["decode_tokens"],
        "decode_ms_per_step": round(1e3 * st["decode_s"] / max(steps, 1), 2),
        # on-device sampling: ids, not logits, cross per decode step
        "host_bytes_per_step": round(st["host_bytes_decode"] / max(steps, 1), 1),
        "host_bytes_per_step_pr2": slots * cfg.vocab * 4,   # (B, V) f32 logits
        "compiles": dict(srv.compiles),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b",
                    choices=sorted(configs.ARCHS))
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--chunks", type=int, nargs="+", default=[1, 2, 4, 8],
                    help="decode_chunk sweep (quantized mode, being ~5x "
                         "slower, runs only the first value and 4, deduped)")
    ap.add_argument("--gemm-impl", choices=["xla", "pallas"], default=None,
                    help="GEMM provider for the serving forward")
    ap.add_argument("--gemm-block", default=None,
                    help="'auto' = repro.tune schedule cache (tunes flash "
                         "attention blocks too) or explicit 'bm,bn,bk' (needs --gemm-impl pallas)")
    args = ap.parse_args()
    gemm_block = args.gemm_block
    if gemm_block and gemm_block != "auto":
        gemm_block = tuple(int(x) for x in gemm_block.split(","))

    results = []
    for quantized in (False, True):
        chunks = args.chunks if not quantized else sorted({args.chunks[0], 4})
        for chunk in chunks:
            results.append(bench(
                args.arch, slots=args.slots, requests=args.requests,
                max_new=args.max_new, max_len=args.max_len,
                quantized=quantized, decode_chunk=chunk,
                gemm_impl=args.gemm_impl, gemm_block=gemm_block))

    def _best(mode):
        return max((r for r in results if r["mode"] == mode),
                   key=lambda r: r["steps_per_s"])

    # the PR2 baseline was measured on one specific workload; only claim a
    # speedup when this run reproduces it (otherwise skip the comparison
    # rather than divide numbers from different workloads).
    comparable = (args.arch == "minicpm-2b" and args.slots == 4
                  and args.requests == 6 and args.max_new == 4)
    comparison = {}
    for base in BASELINE_PR2 if comparable else []:
        new = _best(base["mode"])
        comparison[base["mode"]] = {
            "decode_ms_per_step": {"pr2": base["decode_ms_per_step"],
                                   "now": new["decode_ms_per_step"],
                                   "best_chunk": new["decode_chunk"]},
            "decode_speedup": round(base["decode_ms_per_step"]
                                    / new["decode_ms_per_step"], 2),
            "tok_per_s": {"pr2": base["tok_per_s"], "now": new["tok_per_s"]},
            "host_bytes_per_step": {"pr2": new["host_bytes_per_step_pr2"],
                                    "now": new["host_bytes_per_step"]},
        }

    out = {
        "bench": "serve",
        "note": ("CPU-only container: interpret-mode timings; ratios, the "
                 "chunk sweep, and the host-transfer reduction are the "
                 "load-bearing numbers. compile_s is jit warmup, excluded "
                 "from wall_s (baseline_pr2 wall_s includes it)."),
        "baseline_pr2": BASELINE_PR2,
        "comparison": comparison,
        "results": results,
    }
    OUT.write_text(json.dumps(out, indent=2) + "\n")
    for r in results:
        print(f"serve_bench.{r['arch']}.{r['mode']}.chunk{r['decode_chunk']},"
              f"{r['tok_per_s']} tok/s,{r['steps_per_s']} steps/s,"
              f"decode={r['phase_s']['decode']}s,"
              f"compile={r['compile_s']}s,"
              f"host_B/step={r['host_bytes_per_step']}")
    for mode, c in comparison.items():
        print(f"vs PR2 [{mode}]: decode {c['decode_ms_per_step']['pr2']}ms -> "
              f"{c['decode_ms_per_step']['now']}ms/step "
              f"({c['decode_speedup']}x), host bytes/step "
              f"{c['host_bytes_per_step']['pr2']} -> "
              f"{c['host_bytes_per_step']['now']}")
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
