"""Search spaces for the kernel autotuner.

The paper's §5 design-space sweep picks a different systolic-array tiling per
device and precision; this module is the software analogue: the set of LEGAL
block-shape candidates per kernel, deterministically ordered so a tuning run
is reproducible and a budget-limited run always tries the same prefix.

Legality encodes each kernel's real constraints:
  * GEMM (baseline/fip/ffip): power-of-2 blocks within TPU-friendly bounds,
    ``bk`` even for the FIP-family pair algebra (Eq. 2 consumes k in pairs),
    and the FIP cross tensor ``3 x (bm, bk/2, bn)`` f32 fitting the per-core
    VMEM budget (the kernels pad non-divisible shapes, so divisibility of the
    problem shape is NOT a constraint — only block legality is);
  * flash attention: (bq, bk) power-of-2 sequence blocks; the head dim rides
    along untiled.

Ordering contract: the static default (what the code shipped with) is always
candidate 0, so a tuned schedule can only match or beat the default on the
machine that measured it; the remainder is ordered by log2 distance from the
default (nearest first, ties by ascending tuple) — a budget-limited run
explores the default's neighborhood, where the §5 sweep finds its optima,
before the far corners of the space. The order is deterministic either way.
"""
from __future__ import annotations

from typing import List, Tuple

from repro.kernels import ops as kops

Blocks = Tuple[int, int, int]

# Candidate axes: power-of-2, bounded to what the MXU/VPU tiling makes sane.
# bm reaches down to the f32 sublane tile (8) because serving decode GEMMs
# have M = batch_slots — tiny-M schedules are exactly what §5's sweep varies.
GEMM_BM = (8, 16, 32, 64, 128, 256)
GEMM_BN = (32, 64, 128, 256)
GEMM_BK_BASELINE = (32, 64, 128, 256, 512)
GEMM_BK_FIP = (8, 16, 32, 64, 128, 256)        # even: Eq. 2 pairs
FLASH_BQ = (64, 128, 256)
FLASH_BK = (64, 128, 256)


def round_up_pow2(x: int, lo: int = 8) -> int:
    p = lo
    while p < x:
        p *= 2
    return p


def gemm_block_legal(bm: int, bn: int, bk: int, algo: str,
                     itemsize: int = 4) -> bool:
    """Kernel-level legality of a (bm, bn, bk) block for ``algo``."""
    if min(bm, bn, bk) < 2:
        return False
    if algo in ("fip", "ffip"):
        if bk % 2 != 0:
            return False
        # the pre-add cross tensor is (bm, bk/2, bn); the kernel materializes
        # ~3 of them (g1, g2, product) in VMEM — same budget ops.choose_blocks
        # enforces for the static default.
        if 3 * bm * bn * (bk // 2) * itemsize > kops._VMEM_BUDGET:
            return False
    else:
        # baseline: operand + accumulator blocks in VMEM
        if (bm * bk + bk * bn + bm * bn) * itemsize > kops._VMEM_BUDGET:
            return False
    return True


def gemm_candidates(m: int, n: int, k: int, algo: str,
                    itemsize: int = 4) -> List[Blocks]:
    """Deterministically ordered legal candidates for an (m, k) x (k, n) GEMM.

    Blocks never exceed the pow2-rounded problem dims (a 256-wide block on a
    48-wide problem is pure padding waste), and the static default
    (ops.choose_blocks) always comes first.
    """
    bm_cap = round_up_pow2(m)
    bn_cap = round_up_pow2(n)
    bk_cap = round_up_pow2(k)
    bks = GEMM_BK_FIP if algo in ("fip", "ffip") else GEMM_BK_BASELINE
    cands = [
        (bm, bn, bk)
        for bm in GEMM_BM if bm <= bm_cap
        for bn in GEMM_BN if bn <= bn_cap
        for bk in bks if bk <= bk_cap
        if gemm_block_legal(bm, bn, bk, algo, itemsize)]
    default = tuple(kops.choose_blocks(m, n, k, algo, itemsize))

    def dist(c):
        return sum(abs(x.bit_length() - d.bit_length())
                   for x, d in zip(c, default))

    return [default] + sorted((c for c in cands if c != default),
                              key=lambda c: (dist(c), c))


def conv_candidates(m: int, n: int, k: int, ckw: int, algo: str,
                    itemsize: int = 4) -> List[Blocks]:
    """Candidates for the fused implicit-im2col conv kernels.

    Same legality as the GEMM space, but the bk axis prefers MULTIPLES OF
    ``ckw`` = Cin_g * KW — one full kernel-window row of the flattened
    (kh, kw, cin) contraction axis per block, so a k-block's gather walks
    contiguous input rows (the §5.1.1 W-partitioning locality). For the
    FIP-family pair algebra bk must also be even: odd ``ckw`` contributes its
    even multiples only. Power-of-2 bk values stay in the space as the
    fallback (they are what ``ops.choose_blocks`` defaults to), and the
    static default remains candidate 0 — tuning can only match-or-beat it.
    """
    ckw = max(1, ckw)
    aligned = []
    mult = ckw
    while mult <= min(k, max(GEMM_BK_BASELINE)):
        if mult % 2 == 0 or algo == "baseline":
            aligned.append(mult)
        mult += ckw
    bks = GEMM_BK_FIP if algo in ("fip", "ffip") else GEMM_BK_BASELINE
    bk_cap = round_up_pow2(k, lo=2)
    bk_axis = sorted(set(list(aligned) + [b for b in bks if b <= bk_cap]))
    bm_cap = round_up_pow2(m)
    bn_cap = round_up_pow2(n)
    cands = [
        (bm, bn, bk)
        for bm in GEMM_BM if bm <= bm_cap
        for bn in GEMM_BN if bn <= bn_cap
        for bk in bk_axis
        if gemm_block_legal(bm, bn, bk, algo, itemsize)]
    default = tuple(kops.choose_blocks(m, n, k, algo, itemsize))

    def dist(c):
        return sum(abs(x.bit_length() - d.bit_length())
                   for x, d in zip(c, default))

    return [default] + sorted((c for c in cands if c != default),
                              key=lambda c: (dist(c), c))


def flash_candidates(sq: int, sk: int) -> List[Tuple[int, int]]:
    """(bq, bk) candidates for flash attention; default (128, 128) first.
    The kernel clamps blocks to the (padded) sequence lengths itself."""
    bq_cap = round_up_pow2(sq, lo=min(FLASH_BQ))
    bk_cap = round_up_pow2(sk, lo=min(FLASH_BK))
    cands = sorted((bq, bk)
                   for bq in FLASH_BQ if bq <= bq_cap
                   for bk in FLASH_BK if bk <= bk_cap)
    default = (128, 128)
    return [default] + [c for c in cands if c != default]
