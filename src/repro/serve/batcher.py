"""Serving runtime: slot-based continuous batching over prefill/decode steps.

A fixed pool of B slots; requests occupy a slot, prefill writes their prompt
into the slot's cache region, then all active slots decode in lockstep at
their OWN positions: a ``(B,)`` position vector flows through the decode
program, so each slot writes its KV rows, applies rope, and masks attention
at its true offset (mixed-length prompts decode correctly side by side).
Finished slots (EOS or max_tokens) are immediately refilled from the queue —
the standard continuous-batching scheme (vLLM-style, simplified to
fixed-shape slots so XLA shapes stay static).

Hot-path discipline (the paper's Eq. 15 / §4.4 move — hoist everything off
the critical path — applied to serving):

* **On-device sampling**: the decode program ends in a fused argmax
  (``Model.sample_steps``); only ``(chunk, B)`` int32 token ids cross to the
  host per dispatch, never the ``(B, V)`` float logits.
* **Fused multi-step decode**: ``decode_chunk`` steps run as one
  ``lax.scan`` that feeds sampled tokens back on device, with per-slot
  position/remaining/EOS masking — a finished slot freezes and re-writes its
  own cache row with identical values, so the cache (and therefore every
  emitted token) stays bit-identical to one-step-at-a-time decode while host
  round-trips per token drop by 1/chunk.
* **Bucketed batched prefill**: prompts are padded to power-of-2 length
  buckets and same-bucket requests prefill together in ONE dispatch, written
  straight into the shared slot cache via masked ``dynamic_update_slice``
  (``Model.prefill_sample``) — no batch-1 scratch cache, no per-leaf
  scatter, and the prefill jit cache is bounded to O(log max_len) entries
  instead of one per distinct prompt length.

With ``quantized=True`` the dense/attention projections of the serving
forward route through the paper's int8 FFIP path: weights are quantized
OFFLINE (per-output-channel, asymmetric) with beta folded into the integer
bias (Eq. 15) and colsums precomputed; at decode time the Eq. 20 zero-point
adjuster removes the zero-point cross terms. Activations quantize per token
row, so batched, bucketed, and chunk-fused decoding all stay bit-identical
to sequential decoding.

**Paged mode** (``paged=True``): the per-slot ``slots x max_len`` contiguous
cache is replaced by a shared page POOL per cache leaf (``num_pages`` pages
of ``page_size`` tokens) addressed through a per-slot ``(B, max_pages)``
int32 page table. Pages are allocated on demand as a sequence grows, full
prompt pages are keyed by a rolling hash and SHARED across requests with
identical prefixes (refcounted; copy-on-write when a shared page would be
partially overwritten), and long prompts prefill in page-aligned CHUNKS —
one chunk dispatch per slot per step, interleaved with decode dispatches,
so a long prefill no longer stalls already-active slots. The contiguous
path is retained untouched as the bit-exactness oracle: with
``paged_attention="gather"`` the paged decode gathers pool rows into the
contiguous layout and runs the identical attention math, so emitted tokens
are bit-identical to ``paged=False`` (float and int8-FFIP alike).
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

import repro.obs as obs
from repro.core.gemm import GemmConfig, use_gemm
from repro.dist import context as dist_context
from repro.dist import sharding as dist_sharding
from repro.models.model import Model
from repro.models.transformer import paged_cache_supported
from repro.obs.trace import Tracer
from repro.serve.lifecycle import AdmissionImpossibleError, ServeStallError
from repro.serve.paged import (PageAllocator, PrefixIndex, page_keys,
                               partial_key)

_MIN_BUCKET = 4


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    eos_id: int = -1              # -1: never
    out_tokens: Optional[List[int]] = None
    t_submit: float = 0.0         # set by submit()
    t_first: float = 0.0          # set when the first token lands (TTFT)
    t_done: float = 0.0           # set when the request completes (e2e)
    # per-token inter-token latency (seconds): one entry per decoded token
    # after the first, mirroring what lands in serve_itl_window_seconds —
    # the raw list serve_bench cross-checks the windowed percentiles against
    itl_s: Optional[List[float]] = None


@dataclasses.dataclass
class _PagedSeq:
    """Paged-mode bookkeeping for one in-flight request."""
    n: int                        # prompt length
    pages: List[int]              # pool page ids for logical pages 0..k-1
    keys: List[bytes]             # chain keys of the FULL prompt pages
    pkey: Optional[bytes]         # key of the terminal partial page (if any)
    filled: int                   # leading prompt rows already in the pool
    compute_next: int             # next prompt token index to run
    shared_tail: bool             # pages[-1] attached shared -> COW on write
    reserve: int                  # pages reserved (admission) not yet alloc'd
    registered: int = 0           # full prompt pages published to the index


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0                  # tokens currently in this slot's cache rows
    remaining: int = 0
    seq: Optional[_PagedSeq] = None   # paged mode only


def _cache_batch_axes(model: Model, batch: int, max_len: int):
    """Locate the batch axis of every cache leaf STRUCTURALLY: the axis whose
    size changes when init_cache's batch argument changes. Unlike sniffing for
    a dim that equals the slot count, this can never confuse a stacked layer
    (or head/state) dim that happens to equal the number of slots."""
    c_a = jax.eval_shape(lambda: model.init_cache(batch, max_len))
    c_b = jax.eval_shape(lambda: model.init_cache(batch + 1, max_len))

    def axis(a, b):
        return next(i for i, (sa, sb) in enumerate(zip(a.shape, b.shape))
                    if sa != sb)

    return jax.tree.map(axis, c_a, c_b)


def _cache_supports_buckets(model: Model, batch: int, max_len: int) -> bool:
    """Bucketed prefill needs every cache leaf to have a sequence axis (one
    that scales with max_len) so masked prefill-at-offset-0 commits exactly
    the prompt rows. SSM/hybrid state and encoder cross-KV leaves don't
    (their state is a running summary, not addressable rows), so those
    families fall back to the per-slot scatter prefill."""
    c_a = jax.eval_shape(lambda: model.init_cache(batch, max_len))
    c_b = jax.eval_shape(lambda: model.init_cache(batch, max_len + 1))
    return all(
        any(sa != sb for sa, sb in zip(a.shape, b.shape))
        for a, b in zip(jax.tree.leaves(c_a), jax.tree.leaves(c_b)))


class BatchServer:
    """Single-host reference implementation (the multi-pod serve path lowers
    the same decode step through launch/dryrun.py).

    ``decode_chunk`` is the fused-decode knob: steps per decode dispatch
    (1 = classic one-round-trip-per-token lockstep). ``prefill_buckets``
    enables bucketed batched prefill where the cache layout supports it.
    """

    def __init__(self, model: Model, *, batch_slots: int, max_len: int,
                 greedy: bool = True, quantized: bool = False,
                 gemm_algo: str = "ffip", gemm_impl: Optional[str] = None,
                 gemm_block=None, decode_chunk: int = 1,
                 prefill_buckets: bool = True, paged: bool = False,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 paged_attention: str = "gather",
                 prefix_sharing: bool = True, mesh=None,
                 moe_partition: str = "expert", prepared=None,
                 clock=None, registry=None, tracer=None,
                 trace_capacity: int = 4096, obs_window_s: float = 30.0):
        if not greedy:
            raise NotImplementedError("only greedy decoding is implemented")
        if decode_chunk < 1:
            raise ValueError(f"decode_chunk must be >= 1, got {decode_chunk}")
        if mesh is not None and paged:
            raise NotImplementedError(
                "paged=True with mesh= is not supported yet (the page pool "
                "is host-managed per device); use the contiguous cache for "
                "tensor-parallel serving")
        if prepared is not None:
            if prepared.kind != "lm":
                raise ValueError(
                    f"BatchServer needs an 'lm' artifact, got "
                    f"{prepared.kind!r}")
            if quantized and not prepared.quantized:
                raise ValueError(
                    "quantized=True but the prepared artifact carries no "
                    "int8 weights — re-run `python -m repro.launch.prepare "
                    "--quantized`")
        self.model = model
        self.b = batch_slots
        self.max_len = max_len
        self.decode_chunk = decode_chunk
        self.paged = paged
        self.quantized = quantized   # the router's tier tag (shed policy)
        self.tier = "int8" if quantized else "float"
        self.obs_window_s = obs_window_s  # sliding-window span for TTFT/ITL
        # dist x serve: `mesh` turns on tensor-parallel decode. Params and
        # cache are placed through the repro.dist rule engine (column/row-
        # parallel projections + KV-head sharding on the "model" axis,
        # expert- or ffn-parallel MoE banks per `moe_partition`) and every
        # dispatch traces under the ambient mesh so flash attention's
        # shard_map engages. The specs never split a kernel's K contraction
        # in integer paths, so int8-FFIP decode stays bit-exact; output
        # TOKENS are identical to single-device for float too (launch/serve
        # --compare-single-device asserts it end to end).
        self.mesh = mesh
        self.moe_partition = moe_partition
        self.prepared = prepared
        # -- observability (repro.obs) --------------------------------------
        # Every wall-clock read in this class goes through `_clock` — inject
        # a serve.faults.FakeClock (like ReplicaRouter takes) and all stats /
        # histograms / span timestamps become deterministic on fake time.
        self._clock = clock if clock is not None else obs.default_clock
        self.registry = (registry if registry is not None
                         else obs.get_registry())
        self.tracer = tracer if tracer is not None else Tracer(
            clock=self._clock, capacity=trace_capacity)
        # The router relabels per replica via set_obs_labels() and sets
        # trace_requests=False (it owns the per-rid root "request" span —
        # two roots per rid would split the tree).
        self.trace_requests = True
        self._req_spans: Dict[int, Any] = {}
        self.set_obs_labels({"replica": "solo"})
        self.slots = [_Slot() for _ in range(batch_slots)]
        self._queue: "collections.deque[Request]" = collections.deque()
        self._completed: List[Request] = []
        # idempotency: rid -> (payload key, tokens) for finished requests
        # (bounded LRU); duplicate submits of an INFLIGHT rid wait here and
        # are completed from the original's tokens without a second decode.
        self._results: "collections.OrderedDict[int, Tuple[tuple, List[int]]]" \
            = collections.OrderedDict()
        self._result_cache_size = 1024
        self._dup_waiters: Dict[int, List[Request]] = {}
        self._cached_hits: List[Request] = []
        if paged:
            if page_size < 1 or (page_size & (page_size - 1)):
                raise ValueError(f"page_size must be a power of two, "
                                 f"got {page_size}")
            if max_len % page_size:
                raise ValueError(f"max_len ({max_len}) must be a multiple of "
                                 f"page_size ({page_size})")
            if not paged_cache_supported(model.cfg):
                raise ValueError("paged=True requires a pure-attention "
                                 f"decoder (family={model.cfg.family!r})")
            if paged_attention not in ("gather", "flash"):
                raise ValueError(f"paged_attention must be 'gather' or "
                                 f"'flash', got {paged_attention!r}")
            self.page_size = page_size
            self.max_pages = max_len // page_size
            self.num_pages = (num_pages if num_pages is not None
                              else batch_slots * self.max_pages)
            self.prefill_chunk = prefill_chunk or max_len
            if (self.prefill_chunk % page_size
                    or not 0 < self.prefill_chunk <= max_len):
                raise ValueError(
                    f"prefill_chunk ({self.prefill_chunk}) must be a "
                    f"page-aligned length in (0, max_len]")
            self.paged_attention = paged_attention
            self.prefix_sharing = prefix_sharing
            self.alloc = PageAllocator(self.num_pages)
            self.prefix = PrefixIndex(self.alloc)
            self._reserved = 0          # pages promised to admitted requests
            self.cache = model.init_paged_cache(self.num_pages, page_size)
            self._bucketed = False
            self._batch_axes = None
            self._decode_paged = jax.jit(self._decode_paged_impl,
                                         donate_argnums=(2,))
            self._prefill_chunk_fn = jax.jit(self._prefill_chunk_impl,
                                             donate_argnums=(2,))
            self._copy_page = jax.jit(
                lambda cache, src, dst: jax.tree.map(
                    lambda leaf: leaf.at[:, dst].set(leaf[:, src]), cache),
                donate_argnums=(0,))
        else:
            self.cache = model.init_cache(batch_slots, max_len)
            self._bucketed = (prefill_buckets
                              and _cache_supports_buckets(model, batch_slots,
                                                          max_len))
            self._batch_axes = (None if self._bucketed else
                                _cache_batch_axes(model, batch_slots, max_len))
            if mesh is not None:
                specs = dist_sharding.cache_specs(self.cache, mesh,
                                                  batch=batch_slots)
                self.cache = jax.device_put(
                    self.cache, dist_sharding.to_named(specs, mesh))
        # GEMM provider scope for the whole serving forward. ``gemm_impl``
        # ("pallas") routes the projections through the Pallas kernels and
        # ``gemm_block`` ("auto" / explicit (bm,bn,bk)) picks their tiling
        # from the repro.tune schedule cache — so the PR 3 hot path runs
        # under tuned blocks instead of one hardcoded constant. block="auto"
        # also drives tuned flash-attention (bq, bk) during prefill, which is
        # why a config is built even when impl stays "xla".
        if quantized or gemm_impl is not None or gemm_block is not None:
            impl = gemm_impl or "xla"
            if (gemm_block is not None and gemm_block != "auto"
                    and impl != "pallas"):
                # explicit (bm,bn,bk) only reaches a kernel through the
                # pallas provider; on xla it would be a silent no-op — the
                # exact failure mode the tuner exists to remove.
                raise ValueError(
                    "explicit gemm_block requires gemm_impl='pallas' "
                    "(block='auto' alone is fine: it also drives flash "
                    "attention's tuned blocks)")
            algo = gemm_algo if (quantized or impl == "pallas") else "baseline"
            self._gemm_cfg = GemmConfig(algo=algo, impl=impl,
                                        quantized=quantized, block=gemm_block)
        else:
            self._gemm_cfg = None
        self._qparams = None
        self._qparams_src = None
        self._placed = None
        self._placed_src = None
        self._decode = jax.jit(self._decode_impl, donate_argnums=(2,))
        # bucketed: one jit entry per power-of-2 prompt bucket.
        # fallback: batch-1 prefill scattered into the slot's cache rows
        # (one entry per distinct prompt length).
        self._prefill_bucket = jax.jit(self._prefill_bucket_impl,
                                       donate_argnums=(2,))
        self._prefill_one = jax.jit(self._prefill_impl, donate_argnums=(2,))
        # trace counts survive run_until_drained's stats reset: the jit cache
        # is a server-lifetime property (the compile-count regression test and
        # serve_bench read these directly).
        self.compiles: Dict[str, int] = {"prefill": 0, "decode": 0}
        self.stats: Dict[str, Any] = self._fresh_stats()

    @staticmethod
    def _fresh_stats() -> Dict[str, Any]:
        """Reset contract (enforced by test_obs): EVERY key in this dict is
        PER-DRAIN — :meth:`run_until_drained` replaces ``self.stats`` with a
        fresh copy at entry, so after a drain the dict describes that drain
        only (``pages_peak`` is the peak within the drain: the allocator's
        lifetime peak lives in ``alloc.peak_in_use``). Cumulative-across-
        drains state lives elsewhere, by design: ``compiles`` (jit cache is
        a server-lifetime property), the ``repro.obs`` metrics this class
        mirrors into (monotone counters/histograms in ``self.registry``),
        and the span ring in ``self.tracer``. Per-tick callers
        (:meth:`step` via the router) never reset anything."""
        return {"prefill_s": 0.0, "decode_s": 0.0, "steps": 0,
                "prefill_tokens": 0, "decode_tokens": 0,
                "prefill_dispatches": 0, "decode_dispatches": 0,
                "host_bytes_prefill": 0, "host_bytes_decode": 0,
                # paged-mode extras (zero in contiguous mode). Page-table
                # uploads get their OWN byte counter so the contiguous
                # host-bytes accounting keeps its exact per-dispatch formula.
                "host_bytes_page_tables": 0, "prefill_chunks": 0,
                "prefix_hit_tokens": 0, "cow_copies": 0,
                "pages_in_use": 0, "pages_peak": 0}

    # -- observability ------------------------------------------------------
    def set_obs_labels(self, labels: Dict[str, str]) -> None:
        """(Re)bind this server's metric children. Standalone servers carry
        ``{"replica": "solo"}``; the router rebinds each to its index."""
        self.obs_labels = dict(labels)
        r = self.registry
        rep = self.obs_labels.get("replica", "solo")
        lab = ("replica", "phase")
        self._m_dispatch = {
            p: r.counter("serve_dispatches_total",
                         "device dispatches", lab).labels(replica=rep,
                                                          phase=p)
            for p in ("prefill", "decode")}
        self._m_tokens = {
            p: r.counter("serve_tokens_total",
                         "tokens prefilled / decoded", lab).labels(
                             replica=rep, phase=p)
            for p in ("prefill", "decode")}
        self._m_dispatch_s = {
            p: r.histogram("serve_dispatch_seconds",
                           "wall time per device dispatch", lab).labels(
                               replica=rep, phase=p)
            for p in ("prefill", "decode")}
        self._m_compiles = {
            p: r.counter("serve_compiles_total",
                         "jit traces (server-lifetime, never reset)",
                         lab).labels(replica=rep, phase=p)
            for p in ("prefill", "decode")}
        self._m_host_bytes = {
            p: r.counter("serve_host_bytes_total",
                         "bytes crossing the device->host boundary", lab)
            .labels(replica=rep, phase=p)
            for p in ("prefill", "decode", "page_tables")}
        self._m_e2e = r.histogram(
            "serve_request_e2e_seconds", "submit -> done", ("replica",)
        ).labels(replica=rep)
        self._m_ttft = r.histogram(
            "serve_request_ttft_seconds", "submit -> first token",
            ("replica",)).labels(replica=rep)
        self._m_pages = r.gauge(
            "serve_pages_in_use", "page-pool pages currently referenced",
            ("replica",)).labels(replica=rep)
        self._m_prefix_hits = r.counter(
            "serve_prefix_hit_tokens_total",
            "prompt tokens skipped via prefix sharing", ("replica",)
        ).labels(replica=rep)
        self._m_cow = r.counter(
            "serve_cow_copies_total", "copy-on-write page copies",
            ("replica",)).labels(replica=rep)
        # sliding-window phase attribution (the SLO-facing latencies):
        # TTFT and per-token inter-token latency over the last
        # `obs_window_s` seconds, labeled by replica AND tier so a mixed
        # float/int8 fleet reads per-tier percentiles off one family
        wlab = ("replica", "tier")
        self._w_ttft = r.windowed_histogram(
            "serve_ttft_window_seconds",
            "submit -> first token, sliding window", wlab,
            window_s=self.obs_window_s, clock=self._clock
        ).labels(replica=rep, tier=self.tier)
        self._w_itl = r.windowed_histogram(
            "serve_itl_window_seconds",
            "per-token inter-token latency, sliding window", wlab,
            window_s=self.obs_window_s, clock=self._clock
        ).labels(replica=rep, tier=self.tier)

    @property
    def events(self) -> List[Tuple]:
        """Legacy dispatch-interleaving view, reconstructed from the span
        ring: ``("prefill_chunk", rid, start, end)`` and
        ``("decode", (rids...))`` tuples in dispatch order. Bounded by the
        tracer's ring capacity (the old append-only list grew without limit
        on long-running servers)."""
        out: List[Tuple] = []
        for s in self.tracer.spans:
            if s.name == "prefill_chunk":
                out.append(("prefill_chunk", s.attrs["rid_int"],
                            s.attrs["start"], s.attrs["end"]))
            elif s.name == "decode" and "rids" in s.attrs:
                out.append(("decode", tuple(s.attrs["rids"])))
        return out

    def _end_req_span(self, rid: int, **attrs) -> None:
        span = self._req_spans.pop(rid, None)
        if span is not None:
            self.tracer.end(span, **attrs)

    # -- quantized decode mode / mesh scope --------------------------------
    def _gemm_scope(self):
        """Trace/serving-time scope around every dispatch: the GEMM provider
        (FFIP int8 when quantized) plus, under ``mesh=``, the ambient dist
        mesh so tuned-flash shard_map and NamedSharding resolution engage at
        trace time."""
        stack = contextlib.ExitStack()
        if self.mesh is not None:
            stack.enter_context(dist_context.mesh_context(self.mesh))
        if self._gemm_cfg is not None:
            stack.enter_context(use_gemm(self._gemm_cfg))
        return stack

    def _params_for(self, params):
        """Resolve the run-ready param tree for a dispatch.

        Preference order: an injected ``prepared`` artifact (warm start —
        zero re-quantization/re-encode, `repro.prepare`'s counters prove it);
        else, when a GEMM config is active, a `prepare.prepare_lm` tree built
        once per distinct params object (the former private attach path,
        now a thin wrapper over repro.prepare); else the float params as-is.
        Under ``mesh=`` the result is placed through dist.param_specs once
        per distinct tree."""
        if self.prepared is not None:
            p = self.prepared.params
        elif self._gemm_cfg is None:
            p = params
        else:
            if self._qparams_src is not params:
                from repro import prepare
                self._qparams = prepare.prepare_lm(
                    params, quantized=True, y_deltas=False).params
                self._qparams_src = params
            p = self._qparams
        if self.mesh is not None:
            if self._placed_src is not p:
                specs = dist_sharding.param_specs(
                    p, self.mesh, moe_partition=self.moe_partition)
                self._placed = jax.device_put(
                    p, dist_sharding.to_named(specs, self.mesh))
                self._placed_src = p
            p = self._placed
        return p

    # -- device programs ---------------------------------------------------
    def _decode_impl(self, params, last, cache, pos, live, rem, eos):
        self.compiles["decode"] += 1    # side effect runs at trace time only
        self._m_compiles["decode"].inc()
        return self.model.sample_steps(params, last, cache, pos, live, rem,
                                       eos, steps=self.decode_chunk)

    def _prefill_bucket_impl(self, params, tokens, cache, lengths, mask):
        self.compiles["prefill"] += 1   # once per bucket length
        self._m_compiles["prefill"].inc()
        return self.model.prefill_sample(params, tokens, cache, lengths, mask)

    def _prefill_impl(self, params, tokens, cache, slot_idx):
        # fallback (SSM/hybrid/enc-dec caches): run a batch-1 forward and
        # scatter its cache rows into slot_idx; argmax fused on device.
        self.compiles["prefill"] += 1   # once per distinct prompt length
        self._m_compiles["prefill"].inc()
        one_cache = self.model.init_cache(1, self.max_len)
        new_one, logits = self.model.prefill(params, tokens, one_cache)

        def put(full, one, axis):
            idx = [slice(None)] * full.ndim
            idx[axis] = slot_idx
            return full.at[tuple(idx)].set(
                one.squeeze(axis=axis).astype(full.dtype))

        cache = jax.tree.map(put, cache, new_one, self._batch_axes)
        return cache, jnp.argmax(logits[0]).astype(jnp.int32)

    def _decode_paged_impl(self, params, last, cache, pos, live, rem, eos,
                           page_table):
        self.compiles["decode"] += 1
        self._m_compiles["decode"].inc()
        return self.model.sample_steps(
            params, last, cache, pos, live, rem, eos,
            steps=self.decode_chunk, page_table=page_table,
            paged_impl=self.paged_attention)

    def _prefill_chunk_impl(self, params, tokens, cache, page_table, offset,
                            valid_len, write_start):
        self.compiles["prefill"] += 1   # one entry total: fixed chunk width
        self._m_compiles["prefill"].inc()
        return self.model.prefill_chunk_paged(
            params, tokens, cache, page_table, offset, valid_len,
            write_start, paged_impl=self.paged_attention)

    # -- prefill -----------------------------------------------------------
    def _bucket_len(self, n: int) -> int:
        b = _MIN_BUCKET
        while b < n:
            b *= 2
        return min(b, self.max_len)

    @staticmethod
    def cache_rows(prompt_len: int, max_new_tokens: int) -> int:
        """Cache rows a request can ever occupy. The prompt takes
        ``prompt_len`` rows; each DECODE STEP writes one more — and the final
        sampled token is emitted without a step following it, so it never
        writes a row. ``max_new_tokens`` new tokens therefore need only
        ``max_new_tokens - 1`` rows beyond the prompt (paged admission sizes
        its page reservation from the same formula)."""
        return prompt_len + max(max_new_tokens, 1) - 1

    @staticmethod
    def _req_key(req: Request) -> tuple:
        """Payload identity for idempotent rids: same rid MUST mean same
        work, or the cached-completion contract would silently lie."""
        return (np.asarray(req.prompt, np.int64).tobytes(),
                int(req.max_new_tokens), int(req.eos_id))

    def _find_inflight(self, rid: int) -> Optional[Request]:
        for r in self._queue:
            if r.rid == rid:
                return r
        for s in self.slots:
            if s.req is not None and s.req.rid == rid:
                return s.req
        return None

    def submit(self, req: Request):
        rows = self.cache_rows(len(req.prompt), req.max_new_tokens)
        if rows > self.max_len:
            raise AdmissionImpossibleError(
                f"request {req.rid}: prompt ({len(req.prompt)}) + "
                f"max_new_tokens ({req.max_new_tokens}) needs {rows} cache "
                f"rows (the last sampled token is never written) but "
                f"max_len is {self.max_len}")
        if self.paged:
            # fail fast at SUBMIT time: worst-case pages beyond the whole
            # pool can never be admitted no matter how many slots drain.
            pages = -(-rows // self.page_size)
            if pages > self.num_pages:
                raise AdmissionImpossibleError(
                    f"request {req.rid}: needs {pages} pages worst-case "
                    f"({rows} rows / page_size {self.page_size}) but the "
                    f"pool holds only {self.num_pages}")
        req.t_submit = self._clock()
        key = self._req_key(req)
        inflight = self._find_inflight(req.rid)
        if inflight is not None:
            if self._req_key(inflight) != key:
                raise AdmissionImpossibleError(
                    f"rid {req.rid} resubmitted with a different "
                    f"prompt/budget while the original is in flight")
            req.out_tokens = []
            self._dup_waiters.setdefault(req.rid, []).append(req)
            return
        hit = self._results.get(req.rid)
        if hit is not None:
            hkey, toks = hit
            if hkey != key:
                raise AdmissionImpossibleError(
                    f"rid {req.rid} resubmitted with a different "
                    f"prompt/budget than its cached completion")
            req.out_tokens = list(toks)
            req.t_first = req.t_done = self._clock()
            self.tracer.event("request", rid=str(req.rid), cached=True)
            self._cached_hits.append(req)
            return
        req.out_tokens = []
        req.itl_s = []
        if self.trace_requests and req.rid not in self._req_spans:
            self._req_spans[req.rid] = self.tracer.start(
                "request", rid=str(req.rid), prompt=len(req.prompt),
                max_new_tokens=req.max_new_tokens)
        self._queue.append(req)

    def has_queued(self) -> bool:
        return bool(self._queue)

    def _finish(self, req: Request):
        req.t_done = self._clock()
        self._m_e2e.observe(req.t_done - req.t_submit)
        if req.t_first:
            self._m_ttft.observe(req.t_first - req.t_submit)
        self._end_req_span(req.rid, tokens=len(req.out_tokens))
        self._completed.append(req)
        self._results[req.rid] = (self._req_key(req), list(req.out_tokens))
        self._results.move_to_end(req.rid)
        while len(self._results) > self._result_cache_size:
            self._results.popitem(last=False)
        for w in self._dup_waiters.pop(req.rid, []):
            w.out_tokens = list(req.out_tokens)
            w.itl_s = None if req.itl_s is None else list(req.itl_s)
            w.t_first = req.t_first
            w.t_done = req.t_done
            self._completed.append(w)

    def take_completed(self) -> List[Request]:
        """Drain the completion list (the router's per-tick collection path;
        run_until_drained keeps accumulating instead)."""
        done, self._completed = self._completed, []
        return done

    def abort(self, rid: int) -> bool:
        """Remove a request wherever it lives — queue, slot, or the
        idempotency cache — releasing every resource it held. A paged
        request's pages are decref'd and its admission reservation is
        returned (the ledger drains to 0), with prefix pages published only
        up to the rows actually COMPUTED, so an aborted prefill never
        poisons the prefix index. The cached result (if any) is dropped too:
        after an abort, a resubmitted rid recomputes from scratch. Returns
        True if anything was removed."""
        found = self._results.pop(rid, None) is not None
        for i, r in enumerate(self._queue):
            if r.rid == rid:
                del self._queue[i]
                found = True
                break
        else:
            for slot in self.slots:
                if slot.req is not None and slot.req.rid == rid:
                    if slot.seq is not None:
                        self._release_seq(slot, upto=slot.seq.filled)
                    slot.req = None
                    slot.pos = 0
                    slot.remaining = 0
                    found = True
                    break
        # duplicates that were waiting on the aborted original become
        # first-class queued requests (their payload is identical).
        for w in self._dup_waiters.pop(rid, []):
            self._queue.appendleft(w)
        if found:
            self._end_req_span(rid, aborted=True)
        return found

    # -- router-facing load/health introspection ---------------------------
    def free_slots(self) -> int:
        return sum(1 for s in self.slots if s.req is None)

    def outstanding_rows(self) -> int:
        """Worst-case cache rows committed to requests this server holds
        (slots + internal queue) — the router's least-loaded metric."""
        rows = 0
        for s in self.slots:
            if s.req is not None:
                rows += self.cache_rows(len(s.req.prompt),
                                        s.req.max_new_tokens)
        for r in self._queue:
            rows += self.cache_rows(len(r.prompt), r.max_new_tokens)
        return rows

    def page_headroom(self) -> Optional[int]:
        """Upper bound on pages a NEW request could still claim (free pages
        minus outstanding reservations, plus prefix-index entries that
        admission may evict). None in contiguous mode."""
        if not self.paged:
            return None
        return self.alloc.free_count - self._reserved + len(self.prefix)

    def request_phase(self, rid: int) -> Optional[str]:
        """'queued' | 'prefilling' | 'decoding' for an inflight rid, None if
        unknown (completed or never submitted). Contiguous prefill is atomic
        inside a step, so contiguous requests are never seen 'prefilling'."""
        for r in self._queue:
            if r.rid == rid:
                return "queued"
        for s in self.slots:
            if s.req is not None and s.req.rid == rid:
                if s.seq is not None and s.seq.compute_next < s.seq.n:
                    return "prefilling"
                return "decoding"
        return None

    def _place(self, slot_i: int, req: Request, first: int):
        """Post-prefill bookkeeping shared by all prefill paths."""
        req.out_tokens.append(first)
        req.t_first = self._clock()
        self._w_ttft.observe(req.t_first - req.t_submit)
        slot = self.slots[slot_i]
        if req.max_new_tokens <= 1 or first == req.eos_id:
            # finished at prefill (token budget of 1, or EOS on the first
            # token): releases the slot immediately — admission keeps going.
            self._finish(req)
            if slot.seq is not None:
                self._release_seq(slot)
            slot.req = None
            return
        slot.req = req
        slot.pos = len(req.prompt)   # prompt rows in cache; the first
        slot.remaining = req.max_new_tokens - 1   # generated token is in
        # flight and will be written at row `pos` by the next decode step

    def _admit(self, params):
        if self.paged:
            self._admit_paged()
            return
        while self._queue:
            free = [i for i, s in enumerate(self.slots) if s.req is None]
            if not free:
                return
            if self._bucketed:
                self._admit_bucket(params, free)
            else:
                self._admit_one(params, free[0])

    def _admit_bucket(self, params, free: List[int]):
        """One batched prefill dispatch: the head-of-queue request's bucket,
        plus every queued request (FIFO) sharing that bucket, up to the free
        slot count. Other buckets stay queued in order for the next round.

        The dispatch always runs the forward over all B slot rows (masked-out
        rows are discarded), trading up to B× redundant prefill FLOPs on a
        single-request admission for a jit cache keyed ONLY by bucket length
        — O(log max_len) compiles total instead of O(buckets × batch sizes).
        Under load the dispatch carries several requests and the waste
        amortizes away; latency-sensitive single-stream serving can set
        ``prefill_buckets=False`` to get the batch-1 fallback."""
        bucket = self._bucket_len(len(self._queue[0].prompt))
        batch: List[Request] = []
        kept: List[Request] = []
        while self._queue and len(batch) < len(free):
            r = self._queue.popleft()
            if self._bucket_len(len(r.prompt)) == bucket:
                batch.append(r)
            else:
                kept.append(r)
        self._queue.extendleft(reversed(kept))

        tokens = np.zeros((self.b, bucket), np.int32)
        lengths = np.ones((self.b,), np.int32)
        mask = np.zeros((self.b,), bool)
        for slot_i, req in zip(free, batch):
            n = len(req.prompt)
            tokens[slot_i, :n] = req.prompt
            lengths[slot_i] = n
            mask[slot_i] = True
            self.stats["prefill_tokens"] += n
        span = self.tracer.start("prefill", bucket=bucket,
                                 rids=[r.rid for r in batch])
        t0 = self._clock()
        with self._gemm_scope():
            self.cache, first = self._prefill_bucket(
                params, jnp.asarray(tokens), self.cache,
                jnp.asarray(lengths), jnp.asarray(mask))
        first_h = np.asarray(jax.device_get(first))     # (B,) int32
        dt = self._clock() - t0
        self.tracer.end(span)
        self.stats["prefill_s"] += dt
        self.stats["prefill_dispatches"] += 1
        self.stats["host_bytes_prefill"] += int(first_h.nbytes)
        self._m_dispatch["prefill"].inc()
        self._m_dispatch_s["prefill"].observe(dt)
        self._m_tokens["prefill"].inc(sum(len(r.prompt) for r in batch))
        self._m_host_bytes["prefill"].inc(int(first_h.nbytes))
        for slot_i, req in zip(free, batch):
            self._place(slot_i, req, int(first_h[slot_i]))

    def _admit_one(self, params, slot_i: int):
        req = self._queue.popleft()
        toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
        span = self.tracer.start("prefill", rid=str(req.rid),
                                 tokens=len(req.prompt))
        t0 = self._clock()
        with self._gemm_scope():
            self.cache, first = self._prefill_one(params, toks, self.cache,
                                                  slot_i)
        first_h = int(jax.device_get(first))
        dt = self._clock() - t0
        self.tracer.end(span)
        self.stats["prefill_s"] += dt
        self.stats["prefill_tokens"] += len(req.prompt)
        self.stats["prefill_dispatches"] += 1
        self.stats["host_bytes_prefill"] += 4
        self._m_dispatch["prefill"].inc()
        self._m_dispatch_s["prefill"].observe(dt)
        self._m_tokens["prefill"].inc(len(req.prompt))
        self._m_host_bytes["prefill"].inc(4)
        self._place(slot_i, req, first_h)

    # -- paged mode --------------------------------------------------------
    def _admit_paged(self):
        """Admission is pure host bookkeeping in paged mode — no device work.
        The prompt runs later, one page-aligned chunk per :meth:`step`, via
        :meth:`_prefill_tick`. Strict FIFO: a head-of-queue request that
        cannot reserve its worst-case pages blocks the queue (it will fit
        once running requests release pages)."""
        while self._queue:
            free = [i for i, s in enumerate(self.slots) if s.req is None]
            if not free:
                return
            if not self._try_admit_paged(free[0], self._queue[0]):
                if (all(s.req is None for s in self.slots)
                        and not len(self.prefix)):
                    req = self._queue[0]
                    raise RuntimeError(
                        f"request {req.rid} needs more pages than the pool "
                        f"holds ({self.alloc.num_pages}) even with every "
                        f"slot idle — raise num_pages or lower "
                        f"max_new_tokens")
                return
            self._queue.popleft()

    def _try_admit_paged(self, slot_i: int, req: Request) -> bool:
        """Plan a request: attach shared prefix pages from the index
        (refcounted), then reserve worst-case fresh pages — evicting LRU
        index entries under pressure. All-or-nothing: on failure every
        attached page is released and the queue head stays put."""
        ps = self.page_size
        n = len(req.prompt)
        pages_needed = -(-self.cache_rows(n, req.max_new_tokens) // ps)
        keys = page_keys(req.prompt, ps) if self.prefix_sharing else []
        pkey = partial_key(req.prompt, ps) if self.prefix_sharing else None
        attached: List[int] = []
        hit = 0
        shared_tail = False
        for k in keys:                       # walk stops at the first miss:
            page = self.prefix.get(k)        # chained keys make any later
            if page is None:                 # match impossible
                break
            self.alloc.incref(page)
            attached.append(page)
            hit += ps
        if pkey is not None and len(attached) == len(keys):
            page = self.prefix.get(pkey)
            if page is not None:             # whole-prompt match incl. tail
                self.alloc.incref(page)
                attached.append(page)
                shared_tail = True
                hit = n
        # Worst-case fresh pages: everything not attached, plus one COW copy
        # if the shared tail page will be decoded into (first decode step
        # writes row n, which lives in the tail page).
        worst = (pages_needed - len(attached)
                 + (1 if shared_tail and req.max_new_tokens > 1 else 0))
        while (self.alloc.free_count - self._reserved < worst
               and len(self.prefix)):
            self.prefix.evict_lru(1)
        if self.alloc.free_count - self._reserved < worst:
            for p in attached:
                self.alloc.decref(p)
            return False
        self._reserved += worst
        self.stats["prefix_hit_tokens"] += hit
        if hit:
            self._m_prefix_hits.inc(hit)
        seq = _PagedSeq(
            n=n, pages=attached, keys=keys, pkey=pkey, filled=hit,
            # a fully shared prompt still recomputes its LAST token: the
            # first sampled token needs that hidden state (writes nothing —
            # write_start == n covers no rows).
            compute_next=min(hit, n - 1), shared_tail=shared_tail,
            reserve=worst, registered=min(len(attached), len(keys)))
        slot = self.slots[slot_i]
        slot.req = req
        slot.seq = seq
        slot.pos = 0
        slot.remaining = 0               # set by _place on the final chunk
        return True

    def _alloc_page(self, seq: _PagedSeq) -> int:
        page = self.alloc.alloc()
        assert seq.reserve > 0, "page allocated beyond admission reservation"
        seq.reserve -= 1
        self._reserved -= 1
        return page

    def _ensure_pages(self, slot: _Slot, first_row: int, end_row: int):
        """Make rows [first_row, end_row) WRITABLE: allocate missing pages
        and copy-on-write any shared page in the range (refcount > 1 means
        the prefix index and/or another sequence still reads it)."""
        if first_row >= end_row:
            return
        seq = slot.seq
        ps = self.page_size
        for li in range(first_row // ps, -(-end_row // ps)):
            if li >= len(seq.pages):
                seq.pages.append(self._alloc_page(seq))
            elif self.alloc.refcount(seq.pages[li]) > 1:
                old = seq.pages[li]
                new = self._alloc_page(seq)
                self.cache = self._copy_page(
                    self.cache, jnp.asarray(old, jnp.int32),
                    jnp.asarray(new, jnp.int32))
                self.alloc.decref(old)
                seq.pages[li] = new
                self.stats["cow_copies"] += 1
                self._m_cow.inc()

    def _register_prefix(self, seq: _PagedSeq, upto_rows: int):
        """Publish every FULL prompt page whose rows are all filled."""
        if not self.prefix_sharing:
            return
        while (seq.registered < len(seq.keys)
               and (seq.registered + 1) * self.page_size <= upto_rows):
            self.prefix.register(seq.keys[seq.registered],
                                 seq.pages[seq.registered])
            seq.registered += 1

    def _release_seq(self, slot: _Slot, *, upto: Optional[int] = None):
        """Drop a finished request's page references. Prompt pages stay
        resident through the prefix index (which holds its own reference)
        until LRU eviction; the terminal partial page is published here —
        keyed by the whole prompt — so an identical prompt resubmitted later
        skips prefill entirely. ``upto`` caps publication at the prompt rows
        actually computed (an ABORTED prefill publishes only its finished
        pages — rows past ``seq.filled`` were never written)."""
        seq = slot.seq
        upto = seq.n if upto is None else min(upto, seq.n)
        self._register_prefix(seq, upto)
        tail_li = seq.n // self.page_size
        if (self.prefix_sharing and seq.pkey is not None and upto >= seq.n
                and len(seq.pages) > tail_li):
            self.prefix.register(seq.pkey, seq.pages[tail_li])
        for p in seq.pages:
            self.alloc.decref(p)
        self._reserved -= seq.reserve
        seq.reserve = 0
        slot.seq = None

    def _prefill_tick(self, params) -> int:
        """Dispatch at most ONE page-aligned prefill chunk per mid-prefill
        slot, then return — the caller's decode dispatch runs next, so a
        long prompt admits without stalling already-active slots for more
        than one chunk's latency. Returns the number of chunks dispatched."""
        work = 0
        chunk = self.prefill_chunk
        for slot_i, slot in enumerate(self.slots):
            seq = slot.seq
            if slot.req is None or seq is None or seq.compute_next >= seq.n:
                continue
            start = seq.compute_next
            end = min(seq.n, (start // chunk + 1) * chunk)
            self._ensure_pages(slot, max(start, seq.filled), end)
            tokens = np.zeros((1, chunk), np.int32)
            tokens[0, :end - start] = slot.req.prompt[start:end]
            pt = np.zeros((1, self.max_pages), np.int32)
            pt[0, :len(seq.pages)] = seq.pages
            span = self.tracer.start("prefill_chunk", rid=str(slot.req.rid),
                                     rid_int=slot.req.rid, start=start,
                                     end=end)
            t0 = self._clock()
            with self._gemm_scope():
                self.cache, tok = self._prefill_chunk_fn(
                    params, jnp.asarray(tokens), self.cache, jnp.asarray(pt),
                    jnp.asarray(start, jnp.int32),
                    jnp.asarray(end - start, jnp.int32),
                    jnp.asarray(seq.filled, jnp.int32))
            last_chunk = end >= seq.n
            if last_chunk:                   # token only meaningful here
                first = int(jax.device_get(tok))
                self.stats["host_bytes_prefill"] += 4
                self._m_host_bytes["prefill"].inc(4)
            dt = self._clock() - t0
            self.tracer.end(span)
            self.stats["prefill_s"] += dt
            self.stats["prefill_tokens"] += end - start
            self.stats["prefill_dispatches"] += 1
            self.stats["prefill_chunks"] += 1
            self.stats["host_bytes_page_tables"] += int(pt.nbytes)
            self._m_dispatch["prefill"].inc()
            self._m_dispatch_s["prefill"].observe(dt)
            self._m_tokens["prefill"].inc(end - start)
            self._m_host_bytes["page_tables"].inc(int(pt.nbytes))
            seq.compute_next = end
            seq.filled = max(seq.filled, end)
            self._register_prefix(seq, seq.filled)
            work += 1
            if last_chunk:
                self._place(slot_i, slot.req, first)
        return work

    def _refresh_page_stats(self):
        self.stats["pages_in_use"] = self.alloc.in_use
        self.stats["pages_peak"] = self.alloc.peak_in_use
        self._m_pages.set(self.alloc.in_use)

    # -- decode ------------------------------------------------------------
    def step(self, params) -> int:
        """One fused decode dispatch (``decode_chunk`` lockstep steps) over
        all active slots; in paged mode, preceded by at most one prefill
        CHUNK per mid-prefill slot (chunked prefill interleaves with decode
        instead of stalling it). Returns #active decode slots plus #prefill
        chunks dispatched."""
        if self._cached_hits:   # idempotent duplicates: cached completions
            self._completed.extend(self._cached_hits)
            self._cached_hits.clear()
        params = self._params_for(params)
        self._admit(params)
        prefill_work = self._prefill_tick(params) if self.paged else 0
        # mid-prefill paged slots hold remaining == 0 and sit out the decode
        # dispatch; contiguous occupancy always implies remaining >= 1.
        active = [i for i, s in enumerate(self.slots)
                  if s.req is not None and s.remaining > 0]
        if not active:
            if self.paged:
                self._refresh_page_stats()
            return prefill_work
        last = np.zeros((self.b,), np.int32)
        pos = np.zeros((self.b,), np.int32)
        live = np.zeros((self.b,), bool)
        rem = np.zeros((self.b,), np.int32)
        eos = np.full((self.b,), -1, np.int32)
        for i in active:
            slot = self.slots[i]
            last[i] = slot.req.out_tokens[-1]
            pos[i] = slot.pos
            live[i] = True
            rem[i] = slot.remaining
            eos[i] = slot.req.eos_id
        # per-slot position vector: slot i writes KV at row pos[i] and masks
        # rows >= pos[i] + 1; inactive/frozen slots re-write their own row
        # with unchanged values, so the cache stays bit-identical to
        # sequential decode across the whole chunk. (Paged mode instead GATES
        # frozen slots' writes off — pool rows can be shared.)
        span = self.tracer.start(
            "decode", rids=[self.slots[i].req.rid for i in active],
            chunk=self.decode_chunk)
        if self.paged:
            for i in active:
                slot = self.slots[i]
                self._ensure_pages(slot, slot.pos,
                                   slot.pos + min(self.decode_chunk,
                                                  slot.remaining))
            pt = np.zeros((self.b, self.max_pages), np.int32)
            for i in active:
                seq = self.slots[i].seq
                pt[i, :len(seq.pages)] = seq.pages
            t0 = self._clock()
            with self._gemm_scope():
                self.cache, toks = self._decode_paged(
                    params, jnp.asarray(last), self.cache,
                    jnp.asarray(pos), jnp.asarray(live), jnp.asarray(rem),
                    jnp.asarray(eos), jnp.asarray(pt))
            self.stats["host_bytes_page_tables"] += int(pt.nbytes)
            self._m_host_bytes["page_tables"].inc(int(pt.nbytes))
        else:
            t0 = self._clock()
            with self._gemm_scope():
                self.cache, toks = self._decode(
                    params, jnp.asarray(last), self.cache,
                    jnp.asarray(pos), jnp.asarray(live), jnp.asarray(rem),
                    jnp.asarray(eos))
        toks_h = np.asarray(jax.device_get(toks))       # (chunk, B) int32
        dt = self._clock() - t0
        self.tracer.end(span)
        self.stats["decode_s"] += dt
        self.stats["decode_dispatches"] += 1
        self.stats["host_bytes_decode"] += int(toks_h.nbytes)
        self._m_dispatch["decode"].inc()
        self._m_dispatch_s["decode"].observe(dt)
        self._m_host_bytes["decode"].inc(int(toks_h.nbytes))
        # replay the device's (eos, remaining) bookkeeping on the host to
        # recover which of the chunk tokens were actually emitted per slot.
        # Inter-token attribution: a fused chunk of k steps lands host-side
        # as one dispatch, so each token in it is charged dt / k.
        step_dt = dt / toks_h.shape[0]
        for j in range(toks_h.shape[0]):
            emitted = 0
            for i in active:
                slot = self.slots[i]
                if slot.req is None:
                    continue
                nxt = int(toks_h[j, i])
                slot.req.out_tokens.append(nxt)
                self._w_itl.observe(step_dt)
                if slot.req.itl_s is not None:
                    slot.req.itl_s.append(step_dt)
                slot.pos += 1
                slot.remaining -= 1
                emitted += 1
                if slot.remaining <= 0 or nxt == slot.req.eos_id:
                    self._finish(slot.req)
                    if slot.seq is not None:
                        self._release_seq(slot)
                    slot.req = None   # freed -> next _admit refills it
            if emitted:
                self.stats["steps"] += 1
                self.stats["decode_tokens"] += emitted
                self._m_tokens["decode"].inc(emitted)
        if self.paged:
            self._refresh_page_stats()
        return len(active) + prefill_work

    def run_until_drained(self, params, *, max_steps: int = 10_000,
                          ) -> List[Request]:
        """Step until the queue and all slots drain. Returns the finished
        requests in COMPLETION order — including requests admitted and
        completed within a single step (e.g. max_new_tokens=1). ``stats``
        describe this run only (reset here alongside the completion list);
        ``compiles`` is server-lifetime and is NOT reset.

        Hitting ``max_steps`` with requests still live raises a typed
        :class:`ServeStallError` listing every stuck request id and where it
        was wedged (queued, or its slot's position/budget) — a frozen queue
        surfaces loudly instead of returning a silently short list."""
        self._completed = []
        self.stats = self._fresh_stats()
        for _ in range(max_steps):
            if self.step(params) == 0 and not self._queue:
                break
        else:
            stuck: Dict[int, str] = {}
            for r in self._queue:
                stuck[r.rid] = "queued (never admitted)"
            for i, s in enumerate(self.slots):
                if s.req is not None:
                    phase = self.request_phase(s.req.rid) or "decoding"
                    stuck[s.req.rid] = (f"slot {i} ({phase}): pos={s.pos} "
                                        f"remaining={s.remaining}")
            if stuck:
                raise ServeStallError(
                    f"run_until_drained hit max_steps={max_steps} with "
                    f"{len(stuck)} request(s) still live", stuck=stuck)
        return self._completed
