"""Synthetic deterministic data pipeline: sharded, resumable, double-buffered.

Design mirrors a production grain/tf.data stack in miniature:
  * deterministic sample -> token mapping (counter-based threefry), so any
    (step, host) pair regenerates identical data — resumability + elastic
    re-sharding need no data checkpoint beyond the step index;
  * per-host sharding: host h of H reads batch rows [h*B/H, (h+1)*B/H);
  * double-buffered background prefetch thread (overlaps host data gen with
    device compute — the §5.1.1 memory-partitioning idea at the host level).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    vocab: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    prefetch: int = 2


class SyntheticLM:
    """Deterministic 'web text' surrogate: structured token streams (zipfian
    unigrams + local repetition) so models actually have something to learn."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        assert cfg.global_batch % cfg.n_hosts == 0
        self.local_batch = cfg.global_batch // cfg.n_hosts
        # zipfian unigram distribution (stable across processes)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        p = 1.0 / ranks
        self.unigram = (p / p.sum()).astype(np.float64)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.host_id]))
        toks = rng.choice(cfg.vocab, size=(self.local_batch, cfg.seq_len + 1),
                          p=self.unigram).astype(np.int32)
        # inject local repetition structure (learnable signal)
        rep = rng.integers(0, cfg.seq_len // 2, size=(self.local_batch,))
        for i, r in enumerate(rep):
            if r > 4:
                toks[i, r:2 * r] = toks[i, :r]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class Prefetcher:
    """Background thread generating batches ahead of consumption."""

    def __init__(self, ds: SyntheticLM, start_step: int = 0):
        self.ds = ds
        self.q: "queue.Queue" = queue.Queue(maxsize=ds.cfg.prefetch)
        self.step = start_step
        self._stop = threading.Event()
        self.t = threading.Thread(target=self._run, daemon=True)
        self.t.start()

    def _run(self):
        s = self.step
        while not self._stop.is_set():
            batch = self.ds.batch_at(s)
            while not self._stop.is_set():
                try:
                    self.q.put((s, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            s += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self.t.join(timeout=2)


def make_pipeline(cfg: ModelConfig, global_batch: int, seq_len: int,
                  *, seed: int = 0, start_step: int = 0,
                  n_hosts: int = 1, host_id: int = 0) -> Prefetcher:
    dcfg = DataConfig(global_batch=global_batch, seq_len=seq_len,
                      vocab=cfg.vocab, seed=seed, n_hosts=n_hosts,
                      host_id=host_id)
    return Prefetcher(SyntheticLM(dcfg), start_step=start_step)
